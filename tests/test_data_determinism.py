"""Data-path determinism: the two bugfixes this subsystem rode in on.

* :class:`RoundSampler` is a pure function of ``(seed, round_idx)`` — same
  round, same batches, regardless of call order, block boundaries, resume
  point, or which driver (loop, scan, events) is asking.  The historical
  sampler drew from one stateful stream and silently ignored ``round_idx``
  (``legacy_stream=True`` reproduces it, pinned here for the record).
* ``FederatedDataset.from_arrays`` derives the iid-partition seed through a
  domain-separation tag: passing ``seed`` verbatim made the partition
  permutation the *same stream* as the train/test split, correlating which
  samples land on which agent with which samples went to test.
"""
import jax.numpy as jnp
import numpy as np

from repro.core import ExperimentSpec, Experiment
from repro.data import FederatedDataset, RoundSampler
from repro.data.federated import _PARTITION_TAG, _derive_seed, partition_iid


def _data(n_agents=4, n=80, d=3, seed=0):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(n, d))
    y = np.sign(rng.normal(size=n))
    return FederatedDataset.from_arrays(x, y, n_agents, heterogeneous=True,
                                        seed=seed)


def _flat(batch):
    local, comm = batch
    return [np.asarray(a) for a in (*local, *comm)]


def _assert_batches_equal(a, b):
    for u, v in zip(_flat(a), _flat(b)):
        np.testing.assert_array_equal(u, v)


def _assert_batches_differ(a, b):
    assert any(
        not np.array_equal(u, v) for u, v in zip(_flat(a), _flat(b))
    )


# ---------------------------------------------------------------------------
# RoundSampler purity
# ---------------------------------------------------------------------------


def test_sampler_pure_in_seed_and_round():
    data = _data()
    s = RoundSampler(data, batch_size=4, t_o=2, seed=7)
    _assert_batches_equal(s(5), s(5))  # replay
    # call order cannot matter: interleave arbitrary rounds, then compare
    # round 3 against a fresh sampler that never saw the others
    s(9); s(0); s(42)
    fresh = RoundSampler(data, batch_size=4, t_o=2, seed=7)
    _assert_batches_equal(s(3), fresh(3))
    # different seed or different round: different draws
    _assert_batches_differ(s(3), s(4))
    _assert_batches_differ(s(3), RoundSampler(data, batch_size=4, t_o=2,
                                              seed=8)(3))


def test_sampler_init_probe_has_its_own_round():
    data = _data()
    s = RoundSampler(data, batch_size=4, t_o=2, seed=7)
    _assert_batches_equal(s(-1), s(-1))
    _assert_batches_differ(s(-1), s(0))


def test_sampler_resume_tail_matches_full_block():
    # checkpoint-resume shape: a run repriced/resumed from round 4 must see
    # the same tail stream as the uninterrupted run
    data = _data()
    s = RoundSampler(data, batch_size=4, t_o=2, seed=7)
    full_local, full_comm = s.sample_block(0, 10)
    head = s.sample_block(0, 4)
    tail = s.sample_block(4, 10)
    for arr, h, t in zip(
        (*full_local, *full_comm), (*head[0], *head[1]), (*tail[0], *tail[1])
    ):
        np.testing.assert_array_equal(
            np.asarray(arr), np.concatenate([np.asarray(h), np.asarray(t)])
        )
    # ... and the block draw equals sequential calls across the boundary
    for k in (3, 4, 5):
        _assert_batches_equal(
            s(k),
            (tuple(a[k] for a in full_local), tuple(a[k] for a in full_comm)),
        )


def test_legacy_stream_reproduces_stateful_sampler():
    # the historical behavior, kept behind a flag: one shared stream, the
    # round index ignored — so the same round drawn twice differs, and the
    # indices are exactly the raw default_rng(seed) integer stream
    data = _data()
    s = RoundSampler(data, batch_size=4, t_o=2, seed=7, legacy_stream=True)
    first, second = s(0), s(0)
    _assert_batches_differ(first, second)
    ref = np.random.default_rng(7)
    a, m = data.n_agents, data.samples_per_agent
    idx = ref.integers(0, m, size=(1, 3, a, 4))[0]
    expect = np.take_along_axis(data.y_train[None], idx, axis=2)
    np.testing.assert_array_equal(np.asarray(first[0][1]), expect[:2])


# ---------------------------------------------------------------------------
# Partition/split domain separation (the from_arrays regression)
# ---------------------------------------------------------------------------


def test_iid_partition_seed_is_domain_separated_from_split():
    seed, n_agents = 7, 4
    rng = np.random.default_rng(3)
    x = rng.normal(size=(100, 2))
    y = np.sign(rng.normal(size=100))
    data = FederatedDataset.from_arrays(x, y, n_agents, heterogeneous=False,
                                        seed=seed)
    # reconstruct the split exactly as from_arrays does
    order = np.random.default_rng(seed).permutation(len(y))
    test_idx, train_idx = order[:20], order[20:]
    np.testing.assert_array_equal(data.x_test, x[test_idx])
    # the partition must come from the tag-derived stream ...
    xs, ys = partition_iid(
        x[train_idx], y[train_idx], n_agents,
        seed=_derive_seed(_PARTITION_TAG, seed),
    )
    np.testing.assert_array_equal(data.x_train, xs)
    np.testing.assert_array_equal(data.y_train, ys)
    # ... NOT from the raw seed, which would alias the split stream above
    xs_old, _ = partition_iid(x[train_idx], y[train_idx], n_agents, seed=seed)
    assert not np.array_equal(data.x_train, xs_old)


def test_derive_seed_separates_tags_and_seeds():
    assert _derive_seed(_PARTITION_TAG, 7) != 7
    assert _derive_seed(_PARTITION_TAG, 7) == _derive_seed(_PARTITION_TAG, 7)
    assert _derive_seed(_PARTITION_TAG, 7) != _derive_seed(_PARTITION_TAG, 8)
    assert _derive_seed(0x1234, 7) != _derive_seed(_PARTITION_TAG, 7)


# ---------------------------------------------------------------------------
# Driver-level pins: every driver sees the same batch stream
# ---------------------------------------------------------------------------


def _run(driver, rounds=8, **spec_kw):
    from repro.models import simple as S

    data = _data(seed=1)
    spec = ExperimentSpec.create(
        algo="pisco", n_agents=data.n_agents, t_o=2, eta_l=0.1, p=0.5,
        seed=0, rounds=rounds, driver=driver, **spec_kw
    )
    exp = Experiment(
        spec,
        loss_fn=S.logreg_loss,
        params0={"w": jnp.zeros((3,), jnp.float32)},
        sampler_factory=lambda s: RoundSampler(
            data, batch_size=4, t_o=s.config.t_o, seed=s.config.seed
        ),
    )
    return exp.run()


def test_rerun_is_bit_identical():
    a, b = _run("scan"), _run("scan")
    assert a.loss == b.loss  # exact float equality, not allclose


def test_scan_block_boundaries_do_not_change_the_stream():
    a = _run("scan", block_size=8)
    b = _run("scan", block_size=3)  # blocks [0,3) [3,6) [6,8)
    np.testing.assert_array_equal(a.loss, b.loss)


def test_all_drivers_see_the_same_batches():
    from repro.sim import FREE_NETWORK

    h_loop = _run("loop")
    h_scan = _run("scan")
    h_ev = _run("events", systems=FREE_NETWORK)
    # scan and the trivial events path execute the same jitted program
    np.testing.assert_array_equal(h_scan.loss, h_ev.loss)
    # the loop driver jits per-round instead of per-block: same stream, same
    # math, tolerance only for fusion-order float differences
    np.testing.assert_allclose(h_loop.loss, h_scan.loss, rtol=1e-5, atol=1e-6)
