"""Algorithm registry, ExperimentSpec API, and scan/loop driver parity."""
import dataclasses
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import make_logreg_problem
from repro.core import (
    Experiment,
    ExperimentSpec,
    PiscoConfig,
    dense_mixing,
    get_algorithm,
    make_topology,
    register_algorithm,
    registered_algorithms,
    replicate_params,
    run_training,
    unregister_algorithm,
)
from repro.core.schedule import PeriodicSchedule
from repro.data import FederatedDataset, RoundSampler


def _problem(n=6, t_o=2):
    loss_fn, full_grad_sq, sampler_factory, d = make_logreg_problem(n_agents=n)
    mixing = dense_mixing(make_topology("ring", n))
    x0 = replicate_params({"w": jnp.zeros(d)}, n)
    return loss_fn, full_grad_sq, sampler_factory, d, mixing, x0


# ---------------------------------------------------------------------------
# Parity: the scan driver reproduces the legacy Python-loop History
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("algo", registered_algorithms())
def test_scan_driver_matches_legacy_loop(algo):
    n, rounds = 6, 13
    loss_fn, full_grad_sq, sampler_factory, d, mixing, x0 = _problem(n)
    cfg = PiscoConfig(n_agents=n, t_o=2, eta_l=0.15, eta_c=1.0, p=0.3, seed=0)
    eval_fn = lambda xb: {"grad_sq": full_grad_sq(xb)}

    def run(driver):
        return run_training(
            algo, loss_fn, x0, cfg, mixing, sampler_factory(cfg.t_o),
            rounds=rounds, eval_fn=eval_fn, eval_every=5,
            driver=driver, block_size=4,
        )

    h_loop, h_scan = run("loop"), run("scan")
    assert h_loop.is_global == h_scan.is_global
    np.testing.assert_allclose(h_loop.loss, h_scan.loss, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(
        h_loop.grad_sq_norm, h_scan.grad_sq_norm, rtol=1e-5, atol=1e-7
    )
    np.testing.assert_allclose(
        h_loop.consensus_err, h_scan.consensus_err, rtol=1e-5, atol=1e-7
    )
    assert [m["round"] for m in h_loop.eval_metrics] == [
        m["round"] for m in h_scan.eval_metrics
    ]
    for ml, ms in zip(h_loop.eval_metrics, h_scan.eval_metrics):
        np.testing.assert_allclose(
            ml["grad_sq"], ms["grad_sq"], rtol=1e-5, atol=1e-7
        )
    for field in (
        "agent_to_agent", "agent_to_server",
        "agent_to_agent_bytes", "agent_to_server_bytes",
    ):
        assert getattr(h_loop.accountant, field) == getattr(
            h_scan.accountant, field
        ), field
    assert h_loop.final_state is not None and h_scan.final_state is not None
    np.testing.assert_allclose(
        np.asarray(h_loop.final_state.x["w"]),
        np.asarray(h_scan.final_state.x["w"]),
        rtol=1e-5, atol=1e-6,
    )


@pytest.mark.parametrize(
    "network", ["bernoulli:0.35", "matching", "roundrobin:2"]
)
@pytest.mark.parametrize(
    "algo",
    [
        # pisco exercises every dynamic-path feature in the fast lane; the
        # other six (~5 s each) run in the full tier1-hypothesis lane so the
        # fast lane stays under its 5-minute budget
        a if a == "pisco" else pytest.param(a, marks=pytest.mark.slow)
        for a in registered_algorithms()
    ],
)
def test_scan_driver_matches_loop_under_dynamic_network(algo, network):
    """Same parity contract, but the network itself is time-varying (three
    TopologyProcess kinds) with m-of-n partial participation on server
    rounds.  Loss, schedule, and *realized* byte charges must agree
    round-for-round across drivers for every registered algorithm."""
    n, rounds = 5, 6
    loss_fn, _, sampler_factory, d, _, _ = _problem(n)
    spec = ExperimentSpec.create(
        algo=algo, n_agents=n, t_o=2, eta_l=0.15, eta_c=1.0, p=0.3, seed=0,
        network=network, participation=0.6,
        rounds=rounds, eval_every=4, block_size=4,
    )
    hists = {}
    for driver in ("loop", "scan"):
        hists[driver] = Experiment(
            spec.replace(driver=driver),
            loss_fn=loss_fn,
            params0={"w": jnp.zeros(d)},
            sampler_factory=lambda s: sampler_factory(s.config.t_o),
        ).run()
    h_loop, h_scan = hists["loop"], hists["scan"]
    assert h_loop.is_global == h_scan.is_global
    np.testing.assert_allclose(h_loop.loss, h_scan.loss, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(
        h_loop.consensus_err, h_scan.consensus_err, rtol=1e-5, atol=1e-7
    )
    assert (
        h_loop.accountant.per_round_bytes == h_scan.accountant.per_round_bytes
    )
    for field in (
        "agent_to_agent", "agent_to_server",
        "agent_to_agent_bytes", "agent_to_server_bytes",
    ):
        assert getattr(h_loop.accountant, field) == getattr(
            h_scan.accountant, field
        ), field


def test_scan_driver_parity_with_compression():
    n, rounds = 6, 10
    loss_fn, _, sampler_factory, d, _, x0 = _problem(n)
    spec = ExperimentSpec.create(
        algo="pisco", n_agents=n, t_o=2, eta_l=0.15, p=0.2, seed=0,
        compression="q8", rounds=rounds, eval_every=4, block_size=4,
    )
    hists = {}
    for driver in ("loop", "scan"):
        exp = Experiment(
            spec.replace(driver=driver),
            loss_fn=loss_fn,
            x0=x0,
            sampler_factory=lambda s: sampler_factory(s.config.t_o),
        )
        hists[driver] = exp.run()
    np.testing.assert_allclose(
        hists["loop"].loss, hists["scan"].loss, rtol=1e-5, atol=1e-6
    )
    assert (
        hists["loop"].accountant.agent_to_agent_bytes
        == hists["scan"].accountant.agent_to_agent_bytes
    )


# ---------------------------------------------------------------------------
# Registry: a third-party algorithm plugs in without touching trainer code
# ---------------------------------------------------------------------------


def test_third_party_algorithm_registers_and_runs():
    from repro.core.baselines import SGDState, make_stacked_value_and_grad

    name = "toy_signsgd"

    @register_algorithm(
        name, mixes_per_round=1, uses_local_updates=False,
        description="toy: gossip sign-SGD",
    )
    def _build(spec, loss_fn, cfg, mixing, *, eta=None, eta_g=1.0):
        del spec, eta_g
        eta = cfg.eta_l if eta is None else eta
        stacked_vg = make_stacked_value_and_grad(loss_fn)

        def make(mix):
            def round_fn(state, local_batches, comm_batch):
                from repro.core.pisco import RoundMetrics

                loss, g = stacked_vg(state.x, comm_batch)
                x = jax.tree.map(
                    lambda xi, gi: xi - eta * jnp.sign(gi), state.x, g
                )
                x = mix(x)
                z = jnp.zeros(())
                return SGDState(x=x, step=state.step + 1), RoundMetrics(
                    jnp.mean(loss), z, z
                )

            return round_fn

        def init(loss_fn, x0, batch0):
            del loss_fn, batch0
            return SGDState(x=x0, step=jnp.zeros((), jnp.int32))

        return init, make(mixing.gossip), make(mixing.global_avg)

    try:
        assert name in registered_algorithms()
        n = 4
        loss_fn, _, sampler_factory, d, _, _ = _problem(n)
        spec = ExperimentSpec.create(
            algo=name, n_agents=n, t_o=1, eta_l=0.05, p=0.5, seed=1,
            rounds=8, eval_every=4, driver="scan", block_size=3,
        )
        hist = Experiment(
            spec,
            loss_fn=loss_fn,
            params0={"w": jnp.zeros(d)},
            sampler_factory=lambda s: sampler_factory(s.config.t_o),
        ).run()
        assert len(hist.loss) == 8
        assert np.isfinite(hist.loss).all()
        assert hist.accountant.total == 8
        # the registry priced the byte model from the entry's CommProfile
        assert hist.accountant.total_bytes > 0
    finally:
        unregister_algorithm(name)
    with pytest.raises(ValueError, match="unknown algorithm"):
        get_algorithm(name)


def test_registry_covers_the_papers_seven():
    assert set(registered_algorithms()) >= {
        "pisco", "dsgd", "dsgt", "gossip_pga", "periodical_gt", "fedavg",
        "scaffold",
    }
    assert get_algorithm("pisco").comm.mixes_per_round == 2
    assert get_algorithm("scaffold").comm.server_payloads == 2
    assert get_algorithm("dsgd").comm.mixes_per_round == 1
    with pytest.raises(ValueError, match="already registered"):
        register_algorithm("pisco")(lambda *a, **k: None)


def test_registry_comm_profiles_agree_with_baseline_specs():
    from repro.core.baselines import BASELINES

    for name, spec in BASELINES.items():
        comm = get_algorithm(name).comm
        assert comm.server_based == spec.server_based, name
        assert comm.uses_local_updates == spec.uses_local_updates, name


def test_gossip_pga_avg_period_is_registry_data():
    """p > 0 derives the period as round(1/p); p == 0 falls back to the
    entry's explicit avg_period field (documented default 10)."""
    algo = get_algorithm("gossip_pga")
    assert algo.avg_period == 10
    cfg0 = PiscoConfig(n_agents=4, t_o=1, p=0.0)
    sched = algo.make_default_schedule(cfg0)
    assert isinstance(sched, PeriodicSchedule) and sched.period == 10
    cfg = PiscoConfig(n_agents=4, t_o=1, p=0.25)
    assert algo.make_default_schedule(cfg).period == 4
    # the field is overridable without touching any trainer code
    custom = dataclasses.replace(algo, avg_period=3)
    assert custom.make_default_schedule(cfg0).period == 3


# ---------------------------------------------------------------------------
# ExperimentSpec / Experiment / History
# ---------------------------------------------------------------------------


def test_experiment_spec_round_trips_dict_and_json():
    spec = ExperimentSpec.create(
        algo="dsgt", n_agents=8, t_o=3, eta_l=0.2, p=0.15, seed=7,
        topology="er", topology_kwargs={"p_edge": 0.5, "seed": 3},
        compression="q4", rounds=40, eval_every=5, driver="scan",
        block_size=8,
    )
    assert ExperimentSpec.from_dict(spec.to_dict()) == spec
    assert ExperimentSpec.from_json(spec.to_json()) == spec
    # json payload is plain data
    payload = json.loads(spec.to_json())
    assert payload["config"]["p"] == 0.15
    assert payload["topology_kwargs"] == {"p_edge": 0.5, "seed": 3}


def test_experiment_spec_replace_routes_config_fields():
    spec = ExperimentSpec.create(algo="pisco", n_agents=4, p=0.1)
    assert spec.replace(p=0.9).config.p == 0.9
    assert spec.replace(rounds=7).rounds == 7
    with pytest.raises(ValueError, match="unknown algorithm"):
        ExperimentSpec.create(algo="nope", n_agents=4)


def test_experiment_sweep_seeds_matches_individual_runs():
    """The vmapped multi-seed sweep reproduces per-seed sequential runs."""
    n, rounds = 4, 6
    loss_fn, _, sampler_factory, d, _, _ = _problem(n)
    spec = ExperimentSpec.create(
        algo="pisco", n_agents=n, t_o=1, eta_l=0.1, p=0.5, seed=0,
        rounds=rounds, eval_every=3, driver="scan", block_size=3,
    )
    factory = lambda s: sampler_factory(s.config.t_o, seed=s.config.seed)
    exp = Experiment(
        spec, loss_fn=loss_fn, params0={"w": jnp.zeros(d)},
        sampler_factory=factory,
    )
    seeds = [0, 1]
    swept = exp.sweep(seeds=seeds)
    for seed, hist in zip(seeds, swept):
        # a sequential run whose *data* seed matches, sharing the spec's
        # schedule seed (the sweep advances all seeds through one realized
        # schedule)
        solo = Experiment(
            spec.replace(seed=seed), loss_fn=loss_fn,
            params0={"w": jnp.zeros(d)}, sampler_factory=factory,
        ).run()
        # schedules may differ (solo draws from its own seed) — so compare
        # only when the realized schedules agree
        if solo.is_global == hist.is_global:
            np.testing.assert_allclose(
                solo.loss, hist.loss, rtol=1e-5, atol=1e-6
            )
        assert len(hist.loss) == rounds
        assert np.isfinite(hist.loss).all()
        assert hist.final_state is not None


def test_experiment_sweep_grid():
    n = 4
    loss_fn, _, sampler_factory, d, _, _ = _problem(n)
    spec = ExperimentSpec.create(
        algo="dsgd", n_agents=n, t_o=1, eta_l=0.1, p=0.0, seed=0,
        rounds=5, driver="scan", block_size=5,
    )
    exp = Experiment(
        spec, loss_fn=loss_fn, params0={"w": jnp.zeros(d)},
        sampler_factory=lambda s: sampler_factory(s.config.t_o),
    )
    out = exp.sweep(grid={"p": [0.0, 1.0]})
    assert [s.config.p for s, _ in out] == [0.0, 1.0]
    # dsgd keeps its never-schedule regardless of p; fedavg-style always
    # schedules come from the registry entry, not the grid
    for _, hist in out:
        assert len(hist.loss) == 5


def test_history_to_dict_is_json_serializable():
    n = 4
    loss_fn, full_grad_sq, sampler_factory, d, mixing, x0 = _problem(n)
    cfg = PiscoConfig(n_agents=n, t_o=1, eta_l=0.1, p=0.5, seed=0)
    hist = run_training(
        "pisco", loss_fn, x0, cfg, mixing, sampler_factory(1), rounds=4,
        eval_fn=lambda xb: {"grad_sq": full_grad_sq(xb)}, eval_every=2,
    )
    d1 = hist.to_dict()
    s = json.dumps(d1)  # must not raise
    d2 = json.loads(s)
    assert d2["loss"] == d1["loss"]
    assert all(isinstance(m["round"], int) for m in d2["eval_metrics"])
    assert "final_state" not in d1  # device data stays out of JSON
    assert hist.final_state is not None  # but is a first-class field
    assert d2["accountant"]["agent_to_agent"] == hist.accountant.agent_to_agent
    assert d2["byte_model"]["server_round_bytes"] > 0
    # adversary series serialize in their clean-run defaults (the adversarial
    # shapes are pinned in test_adversary.py)
    assert d2["adversary_mask"] is None
    assert d2["eval_per_agent"] == []
    # ... and round-trip when populated
    hist.adversary_mask = [True, False, False, False]
    hist.eval_per_agent.append(
        {"round": 2, "honest_grad_sq": 0.5, "byz_grad_sq": 1.5}
    )
    d3 = json.loads(json.dumps(hist.to_dict()))
    assert d3["adversary_mask"] == [True, False, False, False]
    assert d3["eval_per_agent"][0]["honest_grad_sq"] == 0.5


def test_round_sampler_block_matches_sequential():
    rng = np.random.default_rng(0)
    x = rng.normal(size=(40, 3)); y = np.sign(rng.normal(size=40))
    data = FederatedDataset.from_arrays(x, y, 4, heterogeneous=True)
    s1 = RoundSampler(data, batch_size=2, t_o=2, seed=5)
    s2 = RoundSampler(data, batch_size=2, t_o=2, seed=5)
    seq = [s1(k) for k in range(6)]
    blk_local, blk_comm = s2.sample_block(0, 6)
    for k in range(6):
        np.testing.assert_array_equal(np.asarray(seq[k][0][0]), np.asarray(blk_local[0][k]))
        np.testing.assert_array_equal(np.asarray(seq[k][1][1]), np.asarray(blk_comm[1][k]))
