"""Substrate tests: data pipeline, optimizers, schedules, checkpointing,
pytree utilities, spec sanitizer, HLO parser."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

# ---------------------------------------------------------------------------
# data
# ---------------------------------------------------------------------------


def test_sorted_partition_is_heterogeneous():
    from repro.data import partition_sorted, synthetic_mnist

    x, y = synthetic_mnist(2000)
    xs, ys = partition_sorted(x, y, 10)
    # each agent sees at most 2 distinct labels (sorted contiguous split)
    for a in range(10):
        assert len(np.unique(ys[a])) <= 2
    # together they cover all classes
    assert len(np.unique(ys)) == 10


def test_iid_partition_is_balanced():
    from repro.data import partition_iid, synthetic_mnist

    x, y = synthetic_mnist(5000)
    xs, ys = partition_iid(x, y, 10, seed=1)
    for a in range(10):
        assert len(np.unique(ys[a])) == 10


def test_round_sampler_shapes():
    from repro.data import FederatedDataset, RoundSampler, synthetic_a9a

    x, y = synthetic_a9a(2000)
    data = FederatedDataset.from_arrays(x, y, 8, heterogeneous=True)
    samp = RoundSampler(data, batch_size=16, t_o=3)
    (lx, ly), (cx, cy) = samp(0)
    assert lx.shape == (3, 8, 16, 124) and ly.shape == (3, 8, 16)
    assert cx.shape == (8, 16, 124) and cy.shape == (8, 16)


def test_synthetic_data_deterministic():
    from repro.data import synthetic_a9a, synthetic_lm_tokens

    x1, y1 = synthetic_a9a(100, seed=5)
    x2, y2 = synthetic_a9a(100, seed=5)
    np.testing.assert_array_equal(x1, x2)
    t1 = synthetic_lm_tokens(1000, 64, seed=2)
    t2 = synthetic_lm_tokens(1000, 64, seed=2)
    np.testing.assert_array_equal(t1, t2)
    assert t1.max() < 64 and t1.min() >= 0


# ---------------------------------------------------------------------------
# optim
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("make", ["sgd", "momentum", "adam", "adamw"])
def test_optimizers_descend_quadratic(make):
    import repro.optim as O

    opt = {
        "sgd": lambda: O.sgd(0.1),
        "momentum": lambda: O.momentum(0.05),
        "adam": lambda: O.adam(0.1),
        "adamw": lambda: O.adamw(0.1, weight_decay=0.0),
    }[make]()
    params = {"w": jnp.array([3.0, -2.0])}
    state = opt.init(params)

    def loss(p):
        return jnp.sum(p["w"] ** 2)

    for _ in range(200):
        g = jax.grad(loss)(params)
        updates, state = opt.update(g, state, params)
        params = O.apply_updates(params, updates)
    assert float(loss(params)) < 1e-2


def test_schedules_endpoints():
    import repro.optim as O

    c = O.constant(0.1)
    assert float(c(jnp.asarray(100))) == pytest.approx(0.1)
    cd = O.cosine_decay(1.0, 100, final=0.1)
    assert float(cd(jnp.asarray(0))) == pytest.approx(1.0)
    assert float(cd(jnp.asarray(100))) == pytest.approx(0.1)
    wc = O.warmup_cosine(1.0, 10, 110)
    assert float(wc(jnp.asarray(0))) == pytest.approx(0.0)
    assert float(wc(jnp.asarray(10))) == pytest.approx(1.0, abs=1e-3)


# ---------------------------------------------------------------------------
# checkpoint
# ---------------------------------------------------------------------------


def test_checkpoint_roundtrip(tmp_path):
    from repro.checkpoint import latest_checkpoint, restore_checkpoint, save_checkpoint

    tree = {
        "params": {"w": np.arange(6, dtype=np.float32).reshape(2, 3)},
        "opt": [np.zeros(2), np.ones(3)],
        "meta": (np.asarray(7),),
    }
    p1 = save_checkpoint(str(tmp_path), 10, tree)
    save_checkpoint(str(tmp_path), 20, tree)
    assert latest_checkpoint(str(tmp_path)).endswith("ckpt_20.npz")
    step, restored = restore_checkpoint(p1)
    assert step == 10
    np.testing.assert_array_equal(restored["params"]["w"], tree["params"]["w"])
    np.testing.assert_array_equal(restored["opt"][1], tree["opt"][1])
    assert restored["meta"][0] == 7


def test_checkpoint_pisco_state(tmp_path):
    from repro.checkpoint import restore_checkpoint, save_checkpoint
    from repro.core.pisco import PiscoState

    state = PiscoState(
        x={"w": jnp.ones((4, 3))}, y={"w": jnp.zeros((4, 3))},
        g={"w": jnp.full((4, 3), 2.0)}, step=jnp.asarray(5, jnp.int32),
    )
    p = save_checkpoint(str(tmp_path), 5, state)
    step, tree = restore_checkpoint(p)
    x, y, g, stp, ef, opt = tree
    np.testing.assert_array_equal(x["w"], np.ones((4, 3)))
    assert int(stp) == 5
    assert ef == ()  # compression off => empty error-feedback slot
    assert opt == ()  # no update rules bound => empty optimizer slot


# ---------------------------------------------------------------------------
# pytree utils
# ---------------------------------------------------------------------------


@given(seed=st.integers(0, 50))
@settings(max_examples=15, deadline=None)
def test_tree_agent_mix_matches_matmul(seed):
    from repro.core.topology import make_topology
    from repro.utils.pytree import tree_agent_mean, tree_agent_mix

    rng = np.random.default_rng(seed)
    n = 6
    topo = make_topology("ring", n)
    tree = {"a": jnp.asarray(rng.normal(size=(n, 4))), "b": jnp.asarray(rng.normal(size=(n, 2, 3)))}
    mixed = tree_agent_mix(tree, topo.w)
    ref_a = topo.w @ np.asarray(tree["a"])  # symmetric W: X W == W X row-wise
    np.testing.assert_allclose(np.asarray(mixed["a"]), ref_a, atol=1e-5)
    avg = tree_agent_mean(tree)
    np.testing.assert_allclose(
        np.asarray(avg["a"]), np.tile(np.asarray(tree["a"]).mean(0, keepdims=True), (n, 1)),
        atol=1e-6,
    )


def test_tree_helpers():
    from repro.utils.pytree import tree_bytes, tree_size, tree_sq_norm, tree_stack, tree_unstack

    trees = [{"w": jnp.ones(3) * i} for i in range(4)]
    stacked = tree_stack(trees)
    assert stacked["w"].shape == (4, 3)
    back = tree_unstack(stacked, 4)
    assert float(back[2]["w"][0]) == 2.0
    assert tree_size(stacked) == 12
    assert tree_bytes(stacked) == 48
    assert float(tree_sq_norm({"w": jnp.array([3.0, 4.0])})) == pytest.approx(25.0)


# ---------------------------------------------------------------------------
# launch specs + HLO parsing
# ---------------------------------------------------------------------------


def test_sanitize_specs_drops_indivisible():
    from jax.sharding import PartitionSpec as P

    from repro.launch.mesh import make_debug_mesh
    from repro.launch.specs import sanitize_specs, stack_spec_tree

    mesh = make_debug_mesh((1, 1), ("data", "model"))
    # model axis size 1 always divides; fake a bigger mesh via shape math:
    import jax

    specs = {"w": P(None, "model"), "v": P("model")}
    shapes = {
        "w": jax.ShapeDtypeStruct((4, 6), jnp.float32),
        "v": jax.ShapeDtypeStruct((5,), jnp.float32),
    }
    fixed, report = sanitize_specs(specs, shapes, mesh)
    assert fixed["w"] == P(None, "model")  # 6 % 1 == 0
    stacked = stack_spec_tree(specs, ("data",))
    assert stacked["w"] == P("data", None, "model")


def test_hlo_shape_bytes_and_collectives():
    from repro.utils.hlo import collective_bytes, shape_bytes

    assert shape_bytes("f32[2,3]") == 24
    assert shape_bytes("bf16[4,4]{1,0}") == 32
    assert shape_bytes("(f32[2], s32[3])") == 8 + 12
    hlo = """
  %ar = f32[16,128]{1,0} all-reduce(%x), replica_groups={}
  %ag.1 = bf16[32,64]{1,0} all-gather(%y), dimensions={0}
  %cp = f32[8]{0} collective-permute(%z), source_target_pairs={{0,1}}
  %ars = f32[16]{0} all-reduce-start(%w)
  %ard = f32[16]{0} all-reduce-done(%ars)
  %unrelated = f32[2]{0} add(%a, %b)
"""
    out = collective_bytes(hlo)
    assert out["all-reduce"] == 16 * 128 * 4 + 16 * 4
    assert out["all-gather"] == 32 * 64 * 2
    assert out["collective-permute"] == 32
    assert out["n_all-reduce"] == 2
    assert out["total"] > 0


def test_roofline_terms():
    from repro.utils.hlo import HBM_BW, ICI_BW, PEAK_FLOPS_BF16, Roofline

    r = Roofline.from_counts(
        1e12, 1e9, 1e8, model_flops=2e14, n_chips=256
    )
    assert r.compute_s == pytest.approx(1e12 / PEAK_FLOPS_BF16)
    assert r.memory_s == pytest.approx(1e9 / HBM_BW)
    assert r.collective_s == pytest.approx(1e8 / ICI_BW)
    assert r.dominant == "compute"
    assert r.useful_ratio == pytest.approx(2e14 / (1e12 * 256))


def test_add_fsdp_axis_greedy():
    from jax.sharding import PartitionSpec as P

    from repro.launch.mesh import make_debug_mesh
    from repro.launch.specs import add_fsdp_axis

    mesh = make_debug_mesh((1, 1), ("data", "model"))
    specs = {"w": P(None, None, "model"), "n": P(None)}
    shapes = {
        "w": jax.ShapeDtypeStruct((2, 4096, 128), jnp.float32),
        "n": jax.ShapeDtypeStruct((2, 64), jnp.float32),
    }
    out = add_fsdp_axis(specs, shapes, mesh, "data", skip_leading=1)
    assert out["w"] == P(None, "data", "model")  # first big unsharded dim
    assert out["n"] == P()  # below min_dim: untouched


def test_wire_corrected_collective_bytes():
    from repro.utils.hlo import collective_bytes

    hlo = """
  %p = bf16[64]{0} parameter(0)
  %wrapped_convert = f32[64]{0} fusion(%p), kind=kLoop, calls=%cc
  %cp = f32[64]{0} collective-permute(%wrapped_convert), source_target_pairs={{0,1}}
  %native = f32[32]{0} parameter(1)
  %cp2 = f32[32]{0} collective-permute(%native), source_target_pairs={{0,1}}
"""
    out = collective_bytes(hlo)
    assert out["collective-permute"] == 64 * 4 + 32 * 4  # raw (normalized f32)
    assert out["wire_collective-permute"] == 64 * 2 + 32 * 4  # bf16 wire + f32
    assert out["total"] == out["wire_collective-permute"]
