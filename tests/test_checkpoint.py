"""Checkpoint round-trip regression tests.

The serving path depends on checkpoints being *exact*: a trained federated
final state must restore bitwise-identically (the delta exporter and the
bit-identity pin of the decode engine both assume it), and every leaf dtype
must survive — including ml_dtypes extension dtypes (bfloat16), which npz
silently erases to raw void bytes unless the manifest restores them.
"""
import jax
import jax.numpy as jnp
import ml_dtypes
import numpy as np
import pytest

from conftest import make_logreg_problem
from repro.checkpoint import (
    latest_checkpoint,
    read_manifest,
    restore_checkpoint,
    save_checkpoint,
)


def _leaves_bit_equal(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        x, y = np.asarray(x), np.asarray(y)
        assert x.dtype == y.dtype, f"dtype {x.dtype} != {y.dtype}"
        assert x.shape == y.shape
        assert x.tobytes() == y.tobytes(), "bit patterns differ"


def test_trained_state_round_trip_bitwise(tmp_path):
    """Save -> restore of a real trained PISCO final state is bitwise exact
    (namedtuple state comes back as a plain tuple, same leaf order)."""
    from repro.core import (
        PiscoConfig, dense_mixing, make_topology, replicate_params,
        run_training,
    )

    n = 4
    loss_fn, _, sampler_factory, d = make_logreg_problem(n_agents=n)
    cfg = PiscoConfig(n_agents=n, t_o=2, eta_l=0.1, eta_c=1.0, p=0.5, seed=0)
    hist = run_training(
        "pisco", loss_fn, replicate_params({"w": jnp.zeros(d)}, n), cfg,
        dense_mixing(make_topology("ring", n)), sampler_factory(2), rounds=3,
    )
    state = hist.final_state
    path = save_checkpoint(str(tmp_path), 3, state)
    step, restored = restore_checkpoint(path)
    assert step == 3
    assert isinstance(restored, tuple)
    assert len(restored) == len(state)
    _leaves_bit_equal(restored, tuple(state))
    # the serving exporter's contract: X is recoverable as field 0
    _leaves_bit_equal(restored[0], state.x)


@pytest.mark.parametrize(
    "dtype",
    [np.float32, np.float16, np.int32, np.int8, ml_dtypes.bfloat16],
    ids=["f32", "f16", "i32", "i8", "bf16"],
)
def test_dtype_preserved_through_round_trip(tmp_path, dtype):
    rng = np.random.default_rng(0)
    tree = {
        "a": rng.normal(size=(5, 3)).astype(dtype),
        "nested": [rng.normal(size=(4,)).astype(dtype)],
    }
    path = save_checkpoint(str(tmp_path), 1, tree)
    _, restored = restore_checkpoint(path)
    _leaves_bit_equal(restored, tree)


def test_mixed_dtype_tree_round_trip(tmp_path):
    tree = {
        "w": np.arange(6, dtype=np.float32).reshape(2, 3),
        "h": np.arange(4, dtype=ml_dtypes.bfloat16),
        "c": np.arange(3, dtype=np.int32),
    }
    path = save_checkpoint(str(tmp_path), 0, tree)
    _, restored = restore_checkpoint(path)
    _leaves_bit_equal(restored, tree)


def test_manifest_metadata_round_trip(tmp_path):
    meta = {"kind": "fleet", "model": {"name": "tiny", "n_layers": 2}}
    path = save_checkpoint(
        str(tmp_path), 5, {"x": np.zeros(3)}, metadata=meta
    )
    m = read_manifest(path)
    assert m["step"] == 5
    assert m["metadata"] == meta
    assert m["keys"] == ["d:x"]
    assert m["dtypes"] == ["float64"]
    # default: no metadata -> empty dict, never a KeyError
    p2 = save_checkpoint(str(tmp_path), 6, {"x": np.zeros(3)})
    assert read_manifest(p2)["metadata"] == {}


def test_latest_checkpoint_picks_max_step(tmp_path):
    assert latest_checkpoint(str(tmp_path)) is None
    for s in (2, 10, 7):
        save_checkpoint(str(tmp_path), s, {"x": np.zeros(1)})
    assert latest_checkpoint(str(tmp_path)).endswith("ckpt_10.npz")
