"""Pluggable update-rule API (DESIGN.md §10).

* ``optimizer="sgd"`` reproduces the legacy hardcoded arithmetic bit-for-bit,
  for every registered protocol, on both drivers (the acceptance pin).
* Lemma 1 (mean Y == mean G) survives momentum/Adam local rules under every
  opt-state communication policy, on both drivers.
* ExperimentSpec JSON round-trips the optimizer fields; legacy payloads
  (no optimizer keys) still load and resolve to the bit-exact SGD default.
* Combinators: chain/trace/scale_by_adam/clip compose; the unified
  ``Optimizer`` dataclass is the same object as ``UpdateRule``.
* FedOpt server rules: ``sgd(1.0)`` recovers plain averaging; FedAdam /
  FedAvgM run end-to-end and are priced as extra server payloads.
"""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import make_logreg_problem
from repro.core import Experiment, ExperimentSpec, registered_algorithms
import repro.optim as O
from repro.optim.update_rules import (
    comm_opt_state,
    make_lr_schedule,
    parse_update_rule,
    resolve_update_rules,
)

N_AGENTS = 5


def _experiment(spec, loss_fn, d, sampler_factory):
    return Experiment(
        spec,
        loss_fn=loss_fn,
        params0={"w": jnp.zeros(d)},
        sampler_factory=lambda s: sampler_factory(s.config.t_o, seed=s.config.seed),
    )


def _spec(algo="pisco", **kw):
    base = dict(
        algo=algo, n_agents=N_AGENTS, t_o=2, eta_l=0.15, eta_c=0.7, p=0.3,
        seed=0, rounds=7, eval_every=3, driver="scan", block_size=3,
    )
    base.update(kw)
    return ExperimentSpec.create(**base)


def _run(spec):
    loss_fn, _, sampler_factory, d = make_logreg_problem(n_agents=N_AGENTS)
    return _experiment(spec, loss_fn, d, sampler_factory).run()


def _assert_histories_bit_identical(h0, h1):
    assert h0.loss == h1.loss
    assert h0.grad_sq_norm == h1.grad_sq_norm
    assert h0.consensus_err == h1.consensus_err
    assert h0.is_global == h1.is_global
    assert h0.accountant.per_round_bytes == h1.accountant.per_round_bytes
    assert h0.accountant.total_bytes == h1.accountant.total_bytes


def _gt_gap(hist):
    s = hist.final_state
    ym = jax.tree.map(lambda v: jnp.mean(v, axis=0), s.y)
    gm = jax.tree.map(lambda v: jnp.mean(v, axis=0), s.g)
    return max(
        float(jnp.max(jnp.abs(a - b)))
        for a, b in zip(jax.tree.leaves(ym), jax.tree.leaves(gm))
    )


# ---------------------------------------------------------------------------
# Acceptance pin: optimizer="sgd" is bit-identical to the legacy path
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("driver", ["loop", "scan"])
@pytest.mark.parametrize("algo", registered_algorithms())
def test_sgd_rule_is_bit_identical_to_legacy(algo, driver):
    """The default sgd(eta_l) rule reproduces the hardcoded updates exactly:
    loss, grad norms, consensus, schedule, and byte accounting all match the
    pre-refactor path bit-for-bit."""
    h_legacy = _run(_spec(algo=algo, driver=driver))
    h_rule = _run(_spec(algo=algo, driver=driver, optimizer="sgd"))
    _assert_histories_bit_identical(h_legacy, h_rule)
    np.testing.assert_array_equal(
        np.asarray(h_legacy.final_state.x["w"]),
        np.asarray(h_rule.final_state.x["w"]),
    )


@pytest.mark.slow
def test_sgd_rule_bit_identical_under_dynamic_network_and_compression():
    for kw in (
        dict(network="bernoulli:0.35", participation=0.6),
        dict(compression="q8"),
    ):
        h_legacy = _run(_spec(**kw))
        h_rule = _run(_spec(optimizer="sgd", **kw))
        _assert_histories_bit_identical(h_legacy, h_rule)


# ---------------------------------------------------------------------------
# Lemma 1 under adaptive rules × opt-state policies × drivers
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("driver", ["loop", "scan"])
@pytest.mark.parametrize("policy", ["mix", "keep", "reset"])
@pytest.mark.parametrize("opt", ["momentum", "adam:lr=0.05"])
def test_lemma1_invariant_under_rules(opt, policy, driver):
    """mean(Y) == mean(G) after any round: the tracker recursion never reads
    optimizer state, and mixed/reset buffers preserve it trivially."""
    h = _run(_spec(optimizer=opt, opt_policy=policy, driver=driver))
    assert np.isfinite(h.loss).all()
    assert _gt_gap(h) < 1e-5


@pytest.mark.parametrize("algo", ["periodical_gt", "dsgt"])
def test_lemma1_invariant_for_tracking_baselines_under_momentum(algo):
    h = _run(_spec(algo=algo, optimizer="momentum:lr=0.05"))
    assert _gt_gap(h) < 1e-5


def test_rule_path_scan_matches_loop():
    """Driver parity holds on the rule path too (momentum local + FedAvgM
    server, opt-state threaded through the lax.scan carry)."""
    kw = dict(optimizer="momentum:lr=0.1", server_optimizer="fedavgm")
    h_loop = _run(_spec(driver="loop", **kw))
    h_scan = _run(_spec(driver="scan", **kw))
    _assert_histories_bit_identical(h_loop, h_scan)


# ---------------------------------------------------------------------------
# Spec round-trip + legacy payloads
# ---------------------------------------------------------------------------


def test_spec_round_trips_optimizer_fields():
    spec = _spec(
        optimizer="clip:1.0|momentum:beta=0.8",
        server_optimizer="fedadam:lr=0.05",
        lr_schedule="cosine:final=0.01",
        opt_policy="keep",
    )
    assert ExperimentSpec.from_dict(spec.to_dict()) == spec
    assert ExperimentSpec.from_json(spec.to_json()) == spec
    payload = json.loads(spec.to_json())
    assert payload["optimizer"] == "clip:1.0|momentum:beta=0.8"
    assert payload["server_optimizer"] == "fedadam:lr=0.05"
    assert payload["lr_schedule"] == "cosine:final=0.01"
    assert payload["opt_policy"] == "keep"


def test_legacy_payload_resolves_to_bit_exact_sgd_default():
    """A pre-refactor JSON payload (no optimizer keys) still loads, and runs
    bit-identically to today's default spec."""
    spec = _spec()
    payload = spec.to_dict()
    for key in ("optimizer", "server_optimizer", "lr_schedule", "opt_policy"):
        assert payload.pop(key) is None
    legacy = ExperimentSpec.from_dict(payload)
    assert legacy == spec
    _assert_histories_bit_identical(_run(legacy), _run(spec))


def test_spec_rejects_malformed_optimizer_strings():
    with pytest.raises(ValueError, match="unknown update rule"):
        _spec(optimizer="adamax")
    with pytest.raises(ValueError, match="cannot terminate"):
        _spec(optimizer="clip:1.0")
    with pytest.raises(ValueError, match="unknown lr schedule"):
        _spec(lr_schedule="step")
    with pytest.raises(ValueError, match="opt_policy"):
        _spec(opt_policy="teleport")


# ---------------------------------------------------------------------------
# Combinators + unified Optimizer dataclass
# ---------------------------------------------------------------------------


def test_optimizer_is_update_rule():
    assert O.Optimizer is O.UpdateRule
    from repro.optim.optimizers import apply_updates as legacy_apply

    assert legacy_apply is O.apply_updates


def test_chain_trace_adam_compose_and_descend():
    params = {"w": jnp.array([3.0, -2.0])}

    def loss(p):
        return jnp.sum(p["w"] ** 2)

    for rule in (
        O.chain(O.trace(0.9), O.scale_by_learning_rate(0.02)),
        O.chain(O.clip_by_global_norm(1.0), O.scale_by_adam(), O.scale(-0.1)),
        parse_update_rule("clip:0.5|adamw:lr=0.1,weight_decay=0.0"),
    ):
        p, state = params, rule.init(params)
        for _ in range(300):
            g = jax.grad(loss)(p)
            updates, state = rule.update(g, state, p)
            p = O.apply_updates(p, updates)
        assert float(loss(p)) < 1e-2, rule.name


def test_clip_by_global_norm_caps_update():
    rule = O.clip_by_global_norm(1.0)
    g = {"a": jnp.array([30.0, 40.0])}  # norm 50
    out, _ = rule.update(g, rule.init(g), None)
    np.testing.assert_allclose(np.asarray(out["a"]), [0.6, 0.8], rtol=1e-6)
    small = {"a": jnp.array([0.3, 0.4])}
    out, _ = rule.update(small, (), None)
    np.testing.assert_allclose(np.asarray(out["a"]), [0.3, 0.4], rtol=1e-6)


def test_n_buffers_metadata():
    assert O.sgd(0.1).n_buffers == 0
    assert O.momentum(0.1).n_buffers == 1
    assert O.adam(0.1).n_buffers == 2
    assert parse_update_rule("clip:1.0|adam").n_buffers == 2


def test_parse_update_rule_lr_precedence():
    # caller fallback lr when unspecified; explicit lr= wins; preset defaults
    # (fedadam -> 0.1) beat the fallback
    count = jnp.zeros((), jnp.int32)
    g = {"w": jnp.ones(2)}

    def first_step(rule):
        u, _ = rule.update(g, rule.init(g), g)
        return float(u["w"][0])

    assert first_step(parse_update_rule("sgd", lr=0.25)) == pytest.approx(-0.25)
    assert first_step(parse_update_rule("sgd:lr=0.5", lr=0.25)) == pytest.approx(-0.5)
    assert first_step(parse_update_rule("sgd:0.5", lr=0.25)) == pytest.approx(-0.5)


def test_make_lr_schedule_wires_optim_schedules():
    sched = make_lr_schedule("cosine:final=0.1", 1.0, 100)
    assert callable(sched)
    assert float(sched(jnp.asarray(0))) == pytest.approx(1.0)
    assert float(sched(jnp.asarray(100))) == pytest.approx(0.1)
    # constant / None keep the plain-float bit-exact path
    assert make_lr_schedule(None, 0.3, 100) == 0.3
    assert make_lr_schedule("constant", 0.3, 100) == 0.3


def test_lr_schedule_composes_with_explicit_lr():
    """An explicit lr= in the optimizer string is the schedule's *base*;
    the schedule still drives the steps (it must not be shadowed — the
    README's momentum:lr=0.1 + cosine combination)."""
    g = {"w": jnp.ones(3)}

    def step_mags(optimizer, n=10):
        kw = resolve_update_rules(
            optimizer, None, "linear:final=0.0", eta_l=0.5, rounds=n, t_o=0
        )
        rule = kw["local_opt"]
        state = rule.init(g)
        mags = []
        for _ in range(n):
            u, state = rule.update(g, state, g)
            mags.append(float(jnp.abs(u["w"][0])))
        return mags

    # base LR comes from the string (0.1, not eta_l=0.5) and decays to ~0
    mags = step_mags("sgd:lr=0.1")
    assert mags[0] == pytest.approx(0.1, rel=1e-5)
    assert mags[-1] == pytest.approx(0.01, rel=1e-4)  # lr at count=9
    # momentum accumulates its trace, but the first step shows the base LR
    assert step_mags("momentum:lr=0.1")[0] == pytest.approx(0.1, rel=1e-5)


def test_lr_schedule_decays_local_lr_per_round():
    """With a linear-to-zero schedule the late-round steps vanish: the final
    iterate moves less than under the constant LR."""
    h_const = _run(_spec(rounds=12))
    h_sched = _run(_spec(rounds=12, lr_schedule="linear:final=0.0"))
    assert np.isfinite(h_sched.loss).all()
    # schedules route through the rule path; histories must differ
    assert h_const.loss != h_sched.loss


# ---------------------------------------------------------------------------
# Server rules (FedOpt family)
# ---------------------------------------------------------------------------


def test_server_sgd_unit_lr_recovers_plain_averaging():
    """server sgd(1.0): x+ = avg_old + (avg_new - avg_old) == plain averaging
    up to fp association."""
    h_avg = _run(_spec(algo="fedavg"))
    h_srv = _run(_spec(algo="fedavg", server_optimizer="sgd:lr=1.0"))
    np.testing.assert_allclose(h_avg.loss, h_srv.loss, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(
        np.asarray(h_avg.final_state.x["w"]),
        np.asarray(h_srv.final_state.x["w"]),
        rtol=1e-5, atol=1e-6,
    )


def test_server_rule_prices_extra_payload():
    """A server rule ships the previous averaged iterate too: +1 payload per
    direction on server rounds; gossip pricing is untouched."""
    h0 = _run(_spec(algo="pisco"))
    h1 = _run(_spec(algo="pisco", server_optimizer="fedadam", opt_policy="keep"))
    assert h0.is_global == h1.is_global  # same realized schedule
    bm0, bm1 = h0.byte_model, h1.byte_model
    assert bm1.server_payloads == bm0.server_payloads + 1
    assert bm1.gossip_round_bytes == bm0.gossip_round_bytes
    assert bm1.server_round_bytes > bm0.server_round_bytes


def test_mix_policy_prices_buffer_streams():
    """opt_policy="mix" moves each params-shaped buffer over the network:
    momentum (+1 stream) and Adam (+2) raise the gossip-round pricing."""
    base = _run(_spec())
    mom = _run(_spec(optimizer="momentum", opt_policy="mix"))
    adam = _run(_spec(optimizer="adam", opt_policy="mix"))
    kept = _run(_spec(optimizer="momentum", opt_policy="keep"))
    assert mom.byte_model.mixes_per_round == base.byte_model.mixes_per_round + 1
    assert adam.byte_model.mixes_per_round == base.byte_model.mixes_per_round + 2
    assert kept.byte_model.mixes_per_round == base.byte_model.mixes_per_round
    assert mom.byte_model.gossip_round_bytes > base.byte_model.gossip_round_bytes


def test_fedopt_scenarios_converge_end_to_end():
    """The acceptance scenarios: momentum-local and FedAdam-server PISCO both
    train to a finite, decreasing loss through the Experiment API."""
    for kw in (
        dict(optimizer="momentum:lr=0.1"),
        dict(server_optimizer="fedadam"),
        dict(optimizer="momentum:lr=0.1", server_optimizer="fedavgm"),
    ):
        h = _run(_spec(rounds=20, **kw))
        assert np.isfinite(h.loss).all()
        assert h.loss[-1] < h.loss[0]


def test_comm_opt_state_policies():
    n = 4
    opt = {
        "count": jnp.asarray(3, jnp.int32),
        "mu": {"w": jnp.arange(8.0).reshape(n, 2)},
    }
    mean = lambda t: jax.tree.map(
        lambda v: jnp.broadcast_to(jnp.mean(v, 0, keepdims=True), v.shape), t
    )
    kept = comm_opt_state(opt, mean, n, "keep", is_global=True)
    assert kept is opt
    mixed = comm_opt_state(opt, mean, n, "mix", is_global=True)
    np.testing.assert_allclose(
        np.asarray(mixed["mu"]["w"]), np.tile([[3.0, 4.0]], (n, 1))
    )
    assert int(mixed["count"]) == 3  # scalar state never mixed
    # reset fires at server rounds only
    same = comm_opt_state(opt, mean, n, "reset", is_global=False)
    np.testing.assert_array_equal(
        np.asarray(same["mu"]["w"]), np.asarray(opt["mu"]["w"])
    )
    zeroed = comm_opt_state(opt, mean, n, "reset", is_global=True)
    assert float(jnp.sum(jnp.abs(zeroed["mu"]["w"]))) == 0.0
    assert int(zeroed["count"]) == 3
    with pytest.raises(ValueError, match="opt policy"):
        comm_opt_state(opt, mean, n, "nope")


def test_resolve_update_rules_empty_when_unset():
    assert resolve_update_rules(eta_l=0.1, rounds=10, t_o=2) == {}
    kw = resolve_update_rules(
        "momentum", "fedadam", "cosine", "keep", eta_l=0.1, rounds=10, t_o=2
    )
    assert set(kw) == {"local_opt", "server_opt", "opt_policy"}


# ---------------------------------------------------------------------------
# Registry defaults + vmapped sweep
# ---------------------------------------------------------------------------


def test_registry_entry_optimizer_defaults():
    from repro.core import get_algorithm, register_algorithm, unregister_algorithm
    from repro.core.algorithms import _build_pisco

    name = "pisco_m_test"
    register_algorithm(
        name, mixes_per_round=2, local_opt="momentum:beta=0.9",
        opt_policy="mix", description="PISCO-M: momentum local steps",
    )(_build_pisco)
    try:
        h = _run(_spec(algo=name))
        assert np.isfinite(h.loss).all()
        # the registry default routed through the rule path: momentum buffer
        # state is threaded and priced
        assert h.byte_model.mixes_per_round == 3
        assert _gt_gap(h) < 1e-5
    finally:
        unregister_algorithm(name)
    with pytest.raises(ValueError, match="opt_policy"):
        register_algorithm("bad_policy_test", opt_policy="nope")(_build_pisco)


def test_multi_seed_sweep_with_rules():
    loss_fn, _, sampler_factory, d = make_logreg_problem(n_agents=N_AGENTS)
    spec = _spec(optimizer="momentum:lr=0.1", server_optimizer="fedavgm", rounds=6)
    exp = _experiment(spec, loss_fn, d, sampler_factory)
    hists = exp.sweep(seeds=[0, 1])
    for h in hists:
        assert len(h.loss) == 6
        assert np.isfinite(h.loss).all()
        assert h.final_state is not None
