"""Serving subsystem tests (DESIGN.md §15).

* Delta exactness properties: top-k set-form is bit-exact whenever the index
  set covers every differing coordinate (fraction=1 for arbitrary diffs,
  partial fractions for sparse perturbations); q8 reconstruction stays within
  the quantizer bound (scale/2 per coordinate); dense is trivially lossless
  and dtype-preserving.
* Fleet memory: the delta representation is >= 10x smaller than n dense
  copies at n=64 agents.
* Exporters: ``from_history`` on a real (tiny) federated LM run and
  ``from_checkpoint`` on the saved state both round-trip bit-exactly at
  fraction=1.
* Engine: token streams through the continuous batcher are bit-identical
  between the delta engine (both materialize modes) and the dense baseline.
* Batcher/load mechanics on a stub engine: admission/eviction lifecycle,
  finish-at-admission, EOS, hand-checked latency arithmetic under fixed
  costs, arrival-process determinism.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.models import ModelConfig, get_bundle
from repro.serve import (
    ArrivalProcess,
    ContinuousBatcher,
    DecodeEngine,
    DeltaSpec,
    FleetDelta,
    Request,
    StepCosts,
    make_requests,
    materialize,
    materialize_fleet,
    run_load,
)
from repro.serve.delta import DenseDelta, TopKDelta

TINY = ModelConfig(
    name="serve-test-tiny",
    arch_type="dense",
    n_layers=1,
    d_model=32,
    n_heads=2,
    n_kv_heads=1,
    head_dim=16,
    d_ff=64,
    vocab_size=64,
    mlp_type="swiglu",
    dtype="float32",
    attn_chunk=32,
    remat=False,
)


@pytest.fixture(scope="module")
def bundle():
    return get_bundle(TINY)


@pytest.fixture(scope="module")
def base(bundle):
    return bundle.init(jax.random.PRNGKey(7))


def _rand_tree(rng, n):
    """Agent-stacked pytree with 1-D and 2-D leaves."""
    return {
        "w": rng.normal(size=(n, 6, 5)).astype(np.float32),
        "b": rng.normal(size=(n, 7)).astype(np.float32),
    }


def _assert_bit_equal(a, b):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        x, y = np.asarray(x), np.asarray(y)
        assert x.dtype == y.dtype
        assert np.array_equal(x, y), "leaves differ"


# ---------------------------------------------------------------------------
# Delta exactness
# ---------------------------------------------------------------------------


@given(seed=st.integers(0, 10))
@settings(max_examples=6, deadline=None)
def test_topk_full_fraction_bit_exact(seed):
    """fraction=1 covers every coordinate -> set-form is bit-exact for
    arbitrary (dense) diffs."""
    rng = np.random.default_rng(seed)
    stacked = _rand_tree(rng, 4)
    fleet = FleetDelta.from_stacked(stacked, DeltaSpec.parse("topk:f=1"))
    _assert_bit_equal(materialize(fleet.base, fleet.deltas), stacked)


@given(seed=st.integers(0, 10))
@settings(max_examples=6, deadline=None)
def test_topk_partial_fraction_bit_exact_on_sparse_diffs(seed):
    """When agents deviate on <= k coordinates, partial top-k still covers
    the full differing set and reconstruction is bit-exact."""
    rng = np.random.default_rng(seed)
    n, shape = 4, (8, 10)
    d = int(np.prod(shape))
    base = rng.normal(size=shape).astype(np.float32)
    k = max(1, int(np.ceil(0.1 * d)))
    stacked = np.broadcast_to(base, (n,) + shape).copy().reshape(n, d)
    for i in range(n):
        idx = rng.choice(d, size=k, replace=False)
        stacked[i, idx] += rng.normal(size=k).astype(np.float32)
    stacked = {"w": stacked.reshape((n,) + shape)}
    fleet = FleetDelta.from_stacked(
        stacked, DeltaSpec(kind="topk", fraction=0.1), base={"w": base}
    )
    _assert_bit_equal(materialize(fleet.base, fleet.deltas), stacked)


def test_q8_reconstruction_within_quantizer_bound():
    rng = np.random.default_rng(0)
    stacked = _rand_tree(rng, 4)
    fleet = FleetDelta.from_stacked(stacked, DeltaSpec.parse("topk:f=1,q8"))
    recon = materialize(fleet.base, fleet.deltas)
    for lk in ("w", "b"):
        err = np.abs(np.asarray(recon[lk]) - stacked[lk])
        scale = np.asarray(fleet.deltas[lk].scale)  # (n, 1)
        bound = scale.reshape(scale.shape[0], *([1] * (err.ndim - 1))) / 2
        assert np.all(err <= bound + 1e-7), f"q8 error exceeds scale/2 on {lk}"


def test_dense_delta_lossless_and_dtype_preserving():
    rng = np.random.default_rng(1)
    stacked = {
        "w": rng.normal(size=(3, 4, 4)).astype(np.float16),
        "c": rng.integers(0, 100, size=(3, 5)).astype(np.int32),
    }
    fleet = FleetDelta.from_stacked(stacked, DeltaSpec(kind="dense"))
    assert isinstance(fleet.deltas["w"], DenseDelta)
    _assert_bit_equal(materialize(fleet.base, fleet.deltas), stacked)


def test_lowrank_full_rank_recovers_residual():
    rng = np.random.default_rng(2)
    stacked = _rand_tree(rng, 3)
    fleet = FleetDelta.from_stacked(stacked, DeltaSpec.parse("lowrank:r=8"))
    # rank 8 >= min(6, 5): SVD is exact up to float error; 1-D leaves dense
    assert isinstance(fleet.deltas["b"], DenseDelta)
    recon = materialize(fleet.base, fleet.deltas)
    np.testing.assert_allclose(
        np.asarray(recon["w"]), stacked["w"], atol=1e-5
    )
    _assert_bit_equal({"b": recon["b"]}, {"b": stacked["b"]})


def test_delta_spec_parse_and_errors():
    assert DeltaSpec.parse("topk:f=0.1,q8") == DeltaSpec(
        kind="topk", fraction=0.1, quantize=True
    )
    assert DeltaSpec.parse("lowrank:r=8").rank == 8
    assert DeltaSpec.parse("dense").name == "dense"
    assert DeltaSpec.parse(DeltaSpec.parse("topk:f=0.1,q8").name).quantize
    with pytest.raises(ValueError):
        DeltaSpec.parse("svd")
    with pytest.raises(ValueError):
        DeltaSpec.parse("topk:f=0")
    with pytest.raises(ValueError):
        DeltaSpec.parse("topk:rank=2")
    with pytest.raises(ValueError):
        DeltaSpec(kind="dense", quantize=True)


def test_fleet_memory_ratio_at_64_agents(base):
    fleet = FleetDelta.synthetic(base, 64, seed=3)
    assert fleet.n_agents == 64
    ratio = fleet.naive_nbytes() / fleet.nbytes()
    assert ratio >= 10.0, f"expected >=10x memory saving at n=64, got {ratio:.1f}x"


def test_synthetic_fleet_is_lossless_topk(base):
    fleet = FleetDelta.synthetic(base, 5, seed=4)
    dense = materialize_fleet(fleet)
    refleet = FleetDelta.from_stacked(
        dense.stacked, DeltaSpec(kind="topk", fraction=1.0)
    )
    _assert_bit_equal(
        materialize(refleet.base, refleet.deltas), dense.stacked
    )
    assert all(
        isinstance(d, TopKDelta) for d in
        jax.tree.leaves(fleet.deltas, is_leaf=lambda x: isinstance(x, TopKDelta))
    )


# ---------------------------------------------------------------------------
# Exporters: trained history and checkpoint round-trips
# ---------------------------------------------------------------------------


def test_from_history_and_checkpoint_round_trip(bundle, tmp_path):
    from repro.checkpoint import save_checkpoint
    from repro.core import (
        PiscoConfig, dense_mixing, make_topology, replicate_params,
        run_training,
    )

    n, seq = 2, 16
    rng = np.random.default_rng(0)

    def sampler(_k):
        toks = rng.integers(0, TINY.vocab_size, size=(2, n, 1, seq))
        return (
            {"tokens": jnp.asarray(toks[:1])},
            {"tokens": jnp.asarray(toks[1])},
        )

    cfg = PiscoConfig(n_agents=n, t_o=1, eta_l=0.05, eta_c=1.0, p=0.5, seed=0)
    x0 = replicate_params(bundle.init(jax.random.PRNGKey(1)), n)
    hist = run_training(
        "pisco", bundle.loss, x0, cfg, dense_mixing(make_topology("ring", n)),
        sampler, rounds=2,
    )
    stacked = jax.tree.map(np.asarray, hist.agent_params())

    fleet = FleetDelta.from_history(hist, DeltaSpec.parse("topk:f=1"))
    assert fleet.n_agents == n
    _assert_bit_equal(materialize(fleet.base, fleet.deltas), stacked)

    path = save_checkpoint(str(tmp_path), 2, hist.final_state)
    fleet2 = FleetDelta.from_checkpoint(path, DeltaSpec.parse("topk:f=1"))
    _assert_bit_equal(materialize(fleet2.base, fleet2.deltas), stacked)


def test_export_fleet_round_trip(tmp_path):
    from repro.checkpoint import read_manifest
    from repro.serve import export_fleet

    rng = np.random.default_rng(5)
    stacked = _rand_tree(rng, 3)
    hist = type("H", (), {"agent_params": lambda self: stacked})()
    path = export_fleet(str(tmp_path), hist, step=7)
    assert read_manifest(path)["metadata"] == {"kind": "fleet"}
    fleet = FleetDelta.from_checkpoint(path, DeltaSpec.parse("topk:f=1"))
    _assert_bit_equal(materialize(fleet.base, fleet.deltas), stacked)


# ---------------------------------------------------------------------------
# Engine: delta-multiplexed decode is bit-identical to the dense baseline
# ---------------------------------------------------------------------------


def _serve_tokens(bundle, fleet, mode, requests):
    eng = DecodeEngine(bundle, fleet, n_slots=2, max_seq=40, materialize=mode)
    rep = run_load(
        ContinuousBatcher(eng), requests, costs=StepCosts(0.05, 0.01)
    )
    return {r.rid: list(r.tokens) for r in rep.requests}


def test_engine_bit_identical_to_dense_baseline(bundle, base):
    fleet = FleetDelta.synthetic(base, 6, seed=9)
    trace = lambda: make_requests(
        ArrivalProcess(rate=4.0), 5, n_agents=6, vocab_size=TINY.vocab_size,
        prompt_len=8, max_new_tokens=5, seed=11,
    )
    dense_toks = _serve_tokens(bundle, materialize_fleet(fleet), "admit", trace())
    assert sum(len(t) for t in dense_toks.values()) == 25
    assert _serve_tokens(bundle, fleet, "admit", trace()) == dense_toks
    assert _serve_tokens(bundle, fleet, "step", trace()) == dense_toks


def test_engine_rejects_bad_inputs(bundle, base):
    fleet = FleetDelta.synthetic(base, 2, seed=0)
    with pytest.raises(ValueError):
        DecodeEngine(bundle, fleet, materialize="eager")
    with pytest.raises(TypeError):
        DecodeEngine(bundle, {"not": "a fleet"})
    enc_dec = dataclasses.replace(TINY, is_enc_dec=True, n_encoder_layers=1)
    with pytest.raises(ValueError):
        DecodeEngine(get_bundle(enc_dec), fleet)


# ---------------------------------------------------------------------------
# Batcher / load mechanics (stub engine: no jit, pure state machine)
# ---------------------------------------------------------------------------


class StubEngine:
    """Deterministic logits: argmax = (agent_id + n_generated) % vocab."""

    vocab = 16

    def __init__(self, n_slots=2):
        self.n_slots = n_slots
        self._agents = np.zeros(n_slots, dtype=np.int64)
        self._counts = np.zeros(n_slots, dtype=np.int64)

    def _logits(self, slot):
        out = np.zeros(self.vocab, dtype=np.float32)
        out[(self._agents[slot] + self._counts[slot]) % self.vocab] = 1.0
        return out

    def admit(self, slot, agent_id, prompt):
        self._agents[slot] = agent_id
        self._counts[slot] = 0
        return self._logits(slot)

    def step(self, tokens):
        self._counts += 1
        return np.stack([self._logits(s) for s in range(self.n_slots)])

    def block_until_ready(self):
        pass


def _req(rid, agent, gen, arrival=0.0, eos=None):
    return Request(
        rid=rid, agent_id=agent, prompt=np.zeros(4, np.int32),
        max_new_tokens=gen, eos_id=eos, arrival_s=arrival,
    )


def test_batcher_admit_evict_lifecycle():
    b = ContinuousBatcher(StubEngine(n_slots=2))
    assert b.free_slots() == [0, 1]
    assert b.admit(_req(0, agent=3, gen=2)) is False
    assert b.admit(_req(1, agent=5, gen=3)) is False
    assert b.free_slots() == []
    with pytest.raises(RuntimeError):
        b.admit(_req(2, agent=0, gen=1))
    fin = b.step()  # req0 reaches 2 tokens -> evicted
    assert [r.rid for r in fin] == [0]
    assert b.free_slots() == [0]
    assert fin[0].tokens == [3, 4]  # agent 3: (3+0)%16, (3+1)%16
    fin = b.step()
    assert [r.rid for r in fin] == [1]
    assert b.completed[-1].tokens == [5, 6, 7]


def test_batcher_finishes_at_admission_and_on_eos():
    b = ContinuousBatcher(StubEngine(n_slots=1))
    assert b.admit(_req(0, agent=2, gen=1)) is True  # max_new_tokens == 1
    assert b.free_slots() == [0]
    # agent 4 emits 4 at admission -> immediate EOS
    assert b.admit(_req(1, agent=4, gen=8, eos=4)) is True
    # agent 3 emits 3, 4 -> EOS on the first decode step
    assert b.admit(_req(2, agent=3, gen=8, eos=4)) is False
    fin = b.step()
    assert [r.rid for r in fin] == [2]
    assert fin[0].tokens == [3, 4]


def test_run_load_latency_arithmetic_single_request():
    """latency = prefill + (gen-1) * decode, zero queue wait."""
    b = ContinuousBatcher(StubEngine(n_slots=2))
    reqs = [_req(0, agent=1, gen=4, arrival=1.0)]
    rep = run_load(b, reqs, costs=StepCosts(prefill_s=0.5, decode_s=0.125))
    (r,) = rep.requests
    assert r.queue_wait_s == 0.0
    assert r.prefill_s == 0.5
    np.testing.assert_allclose(r.decode_s, 3 * 0.125)
    np.testing.assert_allclose(r.latency_s, 0.5 + 3 * 0.125)
    np.testing.assert_allclose(rep.clock_s, 1.0 + 0.5 + 3 * 0.125)
    assert rep.total_tokens == 4


def test_run_load_queue_wait_when_slots_full():
    """Three simultaneous arrivals, one slot: each waits for the previous
    request's full service time."""
    b = ContinuousBatcher(StubEngine(n_slots=1))
    reqs = [_req(i, agent=1, gen=2, arrival=0.0) for i in range(3)]
    rep = run_load(b, reqs, costs=StepCosts(prefill_s=0.5, decode_s=0.25))
    by_rid = {r.rid: r for r in rep.requests}
    service = 0.5 + 0.25  # prefill + one decode step
    for i in range(3):
        np.testing.assert_allclose(by_rid[i].queue_wait_s, i * service)
    assert rep.makespan_s == pytest.approx(3 * service)


def test_arrival_processes_deterministic_and_well_formed():
    p = ArrivalProcess.parse("poisson:rate=2")
    a1, a2 = p.draw(50, seed=3), p.draw(50, seed=3)
    np.testing.assert_array_equal(a1, a2)
    assert not np.array_equal(a1, p.draw(50, seed=4))
    assert np.all(np.diff(a1) >= 0)

    b = ArrivalProcess.parse("bursty:rate=4,burst=5")
    times = b.draw(20, seed=0)
    assert len(np.unique(times)) == 4  # 20 arrivals in groups of 5
    with pytest.raises(ValueError):
        ArrivalProcess.parse("uniform:rate=1")
    with pytest.raises(ValueError):
        ArrivalProcess.parse("poisson:rate=0")

    reqs = make_requests(p, 10, n_agents=6, vocab_size=32, seed=2)
    reqs2 = make_requests(p, 10, n_agents=6, vocab_size=32, seed=2)
    assert [r.agent_id for r in reqs] == [r.agent_id for r in reqs2]
    assert all(0 <= r.agent_id < 6 for r in reqs)
    assert all(r.prompt.dtype == np.int32 for r in reqs)
    np.testing.assert_array_equal(reqs[0].prompt, reqs2[0].prompt)


def test_temperature_sampling_uses_domain_separated_streams():
    """Same rid+step -> same draw; different rid -> (almost surely)
    different stream. Greedy path must ignore the key entirely."""
    b = ContinuousBatcher(StubEngine(n_slots=2), temperature=1.0, seed=0)
    logits = np.linspace(0.0, 1.0, StubEngine.vocab).astype(np.float32)
    r0, r1 = _req(0, agent=1, gen=4), _req(1, agent=1, gen=4)
    draws0 = [b._sample(r0, logits) for _ in range(3)]
    assert draws0[0] == draws0[1] == draws0[2]  # pure in (rid, n_tokens)
    b2 = ContinuousBatcher(StubEngine(n_slots=2), temperature=1.0, seed=0)
    assert b2._sample(r0, logits) == draws0[0]
    greedy = ContinuousBatcher(StubEngine(n_slots=2))
    assert greedy._sample(r0, logits) == StubEngine.vocab - 1
