"""PISCO algorithm tests: the paper's own invariants and guarantees.

* Lemma 1 (gradient-tracking): mean_i y_i == mean_i g_i exactly, at every
  round, for any p / T_o / topology (hypothesis-driven).
* p=1 gives exact consensus after one round (federated case, Remark 2).
* Convergence on the nonconvex-regularized logistic problem (§5.1 analogue).
* Local updates accelerate: T_o=8 reaches the threshold in fewer rounds
  than T_o=1 (Corollary 1's linear speedup, empirically).
* Semi-decentralized p>0 beats p=0 on a disconnected graph (Assumption 1).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from conftest import make_logreg_problem
from repro.core import (
    PiscoConfig,
    dense_mixing,
    init_state,
    make_round_fn,
    make_topology,
    replicate_params,
    run_training,
)


def _tree_mean0(tree):
    return jax.tree.map(lambda v: jnp.mean(v, axis=0), tree)


def _max_abs_diff(a, b):
    return max(
        float(jnp.max(jnp.abs(x - y))) for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b))
    )


@given(
    t_o=st.integers(1, 5),
    p_global=st.booleans(),
    topo_name=st.sampled_from(["ring", "path", "full", "disconnected"]),
    seed=st.integers(0, 20),
)
@settings(max_examples=12, deadline=None)
def test_lemma1_tracking_invariant(t_o, p_global, topo_name, seed):
    """mean(Y) == mean(G) after any round, any mixing kind."""
    n = 8
    loss_fn, _, sampler_factory, d = make_logreg_problem(n_agents=n, seed=seed)
    cfg = PiscoConfig(n_agents=n, t_o=t_o, eta_l=0.1, eta_c=0.9, p=0.5)
    mixing = dense_mixing(make_topology(topo_name, n))
    sampler = sampler_factory(t_o, seed=seed)
    x0 = replicate_params({"w": jnp.zeros(d)}, n)
    state = init_state(loss_fn, x0, sampler(-1)[1])
    fn = jax.jit(make_round_fn(loss_fn, cfg, mixing, global_round=p_global))
    for k in range(3):
        state, _ = fn(state, *sampler(k))
    assert _max_abs_diff(_tree_mean0(state.y), _tree_mean0(state.g)) < 1e-5


def test_federated_round_gives_exact_consensus():
    n = 6
    loss_fn, _, sampler_factory, d = make_logreg_problem(n_agents=n)
    cfg = PiscoConfig(n_agents=n, t_o=2, eta_l=0.1, eta_c=1.0, p=1.0)
    mixing = dense_mixing(make_topology("ring", n))
    sampler = sampler_factory(2)
    x0 = replicate_params({"w": jnp.zeros(d)}, n)
    state = init_state(loss_fn, x0, sampler(-1)[1])
    fn = jax.jit(make_round_fn(loss_fn, cfg, mixing, global_round=True))
    state, metrics = fn(state, *sampler(0))
    assert float(metrics.consensus_err) < 1e-12
    w = state.x["w"]
    assert float(jnp.max(jnp.abs(w - w[0:1]))) < 1e-6


def test_pisco_converges_on_logreg():
    n = 8
    loss_fn, full_grad_sq, sampler_factory, d = make_logreg_problem(n_agents=n)
    cfg = PiscoConfig(n_agents=n, t_o=4, eta_l=0.2, eta_c=1.0, p=0.1, seed=0)
    mixing = dense_mixing(make_topology("ring", n))
    x0 = replicate_params({"w": jnp.zeros(d)}, n)
    hist = run_training(
        "pisco", loss_fn, x0, cfg, mixing, sampler_factory(cfg.t_o),
        rounds=60,
        eval_fn=lambda xb: {"grad_sq": full_grad_sq(xb)},
        eval_every=5,
    )
    assert hist.eval_metrics[-1]["grad_sq"] < 0.02
    assert hist.loss[-1] < hist.loss[0]


def test_local_updates_accelerate():
    """Corollary 1's T_o speedup, measured in communication rounds."""
    n = 8
    loss_fn, full_grad_sq, sampler_factory, d = make_logreg_problem(n_agents=n)
    mixing = dense_mixing(make_topology("ring", n))
    x0 = replicate_params({"w": jnp.zeros(d)}, n)
    rounds_needed = {}
    for t_o in (1, 8):
        cfg = PiscoConfig(n_agents=n, t_o=t_o, eta_l=0.15, eta_c=1.0, p=0.1, seed=3)
        hist = run_training(
            "pisco", loss_fn, x0, cfg, mixing, sampler_factory(t_o),
            rounds=80,
            eval_fn=lambda xb: {"grad_sq": full_grad_sq(xb)},
            eval_every=1,
        )
        r = hist.rounds_to_threshold("grad_sq", 0.03)
        rounds_needed[t_o] = r if r is not None else 10_000
    assert rounds_needed[8] < rounds_needed[1]


def test_server_rescues_disconnected_graph():
    """On a disconnected graph, p=0 stalls on heterogeneous data while a
    small p>0 still converges (the paper's Fig. 6(b) phenomenon)."""
    n = 8
    loss_fn, full_grad_sq, sampler_factory, d = make_logreg_problem(
        n_agents=n, heterogeneous=True
    )
    mixing = dense_mixing(make_topology("disconnected", n, n_components=2))
    x0 = replicate_params({"w": jnp.zeros(d)}, n)
    results = {}
    for p in (0.0, 0.2):
        cfg = PiscoConfig(n_agents=n, t_o=2, eta_l=0.15, eta_c=1.0, p=p, seed=1)
        hist = run_training(
            "pisco", loss_fn, x0, cfg, mixing, sampler_factory(2),
            rounds=60,
            eval_fn=lambda xb: {"grad_sq": full_grad_sq(xb)},
            eval_every=5,
        )
        results[p] = hist.eval_metrics[-1]["grad_sq"]
    assert results[0.2] < results[0.0]


def test_step_counter_and_config_helpers():
    from repro.core import decentralized_config, federated_config

    cfg = PiscoConfig(n_agents=4, p=0.3)
    assert decentralized_config(cfg).p == 0.0
    assert federated_config(cfg).p == 1.0
    with pytest.raises(AssertionError):
        PiscoConfig(n_agents=4, t_o=0)
