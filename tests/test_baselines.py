"""Baseline algorithms: convergence + structural properties."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import make_logreg_problem
from repro.core import PiscoConfig, dense_mixing, make_topology, replicate_params, run_training
from repro.core.baselines import BASELINES


@pytest.mark.parametrize(
    "algo", ["dsgd", "dsgt", "gossip_pga", "fedavg", "scaffold", "periodical_gt"]
)
def test_baseline_converges(algo):
    n = 8
    loss_fn, full_grad_sq, sampler_factory, d = make_logreg_problem(n_agents=n)
    cfg = PiscoConfig(n_agents=n, t_o=2, eta_l=0.15, eta_c=1.0, p=0.1, seed=0)
    mixing = dense_mixing(make_topology("ring", n))
    x0 = replicate_params({"w": jnp.zeros(d)}, n)
    hist = run_training(
        algo, loss_fn, x0, cfg, mixing, sampler_factory(cfg.t_o),
        rounds=50,
        eval_fn=lambda xb: {"grad_sq": full_grad_sq(xb)},
        eval_every=10,
    )
    assert np.isfinite(hist.loss).all()
    assert hist.eval_metrics[-1]["grad_sq"] < 0.2
    assert hist.loss[-1] < hist.loss[0]


def test_gossip_pga_schedule_is_periodic():
    n = 4
    loss_fn, _, sampler_factory, d = make_logreg_problem(n_agents=n)
    cfg = PiscoConfig(n_agents=n, t_o=1, eta_l=0.1, p=0.25, seed=0)
    mixing = dense_mixing(make_topology("ring", n))
    x0 = replicate_params({"w": jnp.zeros(d)}, n)
    hist = run_training(
        "gossip_pga", loss_fn, x0, cfg, mixing, sampler_factory(1), rounds=12
    )
    # p=0.25 -> period 4: rounds 3, 7, 11 are global
    assert hist.is_global == [(k + 1) % 4 == 0 for k in range(12)]


def test_fedavg_always_server():
    n = 4
    loss_fn, _, sampler_factory, d = make_logreg_problem(n_agents=n)
    cfg = PiscoConfig(n_agents=n, t_o=2, eta_l=0.1, p=0.0, seed=0)
    mixing = dense_mixing(make_topology("ring", n))
    x0 = replicate_params({"w": jnp.zeros(d)}, n)
    hist = run_training(
        "fedavg", loss_fn, x0, cfg, mixing, sampler_factory(2), rounds=8
    )
    assert hist.accountant.agent_to_server == 8
    assert hist.accountant.agent_to_agent == 0


def test_scaffold_control_variates_average_to_server_variate():
    """After each SCAFFOLD round, c == mean_i(c_i) (server aggregation)."""
    from repro.core.baselines import make_scaffold_round_fn, scaffold_init
    from repro.core.mixing import dense_mixing as dm

    n = 6
    loss_fn, _, sampler_factory, d = make_logreg_problem(n_agents=n)
    mixing = dm(make_topology("full", n))
    sampler = sampler_factory(2)
    x0 = replicate_params({"w": jnp.zeros(d)}, n)
    state = scaffold_init(loss_fn, x0, sampler(-1)[1])
    fn = jax.jit(make_scaffold_round_fn(loss_fn, 0.1, 1.0, 2, mixing))
    for k in range(3):
        state, _ = fn(state, *sampler(k))
    c_bar = jnp.mean(state.c_i["w"], axis=0)
    assert float(jnp.max(jnp.abs(state.c["w"] - c_bar[None]))) < 1e-6


def test_dsgt_matches_decentralized_structure():
    """DSGT state trees keep the tracking invariant mean(y)=mean(g)."""
    from repro.core.baselines import dsgt_init, make_dsgt_round_fn

    n = 6
    loss_fn, _, sampler_factory, d = make_logreg_problem(n_agents=n)
    mixing = dense_mixing(make_topology("ring", n))
    sampler = sampler_factory(1)
    x0 = replicate_params({"w": jnp.zeros(d)}, n)
    state = dsgt_init(loss_fn, x0, sampler(-1)[1])
    fn = jax.jit(make_dsgt_round_fn(loss_fn, 0.1, mixing))
    for k in range(4):
        state, _ = fn(state, *sampler(k))
    ybar = jnp.mean(state.y["w"], axis=0)
    gbar = jnp.mean(state.g["w"], axis=0)
    assert float(jnp.max(jnp.abs(ybar - gbar))) < 1e-5


def test_registry_covers_everything():
    assert set(BASELINES) == {
        "dsgd", "gossip_pga", "dsgt", "periodical_gt", "fedavg", "scaffold", "pisco",
    }
