"""Beyond-paper extensions: compressed gossip, hierarchical mixing glue."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import make_logreg_problem
from repro.core import PiscoConfig, dense_mixing, make_topology, replicate_params, run_training
from repro.core.mixing import compressed_mixing


@pytest.mark.parametrize("bits", [8, 4])
def test_compressed_gossip_quantizes(bits):
    topo = make_topology("ring", 4)
    base = dense_mixing(topo)
    comp = compressed_mixing(base, bits=bits)
    tree = {"w": jnp.asarray(np.random.default_rng(0).normal(size=(4, 16)), jnp.float32)}
    out_c = comp.gossip(tree)
    out_b = base.gossip(tree)
    err = float(jnp.max(jnp.abs(out_c["w"] - out_b["w"])))
    # quantization error bounded by scale ~ max|x| / qmax
    bound = float(jnp.max(jnp.abs(tree["w"]))) / (2 ** (bits - 1) - 1)
    assert 0 < err <= bound + 1e-6
    # global averaging stays exact
    np.testing.assert_allclose(
        np.asarray(comp.global_avg(tree)["w"]), np.asarray(base.global_avg(tree)["w"])
    )


def test_pisco_converges_with_int8_gossip():
    n = 8
    loss_fn, full_grad_sq, sampler_factory, d = make_logreg_problem(n_agents=n)
    cfg = PiscoConfig(n_agents=n, t_o=2, eta_l=0.15, eta_c=1.0, p=0.1, seed=0)
    base = dense_mixing(make_topology("ring", n))
    comp = compressed_mixing(base, bits=8)
    x0 = replicate_params({"w": jnp.zeros(d)}, n)
    hist = run_training(
        "pisco", loss_fn, x0, cfg, comp, sampler_factory(2),
        rounds=50,
        eval_fn=lambda xb: {"grad_sq": full_grad_sq(xb)},
        eval_every=10,
    )
    assert hist.eval_metrics[-1]["grad_sq"] < 0.05
    assert hist.loss[-1] < hist.loss[0]
