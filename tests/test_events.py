"""Async event-queue subsystem conformance (DESIGN.md §13).

Pins the contracts of ``repro.events``:

* staleness rules are hand-computable arithmetic; async-spec strings
  round-trip through their canonical form;
* the :class:`~repro.events.EventEngine` clock recursion matches hand-derived
  timelines on tiny hand-built fleets (gossip wait chains, bounded-staleness
  drops, buffer-of-m server fire times);
* under degenerate fleets (``FREE_NETWORK``, uniform) the events driver is
  **bit-identical** to the scan driver for PISCO and the baselines — async
  costs nothing when nobody straggles;
* under a heterogeneous fleet the async run is deterministic in the seed,
  strictly cheaper in simulated time than its sync twin, and its frozen
  event trace re-prices to the online seconds exactly;
* ``ExperimentSpec.async_`` validates, JSON round-trips, and stays
  backward-compatible with pre-events payloads; the tuner sweeps the
  staleness bound as a third axis only for the events driver.
"""
import json

import jax.numpy as jnp
import numpy as np
import pytest

from conftest import make_logreg_problem
from repro.core import Experiment, ExperimentSpec
from repro.core.driver import DRIVERS, get_driver
from repro.events import (
    AsyncConfig,
    EventEngine,
    RULES,
    drive_events,
    parse_async_spec,
    reprice_trace,
    staleness_weights,
    with_staleness_bound,
)
from repro.sim import FREE_NETWORK, SystemsModel, SystemsParams, price_history, tune

N_AGENTS = 6
ROUNDS = 20


def _pieces(n=N_AGENTS):
    loss_fn, _, sampler_factory, d = make_logreg_problem(n_agents=n)
    return dict(
        loss_fn=loss_fn,
        params0={"w": jnp.zeros(d)},
        sampler_factory=lambda s: sampler_factory(s.config.t_o),
    )


def _spec(**kw):
    base = dict(
        algo="pisco", n_agents=N_AGENTS, t_o=2, eta_l=0.1, p=0.2, seed=0,
        rounds=ROUNDS,
    )
    base.update(kw)
    return ExperimentSpec.create(**base)


@pytest.fixture(scope="module")
def straggler_pair():
    """One sync/async twin pair under the straggler fleet, shared across
    tests (each run is seconds of jit; don't re-run per assertion)."""
    sync_spec = _spec(driver="scan", systems="lognormal-stragglers")
    async_spec = sync_spec.replace(
        driver="events", async_="poly:alpha=0.5,bound=1,buffer=3"
    )
    h_sync = Experiment(sync_spec, **_pieces()).run()
    h_async = Experiment(async_spec, **_pieces()).run()
    return sync_spec, h_sync, async_spec, h_async


# ---------------------------------------------------------------------------
# Staleness rules: spec grammar + hand-computed weights
# ---------------------------------------------------------------------------


def test_async_spec_round_trips_through_canonical_form():
    for s in (
        "constant", "poly", "poly:alpha=1.0", "poly:bound=2",
        "buffer:buffer=4", "poly:alpha=0.25,bound=3,buffer=2",
    ):
        cfg = parse_async_spec(s)
        assert cfg.rule in RULES
        assert parse_async_spec(cfg.spec()) == cfg
    cfg = parse_async_spec("poly:alpha=1.0,bound=2,buffer=3")
    assert (cfg.alpha, cfg.bound, cfg.buffer) == (1.0, 2, 3)
    assert parse_async_spec("poly:bound=inf").bound is None


@pytest.mark.parametrize("bad", ["warp", "poly:zzz=1", "poly:alpha=", ""])
def test_async_spec_rejects_malformed(bad):
    with pytest.raises(ValueError):
        parse_async_spec(bad)


def test_with_staleness_bound_substitutes_only_the_bound():
    s = with_staleness_bound("poly:alpha=1.0,buffer=2", 3)
    cfg = parse_async_spec(s)
    assert (cfg.rule, cfg.alpha, cfg.bound, cfg.buffer) == ("poly", 1.0, 3, 2)
    assert parse_async_spec(with_staleness_bound(s, None)).bound is None
    assert parse_async_spec(with_staleness_bound(None, 2)).bound == 2


def test_staleness_weights_hand_computed():
    # constant: staleness is ignored, uniform over agents
    w = staleness_weights(np.array([0, 1, 2]), AsyncConfig(rule="constant"))
    np.testing.assert_allclose(w, [1 / 3] * 3)
    # poly alpha=1: raw (1+s)^-1 = [1, 1/2, 1/4] -> normalized [4,2,1]/7
    w = staleness_weights(
        np.array([0, 1, 3]), AsyncConfig(rule="poly", alpha=1.0)
    )
    np.testing.assert_allclose(w, np.array([4, 2, 1]) / 7)
    # buffer: the on-time cohort splits the mass, late pushes get zero
    w = staleness_weights(
        np.array([0, 0, 1]), AsyncConfig(rule="buffer", buffer=2),
        ontime=np.array([True, True, False]),
    )
    np.testing.assert_allclose(w, [0.5, 0.5, 0.0])


# ---------------------------------------------------------------------------
# EventEngine clock recursion on hand-built fleets
# ---------------------------------------------------------------------------


def _fleet(compute, lat=None, up_bw=None, down_bw=None, rtt=0.0):
    n = len(compute)
    inf = np.full((n, n), np.inf)
    return SystemsModel(
        params=SystemsParams(
            compute_s=np.asarray(compute, dtype=np.float64),
            link_latency_s=(
                np.zeros((n, n)) if lat is None else np.asarray(lat, float)
            ),
            link_bw_Bps=inf,
            up_bw_Bps=np.ones(n) if up_bw is None else np.asarray(up_bw, float),
            down_bw_Bps=(
                np.ones(n) if down_bw is None else np.asarray(down_bw, float)
            ),
            server_rtt_s=float(rtt),
        ),
    )


def test_gossip_wait_chain_hand_computed():
    # path 0-1-2, unit compute, edge costs 0.5 and 1.5: each round every
    # agent waits for its slowest incident message -> the 1-2 edge gates the
    # frontier at compute + 1.5 = 2.5 s/round
    lat = np.zeros((3, 3))
    lat[0, 1] = lat[1, 0] = 0.5
    lat[1, 2] = lat[2, 1] = 1.5
    eng = EventEngine(
        model=_fleet([1.0, 1.0, 1.0], lat=lat),
        cfg=AsyncConfig(),
        flags=np.zeros(2, dtype=bool),
        base_edges=np.array([[0, 1], [1, 2]]),
        gossip_bytes=8,
    )
    assert eng.trivial  # nobody straggles past the quantum, nothing dropped
    np.testing.assert_allclose(eng.seconds, [2.5, 2.5])
    assert eng.staleness.tolist() == [[0, 0, 0], [0, 0, 0]]
    assert eng.messages.tolist() == [4, 4]  # 2 directed per active edge


def test_bounded_staleness_drops_the_straggler():
    # agent 2 is 5x slower; quantum q = median compute = 1, so it is late
    # from round 0; bound 0 drops its edge and it stops gating the frontier
    eng = EventEngine(
        model=_fleet([1.0, 1.0, 5.0]),
        cfg=AsyncConfig(rule="constant", bound=0),
        flags=np.zeros(2, dtype=bool),
        base_edges=np.array([[0, 1], [1, 2]]),
        gossip_bytes=8,
    )
    assert not eng.trivial
    np.testing.assert_allclose(eng.seconds, [1.0, 1.0])
    assert eng.staleness.tolist() == [[0, 0, 1], [0, 0, 2]]
    # edge (0,1) stays, edge (1,2) dropped -> 2 directed messages
    assert eng.messages.tolist() == [2, 2]


def test_buffered_server_round_hand_computed():
    # compute [1,2,3], upload 4 s, download 2 s, rtt 0.5: pushes at [5,6,7];
    # buffer-of-2 fires at the 2nd push (t=6), agent 2 is late (weight 0),
    # and the broadcast lands at 6 + 0.5 + 2 = 8.5
    eng = EventEngine(
        model=_fleet([1.0, 2.0, 3.0], up_bw=[1, 1, 1], down_bw=[2, 2, 2],
                     rtt=0.5),
        cfg=AsyncConfig(rule="buffer", buffer=2),
        flags=np.ones(1, dtype=bool),
        base_edges=np.array([[0, 1]]),
        server_bytes=4,
    )
    assert not eng.trivial
    np.testing.assert_allclose(eng.seconds, [8.5])
    np.testing.assert_allclose(eng.weights[0], [0.5, 0.5, 0.0])
    assert eng.staleness[0].tolist() == [0, 0, 1]


def test_reprice_trace_same_fleet_is_bit_exact():
    eng = EventEngine(
        model=_fleet([1.0, 1.0, 5.0], up_bw=[1, 1, 1], down_bw=[2, 2, 2],
                     rtt=0.5),
        cfg=AsyncConfig(rule="poly", bound=0, buffer=2),
        flags=np.array([False, True, False, False]),
        base_edges=np.array([[0, 1], [1, 2]]),
        gossip_bytes=8,
        server_bytes=4,
    )
    assert np.array_equal(reprice_trace(eng.trace, eng.model), eng.seconds)
    # repricing on a faster fleet keeps the gating but shrinks the clock
    fast = _fleet([0.1, 0.1, 0.5], up_bw=[10, 10, 10], down_bw=[20, 20, 20])
    assert reprice_trace(eng.trace, fast).sum() < eng.seconds.sum()


# ---------------------------------------------------------------------------
# Degenerate fleets: the events driver IS the scan driver, bit for bit
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("algo", ["pisco", "dsgt", "fedavg"])
def test_events_free_network_bit_identical_to_scan(algo):
    kw = dict(algo=algo, rounds=12, systems=FREE_NETWORK)
    h_scan = Experiment(_spec(driver="scan", **kw), **_pieces()).run()
    h_ev = Experiment(_spec(driver="events", **kw), **_pieces()).run()
    assert h_scan.is_global == h_ev.is_global
    np.testing.assert_array_equal(h_scan.loss, h_ev.loss)
    assert np.max(h_ev.staleness) == 0  # nobody straggles on a free fleet


def test_events_uniform_fleet_matches_sync_times_too():
    # a uniform (but non-free) fleet keeps all clocks in lockstep: same
    # numerics AND the availability frontier advances at the sync round time
    kw = dict(rounds=12, systems="uniform")
    h_scan = Experiment(_spec(driver="scan", **kw), **_pieces()).run()
    h_ev = Experiment(_spec(driver="events", **kw), **_pieces()).run()
    np.testing.assert_array_equal(h_scan.loss, h_ev.loss)
    np.testing.assert_allclose(h_ev.sim_time_s, h_scan.sim_time_s, rtol=1e-9)


# ---------------------------------------------------------------------------
# Heterogeneous fleet: determinism, time win, trace repricing
# ---------------------------------------------------------------------------


def test_async_beats_the_barrier_under_stragglers(straggler_pair):
    _, h_sync, _, h_async = straggler_pair
    assert h_sync.is_global == h_async.is_global  # same predrawn schedule
    assert np.max(h_async.staleness) > 0  # the straggler actually straggled
    assert sum(h_async.sim_time_s) < sum(h_sync.sim_time_s)
    # convergence is not free-lunch-broken: the async run still trains
    assert h_async.loss[-1] < h_async.loss[3]


def test_events_run_is_seed_deterministic(straggler_pair):
    _, _, async_spec, h_async = straggler_pair
    h2 = Experiment(async_spec, **_pieces()).run()
    np.testing.assert_array_equal(h_async.loss, h2.loss)
    np.testing.assert_array_equal(h_async.sim_time_s, h2.sim_time_s)
    assert h_async.staleness == h2.staleness


def test_event_trace_reprices_online_seconds_exactly(straggler_pair):
    _, _, async_spec, h_async = straggler_pair
    same = price_history(h_async, async_spec)
    assert np.array_equal(same, np.asarray(h_async.sim_time_s))
    wan = price_history(h_async, async_spec, systems="wan-gossip")
    assert wan.shape == same.shape
    assert not np.array_equal(wan, same)


def test_history_exports_trace_and_staleness(straggler_pair):
    _, _, _, h_async = straggler_pair
    for key in ("flags", "active", "gate", "participants", "n_agents"):
        assert key in h_async.event_trace
    payload = h_async.to_dict()
    assert "event_trace" not in payload  # bulk arrays stay off the JSON path
    assert len(payload["staleness"]) == ROUNDS
    assert all(len(row) == N_AGENTS for row in payload["staleness"])


# ---------------------------------------------------------------------------
# Spec surface: validation, JSON, registry, tuner axis
# ---------------------------------------------------------------------------


def test_events_driver_registered():
    assert "events" in DRIVERS
    assert get_driver("events") is drive_events


def test_spec_validation():
    with pytest.raises(ValueError):  # async_ is an events-driver knob
        _spec(driver="scan", systems="uniform", async_="constant")
    with pytest.raises(ValueError):  # the event clock needs a fleet
        _spec(driver="events")
    with pytest.raises(ValueError):  # malformed rule fails at spec build
        _spec(driver="events", systems="uniform", async_="warp")


def test_spec_async_json_round_trip_and_legacy_payload():
    spec = _spec(
        driver="events", systems="lognormal-stragglers",
        async_="poly:alpha=1.0,bound=2,buffer=3",
    )
    assert ExperimentSpec.from_json(spec.to_json()) == spec
    # a pre-events payload (no async_ key) loads with the default
    legacy = json.loads(spec.to_json())
    del legacy["async_"]
    assert ExperimentSpec.from_dict(legacy).async_ is None


def test_tuner_sweeps_staleness_bound_for_events_specs():
    spec = _spec(
        driver="events", systems="lognormal-stragglers", rounds=8,
        async_="poly:alpha=0.5,bound=2,buffer=3",
    )
    res = tune(spec, _pieces(), p_grid=[0.2], staleness_grid=[1, None])
    assert {pt.staleness_bound for pt in res.points} == {1, None}
    assert all(
        pt.to_dict()["staleness_bound"] == pt.staleness_bound
        for pt in res.points
    )


def test_tuner_staleness_grid_requires_events_driver():
    spec = _spec(driver="scan", systems="lognormal-stragglers")
    with pytest.raises(ValueError):
        tune(spec, _pieces(), p_grid=[0.2], staleness_grid=[1], rounds=4)
