"""Conformance suite for the sparse-network substrate.

The edge-list/CSR gossip path must be indistinguishable from the dense
matrix path everywhere they overlap: same Metropolis weights, same training
trajectories for every registered protocol under both drivers, same realized
byte charges, and the same Lemma-1 mean-tracking invariant under compression.
Property tests run through the optional-hypothesis shim (``tests/_hyp.py``),
so they degrade to deterministic fixed examples when hypothesis is absent.
"""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hyp import given, settings, st
from conftest import make_logreg_problem
from repro.core import (
    Experiment,
    ExperimentSpec,
    PiscoConfig,
    dense_mixing,
    dynamic_sparse_mixing,
    is_doubly_stochastic,
    make_sparse_topology,
    make_topology,
    make_topology_process,
    metropolis_edge_weights,
    registered_algorithms,
    replicate_params,
    run_training,
    sparse_mixing,
    use_sparse_topology,
)
from repro.core.topology import (
    SPARSE_AUTO_MIN_AGENTS,
    metropolis_weights,
    sparse_topology_from_edges,
)
from repro.kernels import sparse_compressed_mix, sparse_mix, topology_edge_arrays
from repro.kernels.ref import sparse_compressed_mix_ref, sparse_mix_ref
from repro.utils.pytree import tree_agent_mix_sparse

N_AGENTS = 5


def _experiment(spec, n=N_AGENTS):
    loss_fn, _, sampler_factory, d = make_logreg_problem(n_agents=n)
    return Experiment(
        spec,
        loss_fn=loss_fn,
        params0={"w": jnp.zeros(d)},
        sampler_factory=lambda s: sampler_factory(s.config.t_o),
    )


def _random_connected_edges(n, seed, extra_prob=0.3):
    """Ring ∪ Erdős–Rényi: always connected, random beyond the ring."""
    rng = np.random.default_rng(seed)
    edges = {(i, (i + 1) % n) if i < (i + 1) % n else ((i + 1) % n, i)
             for i in range(n) if n > 1}
    for i in range(n):
        for j in range(i + 1, n):
            if rng.random() < extra_prob:
                edges.add((i, j))
    return np.array(sorted(edges), dtype=np.int64).reshape(-1, 2)


# ---------------------------------------------------------------------------
# Property: segment-sum gossip over the edge list == dense W @ X
# ---------------------------------------------------------------------------


@settings(max_examples=20, deadline=None)
@given(
    n=st.sampled_from([2, 8, 64]),
    seed=st.integers(min_value=0, max_value=5),
    cols=st.sampled_from([1, 7]),
)
def test_sparse_gossip_matches_dense_matrix_product(n, seed, cols):
    edges = _random_connected_edges(n, seed)
    topo = sparse_topology_from_edges("rand", n, edges)
    w = topo.dense_w()
    assert is_doubly_stochastic(w)

    rng = np.random.default_rng(seed + 100)
    x = jnp.asarray(rng.normal(size=(n, cols)).astype(np.float32))
    senders = jnp.asarray(
        np.concatenate([edges[:, 0], edges[:, 1]]), dtype=jnp.int32
    )
    receivers = jnp.asarray(
        np.concatenate([edges[:, 1], edges[:, 0]]), dtype=jnp.int32
    )
    edge_w = jnp.asarray(np.concatenate([topo.edge_weight] * 2), jnp.float32)
    self_w = jnp.asarray(topo.self_weight, jnp.float32)

    out = tree_agent_mix_sparse(x, senders, receivers, edge_w, self_w, n)
    np.testing.assert_allclose(
        np.asarray(out), w.astype(np.float32) @ np.asarray(x),
        rtol=1e-5, atol=1e-6,
    )


@settings(max_examples=10, deadline=None)
@given(n=st.sampled_from([2, 8, 64]), seed=st.integers(min_value=0, max_value=5))
def test_edge_metropolis_matches_dense_metropolis(n, seed):
    """The O(n+m) degree-array construction and the dense n×n construction
    are the same Metropolis–Hastings matrix."""
    edges = _random_connected_edges(n, seed)
    adj = np.zeros((n, n), bool)
    adj[edges[:, 0], edges[:, 1]] = True
    adj[edges[:, 1], edges[:, 0]] = True
    dense = metropolis_weights(adj)

    edge_w, self_w = metropolis_edge_weights(edges, n)
    rebuilt = np.zeros((n, n))
    rebuilt[edges[:, 0], edges[:, 1]] = edge_w
    rebuilt[edges[:, 1], edges[:, 0]] = edge_w
    np.fill_diagonal(rebuilt, self_w)
    np.testing.assert_allclose(rebuilt, dense, rtol=0, atol=1e-12)


@pytest.mark.parametrize("name", ["ring", "path", "star", "torus", "random_regular"])
@pytest.mark.parametrize("n", [2, 9, 64])
def test_sparse_topology_pins_dense_topology_small_n(name, n):
    if name == "torus" and n == 2:
        pytest.skip("torus needs a 2d grid")
    dense = make_topology(name, n, seed=3)
    sparse = make_sparse_topology(name, n, seed=3)
    np.testing.assert_allclose(sparse.dense_w(), dense.w, rtol=0, atol=1e-12)
    assert sparse.connected == dense.connected
    if sparse.lambda_w is not None:
        np.testing.assert_allclose(sparse.lambda_w, dense.lambda_w, atol=1e-9)


# ---------------------------------------------------------------------------
# is_doubly_stochastic at scale (the tol=1e-8 bugfix)
# ---------------------------------------------------------------------------


def test_doubly_stochastic_tolerance_scales_with_n():
    """A float32 Metropolis matrix at n ≥ 4096 accumulates ~1e-7 of row-sum
    error — legitimately doubly stochastic, yet the historical fixed
    tol=1e-8 (now honestly enforced with rtol=0) rejects it.  The scaled
    default accepts it while still rejecting genuinely broken matrices.
    (The sparse constructor is used directly: make_topology would spend
    minutes on the n² spectral-gap eigendecomposition this test does not
    need.)"""
    n = 4096
    topo = make_sparse_topology("random_regular", n, seed=0, degree=6)
    w = topo.dense_w().astype(np.float32)
    row_err = float(np.abs(w.sum(axis=1) - 1.0).max())
    assert row_err > 1e-8  # float32 rounding actually materialized
    assert is_doubly_stochastic(w)  # scaled default: accepted
    assert not is_doubly_stochastic(w, tol=1e-8)  # the old bug, now honest

    bad = w.copy()
    bad[0, 0] += 0.01
    assert not is_doubly_stochastic(bad)


# ---------------------------------------------------------------------------
# Full-protocol parity: dense path vs sparse path, both drivers
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("algo", registered_algorithms())
def test_sparse_training_matches_dense_all_protocols(algo):
    n, rounds = 6, 6
    loss_fn, _, sampler_factory, d = make_logreg_problem(n_agents=n)
    x0 = replicate_params({"w": jnp.zeros(d)}, n)
    cfg = PiscoConfig(n_agents=n, t_o=2, eta_l=0.15, eta_c=1.0, p=0.3, seed=0)

    def run(mixing, driver):
        return run_training(
            loss_fn=loss_fn, algo=algo, x0_stacked=x0, cfg=cfg, mixing=mixing,
            sampler=sampler_factory(cfg.t_o), rounds=rounds,
            driver=driver, block_size=3,
        )

    for driver in ("scan", "loop"):
        hd = run(dense_mixing(make_topology("ring", n)), driver)
        hs = run(sparse_mixing(make_sparse_topology("ring", n)), driver)
        np.testing.assert_allclose(hd.loss, hs.loss, rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(
            hd.consensus_err, hs.consensus_err, rtol=1e-4, atol=1e-6
        )
        assert hd.accountant.agent_to_agent_bytes == \
            hs.accountant.agent_to_agent_bytes
        np.testing.assert_allclose(
            np.asarray(hd.final_state.x["w"]),
            np.asarray(hs.final_state.x["w"]),
            rtol=1e-4, atol=1e-6,
        )


@pytest.mark.parametrize("network", [None, "bernoulli:0.4", "cohort:0.5"])
def test_sparse_experiment_spec_matches_dense(network):
    spec = ExperimentSpec.create(
        algo="pisco", n_agents=N_AGENTS, t_o=2, eta_l=0.1, p=0.3, seed=2,
        network=network, rounds=6, driver="scan", block_size=3,
    )
    hd = _experiment(spec.replace(sparse=False)).run()
    hs = _experiment(spec.replace(sparse=True)).run()
    np.testing.assert_allclose(hd.loss, hs.loss, rtol=1e-5, atol=1e-6)
    assert hd.accountant.per_round_bytes == hs.accountant.per_round_bytes


# ---------------------------------------------------------------------------
# Lemma-1 invariant under sparse sampled links x compression x participation
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("compression", ["q8", "top0.3"])
@pytest.mark.parametrize("network", ["bernoulli:0.4", "cohort:0.5"])
def test_gt_invariant_on_sparse_path(network, compression):
    spec = ExperimentSpec.create(
        algo="pisco", n_agents=N_AGENTS, t_o=2, eta_l=0.1, p=0.3, seed=2,
        network=network, participation=0.6, compression=compression,
        sparse=True, rounds=8, eval_every=4, driver="scan", block_size=3,
    )
    hist = _experiment(spec).run()
    state = hist.final_state
    assert state is not None and np.isfinite(hist.loss).all()
    y_bar = np.asarray(jnp.mean(state.y["w"], axis=0))
    g_bar = np.asarray(jnp.mean(state.g["w"], axis=0))
    scale = max(1.0, float(np.abs(g_bar).max()))
    np.testing.assert_allclose(y_bar, g_bar, atol=2e-5 * scale)


# ---------------------------------------------------------------------------
# Byte accounting: sparse edges priced identically to dense
# ---------------------------------------------------------------------------


def test_sparse_realized_gossip_bytes_match_hand_count():
    """roundrobin:2 on a 4-ring realizes 2 of 4 base edges per round; the
    sparse accountant must charge the same 2 mixes x 4 directed messages
    as the dense path — the wire does not care about the W representation."""
    n, rounds = 4, 4
    spec = ExperimentSpec.create(
        algo="pisco", n_agents=n, t_o=1, eta_l=0.1, p=0.0, seed=0,
        network="roundrobin:2", sparse=True, rounds=rounds,
        driver="scan", block_size=2,
    )
    hist = _experiment(spec, n=n).run()
    msg = 16 * 4  # one fp32 message of the d=16 problem
    per_round = 2 * (2 * 2) * msg  # 2 mixes x (2 realized edges x 2 dirs)
    assert hist.accountant.per_round_bytes == [per_round] * rounds
    assert hist.accountant.agent_to_agent_bytes == rounds * per_round
    h_dense = _experiment(spec.replace(sparse=False), n=n).run()
    assert h_dense.accountant.per_round_bytes == \
        hist.accountant.per_round_bytes


# ---------------------------------------------------------------------------
# Cohort sugar + spec serialization
# ---------------------------------------------------------------------------


def test_cohort_field_expands_to_network_spec():
    spec = ExperimentSpec.create(
        algo="pisco", n_agents=8, t_o=1, eta_l=0.1, p=0.3,
        cohort=0.25, rounds=2,
    )
    assert spec.effective_network == "cohort:0.25"
    with pytest.raises(ValueError, match="cohort"):
        ExperimentSpec.create(
            algo="pisco", n_agents=8, t_o=1, eta_l=0.1, p=0.3,
            cohort=0.25, network="static", rounds=2,
        )


def test_cohort_process_edges_are_seed_incident():
    proc = make_topology_process(
        "cohort:0.5", make_sparse_topology("ring", 8), seed=1
    )
    for k in range(4):
        seeds = set(proc.seeds_at(k))
        assert len(seeds) == 4  # ceil(0.5 * 8)
        for i, j in proc.edges_at(k):
            assert i in seeds or j in seeds


def test_spec_json_round_trip_and_legacy_payload():
    spec = ExperimentSpec.create(
        algo="pisco", n_agents=2048, t_o=2, eta_l=0.1, p=0.1,
        sparse=True, cohort=0.25, rounds=4,
    )
    assert ExperimentSpec.from_json(spec.to_json()) == spec
    # a pre-sparse-era payload (no sparse/cohort keys) loads with defaults
    legacy = json.loads(spec.to_json())
    del legacy["sparse"], legacy["cohort"]
    old = ExperimentSpec.from_dict(legacy)
    assert old.sparse is None and old.cohort is None
    assert old.effective_network == old.network


def test_auto_sparse_threshold():
    assert not use_sparse_topology(None, SPARSE_AUTO_MIN_AGENTS)
    assert use_sparse_topology(None, SPARSE_AUTO_MIN_AGENTS + 1)
    assert use_sparse_topology(True, 2)
    assert not use_sparse_topology(False, 10**6)


# ---------------------------------------------------------------------------
# Pallas sparse-mix kernels vs oracles (interpret mode)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name,n,d", [("ring", 8, 16), ("star", 12, 130), ("torus", 16, 64)])
def test_sparse_mix_kernel_matches_ref_and_dense(name, n, d):
    topo = make_sparse_topology(name, n)
    senders, receivers, edge_w = topology_edge_arrays(topo)
    self_w = topo.self_weight.astype(np.float32)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
    out = sparse_mix(x, senders, receivers, edge_w, self_w, interpret=True)
    ref = sparse_mix_ref(x, senders, receivers, edge_w, self_w)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-6, atol=1e-6)
    dense = topo.dense_w().astype(np.float32) @ np.asarray(x)
    np.testing.assert_allclose(np.asarray(out), dense, rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("bits", [4, 8])
def test_sparse_compressed_mix_kernel_matches_ref(bits):
    topo = make_sparse_topology("ring", 10)
    senders, receivers, edge_w = topology_edge_arrays(topo)
    self_w = topo.self_weight.astype(np.float32)
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=(10, 40)).astype(np.float32))
    out = sparse_compressed_mix(
        x, senders, receivers, edge_w, self_w, bits=bits, gamma=0.7,
        interpret=True,
    )
    ref = sparse_compressed_mix_ref(
        x, senders, receivers, edge_w, self_w, bits, gamma=0.7
    )
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-5, atol=1e-5)
    # mean preservation: compressed sparse gossip is still difference-form
    np.testing.assert_allclose(
        np.asarray(out).mean(axis=0), np.asarray(x).mean(axis=0),
        rtol=1e-4, atol=1e-5,
    )


# ---------------------------------------------------------------------------
# Large-n smoke: the whole point of the substrate (fast-lane resident)
# ---------------------------------------------------------------------------


def test_large_n_sparse_smoke():
    """n=4096 sparse training — a size the dense path cannot represent
    without a 67 MB mixing matrix per operand.  Deliberately NOT marked
    slow: it pins that large-n stays cheap enough for the CI fast lane."""
    n, d, rounds = 4096, 4, 3
    rng = np.random.default_rng(0)
    targets = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))

    def loss_fn(params, batch):
        return 0.5 * jnp.mean((params["w"] - batch) ** 2)

    def sampler(k):
        return jnp.stack([targets, targets]), targets

    topo = make_sparse_topology("random_regular", n, seed=0, degree=4)
    # union of 2 Hamiltonian cycles: ~n·deg/2 edges minus any coincidences
    assert topo.connected and n <= topo.n_edges <= n * 2
    mixing = dynamic_sparse_mixing(
        make_topology_process("cohort:0.25", topo, seed=0)
    )
    cfg = PiscoConfig(n_agents=n, t_o=2, eta_l=0.1, eta_c=1.0, p=0.2, seed=0)
    x0 = replicate_params({"w": jnp.zeros(d, jnp.float32)}, n)
    hist = run_training(
        "pisco", loss_fn, x0, cfg, mixing, sampler,
        rounds=rounds, driver="scan", block_size=rounds,
    )
    assert np.isfinite(hist.loss).all()
    assert float(hist.loss[-1]) < float(hist.loss[0])
    assert hist.final_state.x["w"].shape == (n, d)
