"""Conformance tests for the dynamic-network protocol stack.

Composes the PR-1 compressed-gossip subsystem with time-varying topologies
and partial participation, and checks the invariants the whole stack rests
on: Lemma 1 (mean tracking) under sampled links, realized-edge byte
accounting against hand-computed counts, and seed determinism of the
``network=`` ExperimentSpec field across drivers and serialization.
"""
import json
import pickle

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import make_logreg_problem
from repro.core import (
    Experiment,
    ExperimentSpec,
    dense_mixing,
    make_topology,
    message_bytes,
)

N_AGENTS = 5


def _experiment(spec, n=N_AGENTS):
    loss_fn, _, sampler_factory, d = make_logreg_problem(n_agents=n)
    return Experiment(
        spec,
        loss_fn=loss_fn,
        params0={"w": jnp.zeros(d)},
        sampler_factory=lambda s: sampler_factory(s.config.t_o),
    )


# ---------------------------------------------------------------------------
# Gradient-tracking invariant (Lemma 1) under sampled links x compression
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("compression", [None, "q8", "top0.3"])
@pytest.mark.parametrize("network", ["bernoulli:0.4", "matching"])
def test_gt_invariant_survives_sampled_links_and_compression(network, compression):
    """mean(Y) == mean(G) after rounds of sampled-link (and compressed)
    gossip plus partially-participating server rounds: every realized W_k and
    S_k is doubly stochastic and the difference-form compressed gossip
    preserves the agent mean, so the Lemma-1 identity must hold exactly
    (up to float32 accumulation)."""
    spec = ExperimentSpec.create(
        algo="pisco", n_agents=N_AGENTS, t_o=2, eta_l=0.1, p=0.3, seed=2,
        network=network, participation=0.6, compression=compression,
        rounds=8, eval_every=4, driver="scan", block_size=3,
    )
    hist = _experiment(spec).run()
    state = hist.final_state
    assert state is not None and np.isfinite(hist.loss).all()
    y_bar = np.asarray(jnp.mean(state.y["w"], axis=0))
    g_bar = np.asarray(jnp.mean(state.g["w"], axis=0))
    scale = max(1.0, float(np.abs(g_bar).max()))
    np.testing.assert_allclose(y_bar, g_bar, atol=2e-5 * scale)


# ---------------------------------------------------------------------------
# Realized-edge / realized-participant byte accounting
# ---------------------------------------------------------------------------


def test_realized_gossip_bytes_match_hand_computed_edge_count():
    """roundrobin:2 on a 4-ring realizes exactly 2 of the 4 base edges per
    round — the accountant must charge PISCO's two mixes over 4 directed
    messages, not the static graph's 8."""
    n, rounds = 4, 4
    spec = ExperimentSpec.create(
        algo="pisco", n_agents=n, t_o=1, eta_l=0.1, p=0.0, seed=0,
        network="roundrobin:2", rounds=rounds, driver="scan", block_size=2,
    )
    exp = _experiment(spec, n=n)
    hist = exp.run()
    d = 16  # make_logreg_problem feature dim
    msg = d * 4  # one fp32 message per agent
    assert hist.byte_model.gossip_message_bytes == msg
    per_round = 2 * (2 * 2) * msg  # 2 mixes x (2 realized edges x 2 dirs)
    assert hist.accountant.per_round_bytes == [per_round] * rounds
    assert hist.accountant.agent_to_agent_bytes == rounds * per_round
    assert hist.accountant.agent_to_server_bytes == 0
    # the static model would have priced the full ring (4 edges): 2x more
    assert hist.byte_model.gossip_round_bytes == 2 * per_round


def test_realized_server_bytes_price_sampled_participants():
    """participation=0.5 on 4 agents samples m=2: a server round moves
    2 uploads + 2 downloads of PISCO's two payloads, not 4+4."""
    n, rounds = 4, 3
    spec = ExperimentSpec.create(
        algo="pisco", n_agents=n, t_o=1, eta_l=0.1, p=1.0, seed=0,
        network="static", participation=0.5, rounds=rounds,
        driver="scan", block_size=2,
    )
    hist = _experiment(spec, n=n).run()
    msg = 16 * 4
    per_round = 2 * 2 * 2 * msg  # server_payloads x 2 dirs x m participants
    assert hist.accountant.per_round_bytes == [per_round] * rounds
    assert hist.accountant.agent_to_server_bytes == rounds * per_round
    # full participation would have cost n/m = 2x more per round
    assert hist.byte_model.server_round_bytes == 2 * per_round


def test_joint_compression_dynamic_participation_bytes_hand_counted():
    """The three pricing paths *composed* — q8 gossip compression x
    roundrobin:2 link cycling x m-of-n participation — against fully
    hand-counted per-round charges.

    On a 4-ring (base edges (0,1),(0,3),(1,2),(2,3)), roundrobin:2 realizes
    exactly 2 edges every round.  A q8 message for the d=16 problem is
    16x8 + 32 scale bits = 20 bytes; a full-precision server message is
    64 bytes.  PISCO mixes two streams (X and Y) and ships two payloads per
    server direction, and participation=0.5 samples m=2 of 4 agents:

      gossip round: 2 mixes x (2 edges x 2 dirs) x 20 B          = 160 B
      server round: 2 payloads x 2 dirs x 2 participants x 64 B  = 512 B
    """
    n, rounds = 4, 6
    spec = ExperimentSpec.create(
        algo="pisco", n_agents=n, t_o=1, eta_l=0.1, p=0.5, seed=3,
        network="roundrobin:2", participation=0.5, compression="q8",
        rounds=rounds, driver="scan", block_size=2,
    )
    hist = _experiment(spec, n=n).run()
    assert hist.byte_model.gossip_message_bytes == 20
    assert hist.byte_model.server_message_bytes == 64
    expected = [512 if g else 160 for g in hist.is_global]
    assert hist.accountant.per_round_bytes == expected
    n_srv = sum(hist.is_global)
    assert 0 < n_srv < rounds  # p=0.5/seed=3 realizes both round kinds
    assert hist.accountant.agent_to_server_bytes == 512 * n_srv
    assert hist.accountant.agent_to_agent_bytes == 160 * (rounds - n_srv)
    # identical charges under the legacy loop driver (same pure draws)
    h_loop = _experiment(spec.replace(driver="loop"), n=n).run()
    assert h_loop.accountant.per_round_bytes == expected


def test_static_process_bytes_and_losses_match_legacy_dense_path():
    """network='static' runs through the dynamic machinery but must realize
    the same matrices and the same per-round bytes as the legacy frozen-W
    path (network=None)."""
    base_kw = dict(
        algo="dsgt", n_agents=N_AGENTS, t_o=1, eta_l=0.1, p=0.3, seed=1,
        rounds=7, driver="scan", block_size=3,
    )
    h_legacy = _experiment(ExperimentSpec.create(**base_kw)).run()
    h_static = _experiment(
        ExperimentSpec.create(network="static", **base_kw)
    ).run()
    assert h_legacy.is_global == h_static.is_global
    assert (
        h_legacy.accountant.per_round_bytes
        == h_static.accountant.per_round_bytes
    )
    np.testing.assert_allclose(h_legacy.loss, h_static.loss, rtol=1e-5, atol=1e-7)


# ---------------------------------------------------------------------------
# Seed determinism + spec round-trips
# ---------------------------------------------------------------------------


def test_network_spec_round_trips_and_reproduces_history_exactly():
    """A spec with network/participation fields survives dict / JSON / pickle
    round-trips, and every round-tripped copy reproduces a byte-identical
    History under both drivers (same seeds => same realized links,
    participants, schedule, and floats)."""
    spec = ExperimentSpec.create(
        algo="pisco", n_agents=N_AGENTS, t_o=2, eta_l=0.15, p=0.3, seed=5,
        network="bernoulli:0.35", participation=0.6,
        rounds=8, eval_every=4, block_size=3,
    )
    copies = [
        ExperimentSpec.from_dict(spec.to_dict()),
        ExperimentSpec.from_json(spec.to_json()),
        pickle.loads(pickle.dumps(spec)),
    ]
    for c in copies:
        assert c == spec
    payload = json.loads(spec.to_json())
    assert payload["network"] == "bernoulli:0.35"
    assert payload["participation"] == 0.6

    for driver in ("loop", "scan"):
        ref = _experiment(spec.replace(driver=driver)).run()
        for c in copies:
            rerun = _experiment(c.replace(driver=driver)).run()
            assert rerun.is_global == ref.is_global
            assert rerun.loss == ref.loss  # bitwise: same program, same draws
            assert rerun.grad_sq_norm == ref.grad_sq_norm
            assert (
                rerun.accountant.per_round_bytes
                == ref.accountant.per_round_bytes
            )


def test_sweep_seeds_threads_dynamic_network_operands():
    """The vmapped multi-seed sweep advances every seed through the same
    realized network (matrices broadcast over the seed axis); the seed whose
    data sampler matches a solo run must reproduce it."""
    loss_fn, _, sampler_factory, d = make_logreg_problem(n_agents=4)
    spec = ExperimentSpec.create(
        algo="pisco", n_agents=4, t_o=1, eta_l=0.1, p=0.4, seed=0,
        network="matching", participation=0.5,
        rounds=6, driver="scan", block_size=3,
    )
    factory = lambda s: sampler_factory(s.config.t_o, seed=s.config.seed)
    exp = Experiment(
        spec, loss_fn=loss_fn, params0={"w": jnp.zeros(d)},
        sampler_factory=factory,
    )
    swept = exp.sweep(seeds=[0, 1])
    solo = Experiment(
        spec, loss_fn=loss_fn, params0={"w": jnp.zeros(d)},
        sampler_factory=factory,
    ).run()
    # seed 0 shares the spec's schedule/network/data seeds with the solo run
    assert swept[0].is_global == solo.is_global
    np.testing.assert_allclose(swept[0].loss, solo.loss, rtol=1e-5, atol=1e-6)
    for hist in swept:
        assert len(hist.loss) == 6 and np.isfinite(hist.loss).all()
        # realized charges are a network property: identical across seeds
        assert (
            hist.accountant.per_round_bytes
            == solo.accountant.per_round_bytes
        )


def test_participation_validation():
    with pytest.raises(ValueError, match="participation"):
        ExperimentSpec.create(algo="pisco", n_agents=4, participation=0.0)
    with pytest.raises(ValueError, match="participation"):
        ExperimentSpec.create(algo="pisco", n_agents=4, participation=1.5)


def test_network_spec_validated_at_construction():
    """Typos fail when the spec is built, not mid-run inside make_mixing."""
    with pytest.raises(ValueError, match="unknown topology process"):
        ExperimentSpec.create(algo="pisco", n_agents=4, network="bernouli:0.3")
    with pytest.raises(ValueError, match="failure prob"):
        ExperimentSpec.create(algo="pisco", n_agents=4, network="bernoulli:1.5")
    with pytest.raises(ValueError, match="takes no argument"):
        ExperimentSpec.create(algo="pisco", n_agents=4, network="matching:3")


def test_old_spec_payloads_still_load():
    """Pre-dynamic JSON payloads (no network/participation keys) deserialize
    to the legacy static behavior."""
    spec = ExperimentSpec.create(algo="dsgd", n_agents=4, p=0.0, rounds=5)
    d = spec.to_dict()
    d.pop("network")
    d.pop("participation")
    old = ExperimentSpec.from_dict(d)
    assert old.network is None and old.participation == 1.0
    assert old == spec
