"""Byzantine fault injection + robust aggregation conformance (DESIGN.md §14).

* Hand-computed pins for the robust primitives (trimmed mean, coordinate
  median, Krum selection) on tiny hand-built fleets.
* Property test: with f < n/2 sign-flippers, trimmed mean preserves the
  honest-agent mean within tolerance while plain mean does not.
* Lemma-1 check documenting exactly where gradient tracking's invariant
  mean(Y) == mean(G) survives (clean mean aggregation) and where it breaks
  (corrupted payloads, non-mean rules).
* Wrapper conformance: clean path is the *same object*, accounting is
  bit-identical clean vs adversarial, loop/scan drivers agree under every
  adversary kind, the events trivial path runs, specs validate and
  JSON-round-trip, History records the mask and per-agent eval series.
"""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from conftest import make_logreg_problem
from repro.core import (
    Experiment,
    ExperimentSpec,
    PiscoConfig,
    dense_mixing,
    init_state,
    make_round_fn,
    make_sparse_topology,
    make_topology,
    replicate_params,
    sparse_mixing,
)
from repro.core.adversary import (
    AdversaryProcess,
    AdversarialNetwork,
    adversary_mask,
    make_adversarial_mixing,
    parse_adversary_spec,
    unwrap_network,
)
from repro.core.mixing import make_robust_agg, parse_robust_spec
from repro.data import FederatedDataset, RoundSampler
from repro.utils.pytree import (
    tree_agent_krum,
    tree_agent_mean,
    tree_agent_median,
    tree_agent_trimmed_mean,
)


def _col(values):
    """(n, 1) float32 single-leaf fleet from a value-per-agent list."""
    return {"w": jnp.asarray(values, jnp.float32).reshape(-1, 1)}


def _max_abs_diff(a, b):
    return max(
        float(jnp.max(jnp.abs(x - y)))
        for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b))
    )


# ---------------------------------------------------------------------------
# Hand-computed pins for the robust primitives
# ---------------------------------------------------------------------------


def test_trimmed_mean_hand_pin():
    fleet = _col([1.0, 2.0, 3.0, 4.0, 100.0])
    out = tree_agent_trimmed_mean(fleet, trim=1)
    # drop {1, 100}, average {2, 3, 4} = 3, broadcast to every agent row
    np.testing.assert_allclose(np.asarray(out["w"]), 3.0)
    # trim=0 is exactly the mean
    np.testing.assert_array_equal(
        np.asarray(tree_agent_trimmed_mean(fleet, trim=0)["w"]),
        np.asarray(tree_agent_mean(fleet)["w"]),
    )


def test_trimmed_mean_is_coordinatewise():
    # per-coordinate trimming: the outlier agent differs per column
    x = jnp.asarray([[0.0, 5.0], [1.0, 6.0], [2.0, 7.0], [99.0, -99.0]])
    out = tree_agent_trimmed_mean({"w": x}, trim=1)[("w")]
    # col 0 keeps {1, 2} -> 1.5; col 1 keeps {5, 6} -> 5.5
    np.testing.assert_allclose(np.asarray(out[0]), [1.5, 5.5])


def test_median_hand_pin():
    np.testing.assert_allclose(
        np.asarray(tree_agent_median(_col([1.0, 2.0, 3.0, 4.0, 100.0]))["w"]),
        3.0,
    )
    # even fleet: midpoint interpolation
    np.testing.assert_allclose(
        np.asarray(tree_agent_median(_col([1.0, 2.0, 3.0, 10.0]))["w"]), 2.5
    )


def test_krum_hand_pin():
    # n=5, n_byz=1 -> each agent scored on its m = 5-1-2 = 2 closest peers:
    # agents at 2 and 3 tie on score 2 (peers one apart on both sides);
    # argmin takes the first, so Krum returns agent 1's submission, value 2.
    out = tree_agent_krum(_col([1.0, 2.0, 3.0, 4.0, 100.0]), n_byz=1)
    np.testing.assert_allclose(np.asarray(out["w"]), 2.0)


def test_krum_distance_sums_across_leaves():
    # same first leaf, but a second leaf makes agent 1 an outlier — the
    # summed-across-leaves distance must move the selection to agent 2
    fleet = {
        "a": jnp.asarray([1.0, 2.0, 3.0, 4.0, 100.0]).reshape(-1, 1),
        "b": jnp.asarray([0.0, 10.0, 0.0, 0.0, 0.0]).reshape(-1, 1),
    }
    out = tree_agent_krum(fleet, n_byz=1)
    np.testing.assert_allclose(np.asarray(out["a"]), 3.0)
    np.testing.assert_allclose(np.asarray(out["b"]), 0.0)


def test_krum_returns_an_actual_submission():
    # Krum never blends: the aggregate equals some agent's full row
    rng = np.random.default_rng(3)
    fleet = {"w": jnp.asarray(rng.normal(size=(6, 4)), jnp.float32)}
    out = np.asarray(tree_agent_krum(fleet, n_byz=2)["w"])
    rows = np.asarray(fleet["w"])
    assert any(np.array_equal(out[0], rows[i]) for i in range(6))


# ---------------------------------------------------------------------------
# Property test: trimmed mean survives a sign-flipping minority, mean does not
# ---------------------------------------------------------------------------


@given(n=st.integers(5, 12), seed=st.integers(0, 200))
@settings(max_examples=25, deadline=None)
def test_trimmed_mean_survives_signflip_minority(n, seed):
    rng = np.random.default_rng(seed)
    n_byz = int(rng.integers(1, (n - 1) // 2 + 1))  # f < n/2, at least one
    c = 5.0
    honest = c + rng.normal(size=(n, 3)) * 0.05
    byz = rng.choice(n, size=n_byz, replace=False)
    values = honest.copy()
    values[byz] = -honest[byz]  # the sign-flip attack on the wire
    honest_mean = honest[np.setdiff1d(np.arange(n), byz)].mean(axis=0)

    fleet = {"w": jnp.asarray(values, jnp.float32)}
    trimmed = np.asarray(tree_agent_trimmed_mean(fleet, trim=n_byz)["w"])[0]
    median = np.asarray(tree_agent_median(fleet)["w"])[0]
    mean = np.asarray(tree_agent_mean(fleet)["w"])[0]

    # flipped rows sit at -c, far below the honest cluster at +c: the trim
    # discards them all, so the aggregate stays inside the honest spread
    assert np.max(np.abs(trimmed - honest_mean)) < 0.5
    assert np.max(np.abs(median - honest_mean)) < 0.5
    # plain mean is contracted by ~2 * c * n_byz / n — far outside tolerance
    assert np.max(np.abs(mean - honest_mean)) > 2.0 * c * n_byz / n - 0.5


# ---------------------------------------------------------------------------
# Spec grammars: adversary + robust_agg parse and fail fast
# ---------------------------------------------------------------------------


def test_parse_adversary_spec_grammar():
    adv = parse_adversary_spec("signflip:f=0.25", n_agents=8, seed=3)
    assert (adv.kind, adv.f, adv.n_byz) == ("signflip", 0.25, 2)
    adv = parse_adversary_spec("random:f=0.1,scale=5", n_agents=10)
    assert (adv.kind, adv.scale, adv.needs_round) == ("random", 5.0, True)
    adv = parse_adversary_spec("collusion:f=0.25,target=drift", n_agents=8)
    assert adv.spec() == "collusion:f=0.25,target=drift"
    # spec() round-trips through the parser
    for s in ("signflip:f=0.2", "random:f=0.3,scale=2", "collusion:f=0.25"):
        adv = parse_adversary_spec(s, n_agents=8)
        assert parse_adversary_spec(adv.spec(), n_agents=8) == adv


@pytest.mark.parametrize("bad", [
    "omniscient:f=0.2",          # unknown kind
    "signflip:frac=0.2",         # unknown key
    "signflip:f=0",              # fraction must be in (0, 1)
    "signflip:f=1.0",
    "collusion:f=0.2,target=mean",  # only drift collusion is implemented
])
def test_parse_adversary_spec_rejects(bad):
    with pytest.raises(ValueError):
        parse_adversary_spec(bad, n_agents=8)


def test_adversary_needs_one_honest_agent():
    with pytest.raises(ValueError):
        AdversaryProcess(kind="signflip", f=0.9, n_agents=2)  # ceil = 2 of 2


def test_parse_robust_spec():
    assert parse_robust_spec("trimmed:f=0.3") == ("trimmed", 0.3)
    assert parse_robust_spec("median") == ("median", 0.2)
    assert make_robust_agg("mean", 8) is None  # clean path keeps base rule
    for bad in ("huber", "median:f=0.1", "trimmed:g=0.1", "trimmed:f=0.6"):
        with pytest.raises(ValueError):
            parse_robust_spec(bad)
    with pytest.raises(ValueError):  # n - 2*ceil(f*n) < 1: nothing left
        make_robust_agg("trimmed:f=0.45", 4)


# ---------------------------------------------------------------------------
# The adversary process: mask purity + on-device corruption
# ---------------------------------------------------------------------------


def test_mask_pure_in_seed():
    a = AdversaryProcess(kind="signflip", f=0.2, n_agents=16, seed=4)
    np.testing.assert_array_equal(a.mask(), a.mask())
    assert int(a.mask().sum()) == a.n_byz == 4
    b = AdversaryProcess(kind="signflip", f=0.2, n_agents=16, seed=5)
    assert not np.array_equal(a.mask(), b.mask())
    assert adversary_mask(None, 16) is None
    assert adversary_mask("signflip:f=0.2", 16, seed=4) == list(a.mask())


def test_signflip_corruption_rows():
    adv = AdversaryProcess(kind="signflip", f=0.25, scale=2.0, n_agents=8)
    tree = {"w": jnp.asarray(np.arange(16, dtype=np.float32).reshape(8, 2))}
    out = adv.make_corrupt()(tree, None)
    mask = adv.mask()
    np.testing.assert_array_equal(
        np.asarray(out["w"])[~mask], np.asarray(tree["w"])[~mask]
    )  # honest rows pass through bit-exactly
    np.testing.assert_array_equal(
        np.asarray(out["w"])[mask], -2.0 * np.asarray(tree["w"])[mask]
    )


def test_random_corruption_pure_in_seed_and_round():
    adv = AdversaryProcess(kind="random", f=0.25, n_agents=8, seed=9)
    tree = {"w": jnp.ones((8, 3), jnp.float32)}
    # two independently constructed closures agree bit-for-bit under jit
    c1 = jax.jit(adv.make_corrupt())
    c2 = jax.jit(AdversaryProcess(kind="random", f=0.25, n_agents=8, seed=9)
                 .make_corrupt())
    np.testing.assert_array_equal(
        np.asarray(c1(tree, 3)["w"]), np.asarray(c2(tree, 3)["w"])
    )
    mask = adv.mask()
    out3, out4 = np.asarray(c1(tree, 3)["w"]), np.asarray(c1(tree, 4)["w"])
    np.testing.assert_array_equal(out3[~mask], 1.0)  # honest rows untouched
    assert not np.array_equal(out3[mask], out4[mask])  # fresh noise per round


def test_collusion_rows_agree():
    adv = AdversaryProcess(kind="collusion", f=0.4, scale=3.0, n_agents=5)
    rng = np.random.default_rng(0)
    tree = {"w": jnp.asarray(rng.normal(size=(5, 6)), jnp.float32)}
    out = np.asarray(adv.make_corrupt()(tree, None)["w"])
    mask = adv.mask()
    byz = out[mask]
    np.testing.assert_array_equal(byz, np.broadcast_to(byz[0], byz.shape))
    np.testing.assert_array_equal(out[~mask], np.asarray(tree["w"])[~mask])
    # the common value sits `scale` away from the fleet mean
    drift = byz[0] - np.asarray(tree["w"]).mean(axis=0)
    np.testing.assert_allclose(np.linalg.norm(drift), 3.0, rtol=1e-5)


# ---------------------------------------------------------------------------
# The MixingOps wrapper
# ---------------------------------------------------------------------------


def test_clean_path_returns_base_object():
    for base in (
        dense_mixing(make_topology("ring", 6)),
        sparse_mixing(make_sparse_topology("ring", 6)),
    ):
        assert make_adversarial_mixing(base, None, "mean", n_agents=6) is base


def test_wrapper_preserves_accounting_metadata():
    base = dense_mixing(make_topology("ring", 6))
    wrapped = make_adversarial_mixing(
        base, "signflip:f=0.2", "trimmed", n_agents=6
    )
    assert wrapped.gossip_edges == base.gossip_edges
    assert wrapped.gossip_messages == base.gossip_messages
    assert "adv:signflip" in wrapped.name and "robust:trimmed" in wrapped.name


def test_adversarial_network_unwraps_to_base():
    base = dense_mixing(make_topology("ring", 6))
    wrapped = make_adversarial_mixing(base, "random:f=0.2", n_agents=6)
    assert isinstance(wrapped.network, AdversarialNetwork)
    assert unwrap_network(wrapped.network) is base.network
    assert unwrap_network(base.network) is base.network  # idempotent on bases


def test_wrapped_global_avg_applies_rule_to_corrupted_payloads():
    # end-to-end wiring pin: 2 flippers among 6 agents at value 1.0 — the
    # plain-mean wrapper sees {1,1,1,1,-1,-1} -> 1/3; trimmed recovers 1.0
    n = 6
    base = dense_mixing(make_topology("full", n))
    tree = {"w": jnp.ones((n, 2), jnp.float32)}
    m_mean = make_adversarial_mixing(base, "signflip:f=0.2", "mean", n_agents=n)
    m_trim = make_adversarial_mixing(base, "signflip:f=0.2", "trimmed:f=0.2",
                                     n_agents=n)
    np.testing.assert_allclose(
        np.asarray(m_mean.global_avg(tree)["w"]), 1.0 / 3.0, rtol=1e-6
    )
    np.testing.assert_allclose(np.asarray(m_trim.global_avg(tree)["w"]), 1.0)


# ---------------------------------------------------------------------------
# Lemma 1: where gradient tracking's invariant survives and where it breaks
# ---------------------------------------------------------------------------


def _tracking_deviation(mixing, n=8, seed=0, rounds=3):
    loss_fn, _, sampler_factory, d = make_logreg_problem(n_agents=n, seed=seed)
    cfg = PiscoConfig(n_agents=n, t_o=2, eta_l=0.1, eta_c=0.9, p=0.5)
    sampler = sampler_factory(2, seed=seed)
    x0 = replicate_params({"w": jnp.zeros(d)}, n)
    state = init_state(loss_fn, x0, sampler(-1)[1])
    fn = jax.jit(make_round_fn(loss_fn, cfg, mixing, global_round=True))
    for k in range(rounds):
        state, _ = fn(state, *sampler(k))
    mean0 = lambda t: jax.tree.map(lambda v: jnp.mean(v, axis=0), t)
    return _max_abs_diff(mean0(state.y), mean0(state.g))


def test_lemma1_survives_clean_breaks_under_corruption_and_robust_rules():
    base = dense_mixing(make_topology("ring", 8))
    clean = _tracking_deviation(base)
    corrupted = _tracking_deviation(
        make_adversarial_mixing(base, "signflip:f=0.25", "mean", n_agents=8)
    )
    robust = _tracking_deviation(
        make_adversarial_mixing(base, None, "trimmed:f=0.2", n_agents=8)
    )
    # clean mean aggregation preserves mean(Y) == mean(G) exactly (Lemma 1);
    # flipped payloads break it outright, and even a *clean* fleet under a
    # non-mean rule loses the exact invariant (trimming is not the mean) —
    # the documented trade for bounded aggregate error under attack.
    assert clean < 1e-5
    assert corrupted > 1e-3
    assert robust > 10 * max(clean, 1e-7)


# ---------------------------------------------------------------------------
# ExperimentSpec wiring: validation, JSON, accounting, History series
# ---------------------------------------------------------------------------


def _data(n=6, seed=0):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(240, 5))
    y = np.sign(rng.normal(size=240))
    return FederatedDataset.from_arrays(x, y, n, heterogeneous=False, seed=seed)


def _experiment(n=6, rounds=6, **spec_kw):
    from repro.models import simple as S

    data = _data(n)
    spec = ExperimentSpec.create(
        algo="pisco", n_agents=n, t_o=2, eta_l=0.1, p=0.5, seed=0,
        rounds=rounds, eval_every=max(1, rounds // 2), **spec_kw
    )
    return Experiment(
        spec,
        loss_fn=S.logreg_loss,
        params0={"w": jnp.zeros((5,), jnp.float32)},
        sampler_factory=lambda s: RoundSampler(
            data, batch_size=8, t_o=s.config.t_o, seed=s.config.seed
        ),
        eval_fn=lambda params: {
            "loss": float(S.logreg_loss(
                params, (jnp.asarray(data.x_test), jnp.asarray(data.y_test))
            ))
        },
    )


def test_spec_validates_adversary_and_robust():
    _experiment(adversary="signflip:f=0.2", robust_agg="trimmed")  # fine
    with pytest.raises(ValueError):
        ExperimentSpec.create(algo="pisco", n_agents=6, adversary="bogus:f=0.2")
    with pytest.raises(ValueError):
        ExperimentSpec.create(algo="pisco", n_agents=6, robust_agg="huber")
    with pytest.raises(ValueError):  # robust rules need everyone's upload
        ExperimentSpec.create(
            algo="pisco", n_agents=6, robust_agg="median", participation=0.5
        )
    with pytest.raises(ValueError):  # ... and a synchronous server round
        ExperimentSpec.create(
            algo="pisco", n_agents=6, robust_agg="median",
            driver="events", systems="uniform", async_="constant:buffer=3",
        )


def test_spec_json_round_trip_and_legacy_payloads():
    spec = ExperimentSpec.create(
        algo="pisco", n_agents=8, adversary="signflip:f=0.25",
        robust_agg="trimmed:f=0.25", rounds=4,
    )
    again = ExperimentSpec.from_json(spec.to_json())
    assert again.adversary == "signflip:f=0.25"
    assert again.robust_agg == "trimmed:f=0.25"
    assert again == spec
    # payloads written before this subsystem existed load as clean specs
    legacy = spec.to_dict()
    del legacy["adversary"], legacy["robust_agg"]
    old = ExperimentSpec.from_dict(legacy)
    assert old.adversary is None and old.robust_agg == "mean"


@pytest.mark.slow
def test_accounting_identical_clean_vs_adversarial():
    # Byzantine agents send *wrong* bytes, not fewer: pricing cannot tell
    h_clean = _experiment().run()
    h_adv = _experiment(adversary="signflip:f=0.2", robust_agg="trimmed").run()
    assert h_adv.accountant.total_bytes == h_clean.accountant.total_bytes
    assert h_adv.to_dict()["accountant"] == h_clean.to_dict()["accountant"]


def test_history_records_mask_and_per_agent_eval():
    h = _experiment(adversary="signflip:f=0.2").run()
    mask = h.adversary_mask
    assert isinstance(mask, list) and len(mask) == 6 and sum(mask) == 2
    assert h.eval_per_agent and all(
        "honest_loss" in e and "byz_loss" in e and isinstance(e["round"], int)
        for e in h.eval_per_agent
    )
    d = json.loads(json.dumps(h.to_dict()))
    assert d["adversary_mask"] == mask
    assert len(d["eval_per_agent"]) == len(h.eval_per_agent)
    # clean runs record no mask and no per-agent series
    h0 = _experiment().run()
    assert h0.adversary_mask is None and h0.eval_per_agent == []
    assert json.loads(json.dumps(h0.to_dict()))["adversary_mask"] is None


# ---------------------------------------------------------------------------
# Driver conformance: loop == scan under every kind; events trivial path runs
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kind,network", [
    ("signflip:f=0.2", None),
    # the remaining kinds are full-lane only — each case is two jitted runs,
    # and the fast lane's 5-minute wall already carries signflip parity plus
    # the wrapper unit pins above
    pytest.param("random:f=0.2,scale=0.5", None,
                 marks=pytest.mark.slow),    # exercises the round operand
    pytest.param("random:f=0.2,scale=0.5", "bernoulli:0.3",
                 marks=pytest.mark.slow),    # ... composed with a base slot
    pytest.param("collusion:f=0.2,scale=0.5", None,
                 marks=pytest.mark.slow),
])
def test_loop_and_scan_drivers_agree_under_adversary(kind, network):
    h_loop = _experiment(
        adversary=kind, robust_agg="trimmed", driver="loop", network=network
    ).run()
    h_scan = _experiment(
        adversary=kind, robust_agg="trimmed", driver="scan", network=network
    ).run()
    np.testing.assert_allclose(h_loop.loss, h_scan.loss, rtol=1e-5, atol=1e-6)
    assert h_loop.is_global == h_scan.is_global


@pytest.mark.slow
def test_events_trivial_path_matches_scan_under_adversary():
    from repro.sim import FREE_NETWORK

    kw = dict(adversary="random:f=0.2,scale=0.5", robust_agg="trimmed",
              systems=FREE_NETWORK)
    h_scan = _experiment(driver="scan", **kw).run()
    h_ev = _experiment(driver="events", **kw).run()
    np.testing.assert_array_equal(h_scan.loss, h_ev.loss)
    assert h_ev.adversary_mask == h_scan.adversary_mask
