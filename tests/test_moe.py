"""MoE layer: routing math, dropless exactness, capacity behaviour."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.models.config import ModelConfig, MoEConfig
from repro.models.moe import _route, init_moe, moe_forward
from repro.models.layers import KeyGen


def _cfg(n_experts=4, top_k=2, capacity_factor=4.0, gate_mode="softmax_topk"):
    return ModelConfig(
        name="moe-test", arch_type="moe", n_layers=2, d_model=32, n_heads=2,
        n_kv_heads=2, d_ff=64, vocab_size=64, mlp_type="swiglu",
        moe=MoEConfig(
            n_experts=n_experts, top_k=top_k, d_expert=48,
            capacity_factor=capacity_factor, gate_mode=gate_mode,
        ),
    )


def _dense_reference(params, cfg, x):
    """Dropless ground truth: run every expert on every token, combine."""
    mo = cfg.moe
    b, s, d = x.shape
    xf = x.reshape(-1, d)
    logits = xf.astype(jnp.float32) @ params["router"]
    top_idx, top_w, _ = _route(logits, mo)
    h_gate = jax.nn.silu(jnp.einsum("td,edf->tef", xf, params["w_gate"]))
    h = h_gate * jnp.einsum("td,edf->tef", xf, params["w_up"])
    y_all = jnp.einsum("tef,efd->ted", h, params["w_down"])  # (T, E, d)
    w_full = jnp.zeros((xf.shape[0], mo.n_experts))
    w_full = w_full.at[jnp.arange(xf.shape[0])[:, None], top_idx].set(top_w)
    y = jnp.einsum("te,ted->td", w_full, y_all)
    return y.reshape(b, s, d)


def test_dropless_matches_dense_reference(key):
    cfg = _cfg(capacity_factor=4.0)  # cap == T*k/E * E -> dropless
    params = init_moe(KeyGen(key), cfg, jnp.float32)
    x = jax.random.normal(key, (2, 16, cfg.d_model))
    y, aux = moe_forward(params, cfg, x)
    y_ref = _dense_reference(params, cfg, x)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=1e-5, rtol=1e-5)
    assert float(aux) > 0


@pytest.mark.parametrize("gate_mode", ["softmax_topk", "topk_softmax"])
def test_gate_weights_sum_to_one(gate_mode, key):
    mo = _cfg(gate_mode=gate_mode).moe
    logits = jax.random.normal(key, (64, mo.n_experts))
    _, top_w, probs = _route(logits, mo)
    np.testing.assert_allclose(np.asarray(jnp.sum(top_w, -1)), 1.0, atol=1e-6)
    np.testing.assert_allclose(np.asarray(jnp.sum(probs, -1)), 1.0, atol=1e-6)


@given(seed=st.integers(0, 30))
@settings(max_examples=10, deadline=None)
def test_aux_loss_minimized_by_uniform_routing(seed):
    """Load-balance loss >= coef (its value under perfectly uniform routing)."""
    from repro.models.moe import aux_load_balance_loss

    mo = _cfg().moe
    rng = np.random.default_rng(seed)
    t = 120
    probs = jax.nn.softmax(jnp.asarray(rng.normal(size=(t, mo.n_experts))), -1)
    top_idx = jnp.asarray(rng.integers(0, mo.n_experts, size=(t, mo.top_k)))
    loss = float(aux_load_balance_loss(probs, top_idx, mo))
    uniform = mo.router_aux_coef
    assert loss >= uniform * 0.8  # >= with sampling slack


def test_tight_capacity_drops_tokens(key):
    """capacity_factor < 1 must drop load — output differs from dropless."""
    cfg_drop = _cfg(capacity_factor=0.5)
    cfg_full = _cfg(capacity_factor=4.0)
    params = init_moe(KeyGen(key), cfg_full, jnp.float32)
    x = jax.random.normal(key, (2, 32, cfg_full.d_model))
    y_full, _ = moe_forward(params, cfg_full, x)
    y_drop, _ = moe_forward(params, cfg_drop, x)
    assert float(jnp.max(jnp.abs(y_full - y_drop))) > 1e-4


def test_shared_experts_added(key):
    cfg = _cfg()
    import dataclasses

    cfg_sh = dataclasses.replace(cfg, moe=dataclasses.replace(cfg.moe, n_shared=1))
    params = init_moe(KeyGen(key), cfg_sh, jnp.float32)
    x = jax.random.normal(key, (1, 8, cfg.d_model))
    y_with, _ = moe_forward(params, cfg_sh, x)
    from repro.models.mlp import mlp_forward

    shared_y = mlp_forward(params["shared"], "swiglu", x.reshape(-1, cfg.d_model))
    params_no = {k: v for k, v in params.items() if k != "shared"}
    y_without, _ = moe_forward(params_no, cfg, x)
    np.testing.assert_allclose(
        np.asarray(y_with),
        np.asarray(y_without + shared_y.reshape(x.shape)),
        atol=1e-5,
    )
