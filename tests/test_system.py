"""End-to-end behaviour tests: the launchers and the paper's headline
phenomena on small problems."""
import os
import subprocess
import sys

import jax.numpy as jnp
import numpy as np
import pytest

from conftest import make_logreg_problem
from repro.core import (
    PiscoConfig,
    dense_mixing,
    make_topology,
    replicate_params,
    run_training,
)


def _env():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    return env


@pytest.mark.slow
def test_train_launcher_end_to_end():
    proc = subprocess.run(
        [
            sys.executable, "-m", "repro.launch.train",
            "--arch", "qwen3-8b", "--reduced", "--rounds", "4",
            "--n-agents", "4", "--t-o", "1", "--batch", "2", "--seq", "32",
            "--log-every", "1",
        ],
        env=_env(), capture_output=True, text=True, timeout=900,
    )
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "done: 4 rounds" in proc.stdout
    assert "loss=" in proc.stdout


@pytest.mark.slow
def test_serve_launcher_end_to_end():
    proc = subprocess.run(
        [
            sys.executable, "-m", "repro.launch.serve",
            "--arch", "mamba2-370m", "--reduced", "--agents", "4",
            "--slots", "2", "--requests", "3",
            "--prompt-len", "16", "--gen", "4",
            "--fixed-costs", "0.05,0.01",
        ],
        env=_env(), capture_output=True, text=True, timeout=900,
    )
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "tok/s" in proc.stdout
    assert "latency p50=" in proc.stdout
    assert "fleet: synthetic (4 agents" in proc.stdout


def test_small_p_approaches_full_server_performance():
    """Fig. 5 phenomenon: p=0.1 performs close to p=1 in rounds-to-threshold."""
    n = 8
    loss_fn, full_grad_sq, sampler_factory, d = make_logreg_problem(n_agents=n)
    mixing = dense_mixing(make_topology("ring", n))
    x0 = replicate_params({"w": jnp.zeros(d)}, n)
    rounds = {}
    for p in (0.0, 0.1, 1.0):
        cfg = PiscoConfig(n_agents=n, t_o=4, eta_l=0.15, eta_c=1.0, p=p, seed=2)
        hist = run_training(
            "pisco", loss_fn, x0, cfg, mixing, sampler_factory(4),
            rounds=70,
            eval_fn=lambda xb: {"grad_sq": full_grad_sq(xb)},
            eval_every=1,
        )
        r = hist.rounds_to_threshold("grad_sq", 0.05)
        rounds[p] = r if r is not None else 10_000
    assert rounds[0.1] <= rounds[0.0]
    assert rounds[0.1] <= max(2 * rounds[1.0], rounds[1.0] + 15)


@pytest.mark.slow
def test_checkpoint_resume_in_train_launcher(tmp_path):
    args = [
        sys.executable, "-m", "repro.launch.train",
        "--arch", "mamba2-370m", "--reduced",
        "--n-agents", "2", "--t-o", "1", "--batch", "2", "--seq", "32",
        "--ckpt-dir", str(tmp_path), "--ckpt-every", "2",
    ]
    proc = subprocess.run(
        args + ["--rounds", "3"],
        env=_env(), capture_output=True, text=True, timeout=900,
    )
    assert proc.returncode == 0, proc.stderr[-2000:]
    files = os.listdir(tmp_path)
    assert any(f.startswith("ckpt_") for f in files)
    # resume: the second invocation restores the snapshot state and only
    # runs the remaining rounds
    proc = subprocess.run(
        args + ["--rounds", "4", "--log-every", "1"],
        env=_env(), capture_output=True, text=True, timeout=900,
    )
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "restored" in proc.stdout
    assert "round    3" in proc.stdout
    assert "round    0" not in proc.stdout  # starts at the restored round
