"""Distributed-semantics tests: run a subprocess with 8 fake host devices and
check that the collective (shard_map) mixers agree with the dense reference
mixers, and that a sharded PISCO round equals the single-device one."""
import os
import subprocess
import sys
import textwrap

import pytest

# slow: excluded from the quick lane; distributed: runs in its own CI job
pytestmark = [pytest.mark.slow, pytest.mark.distributed]

_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np
    import jax, jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.core.mixing import (
        collective_global_mixing, collective_shift_mixing,
    )
    from repro.core.pisco import PiscoConfig, init_state, make_round_fn
    from repro.core.mixing import dense_mixing, MixingOps
    from repro.core.topology import make_topology
    from repro.launch.steps import gossip_matrix, mesh_gossip_shifts
    from repro.utils.pytree import tree_agent_mean, tree_agent_mix

    from repro.utils.compat import make_mesh

    mesh = make_mesh((8,), ("agents",))
    n = 8
    rng = np.random.default_rng(0)
    spec_tree = {"w": P("agents", None), "b": P("agents")}
    tree = {
        "w": jnp.asarray(rng.normal(size=(n, 6)), jnp.float32),
        "b": jnp.asarray(rng.normal(size=(n,)), jnp.float32),
    }
    sharded = jax.device_put(
        tree, {k: NamedSharding(mesh, s) for k, s in spec_tree.items()}
    )

    # ---- global (J) mixing == mean ----
    g = collective_global_mixing(mesh, ("agents",), spec_tree)
    out = jax.jit(g.global_avg)(sharded)
    ref = tree_agent_mean(tree)
    err = max(float(jnp.max(jnp.abs(out[k] - ref[k]))) for k in tree)
    assert err < 1e-6, f"global mixing err {err}"

    # ---- ring gossip (ppermute) == dense circulant matmul ----
    shifts = mesh_gossip_shifts(mesh, ("agents",))
    ops = collective_shift_mixing(mesh, ("agents",), spec_tree, shifts)
    w = gossip_matrix(mesh, ("agents",), shifts)
    assert np.allclose(w.sum(0), 1) and np.allclose(w.sum(1), 1), "not doubly stochastic"
    out = jax.jit(ops.gossip)(sharded)
    ref = tree_agent_mix(tree, w)
    err = max(float(jnp.max(jnp.abs(out[k] - ref[k]))) for k in tree)
    assert err < 1e-6, f"ring gossip err {err}"

    # ---- full PISCO round: sharded collective == dense single-device ----
    d = 6
    data_x = jnp.asarray(rng.normal(size=(n, 32, d)), jnp.float32)
    data_y = jnp.asarray(
        np.where(rng.normal(size=(n, 32)) > 0, 1.0, -1.0), jnp.float32
    )
    def loss_fn(params, batch):
        a, lab = batch
        return jnp.mean(jnp.log1p(jnp.exp(-lab * (a @ params["w"]) - params["b"])))

    cfg = PiscoConfig(n_agents=n, t_o=2, eta_l=0.1, eta_c=0.9, p=0.0)
    x0 = {"w": jnp.zeros((n, d)), "b": jnp.zeros((n,))}
    local = (data_x[None].repeat(2, 0)[:, :, :16], data_y[None].repeat(2, 0)[:, :, :16])
    comm = (data_x[:, 16:], data_y[:, 16:])

    state0 = init_state(loss_fn, x0, comm)
    dense_ops = MixingOps(
        gossip=lambda t: tree_agent_mix(t, jnp.asarray(w, jnp.float32)),
        global_avg=tree_agent_mean,
    )
    fn_dense = jax.jit(make_round_fn(loss_fn, cfg, dense_ops, global_round=False))
    s_dense, m_dense = fn_dense(state0, local, comm)

    fn_coll = jax.jit(make_round_fn(loss_fn, cfg, ops, global_round=False))
    state0_sharded = jax.device_put(
        state0,
        type(state0)(
            x={k: NamedSharding(mesh, s) for k, s in spec_tree.items()},
            y={k: NamedSharding(mesh, s) for k, s in spec_tree.items()},
            g={k: NamedSharding(mesh, s) for k, s in spec_tree.items()},
            step=NamedSharding(mesh, P()),
        ),
    )
    s_coll, m_coll = fn_coll(state0_sharded, local, comm)
    for ka in ("x", "y", "g"):
        for kb in ("w", "b"):
            a = getattr(s_dense, ka)[kb]
            b = getattr(s_coll, ka)[kb]
            err = float(jnp.max(jnp.abs(a - b)))
            assert err < 1e-5, f"{ka}/{kb} err {err}"
    print("DISTRIBUTED-OK")
    """
)


def test_collective_mixers_match_dense_in_subprocess():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(
        [sys.executable, "-c", _SCRIPT], env=env, capture_output=True, text=True,
        timeout=600,
    )
    assert proc.returncode == 0, f"stderr:\n{proc.stderr[-3000:]}"
    assert "DISTRIBUTED-OK" in proc.stdout


def test_dryrun_small_pair_compiles():
    """End-to-end dry-run of one cheap pair on the 512-device mesh."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(
        [
            sys.executable, "-m", "repro.launch.dryrun",
            "--arch", "mamba2-370m", "--shape", "decode_32k",
            "--mesh", "single", "--out", "/tmp/dryrun_test",
        ],
        env=env, capture_output=True, text=True, timeout=900,
    )
    assert proc.returncode == 0, f"stderr:\n{proc.stderr[-3000:]}"
    assert "OK " in proc.stdout
