"""Observability layer conformance (DESIGN.md §16).

Pins the contracts of ``repro.obs``:

* the :class:`~repro.obs.trace.TraceRecorder` span model — round spans with
  byte/sim-second attribution, phase children that partition each round,
  per-agent event spans, serve request lifecycles — and its Chrome-trace
  export, schema-validated exactly as ui.perfetto.dev would parse it;
* telemetry is free when off: a run with a recorder attached produces
  bitwise-identical ``History`` losses to a run without one, and all seven
  protocols × {loop, scan, events} drivers attribute identical bytes and
  simulated seconds to every round span (pisco in the fast lane, the other
  six in the full lane);
* the metrics registry (counters monotone, histograms quantile-correct,
  JSONL sink round-trips) and the ``History`` / ``ServeReport`` exporters;
* the perf-regression gate: tolerance kinds, missing-metric semantics,
  manifest-driven artifact pairing, and the end-to-end CLI — which must
  pass a baseline against itself and fail an injected 2× slowdown.
"""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from conftest import make_logreg_problem
from repro.core import Experiment, ExperimentSpec, registered_algorithms
from repro.core.compression import make_byte_model
from repro.core.trainer import History
from repro.obs import (
    GATES,
    MetricGate,
    MetricsRegistry,
    TraceRecorder,
    bench_key,
    compare_dirs,
    compare_payloads,
    profile_capture,
    read_jsonl,
    to_chrome_trace,
    track_compile_time,
    validate_chrome_trace,
    write_trace,
)
from repro.serve.batcher import Request
from repro.serve.load import ServeReport
from repro.sim.costmodel import make_time_model

N_AGENTS = 5
ROUNDS = 10


def _pieces(n=N_AGENTS, with_eval=False):
    loss_fn, full_grad_sq, sampler_factory, d = make_logreg_problem(n_agents=n)
    out = dict(
        loss_fn=loss_fn,
        params0={"w": jnp.zeros(d)},
        sampler_factory=lambda s: sampler_factory(s.config.t_o),
    )
    if with_eval:
        out["eval_fn"] = lambda p: {"grad_sq": full_grad_sq(p)}
    return out


def _spec(driver, **kw):
    base = dict(
        algo="pisco", n_agents=N_AGENTS, t_o=2, eta_l=0.1, p=0.2, seed=0,
        rounds=ROUNDS, driver=driver, systems="uniform",
    )
    if driver == "events":
        base["async_"] = "constant:buffer=3"
    base.update(kw)
    return ExperimentSpec.create(**base)


@pytest.fixture(scope="module")
def traced_runs():
    """One pisco run per driver with a recorder attached, plus a scan run
    without one (the recording-is-free twin).  Shared across tests — each
    run is seconds of jit; don't re-run per assertion."""
    plain = Experiment(_spec("scan"), **_pieces(with_eval=True)).run()
    hists, recs = {}, {}
    for driver in ("loop", "scan", "events"):
        rec = TraceRecorder(meta={"driver": driver})
        hists[driver] = Experiment(
            _spec(driver), recorder=rec, **_pieces(with_eval=True)
        ).run()
        recs[driver] = rec
    return plain, hists, recs


# ---------------------------------------------------------------------------
# TraceRecorder span model (pure python, no jax)
# ---------------------------------------------------------------------------


def test_recorder_round_spans_advance_the_clock():
    rec = TraceRecorder(meta={"kind": "unit"})
    rec.record_round(0, True, 100, parts={"local_steps": 0.25, "server_sync": 0.75})
    rec.record_round(1, False, 200, seconds=0.5)
    assert rec.clock_s == pytest.approx(1.5)
    table = rec.round_table()
    assert [(r, k, b) for r, k, b, _ in table] == [
        (0, "server_round", 100), (1, "gossip_round", 200)
    ]
    assert table[0][3] == pytest.approx(1.0)  # parts sum = span duration
    # phase children partition the round span, in execution order
    phases = [s for s in rec.spans if s.cat == "phase"]
    assert [p.name for p in phases] == ["local_steps", "server_sync"]
    assert phases[0].t0 == pytest.approx(0.0)
    assert phases[1].t0 == pytest.approx(0.25)


def test_recorder_clamps_negative_durations():
    rec = TraceRecorder()
    rec.add_span("host", "oops", 1.0, -0.5)
    assert rec.spans[-1].dur == 0.0


def test_recorder_host_span_measures_wall_time():
    rec = TraceRecorder()
    with rec.host_span("work", detail=1):
        pass
    (span,) = [s for s in rec.spans if s.cat == "host"]
    assert span.name == "work" and span.dur >= 0.0 and span.args["detail"] == 1


def test_recorder_serve_request_lifecycle():
    req = Request(
        rid=7, agent_id=3, prompt=np.zeros(4, np.int32), max_new_tokens=4,
        arrival_s=1.0, admit_s=1.5, first_token_s=2.0, done_s=3.0,
        prefill_s=0.5, decode_s=1.0, tokens=[1, 2, 3, 4], slot=2,
    )
    rec = TraceRecorder()
    rec.record_request(req)
    spans = [s for s in rec.spans if s.cat == "serve"]
    assert [s.name for s in spans] == ["queue", "prefill", "decode"]
    assert all(s.track == "agent 3" for s in spans)
    assert spans[0].t0 == pytest.approx(1.0)  # queue starts at arrival
    assert spans[0].dur == pytest.approx(0.5)
    assert spans[2].args["tokens"] == 4
    assert all(s.args["slot"] == 2 for s in spans)


# ---------------------------------------------------------------------------
# Chrome-trace export + schema validation
# ---------------------------------------------------------------------------


def test_chrome_export_schema_and_track_order(tmp_path):
    rec = TraceRecorder(meta={"kind": "unit"})
    rec.record_round(0, False, 64, seconds=0.25)
    rec.record_agent_round(0, 1, 0.0, 0.25, False, staleness=0)
    rec.record_agent_round(0, 0, 0.0, 0.25, False, staleness=0)
    rec.add_instant("rounds", "eval", 0.25, grad_sq=0.5)
    with rec.host_span("compile"):
        pass
    obj = write_trace(str(tmp_path / "t.json"), rec)
    validate_chrome_trace(obj)
    reloaded = json.load(open(tmp_path / "t.json"))
    assert reloaded == obj
    assert obj["otherData"]["kind"] == "unit"
    # track metadata orders rounds first, then host, then agents by index
    meta = [e for e in obj["traceEvents"] if e["ph"] == "M"
            and e["name"] == "thread_name"]
    order = [e["args"]["name"] for e in sorted(
        meta, key=lambda e: e["tid"])]
    assert order == ["rounds", "host", "agent 0", "agent 1"]
    # ts/dur are microseconds
    rnd = next(e for e in obj["traceEvents"]
               if e["ph"] == "X" and e["name"] == "gossip_round")
    assert rnd["dur"] == pytest.approx(0.25e6)


def test_validate_rejects_malformed_traces():
    rec = TraceRecorder()
    rec.record_round(0, True, 1)
    good = to_chrome_trace(rec)
    with pytest.raises(AssertionError):
        validate_chrome_trace([])  # array flavour not accepted
    with pytest.raises(AssertionError):
        validate_chrome_trace({"traceEvents": []})  # empty
    bad = json.loads(json.dumps(good))
    for e in bad["traceEvents"]:
        if e["ph"] == "X":
            e["dur"] = -1.0
    with pytest.raises(AssertionError):
        validate_chrome_trace(bad)
    bad2 = json.loads(json.dumps(good))
    bad2["traceEvents"] = [e for e in bad2["traceEvents"] if e["ph"] != "M"]
    with pytest.raises(AssertionError):  # spans on a track with no name
        validate_chrome_trace(bad2)


# ---------------------------------------------------------------------------
# Metrics registry
# ---------------------------------------------------------------------------


def test_metrics_registry_counters_gauges_histograms():
    reg = MetricsRegistry(meta={"kind": "unit"})
    reg.counter("c").inc()
    reg.counter("c").inc(2.5)  # get-or-create returns the same instance
    assert reg.counter("c").value == pytest.approx(3.5)
    with pytest.raises(ValueError):
        reg.counter("c").inc(-1)
    reg.gauge("g").set(1.0)
    reg.gauge("g").set(-2.0)
    assert reg.gauge("g").value == -2.0
    reg.histogram("h").observe_many([3.0, 1.0, 2.0])
    snap = reg.snapshot()
    h = snap["metrics"]["h"]
    assert h["count"] == 3 and h["min"] == 1.0 and h["max"] == 3.0
    assert h["p50"] == pytest.approx(2.0)
    with pytest.raises(TypeError):  # name already bound to another type
        reg.gauge("c")
    assert reg.names() == ["c", "g", "h"]


def test_metrics_jsonl_sink_round_trips(tmp_path):
    path = tmp_path / "m.jsonl"
    for i in range(2):
        reg = MetricsRegistry(meta={"run": i})
        reg.counter("n").inc(i)
        reg.write_jsonl(str(path), extra_field=i * 10)
    lines = read_jsonl(str(path))
    assert len(lines) == 2
    assert lines[1]["meta"]["run"] == 1
    assert lines[1]["metrics"]["n"]["value"] == 1
    assert lines[1]["meta"]["extra_field"] == 10


# ---------------------------------------------------------------------------
# Cost-model phase decomposition
# ---------------------------------------------------------------------------


def test_round_parts_sum_to_round_time_exactly():
    from repro.core import replicate_params

    spec = _spec("scan", network="matching", participation=0.6)
    mixing = spec.make_mixing()
    x0 = replicate_params({"w": jnp.zeros(8)}, spec.config.n_agents)
    bm = make_byte_model(mixing, x0, spec.config.n_agents)
    tm = make_time_model(spec, bm, network=mixing.network)
    for k in range(6):
        for is_global in (False, True):
            parts = tm.round_parts(k, is_global)
            assert set(parts) == (
                {"local_steps", "server_sync"} if is_global
                else {"local_steps", "gossip_mix"}
            )
            # exact: both sides are the same two float adds
            assert sum(parts.values()) == tm.round_time(k, is_global)


# ---------------------------------------------------------------------------
# Recording is free; span attribution is driver-invariant
# ---------------------------------------------------------------------------


def test_recording_off_on_losses_bitwise_identical(traced_runs):
    plain, hists, _ = traced_runs
    np.testing.assert_array_equal(plain.loss, hists["scan"].loss)
    assert plain.is_global == hists["scan"].is_global
    assert plain.to_dict()["sim_time_s"] == hists["scan"].to_dict()["sim_time_s"]


def test_round_span_attribution_matches_across_drivers(traced_runs):
    _, _, recs = traced_runs
    tables = {d: r.round_table() for d, r in recs.items()}
    ref = tables["scan"]
    assert len(ref) == ROUNDS
    for table in tables.values():
        # kind and byte attribution exact; seconds allclose (the events
        # engine derives durations from availability-frontier differences,
        # which carry ~1e-16 float noise)
        assert [(r, k, b) for r, k, b, _ in table] == [
            (r, k, b) for r, k, b, _ in ref
        ]
        np.testing.assert_allclose(
            [t[3] for t in table], [t[3] for t in ref], rtol=1e-9
        )


@pytest.mark.parametrize(
    "algo",
    [
        # pisco gates the fast lane; the other six protocols (~10 s each for
        # the three-driver sweep) run in the full tier1-hypothesis lane
        a if a == "pisco" else pytest.param(a, marks=pytest.mark.slow)
        for a in registered_algorithms()
    ],
)
def test_span_parity_all_protocols(algo):
    rounds, n = 6, 4
    tables = {}
    for driver in ("loop", "scan", "events"):
        rec = TraceRecorder()
        kw = dict(algo=algo, rounds=rounds, n_agents=n)
        if driver == "events":
            kw["async_"] = "constant:buffer=2"
        Experiment(_spec(driver, **kw), recorder=rec, **_pieces(n=n)).run()
        tables[driver] = rec.round_table()
    ref = tables["scan"]
    assert len(ref) == rounds
    for table in tables.values():
        assert [(r, k, b) for r, k, b, _ in table] == [
            (r, k, b) for r, k, b, _ in ref
        ]
        np.testing.assert_allclose(
            [t[3] for t in table], [t[3] for t in ref], rtol=1e-9
        )


def test_scan_trace_has_phase_children_and_eval_instants(traced_runs):
    _, _, recs = traced_runs
    rec = recs["scan"]
    rounds = [s for s in rec.spans if s.cat == "round"]
    phases = [s for s in rec.spans if s.cat == "phase"]
    assert rounds and phases
    for rs in rounds:
        kids = [p for p in phases
                if rs.t0 - 1e-12 <= p.t0
                and p.t0 + p.dur <= rs.t0 + rs.dur + 1e-9]
        assert sum(p.dur for p in kids) == pytest.approx(rs.dur, abs=1e-12)
    evals = [i for i in rec.instants if i.name == "eval"]
    assert evals and all("grad_sq" in i.args for i in evals)


def test_events_trace_has_per_agent_tracks(traced_runs):
    _, _, recs = traced_runs
    rec = recs["events"]
    agent_tracks = [t for t in rec.tracks() if t.startswith("agent ")]
    assert len(agent_tracks) == N_AGENTS
    agent_spans = [s for s in rec.spans if s.cat == "agent"]
    assert len(agent_spans) == ROUNDS * N_AGENTS
    assert all("staleness" in s.args and "participant" in s.args
               for s in agent_spans)


def test_real_run_chrome_traces_validate(traced_runs, tmp_path):
    _, _, recs = traced_runs
    for driver, rec in recs.items():
        obj = write_trace(str(tmp_path / f"{driver}.json"), rec)
        validate_chrome_trace(obj)


# ---------------------------------------------------------------------------
# History export: sim-second split, round trip, telemetry
# ---------------------------------------------------------------------------


def test_history_sim_split_and_round_trip(traced_runs):
    plain, _, _ = traced_runs
    d = plain.to_dict()
    assert len(d["sim_time_a2a_s"]) + len(d["sim_time_a2s_s"]) == ROUNDS
    assert sum(d["sim_time_a2a_s"]) == pytest.approx(d["sim_time_a2a_total_s"])
    assert sum(d["sim_time_a2s_s"]) == pytest.approx(d["sim_time_a2s_total_s"])
    assert d["sim_time_a2a_total_s"] + d["sim_time_a2s_total_s"] == (
        pytest.approx(sum(d["sim_time_s"]))
    )
    # JSON-faithful round trip: rebuild and re-export
    h2 = History.from_dict(json.loads(json.dumps(d)))
    assert h2.to_dict() == d


def test_history_telemetry_registry(traced_runs):
    plain, _, _ = traced_runs
    snap = plain.telemetry(meta={"algo": "pisco"}).snapshot()
    m = snap["metrics"]
    assert m["train.rounds_gossip"]["value"] + m["train.rounds_server"][
        "value"] == ROUNDS
    assert m["train.round_bytes"]["count"] == ROUNDS
    assert m["train.bytes_a2a"]["value"] == plain.accountant.agent_to_agent_bytes
    assert snap["meta"]["algo"] == "pisco"


def test_serve_report_telemetry():
    reqs = [
        Request(rid=i, agent_id=i % 2, prompt=np.zeros(2, np.int32),
                max_new_tokens=2, arrival_s=float(i), admit_s=i + 0.5,
                done_s=i + 1.0, prefill_s=0.2, decode_s=0.3,
                tokens=[1, 2], slot=i % 3)
        for i in range(6)
    ]
    report = ServeReport(requests=reqs, clock_s=7.0)
    snap = report.telemetry(meta={"kind": "serve"}).snapshot()
    m = snap["metrics"]
    assert m["serve.requests"]["value"] == 6
    assert m["serve.tokens"]["value"] == 12
    assert m["serve.queue_wait_s"]["count"] == 6
    assert m["serve.slot.0.requests"]["value"] == 2


# ---------------------------------------------------------------------------
# Profiler hooks
# ---------------------------------------------------------------------------


def test_track_compile_time_sees_a_fresh_jit():
    @jax.jit
    def f(x):
        return x * 2.0 + 1.0

    with track_compile_time() as stats:
        f(jnp.arange(3.0)).block_until_ready()
    if stats.supported:
        assert stats.seconds >= 0.0
        assert any("compile" in k for k in stats.events)


def test_profile_capture_noop_and_real(tmp_path):
    with profile_capture(None):
        pass  # no-op must not touch the filesystem
    out = tmp_path / "prof"
    with profile_capture(str(out)):
        jnp.arange(4.0).sum().block_until_ready()
    # degrades to a warning when the profiler is unavailable; when it works
    # the trace directory exists
    assert not out.exists() or any(out.rglob("*"))


# ---------------------------------------------------------------------------
# Perf-regression gate
# ---------------------------------------------------------------------------


def test_gate_kinds():
    ok = lambda fs: not any(f.failed for f in fs)
    base = {"t": 1.0, "h": 10.0, "m": 5.0, "f": True, "c": 2}
    gates = [
        MetricGate("t", "time", 2.0),
        MetricGate("h", "higher", 2.0),
        MetricGate("m", "match", 0.1),
        MetricGate("f", "flag"),
        MetricGate("c", "count", 1),
    ]
    assert ok(compare_payloads("x", base, dict(base), gates=gates))
    assert ok(compare_payloads(
        "x", base, {"t": 1.9, "h": 5.5, "m": 5.4, "f": True, "c": 3},
        gates=gates))
    for bad in (
        {**base, "t": 2.5}, {**base, "h": 4.0}, {**base, "m": 6.0},
        {**base, "f": False}, {**base, "c": 4},
    ):
        assert not ok(compare_payloads("x", base, bad, gates=gates))


def test_gate_missing_metric_semantics():
    gates = [MetricGate("a.b", "time", 2.0)]
    # absent from both → skipped (schema drift in an old baseline)
    (f,) = compare_payloads("x", {}, {}, gates=gates)
    assert f.status == "skipped" and not f.failed
    # absent only from baseline → skipped (new metric, no reference yet)
    (f,) = compare_payloads("x", {}, {"a": {"b": 1.0}}, gates=gates)
    assert f.status == "skipped"
    # absent only from fresh → failure (a gated metric disappeared)
    (f,) = compare_payloads("x", {"a": {"b": 1.0}}, {}, gates=gates)
    assert f.status == "missing" and f.failed


def test_gate_paths_resolve_in_committed_baselines():
    """Every registered gate path must exist in the committed artifacts —
    a renamed payload key would silently turn a gate into a skip."""
    from repro.obs.regress import load_artifacts, lookup

    art = os.path.join(os.path.dirname(__file__), "..", "artifacts", "bench")
    payloads = load_artifacts(art)
    assert set(GATES) <= set(payloads), "baseline artifact missing"
    for bench, gates in GATES.items():
        for gate in gates:
            found, _ = lookup(payloads[bench], gate.path)
            assert found, f"{bench}: gate path {gate.path} absent from baseline"


def _write_fixture_dirs(tmp_path, slowdown=1.0):
    base = tmp_path / "base"
    fresh = tmp_path / "fresh"
    base.mkdir(exist_ok=True)
    fresh.mkdir(exist_ok=True)
    payload = {
        "profiles": {
            "lognormal-stragglers": {
                "sync": {"total_sim_time_s": 10.0},
                "async": {"total_sim_time_s": 4.0},
            },
            "wan-gossip": {"async": {"total_sim_time_s": 20.0}},
            "free": {"bit_identical_loss": True},
        },
        "reprice": {"self_exact": True},
    }
    (base / "BENCH_async.json").write_text(json.dumps(payload))
    fresh_payload = json.loads(json.dumps(payload))
    for prof in fresh_payload["profiles"].values():
        for mode in ("sync", "async"):
            if mode in prof:
                prof[mode]["total_sim_time_s"] *= slowdown
    (fresh / "BENCH_async.json").write_text(json.dumps(fresh_payload))
    return base, fresh


def test_compare_dirs_passes_identical_and_fails_2x_slowdown(tmp_path):
    base, fresh = _write_fixture_dirs(tmp_path, slowdown=1.0)
    findings = compare_dirs(str(base), str(fresh))
    assert findings and not any(f.failed for f in findings)
    base, fresh = _write_fixture_dirs(tmp_path, slowdown=2.0)
    findings = compare_dirs(str(base), str(fresh))
    regressed = [f for f in findings if f.failed]
    assert len(regressed) == 3  # the three sim-time gates; flags still pass


def test_compare_dirs_follows_manifest_paths(tmp_path):
    base, fresh = _write_fixture_dirs(tmp_path)
    # rename the fresh artifact so only the manifest knows where it lives —
    # the gate must pair via the manifest index, not a filename convention
    (fresh / "BENCH_async.json").rename(fresh / "async.v2.json")
    (fresh / "MANIFEST.json").write_text(json.dumps({
        "schema_version": 1,
        "benches": {"async": {"path": "async.v2.json"}},
    }))
    findings = compare_dirs(str(base), str(fresh))
    assert findings and not any(f.failed for f in findings)


def test_check_regress_cli_exit_codes(tmp_path):
    from benchmarks.check_regress import main as gate_main

    base, fresh = _write_fixture_dirs(tmp_path, slowdown=1.0)
    assert gate_main(["--baseline", str(base), "--fresh", str(fresh)]) == 0
    base, fresh = _write_fixture_dirs(tmp_path, slowdown=2.0)
    assert gate_main(["--baseline", str(base), "--fresh", str(fresh)]) == 1
    # escape hatch: copy fresh over baseline, then the gate passes again
    assert gate_main([
        "--baseline", str(base), "--fresh", str(fresh), "--update-baselines",
    ]) == 0
    assert gate_main(["--baseline", str(base), "--fresh", str(fresh)]) == 0
    # an empty fresh dir is an error, not a silent pass
    empty = tmp_path / "empty"
    empty.mkdir()
    assert gate_main(["--baseline", str(base), "--fresh", str(empty)]) == 1


def test_write_manifest_indexes_bench_artifacts(tmp_path):
    from benchmarks.common import write_manifest

    (tmp_path / "BENCH_driver.json").write_text("{}")
    (tmp_path / "BENCH_async.json").write_text("{}")
    (tmp_path / "notes.json").write_text("{}")  # not a bench artifact
    path = write_manifest(str(tmp_path))
    m = json.load(open(path))
    assert m["schema_version"] == 1
    assert set(m["benches"]) == {"driver", "async"}
    assert m["benches"]["driver"]["path"] == "BENCH_driver.json"
    assert bench_key(m["benches"]["driver"]["path"]) == "driver"
