"""Compressed-gossip subsystem tests.

* Round-trip error bounds per compressor (quantizer scale bound, top-k
  contraction), and unbiasedness of stochastic rounding.
* Error-feedback residual contraction (the δ-property EF convergence needs).
* Mean preservation of compressed gossip — Lemma 1's invariant
  (mean_i y_i == mean_i g_i) must survive compression of Y.
* Pallas kernel vs kernels/ref.py parity on odd / non-multiple-of-128 shapes.
* CommAccountant byte totals vs the closed-form RoundByteModel for Bernoulli
  and periodic schedules.
* End-to-end: compressed PISCO matches the uncompressed final gradient norm
  within 2x rounds at >= 4x fewer gossip bytes.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hyp import given, settings, st
from conftest import make_logreg_problem
from repro.core import (
    CommAccountant,
    PiscoConfig,
    StochasticQuantizer,
    TopKCompressor,
    IdentityCompressor,
    compress_mixing,
    dense_mixing,
    init_state,
    init_compression_state,
    make_byte_model,
    make_compressor,
    make_round_fn,
    make_topology,
    message_bytes,
    replicate_params,
    run_training,
)
from repro.kernels import quantize as Q
from repro.kernels import ref as R


def _tree_mean0(tree):
    return jax.tree.map(lambda v: jnp.mean(v, axis=0), tree)


def _max_abs_diff(a, b):
    return max(
        float(jnp.max(jnp.abs(x - y)))
        for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b))
    )


# ---------------------------------------------------------------------------
# compressor round-trip bounds
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("bits", [4, 8])
def test_quantizer_roundtrip_error_bound(bits):
    """Deterministic rounding: per-element error <= scale/2, rowwise scale."""
    x = jax.random.normal(jax.random.PRNGKey(0), (6, 97), jnp.float32)
    q = StochasticQuantizer(bits=bits, stochastic=False).compress(x)
    qmax = 2 ** (bits - 1) - 1
    scale = jnp.max(jnp.abs(x), axis=1, keepdims=True) / qmax
    assert float(jnp.max(jnp.abs(q - x) - 0.5 * scale)) <= 1e-6
    assert q.dtype == x.dtype and q.shape == x.shape


def test_stochastic_quantizer_is_unbiased():
    """E[q(x)] == x over keys (floor + uniform carry rounds unbiasedly)."""
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 64), jnp.float32)
    comp = StochasticQuantizer(bits=4, stochastic=True)
    reps = jnp.stack(
        [comp.compress(x, jax.random.PRNGKey(k)) for k in range(400)]
    )
    bias = float(jnp.max(jnp.abs(jnp.mean(reps, 0) - x)))
    scale = float(jnp.max(jnp.abs(x))) / 7.0
    # CLT: bias ~ scale / sqrt(400) ~ 0.05 * scale; allow 4 sigma
    assert bias < 0.2 * scale


@given(frac=st.floats(0.05, 0.9), seed=st.integers(0, 30))
@settings(max_examples=10, deadline=None)
def test_topk_contraction_property(frac, seed):
    """||x - topk(x)||^2 <= (1 - k/d) ||x||^2 per agent row."""
    x = jax.random.normal(jax.random.PRNGKey(seed), (5, 40), jnp.float32)
    comp = TopKCompressor(fraction=frac)
    q = comp.compress(x)
    k = comp.k_for(40)
    err = jnp.sum((x - q) ** 2, axis=1)
    full = jnp.sum(x**2, axis=1)
    assert float(jnp.max(err - (1.0 - k / 40.0) * full)) <= 1e-5
    # exactly k survivors per row
    assert int(jnp.max(jnp.sum(q != 0, axis=1))) <= k


def test_error_feedback_residual_contracts():
    """The EF residual stays bounded (contraction): after many compressed
    gossip steps, ||residual|| never blows past the offered signal."""
    n, d = 8, 32
    base = dense_mixing(make_topology("ring", n))
    mix = compress_mixing(base, TopKCompressor(0.25), error_feedback=True)
    cg = mix.compression
    tree = {"w": jax.random.normal(jax.random.PRNGKey(2), (n, d), jnp.float32)}
    res = jax.tree.map(jnp.zeros_like, tree)
    key = jax.random.PRNGKey(0)
    for i in range(30):
        key, k = jax.random.split(key)
        out, res = cg(tree, res, k)
        m_norm = float(jnp.sqrt(jnp.sum((tree["w"] + 0) ** 2)))
        r_norm = float(jnp.sqrt(jnp.sum(res["w"] ** 2)))
        # delta-contraction: residual < (1-k/d)^(1/2) * ||message|| and the
        # geometric series it induces stays below ~ (1/delta) * signal
        assert r_norm <= 4.0 * m_norm
        tree = out
    assert np.isfinite(r_norm)


# ---------------------------------------------------------------------------
# mean preservation (Lemma 1 under compression)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("spec", ["q8", "q4", "top0.2"])
@pytest.mark.parametrize("ef", [True, False])
def test_compressed_gossip_preserves_agent_mean(spec, ef):
    n, d = 8, 33
    base = dense_mixing(make_topology("ring", n))
    mix = compress_mixing(base, make_compressor(spec), error_feedback=ef)
    tree = {"w": jax.random.normal(jax.random.PRNGKey(3), (n, d), jnp.float32)}
    out = mix.gossip(tree)  # stateless path
    assert _max_abs_diff(_tree_mean0(out), _tree_mean0(tree)) < 1e-6
    if ef:
        cg = mix.compression
        res = jax.tree.map(jnp.zeros_like, tree)
        out2, _ = cg(tree, res, jax.random.PRNGKey(0))
        assert _max_abs_diff(_tree_mean0(out2), _tree_mean0(tree)) < 1e-6


@given(
    spec=st.sampled_from(["q8", "q4", "top0.25"]),
    t_o=st.integers(1, 3),
    seed=st.integers(0, 10),
)
@settings(max_examples=6, deadline=None)
def test_lemma1_survives_compression(spec, t_o, seed):
    """mean(Y) == mean(G) after compressed gossip rounds (EF path)."""
    n = 8
    loss_fn, _, sampler_factory, d = make_logreg_problem(n_agents=n, seed=seed)
    cfg = PiscoConfig(n_agents=n, t_o=t_o, eta_l=0.1, eta_c=0.9, p=0.5)
    base = dense_mixing(make_topology("ring", n))
    mix = compress_mixing(base, make_compressor(spec), error_feedback=True)
    sampler = sampler_factory(t_o, seed=seed)
    x0 = replicate_params({"w": jnp.zeros(d)}, n)
    state = init_compression_state(
        init_state(loss_fn, x0, sampler(-1)[1]), mix
    )
    fn = jax.jit(make_round_fn(loss_fn, cfg, mix, global_round=False))
    for k in range(3):
        state, _ = fn(state, *sampler(k))
    assert _max_abs_diff(_tree_mean0(state.y), _tree_mean0(state.g)) < 1e-5


# ---------------------------------------------------------------------------
# Pallas kernels vs references (odd / tail shapes)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("shape", [(5, 37), (8, 200), (3, 130), (7, 1000), (1, 1)])
@pytest.mark.parametrize("bits", [4, 8])
def test_quant_dequant_kernel_matches_ref(shape, bits):
    x = jax.random.normal(jax.random.PRNGKey(sum(shape)), shape, jnp.float32)
    out = Q.rowwise_quant_dequant(x, bits=bits, interpret=True)
    ref = R.rowwise_quant_dequant_ref(x, bits)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-6)
    # and the kernel agrees with the jnp compressor's deterministic path
    comp = StochasticQuantizer(bits=bits, stochastic=False).compress(x)
    np.testing.assert_allclose(np.asarray(out), np.asarray(comp), atol=1e-6)


@pytest.mark.parametrize("shape", [(5, 37), (8, 200), (6, 643)])
@pytest.mark.parametrize("bits", [4, 8])
def test_fused_compressed_mix_kernel_matches_ref(shape, bits):
    n, d = shape
    x = jax.random.normal(jax.random.PRNGKey(d), shape, jnp.float32)
    w = jnp.asarray(make_topology("ring", n).w, jnp.float32)
    out = Q.fused_compressed_mix(x, w, bits=bits, interpret=True)
    ref = R.compressed_mix_ref(x, w, bits)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-6)
    # the fused form is mean-preserving too
    np.testing.assert_allclose(
        np.asarray(jnp.mean(out, 0)), np.asarray(jnp.mean(x, 0)), atol=1e-6
    )


# ---------------------------------------------------------------------------
# byte accounting
# ---------------------------------------------------------------------------


def test_message_bytes_closed_form():
    n, d = 8, 100
    template = {"w": jnp.zeros((n, d)), "b": jnp.zeros((n,))}
    # fp32: (100 + 1) * 4 bytes
    assert message_bytes(None, template, n) == 101 * 4
    # int8: ceil((100*8 + 32 + 1*8 + 32) / 8)
    assert message_bytes(StochasticQuantizer(bits=8), template, n) == -(
        -(100 * 8 + 32 + 8 + 32) // 8
    )
    # top-k keeps ceil(0.1*100)=10 and ceil(0.1*1)=1 pairs of (fp32, int32)
    assert message_bytes(TopKCompressor(0.1), template, n) == 11 * 8


@pytest.mark.parametrize("p", [0.0, 0.35, 1.0])
def test_accountant_bytes_match_model_bernoulli(p):
    """Realized byte totals == closed form from the realized round counts."""
    n = 8
    loss_fn, _, sampler_factory, d = make_logreg_problem(n_agents=n)
    cfg = PiscoConfig(n_agents=n, t_o=1, eta_l=0.1, eta_c=1.0, p=p, seed=4)
    base = dense_mixing(make_topology("ring", n))
    mix = compress_mixing(base, StochasticQuantizer(bits=8))
    hist = run_training(
        "pisco", loss_fn, replicate_params({"w": jnp.zeros(d)}, n), cfg, mix,
        sampler_factory(1), rounds=20,
    )
    acct = hist.accountant
    bm = hist.byte_model
    assert acct.total == 20
    assert acct.agent_to_agent_bytes == acct.agent_to_agent * bm.gossip_round_bytes
    assert acct.agent_to_server_bytes == acct.agent_to_server * bm.server_round_bytes
    assert acct.total_bytes == bm.total_bytes(acct.agent_to_agent, acct.agent_to_server)
    # closed-form sizing: ring of 8 has 8 undirected edges => 16 directed
    # messages per mix, 2 mixes/round (X and Y); server = 2 dirs * 8 agents
    gossip_msg = -(-(d * 8 + 32) // 8)  # int8 payload + fp32 scale
    server_msg = d * 4
    assert bm.gossip_round_bytes == 2 * 16 * gossip_msg
    assert bm.server_round_bytes == 2 * 2 * n * server_msg
    if p == 0.0:
        assert acct.agent_to_server == 0
        assert acct.total_bytes == bm.expected_bytes(20, 0.0)
    if p == 1.0:
        assert acct.agent_to_agent == 0
        assert acct.total_bytes == bm.expected_bytes(20, 1.0)


def test_accountant_bytes_match_model_periodic():
    """gossip_pga uses the every-H schedule: exact closed form in rounds."""
    n = 6
    loss_fn, _, sampler_factory, d = make_logreg_problem(n_agents=n)
    # gossip_pga derives H = round(1/p); p=0.25 -> server every 4th round
    cfg = PiscoConfig(n_agents=n, t_o=1, eta_l=0.1, eta_c=1.0, p=0.25, seed=0)
    base = dense_mixing(make_topology("ring", n))
    mix = compress_mixing(base, StochasticQuantizer(bits=8))
    rounds = 21
    hist = run_training(
        "gossip_pga", loss_fn, replicate_params({"w": jnp.zeros(d)}, n), cfg,
        mix, sampler_factory(1), rounds=rounds,
    )
    acct = hist.accountant
    bm = hist.byte_model
    assert acct.agent_to_server == rounds // 4
    assert acct.total_bytes == bm.periodic_bytes(rounds, 4)
    assert acct.total_bytes == bm.total_bytes(acct.agent_to_agent, acct.agent_to_server)


def test_record_backward_compatible():
    acct = CommAccountant()
    acct.record(False)  # no byte argument — pre-compression call sites
    acct.record(True, 100)
    assert acct.total == 2 and acct.total_bytes == 100


# ---------------------------------------------------------------------------
# end-to-end acceptance: same accuracy, >= 4x fewer gossip bytes
# ---------------------------------------------------------------------------


def test_compressed_pisco_matches_uncompressed_at_4x_fewer_bytes():
    n = 8
    loss_fn, full_grad_sq, sampler_factory, d = make_logreg_problem(n_agents=n)
    base = dense_mixing(make_topology("ring", n))
    x0 = replicate_params({"w": jnp.zeros(d)}, n)
    cfg = PiscoConfig(n_agents=n, t_o=2, eta_l=0.15, eta_c=1.0, p=0.1, seed=0)

    def drive(mix, rounds):
        return run_training(
            "pisco", loss_fn, x0, cfg, mix, sampler_factory(2), rounds=rounds,
            eval_fn=lambda xb: {"grad_sq": full_grad_sq(xb)}, eval_every=1,
        )

    rounds = 60
    hist_fp = drive(base, rounds)
    target = hist_fp.eval_metrics[-1]["grad_sq"]

    mix_c = compress_mixing(base, StochasticQuantizer(bits=4), error_feedback=True)
    hist_c = drive(mix_c, 2 * rounds)
    # first instantaneous crossing of the fp32 run's final quality
    vals_c = np.array([m["grad_sq"] for m in hist_c.eval_metrics])
    hits = np.nonzero(vals_c <= target)[0]
    assert hits.size, "compressed run never matched uncompressed quality"
    assert hits[0] + 1 <= 2 * rounds  # within 2x the uncompressed budget
    # >= 4x fewer bytes per gossip round (int4 + rowwise scale overhead)
    assert hist_fp.byte_model.gossip_round_bytes >= 4 * hist_c.byte_model.gossip_round_bytes
    # identical server pricing (full precision both)
    assert hist_fp.byte_model.server_round_bytes == hist_c.byte_model.server_round_bytes


def test_gamma_auto_selection():
    """Contractive top-k gets the damped CHOCO step; quantizers run
    undamped; explicit gamma wins.  (Undamped top-k diverges under large
    local steps — see DESIGN.md §7.)"""
    base = dense_mixing(make_topology("ring", 6))
    assert compress_mixing(base, TopKCompressor(0.1)).compression.gamma == 0.5
    assert compress_mixing(base, StochasticQuantizer(8)).compression.gamma == 1.0
    mix = compress_mixing(base, TopKCompressor(0.1), gamma=0.3)
    assert mix.compression.gamma == 0.3
    # the damped form still preserves the agent mean exactly
    tree = {"w": jax.random.normal(jax.random.PRNGKey(5), (6, 21), jnp.float32)}
    out = mix.gossip(tree)
    assert _max_abs_diff(_tree_mean0(out), _tree_mean0(tree)) < 1e-6


def test_disabled_compression_is_plain_mixing():
    """compress_mixing(identity) must return the base ops untouched, so the
    uncompressed path is bit-identical to the pre-compression code."""
    base = dense_mixing(make_topology("ring", 4))
    assert compress_mixing(base, IdentityCompressor()) is base
    assert base.compression is None
