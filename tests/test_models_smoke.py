"""Per-architecture smoke tests (assignment requirement): reduced variant of
each family runs one forward/train step on CPU with finite outputs and the
right shapes, plus decode-vs-full-forward consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, SHAPES, get_config, get_reduced
from repro.models import get_bundle
from repro.models.rope import mrope_text_positions

B, S = 2, 32

# Heavyweight reduced configs (profiled at 9-18 s per train/decode case on
# the CI container): slow-marked so the fast tier-1 lane stays under its
# 5-minute budget.  qwen3-8b (GQA attention) and mamba2-370m (SSM) remain in
# the fast lane as the per-family smoke representatives; the full
# tier1-hypothesis lane still runs every architecture.
HEAVY_ARCHS = {
    "seamless-m4t-medium", "deepseek-v2-lite-16b", "nemotron-4-340b",
    "jamba-v0.1-52b", "qwen2-vl-2b", "qwen2.5-14b", "mixtral-8x7b",
    "granite-20b",
}


def _arch_params(archs):
    return [
        pytest.param(a, marks=pytest.mark.slow) if a in HEAVY_ARCHS
        else pytest.param(a)
        for a in archs
    ]


def _batch_for(cfg, key, b=B, s=S):
    tokens = jax.random.randint(key, (b, s), 0, cfg.vocab_size)
    batch = {"tokens": tokens}
    if cfg.is_enc_dec:
        batch["frames"] = jax.random.normal(key, (b, s // 4, cfg.d_model))
    if cfg.modality == "vlm":
        n_patch = 8
        batch["prefix_embeds"] = jax.random.normal(key, (b, n_patch, cfg.d_model))
        batch["positions"] = mrope_text_positions(b, s + n_patch)
    return batch


@pytest.mark.parametrize(
    "arch",
    [
        # mamba2's train step is the one non-heavy case that still costs
        # ~20 s (SSD scan compile); its decode/forward cases stay fast-lane
        pytest.param(a, marks=pytest.mark.slow)
        if (a in HEAVY_ARCHS or a == "mamba2-370m") else pytest.param(a)
        for a in ARCH_IDS
    ],
)
def test_train_step_smoke(arch, key):
    cfg = get_reduced(arch)
    assert cfg.n_layers <= 2 and cfg.d_model <= 512
    if cfg.moe is not None:
        assert cfg.moe.n_experts <= 4
    bundle = get_bundle(cfg)
    params = bundle.init(key)
    batch = _batch_for(cfg, key)
    loss, grads = jax.value_and_grad(bundle.loss)(params, batch)
    assert np.isfinite(float(loss))
    gn = sum(float(jnp.sum(g.astype(jnp.float32) ** 2)) for g in jax.tree.leaves(grads))
    assert np.isfinite(gn) and gn > 0
    # one SGD step changes the loss
    params2 = jax.tree.map(lambda p, g: p - 0.1 * g, params, grads)
    loss2 = bundle.loss(params2, batch)
    assert np.isfinite(float(loss2))


@pytest.mark.parametrize("arch", _arch_params(ARCH_IDS))
def test_forward_shapes(arch, key):
    cfg = get_reduced(arch)
    bundle = get_bundle(cfg)
    params = bundle.init(key)
    batch = _batch_for(cfg, key)
    if cfg.is_enc_dec:
        from repro.models.encdec import decode_train, encode

        memory = encode(params, cfg, batch["frames"])
        assert memory.shape == (B, S // 4, cfg.d_model)
        logits = decode_train(params, cfg, batch["tokens"], memory)
        assert logits.shape == (B, S, cfg.vocab_size)
    else:
        from repro.models.transformer import lm_forward

        logits, aux = lm_forward(
            params, cfg, batch["tokens"],
            prefix_embeds=batch.get("prefix_embeds"),
            positions=batch.get("positions"),
        )
        s_total = S + (8 if cfg.modality == "vlm" else 0)
        assert logits.shape == (B, s_total, cfg.vocab_size)
        assert np.isfinite(np.asarray(logits)).all()


@pytest.mark.parametrize(
    "arch",
    _arch_params([
        "qwen3-8b", "qwen2.5-14b", "granite-20b", "nemotron-4-340b",
        "mixtral-8x7b", "deepseek-v2-lite-16b", "mamba2-370m", "jamba-v0.1-52b",
    ]),
)
def test_decode_matches_full_forward(arch, key):
    from repro.models.transformer import lm_forward

    cfg = get_reduced(arch)
    bundle = get_bundle(cfg)
    params = bundle.init(key)
    s = 24
    tokens = jax.random.randint(key, (B, s), 0, cfg.vocab_size)
    full_logits, _ = lm_forward(params, cfg, tokens)
    cache = bundle.init_cache(B, s)
    pre = s - 4
    logits_p, cache = bundle.prefill(params, {"tokens": tokens[:, :pre]}, cache)
    np.testing.assert_allclose(
        np.asarray(logits_p), np.asarray(full_logits[:, :pre]), rtol=2e-4, atol=2e-4
    )
    for t in range(pre, s):
        lg, cache = bundle.decode(params, tokens[:, t : t + 1], cache)
        np.testing.assert_allclose(
            np.asarray(lg[:, 0]), np.asarray(full_logits[:, t]), rtol=2e-4, atol=2e-4
        )


@pytest.mark.slow
def test_encdec_decode_consistency(key):
    cfg = get_reduced("seamless-m4t-medium")
    bundle = get_bundle(cfg)
    params = bundle.init(key)
    s = 16
    batch = _batch_for(cfg, key, s=s)
    from repro.models.encdec import decode_train, encode

    memory = encode(params, cfg, batch["frames"])
    full_logits = decode_train(params, cfg, batch["tokens"], memory)
    cache = bundle.init_cache(B, s, mem_len=s // 4)
    logits, cache = bundle.prefill(params, batch, cache)
    np.testing.assert_allclose(
        np.asarray(logits[:, 0]), np.asarray(full_logits[:, 0]), rtol=2e-4, atol=2e-4
    )
    for t in range(1, s):
        lg, cache = bundle.decode(params, batch["tokens"][:, t : t + 1], cache)
        np.testing.assert_allclose(
            np.asarray(lg[:, 0]), np.asarray(full_logits[:, t]), rtol=2e-4, atol=2e-4
        )


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_exact_assignment_dims(arch):
    """The full configs carry the exact assigned hyper-parameters."""
    cfg = get_config(arch)
    expected = {
        "nemotron-4-340b": (96, 18432, 96, 8, 73728, 256000),
        "seamless-m4t-medium": (12, 1024, 16, 16, 4096, 256206),
        "qwen2-vl-2b": (28, 1536, 12, 2, 8960, 151936),
        "jamba-v0.1-52b": (32, 4096, 32, 8, 14336, 65536),
        "deepseek-v2-lite-16b": (27, 2048, 16, 16, None, 102400),
        "mamba2-370m": (48, 1024, None, None, 0, 50280),
        "qwen3-8b": (36, 4096, 32, 8, 12288, 151936),
        "qwen2.5-14b": (48, 5120, 40, 8, 13824, 152064),
        "mixtral-8x7b": (32, 4096, 32, 8, 14336, 32000),
        "granite-20b": (52, 6144, 48, 1, 24576, 49152),
    }[arch]
    layers, d, h, kv, ff, vocab = expected
    assert cfg.n_layers == layers
    assert cfg.d_model == d
    assert cfg.vocab_size == vocab
    if h is not None:
        assert cfg.n_heads == h
    if kv is not None:
        assert cfg.n_kv_heads == kv
    if ff is not None:
        if cfg.moe is not None and cfg.moe.layer_mode == "all":
            assert cfg.moe.d_expert == ff
        else:
            assert cfg.d_ff == ff
    # MoE extras
    if arch == "jamba-v0.1-52b":
        assert cfg.moe.n_experts == 16 and cfg.moe.top_k == 2
        assert cfg.hybrid_period.count("attn") == 1 and len(cfg.hybrid_period) == 8
    if arch == "deepseek-v2-lite-16b":
        assert cfg.moe.n_experts == 64 and cfg.moe.top_k == 6 and cfg.moe.n_shared == 2
        assert cfg.mla.kv_lora_rank == 512
    if arch == "mixtral-8x7b":
        assert cfg.moe.n_experts == 8 and cfg.moe.top_k == 2
        assert cfg.sliding_window == 4096
    if arch == "mamba2-370m":
        assert cfg.ssm.d_state == 128


def test_long_decode_applicability():
    longs = {a: get_config(a).supports_long_decode() for a in ARCH_IDS}
    assert longs["mamba2-370m"] and longs["jamba-v0.1-52b"] and longs["mixtral-8x7b"]
    assert not longs["qwen3-8b"] and not longs["nemotron-4-340b"]
    # beyond-paper SWA variant unlocks it
    from repro.configs import get_config as gc

    assert gc("qwen3-8b-swa").supports_long_decode()


def test_param_count_sanity():
    # full-size analytic counts land in the right ballpark
    assert 300e9 < get_config("nemotron-4-340b").param_count() < 400e9
    assert 0.3e9 < get_config("mamba2-370m").param_count() < 0.5e9
    mix = get_config("mixtral-8x7b")
    assert 40e9 < mix.param_count() < 55e9
    assert 10e9 < mix.active_param_count() < 16e9
