"""Shared fixtures. NOTE: no XLA_FLAGS here — unit/smoke tests must see the
single real CPU device (the 512-device override belongs to dryrun.py only)."""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture(scope="session")
def key():
    return jax.random.PRNGKey(0)


def make_logreg_problem(n_agents=8, d=16, m=64, seed=0, heterogeneous=True):
    """Tiny logistic-regression federated problem used across tests."""
    import jax.numpy as jnp

    rng = np.random.default_rng(seed)
    w_true = rng.normal(size=d)
    x = rng.normal(size=(n_agents * m, d))
    logits = x @ w_true
    y = np.where(logits + 0.2 * rng.normal(size=len(x)) > 0, 1.0, -1.0)
    if heterogeneous:
        order = np.argsort(y, kind="stable")
        x, y = x[order], y[order]
    x = x.reshape(n_agents, m, d)
    y = y.reshape(n_agents, m)

    xd, yd = jnp.asarray(x), jnp.asarray(y)

    def loss_fn(params, batch):
        a, lab = batch
        lg = a @ params["w"]
        return jnp.mean(jnp.log1p(jnp.exp(-lab * lg)))

    def full_grad_sq(params):
        def floss(p):
            lg = jnp.einsum("amd,d->am", xd, p["w"])
            return jnp.mean(jnp.log1p(jnp.exp(-yd * lg)))

        g = jax.grad(floss)(params)
        return float(sum(jnp.sum(v**2) for v in jax.tree.leaves(g)))

    def sampler_factory(t_o, b=16, seed=1):
        srng = np.random.default_rng(seed)

        def sampler(k):
            idx = srng.integers(0, m, size=(t_o + 1, n_agents, b))
            xb = jnp.asarray(
                np.take_along_axis(x[None], idx[..., None], axis=2)
            )
            yb = jnp.asarray(np.take_along_axis(y[None], idx, axis=2))
            return (xb[:t_o], yb[:t_o]), (xb[-1], yb[-1])

        return sampler

    return loss_fn, full_grad_sq, sampler_factory, d
