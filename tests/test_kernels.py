"""Per-kernel allclose sweeps vs the pure-jnp oracles (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops
from repro.kernels.flash_attention import flash_attention
from repro.kernels.ssd_scan import ssd_scan_kernel
from repro.kernels import ref as R


@pytest.mark.parametrize(
    "b,hq,hkv,sq,sk,d,causal,window",
    [
        (2, 4, 2, 128, 128, 64, True, None),
        (1, 8, 1, 128, 128, 32, True, None),  # MQA
        (2, 4, 4, 256, 256, 64, True, 64),  # sliding window
        (1, 2, 2, 128, 256, 64, False, None),  # cross/bidirectional
        (1, 6, 2, 192, 192, 64, True, None),  # GQA group 3
    ],
)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_matches_oracle(b, hq, hkv, sq, sk, d, causal, window, dtype):
    key = jax.random.PRNGKey(42)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (b, hq, sq, d), dtype)
    k = jax.random.normal(ks[1], (b, hkv, sk, d), dtype)
    v = jax.random.normal(ks[2], (b, hkv, sk, d), dtype)
    out = flash_attention(
        q, k, v, causal=causal, window=window, block_q=64, block_k=64, interpret=True
    )
    ref = R.flash_attention_ref(q, k, v, causal=causal, window=window)
    tol = 2e-6 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), atol=tol, rtol=tol
    )


@pytest.mark.parametrize(
    "b,l,h,p,g,n,chunk",
    [
        (2, 128, 4, 32, 1, 16, 32),
        (1, 256, 8, 64, 2, 32, 64),
        (2, 64, 2, 16, 1, 8, 64),
        (1, 128, 4, 64, 4, 16, 128),  # chunk == l (single chunk)
    ],
)
def test_ssd_scan_matches_recurrence(b, l, h, p, g, n, chunk):
    key = jax.random.PRNGKey(7)
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (b, l, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, l, h)))
    a = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.5)
    bm = jax.random.normal(ks[3], (b, l, g, n))
    cm = jax.random.normal(ks[4], (b, l, g, n))
    y_k, h_k = ssd_scan_kernel(x, dt, a, bm, cm, chunk=chunk, interpret=True)
    y_r, h_r = R.ssd_scan_ref(x, dt, a, bm, cm)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_r), atol=5e-4, rtol=5e-4)
    np.testing.assert_allclose(np.asarray(h_k), np.asarray(h_r), atol=5e-4, rtol=5e-4)


def test_ssd_kernel_matches_model_reference():
    """Kernel vs the chunked jnp implementation used by the model."""
    from repro.models.mamba2 import ssd_reference

    key = jax.random.PRNGKey(3)
    ks = jax.random.split(key, 5)
    b, l, h, p, g, n = 2, 128, 4, 32, 1, 16
    x = jax.random.normal(ks[0], (b, l, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, l, h)))
    a = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.5)
    bm = jax.random.normal(ks[3], (b, l, g, n))
    cm = jax.random.normal(ks[4], (b, l, g, n))
    y_k, h_k = ssd_scan_kernel(x, dt, a, bm, cm, chunk=32, interpret=True)
    y_m, h_m = ssd_reference(x, dt, a, bm, cm, chunk=32)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_m), atol=5e-4, rtol=5e-4)
    np.testing.assert_allclose(np.asarray(h_k), np.asarray(h_m), atol=5e-4, rtol=5e-4)


@pytest.mark.parametrize("shape", [(37,), (128, 64), (3, 5, 7), (1000,)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fused_local_step(shape, dtype):
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 4)
    x, y, gn, go = (jax.random.normal(k_, shape, dtype) for k_ in ks)
    xo, yo = ops.fused_local_step(x, y, gn, go, eta_l=0.1, interpret=True)
    xr, yr = R.fused_local_step_ref(x, y, gn, go, 0.1)
    tol = 1e-6 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(
        np.asarray(xo, np.float32), np.asarray(xr, np.float32), atol=tol, rtol=tol
    )
    np.testing.assert_allclose(
        np.asarray(yo, np.float32), np.asarray(yr, np.float32), atol=tol, rtol=tol
    )
    assert xo.dtype == dtype and yo.dtype == dtype


@pytest.mark.parametrize("shape", [(63,), (256, 33)])
def test_fused_mix_combine(shape):
    key = jax.random.PRNGKey(1)
    ks = jax.random.split(key, 5)
    xk, xto, yto, left, right = (jax.random.normal(k_, shape) for k_ in ks)
    out = ops.fused_mix_combine(
        xk, xto, yto, left, right,
        eta_c=0.8, eta_l=0.05, w_self=0.5, w_left=0.3, w_right=0.2, interpret=True,
    )
    cand = R.mix_combine_ref(xk, xto, yto, 0.8, 0.05)
    ref = R.neighbor_combine_ref(cand, left, right, 0.5, 0.3, 0.2)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)


def test_flash_attention_used_as_attention_core_equivalent():
    """The kernel agrees with the model's chunked attention_core path."""
    from repro.models.attention import attention_core

    key = jax.random.PRNGKey(9)
    ks = jax.random.split(key, 3)
    b, s, h, hkv, d = 2, 256, 4, 2, 64
    q = jax.random.normal(ks[0], (b, s, h, d))
    k = jax.random.normal(ks[1], (b, s, hkv, d))
    v = jax.random.normal(ks[2], (b, s, hkv, d))
    core = attention_core(q, k, v, causal=True, chunk=64)
    out = flash_attention(
        jnp.moveaxis(q, 2, 1), jnp.moveaxis(k, 2, 1), jnp.moveaxis(v, 2, 1),
        causal=True, block_q=64, block_k=64, interpret=True,
    )
    np.testing.assert_allclose(
        np.asarray(jnp.moveaxis(out, 1, 2)), np.asarray(core), atol=2e-5, rtol=2e-5
    )
