"""Optional-hypothesis shim.

The property tests degrade gracefully when `hypothesis` is not installed:
``given`` becomes a fixed-example driver that runs the test body over a small
deterministic grid drawn from each strategy's endpoints (min / midpoint / max,
or every element of a ``sampled_from``), and ``settings`` becomes a no-op.
With hypothesis installed, the real library is re-exported unchanged, so the
full randomized property tests still run.

Usage in test modules:  ``from _hyp import given, settings, st``
"""
from __future__ import annotations

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # degrade to fixed-example tests
    import functools
    import inspect

    HAVE_HYPOTHESIS = False

    class _Strategy:
        def __init__(self, examples):
            self.examples = list(examples)

    class st:  # noqa: N801 - mimics `hypothesis.strategies` module surface
        @staticmethod
        def integers(min_value=0, max_value=100):
            mid = (min_value + max_value) // 2
            return _Strategy(dict.fromkeys([min_value, mid, max_value]))

        @staticmethod
        def floats(min_value=0.0, max_value=1.0):
            mid = 0.5 * (min_value + max_value)
            return _Strategy(dict.fromkeys([min_value, mid, max_value]))

        @staticmethod
        def booleans():
            return _Strategy([False, True])

        @staticmethod
        def sampled_from(elements):
            return _Strategy(elements)

    def given(**strats):
        for name, s in strats.items():
            assert isinstance(s, _Strategy), f"unsupported strategy for {name!r}"

        def deco(fn):
            n_examples = max(len(s.examples) for s in strats.values())
            sig = inspect.signature(fn)
            remaining = [
                p for pname, p in sig.parameters.items() if pname not in strats
            ]

            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                for i in range(n_examples):
                    drawn = {
                        k: s.examples[i % len(s.examples)]
                        for k, s in strats.items()
                    }
                    fn(*args, **drawn, **kwargs)

            # pytest must only see the non-strategy params (fixtures)
            wrapper.__signature__ = sig.replace(parameters=remaining)
            return wrapper

        return deco

    def settings(*args, **kwargs):
        def deco(fn):
            return fn

        return deco
