"""Sim subsystem conformance: profiles, cost model, History wiring, tuner.

Pins the DESIGN.md §11 contracts: profile realizations are pure in
``(profile, n_agents, seed)``; round times come from hand-computable
arithmetic; under the free-network profile simulated time reduces *exactly*
to compute-only time; the simulated-seconds series is identical across the
loop driver, the scan driver, and post-hoc repricing; and the p/τ tuner's
ranking collapses to the rounds ranking when the network is free but flips
toward higher ``p`` when gossip links cross the WAN.
"""
import json
import pickle

import jax.numpy as jnp
import numpy as np
import pytest

from _hyp import given, settings, st
from conftest import make_logreg_problem
from repro.core import Experiment, ExperimentSpec
from repro.sim import (
    FREE_NETWORK,
    PROFILE_NAMES,
    Profile,
    SystemsModel,
    SystemsParams,
    make_profile,
    parse_systems_spec,
    price_history,
    retime,
    tune,
)

N_AGENTS = 5
COMPUTE = 0.01  # the profiles' base seconds-per-local-step


def _pieces(n=N_AGENTS):
    loss_fn, _, sampler_factory, d = make_logreg_problem(n_agents=n)
    return dict(
        loss_fn=loss_fn,
        params0={"w": jnp.zeros(d)},
        sampler_factory=lambda s: sampler_factory(s.config.t_o),
    )


def _experiment(spec, n=N_AGENTS):
    return Experiment(spec, **_pieces(n))


# ---------------------------------------------------------------------------
# Profiles: grammar, serialization, seed-deterministic realizations
# ---------------------------------------------------------------------------


def test_profile_spec_and_json_round_trips():
    for name in PROFILE_NAMES:
        p = make_profile(name)
        assert make_profile(p.spec()) == p
        assert Profile.from_json(p.to_json()) == p
    p = make_profile("wan-gossip:latency=0.2,bw=1e6")
    assert dict(p.overrides) == {"latency": 0.2, "bw": 1e6}
    assert make_profile(p.spec()) == p
    assert Profile.from_dict(p.to_dict()) == p


def test_bad_profile_specs_fail_fast():
    with pytest.raises(ValueError, match="unknown systems profile"):
        parse_systems_spec("wan-gosip")
    with pytest.raises(ValueError, match="bad systems override"):
        parse_systems_spec("uniform:latency")
    with pytest.raises(ValueError, match="bad systems override"):
        parse_systems_spec("uniform:warp=9")
    # value validation: garbage numbers would silently corrupt the ledger
    with pytest.raises(ValueError, match="bandwidths must be positive"):
        parse_systems_spec("uniform:bw=0")
    with pytest.raises(ValueError, match="bandwidths must be positive"):
        parse_systems_spec("uniform:up_bw=-1")
    with pytest.raises(ValueError, match="finite and >= 0"):
        parse_systems_spec("uniform:latency=-0.1")
    with pytest.raises(ValueError, match="finite and >= 0"):
        parse_systems_spec("uniform:compute=inf")


def test_free_network_profile_is_actually_free():
    params = make_profile(FREE_NETWORK).realize(4, seed=0)
    assert np.all(params.link_latency_s == 0.0)
    assert np.all(np.isinf(params.link_bw_Bps))
    assert np.all(np.isinf(params.up_bw_Bps))
    assert np.all(np.isinf(params.down_bw_Bps))
    assert params.server_rtt_s == 0.0


@settings(max_examples=12, deadline=None)
@given(seed=st.integers(min_value=0, max_value=10_000))
def test_profile_draws_are_pure_in_seed(seed):
    """Same seed => bit-identical straggler/latency realizations; the
    contract that makes loop/scan/post-hoc pricing agree."""
    for name in ("lognormal-stragglers", "wan-gossip", "edge-vs-datacenter"):
        prof = make_profile(name)
        a = prof.realize(8, seed=seed)
        b = prof.realize(8, seed=seed)
        for f in ("compute_s", "link_latency_s", "link_bw_Bps",
                  "up_bw_Bps", "down_bw_Bps"):
            np.testing.assert_array_equal(getattr(a, f), getattr(b, f))
        # latency matrices stay symmetric with a zero diagonal under jitter
        np.testing.assert_array_equal(a.link_latency_s, a.link_latency_s.T)
        assert np.all(np.diag(a.link_latency_s) == 0.0)


def test_different_seeds_draw_different_stragglers():
    prof = make_profile("lognormal-stragglers")
    a = prof.realize(8, seed=0)
    b = prof.realize(8, seed=1)
    assert not np.array_equal(a.compute_s, b.compute_s)


def test_edge_vs_datacenter_device_classes():
    params = make_profile("edge-vs-datacenter").realize(6, seed=0)
    dc, edge = params.compute_s[:3], params.compute_s[3:]
    assert dc.max() < edge.min()  # datacenter strictly faster
    assert params.up_bw_Bps[:3].min() > params.up_bw_Bps[3:].max()


def test_systems_params_json_round_trip_with_inf():
    params = make_profile(FREE_NETWORK).realize(3, seed=0)
    rt = SystemsParams.from_dict(json.loads(json.dumps(params.to_dict())))
    np.testing.assert_array_equal(rt.link_bw_Bps, params.link_bw_Bps)
    np.testing.assert_array_equal(rt.compute_s, params.compute_s)
    assert rt.server_rtt_s == params.server_rtt_s


# ---------------------------------------------------------------------------
# Cost model: hand-computed round times
# ---------------------------------------------------------------------------


def _tiny_model():
    lat = np.array([[0.0, 0.05, 0.1], [0.05, 0.0, 0.2], [0.1, 0.2, 0.0]])
    bw = np.full((3, 3), 100.0)
    return SystemsModel(
        params=SystemsParams(
            compute_s=np.array([0.1, 0.2, 0.4]),
            link_latency_s=lat,
            link_bw_Bps=bw,
            up_bw_Bps=np.array([10.0, 5.0, 2.0]),
            down_bw_Bps=np.array([20.0, 10.0, 4.0]),
            server_rtt_s=1.0,
        )
    )


def test_gossip_round_time_gated_by_slowest_realized_edge():
    m = _tiny_model()
    edges = np.array([[0, 1], [1, 2]])
    # compute: 3 steps x slowest agent (0.4); comm: 2 mixes x slowest edge
    # (1-2: 0.2 latency + 10 bytes / 100 Bps = 0.3)
    t = m.gossip_round_time(edges, 10, mixes=2, local_steps=3)
    assert t == pytest.approx(3 * 0.4 + 2 * 0.3)
    # dropping the slow edge re-gates on the 0-1 link
    t = m.gossip_round_time(edges[:1], 10, mixes=2, local_steps=3)
    assert t == pytest.approx(3 * 0.4 + 2 * (0.05 + 0.1))
    # no realized edges: pure compute
    assert m.gossip_round_time(np.zeros((0, 2), int), 10, local_steps=3) == (
        pytest.approx(3 * 0.4)
    )


def test_server_round_time_gated_by_sampled_straggler_tail():
    m = _tiny_model()
    # all three sampled: rtt + slowest upload (2 payloads x 10B / 2 Bps = 10)
    # + slowest download (20B / 4 Bps = 5) + compute over the sample (0.4)
    t = m.server_round_time(np.array([0, 1, 2]), 10, payloads=2, local_steps=1)
    assert t == pytest.approx(0.4 + 1.0 + 10.0 + 5.0)
    # the straggler tail is the *sample*: without agent 2, compute gates on
    # 0.2 and the wire on agent 1's links
    t = m.server_round_time(np.array([0, 1]), 10, payloads=2, local_steps=1)
    assert t == pytest.approx(0.2 + 1.0 + 20.0 / 5.0 + 20.0 / 10.0)


# ---------------------------------------------------------------------------
# History wiring: sim_time_s across drivers, free-network reduction
# ---------------------------------------------------------------------------


def test_free_network_reduces_to_compute_only():
    """Acceptance pin: zero latency + infinite bandwidth => sim_time_s is
    exactly local_steps x compute per round, for every round kind."""
    spec = ExperimentSpec.create(
        algo="pisco", n_agents=N_AGENTS, t_o=3, eta_l=0.1, p=0.5, seed=1,
        systems=FREE_NETWORK, rounds=6, driver="scan", block_size=2,
    )
    hist = _experiment(spec).run()
    assert hist.sim_time_s == [3 * COMPUTE] * 6
    # a protocol without local updates prices one step per round
    hist = _experiment(spec.replace(algo="dsgt")).run()
    assert hist.sim_time_s == [COMPUTE] * 6
    assert hist.accountant.total_seconds == pytest.approx(6 * COMPUTE)


def test_sim_series_identical_across_drivers_and_posthoc():
    """Same seed => the same simulated seconds, round for round, whether the
    loop driver, the scan driver, or price_history computed them — under
    stragglers, link failures, and partial participation at once."""
    spec = ExperimentSpec.create(
        algo="pisco", n_agents=N_AGENTS, t_o=2, eta_l=0.1, p=0.3, seed=4,
        network="bernoulli:0.4", participation=0.6,
        systems="lognormal-stragglers", rounds=8, driver="scan", block_size=3,
    )
    h_scan = _experiment(spec).run()
    h_loop = _experiment(spec.replace(driver="loop")).run()
    assert len(h_scan.sim_time_s) == 8
    assert h_scan.sim_time_s == h_loop.sim_time_s  # bitwise
    np.testing.assert_array_equal(
        price_history(h_scan, spec), np.asarray(h_scan.sim_time_s)
    )
    # server rounds priced differently from gossip rounds
    assert h_scan.accountant.agent_to_server_seconds > 0
    assert h_scan.accountant.agent_to_agent_seconds > 0


def test_runs_without_systems_record_no_sim_time():
    spec = ExperimentSpec.create(
        algo="pisco", n_agents=N_AGENTS, t_o=1, eta_l=0.1, p=0.3, seed=0,
        rounds=4, driver="scan",
    )
    hist = _experiment(spec).run()
    assert hist.sim_time_s == []
    assert hist.time_model is None
    d = hist.to_dict()
    assert d["sim_time_s"] == [] and d["sim_time_total_s"] == 0.0


def test_compression_shortens_simulated_transfers():
    """The time model prices the *wire* format: q8 gossip messages move
    ~4x fewer bytes, so transfer-bound gossip rounds get faster."""
    kw = dict(
        algo="pisco", n_agents=N_AGENTS, t_o=1, eta_l=0.1, p=0.0, seed=0,
        systems="uniform:latency=0,bw=1e3,rtt=0", rounds=3, driver="scan",
    )
    full = _experiment(ExperimentSpec.create(**kw)).run()
    q8 = _experiment(ExperimentSpec.create(compression="q8", **kw)).run()
    assert q8.byte_model.gossip_message_bytes < full.byte_model.gossip_message_bytes
    assert sum(q8.sim_time_s) < sum(full.sim_time_s)


# ---------------------------------------------------------------------------
# ExperimentSpec systems= field: round-trips and legacy payloads
# ---------------------------------------------------------------------------


def test_systems_spec_round_trips():
    spec = ExperimentSpec.create(
        algo="pisco", n_agents=N_AGENTS, t_o=2, eta_l=0.15, p=0.3, seed=5,
        network="bernoulli:0.35", participation=0.6,
        systems="wan-gossip:latency=0.1", rounds=6,
    )
    for c in (
        ExperimentSpec.from_dict(spec.to_dict()),
        ExperimentSpec.from_json(spec.to_json()),
        pickle.loads(pickle.dumps(spec)),
    ):
        assert c == spec
    assert json.loads(spec.to_json())["systems"] == "wan-gossip:latency=0.1"


def test_legacy_payloads_without_systems_load_bit_exact():
    """A pre-sim JSON payload (no ``systems`` key) deserializes to the exact
    legacy behavior: same spec, no sim series, identical History floats."""
    spec = ExperimentSpec.create(
        algo="dsgt", n_agents=N_AGENTS, t_o=1, eta_l=0.1, p=0.3, seed=1,
        rounds=5, driver="scan",
    )
    payload = spec.to_dict()
    payload.pop("systems")  # what a pre-PR-5 writer emitted
    old = ExperimentSpec.from_dict(payload)
    assert old.systems is None and old == spec
    h_old = _experiment(old).run()
    h_new = _experiment(spec).run()
    assert h_old.loss == h_new.loss  # bitwise
    assert h_old.accountant.per_round_bytes == h_new.accountant.per_round_bytes
    assert h_old.sim_time_s == [] == h_new.sim_time_s


def test_bad_systems_spec_fails_at_construction():
    with pytest.raises(ValueError, match="unknown systems profile"):
        ExperimentSpec.create(algo="pisco", n_agents=4, systems="wann-gossip")
    with pytest.raises(ValueError, match="bad systems override"):
        ExperimentSpec.create(algo="pisco", n_agents=4, systems="uniform:x=1")


# ---------------------------------------------------------------------------
# Tuner: frontier, free-network reduction, the wan/lan flip
# ---------------------------------------------------------------------------


def _tuner_spec(rounds=60):
    return ExperimentSpec.create(
        algo="pisco", n_agents=N_AGENTS, t_o=1, eta_l=0.3, p=0.1, seed=0,
        rounds=rounds, eval_every=rounds, driver="scan",
    )


def test_tuner_free_ranking_matches_rounds_ranking():
    """Acceptance pin: with a free network (fixed τ), simulated time is
    rounds x constant, so the tuner's ranking over p must equal the
    rounds-to-target ranking — fig4's round-count criterion."""
    res = tune(
        _tuner_spec(), _pieces(), p_grid=[0.0, 0.3, 1.0],
        systems=FREE_NETWORK,
    )
    by_rounds = sorted(
        res.points,
        key=lambda pt: (
            0 if pt.rounds_to_target is not None else 1,
            pt.rounds_to_target if pt.rounds_to_target is not None else 0,
            pt.final_loss,
        ),
    )
    assert res.ranking() == [(pt.p, pt.t_o) for pt in by_rounds]
    # and time is literally rounds x (t_o x compute) for every point
    for pt in res.points:
        assert pt.total_sim_time_s == pytest.approx(pt.rounds_run * COMPUTE)


def test_tuner_flips_to_higher_p_when_gossip_crosses_the_wan():
    """Acceptance pin: cheap-gossip profiles favor small p, WAN gossip makes
    server rounds the fast path — the paper's trade-off, on the time axis."""
    res = tune(
        _tuner_spec(), _pieces(), p_grid=[0.0, 1.0], systems="lan-gossip",
    )
    # compare at a target every configuration reaches, so best-p reflects
    # time, not reachability
    target = 1.02 * max(pt.final_loss for pt in res.points)
    lan = retime(res, "lan-gossip", target_loss=target)
    wan = retime(res, "wan-gossip", target_loss=target)
    assert all(pt.time_to_target_s is not None for pt in lan.points)
    assert all(pt.time_to_target_s is not None for pt in wan.points)
    assert lan.best.p == 0.0
    assert wan.best.p == 1.0
    # repricing never changes the trajectory, only the clock
    for a, b in zip(
        sorted(lan.points, key=lambda pt: pt.p),
        sorted(wan.points, key=lambda pt: pt.p),
    ):
        assert a.rounds_to_target == b.rounds_to_target
        assert a.bytes_to_target == b.bytes_to_target
        assert a.final_loss == b.final_loss


@pytest.mark.slow  # multi-rung sweep; strategy coverage, not an acceptance pin
def test_tuner_halving_spends_less_and_reports_every_config():
    grid = tune(
        _tuner_spec(40), _pieces(), p_grid=[0.0, 0.3, 1.0],
        systems="lan-gossip", strategy="grid",
    )
    halved = tune(
        _tuner_spec(40), _pieces(), p_grid=[0.0, 0.3, 1.0],
        systems="lan-gossip", strategy="halving", min_rounds=8,
    )
    assert halved.best.rounds_run == 40  # the winner ran the full budget
    assert sum(pt.rounds_run for pt in halved.points) < sum(
        pt.rounds_run for pt in grid.points
    )
    # eliminated configs still show up in the frontier, at their last rung
    assert sorted(pt.p for pt in halved.points) == [0.0, 0.3, 1.0]
    assert halved.best.time_to_target_s is not None


def test_tuner_sweeps_tau_and_requires_systems():
    res = tune(
        _tuner_spec(16), _pieces(), p_grid=[0.1], tau_grid=(1, 3),
        systems=FREE_NETWORK,
    )
    taus = sorted(pt.t_o for pt in res.points)
    assert taus == [1, 3]
    # free network: each round costs t_o x compute
    for pt in res.points:
        assert pt.total_sim_time_s == pytest.approx(16 * pt.t_o * COMPUTE)
    with pytest.raises(ValueError, match="systems profile"):
        tune(_tuner_spec(8), _pieces(), p_grid=[0.1])
    with pytest.raises(ValueError, match="strategy"):
        tune(_tuner_spec(8), _pieces(), p_grid=[0.1],
             systems=FREE_NETWORK, strategy="bogus")
