"""Topology & mixing-matrix properties (Definition 1, Assumption 1)."""
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.core.topology import (
    GRAPHS,
    ParticipationProcess,
    RoundRobinProcess,
    StaticProcess,
    TOPOLOGY_PROCESSES,
    Topology,
    best_constant_weights,
    erdos_renyi_graph,
    expected_mixing_rate,
    global_matrix,
    is_connected,
    is_doubly_stochastic,
    make_topology,
    make_topology_process,
    metropolis_weights,
    mixing_rate,
    ring_graph,
    second_singular_value,
    torus_graph,
)


@pytest.mark.parametrize("name", ["ring", "path", "star", "full"])
@pytest.mark.parametrize("n", [2, 4, 10, 16])
@pytest.mark.parametrize("weighting", ["metropolis", "best_constant"])
def test_doubly_stochastic(name, n, weighting):
    topo = make_topology(name, n, weighting)
    assert is_doubly_stochastic(topo.w)


@given(n=st.integers(3, 24), prob=st.floats(0.05, 0.9), seed=st.integers(0, 100))
@settings(max_examples=40, deadline=None)
def test_er_metropolis_doubly_stochastic(n, prob, seed):
    adj = erdos_renyi_graph(n, prob, seed)
    w = metropolis_weights(adj)
    assert is_doubly_stochastic(w)
    lam = mixing_rate(w)
    assert 0.0 <= lam <= 1.0 + 1e-9
    # disconnected graphs must have lambda_w == 0 (Definition 1)
    if not is_connected(adj):
        assert lam == pytest.approx(0.0, abs=1e-9)


@given(n=st.integers(3, 20), seed=st.integers(0, 50))
@settings(max_examples=25, deadline=None)
def test_contraction_property(n, seed):
    """||Wx - x_bar||^2 <= (1 - lambda_w) ||x - x_bar||^2 (paper §2.1)."""
    rng = np.random.default_rng(seed)
    topo = make_topology("ring", n)
    x = rng.normal(size=(n, 3))
    xbar = x.mean(axis=0, keepdims=True)
    lhs = np.sum((topo.w @ x - xbar) ** 2)
    rhs = (1.0 - topo.lambda_w) * np.sum((x - xbar) ** 2)
    assert lhs <= rhs + 1e-9


def test_global_matrix_is_projection():
    j = global_matrix(7)
    assert np.allclose(j @ j, j)
    assert mixing_rate(j) == pytest.approx(1.0)


def test_expected_mixing_rate_formula():
    # Assumption 1: lambda_p = lambda_w + p (1 - lambda_w)
    assert expected_mixing_rate(0.0, 0.5) == pytest.approx(0.5)
    assert expected_mixing_rate(0.3, 0.0) == pytest.approx(0.3)
    assert expected_mixing_rate(0.3, 1.0) == pytest.approx(1.0)
    topo = make_topology("ring", 10)
    assert topo.expected_rate(0.1) == pytest.approx(
        topo.lambda_w + 0.1 * (1 - topo.lambda_w)
    )


def test_disconnected_has_zero_rate_and_connected_flag():
    topo = make_topology("disconnected", 12, n_components=3)
    assert not topo.connected
    assert topo.lambda_w == pytest.approx(0.0, abs=1e-9)


def test_ring_detected_as_circulant():
    topo = make_topology("ring", 8)
    assert topo.shifts is not None
    shifts = dict((s, w) for s, w in topo.shifts)
    assert 1 in shifts and (8 - 1) in shifts or -1 in shifts


def test_torus_shapes():
    adj = torus_graph(4, 4)
    assert adj.sum(axis=1).min() == 4  # every node has 4 neighbors
    topo = make_topology("torus", 16, rows=4)
    assert is_doubly_stochastic(topo.w)
    assert topo.connected


def test_path_worse_than_ring():
    ring = make_topology("ring", 16)
    path = make_topology("path", 16)
    full = make_topology("full", 16)
    assert path.lambda_w < ring.lambda_w < full.lambda_w


def test_best_constant_on_ring_beats_or_matches_metropolis():
    ring_m = make_topology("ring", 16, "metropolis")
    ring_b = make_topology("ring", 16, "best_constant")
    assert ring_b.lambda_w >= ring_m.lambda_w - 1e-9


# ---------------------------------------------------------------------------
# Dynamic networks: TopologyProcess realizations (property-based)
# ---------------------------------------------------------------------------

PROCESS_SPECS = ["static", "bernoulli:0.3", "matching", "roundrobin:2"]
PROCESS_NS = [1, 2, 3, 8, 16]


@given(
    n=st.sampled_from(PROCESS_NS),
    spec=st.sampled_from(PROCESS_SPECS),
    k=st.integers(0, 40),
    seed=st.integers(0, 20),
)
@settings(max_examples=60, deadline=None)
def test_process_realizations_are_valid_mixing_matrices(n, spec, k, seed):
    """Every realized W_k is symmetric, doubly stochastic, supported only on
    base-graph edges, and satisfies its own §2.1 contraction bound."""
    base = make_topology("ring", n)
    proc = make_topology_process(spec, base, seed=seed)
    w = proc.weights_at(k)
    assert w.shape == (n, n)
    assert is_doubly_stochastic(w)
    assert np.allclose(w, w.T)
    off_support = (np.abs(w) > 1e-12) & ~np.eye(n, dtype=bool)
    assert not np.any(off_support & ~base.adj), "gossip over a non-edge"
    lam = mixing_rate(w)
    assert -1e-9 <= lam <= 1.0 + 1e-9
    # per-realization contraction at the realization's own rate
    rng = np.random.default_rng(seed + 1)
    x = rng.normal(size=(n, 3))
    xbar = x.mean(axis=0, keepdims=True)
    lhs = np.sum((w @ x - xbar) ** 2)
    rhs = (1.0 - lam) * np.sum((x - xbar) ** 2)
    assert lhs <= rhs + 1e-9


@given(
    n=st.sampled_from([2, 3, 8, 16]),
    spec=st.sampled_from(PROCESS_SPECS),
    seed=st.integers(0, 10),
)
@settings(max_examples=30, deadline=None)
def test_process_time_average_mixes_at_least_as_well_as_worst_draw(n, spec, seed):
    """||mean_t W_t - J|| <= max_t ||W_t - J|| (convexity of the operator
    norm), i.e. the time-averaged matrix's mixing rate is bounded below by
    the worst single realization — the dynamic analogue of the static bound.
    For the static process this is equality with the base graph's rate."""
    base = make_topology("ring", n)
    proc = make_topology_process(spec, base, seed=seed)
    draws = [proc.weights_at(k) for k in range(12)]
    w_bar = np.mean(draws, axis=0)
    worst = max(second_singular_value(w) for w in draws)
    assert second_singular_value(w_bar) <= worst + 1e-9
    assert mixing_rate(w_bar) >= min(mixing_rate(w) for w in draws) - 1e-9
    if spec == "static":
        assert mixing_rate(w_bar) == pytest.approx(base.lambda_w, abs=1e-9)


def test_static_process_reproduces_base_topology():
    base = make_topology("ring", 8, "best_constant")
    proc = make_topology_process("static", base)
    assert isinstance(proc, StaticProcess) and proc.static
    for k in (0, 3, 17):
        np.testing.assert_array_equal(proc.weights_at(k), base.w)
        assert proc.messages_at(k) == int(base.adj.sum())


def test_bernoulli_process_failure_prob_limits():
    base = make_topology("ring", 8)
    keep_all = make_topology_process("bernoulli:0.0", base, seed=1)
    drop_all = make_topology_process("bernoulli:1.0", base, seed=1)
    for k in range(4):
        np.testing.assert_array_equal(keep_all.adjacency_at(k), base.adj)
        assert drop_all.messages_at(k) == 0
        np.testing.assert_array_equal(drop_all.weights_at(k), np.eye(8))


def test_matching_process_realizes_disjoint_pairs():
    base = make_topology("full", 8)
    proc = make_topology_process("matching", base, seed=3)
    for k in range(6):
        edges = proc.edges_at(k)
        flat = edges.ravel()
        assert len(flat) == len(set(flat.tolist())), "agent in two pairs"
        # maximal on the complete graph: n/2 pairs
        assert len(edges) == 4
        w = proc.weights_at(k)
        matched = sorted(flat.tolist())
        for i, j in edges:
            assert w[i, j] == pytest.approx(0.5)


def test_roundrobin_cycle_covers_every_base_edge_once():
    base = make_topology("ring", 10)
    proc = make_topology_process("roundrobin:3", base, seed=0)
    assert isinstance(proc, RoundRobinProcess)
    union = np.zeros_like(base.adj)
    total_edges = 0
    for k in range(3):
        union |= proc.adjacency_at(k)
        total_edges += len(proc.edges_at(k))
    np.testing.assert_array_equal(union, base.adj)
    assert total_edges == int(base.adj.sum()) // 2
    # deterministic cycle
    np.testing.assert_array_equal(proc.weights_at(0), proc.weights_at(3))


@given(spec=st.sampled_from(PROCESS_SPECS), seed=st.integers(0, 10))
@settings(max_examples=20, deadline=None)
def test_process_draws_are_pure_functions_of_seed_and_round(spec, seed):
    """Block draws equal per-round draws and re-instantiation: the contract
    that makes the loop and scan drivers agree under any block boundaries."""
    base = make_topology("ring", 8)
    p1 = make_topology_process(spec, base, seed=seed)
    p2 = make_topology_process(spec, base, seed=seed)
    ws, msgs = p1.draw_block(2, 7)
    for i, k in enumerate(range(2, 7)):
        np.testing.assert_allclose(ws[i], p2.weights_at(k).astype(np.float32))
        assert msgs[i] == p2.messages_at(k)


def test_make_topology_process_rejects_unknown_kind():
    base = make_topology("ring", 4)
    with pytest.raises(ValueError, match="unknown topology process"):
        make_topology_process("smallworld", base)
    assert set(TOPOLOGY_PROCESSES) == {
        "static", "bernoulli", "matching", "roundrobin", "cohort"
    }


# ---------------------------------------------------------------------------
# Partial participation
# ---------------------------------------------------------------------------


@given(
    n=st.sampled_from(PROCESS_NS),
    frac=st.floats(0.1, 1.0),
    k=st.integers(0, 20),
    seed=st.integers(0, 10),
)
@settings(max_examples=40, deadline=None)
def test_participation_matrix_is_doubly_stochastic_sampling(n, frac, k, seed):
    proc = ParticipationProcess(n, frac, seed=seed)
    assert 1 <= proc.m <= n
    part = proc.participants_at(k)
    assert len(part) == proc.m == len(set(part.tolist()))
    s = proc.server_matrix_at(k)
    assert is_doubly_stochastic(s)
    assert np.allclose(s, s.T)
    # participants average among themselves, absentees hold
    absent = np.setdiff1d(np.arange(n), part)
    for i in absent:
        assert s[i, i] == pytest.approx(1.0)
    x = np.random.default_rng(seed).normal(size=(n, 2))
    np.testing.assert_allclose((s @ x).mean(axis=0), x.mean(axis=0), atol=1e-12)


def test_participation_draws_are_deterministic_and_vary_by_round():
    p1 = ParticipationProcess(16, 0.25, seed=4)
    p2 = ParticipationProcess(16, 0.25, seed=4)
    ss, counts = p1.draw_block(0, 8)
    assert counts.tolist() == [4] * 8
    sets = set()
    for i in range(8):
        np.testing.assert_allclose(ss[i], p2.server_matrix_at(i).astype(np.float32))
        sets.add(tuple(p2.participants_at(i).tolist()))
    assert len(sets) > 1, "participation never resampled"
