"""Topology & mixing-matrix properties (Definition 1, Assumption 1)."""
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.core.topology import (
    GRAPHS,
    Topology,
    best_constant_weights,
    erdos_renyi_graph,
    expected_mixing_rate,
    global_matrix,
    is_connected,
    is_doubly_stochastic,
    make_topology,
    metropolis_weights,
    mixing_rate,
    ring_graph,
    torus_graph,
)


@pytest.mark.parametrize("name", ["ring", "path", "star", "full"])
@pytest.mark.parametrize("n", [2, 4, 10, 16])
@pytest.mark.parametrize("weighting", ["metropolis", "best_constant"])
def test_doubly_stochastic(name, n, weighting):
    topo = make_topology(name, n, weighting)
    assert is_doubly_stochastic(topo.w)


@given(n=st.integers(3, 24), prob=st.floats(0.05, 0.9), seed=st.integers(0, 100))
@settings(max_examples=40, deadline=None)
def test_er_metropolis_doubly_stochastic(n, prob, seed):
    adj = erdos_renyi_graph(n, prob, seed)
    w = metropolis_weights(adj)
    assert is_doubly_stochastic(w)
    lam = mixing_rate(w)
    assert 0.0 <= lam <= 1.0 + 1e-9
    # disconnected graphs must have lambda_w == 0 (Definition 1)
    if not is_connected(adj):
        assert lam == pytest.approx(0.0, abs=1e-9)


@given(n=st.integers(3, 20), seed=st.integers(0, 50))
@settings(max_examples=25, deadline=None)
def test_contraction_property(n, seed):
    """||Wx - x_bar||^2 <= (1 - lambda_w) ||x - x_bar||^2 (paper §2.1)."""
    rng = np.random.default_rng(seed)
    topo = make_topology("ring", n)
    x = rng.normal(size=(n, 3))
    xbar = x.mean(axis=0, keepdims=True)
    lhs = np.sum((topo.w @ x - xbar) ** 2)
    rhs = (1.0 - topo.lambda_w) * np.sum((x - xbar) ** 2)
    assert lhs <= rhs + 1e-9


def test_global_matrix_is_projection():
    j = global_matrix(7)
    assert np.allclose(j @ j, j)
    assert mixing_rate(j) == pytest.approx(1.0)


def test_expected_mixing_rate_formula():
    # Assumption 1: lambda_p = lambda_w + p (1 - lambda_w)
    assert expected_mixing_rate(0.0, 0.5) == pytest.approx(0.5)
    assert expected_mixing_rate(0.3, 0.0) == pytest.approx(0.3)
    assert expected_mixing_rate(0.3, 1.0) == pytest.approx(1.0)
    topo = make_topology("ring", 10)
    assert topo.expected_rate(0.1) == pytest.approx(
        topo.lambda_w + 0.1 * (1 - topo.lambda_w)
    )


def test_disconnected_has_zero_rate_and_connected_flag():
    topo = make_topology("disconnected", 12, n_components=3)
    assert not topo.connected
    assert topo.lambda_w == pytest.approx(0.0, abs=1e-9)


def test_ring_detected_as_circulant():
    topo = make_topology("ring", 8)
    assert topo.shifts is not None
    shifts = dict((s, w) for s, w in topo.shifts)
    assert 1 in shifts and (8 - 1) in shifts or -1 in shifts


def test_torus_shapes():
    adj = torus_graph(4, 4)
    assert adj.sum(axis=1).min() == 4  # every node has 4 neighbors
    topo = make_topology("torus", 16, rows=4)
    assert is_doubly_stochastic(topo.w)
    assert topo.connected


def test_path_worse_than_ring():
    ring = make_topology("ring", 16)
    path = make_topology("path", 16)
    full = make_topology("full", 16)
    assert path.lambda_w < ring.lambda_w < full.lambda_w


def test_best_constant_on_ring_beats_or_matches_metropolis():
    ring_m = make_topology("ring", 16, "metropolis")
    ring_b = make_topology("ring", 16, "best_constant")
    assert ring_b.lambda_w >= ring_m.lambda_w - 1e-9
