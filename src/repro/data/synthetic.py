"""Synthetic datasets mirroring the paper's experimental workloads.

The container is offline, so we generate statistically-similar stand-ins with
fixed seeds:

* :func:`synthetic_a9a`   — binary classification, d=124 sparse-ish binary
  features (a9a is one-hot encoded census data), separable by a planted
  logistic model plus label noise.  Matches the paper's §5.1 workload shape
  (n=10 agents × m=3256 samples).
* :func:`synthetic_mnist` — 10-class, 784-dim "digit" clusters (one Gaussian
  cluster per class on a random template), §5.2's 1-hidden-layer MLP workload.
* :func:`synthetic_cifar` — 10-class small images (3×16×16 by default) for
  the CNN experiment (Fig. 7).
* :func:`synthetic_lm_tokens` — Zipfian token streams for LM training
  (examples + the ~100M end-to-end driver).
"""
from __future__ import annotations

from typing import Tuple

import numpy as np


def synthetic_a9a(
    n_samples: int = 32560, d: int = 124, seed: int = 0, noise: float = 0.1
) -> Tuple[np.ndarray, np.ndarray]:
    """Returns (features (N, d) float32, labels (N,) in {-1, +1})."""
    rng = np.random.default_rng(seed)
    # one-hot-ish binary features with varying activation rates
    rates = rng.uniform(0.02, 0.5, size=d)
    feats = (rng.random((n_samples, d)) < rates).astype(np.float32)
    w = rng.normal(size=d) / np.sqrt(d)
    logits = feats @ w + 0.3 * rng.normal(size=n_samples)
    labels = np.where(logits + noise * rng.normal(size=n_samples) > np.median(logits), 1.0, -1.0)
    return feats, labels.astype(np.float32)


def synthetic_mnist(
    n_samples: int = 20000, d: int = 784, n_classes: int = 10, seed: int = 0
) -> Tuple[np.ndarray, np.ndarray]:
    """Returns (images (N, 784) float32 in [0,1], labels (N,) int32)."""
    rng = np.random.default_rng(seed)
    templates = rng.random((n_classes, d)) * (rng.random((n_classes, d)) < 0.2)
    labels = rng.integers(0, n_classes, size=n_samples)
    x = templates[labels] + 0.15 * rng.normal(size=(n_samples, d))
    x = np.clip(x, 0.0, 1.0).astype(np.float32)
    return x, labels.astype(np.int32)


def synthetic_cifar(
    n_samples: int = 10000, hw: int = 16, n_classes: int = 10, seed: int = 0
) -> Tuple[np.ndarray, np.ndarray]:
    """Returns (images (N, hw, hw, 3) float32, labels (N,) int32)."""
    rng = np.random.default_rng(seed)
    templates = rng.random((n_classes, hw, hw, 3)).astype(np.float32)
    labels = rng.integers(0, n_classes, size=n_samples)
    x = 0.6 * templates[labels] + 0.4 * rng.random((n_samples, hw, hw, 3))
    return x.astype(np.float32), labels.astype(np.int32)


def synthetic_lm_tokens(
    n_tokens: int, vocab_size: int, seed: int = 0, alpha: float = 1.1
) -> np.ndarray:
    """Zipf-distributed token stream with local bigram structure (so a small
    LM has something learnable)."""
    rng = np.random.default_rng(seed)
    ranks = np.arange(1, vocab_size + 1, dtype=np.float64)
    probs = ranks ** (-alpha)
    probs /= probs.sum()
    base = rng.choice(vocab_size, size=n_tokens, p=probs)
    # inject learnable bigrams: token t often followed by (t*7+1) % vocab
    follow = rng.random(n_tokens) < 0.35
    base[1:][follow[1:]] = (base[:-1][follow[1:]] * 7 + 1) % vocab_size
    return base.astype(np.int32)
