from repro.data.synthetic import (
    synthetic_a9a,
    synthetic_mnist,
    synthetic_cifar,
    synthetic_lm_tokens,
)
from repro.data.federated import (
    partition_sorted,
    partition_iid,
    FederatedDataset,
    RoundSampler,
)

__all__ = [
    "synthetic_a9a",
    "synthetic_mnist",
    "synthetic_cifar",
    "synthetic_lm_tokens",
    "partition_sorted",
    "partition_iid",
    "FederatedDataset",
    "RoundSampler",
]
