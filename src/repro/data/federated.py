"""Federated data partitioning + per-round minibatch sampling.

The paper's heterogeneity protocol (§5): *sort the dataset by label and split
it contiguously* across agents, so each agent sees a disjoint label slice —
extreme non-IID.  ``partition_iid`` is the shuffled control.

:class:`RoundSampler` produces exactly what one PISCO round consumes
(Algorithm 1 uses T_o + 1 fresh minibatches per agent per round):
``local_batches`` with leaves shaped (T_o, n_agents, b, ...) and a
``comm_batch`` with leaves (n_agents, b, ...).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

# Domain-separation tags (repro.core.topology idiom): every RNG stream in the
# data path is keyed by (tag, seed[, round]) so equal seeds can never alias two
# different draws.  _PARTITION_TAG fixes the historical bug where the iid
# partition permutation reused the train/test-split stream verbatim;
# _SAMPLER_TAG keys the per-round minibatch stream, making RoundSampler a pure
# function of (seed, round_idx) instead of a stateful call-order-dependent one.
_PARTITION_TAG = 0x9B1D
_SAMPLER_TAG = 0x5A3D


def _derive_seed(tag: int, seed: int) -> int:
    """Collapse (tag, seed) into one int for APIs taking a scalar seed."""
    return int(np.random.SeedSequence((int(tag), int(seed))).generate_state(1)[0])


def partition_sorted(
    x: np.ndarray, y: np.ndarray, n_agents: int
) -> Tuple[np.ndarray, np.ndarray]:
    """Sort by label, split contiguously: (n_agents, m, ...), (n_agents, m)."""
    order = np.argsort(y, kind="stable")
    xs, ys = x[order], y[order]
    m = len(y) // n_agents
    xs = xs[: m * n_agents].reshape(n_agents, m, *x.shape[1:])
    ys = ys[: m * n_agents].reshape(n_agents, m)
    return xs, ys


def partition_iid(
    x: np.ndarray, y: np.ndarray, n_agents: int, seed: int = 0
) -> Tuple[np.ndarray, np.ndarray]:
    rng = np.random.default_rng(seed)
    order = rng.permutation(len(y))
    xs, ys = x[order], y[order]
    m = len(y) // n_agents
    xs = xs[: m * n_agents].reshape(n_agents, m, *x.shape[1:])
    ys = ys[: m * n_agents].reshape(n_agents, m)
    return xs, ys


@dataclasses.dataclass
class FederatedDataset:
    """Agent-partitioned dataset with train/test split."""

    x_train: np.ndarray  # (A, m, ...)
    y_train: np.ndarray  # (A, m)
    x_test: np.ndarray  # (N_test, ...)
    y_test: np.ndarray  # (N_test,)

    @property
    def n_agents(self) -> int:
        return self.x_train.shape[0]

    @property
    def samples_per_agent(self) -> int:
        return self.x_train.shape[1]

    @classmethod
    def from_arrays(
        cls,
        x: np.ndarray,
        y: np.ndarray,
        n_agents: int,
        *,
        heterogeneous: bool = True,
        test_fraction: float = 0.2,
        seed: int = 0,
    ) -> "FederatedDataset":
        rng = np.random.default_rng(seed)
        order = rng.permutation(len(y))
        n_test = int(len(y) * test_fraction)
        test_idx, train_idx = order[:n_test], order[n_test:]
        if heterogeneous:
            xs, ys = partition_sorted(x[train_idx], y[train_idx], n_agents)
        else:
            # Domain-separated partition seed: passing ``seed`` verbatim made
            # the iid partition permutation the *same stream* as the
            # train/test split above, correlating which samples land where.
            xs, ys = partition_iid(
                x[train_idx], y[train_idx], n_agents,
                seed=_derive_seed(_PARTITION_TAG, seed),
            )
        return cls(xs, ys, x[test_idx], y[test_idx])


class RoundSampler:
    """Sampler matching the trainer's contract: sampler(k) ->
    (local_batches [T_o, A, b, ...], comm_batch [A, b, ...]).

    Round ``k``'s minibatch indices are a **pure function of
    ``(seed, round_idx)``** — the same domain-separated
    ``np.random.default_rng((tag, seed, k))`` idiom the topology processes
    use — so eval replays, checkpoint resume, out-of-order calls, and every
    driver (loop, scan blocks at any boundary, events) see bit-identical
    batches for the same round.  The historical sampler drew from one
    stateful stream and silently ignored ``round_idx``; pass
    ``legacy_stream=True`` to reproduce that call-order-dependent behavior.
    """

    def __init__(
        self, data: FederatedDataset, batch_size: int, t_o: int, seed: int = 0,
        *, legacy_stream: bool = False,
    ):
        self.data = data
        self.b = batch_size
        self.t_o = t_o
        self.seed = seed
        self.legacy_stream = legacy_stream
        self._rng = np.random.default_rng(seed) if legacy_stream else None

    def _round_idx(self, round_idx: int, n_rounds: int = 1) -> np.ndarray:
        """(n_rounds, T_o + 1, A, b) sample indices for rounds starting at
        ``round_idx``, each round's draw pure in ``(seed, round)``.  Round
        indices are mapped to nonnegative ints (SeedSequence rejects
        negatives); the init probe ``sampler(-1)`` lands on its own round."""
        a, m = self.data.n_agents, self.data.samples_per_agent
        if self.legacy_stream:
            return self._rng.integers(
                0, m, size=(n_rounds, self.t_o + 1, a, self.b)
            )
        return np.stack([
            np.random.default_rng(
                (_SAMPLER_TAG, int(self.seed), int(round_idx + r) % (1 << 63))
            ).integers(0, m, size=(self.t_o + 1, a, self.b))
            for r in range(n_rounds)
        ])

    def __call__(self, round_idx: int):
        a = self.data.n_agents
        idx = self._round_idx(round_idx)[0]
        xb = np.take_along_axis(
            self.data.x_train[None],
            idx.reshape(self.t_o + 1, a, self.b, *([1] * (self.data.x_train.ndim - 2))),
            axis=2,
        )
        yb = np.take_along_axis(self.data.y_train[None], idx, axis=2)
        xb, yb = jnp.asarray(xb), jnp.asarray(yb)
        local = (xb[: self.t_o], yb[: self.t_o])
        comm = (xb[-1], yb[-1])
        return local, comm

    def sample_block(self, start: int, stop: int):
        """Batches for rounds ``[start, stop)`` with a leading round axis, in
        one numpy gather + one device put (the scan driver's fast path).

        Each round's indices are drawn from that round's own pure stream, so
        a block draw and ``stop - start`` sequential ``__call__``s see
        identical batches regardless of where block boundaries fall."""
        n = stop - start
        a = self.data.n_agents
        idx = self._round_idx(start, n)
        xb = np.take_along_axis(
            self.data.x_train[None, None],
            idx.reshape(
                n, self.t_o + 1, a, self.b, *([1] * (self.data.x_train.ndim - 2))
            ),
            axis=3,
        )
        yb = np.take_along_axis(self.data.y_train[None, None], idx, axis=3)
        xb, yb = jnp.asarray(xb), jnp.asarray(yb)
        local = (xb[:, : self.t_o], yb[:, : self.t_o])
        comm = (xb[:, -1], yb[:, -1])
        return local, comm
