"""Federated data partitioning + per-round minibatch sampling.

The paper's heterogeneity protocol (§5): *sort the dataset by label and split
it contiguously* across agents, so each agent sees a disjoint label slice —
extreme non-IID.  ``partition_iid`` is the shuffled control.

:class:`RoundSampler` produces exactly what one PISCO round consumes
(Algorithm 1 uses T_o + 1 fresh minibatches per agent per round):
``local_batches`` with leaves shaped (T_o, n_agents, b, ...) and a
``comm_batch`` with leaves (n_agents, b, ...).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Sequence, Tuple

import jax.numpy as jnp
import numpy as np


def partition_sorted(
    x: np.ndarray, y: np.ndarray, n_agents: int
) -> Tuple[np.ndarray, np.ndarray]:
    """Sort by label, split contiguously: (n_agents, m, ...), (n_agents, m)."""
    order = np.argsort(y, kind="stable")
    xs, ys = x[order], y[order]
    m = len(y) // n_agents
    xs = xs[: m * n_agents].reshape(n_agents, m, *x.shape[1:])
    ys = ys[: m * n_agents].reshape(n_agents, m)
    return xs, ys


def partition_iid(
    x: np.ndarray, y: np.ndarray, n_agents: int, seed: int = 0
) -> Tuple[np.ndarray, np.ndarray]:
    rng = np.random.default_rng(seed)
    order = rng.permutation(len(y))
    xs, ys = x[order], y[order]
    m = len(y) // n_agents
    xs = xs[: m * n_agents].reshape(n_agents, m, *x.shape[1:])
    ys = ys[: m * n_agents].reshape(n_agents, m)
    return xs, ys


@dataclasses.dataclass
class FederatedDataset:
    """Agent-partitioned dataset with train/test split."""

    x_train: np.ndarray  # (A, m, ...)
    y_train: np.ndarray  # (A, m)
    x_test: np.ndarray  # (N_test, ...)
    y_test: np.ndarray  # (N_test,)

    @property
    def n_agents(self) -> int:
        return self.x_train.shape[0]

    @property
    def samples_per_agent(self) -> int:
        return self.x_train.shape[1]

    @classmethod
    def from_arrays(
        cls,
        x: np.ndarray,
        y: np.ndarray,
        n_agents: int,
        *,
        heterogeneous: bool = True,
        test_fraction: float = 0.2,
        seed: int = 0,
    ) -> "FederatedDataset":
        rng = np.random.default_rng(seed)
        order = rng.permutation(len(y))
        n_test = int(len(y) * test_fraction)
        test_idx, train_idx = order[:n_test], order[n_test:]
        part = partition_sorted if heterogeneous else partition_iid
        if heterogeneous:
            xs, ys = part(x[train_idx], y[train_idx], n_agents)
        else:
            xs, ys = part(x[train_idx], y[train_idx], n_agents, seed=seed)
        return cls(xs, ys, x[test_idx], y[test_idx])


class RoundSampler:
    """Sampler matching the trainer's contract: sampler(k) ->
    (local_batches [T_o, A, b, ...], comm_batch [A, b, ...])."""

    def __init__(
        self, data: FederatedDataset, batch_size: int, t_o: int, seed: int = 0
    ):
        self.data = data
        self.b = batch_size
        self.t_o = t_o
        self._rng = np.random.default_rng(seed)

    def __call__(self, round_idx: int):
        a, m = self.data.n_agents, self.data.samples_per_agent
        idx = self._rng.integers(0, m, size=(self.t_o + 1, a, self.b))
        xb = np.take_along_axis(
            self.data.x_train[None],
            idx.reshape(self.t_o + 1, a, self.b, *([1] * (self.data.x_train.ndim - 2))),
            axis=2,
        )
        yb = np.take_along_axis(self.data.y_train[None], idx, axis=2)
        xb, yb = jnp.asarray(xb), jnp.asarray(yb)
        local = (xb[: self.t_o], yb[: self.t_o])
        comm = (xb[-1], yb[-1])
        return local, comm

    def sample_block(self, start: int, stop: int):
        """Batches for rounds ``[start, stop)`` with a leading round axis, in
        one numpy gather + one device put (the scan driver's fast path).

        Consumes the RNG stream in exactly the per-round order, so a block
        draw and ``stop - start`` sequential ``__call__``s see identical
        batches."""
        n = stop - start
        a, m = self.data.n_agents, self.data.samples_per_agent
        idx = self._rng.integers(0, m, size=(n, self.t_o + 1, a, self.b))
        xb = np.take_along_axis(
            self.data.x_train[None, None],
            idx.reshape(
                n, self.t_o + 1, a, self.b, *([1] * (self.data.x_train.ndim - 2))
            ),
            axis=3,
        )
        yb = np.take_along_axis(self.data.y_train[None, None], idx, axis=3)
        xb, yb = jnp.asarray(xb), jnp.asarray(yb)
        local = (xb[:, : self.t_o], yb[:, : self.t_o])
        comm = (xb[:, -1], yb[:, -1])
        return local, comm
