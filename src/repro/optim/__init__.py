from repro.optim.optimizers import (
    Optimizer,
    sgd,
    momentum,
    adam,
    adamw,
    apply_updates,
)
from repro.optim.schedules import constant, cosine_decay, warmup_cosine, linear_decay

__all__ = [
    "Optimizer",
    "sgd",
    "momentum",
    "adam",
    "adamw",
    "apply_updates",
    "constant",
    "cosine_decay",
    "warmup_cosine",
    "linear_decay",
]
