"""Composable update rules — the one optimizer API for the whole repo.

An :class:`UpdateRule` is an optax-style gradient transformation::

    state   = rule.init(params)
    updates, state = rule.update(grads, state, params)
    params  = apply_updates(params, updates)

and is what both the federated core (PISCO's eq. 3a local step, the baseline
descent steps, FedOpt-style server rounds) and the standalone LM examples run.
``repro.optim.optimizers.Optimizer`` is the same dataclass (one API, shared
``apply_updates``); the legacy names (``sgd`` / ``momentum`` / ``adam`` /
``adamw``) are thin aliases over the combinators below.

Three layers:

* **Transformations** — ``trace`` (momentum), ``scale_by_adam``,
  ``clip_by_global_norm``, ``add_decayed_weights``, ``scale``,
  ``scale_by_learning_rate`` (the only place LR schedules plug in), composed
  with ``chain``.
* **Aliases** — ``sgd(lr)`` (implemented directly so its arithmetic is
  bit-identical to the historical hardcoded ``x - eta * g`` step),
  ``momentum``, ``nesterov``, ``adam``, ``adamw``, and the server-side
  ``fedavgm`` / ``fedadam`` presets of the FedOpt family.
* **Declarative layer** — :func:`parse_update_rule` turns the JSON/CLI string
  form (``"momentum:beta=0.9"``, ``"clip:1.0|adam"``) into a rule, and
  :func:`resolve_update_rules` builds the ``Algorithm.bind`` kwargs from
  ``ExperimentSpec`` fields / ``launch.train`` flags, including per-round
  local-LR decay through :mod:`repro.optim.schedules`.

Agent-stacked usage: the federated core calls ``rule.init`` on the
agent-stacked pytree (leading axis = n_agents on every leaf), so every
params-shaped buffer (momentum trace, Adam moments) is per-agent state.  What
happens to those buffers at communication rounds is a declarative per-
algorithm policy (:func:`comm_opt_state`): ``"mix"`` moves them through the
round's mixing operator (W or J) like the model, ``"keep"`` leaves them
local, ``"reset"`` zeroes them whenever agents synchronize through the
server.  Scalar state (the shared step count) is never mixed.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional, Tuple, Union

import jax
import jax.numpy as jnp

from repro.optim import schedules as S

PyTree = Any
Schedule = Callable[[jnp.ndarray], jnp.ndarray]


@dataclasses.dataclass(frozen=True)
class UpdateRule:
    """``init/update`` gradient transformation (a.k.a. ``Optimizer``).

    ``n_buffers`` counts the params-shaped state streams the rule carries
    (momentum trace = 1, Adam moments = 2, plain SGD = 0) — the quantity the
    byte model prices when the ``"mix"`` opt-state policy ships buffers over
    the network alongside the model.
    """

    init: Callable[[PyTree], PyTree]
    update: Callable[[PyTree, PyTree, Optional[PyTree]], Tuple[PyTree, PyTree]]
    name: str = "rule"
    n_buffers: int = 0


def apply_updates(params: PyTree, updates: PyTree) -> PyTree:
    """Shared application step: ``params + updates`` (fp32 accumulate for
    narrow param dtypes)."""
    return jax.tree.map(
        lambda p, u: (p.astype(jnp.float32) + u).astype(p.dtype), params, updates
    )


def _lr_at(lr: Union[float, Schedule], count: jnp.ndarray) -> jnp.ndarray:
    """The single LR-schedule evaluation point (plain float or callable)."""
    return lr(count) if callable(lr) else jnp.asarray(lr)


# ---------------------------------------------------------------------------
# Transformations
# ---------------------------------------------------------------------------


def chain(*rules: UpdateRule) -> UpdateRule:
    """Compose transformations left-to-right; state is the tuple of states."""

    def init(params):
        return tuple(r.init(params) for r in rules)

    def update(grads, state, params=None):
        new_states = []
        for r, s in zip(rules, state):
            grads, s = r.update(grads, s, params)
            new_states.append(s)
        return grads, tuple(new_states)

    return UpdateRule(
        init,
        update,
        name="|".join(r.name for r in rules),
        n_buffers=sum(r.n_buffers for r in rules),
    )


def scale(factor: float) -> UpdateRule:
    def init(params):
        return ()

    def update(grads, state, params=None):
        return jax.tree.map(lambda g: factor * g, grads), state

    return UpdateRule(init, update, name=f"scale({factor})")


def scale_by_learning_rate(lr: Union[float, Schedule]) -> UpdateRule:
    """``-lr_t * g`` — the terminal descent scaling; owns the step count the
    schedule is evaluated at."""

    def init(params):
        return {"count": jnp.zeros((), jnp.int32)}

    if callable(lr):
        def update(grads, state, params=None):
            step = _lr_at(lr, state["count"])
            updates = jax.tree.map(lambda g: -step * g, grads)
            return updates, {"count": state["count"] + 1}
    else:
        # python-scalar multiply: weak-typed (preserves the leaf dtype) and
        # (-lr) * g is bit-identical to the hardcoded x - lr * g step
        neg = -float(lr)

        def update(grads, state, params=None):
            updates = jax.tree.map(lambda g: neg * g, grads)
            return updates, {"count": state["count"] + 1}

    return UpdateRule(init, update, name="lr")


def trace(decay: float, nesterov: bool = False) -> UpdateRule:
    """Momentum accumulator: ``mu = decay * mu + g`` (heavy-ball / Nesterov)."""

    def init(params):
        return {"mu": jax.tree.map(jnp.zeros_like, params)}

    def update(grads, state, params=None):
        mu = jax.tree.map(lambda m, g: decay * m + g, state["mu"], grads)
        if nesterov:
            out = jax.tree.map(lambda m, g: decay * m + g, mu, grads)
        else:
            out = mu
        return out, {"mu": mu}

    return UpdateRule(init, update, name=f"trace({decay})", n_buffers=1)


def scale_by_adam(
    b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8
) -> UpdateRule:
    """Adam direction: bias-corrected first/second moments (no LR)."""

    def init(params):
        z = lambda p: jnp.zeros_like(p, jnp.float32)
        return {
            "count": jnp.zeros((), jnp.int32),
            "m": jax.tree.map(z, params),
            "v": jax.tree.map(z, params),
        }

    def update(grads, state, params=None):
        count = state["count"] + 1
        m = jax.tree.map(
            lambda mm, g: b1 * mm + (1 - b1) * g.astype(jnp.float32),
            state["m"], grads,
        )
        v = jax.tree.map(
            lambda vv, g: b2 * vv + (1 - b2) * jnp.square(g.astype(jnp.float32)),
            state["v"], grads,
        )
        c1 = 1.0 - b1 ** count.astype(jnp.float32)
        c2 = 1.0 - b2 ** count.astype(jnp.float32)
        out = jax.tree.map(
            lambda mm, vv: (mm / c1) / (jnp.sqrt(vv / c2) + eps), m, v
        )
        return out, {"count": count, "m": m, "v": v}

    return UpdateRule(init, update, name="adam_dir", n_buffers=2)


def clip_by_global_norm(max_norm: float) -> UpdateRule:
    """Rescale the whole update pytree when its global L2 norm exceeds
    ``max_norm`` (agent-stacked trees are clipped jointly — the norm is over
    every leaf element, matching optax semantics on the stacked problem)."""

    def init(params):
        return ()

    def update(grads, state, params=None):
        sq = sum(jnp.sum(jnp.square(g)) for g in jax.tree.leaves(grads))
        norm = jnp.sqrt(sq)
        factor = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-16))
        return jax.tree.map(lambda g: factor * g, grads), state

    return UpdateRule(init, update, name=f"clip({max_norm})")


def add_decayed_weights(weight_decay: float) -> UpdateRule:
    def init(params):
        return ()

    def update(grads, state, params=None):
        if not weight_decay or params is None:
            return grads, state
        return (
            jax.tree.map(
                lambda g, p: g + weight_decay * p.astype(jnp.float32), grads, params
            ),
            state,
        )

    return UpdateRule(init, update, name=f"wd({weight_decay})")


def _named(rule: UpdateRule, name: str) -> UpdateRule:
    return dataclasses.replace(rule, name=name)


# ---------------------------------------------------------------------------
# Aliases (local rules + FedOpt server presets)
# ---------------------------------------------------------------------------


def sgd(lr: Union[float, Schedule]) -> UpdateRule:
    """Plain SGD.  This is the repo-wide default local rule and must stay
    bit-identical to the historical hardcoded ``x - eta * g`` descent step
    (pinned by tests/test_update_rules.py)."""
    return _named(scale_by_learning_rate(lr), "sgd")


def momentum(
    lr: Union[float, Schedule], beta: float = 0.9, nesterov: bool = False
) -> UpdateRule:
    return _named(
        chain(trace(beta, nesterov=nesterov), scale_by_learning_rate(lr)),
        f"{'nesterov' if nesterov else 'momentum'}({beta})",
    )


def adam(
    lr: Union[float, Schedule],
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
) -> UpdateRule:
    return _named(
        chain(scale_by_adam(b1, b2, eps), scale_by_learning_rate(lr)), "adam"
    )


def adamw(
    lr: Union[float, Schedule],
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
    weight_decay: float = 0.01,
) -> UpdateRule:
    return _named(
        chain(
            scale_by_adam(b1, b2, eps),
            add_decayed_weights(weight_decay),
            scale_by_learning_rate(lr),
        ),
        "adamw",
    )


def fedavgm(lr: Union[float, Schedule] = 1.0, beta: float = 0.9) -> UpdateRule:
    """FedAvgM server rule [Hsu et al.]: momentum over round pseudo-gradients."""
    return _named(momentum(lr, beta=beta), f"fedavgm({beta})")


def fedadam(
    lr: Union[float, Schedule] = 0.1,
    b1: float = 0.9,
    b2: float = 0.99,
    eps: float = 1e-3,
) -> UpdateRule:
    """FedAdam server rule [Reddi et al.]: server-side Adam with the FedOpt
    defaults (large eps, short second-moment horizon)."""
    return _named(adam(lr, b1=b1, b2=b2, eps=eps), "fedadam")


# ---------------------------------------------------------------------------
# Opt-state plumbing for the federated core
# ---------------------------------------------------------------------------

OPT_POLICIES = ("mix", "keep", "reset")


def init_opt_state(
    x0: PyTree,
    local_opt: Optional[UpdateRule] = None,
    server_opt: Optional[UpdateRule] = None,
) -> PyTree:
    """The ``opt`` slot algorithm states carry: ``()`` on the legacy path
    (no rules bound — zero leaves, bit-identical state), else a dict with the
    agent-stacked local-rule state and the (stacked-broadcast) server state."""
    if local_opt is None and server_opt is None:
        return ()
    if local_opt is None:
        # server rule alone: the round functions fall back to the default
        # sgd local rule; take its state from sgd itself so the two can
        # never drift apart structurally (the lr value is irrelevant here)
        local_opt = sgd(0.0)
    return {
        "local": local_opt.init(x0),
        "server": server_opt.init(x0) if server_opt is not None else (),
    }


def comm_opt_state(
    opt_state: PyTree,
    mix: Callable[[PyTree], PyTree],
    n_agents: int,
    policy: str,
    *,
    is_global: bool = False,
) -> PyTree:
    """Apply the declarative opt-state communication policy at a comm round.

    ``"mix"``  — every agent-stacked buffer moves through the same mixing
                 operator as the model (W on gossip rounds, J on server
                 rounds); scalar state (step counts) is untouched.
    ``"keep"`` — buffers stay local, always.
    ``"reset"``— buffers are zeroed when agents synchronize through the
                 server (server rounds only); step counts keep running.
    """
    if policy not in OPT_POLICIES:
        raise ValueError(f"opt policy {policy!r} not in {OPT_POLICIES}")
    if policy == "keep" or opt_state == ():
        return opt_state

    def stacked(v):
        return hasattr(v, "ndim") and v.ndim >= 1 and v.shape[0] == n_agents

    if policy == "reset":
        if not is_global:
            return opt_state
        return jax.tree.map(
            lambda v: jnp.zeros_like(v) if stacked(v) else v, opt_state
        )
    return jax.tree.map(lambda v: mix(v) if stacked(v) else v, opt_state)


def server_step(
    server_opt: UpdateRule,
    server_state: PyTree,
    avg_old: PyTree,
    avg_new: PyTree,
) -> Tuple[PyTree, PyTree]:
    """One FedOpt server update at a global-averaging round.

    The round's pseudo-gradient is ``avg_old - avg_new`` (both already pushed
    through the server's averaging operator, so partial participation prices
    in); the server rule descends from ``avg_old`` along it.  With
    ``server_opt = sgd(1.0)`` this recovers plain averaging (up to fp
    association), and ``sgd(eta_g)`` is the classic two-sided step size.
    """
    delta = jax.tree.map(lambda a, b: a - b, avg_old, avg_new)
    upd, server_state = server_opt.update(delta, server_state, avg_old)
    return apply_updates(avg_old, upd), server_state


# ---------------------------------------------------------------------------
# Declarative layer: strings -> rules (ExperimentSpec fields, CLI flags)
# ---------------------------------------------------------------------------

# name -> (constructor, default kwargs overriding the caller's fallback lr)
_RULE_TABLE = {
    "sgd": (sgd, {}),
    "momentum": (momentum, {}),
    "nesterov": (lambda lr, beta=0.9: momentum(lr, beta=beta, nesterov=True), {}),
    "adam": (adam, {}),
    "adamw": (adamw, {}),
    "fedavgm": (fedavgm, {"lr": 1.0}),
    "fedadam": (fedadam, {"lr": 0.1}),
}
# lr-free transformations allowed in non-final chain positions
_TRANSFORM_TABLE = {
    "clip": (clip_by_global_norm, "max_norm"),
}

RULE_NAMES = tuple(sorted(_RULE_TABLE)) + tuple(sorted(_TRANSFORM_TABLE))


def _parse_args(argstr: str, positional: Optional[str] = None) -> dict:
    """``"0.9"`` (one positional) or ``"beta=0.9,lr=0.1"`` -> kwargs dict."""
    out = {}
    for part in filter(None, (s.strip() for s in argstr.split(","))):
        if "=" in part:
            k, v = part.split("=", 1)
            out[k.strip()] = float(v)
        elif positional is not None and positional not in out:
            out[positional] = float(part)
        else:
            raise ValueError(f"positional arg {part!r} needs a k=v form")
    return out


def parse_update_rule(
    spec: str, *, lr: Union[float, Schedule] = 1.0, force_lr: bool = False
) -> UpdateRule:
    """Build an :class:`UpdateRule` from its declarative string form.

    Grammar: ``part("|"part)*`` where each part is ``name[:args]``.  The
    final part must be a named rule (``sgd`` / ``momentum`` / ``nesterov`` /
    ``adam`` / ``adamw`` / ``fedavgm`` / ``fedadam``); earlier parts are
    lr-free transforms (``clip:<max_norm>``).  ``lr`` is the caller's
    fallback step size (``eta_l`` locally, 1.0 server-side), overridden by a
    rule's own default (``fedadam`` -> 0.1) or an explicit ``lr=`` arg —
    unless ``force_lr`` is set, which makes the caller's ``lr`` win (used
    when an active lr_schedule, already built on the spec's base LR, must
    not be shadowed by the string's ``lr=``)::

        "sgd"                     # the bit-exact legacy default
        "momentum:beta=0.9"
        "adam:lr=0.01,b2=0.99"
        "clip:1.0|momentum"       # global-norm clip, then momentum
    """
    parts = [p.strip() for p in spec.split("|") if p.strip()]
    if not parts:
        raise ValueError(f"empty update-rule spec {spec!r}")
    rules = []
    for i, part in enumerate(parts):
        name, _, argstr = part.partition(":")
        name = name.strip()
        last = i == len(parts) - 1
        if name in _TRANSFORM_TABLE:
            if last:
                raise ValueError(
                    f"{name!r} is a transform and cannot terminate the chain "
                    f"{spec!r}; end with one of {sorted(_RULE_TABLE)}"
                )
            ctor, positional = _TRANSFORM_TABLE[name]
            rules.append(ctor(**_parse_args(argstr, positional)))
        elif name in _RULE_TABLE:
            if not last:
                raise ValueError(
                    f"rule {name!r} must be the final part of {spec!r}"
                )
            ctor, defaults = _RULE_TABLE[name]
            kw = dict(defaults)
            kw.update(_parse_args(argstr, "lr"))
            if force_lr:
                kw["lr"] = lr
            else:
                kw.setdefault("lr", lr)
            rules.append(ctor(**kw))
        else:
            raise ValueError(
                f"unknown update rule {name!r}; options: {RULE_NAMES}"
            )
    rule = rules[0] if len(rules) == 1 else chain(*rules)
    return _named(rule, spec)


def _explicit_lr(spec: str) -> Optional[float]:
    """The ``lr`` the rule string itself pins (explicit ``lr=``/positional on
    the final part, or a preset default like fedadam's 0.1), if any."""
    last = spec.split("|")[-1].strip()
    name, _, argstr = last.partition(":")
    entry = _RULE_TABLE.get(name.strip())
    args = dict(entry[1]) if entry else {}
    try:
        args.update(_parse_args(argstr, "lr"))
    except ValueError:
        return None  # parse_update_rule will raise the real error
    return args.get("lr")


# lr-schedule string forms, over repro.optim.schedules
_SCHEDULE_NAMES = ("constant", "linear", "cosine", "warmup_cosine")


def make_lr_schedule(
    spec: Optional[str], base_lr: float, total_steps: int
) -> Union[float, Schedule]:
    """Per-round local-LR decay: ``spec`` is ``name[:k=v,...]`` over
    :mod:`repro.optim.schedules`, evaluated at the rule's local-step count
    (``rounds * (T_o + 1)`` total steps).  ``None``/``"constant"`` return the
    plain float so the bit-exact scalar path stays in force."""
    if spec is None:
        return base_lr
    name, _, argstr = spec.partition(":")
    name = name.strip()
    if name == "constant":
        return base_lr
    args = _parse_args(argstr, "final")
    if name == "linear":
        return S.linear_decay(base_lr, total_steps, final=args.get("final", 0.0))
    if name == "cosine":
        return S.cosine_decay(base_lr, total_steps, final=args.get("final", 0.0))
    if name == "warmup_cosine":
        warmup = int(args.get("warmup", 0.1) * total_steps)
        return S.warmup_cosine(
            base_lr, warmup, total_steps, final=args.get("final", 0.0)
        )
    raise ValueError(
        f"unknown lr schedule {name!r}; options: {_SCHEDULE_NAMES}"
    )


def resolve_update_rules(
    optimizer: Optional[str] = None,
    server_optimizer: Optional[str] = None,
    lr_schedule: Optional[str] = None,
    opt_policy: Optional[str] = None,
    *,
    eta_l: float,
    rounds: int,
    t_o: int,
) -> dict:
    """``Algorithm.bind`` kwargs from the declarative optimizer fields — the
    one resolution point shared by ``ExperimentSpec`` and the launch CLI.
    Returns ``{}`` when everything is unset (the legacy hardcoded-SGD path)."""
    kw = {}
    if optimizer is not None or lr_schedule is not None:
        # an explicit lr= in the rule string is the schedule's base LR, and
        # the schedule (not the constant) drives the steps
        base = eta_l
        if optimizer is not None:
            explicit = _explicit_lr(optimizer)
            if explicit is not None:
                base = explicit
        lr = make_lr_schedule(lr_schedule, base, rounds * (t_o + 1))
        kw["local_opt"] = parse_update_rule(
            optimizer or "sgd", lr=lr, force_lr=lr_schedule is not None
        )
    if server_optimizer is not None:
        kw["server_opt"] = parse_update_rule(server_optimizer, lr=1.0)
    if opt_policy is not None:
        if opt_policy not in OPT_POLICIES:
            raise ValueError(
                f"opt policy {opt_policy!r} not in {OPT_POLICIES}"
            )
        kw["opt_policy"] = opt_policy
    return kw
