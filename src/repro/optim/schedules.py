"""Learning-rate schedules."""
from __future__ import annotations

import jax.numpy as jnp


def constant(value: float):
    def sched(count):
        return jnp.asarray(value, jnp.float32)

    return sched


def linear_decay(init: float, total_steps: int, final: float = 0.0):
    def sched(count):
        frac = jnp.clip(count.astype(jnp.float32) / total_steps, 0.0, 1.0)
        return init + (final - init) * frac

    return sched


def cosine_decay(init: float, total_steps: int, final: float = 0.0):
    def sched(count):
        frac = jnp.clip(count.astype(jnp.float32) / total_steps, 0.0, 1.0)
        return final + 0.5 * (init - final) * (1.0 + jnp.cos(jnp.pi * frac))

    return sched


def warmup_cosine(init: float, warmup_steps: int, total_steps: int, final: float = 0.0):
    cos = cosine_decay(init, max(1, total_steps - warmup_steps), final)

    def sched(count):
        c = count.astype(jnp.float32)
        warm = init * c / max(1, warmup_steps)
        return jnp.where(c < warmup_steps, warm, cos(count - warmup_steps))

    return sched
