"""Minimal optimizer library (no optax in this container).

PISCO's local phase is plain tracked-SGD by construction (eq. 3a uses the
tracker as the descent direction), but the framework also trains standard
synchronous baselines and the end-to-end LM examples — those use these
optimizers.  API mirrors optax: ``opt.init(params) -> state``,
``opt.update(grads, state, params) -> (updates, state)``, then
:func:`apply_updates`.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Tuple, Union

import jax
import jax.numpy as jnp

PyTree = Any
Schedule = Callable[[jnp.ndarray], jnp.ndarray]


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[PyTree], PyTree]
    update: Callable[[PyTree, PyTree, Optional[PyTree]], Tuple[PyTree, PyTree]]


def _lr_at(lr: Union[float, Schedule], count: jnp.ndarray) -> jnp.ndarray:
    return lr(count) if callable(lr) else jnp.asarray(lr)


def sgd(lr: Union[float, Schedule]) -> Optimizer:
    def init(params):
        return {"count": jnp.zeros((), jnp.int32)}

    def update(grads, state, params=None):
        step = _lr_at(lr, state["count"])
        updates = jax.tree.map(lambda g: -step * g, grads)
        return updates, {"count": state["count"] + 1}

    return Optimizer(init, update)


def momentum(lr: Union[float, Schedule], beta: float = 0.9, nesterov: bool = False) -> Optimizer:
    def init(params):
        return {
            "count": jnp.zeros((), jnp.int32),
            "mu": jax.tree.map(jnp.zeros_like, params),
        }

    def update(grads, state, params=None):
        mu = jax.tree.map(lambda m, g: beta * m + g, state["mu"], grads)
        if nesterov:
            eff = jax.tree.map(lambda m, g: beta * m + g, mu, grads)
        else:
            eff = mu
        step = _lr_at(lr, state["count"])
        updates = jax.tree.map(lambda m: -step * m, eff)
        return updates, {"count": state["count"] + 1, "mu": mu}

    return Optimizer(init, update)


def adam(
    lr: Union[float, Schedule],
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
) -> Optimizer:
    return adamw(lr, b1=b1, b2=b2, eps=eps, weight_decay=0.0)


def adamw(
    lr: Union[float, Schedule],
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
    weight_decay: float = 0.01,
) -> Optimizer:
    def init(params):
        return {
            "count": jnp.zeros((), jnp.int32),
            "m": jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params),
            "v": jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params),
        }

    def update(grads, state, params=None):
        count = state["count"] + 1
        m = jax.tree.map(
            lambda mm, g: b1 * mm + (1 - b1) * g.astype(jnp.float32), state["m"], grads
        )
        v = jax.tree.map(
            lambda vv, g: b2 * vv + (1 - b2) * jnp.square(g.astype(jnp.float32)),
            state["v"],
            grads,
        )
        c1 = 1.0 - b1 ** count.astype(jnp.float32)
        c2 = 1.0 - b2 ** count.astype(jnp.float32)
        step = _lr_at(lr, count)

        def upd(mm, vv, p):
            u = -step * (mm / c1) / (jnp.sqrt(vv / c2) + eps)
            if weight_decay and p is not None:
                u = u - step * weight_decay * p.astype(jnp.float32)
            return u

        if params is None:
            updates = jax.tree.map(lambda mm, vv: upd(mm, vv, None), m, v)
        else:
            updates = jax.tree.map(upd, m, v, params)
        return updates, {"count": count, "m": m, "v": v}

    return Optimizer(init, update)


def apply_updates(params: PyTree, updates: PyTree) -> PyTree:
    return jax.tree.map(lambda p, u: (p.astype(jnp.float32) + u).astype(p.dtype), params, updates)
