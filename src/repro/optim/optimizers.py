"""Back-compat optimizer names — now thin aliases over
:mod:`repro.optim.update_rules` (no optax in this container).

Historically this module carried its own ``Optimizer`` dataclass and a
duplicate of the LR-schedule plumbing; both now live in ``update_rules``:
``Optimizer`` *is* :class:`~repro.optim.update_rules.UpdateRule` (one
dataclass, one ``apply_updates``), and ``sgd`` / ``momentum`` / ``adam`` /
``adamw`` are the combinator-built aliases the federated core binds as local
and server rules.  Existing callers (`opt.init` / `opt.update` /
`apply_updates`) work unchanged.
"""
from __future__ import annotations

from repro.optim.update_rules import (
    UpdateRule,
    adam,
    adamw,
    apply_updates,
    momentum,
    sgd,
)

# The unified dataclass: one optimizer API for the LM examples and the
# federated round functions alike.
Optimizer = UpdateRule

__all__ = [
    "Optimizer",
    "UpdateRule",
    "sgd",
    "momentum",
    "adam",
    "adamw",
    "apply_updates",
]
