"""Fused quantize → mix → dequantize Pallas kernels for compressed gossip.

The compressed simulation path (repro.core.compression) evaluates, per leaf,

    q   = dequant(quant(x))          per-agent-row symmetric int grid
    out = x + W q - q                mean-preserving difference gossip

Unfused that is four HBM round trips over the agent-stacked state; the
kernels here do one pass per column block.  Same tiling discipline as
gt_update.py: arrays are processed as lane-aligned ``(rows, 128·c)`` tiles
with a padded tail, rows padded to the fp32 sublane multiple.  The agent
axis (rows) is small, so W lives whole in VMEM and the ``W q`` contraction
hits the MXU.

Per-row scales must see the *entire* row, which a column-blocked grid can't,
so quantization is two-phase: a max-reduction kernel accumulates row scales
across column blocks (grid-carried VMEM accumulator), then the fused kernel
quantizes, mixes, and combines in one pass.  Rounding is deterministic
round-to-nearest — bit-matching `kernels/ref.py` and the ``stochastic=False``
compressor — so parity tests hold to fp32 exactness.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANE = 128
SUBLANE = 8
COL_BLOCK = 512  # lanes per grid step; multiple of LANE


def _qmax(bits: int) -> float:
    assert bits in (4, 8), "int8 / int4 wire formats only"
    return float(2 ** (bits - 1) - 1)


def _pad2d(x: jnp.ndarray, col_multiple: int) -> Tuple[jnp.ndarray, int, int]:
    """Pad (n, d) to (sublane-multiple, col_multiple-multiple) with zeros."""
    n, d = x.shape
    np_ = -(-n // SUBLANE) * SUBLANE
    dp = -(-d // col_multiple) * col_multiple
    if (np_, dp) != (n, d):
        x = jnp.pad(x, ((0, np_ - n), (0, dp - d)))
    return x, n, d


def _row_absmax_kernel(x_ref, o_ref):
    j = pl.program_id(0)
    m = jnp.max(jnp.abs(x_ref[...].astype(jnp.float32)), axis=1, keepdims=True)
    m = jnp.broadcast_to(m, o_ref.shape)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = m

    @pl.when(j > 0)
    def _acc():
        o_ref[...] = jnp.maximum(o_ref[...], m)


def _quant_dequant_kernel(x_ref, s_ref, o_ref, *, qmax):
    x = x_ref[...].astype(jnp.float32)
    scale = jnp.maximum(s_ref[:, :1].astype(jnp.float32), 1e-12) / qmax
    q = jnp.clip(jnp.round(x / scale), -qmax, qmax)
    o_ref[...] = (q * scale).astype(o_ref.dtype)


def _compressed_mix_kernel(x_ref, w_ref, s_ref, o_ref, *, qmax):
    x = x_ref[...].astype(jnp.float32)
    scale = jnp.maximum(s_ref[:, :1].astype(jnp.float32), 1e-12) / qmax
    q = jnp.clip(jnp.round(x / scale), -qmax, qmax) * scale
    mixed = jnp.dot(
        w_ref[...].astype(jnp.float32), q, preferred_element_type=jnp.float32
    )
    o_ref[...] = (x + mixed - q).astype(o_ref.dtype)


def _row_scales(xp: jnp.ndarray, cb: int, interpret: bool) -> jnp.ndarray:
    """(rows, LANE) array whose every lane holds the row's abs-max."""
    rows, dp = xp.shape
    grid = (dp // cb,)
    return pl.pallas_call(
        _row_absmax_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((rows, cb), lambda j: (0, j))],
        out_specs=pl.BlockSpec((rows, LANE), lambda j: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, LANE), jnp.float32),
        interpret=interpret,
    )(xp)


def rowwise_quant_dequant(
    x: jnp.ndarray, *, bits: int = 8, interpret: bool = False
) -> jnp.ndarray:
    """Dequantized round-trip of a per-agent-row symmetric quantizer.

    ``x`` is (n_agents, d); matches ``rowwise_quant_dequant_ref`` and the
    deterministic ``StochasticQuantizer`` bit-for-bit.
    """
    qm = _qmax(bits)
    xp, n, d = _pad2d(x, LANE)
    rows, dp = xp.shape
    cb = min(COL_BLOCK, dp)
    xp, _, _ = _pad2d(xp, cb)
    dp = xp.shape[1]
    scales = _row_scales(xp, cb, interpret)
    out = pl.pallas_call(
        functools.partial(_quant_dequant_kernel, qmax=qm),
        grid=(dp // cb,),
        in_specs=[
            pl.BlockSpec((rows, cb), lambda j: (0, j)),
            pl.BlockSpec((rows, LANE), lambda j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((rows, cb), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct(xp.shape, x.dtype),
        interpret=interpret,
    )(xp, scales)
    return out[:n, :d]


def fused_compressed_mix(
    x: jnp.ndarray, w: jnp.ndarray, *, bits: int = 8, interpret: bool = False
) -> jnp.ndarray:
    """One-pass ``x + W·q(x) − q(x)`` with per-row int-``bits`` quantization.

    ``x`` is (n_agents, d) agent-stacked state, ``w`` the (n, n) doubly
    stochastic mixing matrix.  The quantized payload never round-trips
    through HBM: scale application, the MXU contraction with W, and the
    difference combine happen in VMEM per column block.
    """
    qm = _qmax(bits)
    n, d = x.shape
    assert w.shape == (n, n), f"w {w.shape} vs x {x.shape}"
    xp, _, _ = _pad2d(x, LANE)
    rows, dp = xp.shape
    cb = min(COL_BLOCK, dp)
    xp, _, _ = _pad2d(xp, cb)
    dp = xp.shape[1]
    wp = jnp.zeros((rows, rows), jnp.float32).at[:n, :n].set(w.astype(jnp.float32))
    scales = _row_scales(xp, cb, interpret)
    out = pl.pallas_call(
        functools.partial(_compressed_mix_kernel, qmax=qm),
        grid=(dp // cb,),
        in_specs=[
            pl.BlockSpec((rows, cb), lambda j: (0, j)),
            pl.BlockSpec((rows, rows), lambda j: (0, 0)),
            pl.BlockSpec((rows, LANE), lambda j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((rows, cb), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct(xp.shape, x.dtype),
        interpret=interpret,
    )(xp, wp, scales)
    return out[:n, :d]
