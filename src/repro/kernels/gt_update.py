"""Fused PISCO state updates as Pallas kernels.

The PISCO inner loop is memory-bound elementwise arithmetic over the full
parameter/tracker/gradient state (3× model size per agent).  Unfused, each
round reads/writes these arrays several times; the two kernels here do one
pass each:

* ``fused_local_step``   — eq. (3a)+(3c):  x' = x - η_l·y ; y' = y + g⁺ - g⁻
  (4 reads, 2 writes instead of 6 reads, 2 writes + intermediate traffic).
* ``fused_mix_combine``  — eq. (4a) candidate + ring-gossip weighted combine:
  out = w_s·u + w_l·left + w_r·right  with  u = (1-η_c)·x_k + η_c·(x_to - η_l·y_to)
  fused so the mixing candidate never round-trips through HBM.

Arrays are processed as flattened (rows, 128) tiles (lane-aligned); the ops
wrapper pads the tail.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANE = 128
ROW_BLOCK = 256


def _local_step_kernel(x_ref, y_ref, gn_ref, go_ref, xo_ref, yo_ref, *, eta_l):
    x = x_ref[...].astype(jnp.float32)
    y = y_ref[...].astype(jnp.float32)
    gn = gn_ref[...].astype(jnp.float32)
    go = go_ref[...].astype(jnp.float32)
    xo_ref[...] = (x - eta_l * y).astype(xo_ref.dtype)
    yo_ref[...] = (y + gn - go).astype(yo_ref.dtype)


def _mix_combine_kernel(
    xk_ref, xto_ref, yto_ref, left_ref, right_ref, o_ref,
    *, eta_c, eta_l, w_self, w_left, w_right,
):
    cand = (1.0 - eta_c) * xk_ref[...].astype(jnp.float32) + eta_c * (
        xto_ref[...].astype(jnp.float32) - eta_l * yto_ref[...].astype(jnp.float32)
    )
    out = (
        w_self * cand
        + w_left * left_ref[...].astype(jnp.float32)
        + w_right * right_ref[...].astype(jnp.float32)
    )
    o_ref[...] = out.astype(o_ref.dtype)


def _tile(arr: jnp.ndarray) -> Tuple[jnp.ndarray, int]:
    """Flatten + pad to (rows, LANE)."""
    flat = arr.reshape(-1)
    n = flat.shape[0]
    rows = -(-n // LANE)
    pad = rows * LANE - n
    if pad:
        flat = jnp.pad(flat, (0, pad))
    return flat.reshape(rows, LANE), n


def _untile(tiled: jnp.ndarray, n: int, shape, dtype) -> jnp.ndarray:
    return tiled.reshape(-1)[:n].reshape(shape).astype(dtype)


def fused_local_step(
    x: jnp.ndarray, y: jnp.ndarray, g_new: jnp.ndarray, g_old: jnp.ndarray,
    eta_l: float, *, interpret: bool = False,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    xt, n = _tile(x)
    yt, _ = _tile(y)
    gnt, _ = _tile(g_new)
    got, _ = _tile(g_old)
    rows = xt.shape[0]
    rb = min(ROW_BLOCK, rows)
    grid = (-(-rows // rb),)
    # pad rows to a block multiple
    rpad = grid[0] * rb - rows
    if rpad:
        xt, yt, gnt, got = (jnp.pad(t, ((0, rpad), (0, 0))) for t in (xt, yt, gnt, got))
    spec = pl.BlockSpec((rb, LANE), lambda i: (i, 0))
    xo, yo = pl.pallas_call(
        functools.partial(_local_step_kernel, eta_l=eta_l),
        grid=grid,
        in_specs=[spec] * 4,
        out_specs=[spec, spec],
        out_shape=[jax.ShapeDtypeStruct(xt.shape, x.dtype)] * 2,
        interpret=interpret,
    )(xt, yt, gnt, got)
    return _untile(xo, n, x.shape, x.dtype), _untile(yo, n, y.shape, y.dtype)


def fused_mix_combine(
    x_k: jnp.ndarray, x_to: jnp.ndarray, y_to: jnp.ndarray,
    left: jnp.ndarray, right: jnp.ndarray,
    *, eta_c: float, eta_l: float,
    w_self: float, w_left: float, w_right: float,
    interpret: bool = False,
) -> jnp.ndarray:
    xkt, n = _tile(x_k)
    tiles = [xkt] + [_tile(t)[0] for t in (x_to, y_to, left, right)]
    rows = xkt.shape[0]
    rb = min(ROW_BLOCK, rows)
    grid = (-(-rows // rb),)
    rpad = grid[0] * rb - rows
    if rpad:
        tiles = [jnp.pad(t, ((0, rpad), (0, 0))) for t in tiles]
    spec = pl.BlockSpec((rb, LANE), lambda i: (i, 0))
    out = pl.pallas_call(
        functools.partial(
            _mix_combine_kernel,
            eta_c=eta_c, eta_l=eta_l,
            w_self=w_self, w_left=w_left, w_right=w_right,
        ),
        grid=grid,
        in_specs=[spec] * 5,
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct(tiles[0].shape, x_k.dtype),
        interpret=interpret,
    )(*tiles)
    return _untile(out, n, x_k.shape, x_k.dtype)
