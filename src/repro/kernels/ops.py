"""jit'd public wrappers around the Pallas kernels.

``interpret`` defaults to True on CPU backends (this container) and False on
real TPUs, overridable via REPRO_PALLAS_INTERPRET=0/1.
"""
from __future__ import annotations

import functools
import os
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import flash_attention as _fa
from repro.kernels import gt_update as _gt
from repro.kernels import quantize as _qz
from repro.kernels import ssd_scan as _ssd


def _default_interpret() -> bool:
    env = os.environ.get("REPRO_PALLAS_INTERPRET")
    if env is not None:
        return env not in ("0", "false", "False")
    return jax.default_backend() != "tpu"


@functools.partial(
    jax.jit, static_argnames=("causal", "window", "block_q", "block_k", "interpret")
)
def flash_attention(
    q, k, v, *, causal: bool = True, window: Optional[int] = None,
    block_q: int = 128, block_k: int = 128, interpret: Optional[bool] = None,
):
    """q (B,Hq,Sq,D), k/v (B,Hkv,Sk,D) -> (B,Hq,Sq,D)."""
    interp = _default_interpret() if interpret is None else interpret
    return _fa.flash_attention(
        q, k, v, causal=causal, window=window,
        block_q=block_q, block_k=block_k, interpret=interp,
    )


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(
    x, dt, a, b_mat, c_mat, *, chunk: int = 128, interpret: Optional[bool] = None
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """SSD over (B,L,H,P) with state (B,H,P,N); returns (y, final_state)."""
    interp = _default_interpret() if interpret is None else interpret
    return _ssd.ssd_scan_kernel(
        x, dt, a, b_mat, c_mat, chunk=chunk, interpret=interp
    )


@functools.partial(jax.jit, static_argnames=("eta_l", "interpret"))
def fused_local_step(x, y, g_new, g_old, *, eta_l: float, interpret: Optional[bool] = None):
    interp = _default_interpret() if interpret is None else interpret
    return _gt.fused_local_step(x, y, g_new, g_old, eta_l, interpret=interp)


@functools.partial(
    jax.jit,
    static_argnames=("eta_c", "eta_l", "w_self", "w_left", "w_right", "interpret"),
)
def fused_mix_combine(
    x_k, x_to, y_to, left, right, *,
    eta_c: float, eta_l: float, w_self: float, w_left: float, w_right: float,
    interpret: Optional[bool] = None,
):
    interp = _default_interpret() if interpret is None else interpret
    return _gt.fused_mix_combine(
        x_k, x_to, y_to, left, right,
        eta_c=eta_c, eta_l=eta_l,
        w_self=w_self, w_left=w_left, w_right=w_right,
        interpret=interp,
    )


@functools.partial(jax.jit, static_argnames=("bits", "interpret"))
def rowwise_quant_dequant(x, *, bits: int = 8, interpret: Optional[bool] = None):
    """Per-agent-row int quantizer round trip over (n_agents, d)."""
    interp = _default_interpret() if interpret is None else interpret
    return _qz.rowwise_quant_dequant(x, bits=bits, interpret=interp)


@functools.partial(jax.jit, static_argnames=("bits", "interpret"))
def fused_compressed_mix(x, w, *, bits: int = 8, interpret: Optional[bool] = None):
    """Fused quantize → mix → dequantize:  x + W·q(x) − q(x)."""
    interp = _default_interpret() if interpret is None else interpret
    return _qz.fused_compressed_mix(x, w, bits=bits, interpret=interp)
