"""Pure-jnp oracles for every Pallas kernel (the allclose references).

These are deliberately naive/direct implementations — clarity over speed —
used by tests/test_kernels.py to validate the kernels across shape/dtype
sweeps in interpret mode.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def flash_attention_ref(
    q: jnp.ndarray,  # (B, Hq, Sq, D)
    k: jnp.ndarray,  # (B, Hkv, Sk, D)
    v: jnp.ndarray,  # (B, Hkv, Sk, D)
    *,
    causal: bool = True,
    window: Optional[int] = None,
) -> jnp.ndarray:
    b, hq, sq, d = q.shape
    hkv = k.shape[1]
    g = hq // hkv
    kf = jnp.repeat(k, g, axis=1).astype(jnp.float32)
    vf = jnp.repeat(v, g, axis=1).astype(jnp.float32)
    scores = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), kf) / math.sqrt(d)
    qpos = jnp.arange(sq)[:, None]
    kpos = jnp.arange(k.shape[2])[None, :]
    mask = jnp.ones((sq, k.shape[2]), bool)
    if causal:
        mask = mask & (kpos <= qpos)
    if window is not None:
        mask = mask & (kpos > qpos - window)
    scores = jnp.where(mask[None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", probs, vf)
    return out.astype(q.dtype)


def ssd_scan_ref(
    x: jnp.ndarray,  # (B, L, H, P)
    dt: jnp.ndarray,  # (B, L, H) positive
    a: jnp.ndarray,  # (H,) negative
    b_mat: jnp.ndarray,  # (B, L, G, N)
    c_mat: jnp.ndarray,  # (B, L, G, N)
    h0: Optional[jnp.ndarray] = None,  # (B, H, P, N)
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Direct O(L) recurrence — the semantic ground truth of SSD."""
    bsz, l, h, p = x.shape
    g, n = b_mat.shape[2:]
    rep = h // g
    state = (
        jnp.zeros((bsz, h, p, n), jnp.float32) if h0 is None else h0.astype(jnp.float32)
    )

    def step(carry, t_in):
        x_t, dt_t, b_t, c_t = t_in  # (B,H,P), (B,H), (B,G,N), (B,G,N)
        b_h = jnp.repeat(b_t, rep, axis=1)
        c_h = jnp.repeat(c_t, rep, axis=1)
        decay = jnp.exp(dt_t * a[None, :])
        upd = (dt_t[..., None] * x_t)[..., :, None] * b_h[:, :, None, :]
        new = carry * decay[..., None, None] + upd
        y = jnp.einsum("bhpn,bhn->bhp", new, c_h)
        return new, y

    final, ys = jax.lax.scan(
        step,
        state,
        (
            jnp.moveaxis(x.astype(jnp.float32), 1, 0),
            jnp.moveaxis(dt.astype(jnp.float32), 1, 0),
            jnp.moveaxis(b_mat.astype(jnp.float32), 1, 0),
            jnp.moveaxis(c_mat.astype(jnp.float32), 1, 0),
        ),
    )
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype), final


def fused_local_step_ref(
    x: jnp.ndarray, y: jnp.ndarray, g_new: jnp.ndarray, g_old: jnp.ndarray, eta_l: float
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """PISCO eq. (3a)+(3c) fused:  x' = x - eta_l*y ;  y' = y + g_new - g_old."""
    return x - eta_l * y, y + g_new - g_old


def mix_combine_ref(
    x_k: jnp.ndarray,
    x_to: jnp.ndarray,
    y_to: jnp.ndarray,
    eta_c: float,
    eta_l: float,
) -> jnp.ndarray:
    """PISCO eq. (4a) pre-mix candidate: (1-eta_c)·x_k + eta_c·(x_to - eta_l·y_to)."""
    return (1.0 - eta_c) * x_k + eta_c * (x_to - eta_l * y_to)


def neighbor_combine_ref(
    self_x: jnp.ndarray,
    left: jnp.ndarray,
    right: jnp.ndarray,
    w_self: float,
    w_left: float,
    w_right: float,
) -> jnp.ndarray:
    """Post-ppermute ring-gossip weighted combine."""
    return w_self * self_x + w_left * left + w_right * right


def rowwise_quant_dequant_ref(x: jnp.ndarray, bits: int) -> jnp.ndarray:
    """Per-agent-row symmetric quantizer round trip, round-to-nearest.

    x: (n_agents, d).  Identical math to the deterministic path of
    ``repro.core.compression.StochasticQuantizer``.
    """
    qmax = float(2 ** (bits - 1) - 1)
    xf = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(xf), axis=1, keepdims=True), 1e-12) / qmax
    q = jnp.clip(jnp.round(xf / scale), -qmax, qmax)
    return (q * scale).astype(x.dtype)


def compressed_mix_ref(x: jnp.ndarray, w: jnp.ndarray, bits: int) -> jnp.ndarray:
    """Mean-preserving compressed gossip:  x + W·q(x) − q(x)."""
    q = rowwise_quant_dequant_ref(x, bits).astype(jnp.float32)
    xf = x.astype(jnp.float32)
    return (xf + w.astype(jnp.float32) @ q - q).astype(x.dtype)


def sparse_mix_ref(
    x: jnp.ndarray,
    senders: jnp.ndarray,
    receivers: jnp.ndarray,
    edge_w: jnp.ndarray,
    self_w: jnp.ndarray,
) -> jnp.ndarray:
    """Edge-list gossip:  out_i = self_w_i·x_i + Σ_{e: s_e→i} w_e·x_{s_e}."""
    xf = x.astype(jnp.float32)
    contrib = edge_w.astype(jnp.float32)[:, None] * xf[senders]
    acc = jax.ops.segment_sum(contrib, receivers, num_segments=x.shape[0])
    return (self_w.astype(jnp.float32)[:, None] * xf + acc).astype(x.dtype)


def sparse_compressed_mix_ref(
    x: jnp.ndarray,
    senders: jnp.ndarray,
    receivers: jnp.ndarray,
    edge_w: jnp.ndarray,
    self_w: jnp.ndarray,
    bits: int,
    gamma: float = 1.0,
) -> jnp.ndarray:
    """Mean-preserving compressed gossip over an edge list:
    x + γ·(W·q(x) − q(x)) with the implicit sparse W."""
    q = rowwise_quant_dequant_ref(x, bits)
    mixed = sparse_mix_ref(q, senders, receivers, edge_w, self_w).astype(
        jnp.float32
    )
    xf = x.astype(jnp.float32)
    return (xf + gamma * (mixed - q.astype(jnp.float32))).astype(x.dtype)
