"""Fused sparse-gossip Pallas kernels: scatter-accumulate over edge blocks.

The sparse mixing path (repro.core.mixing.sparse_mixing) evaluates, per leaf,

    out_i = self_w[i] * x_i + sum_{e : senders[e] -> i} edge_w[e] * x_send

and its compressed form dequant -> scatter-accumulate -> combine,

    q   = dequant(quant(x))            per-agent-row symmetric int grid
    out = x + gamma * (W q - q)        mean-preserving difference gossip

where the implicit ``W q`` is the same per-edge gather/scatter.  Unfused the
compressed form round-trips the quantized payload through HBM; the kernels
here do one pass per column block, accumulating edge contributions across a
second (innermost) grid axis into a VMEM-resident output block.

Tiling follows quantize.py: lane-aligned ``(rows, 128·c)`` tiles with padded
tails, per-row quantization scales computed by the shared two-phase
max-reduction.  Edge arrays are padded to an EDGE_BLOCK multiple with weight-0
sentinel edges (sender = receiver = 0), which contribute exactly nothing.
Rounding is deterministic round-to-nearest — bit-matching ``kernels/ref.py``
and the ``stochastic=False`` compressor.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.kernels.quantize import (
    COL_BLOCK,
    LANE,
    _pad2d,
    _qmax,
    _row_scales,
)

EDGE_BLOCK = 512  # directed edges processed per grid step


def _pad_edges(
    senders: jnp.ndarray, receivers: jnp.ndarray, edge_w: jnp.ndarray
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, int]:
    """Pad directed-edge arrays to an EDGE_BLOCK multiple and reshape to
    (n_blocks, EDGE_BLOCK) so a BlockSpec can slice one block per grid step.
    Padding edges carry weight 0 into row 0 — a no-op contribution."""
    e = int(senders.shape[0])
    ep = max(EDGE_BLOCK, -(-e // EDGE_BLOCK) * EDGE_BLOCK)
    pad = ep - e
    if pad:
        senders = jnp.pad(senders, (0, pad))
        receivers = jnp.pad(receivers, (0, pad))
        edge_w = jnp.pad(edge_w, (0, pad))
    nb = ep // EDGE_BLOCK
    return (
        senders.reshape(nb, EDGE_BLOCK),
        receivers.reshape(nb, EDGE_BLOCK),
        edge_w.reshape(nb, EDGE_BLOCK),
        nb,
    )


def _sparse_mix_kernel(x_ref, send_ref, recv_ref, ew_ref, sw_ref, o_ref):
    e = pl.program_id(1)
    x = x_ref[...].astype(jnp.float32)

    @pl.when(e == 0)
    def _init():
        o_ref[...] = sw_ref[:, :1].astype(jnp.float32) * x

    send = send_ref[0]
    recv = recv_ref[0]
    w = ew_ref[0].astype(jnp.float32)
    contrib = w[:, None] * x[send]
    o_ref[...] += jnp.zeros_like(x).at[recv].add(contrib)


def _sparse_compressed_mix_kernel(
    x_ref, send_ref, recv_ref, ew_ref, sw_ref, s_ref, o_ref, *, qmax, gamma
):
    e = pl.program_id(1)
    x = x_ref[...].astype(jnp.float32)
    scale = jnp.maximum(s_ref[:, :1].astype(jnp.float32), 1e-12) / qmax
    q = jnp.clip(jnp.round(x / scale), -qmax, qmax) * scale

    @pl.when(e == 0)
    def _init():
        sw = sw_ref[:, :1].astype(jnp.float32)
        o_ref[...] = x + gamma * (sw - 1.0) * q

    send = send_ref[0]
    recv = recv_ref[0]
    w = ew_ref[0].astype(jnp.float32)
    contrib = w[:, None] * q[send]
    o_ref[...] += gamma * jnp.zeros_like(x).at[recv].add(contrib)


def _prep(x: jnp.ndarray):
    """Lane/sublane-pad ``x`` and pick the column block size."""
    xp, n, d = _pad2d(x, LANE)
    cb = min(COL_BLOCK, xp.shape[1])
    xp, _, _ = _pad2d(xp, cb)
    return xp, n, d, cb


def _sw2d(self_w: jnp.ndarray, rows: int) -> jnp.ndarray:
    """(rows, LANE) tile holding the per-row self weight in every lane
    (padded rows hold 0 — their x rows are zero anyway)."""
    sw = jnp.zeros(rows, jnp.float32).at[: self_w.shape[0]].set(
        self_w.astype(jnp.float32)
    )
    return jnp.broadcast_to(sw[:, None], (rows, LANE))


def sparse_mix(
    x: jnp.ndarray,
    senders: jnp.ndarray,
    receivers: jnp.ndarray,
    edge_w: jnp.ndarray,
    self_w: jnp.ndarray,
    *,
    interpret: bool = False,
) -> jnp.ndarray:
    """Edge-list gossip ``out_i = self_w_i x_i + sum_e w_e x_send`` fused in
    one pass per column block.

    ``x`` is (n_agents, d); ``senders``/``receivers``/``edge_w`` are the
    directed edge arrays (both orientations of each undirected edge).
    Matches ``kernels.ref.sparse_mix_ref`` to fp32 exactness.
    """
    n_total, _ = x.shape
    xp, n, d, cb = _prep(x)
    rows, dp = xp.shape
    send_b, recv_b, ew_b, nb = _pad_edges(
        jnp.asarray(senders, jnp.int32),
        jnp.asarray(receivers, jnp.int32),
        jnp.asarray(edge_w, jnp.float32),
    )
    sw = _sw2d(self_w, rows)
    out = pl.pallas_call(
        _sparse_mix_kernel,
        grid=(dp // cb, nb),
        in_specs=[
            pl.BlockSpec((rows, cb), lambda j, e: (0, j)),
            pl.BlockSpec((1, EDGE_BLOCK), lambda j, e: (e, 0)),
            pl.BlockSpec((1, EDGE_BLOCK), lambda j, e: (e, 0)),
            pl.BlockSpec((1, EDGE_BLOCK), lambda j, e: (e, 0)),
            pl.BlockSpec((rows, LANE), lambda j, e: (0, 0)),
        ],
        out_specs=pl.BlockSpec((rows, cb), lambda j, e: (0, j)),
        out_shape=jax.ShapeDtypeStruct((rows, dp), jnp.float32),
        interpret=interpret,
    )(xp, send_b, recv_b, ew_b, sw)
    return out[:n, :d].astype(x.dtype)


def sparse_compressed_mix(
    x: jnp.ndarray,
    senders: jnp.ndarray,
    receivers: jnp.ndarray,
    edge_w: jnp.ndarray,
    self_w: jnp.ndarray,
    *,
    bits: int = 8,
    gamma: float = 1.0,
    interpret: bool = False,
) -> jnp.ndarray:
    """One-pass ``x + gamma (W q(x) - q(x))`` over an edge list: per-row
    int-``bits`` dequant, per-edge-block scatter-accumulate, difference
    combine — the quantized payload never round-trips through HBM.

    Matches ``kernels.ref.sparse_compressed_mix_ref`` to fp32 exactness.
    """
    qm = _qmax(bits)
    xp, n, d, cb = _prep(x)
    rows, dp = xp.shape
    send_b, recv_b, ew_b, nb = _pad_edges(
        jnp.asarray(senders, jnp.int32),
        jnp.asarray(receivers, jnp.int32),
        jnp.asarray(edge_w, jnp.float32),
    )
    sw = _sw2d(self_w, rows)
    scales = _row_scales(xp, cb, interpret)
    out = pl.pallas_call(
        functools.partial(_sparse_compressed_mix_kernel, qmax=qm, gamma=gamma),
        grid=(dp // cb, nb),
        in_specs=[
            pl.BlockSpec((rows, cb), lambda j, e: (0, j)),
            pl.BlockSpec((1, EDGE_BLOCK), lambda j, e: (e, 0)),
            pl.BlockSpec((1, EDGE_BLOCK), lambda j, e: (e, 0)),
            pl.BlockSpec((1, EDGE_BLOCK), lambda j, e: (e, 0)),
            pl.BlockSpec((rows, LANE), lambda j, e: (0, 0)),
            pl.BlockSpec((rows, LANE), lambda j, e: (0, 0)),
        ],
        out_specs=pl.BlockSpec((rows, cb), lambda j, e: (0, j)),
        out_shape=jax.ShapeDtypeStruct((rows, dp), jnp.float32),
        interpret=interpret,
    )(xp, send_b, recv_b, ew_b, sw, scales)
    return out[:n, :d].astype(x.dtype)


def topology_edge_arrays(topo) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Directed ``(senders, receivers, edge_w)`` for a SparseTopology —
    convenience for feeding :func:`sparse_mix` straight from a topology."""
    e = topo.edges
    if len(e) == 0:
        z = np.zeros(0, dtype=np.int32)
        return z, z.copy(), np.zeros(0, dtype=np.float32)
    senders = np.concatenate([e[:, 0], e[:, 1]]).astype(np.int32)
    receivers = np.concatenate([e[:, 1], e[:, 0]]).astype(np.int32)
    edge_w = np.concatenate([topo.edge_weight, topo.edge_weight]).astype(
        np.float32
    )
    return senders, receivers, edge_w
