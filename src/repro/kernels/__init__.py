"""Pallas TPU kernels for the compute hot spots (validated interpret=True on
CPU): flash_attention (prefill/train attention), ssd_scan (Mamba-2 chunked
scan), gt_update (fused PISCO local-step / mix-combine elementwise passes),
quantize (fused quantize→mix→dequantize for compressed gossip).

The paper itself has no kernel-level contribution (its contribution is the
communication protocol); these kernels target the workloads PISCO trains plus
PISCO's own memory-bound state updates.  ops.py holds the jit'd wrappers,
ref.py the pure-jnp oracles.
"""
from repro.kernels import ops, ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.gt_update import fused_local_step, fused_mix_combine
from repro.kernels.quantize import fused_compressed_mix, rowwise_quant_dequant
from repro.kernels.ssd_scan import ssd_scan_kernel

__all__ = [
    "ops", "ref", "flash_attention", "fused_local_step",
    "fused_mix_combine", "fused_compressed_mix", "rowwise_quant_dequant",
    "ssd_scan_kernel",
]
