"""Pallas TPU kernels for the compute hot spots (validated interpret=True on
CPU): flash_attention (prefill/train attention), ssd_scan (Mamba-2 chunked
scan), gt_update (fused PISCO local-step / mix-combine elementwise passes),
quantize (fused quantize→mix→dequantize for compressed gossip), sparse_mix
(edge-list gossip scatter-accumulate, plain and compressed).

The paper itself has no kernel-level contribution (its contribution is the
communication protocol); these kernels target the workloads PISCO trains plus
PISCO's own memory-bound state updates.  ops.py holds the jit'd wrappers,
ref.py the pure-jnp oracles.
"""
from repro.kernels import ops, ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.gt_update import fused_local_step, fused_mix_combine
from repro.kernels.quantize import fused_compressed_mix, rowwise_quant_dequant
from repro.kernels.sparse_mix import (
    sparse_compressed_mix,
    sparse_mix,
    topology_edge_arrays,
)
from repro.kernels.ssd_scan import ssd_scan_kernel

__all__ = [
    "ops", "ref", "flash_attention", "fused_local_step",
    "fused_mix_combine", "fused_compressed_mix", "rowwise_quant_dequant",
    "sparse_mix", "sparse_compressed_mix", "topology_edge_arrays",
    "ssd_scan_kernel",
]
