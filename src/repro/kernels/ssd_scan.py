"""Mamba-2 SSD chunked scan as a Pallas TPU kernel.

Grid ``(batch, heads, num_chunks)`` with the chunk axis innermost and
sequential: the inter-chunk SSM state ``(P, N)`` lives in fp32 VMEM scratch
and is carried across chunk steps — the TPU-native replacement for the
paper's GPU kernel, trading warp-level parallel prefix for the systolic
strengths of the MXU (the per-chunk work is 4 small matmuls on
(chunk × chunk/N/P)-shaped operands, all VMEM-resident).

Per chunk c with decays  a_t = dt_t · A_h  (negative):
  cum_t   = cumsum(a)                (within chunk)
  S_{ls}  = exp(cum_l - cum_s)·dt_s  for l >= s          (decay matrix)
  y_diag  = ((C Bᵀ) ⊙ S) x
  y_off   = exp(cum)_l · (C h_inᵀ)
  h_out   = exp(cum_L) h_in + Σ_s dt_s·exp(cum_L - cum_s)·x_s ⊗ B_s

Validated in interpret mode against :func:`repro.kernels.ref.ssd_scan_ref`
(the direct O(L) recurrence) and the chunked jnp reference in
``repro.models.mamba2``.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _ssd_kernel(
    x_ref,  # (1, cl, 1, P)
    dt_ref,  # (1, cl, 1)
    a_ref,  # (1,)
    b_ref,  # (1, cl, 1, N)
    c_ref,  # (1, cl, 1, N)
    y_ref,  # (1, cl, 1, P)
    hfin_ref,  # (1, 1, P, N)
    h_scr,  # (P, N) fp32 carried state
    *,
    cl: int,
    nc: int,
):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    x = x_ref[0, :, 0, :].astype(jnp.float32)  # (cl, P)
    dt = dt_ref[0, :, 0].astype(jnp.float32)  # (cl,)
    a = a_ref[0].astype(jnp.float32)  # scalar
    b = b_ref[0, :, 0, :].astype(jnp.float32)  # (cl, N)
    c = c_ref[0, :, 0, :].astype(jnp.float32)  # (cl, N)

    a_dt = dt * a  # (cl,) negative
    cum = jnp.cumsum(a_dt)  # (cl,)

    # decay matrix S[l, s] = exp(cum_l - cum_s) * dt_s   (l >= s)
    diff = cum[:, None] - cum[None, :]
    li = jax.lax.broadcasted_iota(jnp.int32, (cl, cl), 0)
    si = jax.lax.broadcasted_iota(jnp.int32, (cl, cl), 1)
    seg = jnp.where(li >= si, diff, NEG_INF)
    s_mat = jnp.exp(seg) * dt[None, :]

    h_in = h_scr[...]  # (P, N)

    scores = (c @ b.T) * s_mat  # (cl, cl)
    y_diag = scores @ x  # (cl, P)
    y_off = jnp.exp(cum)[:, None] * (c @ h_in.T)  # (cl, P)
    y_ref[0, :, 0, :] = (y_diag + y_off).astype(y_ref.dtype)

    # state update to the chunk boundary
    w = dt * jnp.exp(cum[-1] - cum)  # (cl,)
    h_new = jnp.exp(cum[-1]) * h_in + (x * w[:, None]).T @ b  # (P, N)
    h_scr[...] = h_new

    @pl.when(ci == nc - 1)
    def _finish():
        hfin_ref[0, 0, ...] = h_new


def ssd_scan_kernel(
    x: jnp.ndarray,  # (B, L, H, P)
    dt: jnp.ndarray,  # (B, L, H)
    a: jnp.ndarray,  # (H,)
    b_mat: jnp.ndarray,  # (B, L, G, N)
    c_mat: jnp.ndarray,  # (B, L, G, N)
    *,
    chunk: int = 128,
    interpret: bool = False,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    bsz, l, h, p = x.shape
    g, n = b_mat.shape[2:]
    assert h % g == 0
    group = h // g
    cl = min(chunk, l)
    assert l % cl == 0, f"seq {l} must divide chunk {cl}"
    nc = l // cl

    kernel = functools.partial(_ssd_kernel, cl=cl, nc=nc)
    grid = (bsz, h, nc)
    y, hfin = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, cl, 1, p), lambda bi, hi, ci: (bi, ci, hi, 0)),
            pl.BlockSpec((1, cl, 1), lambda bi, hi, ci: (bi, ci, hi)),
            pl.BlockSpec((1,), lambda bi, hi, ci: (hi,)),
            pl.BlockSpec((1, cl, 1, n), lambda bi, hi, ci: (bi, ci, hi // group, 0)),
            pl.BlockSpec((1, cl, 1, n), lambda bi, hi, ci: (bi, ci, hi // group, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, cl, 1, p), lambda bi, hi, ci: (bi, ci, hi, 0)),
            pl.BlockSpec((1, 1, p, n), lambda bi, hi, ci: (bi, hi, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, l, h, p), x.dtype),
            jax.ShapeDtypeStruct((bsz, h, p, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        interpret=interpret,
    )(x, dt, a, b_mat, c_mat)
    return y, hfin
