"""Flash attention as a Pallas TPU kernel (causal / sliding-window / GQA).

TPU-native design (vs. the CUDA original): the grid is
``(batch, q_heads, num_q_blocks, num_k_blocks)`` with the key-block axis
*innermost and sequential* — TPU grids execute in order on each core, so the
online-softmax running statistics (m, l, acc) live in VMEM scratch that
persists across the k-block steps of one q block.  Block sizes default to
(128, 128): MXU-aligned on the contraction dims.  GQA is handled in the
BlockSpec index maps (query head h reads kv head ``h // group``) so no
repeated K/V ever materializes in HBM.

Validated in interpret mode against :func:`repro.kernels.ref.flash_attention_ref`.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(
    q_ref,  # (1, 1, bq, d)
    k_ref,  # (1, 1, bk, d)
    v_ref,  # (1, 1, bk, d)
    o_ref,  # (1, 1, bq, d)
    m_scr,  # (bq,) running max
    l_scr,  # (bq,) running denom
    acc_scr,  # (bq, d) running numerator
    *,
    scale: float,
    bq: int,
    bk: int,
    nk: int,
    causal: bool,
    window: Optional[int],
):
    ki = pl.program_id(3)
    qi = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)  # (bq, d)
    k = k_ref[0, 0].astype(jnp.float32)  # (bk, d)
    v = v_ref[0, 0].astype(jnp.float32)

    s = (q @ k.T) * scale  # (bq, bk)

    q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = jnp.ones((bq, bk), jnp.bool_)
    if causal:
        mask = mask & (k_pos <= q_pos)
    if window is not None:
        mask = mask & (k_pos > q_pos - window)
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    p = jnp.exp(s - m_new[:, None])
    # guard fully-masked rows (all NEG_INF): exp(NEG_INF - NEG_INF) = 1 junk
    row_live = m_new > NEG_INF / 2
    p = jnp.where(row_live[:, None], p, 0.0)
    alpha = jnp.where(row_live, jnp.exp(m_prev - m_new), 1.0)

    l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=-1)
    acc_scr[...] = acc_scr[...] * alpha[:, None] + p @ v
    m_scr[...] = m_new

    @pl.when(ki == nk - 1)
    def _finish():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0, ...] = (acc_scr[...] / denom[:, None]).astype(o_ref.dtype)


def flash_attention(
    q: jnp.ndarray,  # (B, Hq, Sq, D)
    k: jnp.ndarray,  # (B, Hkv, Sk, D)
    v: jnp.ndarray,  # (B, Hkv, Sk, D)
    *,
    causal: bool = True,
    window: Optional[int] = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    b, hq, sq, d = q.shape
    _, hkv, sk, _ = k.shape
    assert hq % hkv == 0
    group = hq // hkv
    bq = min(block_q, sq)
    bk = min(block_k, sk)
    assert sq % bq == 0 and sk % bk == 0, "seq lengths must divide block sizes"
    nq, nk = sq // bq, sk // bk
    scale = 1.0 / math.sqrt(d)

    kernel = functools.partial(
        _flash_kernel,
        scale=scale,
        bq=bq,
        bk=bk,
        nk=nk,
        causal=causal,
        window=window,
    )
    grid = (b, hq, nq, nk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda bi, hi, qi, ki: (bi, hi // group, ki, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda bi, hi, qi, ki: (bi, hi // group, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, d), lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hq, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
