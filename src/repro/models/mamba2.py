"""Mamba-2 block with the SSD (state-space duality) chunked algorithm
(arXiv:2405.21060), pure JAX; the Pallas kernel in
``repro.kernels.ssd_scan`` is the TPU-target equivalent of the chunked scan
and is validated against :func:`ssd_reference` below.

Layout: heads H = d_inner / head_dim(P), groups G (B/C shared per group),
state size N.  Training/prefill uses the 4-step chunked SSD; decode carries
(conv window, SSM state) caches and costs O(1) per token — the reason the
``long_500k`` shape runs for SSM/hybrid archs.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.config import ModelConfig, SSMConfig
from repro.models.layers import KeyGen, normal_init, rms_norm

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------


def _dims(cfg: ModelConfig):
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    n_heads = d_in // s.head_dim
    conv_ch = d_in + 2 * s.n_groups * s.d_state
    return s, d_in, n_heads, conv_ch


def init_mamba2(kg: KeyGen, cfg: ModelConfig, dtype) -> Dict[str, Any]:
    s, d_in, n_heads, conv_ch = _dims(cfg)
    d = cfg.d_model
    sc = cfg.init_scale
    proj_out = 2 * d_in + 2 * s.n_groups * s.d_state + n_heads
    key_a = kg()
    a = jax.random.uniform(
        key_a, (n_heads,), minval=s.a_init_range[0], maxval=s.a_init_range[1]
    )
    # dt bias st. softplus(dt_bias) spans [dt_min, dt_max] log-uniformly
    key_dt = kg()
    dt = jnp.exp(
        jax.random.uniform(key_dt, (n_heads,))
        * (math.log(s.dt_max) - math.log(s.dt_min))
        + math.log(s.dt_min)
    )
    dt_bias = dt + jnp.log(-jnp.expm1(-dt))  # inverse softplus
    return {
        "in_proj": normal_init(kg(), (d, proj_out), sc, dtype),
        "conv_w": normal_init(kg(), (s.d_conv, conv_ch), 0.5 / math.sqrt(s.d_conv), dtype),
        "conv_b": jnp.zeros((conv_ch,), dtype),
        "a_log": jnp.log(a).astype(jnp.float32),
        "dt_bias": dt_bias.astype(jnp.float32),
        "d_skip": jnp.ones((n_heads,), jnp.float32),
        "norm": jnp.ones((d_in,), dtype),
        "out_proj": normal_init(
            kg(), (d_in, d), sc / math.sqrt(2 * cfg.n_layers), dtype
        ),
    }


def spec_mamba2(cfg: ModelConfig, model_axis: str = "model") -> Dict[str, Any]:
    mp = model_axis
    return {
        "in_proj": P(None, mp),
        "conv_w": P(None, mp),
        "conv_b": P(mp),
        "a_log": P(None),
        "dt_bias": P(None),
        "d_skip": P(None),
        "norm": P(mp),
        "out_proj": P(mp, None),
    }


# ---------------------------------------------------------------------------
# SSD chunked algorithm (reference; kernels/ssd_scan mirrors it)
# ---------------------------------------------------------------------------


def segsum(a: jnp.ndarray) -> jnp.ndarray:
    """Segment-sum: out[..., i, j] = sum_{k=j+1..i} a[..., k], -inf for j > i."""
    l = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    idx = jnp.arange(l)
    return jnp.where(idx[:, None] >= idx[None, :], diff, NEG_INF)


def ssd_reference(
    x: jnp.ndarray,  # (B, L, H, P)
    dt: jnp.ndarray,  # (B, L, H)  (already softplus'ed, positive)
    a: jnp.ndarray,  # (H,)       (negative; A = -exp(a_log))
    b_mat: jnp.ndarray,  # (B, L, G, N)
    c_mat: jnp.ndarray,  # (B, L, G, N)
    chunk: int,
    h0: jnp.ndarray = None,  # (B, H, P, N) initial state
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Chunked SSD; returns (y (B,L,H,P), final_state (B,H,P,N))."""
    bsz, l_orig, h, p = x.shape
    g, n = b_mat.shape[2], b_mat.shape[3]
    if l_orig % chunk:
        # zero-pad to a chunk multiple: dt=0 makes padded steps exact no-ops
        # (decay exp(0)=1, input contribution dt·B·x = 0).
        pad = chunk - l_orig % chunk
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b_mat = jnp.pad(b_mat, ((0, 0), (0, pad), (0, 0), (0, 0)))
        c_mat = jnp.pad(c_mat, ((0, 0), (0, pad), (0, 0), (0, 0)))
    l = x.shape[1]
    nc = l // chunk
    rep = h // g

    xc = x.reshape(bsz, nc, chunk, h, p)
    dtc = dt.reshape(bsz, nc, chunk, h)
    bc = jnp.repeat(b_mat.reshape(bsz, nc, chunk, g, n), rep, axis=3)
    cc = jnp.repeat(c_mat.reshape(bsz, nc, chunk, g, n), rep, axis=3)

    a_dt = dtc * a[None, None, None, :]  # (B, nc, cl, H), negative
    a_cum = jnp.cumsum(a_dt, axis=2)

    # 1) intra-chunk (diagonal blocks)
    l_mat = jnp.exp(segsum(jnp.moveaxis(a_dt, -1, 2)))  # (B, nc, H, cl, cl)
    y_diag = jnp.einsum(
        "bzlhn,bzshn,bzhls,bzshp->bzlhp", cc, bc, l_mat, xc * dtc[..., None]
    )

    # 2) per-chunk states carried to the boundary (fp32 carry)
    decay_states = jnp.exp(a_cum[:, :, -1:, :] - a_cum)  # (B, nc, cl, H)
    states = jnp.einsum(
        "bzlhn,bzlh,bzlhp->bzhpn",
        bc.astype(jnp.float32),
        (decay_states * dtc).astype(jnp.float32),
        xc.astype(jnp.float32),
    )  # (B, nc, H, P, N) fp32

    # 3) inter-chunk recurrence (sequential scan over chunks)
    chunk_decay = jnp.exp(a_cum[:, :, -1, :]).astype(jnp.float32)  # (B, nc, H)
    if h0 is None:
        h0 = jnp.zeros((bsz, h, p, n), jnp.float32)
    else:
        h0 = h0.astype(jnp.float32)

    def step(carry, inp):
        st, dec = inp
        new = carry * dec[:, :, None, None] + st
        return new, carry  # emit the state *entering* the chunk

    final, prev_states = jax.lax.scan(
        step,
        h0,
        (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)),
    )
    prev_states = jnp.moveaxis(prev_states, 0, 1)  # (B, nc, H, P, N)

    # 4) contribution of incoming chunk states to outputs
    state_decay = jnp.exp(a_cum)  # (B, nc, cl, H)
    y_off = jnp.einsum(
        "bzlhn,bzhpn,bzlh->bzlhp",
        cc.astype(jnp.float32),
        prev_states,
        state_decay.astype(jnp.float32),
    ).astype(y_diag.dtype)

    y = (y_diag + y_off).reshape(bsz, l, h, p)[:, :l_orig]
    return y, final


def ssd_decode_step(
    state: jnp.ndarray,  # (B, H, P, N)
    x_t: jnp.ndarray,  # (B, H, P)
    dt_t: jnp.ndarray,  # (B, H)
    a: jnp.ndarray,  # (H,)
    b_t: jnp.ndarray,  # (B, G, N)
    c_t: jnp.ndarray,  # (B, G, N)
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """O(1) recurrent update:  h <- h·exp(dt·A) + dt·x⊗B ;  y = C·h."""
    bsz, h, p, n = state.shape
    g = b_t.shape[1]
    rep = h // g
    b_h = jnp.repeat(b_t, rep, axis=1)  # (B, H, N)
    c_h = jnp.repeat(c_t, rep, axis=1)
    decay = jnp.exp(dt_t * a[None, :])  # (B, H)
    upd = (dt_t[..., None] * x_t)[..., :, None] * b_h[:, :, None, :]  # (B,H,P,N)
    new_state = state * decay[..., None, None] + upd
    y = jnp.einsum("bhpn,bhn->bhp", new_state, c_h)
    return y, new_state


# ---------------------------------------------------------------------------
# Depthwise causal conv (width d_conv)
# ---------------------------------------------------------------------------


def causal_conv(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """x: (B, L, C), w: (W, C) depthwise, left-padded causal."""
    width = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (width - 1, 0), (0, 0)))
    l = x.shape[1]
    y = sum(pad[:, i : i + l, :] * w[i][None, None, :] for i in range(width))
    return y + b[None, None, :].astype(y.dtype)


def conv_decode_step(
    window: jnp.ndarray,  # (B, W-1, C) previous inputs
    x_t: jnp.ndarray,  # (B, 1, C)
    w: jnp.ndarray,
    b: jnp.ndarray,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    full = jnp.concatenate([window, x_t], axis=1)  # (B, W, C)
    y = jnp.einsum("bwc,wc->bc", full, w) + b
    return y[:, None, :], full[:, 1:, :]


# ---------------------------------------------------------------------------
# Block forward (train / prefill / decode)
# ---------------------------------------------------------------------------


def _split_proj(cfg: ModelConfig, zxbcdt: jnp.ndarray):
    s, d_in, n_heads, _ = _dims(cfg)
    gn = s.n_groups * s.d_state
    z, xbc, dt = jnp.split(zxbcdt, [d_in, 2 * d_in + 2 * gn], axis=-1)
    return z, xbc, dt  # xbc = [x, B, C] conv channels


def _split_xbc(cfg: ModelConfig, xbc: jnp.ndarray):
    s, d_in, n_heads, _ = _dims(cfg)
    gn = s.n_groups * s.d_state
    x, b_mat, c_mat = jnp.split(xbc, [d_in, d_in + gn], axis=-1)
    bsz, l = x.shape[:2]
    x = x.reshape(bsz, l, n_heads, s.head_dim)
    b_mat = b_mat.reshape(bsz, l, s.n_groups, s.d_state)
    c_mat = c_mat.reshape(bsz, l, s.n_groups, s.d_state)
    return x, b_mat, c_mat


def mamba2_forward(
    params: Dict, cfg: ModelConfig, u: jnp.ndarray, *, use_kernel: bool = False
) -> jnp.ndarray:
    """u: (B, L, d_model) -> (B, L, d_model)."""
    s, d_in, n_heads, _ = _dims(cfg)
    zxbcdt = u @ params["in_proj"]
    z, xbc, dt_raw = _split_proj(cfg, zxbcdt)
    xbc = jax.nn.silu(causal_conv(xbc, params["conv_w"], params["conv_b"]))
    x, b_mat, c_mat = _split_xbc(cfg, xbc)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"])
    a = -jnp.exp(params["a_log"])
    if use_kernel:
        from repro.kernels.ops import ssd_scan

        y, _ = ssd_scan(x, dt, a, b_mat, c_mat, chunk=s.chunk)
    else:
        y, _ = ssd_reference(x, dt.astype(x.dtype), a, b_mat, c_mat, chunk=s.chunk)
    y = y.astype(u.dtype) + params["d_skip"].astype(u.dtype)[None, None, :, None] * x
    y = y.reshape(u.shape[0], u.shape[1], d_in)
    y = rms_norm(y * jax.nn.silu(z), params["norm"], cfg.norm_eps)
    return y @ params["out_proj"]


def init_mamba2_cache(cfg: ModelConfig, batch: int, dtype) -> Dict:
    s, d_in, n_heads, conv_ch = _dims(cfg)
    return {
        "conv": jnp.zeros((batch, s.d_conv - 1, conv_ch), dtype),
        "ssm": jnp.zeros((batch, n_heads, s.head_dim, s.d_state), jnp.float32),
    }


def spec_mamba2_cache(cfg: ModelConfig, batch_axes, model_axis="model") -> Dict:
    return {
        "conv": P(batch_axes, None, model_axis),
        "ssm": P(batch_axes, None, None, None),
    }


def mamba2_decode(
    params: Dict, cfg: ModelConfig, u: jnp.ndarray, cache: Dict
) -> Tuple[jnp.ndarray, Dict]:
    """u: (B, 1, d_model); O(1) per token."""
    s, d_in, n_heads, _ = _dims(cfg)
    zxbcdt = u @ params["in_proj"]
    z, xbc, dt_raw = _split_proj(cfg, zxbcdt)
    conv_out, conv_win = conv_decode_step(
        cache["conv"], xbc, params["conv_w"], params["conv_b"]
    )
    xbc = jax.nn.silu(conv_out)
    x, b_mat, c_mat = _split_xbc(cfg, xbc)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"])  # (B,1,H)
    a = -jnp.exp(params["a_log"])
    y, new_state = ssd_decode_step(
        cache["ssm"],
        x[:, 0].astype(jnp.float32),
        dt[:, 0],
        a,
        b_mat[:, 0].astype(jnp.float32),
        c_mat[:, 0].astype(jnp.float32),
    )
    y = y.astype(u.dtype) + params["d_skip"].astype(u.dtype)[None, :, None] * x[:, 0]
    y = y.reshape(u.shape[0], 1, d_in)
    y = rms_norm(y * jax.nn.silu(z), params["norm"], cfg.norm_eps)
    return y @ params["out_proj"], {"conv": conv_win, "ssm": new_state}
