"""Rotary position embeddings, including Qwen2-VL's M-RoPE.

M-RoPE (arXiv:2409.12191 §2.1): the head dim is split into three sections
(temporal, height, width); each section rotates with its own position id.
Text tokens use identical (t, h, w) ids so M-RoPE degenerates to 1-D RoPE;
vision patch tokens carry distinct h/w ids.  We take 3-row position ids
``(3, B, S)`` for the VLM and plain ``(B, S)`` elsewhere.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax.numpy as jnp


def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    """Inverse frequencies, shape (head_dim // 2,), float32."""
    exponent = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta**exponent)


def rope_cos_sin(
    positions: jnp.ndarray,  # (..., S) int32
    head_dim: int,
    theta: float,
    mrope_sections: Optional[Tuple[int, int, int]] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """cos/sin tables, shape positions.shape[1:] + (head_dim//2,).

    With ``mrope_sections`` the positions must be (3, B, S); section i of the
    frequency axis uses positions[i].
    """
    inv = rope_freqs(head_dim, theta)  # (hd/2,)
    if mrope_sections is None:
        ang = positions[..., None].astype(jnp.float32) * inv  # (B, S, hd/2)
        return jnp.cos(ang), jnp.sin(ang)
    assert positions.ndim >= 3 and positions.shape[0] == 3, "M-RoPE needs (3,B,S) ids"
    assert sum(mrope_sections) == head_dim // 2
    ang_all = positions[..., None].astype(jnp.float32) * inv  # (3, B, S, hd/2)
    pieces = []
    start = 0
    for i, sec in enumerate(mrope_sections):
        pieces.append(ang_all[i, ..., start : start + sec])
        start += sec
    ang = jnp.concatenate(pieces, axis=-1)  # (B, S, hd/2)
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(
    x: jnp.ndarray,  # (B, S, H, hd)
    cos: jnp.ndarray,  # (B, S, hd/2)
    sin: jnp.ndarray,
) -> jnp.ndarray:
    """Rotate pairs (x[..., :hd/2], x[..., hd/2:]) — the HF 'rotate_half' layout."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    c = cos[..., None, :].astype(x.dtype)  # broadcast over heads
    s = sin[..., None, :].astype(x.dtype)
    return jnp.concatenate((x1 * c - x2 * s, x2 * c + x1 * s), axis=-1)


def text_positions(batch: int, seq: int, offset=0) -> jnp.ndarray:
    pos = jnp.arange(seq, dtype=jnp.int32)[None, :] + offset
    return jnp.broadcast_to(pos, (batch, seq))


def mrope_text_positions(batch: int, seq: int, offset=0) -> jnp.ndarray:
    """Degenerate (t==h==w) M-RoPE ids for text-only streams: (3, B, S)."""
    pos = text_positions(batch, seq, offset)
    return jnp.broadcast_to(pos[None], (3, batch, seq))
