"""Model registry: one uniform interface over all architecture families.

``ModelBundle`` is what the PISCO trainer, the launcher and the dry-run all
consume: init / loss / prefill / decode / specs, family-dispatched.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.models import encdec as E
from repro.models import transformer as T
from repro.models.config import ModelConfig

PyTree = Any


@dataclasses.dataclass(frozen=True)
class ModelBundle:
    cfg: ModelConfig
    init: Callable[[Any], PyTree]  # key -> params
    loss: Callable[[PyTree, Dict], jnp.ndarray]  # (params, batch) -> scalar
    param_specs: Callable[..., PyTree]
    init_cache: Callable[..., Dict]  # (batch, max_seq) -> cache
    cache_specs: Callable[..., Dict]
    prefill: Callable[..., Any]  # (params, batch, cache) -> (logits, cache)
    decode: Callable[..., Any]  # (params, token, cache) -> (logits, cache)


def get_bundle(cfg: ModelConfig) -> ModelBundle:
    if cfg.is_enc_dec:
        def loss(params, batch):
            return E.encdec_loss(params, cfg, batch)

        def init_cache(batch, max_seq, mem_len=None):
            return E.init_encdec_cache(cfg, batch, max_seq, mem_len or max_seq)

        def prefill(params, batch, cache):
            # enc-dec "prefill" = run the encoder, store memory; decoder
            # self-KV starts empty.
            memory = E.encode(params, cfg, batch["frames"])
            cache = dict(cache, memory=memory)
            logits, cache = E.encdec_decode_step(params, cfg, batch["tokens"][:, :1], cache)
            return logits, cache

        def decode(params, token, cache):
            return E.encdec_decode_step(params, cfg, token, cache)

        return ModelBundle(
            cfg=cfg,
            init=lambda key: E.init_encdec(key, cfg),
            loss=loss,
            param_specs=lambda model_axis="model": E.encdec_param_specs(cfg, model_axis),
            init_cache=init_cache,
            cache_specs=lambda batch_axes, model_axis="model": E.encdec_cache_specs(
                cfg, batch_axes, model_axis
            ),
            prefill=prefill,
            decode=decode,
        )

    def loss(params, batch):
        return T.lm_loss(params, cfg, batch)

    def prefill(params, batch, cache):
        return T.lm_prefill(
            params,
            cfg,
            batch["tokens"],
            cache,
            prefix_embeds=batch.get("prefix_embeds"),
            positions=batch.get("positions"),
        )

    def decode(params, token, cache):
        return T.lm_decode(params, cfg, token, cache)

    return ModelBundle(
        cfg=cfg,
        init=lambda key: T.init_lm(key, cfg),
        loss=loss,
        param_specs=lambda model_axis="model": T.lm_param_specs(cfg, model_axis),
        init_cache=lambda batch, max_seq: T.init_cache(cfg, batch, max_seq),
        cache_specs=lambda batch_axes, model_axis="model": T.cache_specs(
            cfg, batch_axes, model_axis
        ),
        prefill=prefill,
        decode=decode,
    )
