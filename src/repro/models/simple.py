"""The paper's own experimental models (§5): logistic regression with a
nonconvex regularizer, a 1-hidden-layer MLP (32 sigmoid units + softmax), and
the small CIFAR CNN of Fig. 7 — all pure JAX.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# §5.1 logistic regression + nonconvex regularizer
# ---------------------------------------------------------------------------


def logreg_init(d: int) -> Dict:
    return {"w": jnp.zeros((d,), jnp.float32)}


def logreg_loss(params: Dict, batch: Tuple, rho: float = 0.01) -> jnp.ndarray:
    """log(1 + exp(-y a^T x)) + rho * sum_l x_l^2 / (1 + x_l^2)  [WJZ+19]."""
    a, y = batch
    logits = a @ params["w"]
    data = jnp.mean(jnp.log1p(jnp.exp(-y * logits)))
    w = params["w"]
    reg = rho * jnp.sum(w * w / (1.0 + w * w))
    return data + reg


def logreg_accuracy(params: Dict, a: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    pred = jnp.where(a @ params["w"] > 0, 1.0, -1.0)
    return jnp.mean(pred == y)


# ---------------------------------------------------------------------------
# §5.2 one-hidden-layer MLP (sigmoid, 32 units, softmax CE)
# ---------------------------------------------------------------------------


def mlp_init(key, d_in: int = 784, hidden: int = 32, n_classes: int = 10) -> Dict:
    k1, k2 = jax.random.split(key)
    return {
        "w1": 0.1 * jax.random.normal(k1, (hidden, d_in)),
        "c1": jnp.zeros((hidden,)),
        "w2": 0.1 * jax.random.normal(k2, (n_classes, hidden)),
        "c2": jnp.zeros((n_classes,)),
    }


def mlp_logits(params: Dict, x: jnp.ndarray) -> jnp.ndarray:
    h = jax.nn.sigmoid(x @ params["w1"].T + params["c1"])
    return h @ params["w2"].T + params["c2"]


def mlp_loss(params: Dict, batch: Tuple) -> jnp.ndarray:
    x, y = batch
    logits = mlp_logits(params, x)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, y[..., None].astype(jnp.int32), axis=-1)[..., 0]
    return jnp.mean(logz - gold)


def mlp_accuracy(params: Dict, x: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    return jnp.mean(jnp.argmax(mlp_logits(params, x), axis=-1) == y)


# ---------------------------------------------------------------------------
# Fig. 7 CNN (scaled to the synthetic 16x16 CIFAR stand-in)
# ---------------------------------------------------------------------------


def _conv(x, w):
    """x: (B, H, W, C), w: (kh, kw, Cin, Cout), SAME padding."""
    return jax.lax.conv_general_dilated(
        x, w, window_strides=(1, 1), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )


def _pool(x):
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID"
    )


def cnn_init(key, hw: int = 16, n_classes: int = 10) -> Dict:
    ks = jax.random.split(key, 5)
    # two conv modules (paper uses three at 32x32; the 16x16 stand-in uses two)
    flat = (hw // 4) * (hw // 4) * 64
    return {
        "c1": 0.2 * jax.random.normal(ks[0], (3, 3, 3, 32)),
        "c2": 0.2 * jax.random.normal(ks[1], (3, 3, 32, 64)),
        "w1": 0.1 * jax.random.normal(ks[2], (128, flat)),
        "b1": jnp.zeros((128,)),
        "w2": 0.1 * jax.random.normal(ks[3], (n_classes, 128)),
        "b2": jnp.zeros((n_classes,)),
    }


def cnn_logits(params: Dict, x: jnp.ndarray) -> jnp.ndarray:
    h = jax.nn.relu(_conv(x, params["c1"]))
    h = _pool(h)
    h = jax.nn.relu(_conv(h, params["c2"]))
    h = _pool(h)
    h = h.reshape(h.shape[0], -1)
    h = jax.nn.relu(h @ params["w1"].T + params["b1"])
    return h @ params["w2"].T + params["b2"]


def cnn_loss(params: Dict, batch: Tuple) -> jnp.ndarray:
    x, y = batch
    logits = cnn_logits(params, x)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, y[..., None].astype(jnp.int32), axis=-1)[..., 0]
    return jnp.mean(logz - gold)


def cnn_accuracy(params: Dict, x: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    return jnp.mean(jnp.argmax(cnn_logits(params, x), axis=-1) == y)
