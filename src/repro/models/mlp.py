"""Feed-forward blocks: SwiGLU (Llama-family), squared-ReLU (Nemotron-4),
GELU (Seamless)."""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.config import ModelConfig
from repro.models.layers import KeyGen, normal_init


def init_mlp(kg: KeyGen, d: int, f: int, mlp_type: str, scale: float, dtype) -> Dict:
    if mlp_type == "swiglu":
        return {
            "w_gate": normal_init(kg(), (d, f), scale, dtype),
            "w_up": normal_init(kg(), (d, f), scale, dtype),
            "w_down": normal_init(kg(), (f, d), scale, dtype),
        }
    # squared_relu / gelu: two matrices
    return {
        "w_up": normal_init(kg(), (d, f), scale, dtype),
        "w_down": normal_init(kg(), (f, d), scale, dtype),
    }


def spec_mlp(mlp_type: str, model_axis: str = "model") -> Dict:
    mp = model_axis
    if mlp_type == "swiglu":
        return {"w_gate": P(None, mp), "w_up": P(None, mp), "w_down": P(mp, None)}
    return {"w_up": P(None, mp), "w_down": P(mp, None)}


def mlp_forward(params: Dict, mlp_type: str, x: jnp.ndarray) -> jnp.ndarray:
    if mlp_type == "swiglu":
        h = jax.nn.silu(x @ params["w_gate"]) * (x @ params["w_up"])
    elif mlp_type == "squared_relu":
        h = jnp.square(jax.nn.relu(x @ params["w_up"]))
    elif mlp_type == "gelu":
        h = jax.nn.gelu(x @ params["w_up"])
    else:
        raise ValueError(f"unknown mlp_type {mlp_type!r}")
    return h @ params["w_down"]
