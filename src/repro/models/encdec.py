"""Encoder-decoder transformer (Seamless-M4T text/speech backbone).

Per the assignment carve-out, the audio frontend (mel + conv feature
extractor) is a stub: the encoder consumes precomputed frame embeddings
``(B, T_frames, d_model)`` supplied by ``input_specs()``.  We implement the
transformer backbone: a bidirectional encoder stack and a causal decoder with
cross-attention, including the cached decode path (self-attn KV cache +
static encoder memory).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import attention as A
from repro.models.config import ModelConfig
from repro.models.layers import KeyGen, init_rms_norm, normal_init, rms_norm, spec_rms_norm
from repro.models.mlp import init_mlp, mlp_forward, spec_mlp
from repro.models.rope import rope_cos_sin, text_positions

PyTree = Any


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


# ---------------------------------------------------------------------------
# Params
# ---------------------------------------------------------------------------


def _init_enc_layer(kg: KeyGen, cfg: ModelConfig, dtype) -> Dict:
    return {
        "norm1": init_rms_norm(cfg.d_model, dtype),
        "attn": A.init_gqa(kg, cfg, dtype),
        "norm2": init_rms_norm(cfg.d_model, dtype),
        "ffn": init_mlp(kg, cfg.d_model, cfg.d_ff, cfg.mlp_type, cfg.init_scale, dtype),
    }


def _init_dec_layer(kg: KeyGen, cfg: ModelConfig, dtype) -> Dict:
    return {
        "norm1": init_rms_norm(cfg.d_model, dtype),
        "self_attn": A.init_gqa(kg, cfg, dtype),
        "norm_x": init_rms_norm(cfg.d_model, dtype),
        "cross_attn": A.init_gqa(kg, cfg, dtype),
        "norm2": init_rms_norm(cfg.d_model, dtype),
        "ffn": init_mlp(kg, cfg.d_model, cfg.d_ff, cfg.mlp_type, cfg.init_scale, dtype),
    }


def init_encdec(key, cfg: ModelConfig) -> PyTree:
    dtype = _dtype(cfg)
    kg = KeyGen(key)
    enc = [_init_enc_layer(kg, cfg, dtype) for _ in range(cfg.n_encoder_layers)]
    dec = [_init_dec_layer(kg, cfg, dtype) for _ in range(cfg.n_layers)]
    return {
        "embed": normal_init(kg(), (cfg.vocab_size, cfg.d_model), cfg.init_scale, dtype),
        "enc_layers": jax.tree.map(lambda *xs: jnp.stack(xs), *enc),
        "enc_norm": init_rms_norm(cfg.d_model, dtype),
        "dec_layers": jax.tree.map(lambda *xs: jnp.stack(xs), *dec),
        "final_norm": init_rms_norm(cfg.d_model, dtype),
        "lm_head": normal_init(kg(), (cfg.d_model, cfg.vocab_size), cfg.init_scale, dtype),
    }


def encdec_param_specs(cfg: ModelConfig, model_axis: str = "model") -> PyTree:
    def stacked(sp):
        return jax.tree.map(lambda s: P(None, *s), sp, is_leaf=lambda s: isinstance(s, P))

    enc_sp = {
        "norm1": spec_rms_norm(),
        "attn": A.spec_gqa(cfg, model_axis),
        "norm2": spec_rms_norm(),
        "ffn": spec_mlp(cfg.mlp_type, model_axis),
    }
    dec_sp = {
        "norm1": spec_rms_norm(),
        "self_attn": A.spec_gqa(cfg, model_axis),
        "norm_x": spec_rms_norm(),
        "cross_attn": A.spec_gqa(cfg, model_axis),
        "norm2": spec_rms_norm(),
        "ffn": spec_mlp(cfg.mlp_type, model_axis),
    }
    return {
        "embed": P(model_axis, None),
        "enc_layers": stacked(enc_sp),
        "enc_norm": spec_rms_norm(),
        "dec_layers": stacked(dec_sp),
        "final_norm": spec_rms_norm(),
        "lm_head": P(None, model_axis),
    }


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------


def encode(params: PyTree, cfg: ModelConfig, frames: jnp.ndarray) -> jnp.ndarray:
    """frames: (B, T, d_model) stub frontend output -> encoder memory."""
    b, t, _ = frames.shape
    cos_sin = rope_cos_sin(
        text_positions(b, t), cfg.resolved_head_dim, cfg.rope_theta
    )

    def layer(x, lp):
        h = rms_norm(x, lp["norm1"]["scale"], cfg.norm_eps)
        x = x + A.gqa_forward(lp["attn"], cfg, h, cos_sin, causal=False)
        h = rms_norm(x, lp["norm2"]["scale"], cfg.norm_eps)
        x = x + mlp_forward(lp["ffn"], cfg.mlp_type, h)
        return x, None

    body = jax.checkpoint(layer) if cfg.remat else layer
    x, _ = jax.lax.scan(body, frames.astype(_dtype(cfg)), params["enc_layers"], unroll=cfg.scan_unroll or 1)
    return rms_norm(x, params["enc_norm"]["scale"], cfg.norm_eps)


def _dec_layer(lp, cfg, x, memory, cos_sin, mem_cos_sin):
    h = rms_norm(x, lp["norm1"]["scale"], cfg.norm_eps)
    x = x + A.gqa_forward(lp["self_attn"], cfg, h, cos_sin, causal=True)
    h = rms_norm(x, lp["norm_x"]["scale"], cfg.norm_eps)
    x = x + A.gqa_forward(
        lp["cross_attn"], cfg, h, cos_sin, causal=False, x_kv=memory,
        cos_sin_kv=mem_cos_sin,
    )
    h = rms_norm(x, lp["norm2"]["scale"], cfg.norm_eps)
    x = x + mlp_forward(lp["ffn"], cfg.mlp_type, h)
    return x


def decode_train(
    params: PyTree, cfg: ModelConfig, tokens: jnp.ndarray, memory: jnp.ndarray
) -> jnp.ndarray:
    b, s = tokens.shape
    x = params["embed"][tokens]
    cos_sin = rope_cos_sin(text_positions(b, s), cfg.resolved_head_dim, cfg.rope_theta)
    mem_cos_sin = rope_cos_sin(
        text_positions(b, memory.shape[1]), cfg.resolved_head_dim, cfg.rope_theta
    )

    def layer(xx, lp):
        return _dec_layer(lp, cfg, xx, memory, cos_sin, mem_cos_sin), None

    body = jax.checkpoint(layer) if cfg.remat else layer
    x, _ = jax.lax.scan(body, x, params["dec_layers"], unroll=cfg.scan_unroll or 1)
    x = rms_norm(x, params["final_norm"]["scale"], cfg.norm_eps)
    return x @ params["lm_head"]


def encdec_loss(params: PyTree, cfg: ModelConfig, batch: Dict) -> jnp.ndarray:
    """batch: {"frames": (B,T,d), "tokens": (B,S)}."""
    memory = encode(params, cfg, batch["frames"])
    logits = decode_train(params, cfg, batch["tokens"], memory)
    pred = logits[:, :-1].astype(jnp.float32)
    tgt = batch["tokens"][:, 1:]
    logz = jax.nn.logsumexp(pred, axis=-1)
    gold = jnp.take_along_axis(pred, tgt[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)


# ---------------------------------------------------------------------------
# Cached decode
# ---------------------------------------------------------------------------


def init_encdec_cache(cfg: ModelConfig, batch: int, max_seq: int, mem_len: int) -> Dict:
    dtype = _dtype(cfg)
    one = A.init_gqa_cache(cfg, batch, max_seq, dtype)
    stacked = jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (cfg.n_layers,) + x.shape), one
    )
    return {
        "pos": jnp.zeros((), jnp.int32),
        "self_kv": stacked,
        "memory": jnp.zeros((batch, mem_len, cfg.d_model), dtype),
    }


def encdec_cache_specs(cfg: ModelConfig, batch_axes, model_axis="model") -> Dict:
    kv = A.spec_gqa_cache(cfg, batch_axes, model_axis)
    return {
        "pos": P(),
        "self_kv": jax.tree.map(
            lambda s: P(None, *s), kv, is_leaf=lambda s: isinstance(s, P)
        ),
        "memory": P(batch_axes, None, None),
    }


def encdec_decode_step(
    params: PyTree, cfg: ModelConfig, token: jnp.ndarray, cache: Dict
) -> Tuple[jnp.ndarray, Dict]:
    pos = cache["pos"]
    memory = cache["memory"]
    b = token.shape[0]
    x = params["embed"][token]
    hd = cfg.resolved_head_dim
    cos_sin = rope_cos_sin(text_positions(b, 1, pos), hd, cfg.rope_theta)
    mem_cos_sin = rope_cos_sin(
        text_positions(b, memory.shape[1]), hd, cfg.rope_theta
    )

    def layer(xx, scanned):
        lp, cc = scanned
        h = rms_norm(xx, lp["norm1"]["scale"], cfg.norm_eps)
        h_attn, cc = A.gqa_decode(lp["self_attn"], cfg, h, cos_sin, cc, pos)
        xx = xx + h_attn
        h = rms_norm(xx, lp["norm_x"]["scale"], cfg.norm_eps)
        xx = xx + A.gqa_forward(
            lp["cross_attn"], cfg, h, cos_sin, causal=False, x_kv=memory,
            cos_sin_kv=mem_cos_sin,
        )
        h = rms_norm(xx, lp["norm2"]["scale"], cfg.norm_eps)
        xx = xx + mlp_forward(lp["ffn"], cfg.mlp_type, h)
        return xx, cc

    x, new_kv = jax.lax.scan(layer, x, (params["dec_layers"], cache["self_kv"]), unroll=cfg.scan_unroll or 1)
    x = rms_norm(x, params["final_norm"]["scale"], cfg.norm_eps)
    logits = x @ params["lm_head"]
    return logits, {"pos": pos + 1, "self_kv": new_kv, "memory": memory}
