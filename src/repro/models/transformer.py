"""Decoder-only LM covering dense / MoE / SSM / hybrid / VLM families.

Layer stacking: layers are grouped into repeating *periods* (`cfg.scan_period()`;
1 for uniform stacks, 8 for Jamba's 1-attn:7-mamba pattern, 2 for every-other-
layer MoE).  Parameters for each position within the period are stacked over
the periods and the stack is driven by ``lax.scan`` (+ optional remat) — this
keeps the lowered HLO O(period) instead of O(n_layers), which matters both for
compile time and for the dry-run of 96-layer configs.

DeepSeek's "first layer dense-FFN" exception lives outside the scan
(``head_layers``).

Three entry points:
* :func:`lm_loss`      — next-token CE (+ MoE aux), the train-step objective.
* :func:`lm_prefill`   — logits + filled cache (inference-prefill shape).
* :func:`lm_decode`    — one token with cache (decode shapes).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import attention as A
from repro.models import mamba2 as M
from repro.models.config import ModelConfig
from repro.models.layers import KeyGen, init_rms_norm, normal_init, rms_norm, spec_rms_norm
from repro.models.mlp import init_mlp, mlp_forward, spec_mlp
from repro.models.moe import init_moe, moe_forward, spec_moe
from repro.models.rope import mrope_text_positions, rope_cos_sin, text_positions

PyTree = Any


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


# ---------------------------------------------------------------------------
# Per-layer blocks
# ---------------------------------------------------------------------------


def init_block(kg: KeyGen, cfg: ModelConfig, kind: str, ffn_kind: str, dtype) -> Dict:
    p: Dict[str, Any] = {"norm1": init_rms_norm(cfg.d_model, dtype)}
    if kind == "attn":
        p["mixer"] = (
            A.init_mla(kg, cfg, dtype) if cfg.attn_impl == "mla" else A.init_gqa(kg, cfg, dtype)
        )
    elif kind == "mamba":
        p["mixer"] = M.init_mamba2(kg, cfg, dtype)
    else:
        raise ValueError(kind)
    if ffn_kind == "dense":
        p["norm2"] = init_rms_norm(cfg.d_model, dtype)
        p["ffn"] = init_mlp(kg, cfg.d_model, cfg.d_ff, cfg.mlp_type, cfg.init_scale, dtype)
    elif ffn_kind == "moe":
        p["norm2"] = init_rms_norm(cfg.d_model, dtype)
        p["ffn"] = init_moe(kg, cfg, dtype)
    return p


def spec_block(cfg: ModelConfig, kind: str, ffn_kind: str, model_axis="model") -> Dict:
    sp: Dict[str, Any] = {"norm1": spec_rms_norm()}
    if kind == "attn":
        sp["mixer"] = (
            A.spec_mla(cfg, model_axis) if cfg.attn_impl == "mla" else A.spec_gqa(cfg, model_axis)
        )
    else:
        sp["mixer"] = M.spec_mamba2(cfg, model_axis)
    if ffn_kind == "dense":
        sp["norm2"] = spec_rms_norm()
        sp["ffn"] = spec_mlp(cfg.mlp_type, model_axis)
    elif ffn_kind == "moe":
        sp["norm2"] = spec_rms_norm()
        sp["ffn"] = spec_moe(cfg, model_axis)
    return sp


def block_forward(
    params: Dict, cfg: ModelConfig, kind: str, ffn_kind: str, x, cos_sin
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    h = rms_norm(x, params["norm1"]["scale"], cfg.norm_eps)
    if kind == "attn":
        if cfg.attn_impl == "mla":
            h = A.mla_forward(params["mixer"], cfg, h, cos_sin)
        else:
            h = A.gqa_forward(params["mixer"], cfg, h, cos_sin)
    else:
        h = M.mamba2_forward(params["mixer"], cfg, h)
    x = x + h
    aux = jnp.zeros((), jnp.float32)
    if ffn_kind != "none":
        h = rms_norm(x, params["norm2"]["scale"], cfg.norm_eps)
        if ffn_kind == "dense":
            h = mlp_forward(params["ffn"], cfg.mlp_type, h)
        else:
            h, aux = moe_forward(params["ffn"], cfg, h)
        x = x + h
    return x, aux


def block_decode(
    params: Dict, cfg: ModelConfig, kind: str, ffn_kind: str, x, cos_sin, cache, pos
) -> Tuple[jnp.ndarray, jnp.ndarray, Dict]:
    h = rms_norm(x, params["norm1"]["scale"], cfg.norm_eps)
    if kind == "attn":
        if cfg.attn_impl == "mla":
            h, cache = A.mla_decode(params["mixer"], cfg, h, cos_sin, cache, pos)
        else:
            h, cache = A.gqa_decode(params["mixer"], cfg, h, cos_sin, cache, pos)
    else:
        h, cache = M.mamba2_decode(params["mixer"], cfg, h, cache)
    x = x + h
    aux = jnp.zeros((), jnp.float32)
    if ffn_kind != "none":
        h = rms_norm(x, params["norm2"]["scale"], cfg.norm_eps)
        if ffn_kind == "dense":
            h = mlp_forward(params["ffn"], cfg.mlp_type, h)
        else:
            h, aux = moe_forward(params["ffn"], cfg, h)
        x = x + h
    return x, aux, cache


# ---------------------------------------------------------------------------
# Whole-model parameters
# ---------------------------------------------------------------------------


def _period_patterns(cfg: ModelConfig):
    """(head_patterns, period_pattern, n_periods): lists of (kind, ffn_kind)."""
    kinds = cfg.layer_kinds()
    ffns = cfg.ffn_kinds()
    pairs = list(zip(kinds, ffns))
    head = pairs[: cfg.first_k_dense]
    body = pairs[cfg.first_k_dense :]
    period = cfg.scan_period()
    assert len(body) % period == 0
    return head, body[:period], len(body) // period


def init_lm(key, cfg: ModelConfig) -> PyTree:
    dtype = _dtype(cfg)
    kg = KeyGen(key)
    head_pat, period_pat, n_periods = _period_patterns(cfg)
    params: Dict[str, Any] = {
        "embed": normal_init(kg(), (cfg.vocab_size, cfg.d_model), cfg.init_scale, dtype),
        "final_norm": init_rms_norm(cfg.d_model, dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = normal_init(
            kg(), (cfg.d_model, cfg.vocab_size), cfg.init_scale, dtype
        )
    params["head_layers"] = [
        init_block(kg, cfg, k, f, dtype) for (k, f) in head_pat
    ]
    layers = {}
    for i, (k, f) in enumerate(period_pat):
        stacked = [init_block(kg, cfg, k, f, dtype) for _ in range(n_periods)]
        layers[f"pos{i}"] = jax.tree.map(lambda *xs: jnp.stack(xs), *stacked)
    params["layers"] = layers
    return params


def lm_param_specs(cfg: ModelConfig, model_axis: str = "model") -> PyTree:
    head_pat, period_pat, n_periods = _period_patterns(cfg)
    specs: Dict[str, Any] = {
        "embed": P(model_axis, None),
        "final_norm": spec_rms_norm(),
    }
    if not cfg.tie_embeddings:
        specs["lm_head"] = P(None, model_axis)
    specs["head_layers"] = [spec_block(cfg, k, f, model_axis) for (k, f) in head_pat]
    layers = {}
    for i, (k, f) in enumerate(period_pat):
        sp = spec_block(cfg, k, f, model_axis)
        # account for the stacked leading period axis
        layers[f"pos{i}"] = jax.tree.map(
            lambda s: P(None, *s), sp, is_leaf=lambda s: isinstance(s, P)
        )
    specs["layers"] = layers
    return specs


# ---------------------------------------------------------------------------
# Position tables
# ---------------------------------------------------------------------------


def _cos_sin(cfg: ModelConfig, positions, batch, seq, offset=0):
    if cfg.arch_type == "ssm" or not _uses_rope(cfg):
        return None
    hd = cfg.resolved_head_dim if cfg.attn_impl != "mla" else cfg.mla.rope_head_dim
    if positions is None:
        if cfg.mrope_sections is not None:
            positions = mrope_text_positions(batch, seq, offset)
        else:
            positions = text_positions(batch, seq, offset)
    return rope_cos_sin(positions, hd, cfg.rope_theta, cfg.mrope_sections)


def _uses_rope(cfg: ModelConfig) -> bool:
    # Jamba uses no positional encoding (Mamba layers carry position).
    return cfg.arch_type != "hybrid"


# ---------------------------------------------------------------------------
# Forward passes
# ---------------------------------------------------------------------------


def _embed(params, cfg: ModelConfig, tokens, prefix_embeds):
    x = params["embed"][tokens]  # (B, S_txt, d)
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(x.dtype), x], axis=1)
    return x


def lm_forward(
    params: PyTree,
    cfg: ModelConfig,
    tokens: jnp.ndarray,  # (B, S_txt)
    *,
    prefix_embeds: Optional[jnp.ndarray] = None,  # (B, S_img, d) VLM/audio stub
    positions: Optional[jnp.ndarray] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Full causal forward; returns (logits (B,S,V), moe_aux)."""
    x = _embed(params, cfg, tokens, prefix_embeds)
    b, s, _ = x.shape
    cos_sin = _cos_sin(cfg, positions, b, s)
    head_pat, period_pat, _ = _period_patterns(cfg)

    aux = jnp.zeros((), jnp.float32)
    for bp, (k, f) in zip(params["head_layers"], head_pat):
        x, a = block_forward(bp, cfg, k, f, x, cos_sin)
        aux = aux + a

    def period_body(x_in, period_params):
        a_tot = jnp.zeros((), jnp.float32)
        xx = x_in
        for i, (k, f) in enumerate(period_pat):
            xx, a = block_forward(period_params[f"pos{i}"], cfg, k, f, xx, cos_sin)
            a_tot = a_tot + a
        return xx, a_tot

    body = period_body
    if cfg.remat:
        body = _remat(cfg, period_body)
    x, auxs = jax.lax.scan(body, x, params["layers"], unroll=cfg.scan_unroll or 1)
    aux = aux + jnp.sum(auxs)

    x = rms_norm(x, params["final_norm"]["scale"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = x @ head
    return logits, aux


def _remat(cfg: ModelConfig, fn):
    """Rematerialization with the configured policy (§Perf lever)."""
    if cfg.remat_policy == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        )
    return jax.checkpoint(fn)


def _hidden_states(params: PyTree, cfg: ModelConfig, tokens, prefix_embeds, positions):
    """Forward to the final norm WITHOUT projecting to the vocabulary."""
    x = _embed(params, cfg, tokens, prefix_embeds)
    b, s, _ = x.shape
    cos_sin = _cos_sin(cfg, positions, b, s)
    head_pat, period_pat, _ = _period_patterns(cfg)
    aux = jnp.zeros((), jnp.float32)
    for bp, (k, f) in zip(params["head_layers"], head_pat):
        x, a = block_forward(bp, cfg, k, f, x, cos_sin)
        aux = aux + a

    def period_body(x_in, period_params):
        a_tot = jnp.zeros((), jnp.float32)
        xx = x_in
        for i, (k, f) in enumerate(period_pat):
            xx, a = block_forward(period_params[f"pos{i}"], cfg, k, f, xx, cos_sin)
            a_tot = a_tot + a
        return xx, a_tot

    body = _remat(cfg, period_body) if cfg.remat else period_body
    x, auxs = jax.lax.scan(body, x, params["layers"], unroll=cfg.scan_unroll or 1)
    aux = aux + jnp.sum(auxs)
    return rms_norm(x, params["final_norm"]["scale"], cfg.norm_eps), aux


def _chunked_ce(hidden: jnp.ndarray, head: jnp.ndarray, targets: jnp.ndarray, chunk: int):
    """Next-token CE via a scan over sequence chunks: the (chunk, V) logits
    block is the only vocabulary-sized tensor ever live (§Perf: removes the
    full (B, S, V) materialization from both HBM traffic and peak memory)."""
    b, s_pred, d = hidden.shape
    chunk = min(chunk, s_pred)
    n_full = s_pred // chunk
    rem = s_pred - n_full * chunk

    def ce_of(h_blk, t_blk):
        logits = (h_blk @ head).astype(jnp.float32)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, t_blk[..., None], axis=-1)[..., 0]
        return jnp.sum(logz - gold)

    total = jnp.zeros((), jnp.float32)
    if n_full:
        h_main = hidden[:, : n_full * chunk].reshape(b, n_full, chunk, d)
        t_main = targets[:, : n_full * chunk].reshape(b, n_full, chunk)

        def body(acc, blk):
            h_blk, t_blk = blk
            return acc + ce_of(h_blk, t_blk), None

        total, _ = jax.lax.scan(
            body, total, (jnp.moveaxis(h_main, 1, 0), jnp.moveaxis(t_main, 1, 0))
        )
    if rem:
        total = total + ce_of(hidden[:, n_full * chunk :], targets[:, n_full * chunk :])
    return total / (b * s_pred)


def lm_loss(params: PyTree, cfg: ModelConfig, batch: Dict) -> jnp.ndarray:
    """Next-token cross-entropy over the text tokens (+ MoE aux loss).

    batch: {"tokens": (B, S)} (+ "prefix_embeds", "positions" for vlm/audio).
    """
    tokens = batch["tokens"]
    if cfg.loss_chunk > 0:
        hidden, aux = _hidden_states(
            params, cfg, tokens,
            batch.get("prefix_embeds"), batch.get("positions"),
        )
        head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
        txt_hidden = hidden[:, -tokens.shape[1] : -1, :]
        ce = _chunked_ce(txt_hidden, head, tokens[:, 1:], cfg.loss_chunk)
        return ce + aux
    logits, aux = lm_forward(
        params,
        cfg,
        tokens,
        prefix_embeds=batch.get("prefix_embeds"),
        positions=batch.get("positions"),
    )
    # align: predict token t+1 from position t (text-only tail of the stream)
    txt_logits = logits[:, -tokens.shape[1] :, :]
    pred = txt_logits[:, :-1].astype(jnp.float32)
    tgt = tokens[:, 1:]
    logz = jax.nn.logsumexp(pred, axis=-1)
    gold = jnp.take_along_axis(pred, tgt[..., None], axis=-1)[..., 0]
    ce = jnp.mean(logz - gold)
    return ce + aux


# ---------------------------------------------------------------------------
# KV / SSM caches
# ---------------------------------------------------------------------------


def _init_block_cache(cfg: ModelConfig, kind: str, batch: int, max_seq: int, dtype):
    if kind == "attn":
        if cfg.attn_impl == "mla":
            return A.init_mla_cache(cfg, batch, max_seq, dtype)
        return A.init_gqa_cache(cfg, batch, max_seq, dtype)
    return M.init_mamba2_cache(cfg, batch, dtype)


def _spec_block_cache(cfg: ModelConfig, kind: str, batch_axes, model_axis):
    if kind == "attn":
        if cfg.attn_impl == "mla":
            return A.spec_mla_cache(cfg, batch_axes, model_axis)
        return A.spec_gqa_cache(cfg, batch_axes, model_axis)
    return M.spec_mamba2_cache(cfg, batch_axes, model_axis)


def init_cache(cfg: ModelConfig, batch: int, max_seq: int) -> Dict:
    dtype = _dtype(cfg)
    head_pat, period_pat, n_periods = _period_patterns(cfg)
    cache: Dict[str, Any] = {
        "pos": jnp.zeros((), jnp.int32),
        "head_layers": [
            _init_block_cache(cfg, k, batch, max_seq, dtype) for (k, _) in head_pat
        ],
    }
    layers = {}
    for i, (k, _) in enumerate(period_pat):
        one = _init_block_cache(cfg, k, batch, max_seq, dtype)
        layers[f"pos{i}"] = jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (n_periods,) + x.shape), one
        )
    cache["layers"] = layers
    return cache


def cache_specs(cfg: ModelConfig, batch_axes, model_axis: str = "model") -> Dict:
    head_pat, period_pat, _ = _period_patterns(cfg)
    specs: Dict[str, Any] = {
        "pos": P(),
        "head_layers": [
            _spec_block_cache(cfg, k, batch_axes, model_axis) for (k, _) in head_pat
        ],
    }
    layers = {}
    for i, (k, _) in enumerate(period_pat):
        sp = _spec_block_cache(cfg, k, batch_axes, model_axis)
        layers[f"pos{i}"] = jax.tree.map(
            lambda s: P(None, *s), sp, is_leaf=lambda s: isinstance(s, P)
        )
    specs["layers"] = layers
    return specs


def lm_decode(
    params: PyTree,
    cfg: ModelConfig,
    token: jnp.ndarray,  # (B, 1) int32
    cache: Dict,
) -> Tuple[jnp.ndarray, Dict]:
    """One decode step; returns (logits (B,1,V), updated cache)."""
    pos = cache["pos"]
    x = params["embed"][token]
    b = x.shape[0]
    head_pat, period_pat, _ = _period_patterns(cfg)
    if _uses_rope(cfg) and cfg.arch_type != "ssm":
        posn = (
            mrope_text_positions(b, 1, pos)
            if cfg.mrope_sections is not None
            else text_positions(b, 1, pos)
        )
        hd = cfg.resolved_head_dim if cfg.attn_impl != "mla" else cfg.mla.rope_head_dim
        cos_sin = rope_cos_sin(posn, hd, cfg.rope_theta, cfg.mrope_sections)
    else:
        cos_sin = None

    new_head_caches = []
    for bp, (k, f), cc in zip(params["head_layers"], head_pat, cache["head_layers"]):
        x, _, cc = block_decode(bp, cfg, k, f, x, cos_sin, cc, pos)
        new_head_caches.append(cc)

    def period_body(x_in, scanned):
        period_params, period_cache = scanned
        xx = x_in
        new_cc = {}
        for i, (k, f) in enumerate(period_pat):
            xx, _, cc = block_decode(
                period_params[f"pos{i}"], cfg, k, f, xx, cos_sin, period_cache[f"pos{i}"], pos
            )
            new_cc[f"pos{i}"] = cc
        return xx, new_cc

    x, new_layer_caches = jax.lax.scan(period_body, x, (params["layers"], cache["layers"]), unroll=cfg.scan_unroll or 1)

    x = rms_norm(x, params["final_norm"]["scale"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = x @ head
    new_cache = {
        "pos": pos + 1,
        "head_layers": new_head_caches,
        "layers": new_layer_caches,
    }
    return logits, new_cache


def lm_prefill(
    params: PyTree,
    cfg: ModelConfig,
    tokens: jnp.ndarray,
    cache: Dict,
    *,
    prefix_embeds: Optional[jnp.ndarray] = None,
    positions: Optional[jnp.ndarray] = None,
) -> Tuple[jnp.ndarray, Dict]:
    """Prefill = full causal forward + cache fill.

    For attention layers the K/V computed during the forward are re-derived
    per layer and written into the cache; for mamba layers the final SSM/conv
    states are produced by the same chunked scan."""
    x = _embed(params, cfg, tokens, prefix_embeds)
    b, s, _ = x.shape
    cos_sin = _cos_sin(cfg, positions, b, s)
    head_pat, period_pat, _ = _period_patterns(cfg)

    def prefill_block(bp, kind, ffn_kind, xx, cc):
        h = rms_norm(xx, bp["norm1"]["scale"], cfg.norm_eps)
        if kind == "attn":
            if cfg.attn_impl == "mla":
                q_nope, q_rope, c_kv, k_rope = A._mla_qkr(bp["mixer"], cfg, h, cos_sin)
                cc = A.mla_fill_cache(cc, c_kv, k_rope)
                out = A.mla_forward(bp["mixer"], cfg, h, cos_sin)
            else:
                q, k, v = A._project_qkv(bp["mixer"], cfg, h)
                if cos_sin is not None:
                    q = A.apply_rope(q, *cos_sin)
                    k = A.apply_rope(k, *cos_sin)
                cc = A.gqa_fill_cache(cc, k, v)
                core = A.attention_core(
                    q, k, v, causal=True, window=cfg.sliding_window,
                    chunk=cfg.attn_chunk, softcap=cfg.attn_logit_softcap,
                )
                out = jnp.einsum("bshk,hkd->bsd", core, bp["mixer"]["wo"])
        else:
            s_cfg, d_in, n_heads, _ = M._dims(cfg)
            zxbcdt = h @ bp["mixer"]["in_proj"]
            z, xbc, dt_raw = M._split_proj(cfg, zxbcdt)
            conv_full = M.causal_conv(xbc, bp["mixer"]["conv_w"], bp["mixer"]["conv_b"])
            conv_win = xbc[:, -(s_cfg.d_conv - 1) :, :]
            xbc_act = jax.nn.silu(conv_full)
            xm, b_mat, c_mat = M._split_xbc(cfg, xbc_act)
            dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + bp["mixer"]["dt_bias"])
            a_neg = -jnp.exp(bp["mixer"]["a_log"])
            y, final_state = M.ssd_reference(
                xm, dt.astype(xm.dtype), a_neg, b_mat, c_mat, chunk=s_cfg.chunk
            )
            y = y.astype(xx.dtype) + bp["mixer"]["d_skip"].astype(xx.dtype)[None, None, :, None] * xm
            y = y.reshape(xx.shape[0], xx.shape[1], d_in)
            y = rms_norm(y * jax.nn.silu(z), bp["mixer"]["norm"], cfg.norm_eps)
            out = y @ bp["mixer"]["out_proj"]
            cc = {"conv": conv_win, "ssm": final_state.astype(jnp.float32)}
        xx = xx + out
        if ffn_kind != "none":
            h2 = rms_norm(xx, bp["norm2"]["scale"], cfg.norm_eps)
            if ffn_kind == "dense":
                h2 = mlp_forward(bp["ffn"], cfg.mlp_type, h2)
            else:
                h2, _ = moe_forward(bp["ffn"], cfg, h2)
            xx = xx + h2
        return xx, cc

    new_head_caches = []
    for bp, (k, f), cc in zip(params["head_layers"], head_pat, cache["head_layers"]):
        x, cc = prefill_block(bp, k, f, x, cc)
        new_head_caches.append(cc)

    def period_body(x_in, scanned):
        pp, pc = scanned
        xx = x_in
        new_cc = {}
        for i, (k, f) in enumerate(period_pat):
            xx, cc = prefill_block(pp[f"pos{i}"], k, f, xx, pc[f"pos{i}"])
            new_cc[f"pos{i}"] = cc
        return xx, new_cc

    x, new_layer_caches = jax.lax.scan(period_body, x, (params["layers"], cache["layers"]), unroll=cfg.scan_unroll or 1)
    x = rms_norm(x, params["final_norm"]["scale"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = x @ head
    new_cache = {
        "pos": jnp.asarray(x.shape[1], jnp.int32),
        "head_layers": new_head_caches,
        "layers": new_layer_caches,
    }
    return logits, new_cache
