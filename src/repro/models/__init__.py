from repro.models.config import (
    MLAConfig,
    MoEConfig,
    ModelConfig,
    SSMConfig,
    config_from_dict,
    config_to_dict,
)
from repro.models.registry import ModelBundle, get_bundle

__all__ = [
    "MLAConfig",
    "MoEConfig",
    "ModelConfig",
    "SSMConfig",
    "ModelBundle",
    "config_from_dict",
    "config_to_dict",
    "get_bundle",
]
