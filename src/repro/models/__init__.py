from repro.models.config import MLAConfig, MoEConfig, ModelConfig, SSMConfig
from repro.models.registry import ModelBundle, get_bundle

__all__ = [
    "MLAConfig",
    "MoEConfig",
    "ModelConfig",
    "SSMConfig",
    "ModelBundle",
    "get_bundle",
]
