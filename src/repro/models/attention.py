"""Attention: GQA/MQA (+qk-norm, qkv-bias, sliding-window) and DeepSeek MLA.

Compute paths:

* ``naive``   — full (B, Hkv, G, Sq, Sk) scores; used for short sequences and
  as the numerical reference.
* ``chunked`` — unrolled query-block loop with *static* key slices
  ``k[:, :q_block_end]`` (causal) so long-sequence prefill never materializes
  the full score matrix and skips the upper triangle entirely.  This is the
  memory-safe lowering the dry-run uses; the Pallas flash kernel
  (``repro.kernels.flash_attention``) is the TPU-target equivalent.
* ``decode``  — single query token against a KV cache (rolling buffer under
  sliding-window attention, compressed latent cache under MLA).

All variants share one mask convention: scores are masked with -inf *before*
softmax, softmax in fp32.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.config import ModelConfig
from repro.models.layers import KeyGen, dense, normal_init, rms_norm, zeros_init, ones_init
from repro.models.rope import apply_rope

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Parameter init + specs
# ---------------------------------------------------------------------------


def init_gqa(kg: KeyGen, cfg: ModelConfig, dtype) -> Dict[str, Any]:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    h, hkv = cfg.n_heads, cfg.n_kv_heads
    s = cfg.init_scale
    p = {
        "wq": normal_init(kg(), (d, h, hd), s, dtype),
        "wk": normal_init(kg(), (d, hkv, hd), s, dtype),
        "wv": normal_init(kg(), (d, hkv, hd), s, dtype),
        "wo": normal_init(kg(), (h, hd, d), s / math.sqrt(2 * cfg.n_layers), dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = zeros_init((h, hd), dtype)
        p["bk"] = zeros_init((hkv, hd), dtype)
        p["bv"] = zeros_init((hkv, hd), dtype)
    if cfg.qk_norm:
        p["q_norm"] = ones_init((hd,), dtype)
        p["k_norm"] = ones_init((hd,), dtype)
    return p


def spec_gqa(cfg: ModelConfig, model_axis: str = "model") -> Dict[str, Any]:
    mp = model_axis
    sp = {
        "wq": P(None, mp, None),
        "wk": P(None, mp, None) if cfg.n_kv_heads > 1 else P(None, None, None),
        "wv": P(None, mp, None) if cfg.n_kv_heads > 1 else P(None, None, None),
        "wo": P(mp, None, None),
    }
    if cfg.qkv_bias:
        sp["bq"] = P(mp, None)
        sp["bk"] = P(mp, None) if cfg.n_kv_heads > 1 else P(None, None)
        sp["bv"] = P(mp, None) if cfg.n_kv_heads > 1 else P(None, None)
    if cfg.qk_norm:
        sp["q_norm"] = P(None)
        sp["k_norm"] = P(None)
    return sp


def init_mla(kg: KeyGen, cfg: ModelConfig, dtype) -> Dict[str, Any]:
    m = cfg.mla
    d, h = cfg.d_model, cfg.n_heads
    s = cfg.init_scale
    q_dim = m.nope_head_dim + m.rope_head_dim
    return {
        "wq": normal_init(kg(), (d, h, q_dim), s, dtype),
        "w_dkv": normal_init(kg(), (d, m.kv_lora_rank), s, dtype),
        "w_kr": normal_init(kg(), (d, m.rope_head_dim), s, dtype),
        "kv_norm": ones_init((m.kv_lora_rank,), dtype),
        "w_uk": normal_init(kg(), (m.kv_lora_rank, h, m.nope_head_dim), s, dtype),
        "w_uv": normal_init(kg(), (m.kv_lora_rank, h, m.v_head_dim), s, dtype),
        "wo": normal_init(
            kg(), (h, m.v_head_dim, d), s / math.sqrt(2 * cfg.n_layers), dtype
        ),
    }


def spec_mla(cfg: ModelConfig, model_axis: str = "model") -> Dict[str, Any]:
    mp = model_axis
    return {
        "wq": P(None, mp, None),
        "w_dkv": P(None, None),
        "w_kr": P(None, None),
        "kv_norm": P(None),
        "w_uk": P(None, mp, None),
        "w_uv": P(None, mp, None),
        "wo": P(mp, None, None),
    }


# ---------------------------------------------------------------------------
# Score/softmax cores
# ---------------------------------------------------------------------------


def _softcap(scores: jnp.ndarray, cap: Optional[float]) -> jnp.ndarray:
    if cap is None:
        return scores
    return cap * jnp.tanh(scores / cap)


def _sdpa(
    q: jnp.ndarray,  # (B, Sq, Hkv, G, D)
    k: jnp.ndarray,  # (B, Sk, Hkv, D)
    v: jnp.ndarray,  # (B, Sk, Hkv, Dv)
    mask: Optional[jnp.ndarray],  # broadcastable to (B, Hkv, G, Sq, Sk) or None
    softcap: Optional[float],
) -> jnp.ndarray:
    """Grouped scaled-dot-product attention; returns (B, Sq, Hkv, G, Dv)."""
    scale = 1.0 / math.sqrt(q.shape[-1])
    scores = jnp.einsum("bqhgd,bkhd->bhgqk", q, k) * scale
    scores = _softcap(scores, softcap)
    if mask is not None:
        scores = jnp.where(mask, scores, NEG_INF)
    probs = jax.nn.softmax(scores.astype(jnp.float32), axis=-1).astype(q.dtype)
    return jnp.einsum("bhgqk,bkhd->bqhgd", probs, v)


def _causal_mask(sq: int, sk: int, q_offset: int, window: Optional[int]):
    qpos = jnp.arange(sq)[:, None] + q_offset
    kpos = jnp.arange(sk)[None, :]
    mask = kpos <= qpos
    if window is not None:
        mask = mask & (kpos > qpos - window)
    return mask[None, None, None]  # (1,1,1,Sq,Sk)


def attention_core(
    q: jnp.ndarray,  # (B, Sq, H, D)
    k: jnp.ndarray,  # (B, Sk, Hkv, D)
    v: jnp.ndarray,  # (B, Sk, Hkv, Dv)
    *,
    causal: bool,
    window: Optional[int] = None,
    chunk: int = 1024,
    softcap: Optional[float] = None,
) -> jnp.ndarray:
    """Full-sequence attention (train / prefill). Returns (B, Sq, H, Dv)."""
    b, sq, h, dh = q.shape
    hkv = k.shape[2]
    g = h // hkv
    qg = q.reshape(b, sq, hkv, g, dh)

    if sq <= chunk or not causal or sq % chunk != 0:
        # naive path (short sequences / non-causal / ragged lengths)
        mask = _causal_mask(sq, k.shape[1], 0, window) if causal else None
        out = _sdpa(qg, k, v, mask, softcap)
        return out.reshape(b, sq, h, -1)

    # Chunked causal path: static key slices, upper triangle never computed.
    outs = []
    for ci in range(sq // chunk):
        q_start = ci * chunk
        k_end = q_start + chunk
        k_start = 0 if window is None else max(0, k_end - window - chunk)
        qc = qg[:, q_start : q_start + chunk]
        kc = k[:, k_start:k_end]
        vc = v[:, k_start:k_end]
        mask = _causal_mask(chunk, k_end - k_start, q_start - k_start, window)
        outs.append(_sdpa(qc, kc, vc, mask, softcap))
    return jnp.concatenate(outs, axis=1).reshape(b, sq, h, -1)


def decode_attention_core(
    q: jnp.ndarray,  # (B, 1, H, D)
    k_cache: jnp.ndarray,  # (B, S_cache, Hkv, D)
    v_cache: jnp.ndarray,  # (B, S_cache, Hkv, Dv)
    valid: jnp.ndarray,  # (B, S_cache) bool — which cache slots are live
    softcap: Optional[float] = None,
) -> jnp.ndarray:
    b, _, h, dh = q.shape
    hkv = k_cache.shape[2]
    qg = q.reshape(b, 1, hkv, h // hkv, dh)
    mask = valid[:, None, None, None, :]  # (B,1,1,1,S)
    out = _sdpa(qg, k_cache, v_cache, mask, softcap)
    return out.reshape(b, 1, h, -1)


# ---------------------------------------------------------------------------
# GQA block forward (train / prefill / decode)
# ---------------------------------------------------------------------------


def _project_qkv(params, cfg: ModelConfig, x, x_kv=None):
    xkv = x if x_kv is None else x_kv
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    k = jnp.einsum("bsd,dhk->bshk", xkv, params["wk"])
    v = jnp.einsum("bsd,dhk->bshk", xkv, params["wv"])
    if cfg.qkv_bias:
        q = q + params["bq"].astype(q.dtype)
        k = k + params["bk"].astype(k.dtype)
        v = v + params["bv"].astype(v.dtype)
    if cfg.qk_norm:
        q = rms_norm(q, params["q_norm"], cfg.norm_eps)
        k = rms_norm(k, params["k_norm"], cfg.norm_eps)
    return q, k, v


def gqa_forward(
    params,
    cfg: ModelConfig,
    x: jnp.ndarray,  # (B, S, d)
    cos_sin: Optional[Tuple[jnp.ndarray, jnp.ndarray]],
    *,
    causal: bool = True,
    x_kv: Optional[jnp.ndarray] = None,  # cross-attention memory
    cos_sin_kv: Optional[Tuple] = None,
) -> jnp.ndarray:
    q, k, v = _project_qkv(params, cfg, x, x_kv)
    if cos_sin is not None:
        q = apply_rope(q, *cos_sin)
        k = apply_rope(k, *(cos_sin_kv if cos_sin_kv is not None else cos_sin))
    out = attention_core(
        q,
        k,
        v,
        causal=causal,
        window=cfg.sliding_window,
        chunk=cfg.attn_chunk,
        softcap=cfg.attn_logit_softcap,
    )
    return jnp.einsum("bshk,hkd->bsd", out, params["wo"])


def init_gqa_cache(cfg: ModelConfig, batch: int, max_seq: int, dtype) -> Dict:
    """KV cache; rolling buffer of size `window` under SWA."""
    size = max_seq if cfg.sliding_window is None else min(max_seq, cfg.sliding_window)
    hd = cfg.resolved_head_dim
    return {
        "k": jnp.zeros((batch, size, cfg.n_kv_heads, hd), dtype),
        "v": jnp.zeros((batch, size, cfg.n_kv_heads, hd), dtype),
    }


def spec_gqa_cache(cfg: ModelConfig, batch_axes, model_axis="model") -> Dict:
    kv = P(batch_axes, None, model_axis if cfg.n_kv_heads > 1 else None, None)
    return {"k": kv, "v": kv}


def gqa_fill_cache(cache: Dict, k: jnp.ndarray, v: jnp.ndarray) -> Dict:
    """Write prefill K/V into the cache (rolling tail under SWA)."""
    size = cache["k"].shape[1]
    s = k.shape[1]
    if s >= size:
        return {"k": k[:, s - size :], "v": v[:, s - size :]}
    return {
        "k": jax.lax.dynamic_update_slice(cache["k"], k, (0, 0, 0, 0)),
        "v": jax.lax.dynamic_update_slice(cache["v"], v, (0, 0, 0, 0)),
    }


def gqa_decode(
    params,
    cfg: ModelConfig,
    x: jnp.ndarray,  # (B, 1, d)
    cos_sin: Tuple[jnp.ndarray, jnp.ndarray],  # tables for position `pos`
    cache: Dict,
    pos: jnp.ndarray,  # scalar int32 — number of tokens already in context
) -> Tuple[jnp.ndarray, Dict]:
    q, k, v = _project_qkv(params, cfg, x)
    if cos_sin is not None:
        q = apply_rope(q, *cos_sin)
        k = apply_rope(k, *cos_sin)
    size = cache["k"].shape[1]
    slot = pos % size if cfg.sliding_window is not None else pos
    k_cache = jax.lax.dynamic_update_slice(cache["k"], k, (0, slot, 0, 0))
    v_cache = jax.lax.dynamic_update_slice(cache["v"], v, (0, slot, 0, 0))
    idx = jnp.arange(size)
    if cfg.sliding_window is None:
        valid = idx <= pos
    else:
        valid = (idx <= pos) | (pos >= size)  # rolling buffer fully valid once wrapped
    valid = jnp.broadcast_to(valid[None], (x.shape[0], size))
    out = decode_attention_core(q, k_cache, v_cache, valid, cfg.attn_logit_softcap)
    y = jnp.einsum("bshk,hkd->bsd", out, params["wo"])
    return y, {"k": k_cache, "v": v_cache}


# ---------------------------------------------------------------------------
# MLA forward (train/prefill materialized; decode absorbed)
# ---------------------------------------------------------------------------


def _mla_qkr(params, cfg, x, cos_sin):
    m = cfg.mla
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    q_nope = q[..., : m.nope_head_dim]
    q_rope = apply_rope(q[..., m.nope_head_dim :], *cos_sin)
    c_kv = rms_norm(
        jnp.einsum("bsd,dr->bsr", x, params["w_dkv"]), params["kv_norm"], cfg.norm_eps
    )
    k_rope = apply_rope(
        jnp.einsum("bsd,dr->bsr", x, params["w_kr"])[:, :, None, :], *cos_sin
    )[:, :, 0]  # (B, S, rope_dim), single shared head
    return q_nope, q_rope, c_kv, k_rope


def mla_forward(params, cfg: ModelConfig, x, cos_sin, *, causal=True) -> jnp.ndarray:
    """Materialized MLA (train / prefill): up-project the latent to full K/V."""
    m = cfg.mla
    q_nope, q_rope, c_kv, k_rope = _mla_qkr(params, cfg, x, cos_sin)
    k_nope = jnp.einsum("bsr,rhk->bshk", c_kv, params["w_uk"])
    value = jnp.einsum("bsr,rhk->bshk", c_kv, params["w_uv"])
    h = cfg.n_heads
    k_rope_b = jnp.broadcast_to(k_rope[:, :, None, :], k_nope.shape[:3] + (m.rope_head_dim,))
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate([k_nope, k_rope_b], axis=-1)
    out = attention_core(
        q, k, value, causal=causal, window=None, chunk=cfg.attn_chunk,
        softcap=cfg.attn_logit_softcap,
    )
    return jnp.einsum("bshk,hkd->bsd", out, params["wo"])


def init_mla_cache(cfg: ModelConfig, batch: int, max_seq: int, dtype) -> Dict:
    m = cfg.mla
    return {
        "c_kv": jnp.zeros((batch, max_seq, m.kv_lora_rank), dtype),
        "k_rope": jnp.zeros((batch, max_seq, m.rope_head_dim), dtype),
    }


def spec_mla_cache(cfg: ModelConfig, batch_axes, model_axis="model") -> Dict:
    return {"c_kv": P(batch_axes, None, None), "k_rope": P(batch_axes, None, None)}


def mla_fill_cache(cache: Dict, c_kv: jnp.ndarray, k_rope: jnp.ndarray) -> Dict:
    return {
        "c_kv": jax.lax.dynamic_update_slice(cache["c_kv"], c_kv, (0, 0, 0)),
        "k_rope": jax.lax.dynamic_update_slice(cache["k_rope"], k_rope, (0, 0, 0)),
    }


def mla_decode(
    params, cfg: ModelConfig, x, cos_sin, cache: Dict, pos
) -> Tuple[jnp.ndarray, Dict]:
    """Absorbed-matrix MLA decode: attention runs in the compressed latent
    space (MQA-shaped), W_uk folded into the query and W_uv applied after the
    value reduction — the DeepSeek-V2 production decode path."""
    m = cfg.mla
    q_nope, q_rope, c_kv_new, k_rope_new = _mla_qkr(params, cfg, x, cos_sin)
    c_cache = jax.lax.dynamic_update_slice(cache["c_kv"], c_kv_new, (0, pos, 0))
    r_cache = jax.lax.dynamic_update_slice(cache["k_rope"], k_rope_new, (0, pos, 0))
    # Absorb: q_lat[b,1,h,r] = q_nope · W_uk
    q_lat = jnp.einsum("bshk,rhk->bshr", q_nope, params["w_uk"])
    scale = 1.0 / math.sqrt(m.nope_head_dim + m.rope_head_dim)
    scores = (
        jnp.einsum("bshr,btr->bhst", q_lat, c_cache)
        + jnp.einsum("bshr,btr->bhst", q_rope, r_cache)
    ) * scale
    scores = _softcap(scores, cfg.attn_logit_softcap)
    size = c_cache.shape[1]
    valid = (jnp.arange(size) <= pos)[None, None, None, :]
    scores = jnp.where(valid, scores, NEG_INF)
    probs = jax.nn.softmax(scores.astype(jnp.float32), axis=-1).astype(x.dtype)
    ctx = jnp.einsum("bhst,btr->bshr", probs, c_cache)  # latent-space context
    out = jnp.einsum("bshr,rhk->bshk", ctx, params["w_uv"])
    y = jnp.einsum("bshk,hkd->bsd", out, params["wo"])
    return y, {"c_kv": c_cache, "k_rope": r_cache}
