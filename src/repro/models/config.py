"""Model configuration for the composable model zoo.

One :class:`ModelConfig` covers every assigned architecture family:
dense GQA/MQA decoders, MoE (Mixtral / DeepSeek-MLA), SSM (Mamba2 SSD),
hybrid (Jamba), encoder-decoder (Seamless, stub audio frontend) and VLM
(Qwen2-VL, stub vision frontend, M-RoPE).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int  # routed experts
    top_k: int
    d_expert: int  # per-expert FFN hidden size
    n_shared: int = 0  # shared (always-on) experts, DeepSeek-style
    # which layers are MoE: "all" | "every_2" (odd layers) | "after_first"
    layer_mode: str = "all"
    router_aux_coef: float = 0.01
    capacity_factor: float = 1.25
    # router weight normalization: "softmax_topk" (Mixtral: softmax over the
    # selected logits) | "topk_softmax" (DeepSeek: softmax first, renormalize)
    gate_mode: str = "softmax_topk"


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64  # SSD "P"
    n_groups: int = 1
    chunk: int = 256
    dt_min: float = 0.001
    dt_max: float = 0.1
    a_init_range: Tuple[float, float] = (1.0, 16.0)


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    """Multi-head Latent Attention (DeepSeek-V2)."""

    kv_lora_rank: int = 512
    q_lora_rank: int = 0  # 0 => direct q projection (V2-Lite)
    rope_head_dim: int = 64
    nope_head_dim: int = 128
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str  # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None  # default d_model // n_heads
    # --- attention variants ---
    attn_impl: str = "gqa"  # gqa | mla | none (pure SSM)
    qk_norm: bool = False  # Qwen3
    qkv_bias: bool = False  # Qwen2.5 / Qwen2-VL
    sliding_window: Optional[int] = None  # Mixtral SWA
    rope_theta: float = 10000.0
    mrope_sections: Optional[Tuple[int, int, int]] = None  # Qwen2-VL M-RoPE
    attn_logit_softcap: Optional[float] = None
    # --- feed-forward variant ---
    mlp_type: str = "swiglu"  # swiglu | squared_relu | gelu
    # --- sub-configs ---
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    mla: Optional[MLAConfig] = None
    # --- hybrid layout (Jamba): mixer per layer within a period ---
    # e.g. ("mamba","mamba","mamba","attn","mamba","mamba","mamba","mamba")
    hybrid_period: Optional[Tuple[str, ...]] = None
    first_k_dense: int = 0  # DeepSeek: first k layers use dense FFN, not MoE
    # --- encoder-decoder (Seamless) ---
    is_enc_dec: bool = False
    n_encoder_layers: int = 0
    # --- modality frontend stubs ---
    modality: str = "text"  # text | audio | vlm
    # --- numerics / implementation ---
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    dtype: str = "float32"  # parameter/compute dtype ("bfloat16" for dry-run)
    attn_chunk: int = 1024  # online-softmax q-block size for long sequences
    remat: bool = True  # rematerialize each scanned layer in training
    remat_policy: str = "full"  # full | dots (save matmul outputs, recompute rest)
    loss_chunk: int = 0  # >0: compute CE over sequence chunks (never
    #     materializes the full (B, S, V) logits — §Perf lever)
    scan_unroll: bool = False  # fully unroll the layer scan (used by the
    #     dry-run cost-correction variants: XLA cost analysis counts while
    #     bodies once, so scanned stacks need unrolled small variants)
    init_scale: float = 0.02

    # ------------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim is not None:
            return self.head_dim
        return self.d_model // self.n_heads

    @property
    def q_group(self) -> int:
        return max(1, self.n_heads // max(1, self.n_kv_heads))

    def layer_kinds(self) -> List[str]:
        """Mixer kind per decoder layer ('attn' | 'mamba')."""
        if self.hybrid_period:
            period = list(self.hybrid_period)
            assert self.n_layers % len(period) == 0
            return period * (self.n_layers // len(period))
        if self.arch_type == "ssm":
            return ["mamba"] * self.n_layers
        return ["attn"] * self.n_layers

    def ffn_kinds(self) -> List[str]:
        """FFN kind per decoder layer ('dense' | 'moe' | 'none')."""
        if self.arch_type == "ssm":
            return ["none"] * self.n_layers  # Mamba2 block subsumes the FFN
        if self.moe is None:
            return ["dense"] * self.n_layers
        mode = self.moe.layer_mode
        kinds = []
        for l in range(self.n_layers):
            if mode == "all":
                kinds.append("moe")
            elif mode == "every_2":
                kinds.append("moe" if l % 2 == 1 else "dense")
            elif mode == "after_first":
                kinds.append("dense" if l < self.first_k_dense else "moe")
            else:
                raise ValueError(mode)
        return kinds

    def scan_period(self) -> int:
        """Length of the repeating layer pattern (the scan unit)."""
        body = self.n_layers - self.first_k_dense
        if self.hybrid_period:
            p = len(self.hybrid_period)
            if self.moe is not None and self.moe.layer_mode == "every_2":
                p = max(p, 2) if p % 2 == 0 else p * 2
            assert body % p == 0
            return p
        if self.moe is not None and self.moe.layer_mode == "every_2":
            assert body % 2 == 0
            return 2
        return 1

    def supports_long_decode(self) -> bool:
        """Sub-quadratic / bounded-cache decode => long_500k applies."""
        if self.arch_type in ("ssm", "hybrid"):
            return True
        return self.sliding_window is not None

    def param_count(self) -> int:
        """Analytic parameter count (embedding included, biases ignored)."""
        d, f, V = self.d_model, self.d_ff, self.vocab_size
        hd = self.resolved_head_dim
        n_attn = 0
        n_mamba = 0
        for kind in self.layer_kinds():
            if kind == "attn":
                if self.attn_impl == "mla":
                    m = self.mla
                    qd = self.n_heads * (m.nope_head_dim + m.rope_head_dim)
                    n_attn += d * qd  # q proj
                    n_attn += d * (m.kv_lora_rank + m.rope_head_dim)  # down
                    n_attn += m.kv_lora_rank * self.n_heads * (
                        m.nope_head_dim + m.v_head_dim
                    )  # up
                    n_attn += self.n_heads * m.v_head_dim * d  # out
                else:
                    n_attn += d * self.n_heads * hd  # q
                    n_attn += 2 * d * self.n_kv_heads * hd  # k, v
                    n_attn += self.n_heads * hd * d  # o
            else:  # mamba
                s = self.ssm
                d_in = s.expand * d
                n_mamba += d * (2 * d_in + 2 * s.n_groups * s.d_state + d_in // s.head_dim)
                n_mamba += s.d_conv * (d_in + 2 * s.n_groups * s.d_state)
                n_mamba += d_in * d  # out proj
        n_ffn = 0
        for kind in self.ffn_kinds():
            if kind == "dense":
                mult = 3 if self.mlp_type == "swiglu" else 2
                n_ffn += mult * d * f
            elif kind == "moe":
                mo = self.moe
                mult = 3 if self.mlp_type == "swiglu" else 2
                n_ffn += mo.n_experts * mult * d * mo.d_expert
                n_ffn += mo.n_shared * mult * d * mo.d_expert
                n_ffn += d * mo.n_experts  # router
        n_embed = V * d * (1 if self.tie_embeddings else 2)
        n_enc = 0
        if self.is_enc_dec:
            # encoder self-attn + ffn + decoder cross-attn
            per_enc = 4 * d * self.n_heads * hd + 3 * d * f
            n_enc += self.n_encoder_layers * per_enc
            n_enc += self.n_layers * 4 * d * self.n_heads * hd  # cross-attn
        return n_attn + n_mamba + n_ffn + n_embed + n_enc

    def active_param_count(self) -> int:
        """Active (per-token) parameters — MoE counts top_k + shared only."""
        if self.moe is None:
            return self.param_count()
        full = self.param_count()
        mo = self.moe
        mult = 3 if self.mlp_type == "swiglu" else 2
        per_expert = mult * self.d_model * mo.d_expert
        n_moe_layers = sum(1 for k in self.ffn_kinds() if k == "moe")
        inactive = n_moe_layers * (mo.n_experts - mo.top_k) * per_expert
        return full - inactive


# ---------------------------------------------------------------------------
# JSON round-trip (checkpoint manifests carry the model config so a serving
# process can rebuild the bundle without knowing the training script's arch)
# ---------------------------------------------------------------------------


def config_to_dict(cfg: ModelConfig) -> dict:
    """JSON-serializable form of a :class:`ModelConfig` (nested sub-configs
    become dicts, tuples become lists)."""
    return dataclasses.asdict(cfg)


def config_from_dict(d: dict) -> ModelConfig:
    """Inverse of :func:`config_to_dict` — rebuilds nested sub-configs and
    restores the tuple-typed fields JSON turned into lists."""
    d = dict(d)
    if d.get("moe") is not None:
        d["moe"] = MoEConfig(**d["moe"])
    if d.get("ssm") is not None:
        s = dict(d["ssm"])
        if s.get("a_init_range") is not None:
            s["a_init_range"] = tuple(s["a_init_range"])
        d["ssm"] = SSMConfig(**s)
    if d.get("mla") is not None:
        d["mla"] = MLAConfig(**d["mla"])
    for k in ("mrope_sections", "hybrid_period"):
        if d.get(k) is not None:
            d[k] = tuple(d[k])
    return ModelConfig(**d)
