"""Mixture-of-Experts: sort-based capacity dispatch (dropless up to the
capacity factor) + optional DeepSeek-style shared experts.

Why sort-based: the dry-run shapes push up to 1M tokens through a layer; a
one-hot dispatch tensor (T, E, C) would be astronomically large, while the
sort-based path is O(T·top_k) memory and lowers to gather/scatter + one
batched (E, C, d) × (E, d, f) einsum — which is also what a TPU expert-
parallel layout wants (the einsum's E axis shards; tokens move via the same
gather/scatter pattern an all-to-all would implement).

Router math follows the configured ``gate_mode``:
* ``softmax_topk`` (Mixtral): softmax over the top-k *logits*.
* ``topk_softmax`` (DeepSeek): softmax over all experts, keep top-k, renorm.

The load-balance auxiliary loss is the standard Switch/Mixtral form:
``E * sum_e f_e * p_e`` with f = token fraction, p = mean router prob.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.config import ModelConfig, MoEConfig
from repro.models.layers import KeyGen, normal_init
from repro.models.mlp import init_mlp, mlp_forward, spec_mlp


def init_moe(kg: KeyGen, cfg: ModelConfig, dtype) -> Dict[str, Any]:
    mo = cfg.moe
    d, fe = cfg.d_model, mo.d_expert
    s = cfg.init_scale
    mult3 = cfg.mlp_type == "swiglu"
    p: Dict[str, Any] = {
        "router": normal_init(kg(), (d, mo.n_experts), s, jnp.float32),
    }
    if mult3:
        p["w_gate"] = normal_init(kg(), (mo.n_experts, d, fe), s, dtype)
        p["w_up"] = normal_init(kg(), (mo.n_experts, d, fe), s, dtype)
        p["w_down"] = normal_init(kg(), (mo.n_experts, fe, d), s, dtype)
    else:
        p["w_up"] = normal_init(kg(), (mo.n_experts, d, fe), s, dtype)
        p["w_down"] = normal_init(kg(), (mo.n_experts, fe, d), s, dtype)
    if mo.n_shared:
        p["shared"] = init_mlp(kg, d, mo.n_shared * fe, cfg.mlp_type, s, dtype)
    return p


def spec_moe(cfg: ModelConfig, model_axis: str = "model") -> Dict[str, Any]:
    mo = cfg.moe
    mp = model_axis
    # Experts' hidden dim shards over the model axis (tensor-parallel experts);
    # the expert axis itself is sharded instead when E % mesh == 0 (the
    # launcher's sanitizer keeps whichever is divisible — see launch/specs).
    sp: Dict[str, Any] = {"router": P(None, None)}
    if cfg.mlp_type == "swiglu":
        sp["w_gate"] = P(None, None, mp)
        sp["w_up"] = P(None, None, mp)
        sp["w_down"] = P(None, mp, None)
    else:
        sp["w_up"] = P(None, None, mp)
        sp["w_down"] = P(None, mp, None)
    if mo.n_shared:
        sp["shared"] = spec_mlp(cfg.mlp_type, model_axis)
    return sp


def _route(
    logits: jnp.ndarray, mo: MoEConfig
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Return (topk_idx (T,k), topk_weight (T,k), probs (T,E))."""
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    if mo.gate_mode == "softmax_topk":
        top_logit, top_idx = jax.lax.top_k(logits, mo.top_k)
        top_w = jax.nn.softmax(top_logit.astype(jnp.float32), axis=-1)
    elif mo.gate_mode == "topk_softmax":
        top_p, top_idx = jax.lax.top_k(probs, mo.top_k)
        top_w = top_p / jnp.sum(top_p, axis=-1, keepdims=True)
    else:
        raise ValueError(mo.gate_mode)
    return top_idx, top_w, probs


def aux_load_balance_loss(probs: jnp.ndarray, top_idx: jnp.ndarray, mo: MoEConfig):
    e = mo.n_experts
    counts = jnp.zeros((e,), jnp.float32).at[top_idx.reshape(-1)].add(1.0)
    frac = counts / (top_idx.shape[0] * mo.top_k)
    mean_p = jnp.mean(probs, axis=0)
    return e * jnp.sum(frac * mean_p) * mo.router_aux_coef


def moe_forward(
    params: Dict, cfg: ModelConfig, x: jnp.ndarray
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, d) -> (y, aux_loss)."""
    mo = cfg.moe
    b, s, d = x.shape
    t = b * s
    xf = x.reshape(t, d)

    logits = xf.astype(jnp.float32) @ params["router"]
    top_idx, top_w, probs = _route(logits, mo)
    aux = aux_load_balance_loss(probs, top_idx, mo)

    # ---- sort-based capacity dispatch -------------------------------------
    k = mo.top_k
    cap = int(mo.capacity_factor * t * k / mo.n_experts)
    cap = max(1, min(cap, t * k))
    flat_expert = top_idx.reshape(-1)  # (T*k,)
    flat_weight = top_w.reshape(-1)  # (T*k,)
    order = jnp.argsort(flat_expert)  # stable sort: tokens grouped by expert
    counts = jnp.zeros((mo.n_experts,), jnp.int32).at[flat_expert].add(1)
    offsets = jnp.cumsum(counts) - counts  # exclusive prefix
    # (E, C) gather positions into `order`, padded past each expert's count
    slot = jnp.arange(cap, dtype=jnp.int32)
    gather_pos = offsets[:, None] + slot[None, :]
    in_range = slot[None, :] < jnp.minimum(counts[:, None], cap)
    gather_pos = jnp.clip(gather_pos, 0, t * k - 1)
    src = order[gather_pos]  # (E, C) indices into the flattened (T*k) stream
    tok = src // k  # source token ids
    x_exp = xf[tok] * in_range[..., None].astype(xf.dtype)  # (E, C, d)

    # ---- expert FFN as one batched einsum ---------------------------------
    if cfg.mlp_type == "swiglu":
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", x_exp, params["w_gate"]))
        h = h * jnp.einsum("ecd,edf->ecf", x_exp, params["w_up"])
    elif cfg.mlp_type == "squared_relu":
        h = jnp.square(jax.nn.relu(jnp.einsum("ecd,edf->ecf", x_exp, params["w_up"])))
    else:
        h = jax.nn.gelu(jnp.einsum("ecd,edf->ecf", x_exp, params["w_up"]))
    y_exp = jnp.einsum("ecf,efd->ecd", h, params["w_down"])  # (E, C, d)

    # ---- combine: weighted scatter-add back to tokens ----------------------
    w = flat_weight[src] * in_range.astype(jnp.float32)  # (E, C)
    y = jnp.zeros((t, d), y_exp.dtype)
    y = y.at[tok.reshape(-1)].add(
        (y_exp * w[..., None].astype(y_exp.dtype)).reshape(-1, d)
    )

    if mo.n_shared:
        y = y + mlp_forward(params["shared"], cfg.mlp_type, xf)
    return y.reshape(b, s, d), aux
