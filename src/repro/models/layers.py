"""Shared primitive layers: norms, initializers, linear helpers.

Parameters are plain nested dicts of jnp arrays; every module exposes
``init_*`` (params), a forward function, and ``spec_*`` (a PartitionSpec tree
with the same structure, used by the launcher for pjit shardings).
"""
from __future__ import annotations

from typing import Any, Optional, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def normal_init(key, shape, scale: float, dtype) -> jnp.ndarray:
    return (scale * jax.random.normal(key, shape, dtype=jnp.float32)).astype(dtype)


def zeros_init(shape, dtype) -> jnp.ndarray:
    return jnp.zeros(shape, dtype=dtype)


def ones_init(shape, dtype) -> jnp.ndarray:
    return jnp.ones(shape, dtype=dtype)


def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float) -> jnp.ndarray:
    """RMSNorm in fp32 with cast back (the production-standard recipe)."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    normed = xf * jax.lax.rsqrt(var + eps)
    return (normed * scale.astype(jnp.float32)).astype(x.dtype)


def init_rms_norm(d: int, dtype) -> dict:
    return {"scale": ones_init((d,), dtype)}


def spec_rms_norm() -> dict:
    return {"scale": P(None)}


def dense(x: jnp.ndarray, w: jnp.ndarray, b: Optional[jnp.ndarray] = None):
    y = x @ w
    if b is not None:
        y = y + b.astype(y.dtype)
    return y


class KeyGen:
    """Split-on-demand PRNG key stream for sequential init code."""

    def __init__(self, key):
        self._key = key

    def __call__(self):
        self._key, sub = jax.random.split(self._key)
        return sub
