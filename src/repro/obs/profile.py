"""Profiler hooks: ``jax.profiler`` capture + compile-seconds attribution.

Two instruments, both safe to leave in production code paths:

* :func:`profile_capture` — context manager around ``jax.profiler.trace``.
  ``outdir=None`` (the default everywhere) is a strict no-op; any profiler
  failure (unsupported backend, missing tensorboard plugin) degrades to a
  warning rather than killing a benchmark run.

* :func:`track_compile_time` — measures seconds spent compiling inside the
  ``with`` body, via ``jax.monitoring``'s event-duration listeners (the
  channel JAX's own internal telemetry uses; events fire with names like
  ``/jax/core/compile/backend_compile_duration``).  ``jax.monitoring`` has
  no public unregister, so one module-level listener is installed lazily on
  first use and fans out to a stack of active :class:`CompileStats` —
  nesting works, and an empty stack makes the listener a dict lookup + no-op.
  On jax builds without ``jax.monitoring`` the stats come back with
  ``supported=False`` and zero seconds.
"""
from __future__ import annotations

import contextlib
import dataclasses
import warnings
from typing import Dict, Iterator, List, Optional


@dataclasses.dataclass
class CompileStats:
    """Compile seconds observed while a ``track_compile_time`` block ran."""

    seconds: float = 0.0
    events: Dict[str, float] = dataclasses.field(default_factory=dict)
    supported: bool = True

    def _observe(self, event: str, duration_s: float) -> None:
        self.events[event] = self.events.get(event, 0.0) + duration_s
        # backend_compile is a sub-phase of the jaxpr-trace events; summing
        # all "/compile/" events would double-count, so track the dominant
        # top-level one for `seconds` and keep the full split in `events`.
        if event.endswith("backend_compile_duration"):
            self.seconds += duration_s


_ACTIVE: List[CompileStats] = []
_LISTENER_INSTALLED = False


def _listener(event: str, duration_s: float, **kwargs) -> None:
    if "compile" in event and _ACTIVE:
        _ACTIVE[-1]._observe(event, duration_s)


def _ensure_listener() -> bool:
    global _LISTENER_INSTALLED
    if _LISTENER_INSTALLED:
        return True
    try:
        from jax import monitoring
        monitoring.register_event_duration_secs_listener(_listener)
    except Exception:  # pragma: no cover - old/stripped jax builds
        return False
    _LISTENER_INSTALLED = True
    return True


@contextlib.contextmanager
def track_compile_time() -> Iterator[CompileStats]:
    """Yield a :class:`CompileStats` accumulating compile seconds spent
    inside the block.  Zero overhead beyond a listener dict update per
    compile event; nesting attributes each compile to the innermost block."""
    stats = CompileStats(supported=_ensure_listener())
    _ACTIVE.append(stats)
    try:
        yield stats
    finally:
        _ACTIVE.remove(stats)


@contextlib.contextmanager
def profile_capture(outdir: Optional[str]) -> Iterator[None]:
    """Capture a ``jax.profiler`` trace of the block into ``outdir``.

    ``outdir=None`` is a no-op (the default wiring everywhere), so call
    sites need no conditional.  The resulting directory opens in
    TensorBoard's profile plugin or via Perfetto's XPlane importer.
    """
    if not outdir:
        yield
        return
    import jax

    try:
        ctx = jax.profiler.trace(outdir)
    except Exception as e:  # pragma: no cover - backend without profiler
        warnings.warn(f"jax.profiler.trace unavailable ({e}); not profiling")
        yield
        return
    with ctx:
        yield
