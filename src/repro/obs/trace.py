"""Flight-recorder span tracing: one structured event stream for every driver.

The recorder is a **host-side** sink: drivers already sync per-round metrics,
bytes and simulated seconds to the host through the single
:func:`repro.core.driver.record_flags` funnel, and the recorder simply turns
those values into nested spans — it never touches device data, adds no
synchronization, and when no recorder is attached (``History.recorder is
None``, the default) every hook is a single ``getattr`` returning ``None``,
so the telemetry-off path is bit-identical to a pre-obs run by construction.

Two clocks, same discipline as :class:`~repro.core.trainer.History`:

* the **round timeline** (tracks ``rounds`` and ``agent <i>``) runs on
  *simulated* seconds when the experiment carries a systems profile — span
  k's duration is exactly the ``sim_time_s[k]`` the accountant recorded;
  without a profile each round gets a fixed nominal width
  (:data:`DEFAULT_ROUND_S`) so the trace still renders;
* **serve request lifecycles** (queue → prefill → decode, one track per
  agent) run on the load generator's simulated clock from
  :func:`repro.serve.load.run_load`.

Spans are plain host data; :mod:`repro.obs.export` serializes them to the
Chrome trace-event format for ``ui.perfetto.dev``.
"""
from __future__ import annotations

import contextlib
import dataclasses
import time
from typing import Any, Dict, List, Mapping, Optional

#: Nominal round width (seconds) when no systems model prices the run — the
#: trace keeps rendering with rounds as fixed-width slots.
DEFAULT_ROUND_S = 1e-3

#: The driver timeline track: one span per executed communication round.
ROUND_TRACK = "rounds"


@dataclasses.dataclass
class Span:
    """One complete slice: ``[t0, t0 + dur)`` on ``track``."""

    track: str
    name: str
    t0: float  # seconds on the recorder's clock
    dur: float
    cat: str = "span"
    args: Dict[str, Any] = dataclasses.field(default_factory=dict)


@dataclasses.dataclass
class Instant:
    """A zero-duration marker (eval readouts, checkpoint writes)."""

    track: str
    name: str
    t: float
    args: Dict[str, Any] = dataclasses.field(default_factory=dict)


class TraceRecorder:
    """Collects spans from the drivers / serve loop; exported via
    :mod:`repro.obs.export`.

    Attach one to a run by passing ``recorder=`` to
    :class:`~repro.core.experiment.Experiment` (or ``--trace-out`` on the
    launchers); the drivers feed it through their existing recording seams.
    """

    def __init__(self, meta: Optional[Mapping[str, Any]] = None):
        self.enabled = True
        self.spans: List[Span] = []
        self.instants: List[Instant] = []
        self.meta: Dict[str, Any] = dict(meta or {})
        self._round_clock = 0.0

    # -- generic API --------------------------------------------------------

    @property
    def clock_s(self) -> float:
        """Current position of the round timeline (simulated seconds)."""
        return self._round_clock

    def add_span(
        self, track: str, name: str, t0: float, dur: float,
        *, cat: str = "span", **args: Any,
    ) -> Span:
        span = Span(
            track=track, name=name, t0=float(t0), dur=max(float(dur), 0.0),
            cat=cat, args=args,
        )
        self.spans.append(span)
        return span

    def add_instant(self, track: str, name: str, t: float, **args: Any) -> None:
        self.instants.append(Instant(track=track, name=name, t=float(t), args=args))

    @contextlib.contextmanager
    def host_span(self, name: str, *, track: str = "host", **args: Any):
        """Time a host-side block (compile, export, ...) with real seconds.

        Host spans live on their own track so real wall time is never
        interleaved with the simulated round timeline.
        """
        t0 = time.perf_counter()
        try:
            yield
        finally:
            self.add_span(
                track, name, t0, time.perf_counter() - t0, cat="host", **args
            )

    # -- driver timeline ----------------------------------------------------

    def record_round(
        self,
        k: int,
        is_global: bool,
        nbytes: int,
        seconds: Optional[float] = None,
        parts: Optional[Mapping[str, float]] = None,
        **args: Any,
    ) -> None:
        """One executed communication round on the ``rounds`` track.

        ``seconds`` is the round's simulated duration (``None`` — no systems
        model — renders as a :data:`DEFAULT_ROUND_S` slot); ``parts`` is the
        optional phase decomposition (``local_steps`` + ``gossip_mix`` /
        ``server_sync`` from :meth:`RoundTimeModel.round_parts`), drawn as
        sequential child spans nested inside the round span.
        """
        if seconds is not None:
            dur = float(seconds)
        elif parts:
            dur = float(sum(parts.values()))
        else:
            dur = DEFAULT_ROUND_S
        t0 = self._round_clock
        name = "server_round" if is_global else "gossip_round"
        span_args = dict(round=int(k), bytes=int(nbytes), **args)
        if seconds is not None:
            span_args["sim_s"] = float(seconds)
        self.add_span(ROUND_TRACK, name, t0, dur, cat="round", **span_args)
        if parts:
            cursor = t0
            for phase, pdur in parts.items():
                self.add_span(
                    ROUND_TRACK, phase, cursor, float(pdur), cat="phase",
                    round=int(k),
                )
                cursor += float(pdur)
        self._round_clock = t0 + dur

    def record_agent_round(
        self, k: int, agent: int, t0: float, dur: float,
        is_global: bool, **args: Any,
    ) -> None:
        """Per-agent activity for round ``k`` (events driver: staleness,
        gating and participation per agent as its own Perfetto track)."""
        self.add_span(
            f"agent {agent}",
            "server_round" if is_global else "gossip_round",
            t0, dur, cat="agent", round=int(k), **args,
        )

    # -- serve request lifecycles -------------------------------------------

    def record_request(self, req: Any) -> None:
        """Queue → prefill → decode spans for one finished serve request,
        on the owning agent's track (timestamps from the simulated clock the
        load loop stamped onto the :class:`~repro.serve.batcher.Request`)."""
        track = f"agent {req.agent_id}"
        base = dict(rid=int(req.rid))
        if getattr(req, "slot", None) is not None:
            base["slot"] = int(req.slot)
        if req.admit_s is not None and req.admit_s > req.arrival_s:
            self.add_span(
                track, "queue", req.arrival_s, req.admit_s - req.arrival_s,
                cat="serve", **base,
            )
        if req.admit_s is not None and req.first_token_s is not None:
            self.add_span(
                track, "prefill", req.admit_s,
                req.first_token_s - req.admit_s, cat="serve", **base,
            )
        if req.first_token_s is not None and req.done_s is not None:
            self.add_span(
                track, "decode", req.first_token_s,
                req.done_s - req.first_token_s, cat="serve",
                tokens=len(req.tokens), **base,
            )

    # -- readouts -----------------------------------------------------------

    def round_table(self) -> List[tuple]:
        """``(round, kind, bytes, dur)`` per round span, in record order —
        the attribution the driver-parity tests compare across drivers."""
        return [
            (s.args["round"], s.name, s.args["bytes"], s.dur)
            for s in self.spans
            if s.cat == "round"
        ]

    def tracks(self) -> List[str]:
        seen: Dict[str, None] = {}
        for s in self.spans:
            seen.setdefault(s.track)
        for i in self.instants:
            seen.setdefault(i.track)
        return list(seen)
