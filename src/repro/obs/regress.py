"""Perf-regression gate: diff fresh ``BENCH_*.json`` against baselines.

Stdlib-only on purpose — the CI ``regress-gate`` lane runs this against two
directories of JSON artifacts and needs nothing beyond a Python interpreter
(no jax, no numpy).

Tolerance philosophy (also documented in DESIGN.md §16):

* **deterministic** metrics — simulated seconds, byte counts, memory
  ratios, round counts, boolean pins — are functions of seeds and byte
  models, not of the machine, so they get tight tolerances (exact for
  counts/flags, 1.25× for simulated time: loose enough to absorb an
  intentional reshuffle, tight enough that a 2× cost-model slowdown fails);
* **wall-clock** metrics — per-round seconds, compile seconds, tokens/s —
  vary hugely between the container that committed the baseline and
  whatever CI machine re-measures them, so they only gate at 5×: a true
  order-of-magnitude cliff still fails, scheduler noise never does.

Gate kinds:

========== =============================================================
``time``    lower-is-better; fails when ``fresh > base * tol``
``higher``  higher-is-better; fails when ``fresh < base / tol``
``match``   relative difference must stay within ``tol`` (0 → exact)
``flag``    a boolean pin; fails when baseline is truthy and fresh is not
``count``   integer budget; fails when ``fresh > base + tol``
========== =============================================================
"""
from __future__ import annotations

import dataclasses
import glob
import json
import os
from typing import Any, Dict, List, Optional, Tuple

#: Bump when artifact/manifest layout changes shape.
BENCH_SCHEMA_VERSION = 1

_KINDS = ("time", "higher", "match", "flag", "count")

# Wall-clock measurements gate loosely: baselines come from a different
# machine than the CI runner that re-measures them.
WALL_TOL = 5.0
# Simulated time is deterministic (numpy-seeded fleets × byte models);
# 1.25x absorbs intentional retunes while a 2x cost slowdown still fails.
SIM_TOL = 1.25


@dataclasses.dataclass(frozen=True)
class MetricGate:
    """One gated metric inside a bench payload, addressed by dotted path."""

    path: str  # e.g. "results.scan.per_round_s" ("." splits keys)
    kind: str  # one of _KINDS
    tol: float = 0.0

    def __post_init__(self):
        if self.kind not in _KINDS:
            raise ValueError(f"unknown gate kind {self.kind!r}")


@dataclasses.dataclass
class Finding:
    """The verdict for one gate on one bench."""

    bench: str
    path: str
    kind: str
    status: str  # "ok" | "regressed" | "missing" | "skipped"
    base: Any = None
    fresh: Any = None
    detail: str = ""

    @property
    def failed(self) -> bool:
        return self.status in ("regressed", "missing")


# Per-bench gates, keyed by the BENCH_<key>.json key.  Paths index into the
# committed payloads; deterministic pins tight, wall-clock loose (see module
# docstring).  A path absent from BOTH payloads is skipped (schema drift in
# an old baseline), absent only from the fresh payload is a failure.
GATES: Dict[str, List[MetricGate]] = {
    "driver": [
        MetricGate("results.loop.per_round_s", "time", WALL_TOL),
        MetricGate("results.scan.per_round_s", "time", WALL_TOL),
        MetricGate("results.events.per_round_s", "time", WALL_TOL),
        MetricGate("results.scan.compile_s", "time", WALL_TOL),
        MetricGate("results.loop.a2a_rounds", "match", 0.0),
        MetricGate("results.scan.a2a_rounds", "match", 0.0),
        MetricGate("results.loop.final_loss", "match", 0.05),
        MetricGate("results.scan.final_loss", "match", 0.05),
        MetricGate("speedup", "higher", 3.0),
    ],
    "async": [
        MetricGate(
            "profiles.lognormal-stragglers.async.total_sim_time_s",
            "time", SIM_TOL,
        ),
        MetricGate(
            "profiles.lognormal-stragglers.sync.total_sim_time_s",
            "time", SIM_TOL,
        ),
        MetricGate("profiles.wan-gossip.async.total_sim_time_s", "time", SIM_TOL),
        MetricGate("profiles.free.bit_identical_loss", "flag"),
        MetricGate("reprice.self_exact", "flag"),
    ],
    "sparse": [
        MetricGate("results.n=10000.sparse_mixing_state_bytes", "match", 0.0),
        MetricGate("results.n=10000.per_round_s", "time", WALL_TOL),
        MetricGate("parity.ok", "flag"),
    ],
    "robust": [
        MetricGate("robustness_flip", "flag"),
        MetricGate("trimmed_within_10pct", "flag"),
        MetricGate("rows.signflip+trimmed.total_bytes", "match", 0.0),
    ],
    "serve": [
        MetricGate("memory.64.ratio", "higher", 1.01),
        MetricGate("bit_identity.admit_vs_dense", "flag"),
        MetricGate("bit_identity.step_vs_dense", "flag"),
        MetricGate("rates.rate=8.tokens_per_s", "higher", WALL_TOL),
        MetricGate("rates.rate=8.p99_s", "time", WALL_TOL),
    ],
    "roofline": [
        MetricGate("summary.n_fail", "count", 0),
    ],
}


def lookup(payload: Any, path: str) -> Tuple[bool, Any]:
    """Resolve a dotted path; returns ``(found, value)``."""
    node = payload
    for part in path.split("."):
        if not isinstance(node, dict) or part not in node:
            return False, None
        node = node[part]
    return True, node


def _check(gate: MetricGate, base: Any, fresh: Any) -> Tuple[bool, str]:
    """(ok, detail) for one gate; raw values already looked up."""
    if gate.kind == "flag":
        if base and not fresh:
            return False, "pinned flag went false"
        return True, ""
    if gate.kind == "count":
        if fresh > base + gate.tol:
            return False, f"count {fresh} > {base} + {gate.tol:g}"
        return True, ""
    base = float(base)
    fresh = float(fresh)
    if gate.kind == "time":
        limit = base * gate.tol
        if fresh > limit + 1e-12:
            return False, f"{fresh:.6g} > {base:.6g} × {gate.tol:g}"
        return True, ""
    if gate.kind == "higher":
        limit = base / gate.tol
        if fresh < limit - 1e-12:
            return False, f"{fresh:.6g} < {base:.6g} / {gate.tol:g}"
        return True, ""
    # match
    denom = max(abs(base), 1e-12)
    rel = abs(fresh - base) / denom
    if rel > gate.tol + 1e-12:
        return False, f"rel diff {rel:.3g} > {gate.tol:g}"
    return True, ""


def compare_payloads(
    bench: str, base: Dict[str, Any], fresh: Dict[str, Any],
    gates: Optional[List[MetricGate]] = None,
) -> List[Finding]:
    """Run every gate registered for ``bench`` over one payload pair."""
    findings: List[Finding] = []
    for gate in GATES.get(bench, []) if gates is None else gates:
        b_found, b_val = lookup(base, gate.path)
        f_found, f_val = lookup(fresh, gate.path)
        if not b_found and not f_found:
            findings.append(Finding(
                bench, gate.path, gate.kind, "skipped",
                detail="path absent from both payloads",
            ))
            continue
        if not b_found:
            findings.append(Finding(
                bench, gate.path, gate.kind, "skipped", fresh=f_val,
                detail="no baseline value (new metric)",
            ))
            continue
        if not f_found:
            findings.append(Finding(
                bench, gate.path, gate.kind, "missing", base=b_val,
                detail="metric disappeared from fresh artifact",
            ))
            continue
        ok, detail = _check(gate, b_val, f_val)
        findings.append(Finding(
            bench, gate.path, gate.kind, "ok" if ok else "regressed",
            base=b_val, fresh=f_val, detail=detail,
        ))
    return findings


def bench_key(path: str) -> Optional[str]:
    """``.../BENCH_driver.json`` → ``driver``; non-BENCH files → None."""
    name = os.path.basename(path)
    if not (name.startswith("BENCH_") and name.endswith(".json")):
        return None
    return name[len("BENCH_"):-len(".json")]


def load_artifacts(art_dir: str) -> Dict[str, Dict[str, Any]]:
    """Map bench key → payload for a directory of artifacts.

    Prefers the ``MANIFEST.json`` index when present (so the gate sees
    exactly what the harness declared); falls back to globbing
    ``BENCH_*.json`` for pre-manifest baselines.
    """
    out: Dict[str, Dict[str, Any]] = {}
    manifest = os.path.join(art_dir, "MANIFEST.json")
    if os.path.exists(manifest):
        with open(manifest) as f:
            m = json.load(f)
        for key, entry in m.get("benches", {}).items():
            p = os.path.join(art_dir, entry["path"])
            if os.path.exists(p):
                with open(p) as f:
                    out[key] = json.load(f)
        if out:
            return out
    for p in sorted(glob.glob(os.path.join(art_dir, "BENCH_*.json"))):
        key = bench_key(p)
        if key is not None:
            with open(p) as f:
                out[key] = json.load(f)
    return out


def compare_dirs(
    baseline_dir: str, fresh_dir: str,
    only: Optional[List[str]] = None,
) -> List[Finding]:
    """Gate every bench present in both directories; skip the rest."""
    base = load_artifacts(baseline_dir)
    fresh = load_artifacts(fresh_dir)
    findings: List[Finding] = []
    keys = sorted(set(base) | set(fresh))
    if only:
        keys = [k for k in keys if k in set(only)]
    for key in keys:
        if key not in GATES:
            continue
        if key not in fresh:
            findings.append(Finding(
                key, "*", "-", "skipped",
                detail="bench not in fresh run (subset run?)",
            ))
            continue
        if key not in base:
            findings.append(Finding(
                key, "*", "-", "skipped",
                detail="no committed baseline yet",
            ))
            continue
        findings.extend(compare_payloads(key, base[key], fresh[key]))
    return findings


def format_findings(findings: List[Finding]) -> str:
    """Fixed-width report table, one line per gate."""
    lines = [f"{'bench':<10} {'metric':<50} {'status':<10} detail"]
    for f in findings:
        vals = ""
        if f.status in ("ok", "regressed") and f.base is not None:
            vals = f" (base={f.base!r:.24} fresh={f.fresh!r:.24})"
        lines.append(
            f"{f.bench:<10} {f.path:<50} {f.status:<10} {f.detail}{vals}"
        )
    n_fail = sum(1 for f in findings if f.failed)
    n_ok = sum(1 for f in findings if f.status == "ok")
    n_skip = sum(1 for f in findings if f.status == "skipped")
    lines.append(f"-- {n_ok} ok, {n_fail} regressed, {n_skip} skipped")
    return "\n".join(lines)
