"""Observability: span tracing, Chrome-trace export, metrics, profiling,
and the perf-regression gate.

Import layering matters here: :mod:`repro.obs.regress` (and this package
``__init__``) must stay stdlib-only so the CI regress-gate lane can run
``benchmarks/check_regress.py`` on a bare interpreter, and
:mod:`repro.obs.profile` imports jax lazily inside its context managers.
"""
from repro.obs.export import (
    to_chrome_trace,
    validate_chrome_trace,
    write_trace,
)
from repro.obs.metrics import (
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    read_jsonl,
)
from repro.obs.profile import CompileStats, profile_capture, track_compile_time
from repro.obs.regress import (
    GATES,
    Finding,
    MetricGate,
    bench_key,
    compare_dirs,
    compare_payloads,
    format_findings,
)
from repro.obs.trace import DEFAULT_ROUND_S, ROUND_TRACK, Span, TraceRecorder

__all__ = [
    "CompileStats",
    "Counter",
    "DEFAULT_ROUND_S",
    "Finding",
    "GATES",
    "Gauge",
    "Histogram",
    "MetricGate",
    "MetricsRegistry",
    "ROUND_TRACK",
    "Span",
    "TraceRecorder",
    "bench_key",
    "compare_dirs",
    "compare_payloads",
    "format_findings",
    "profile_capture",
    "read_jsonl",
    "to_chrome_trace",
    "track_compile_time",
    "validate_chrome_trace",
    "write_trace",
]
