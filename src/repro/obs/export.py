"""Chrome trace-event exporter: ``TraceRecorder`` → ``trace.json``.

Emits the JSON-object flavour of the Chrome Trace Event Format —
``{"traceEvents": [...], "displayTimeUnit": "ms", "otherData": {...}}`` —
which both ``chrome://tracing`` and ``ui.perfetto.dev`` open directly.

Mapping:

* each recorder **track** becomes a thread (``tid``) under one process,
  named via an ``"M"`` (metadata) ``thread_name`` event, with ordering
  pinned by ``thread_sort_index`` so ``rounds`` renders above the agent
  tracks;
* every :class:`~repro.obs.trace.Span` becomes an ``"X"`` (complete)
  event with ``ts``/``dur`` in microseconds;
* every :class:`~repro.obs.trace.Instant` becomes an ``"i"`` event with
  thread scope.

:func:`validate_chrome_trace` is the schema check the tests and CI lean on —
it asserts exactly the invariants Perfetto's importer needs.
"""
from __future__ import annotations

import json
import os
from typing import Any, Dict, List

from repro.obs.trace import ROUND_TRACK, TraceRecorder

#: Bump when the emitted structure changes shape.
TRACE_SCHEMA_VERSION = 1

_PID = 1


def _us(seconds: float) -> float:
    return float(seconds) * 1e6


def _track_order(tracks: List[str]) -> Dict[str, int]:
    """rounds first, then host, then agent tracks in numeric order."""

    def key(t: str):
        if t == ROUND_TRACK:
            return (0, 0, t)
        if t == "host":
            return (1, 0, t)
        if t.startswith("agent "):
            try:
                return (2, int(t.split()[1]), t)
            except ValueError:
                return (2, 0, t)
        return (3, 0, t)

    return {t: i for i, t in enumerate(sorted(tracks, key=key))}


def to_chrome_trace(rec: TraceRecorder) -> Dict[str, Any]:
    """Serialize a recorder to a Chrome-trace dict (pure data, no I/O)."""
    order = _track_order(rec.tracks())
    tids = {t: i + 1 for t, i in order.items()}
    events: List[Dict[str, Any]] = []
    for track, tid in sorted(tids.items(), key=lambda kv: kv[1]):
        events.append({
            "name": "thread_name", "ph": "M", "pid": _PID, "tid": tid,
            "args": {"name": track},
        })
        events.append({
            "name": "thread_sort_index", "ph": "M", "pid": _PID, "tid": tid,
            "args": {"sort_index": order[track]},
        })
    for s in rec.spans:
        events.append({
            "name": s.name, "cat": s.cat, "ph": "X",
            "ts": _us(s.t0), "dur": _us(s.dur),
            "pid": _PID, "tid": tids[s.track], "args": dict(s.args),
        })
    for i in rec.instants:
        events.append({
            "name": i.name, "ph": "i", "s": "t",
            "ts": _us(i.t), "pid": _PID, "tid": tids[i.track],
            "args": dict(i.args),
        })
    return {
        "traceEvents": events,
        "displayTimeUnit": "ms",
        "otherData": {"schema_version": TRACE_SCHEMA_VERSION, **rec.meta},
    }


def write_trace(path: str, rec: TraceRecorder) -> Dict[str, Any]:
    """Write ``rec`` to ``path`` as Chrome-trace JSON; returns the dict."""
    obj = to_chrome_trace(rec)
    d = os.path.dirname(os.path.abspath(path))
    os.makedirs(d, exist_ok=True)
    with open(path, "w") as f:
        json.dump(obj, f)
    return obj


def validate_chrome_trace(obj: Any) -> None:
    """Assert ``obj`` is a Perfetto-loadable Chrome trace.

    Raises ``AssertionError`` with a pointed message on the first violation.
    Used by the test suite and by CI's serve-smoke trace check.
    """
    assert isinstance(obj, dict), "trace must be the JSON-object flavour"
    assert "traceEvents" in obj, "missing traceEvents"
    events = obj["traceEvents"]
    assert isinstance(events, list) and events, "traceEvents must be non-empty"
    named_tids = set()
    for e in events:
        assert isinstance(e, dict), f"event not an object: {e!r}"
        ph = e.get("ph")
        assert ph in {"M", "X", "i", "B", "E", "C"}, f"unknown phase {ph!r}"
        assert "pid" in e and "tid" in e, f"event missing pid/tid: {e!r}"
        if ph == "M" and e.get("name") == "thread_name":
            named_tids.add((e["pid"], e["tid"]))
        if ph == "X":
            assert isinstance(e.get("ts"), (int, float)), f"X needs ts: {e!r}"
            assert isinstance(e.get("dur"), (int, float)), f"X needs dur: {e!r}"
            assert e["dur"] >= 0, f"negative dur: {e!r}"
        if ph == "i":
            assert isinstance(e.get("ts"), (int, float)), f"i needs ts: {e!r}"
    used_tids = {
        (e["pid"], e["tid"]) for e in events if e.get("ph") in {"X", "i"}
    }
    assert used_tids <= named_tids, (
        f"events on unnamed threads: {used_tids - named_tids}"
    )
