"""Metrics registry: counters / gauges / histograms with a JSONL sink.

A :class:`MetricsRegistry` is a flat, host-side bag of named instruments.
Producers (``History.telemetry()``, ``ServeReport.telemetry()``, the
launchers) populate one and either inspect it in-process via
:meth:`MetricsRegistry.snapshot` or append it to a JSONL run log via
:meth:`MetricsRegistry.write_jsonl` — one JSON object per line, so a
directory of runs greps/streams like any other log.

Instruments are deliberately primitive — ints/floats and a value list with
summary quantiles — because everything feeding them is already reduced to
host scalars by the accountant/report layers; no locks, no label cartesian
products, no background threads.
"""
from __future__ import annotations

import json
import math
import os
from typing import Any, Dict, Iterable, List, Optional

#: Bump when the snapshot/JSONL structure changes shape.
METRICS_SCHEMA_VERSION = 1


class Counter:
    """Monotone accumulator (bytes sent, rounds run, tokens decoded)."""

    def __init__(self, name: str):
        self.name = name
        self.value: float = 0

    def inc(self, amount: float = 1) -> None:
        if amount < 0:
            raise ValueError(f"counter {self.name}: negative inc {amount}")
        self.value += amount

    def snapshot(self) -> Dict[str, Any]:
        return {"type": "counter", "value": self.value}


class Gauge:
    """Last-write-wins scalar (final loss, tokens/s, p99 latency)."""

    def __init__(self, name: str):
        self.name = name
        self.value: Optional[float] = None

    def set(self, value: float) -> None:
        self.value = float(value)

    def snapshot(self) -> Dict[str, Any]:
        return {"type": "gauge", "value": self.value}


class Histogram:
    """Value collector with count/sum/min/max and p50/p90/p99 readouts."""

    def __init__(self, name: str):
        self.name = name
        self.values: List[float] = []

    def observe(self, value: float) -> None:
        self.values.append(float(value))

    def observe_many(self, values: Iterable[float]) -> None:
        self.values.extend(float(v) for v in values)

    @staticmethod
    def _quantile(sorted_vals: List[float], q: float) -> float:
        # Linear interpolation between closest ranks (numpy default).
        if not sorted_vals:
            return math.nan
        pos = q * (len(sorted_vals) - 1)
        lo = int(math.floor(pos))
        hi = min(lo + 1, len(sorted_vals) - 1)
        frac = pos - lo
        return sorted_vals[lo] * (1 - frac) + sorted_vals[hi] * frac

    def snapshot(self) -> Dict[str, Any]:
        vs = sorted(self.values)
        out: Dict[str, Any] = {"type": "histogram", "count": len(vs)}
        if vs:
            out.update(
                sum=float(sum(vs)), min=vs[0], max=vs[-1],
                p50=self._quantile(vs, 0.50),
                p90=self._quantile(vs, 0.90),
                p99=self._quantile(vs, 0.99),
            )
        return out


class MetricsRegistry:
    """Get-or-create registry of instruments, keyed by name."""

    def __init__(self, meta: Optional[Dict[str, Any]] = None):
        self.meta: Dict[str, Any] = dict(meta or {})
        self._instruments: Dict[str, Any] = {}

    def _get(self, name: str, cls):
        inst = self._instruments.get(name)
        if inst is None:
            inst = self._instruments[name] = cls(name)
        elif not isinstance(inst, cls):
            raise TypeError(
                f"metric {name!r} already registered as "
                f"{type(inst).__name__}, requested {cls.__name__}"
            )
        return inst

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge)

    def histogram(self, name: str) -> Histogram:
        return self._get(name, Histogram)

    def names(self) -> List[str]:
        return sorted(self._instruments)

    def snapshot(self) -> Dict[str, Any]:
        """One JSON-ready dict: meta + every instrument's reduced state."""
        return {
            "schema_version": METRICS_SCHEMA_VERSION,
            "meta": dict(self.meta),
            "metrics": {
                name: self._instruments[name].snapshot()
                for name in self.names()
            },
        }

    def write_jsonl(self, path: str, **extra: Any) -> Dict[str, Any]:
        """Append this registry's snapshot as one line of ``path``."""
        snap = self.snapshot()
        if extra:
            snap["meta"].update(extra)
        d = os.path.dirname(os.path.abspath(path))
        os.makedirs(d, exist_ok=True)
        with open(path, "a") as f:
            f.write(json.dumps(snap) + "\n")
        return snap


def read_jsonl(path: str) -> List[Dict[str, Any]]:
    """Load every snapshot line from a metrics JSONL file."""
    out = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                out.append(json.loads(line))
    return out
