"""Pytree checkpointing: npz payload + json manifest, atomic writes.

Works for any pytree of arrays (PISCO states, model params, optimizer
states).  Leaves are flattened with jax.tree_util key-paths so restore does
not need the original tree definition — it rebuilds nested dicts/lists/tuples
from the manifest.
"""
from __future__ import annotations

import json
import os
import re
import tempfile
from typing import Any, Optional

import jax
import numpy as np

PyTree = Any

_CKPT_RE = re.compile(r"^ckpt_(\d+)\.npz$")


def _flatten_with_paths(tree: PyTree):
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    items = []
    for path, leaf in flat:
        key = "/".join(_path_elem_str(p) for p in path)
        items.append((key, np.asarray(leaf)))
    return items


def _path_elem_str(p) -> str:
    if isinstance(p, jax.tree_util.DictKey):
        return f"d:{p.key}"
    if isinstance(p, jax.tree_util.SequenceKey):
        return f"s:{p.idx}"
    if isinstance(p, jax.tree_util.GetAttrKey):
        return f"a:{p.name}"
    return f"x:{p}"


def save_checkpoint(
    directory: str, step: int, tree: PyTree, *, metadata: Optional[dict] = None
) -> str:
    """Atomically write ckpt_<step>.npz (+ manifest inside the npz).

    ``metadata`` (JSON-serializable) rides along in the manifest — e.g. the
    fleet exporter tags its checkpoints ``{"kind": "fleet"}`` — and is read
    back by :func:`read_manifest` without loading any arrays."""
    os.makedirs(directory, exist_ok=True)
    items = _flatten_with_paths(tree)
    manifest = {
        "step": step,
        "keys": [k for k, _ in items],
        "dtypes": [str(arr.dtype) for _, arr in items],
        "structure": _structure_of(tree),
        "metadata": metadata or {},
    }
    payload = {f"arr_{i}": arr for i, (_, arr) in enumerate(items)}
    payload["__manifest__"] = np.frombuffer(
        json.dumps(manifest).encode(), dtype=np.uint8
    )
    path = os.path.join(directory, f"ckpt_{step}.npz")
    fd, tmp = tempfile.mkstemp(dir=directory, suffix=".tmp")
    try:
        with os.fdopen(fd, "wb") as f:
            np.savez(f, **payload)
        os.replace(tmp, path)
    finally:
        if os.path.exists(tmp):
            os.remove(tmp)
    return path


def _structure_of(tree: PyTree):
    """JSON-serializable recursive structure descriptor."""
    if isinstance(tree, dict):
        return {
            "kind": "dict",
            # jax flattens dict keys in sorted order — mirror it exactly
            "items": {str(k): _structure_of(tree[k]) for k in sorted(tree)},
        }
    if isinstance(tree, (list,)):
        return {"kind": "list", "items": [_structure_of(v) for v in tree]}
    if isinstance(tree, tuple) and hasattr(tree, "_fields"):  # namedtuple
        return {
            "kind": "namedtuple",
            "fields": list(tree._fields),
            "items": [_structure_of(v) for v in tree],
        }
    if isinstance(tree, tuple):
        return {"kind": "tuple", "items": [_structure_of(v) for v in tree]}
    return {"kind": "leaf"}


def _rebuild(structure, leaves_iter):
    kind = structure["kind"]
    if kind == "dict":
        return {k: _rebuild(v, leaves_iter) for k, v in structure["items"].items()}
    if kind == "list":
        return [_rebuild(v, leaves_iter) for v in structure["items"]]
    if kind in ("tuple", "namedtuple"):
        vals = [_rebuild(v, leaves_iter) for v in structure["items"]]
        return tuple(vals)
    return next(leaves_iter)


def _restore_dtype(arr: np.ndarray, name: str) -> np.ndarray:
    """Undo npz's dtype erasure for extension dtypes: ml_dtypes leaves
    (bfloat16, float8_*) come back as raw void bytes — reinterpret them."""
    if str(arr.dtype) == name:
        return arr
    try:
        dt = np.dtype(name)
    except TypeError:
        import ml_dtypes

        dt = np.dtype(getattr(ml_dtypes, name))
    return arr.view(dt) if arr.dtype.kind == "V" else arr.astype(dt)


def restore_checkpoint(path: str) -> tuple:
    """Returns (step, tree). Namedtuples come back as plain tuples; leaf
    dtypes are restored exactly as saved (including ml_dtypes extensions)."""
    with np.load(path) as data:
        manifest = json.loads(bytes(data["__manifest__"].tobytes()).decode())
        arrays = [data[f"arr_{i}"] for i in range(len(manifest["keys"]))]
    dtypes = manifest.get("dtypes")
    if dtypes is not None:
        arrays = [_restore_dtype(a, d) for a, d in zip(arrays, dtypes)]
    tree = _rebuild(manifest["structure"], iter(arrays))
    return manifest["step"], tree


def read_manifest(path: str) -> dict:
    """The checkpoint's manifest (step, leaf keys, structure, metadata)
    without materializing the payload arrays."""
    with np.load(path) as data:
        manifest = json.loads(bytes(data["__manifest__"].tobytes()).decode())
    manifest.setdefault("metadata", {})
    return manifest


def latest_checkpoint(directory: str) -> Optional[str]:
    if not os.path.isdir(directory):
        return None
    best, best_step = None, -1
    for name in os.listdir(directory):
        m = _CKPT_RE.match(name)
        if m and int(m.group(1)) > best_step:
            best_step = int(m.group(1))
            best = os.path.join(directory, name)
    return best
