from repro.checkpoint.checkpoint import (
    save_checkpoint,
    restore_checkpoint,
    latest_checkpoint,
    read_manifest,
)

__all__ = [
    "save_checkpoint",
    "restore_checkpoint",
    "latest_checkpoint",
    "read_manifest",
]
