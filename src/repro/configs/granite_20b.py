"""Granite-20B (code) [arXiv:2405.04324] — llama-arch dense decoder with MQA.

52 layers, d_model 6144, 48 heads (kv=1, i.e. multi-query), d_ff 24576,
vocab 49152.
"""
from repro.models.config import ModelConfig

ARCH_ID = "granite-20b"


def config(dtype: str = "bfloat16") -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        arch_type="dense",
        n_layers=52,
        d_model=6144,
        n_heads=48,
        n_kv_heads=1,
        head_dim=128,
        d_ff=24576,
        vocab_size=49152,
        mlp_type="swiglu",
        rope_theta=10000.0,
        dtype=dtype,
    )


def reduced(dtype: str = "float32") -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-reduced",
        arch_type="dense",
        n_layers=2,
        d_model=128,
        n_heads=8,
        n_kv_heads=1,
        head_dim=16,
        d_ff=512,
        vocab_size=512,
        mlp_type="swiglu",
        dtype=dtype,
        attn_chunk=64,
        remat=False,
    )
