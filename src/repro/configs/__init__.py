"""Architecture registry: the 10 assigned configs + shapes + variants.

``get_config(arch_id)`` / ``get_reduced(arch_id)`` resolve by the public
architecture id (dashes), e.g. ``--arch qwen3-8b`` in the launchers.
"""
from __future__ import annotations

from repro.configs import (
    deepseek_v2_lite_16b,
    granite_20b,
    jamba_v01_52b,
    mamba2_370m,
    mixtral_8x7b,
    nemotron_4_340b,
    qwen2_5_14b,
    qwen2_vl_2b,
    qwen3_8b,
    seamless_m4t_medium,
)
from repro.configs.shapes import SHAPES, InputShape

_MODULES = {
    m.ARCH_ID: m
    for m in (
        nemotron_4_340b,
        seamless_m4t_medium,
        qwen2_vl_2b,
        jamba_v01_52b,
        deepseek_v2_lite_16b,
        mamba2_370m,
        qwen3_8b,
        qwen2_5_14b,
        mixtral_8x7b,
        granite_20b,
    )
}

ARCH_IDS = tuple(_MODULES)

# Beyond-paper variants (EXPERIMENTS.md §Perf)
_VARIANTS = {
    "qwen3-8b-swa": lambda dtype="bfloat16": qwen3_8b.sliding_window_variant(dtype),
}


def get_config(arch_id: str, dtype: str = "bfloat16"):
    if arch_id in _VARIANTS:
        return _VARIANTS[arch_id](dtype)
    return _MODULES[arch_id].config(dtype)


def get_reduced(arch_id: str, dtype: str = "float32"):
    return _MODULES[arch_id.removesuffix("-swa")].reduced(dtype)


__all__ = [
    "ARCH_IDS",
    "SHAPES",
    "InputShape",
    "get_config",
    "get_reduced",
]
