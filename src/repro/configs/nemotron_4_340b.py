"""Nemotron-4-340B [arXiv:2402.16819] — dense GQA decoder, squared-ReLU MLP.

96 layers, d_model 18432, 96 heads (GQA kv=8), d_ff 73728, vocab 256000.
"""
from repro.models.config import ModelConfig

ARCH_ID = "nemotron-4-340b"


def config(dtype: str = "bfloat16") -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        arch_type="dense",
        n_layers=96,
        d_model=18432,
        n_heads=96,
        n_kv_heads=8,
        head_dim=192,
        d_ff=73728,
        vocab_size=256000,
        mlp_type="squared_relu",
        rope_theta=10000.0,
        dtype=dtype,
    )


def reduced(dtype: str = "float32") -> ModelConfig:
    """Smoke-test variant: same family (GQA + squared-ReLU), tiny dims."""
    return ModelConfig(
        name=ARCH_ID + "-reduced",
        arch_type="dense",
        n_layers=2,
        d_model=128,
        n_heads=8,
        n_kv_heads=2,
        head_dim=16,
        d_ff=512,
        vocab_size=512,
        mlp_type="squared_relu",
        dtype=dtype,
        attn_chunk=64,
        remat=False,
    )
