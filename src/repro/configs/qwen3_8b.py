"""Qwen3-8B [hf:Qwen/Qwen3-8B] — dense GQA decoder with QK-norm.

36 layers, d_model 4096, 32 heads (GQA kv=8), d_ff 12288, vocab 151936.
"""
from repro.models.config import ModelConfig

ARCH_ID = "qwen3-8b"


def config(dtype: str = "bfloat16") -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        arch_type="dense",
        n_layers=36,
        d_model=4096,
        n_heads=32,
        n_kv_heads=8,
        head_dim=128,
        d_ff=12288,
        vocab_size=151936,
        mlp_type="swiglu",
        qk_norm=True,
        rope_theta=1_000_000.0,
        dtype=dtype,
    )


def sliding_window_variant(dtype: str = "bfloat16", window: int = 4096) -> ModelConfig:
    """Beyond-paper variant (EXPERIMENTS.md §Perf): sliding-window attention
    unlocks the long_500k decode shape for this otherwise full-attention
    dense arch (bounded rolling KV cache)."""
    import dataclasses

    return dataclasses.replace(
        config(dtype), name=ARCH_ID + "-swa", sliding_window=window
    )


def reduced(dtype: str = "float32") -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-reduced",
        arch_type="dense",
        n_layers=2,
        d_model=128,
        n_heads=4,
        n_kv_heads=2,
        head_dim=32,
        d_ff=256,
        vocab_size=512,
        mlp_type="swiglu",
        qk_norm=True,
        dtype=dtype,
        attn_chunk=64,
        remat=False,
    )
