"""Mamba2-370m [arXiv:2405.21060] — attention-free SSM with SSD.

48 layers, d_model 1024, ssm_state 128, head_dim 64, expand 2, vocab 50280,
tied embeddings.  Every layer is one Mamba-2 block (the block subsumes the
FFN; d_ff=0 in the assignment).
"""
from repro.models.config import ModelConfig, SSMConfig

ARCH_ID = "mamba2-370m"


def config(dtype: str = "bfloat16") -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        arch_type="ssm",
        n_layers=48,
        d_model=1024,
        n_heads=16,  # unused by the SSM path (attn-free)
        n_kv_heads=16,
        d_ff=0,
        vocab_size=50280,
        attn_impl="none",
        ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64, chunk=256),
        tie_embeddings=True,
        dtype=dtype,
    )


def reduced(dtype: str = "float32") -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-reduced",
        arch_type="ssm",
        n_layers=2,
        d_model=128,
        n_heads=4,
        n_kv_heads=4,
        d_ff=0,
        vocab_size=512,
        attn_impl="none",
        ssm=SSMConfig(d_state=16, d_conv=4, expand=2, head_dim=32, chunk=32),
        tie_embeddings=True,
        dtype=dtype,
        remat=False,
    )
