"""Jamba-v0.1 (52B) [arXiv:2403.19887] — hybrid Mamba+attention 1:7, MoE.

32 layers, d_model 4096, 32 heads (GQA kv=8), d_ff 14336, vocab 65536,
MoE 16 experts top-2 on every other layer.  Period of 8 layers contains one
attention mixer (position 4, matching the paper's 1:7 ratio) and 7 Mamba
mixers.

Hardware adaptation (DESIGN.md): Jamba ships Mamba-1 (S6, d_state 16); we
substitute Mamba-2 SSD blocks (d_state 128, head_dim 64) — the chunked-scan
formulation that maps onto the MXU and onto our Pallas ``ssd_scan`` kernel.
Jamba uses no explicit positional encoding (``rope`` disabled for its
attention layers).
"""
from repro.models.config import ModelConfig, MoEConfig, SSMConfig

ARCH_ID = "jamba-v0.1-52b"

HYBRID_PERIOD = ("mamba", "mamba", "mamba", "mamba", "attn", "mamba", "mamba", "mamba")


def config(dtype: str = "bfloat16") -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        arch_type="hybrid",
        n_layers=32,
        d_model=4096,
        n_heads=32,
        n_kv_heads=8,
        head_dim=128,
        d_ff=14336,
        vocab_size=65536,
        mlp_type="swiglu",
        hybrid_period=HYBRID_PERIOD,
        moe=MoEConfig(
            n_experts=16, top_k=2, d_expert=14336, layer_mode="every_2",
        ),
        ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64, chunk=256),
        dtype=dtype,
    )


def reduced(dtype: str = "float32") -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-reduced",
        arch_type="hybrid",
        n_layers=2,
        d_model=128,
        n_heads=4,
        n_kv_heads=2,
        head_dim=32,
        d_ff=256,
        vocab_size=512,
        mlp_type="swiglu",
        hybrid_period=("mamba", "attn"),
        moe=MoEConfig(n_experts=4, top_k=2, d_expert=256, layer_mode="every_2", capacity_factor=4.0),
        ssm=SSMConfig(d_state=16, d_conv=4, expand=2, head_dim=32, chunk=32),
        dtype=dtype,
        attn_chunk=64,
        remat=False,
    )
