"""SeamlessM4T-medium [arXiv:2308.11596] — encoder-decoder multimodal backbone.

12 encoder + 12 decoder layers, d_model 1024, 16 heads (kv=16), d_ff 4096,
vocab 256206.  The speech frontend (mel + conformer feature extractor) is a
stub per the assignment carve-out: the encoder consumes precomputed frame
embeddings of shape (B, T_frames, d_model) provided by ``input_specs()``.
"""
from repro.models.config import ModelConfig

ARCH_ID = "seamless-m4t-medium"

# Stub frontend: ~50 frames/sec after conv subsampling; we expose the frame
# count as a fraction of the text sequence length in input_specs.
FRAMES_PER_SEQ_DIV = 4  # T_frames = seq_len // 4


def config(dtype: str = "bfloat16") -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        arch_type="audio",
        is_enc_dec=True,
        n_encoder_layers=12,
        n_layers=12,
        d_model=1024,
        n_heads=16,
        n_kv_heads=16,
        head_dim=64,
        d_ff=4096,
        vocab_size=256206,
        mlp_type="gelu",
        modality="audio",
        dtype=dtype,
    )


def reduced(dtype: str = "float32") -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-reduced",
        arch_type="audio",
        is_enc_dec=True,
        n_encoder_layers=2,
        n_layers=2,
        d_model=128,
        n_heads=4,
        n_kv_heads=4,
        head_dim=32,
        d_ff=256,
        vocab_size=512,
        mlp_type="gelu",
        modality="audio",
        dtype=dtype,
        attn_chunk=64,
        remat=False,
    )
