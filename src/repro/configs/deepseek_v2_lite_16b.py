"""DeepSeek-V2-Lite (16B) [arXiv:2405.04434] — MLA + fine-grained MoE.

27 layers, d_model 2048, 16 heads, MLA (kv_lora 512, nope 128, rope 64,
v 128), vocab 102400.  MoE: 64 routed experts top-6 + 2 shared experts,
d_expert 1408, first layer dense (first_k_dense_replace=1).

Assignment-line discrepancy (DESIGN.md §4): the bracket mentions "160 routed"
which belongs to full V2; Lite has 64 routed — we follow Lite's model card,
matching the "MoE 64e top-6" figure.  d_ff per the assignment equals the
expert hidden size (1408), the same convention the Mixtral line uses.
"""
from repro.models.config import MLAConfig, ModelConfig, MoEConfig

ARCH_ID = "deepseek-v2-lite-16b"


def config(dtype: str = "bfloat16") -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        arch_type="moe",
        n_layers=27,
        d_model=2048,
        n_heads=16,
        n_kv_heads=16,  # MLA: a single latent serves all heads; field unused
        d_ff=10944,  # dense FFN of the first (non-MoE) layer, per model card
        vocab_size=102400,
        mlp_type="swiglu",
        attn_impl="mla",
        mla=MLAConfig(
            kv_lora_rank=512, q_lora_rank=0, rope_head_dim=64,
            nope_head_dim=128, v_head_dim=128,
        ),
        moe=MoEConfig(
            n_experts=64, top_k=6, d_expert=1408, n_shared=2,
            layer_mode="after_first", gate_mode="topk_softmax",
        ),
        first_k_dense=1,
        dtype=dtype,
    )


def reduced(dtype: str = "float32") -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-reduced",
        arch_type="moe",
        n_layers=2,
        d_model=128,
        n_heads=4,
        n_kv_heads=4,
        d_ff=256,
        vocab_size=512,
        mlp_type="swiglu",
        attn_impl="mla",
        mla=MLAConfig(
            kv_lora_rank=32, rope_head_dim=16, nope_head_dim=32, v_head_dim=32
        ),
        moe=MoEConfig(
            n_experts=4, top_k=2, d_expert=64, n_shared=1,
            layer_mode="after_first", gate_mode="topk_softmax",
            capacity_factor=4.0,
        ),
        first_k_dense=1,
        dtype=dtype,
        attn_chunk=64,
        remat=False,
    )
