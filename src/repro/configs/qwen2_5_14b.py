"""Qwen2.5-14B [hf:Qwen/Qwen2.5-0.5B card family] — dense GQA with QKV bias.

48 layers, d_model 5120, 40 heads (GQA kv=8), d_ff 13824, vocab 152064.
"""
from repro.models.config import ModelConfig

ARCH_ID = "qwen2.5-14b"


def config(dtype: str = "bfloat16") -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        arch_type="dense",
        n_layers=48,
        d_model=5120,
        n_heads=40,
        n_kv_heads=8,
        head_dim=128,
        d_ff=13824,
        vocab_size=152064,
        mlp_type="swiglu",
        qkv_bias=True,
        rope_theta=1_000_000.0,
        dtype=dtype,
    )


def reduced(dtype: str = "float32") -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-reduced",
        arch_type="dense",
        n_layers=2,
        d_model=128,
        n_heads=4,
        n_kv_heads=2,
        head_dim=32,
        d_ff=256,
        vocab_size=512,
        mlp_type="swiglu",
        qkv_bias=True,
        dtype=dtype,
        attn_chunk=64,
        remat=False,
    )
