"""Mixtral-8x7B [arXiv:2401.04088] — MoE (8 experts top-2) + sliding-window
attention.

32 layers, d_model 4096, 32 heads (GQA kv=8), expert d_ff 14336, vocab 32000,
window 4096.  SWA bounds the KV cache, so the long_500k decode shape runs.
"""
from repro.models.config import ModelConfig, MoEConfig

ARCH_ID = "mixtral-8x7b"


def config(dtype: str = "bfloat16") -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        arch_type="moe",
        n_layers=32,
        d_model=4096,
        n_heads=32,
        n_kv_heads=8,
        head_dim=128,
        d_ff=14336,
        vocab_size=32000,
        mlp_type="swiglu",
        sliding_window=4096,
        rope_theta=1_000_000.0,
        moe=MoEConfig(
            n_experts=8, top_k=2, d_expert=14336, layer_mode="all",
            gate_mode="softmax_topk",
        ),
        dtype=dtype,
    )


def reduced(dtype: str = "float32") -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-reduced",
        arch_type="moe",
        n_layers=2,
        d_model=128,
        n_heads=4,
        n_kv_heads=2,
        head_dim=32,
        d_ff=256,
        vocab_size=512,
        mlp_type="swiglu",
        sliding_window=32,
        moe=MoEConfig(n_experts=4, top_k=2, d_expert=256, layer_mode="all", capacity_factor=4.0),
        dtype=dtype,
        attn_chunk=64,
        remat=False,
    )
