"""Qwen2-VL-2B [arXiv:2409.12191] — VLM decoder with M-RoPE + QKV bias.

28 layers, d_model 1536, 12 heads (GQA kv=2), d_ff 8960, vocab 151936.
The ViT vision encoder + projector is a stub per the assignment carve-out:
``input_specs()`` supplies precomputed patch embeddings (B, n_patches,
d_model) that prefix the token stream; M-RoPE position ids (3, B, S) give
patch tokens distinct height/width coordinates.
"""
from repro.models.config import ModelConfig

ARCH_ID = "qwen2-vl-2b"

# Stub vision frontend: patches prefix 1/8 of the sequence budget.
PATCHES_PER_SEQ_DIV = 8


def config(dtype: str = "bfloat16") -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        arch_type="vlm",
        n_layers=28,
        d_model=1536,
        n_heads=12,
        n_kv_heads=2,
        head_dim=128,
        d_ff=8960,
        vocab_size=151936,
        mlp_type="swiglu",
        qkv_bias=True,
        rope_theta=1_000_000.0,
        mrope_sections=(16, 24, 24),  # t/h/w sections of head_dim//2 = 64
        modality="vlm",
        dtype=dtype,
    )


def reduced(dtype: str = "float32") -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-reduced",
        arch_type="vlm",
        n_layers=2,
        d_model=128,
        n_heads=4,
        n_kv_heads=2,
        head_dim=32,
        d_ff=256,
        vocab_size=512,
        mlp_type="swiglu",
        qkv_bias=True,
        mrope_sections=(4, 6, 6),  # head_dim//2 = 16
        modality="vlm",
        dtype=dtype,
        attn_chunk=64,
        remat=False,
    )
