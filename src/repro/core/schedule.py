"""Host-side communication schedules.

The paper's line 8 — ``W^k = J w.p. p else W`` — is an i.i.d. Bernoulli(p)
sequence.  We also provide the deterministic every-H schedule of Gossip-PGA /
HL-SGD for the baseline comparisons (Table 1), and an accountant that tallies
agent-to-agent vs agent-to-server rounds (Figure 4's x/y axes).
"""
from __future__ import annotations

import dataclasses
from typing import Iterator

import numpy as np


@dataclasses.dataclass
class CommAccountant:
    """Counts communication rounds by kind (paper Fig. 4)."""

    agent_to_agent: int = 0
    agent_to_server: int = 0

    def record(self, is_global: bool) -> None:
        if is_global:
            self.agent_to_server += 1
        else:
            self.agent_to_agent += 1

    @property
    def total(self) -> int:
        return self.agent_to_agent + self.agent_to_server


class BernoulliSchedule:
    """PISCO's probabilistic schedule: True => server round (W^k = J)."""

    def __init__(self, p: float, seed: int = 0):
        assert 0.0 <= p <= 1.0
        self.p = p
        self._rng = np.random.default_rng(seed)

    def __call__(self, step: int) -> bool:
        if self.p <= 0.0:
            return False
        if self.p >= 1.0:
            return True
        return bool(self._rng.random() < self.p)


class PeriodicSchedule:
    """Gossip-PGA / HL-SGD style: server every H rounds (H = period)."""

    def __init__(self, period: int):
        assert period >= 1
        self.period = period

    def __call__(self, step: int) -> bool:
        return (step + 1) % self.period == 0


class NeverSchedule:
    def __call__(self, step: int) -> bool:
        return False


class AlwaysSchedule:
    def __call__(self, step: int) -> bool:
        return True


def make_schedule(p: float, seed: int = 0):
    if p <= 0.0:
        return NeverSchedule()
    if p >= 1.0:
        return AlwaysSchedule()
    return BernoulliSchedule(p, seed)
