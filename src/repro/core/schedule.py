"""Host-side communication schedules and cost accounting.

The paper's line 8 — ``W^k = J w.p. p else W`` — is an i.i.d. Bernoulli(p)
sequence.  We also provide the deterministic every-H schedule of Gossip-PGA /
HL-SGD for the baseline comparisons (Table 1), and an accountant that tallies
agent-to-agent vs agent-to-server rounds (Figure 4's x/y axes) — now also in
*bytes*, so compressed-gossip runs can put bits on the x-axis: server rounds
ship full precision while gossip rounds ship whatever the attached compressor
prices (:class:`RoundByteModel`, built by
:func:`repro.core.compression.make_byte_model`).
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

import numpy as np


@dataclasses.dataclass
class CommAccountant:
    """Counts communication rounds — bytes, and simulated seconds — by kind.

    ``per_round_bytes`` keeps the realized per-round charge in round order, so
    bytes-to-target-accuracy readouts stay exact under dynamic networks where
    rounds are no longer interchangeable (link failures / partial
    participation make every round's byte cost a random variable).

    ``per_round_seconds`` is the same ledger on the *time* axis: when a
    systems model is attached (``ExperimentSpec.systems``, DESIGN.md §11) the
    drivers record each round's simulated wall-clock alongside its bytes.
    Runs without a systems model leave the seconds ledger empty — the
    pre-sim behavior, bit-identical.
    """

    agent_to_agent: int = 0
    agent_to_server: int = 0
    agent_to_agent_bytes: int = 0
    agent_to_server_bytes: int = 0
    per_round_bytes: list = dataclasses.field(default_factory=list)
    agent_to_agent_seconds: float = 0.0
    agent_to_server_seconds: float = 0.0
    per_round_seconds: list = dataclasses.field(default_factory=list)

    def record(
        self, is_global: bool, nbytes: int = 0, seconds: Optional[float] = None
    ) -> None:
        self.per_round_bytes.append(int(nbytes))
        if seconds is not None:
            self.per_round_seconds.append(float(seconds))
        if is_global:
            self.agent_to_server += 1
            self.agent_to_server_bytes += nbytes
            if seconds is not None:
                self.agent_to_server_seconds += seconds
        else:
            self.agent_to_agent += 1
            self.agent_to_agent_bytes += nbytes
            if seconds is not None:
                self.agent_to_agent_seconds += seconds

    @property
    def total(self) -> int:
        return self.agent_to_agent + self.agent_to_server

    @property
    def total_bytes(self) -> int:
        return self.agent_to_agent_bytes + self.agent_to_server_bytes

    @property
    def total_seconds(self) -> float:
        return self.agent_to_agent_seconds + self.agent_to_server_seconds


@dataclasses.dataclass(frozen=True)
class RoundByteModel:
    """Closed-form network-wide bytes for one communication round.

    A gossip round moves compressed neighbor messages; a server round moves
    full-precision uploads + broadcast downloads.  Pure arithmetic — the
    sizing lives in :func:`repro.core.compression.make_byte_model`.
    """

    gossip_round_bytes: int
    server_round_bytes: int
    gossip_message_bytes: int = 0  # one agent's compressed message
    server_message_bytes: int = 0  # one agent's full-precision message
    mixes_per_round: int = 1  # mixing invocations per gossip round
    server_payloads: int = 1  # payloads per direction of a server exchange

    def round_bytes(self, is_global: bool) -> int:
        return self.server_round_bytes if is_global else self.gossip_round_bytes

    # -- realized-network pricing (dynamic topologies / participation) ------

    def realized_gossip_bytes(self, directed_messages: int) -> int:
        """Bytes for one gossip round that realized ``directed_messages``
        neighbor messages per mix (2 x realized undirected edges)."""
        return self.mixes_per_round * directed_messages * self.gossip_message_bytes

    def realized_server_bytes(self, participants: int) -> int:
        """Bytes for one server round with ``participants`` agents sampled:
        each participant uploads + downloads ``server_payloads`` payloads."""
        return self.server_payloads * 2 * participants * self.server_message_bytes

    def realized_round_bytes(
        self, is_global: bool, directed_messages: int, participants: int
    ) -> int:
        if is_global:
            return self.realized_server_bytes(participants)
        return self.realized_gossip_bytes(directed_messages)

    def total_bytes(self, n_gossip_rounds: int, n_server_rounds: int) -> int:
        """Exact total for a realized schedule (what the accountant tallies)."""
        return (
            n_gossip_rounds * self.gossip_round_bytes
            + n_server_rounds * self.server_round_bytes
        )

    def expected_bytes(self, rounds: int, p: float) -> float:
        """E[bytes] after ``rounds`` i.i.d. Bernoulli(p) draws."""
        return rounds * (
            p * self.server_round_bytes + (1.0 - p) * self.gossip_round_bytes
        )

    def periodic_bytes(self, rounds: int, period: int) -> int:
        """Exact total under the every-H schedule (server when (k+1) % H == 0)."""
        n_server = rounds // period
        return self.total_bytes(rounds - n_server, n_server)


class BernoulliSchedule:
    """PISCO's probabilistic schedule: True => server round (W^k = J)."""

    def __init__(self, p: float, seed: int = 0):
        assert 0.0 <= p <= 1.0
        self.p = p
        self._rng = np.random.default_rng(seed)

    def __call__(self, step: int) -> bool:
        if self.p <= 0.0:
            return False
        if self.p >= 1.0:
            return True
        return bool(self._rng.random() < self.p)


class PeriodicSchedule:
    """Gossip-PGA / HL-SGD style: server every H rounds (H = period)."""

    def __init__(self, period: int):
        assert period >= 1
        self.period = period

    def __call__(self, step: int) -> bool:
        return (step + 1) % self.period == 0


class NeverSchedule:
    def __call__(self, step: int) -> bool:
        return False


class AlwaysSchedule:
    def __call__(self, step: int) -> bool:
        return True


def make_schedule(p: float, seed: int = 0):
    if p <= 0.0:
        return NeverSchedule()
    if p >= 1.0:
        return AlwaysSchedule()
    return BernoulliSchedule(p, seed)
