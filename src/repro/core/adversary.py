"""Byzantine fault injection + robust aggregation (ROADMAP item 4).

PISCO's robustness story is stressed here with *actively faulty* agents
rather than merely heterogeneous ones: an :class:`AdversaryProcess` corrupts
the selected agents' **outgoing communication payloads** — both gossip
messages and server uploads — while their local compute stays honest (the
corruption is on the wire, which is what a Byzantine peer controls).

Like the topology processes, everything is a pure function of the spec:

* *which* agents are Byzantine is drawn once from the domain-separated
  ``np.random.default_rng((_ADV_TAG, seed))`` stream (pure in ``seed``);
* *what* they send in round ``k`` is pure in ``(seed, k)`` — kinds needing
  per-round randomness fold the round index into an on-device PRNG key, and
  the round index rides the drivers' existing per-round operand path
  (:class:`~repro.core.mixing.DynamicWSlot`), so every driver (loop, scan at
  any block boundary, events) sees identical corruption.

The counterpart is the pluggable **robust server-averaging rule**
(``robust_agg=``): coordinate-wise trimmed mean, coordinate median, or
Krum-style selection (:mod:`repro.utils.pytree` primitives, selected by
:func:`repro.core.mixing.make_robust_agg`) replacing the plain mean at
global-averaging rounds.  Both features compose as a :class:`MixingOps`
wrapper (:func:`make_adversarial_mixing`) — round functions, byte/time
accounting, and compression are untouched: corruption happens *before* the
wire compressor (Byzantine agents corrupt what they transmit) and the robust
rule replaces ``global_avg`` (which compression never touches).

See DESIGN.md §14 for where gradient tracking's Lemma-1 invariant survives
(clean runs, exactly) and where it breaks (any corrupted or non-mean
aggregate — by design: that breakage is what the robust rules trade for
bounded aggregate error).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.mixing import MixingOps, make_robust_agg

PyTree = Any

_ADV_TAG = 0xB12A  # domain separation for the Byzantine-set draw

ADVERSARY_KINDS = ("signflip", "random", "collusion")


@dataclasses.dataclass(frozen=True)
class AdversaryProcess:
    """Which agents are Byzantine and what they put on the wire.

    ``kind``:

    * ``signflip``  — corrupted payloads are ``-scale * x`` (the classic
      gradient/model sign-flip attack);
    * ``random``    — corrupted payloads are ``scale``-sized Gaussian noise,
      re-drawn each round (pure in ``(seed, round)`` via ``fold_in``);
    * ``collusion`` — all Byzantine agents transmit the *same* drifted value
      (the fleet mean plus ``scale`` along a fixed seed-drawn unit
      direction), the coordinated attack plain averaging cannot outvote.
    """

    kind: str
    f: float = 0.2
    scale: float = 1.0
    target: str = "drift"
    n_agents: int = 1
    seed: int = 0

    def __post_init__(self):
        if self.kind not in ADVERSARY_KINDS:
            raise ValueError(
                f"unknown adversary kind {self.kind!r}; "
                f"options: {ADVERSARY_KINDS}"
            )
        if not 0.0 < self.f < 1.0:
            raise ValueError(f"adversary fraction must be in (0, 1), got {self.f}")
        if self.kind == "collusion" and self.target != "drift":
            raise ValueError(
                f"collusion target {self.target!r} not supported (only 'drift')"
            )
        if self.n_byz >= self.n_agents:
            raise ValueError(
                f"f={self.f} makes all {self.n_agents} agents Byzantine — "
                "at least one honest agent is required"
            )

    @property
    def n_byz(self) -> int:
        return int(np.ceil(self.f * self.n_agents))

    @property
    def needs_round(self) -> bool:
        """Whether corruption depends on the round index (and therefore needs
        the per-round operand thread through the drivers)."""
        return self.kind == "random"

    def spec(self) -> str:
        s = f"{self.kind}:f={self.f:g}"
        if self.scale != 1.0:
            s += f",scale={self.scale:g}"
        if self.kind == "collusion":
            s += f",target={self.target}"
        return s

    def mask(self) -> np.ndarray:
        """(n_agents,) bool — True where Byzantine.  Pure in ``seed``."""
        rng = np.random.default_rng((_ADV_TAG, int(self.seed)))
        byz = rng.choice(self.n_agents, size=self.n_byz, replace=False)
        out = np.zeros(self.n_agents, dtype=bool)
        out[byz] = True
        return out

    # -- on-device corruption ----------------------------------------------

    def make_corrupt(self) -> Callable[[PyTree, Any], PyTree]:
        """``corrupt(tree, k)`` mapping an agent-stacked payload pytree to its
        on-the-wire form: honest rows pass through bit-exactly, Byzantine
        rows are replaced per ``kind``.  ``k`` is the (possibly traced) round
        index; kinds with round-independent corruption ignore it."""
        maskj = jnp.asarray(self.mask())
        scale = float(self.scale)
        base_key = jax.random.fold_in(
            jax.random.PRNGKey(int(self.seed) & 0x7FFFFFFF), _ADV_TAG
        )

        def rowmask(x):
            return maskj.reshape((-1,) + (1,) * (x.ndim - 1))

        if self.kind == "signflip":

            def corrupt(tree: PyTree, k=None) -> PyTree:
                def leaf(x):
                    xf = x.astype(jnp.float32)
                    return jnp.where(rowmask(x), -scale * xf, xf).astype(x.dtype)

                return jax.tree.map(leaf, tree)

        elif self.kind == "random":

            def corrupt(tree: PyTree, k) -> PyTree:
                kr = jax.random.fold_in(base_key, jnp.asarray(k, jnp.int32))
                leaves, treedef = jax.tree.flatten(tree)
                out = []
                for i, x in enumerate(leaves):
                    noise = scale * jax.random.normal(
                        jax.random.fold_in(kr, i), x.shape, jnp.float32
                    )
                    out.append(
                        jnp.where(rowmask(x), noise, x.astype(jnp.float32))
                        .astype(x.dtype)
                    )
                return jax.tree.unflatten(treedef, out)

        else:  # collusion: one common drifted value across all Byzantine rows

            def corrupt(tree: PyTree, k=None) -> PyTree:
                leaves, treedef = jax.tree.flatten(tree)
                out = []
                for i, x in enumerate(leaves):
                    d = jax.random.normal(
                        jax.random.fold_in(base_key, i), x.shape[1:], jnp.float32
                    )
                    d = d / jnp.maximum(
                        jnp.linalg.norm(d.reshape(-1)), jnp.float32(1e-12)
                    )
                    xf = x.astype(jnp.float32)
                    target = jnp.mean(xf, axis=0, keepdims=True) + scale * d[None]
                    out.append(jnp.where(rowmask(x), target, xf).astype(x.dtype))
                return jax.tree.unflatten(treedef, out)

        return corrupt


def parse_adversary_spec(
    spec: str, n_agents: int = 1, seed: int = 0
) -> AdversaryProcess:
    """``AdversaryProcess`` from ``"kind[:k=v,...]"`` — e.g.
    ``"signflip:f=0.2"``, ``"random:f=0.1,scale=5"``,
    ``"collusion:f=0.25,target=drift"``.  Fails fast on unknown kinds/keys
    (ExperimentSpec validates at construction with a 1-honest-agent probe)."""
    head, _, tail = str(spec).partition(":")
    kw: dict = {}
    if tail:
        for item in tail.split(","):
            key, eq, v = item.partition("=")
            key = key.strip()
            if not eq or key not in ("f", "scale", "target"):
                raise ValueError(
                    f"bad adversary argument {item!r} in {spec!r} "
                    "(keys: f, scale, target)"
                )
            kw[key] = v if key == "target" else float(v)
    return AdversaryProcess(
        kind=head.strip(), n_agents=n_agents, seed=seed, **kw
    )


def adversary_mask(
    spec: Optional[str], n_agents: int, seed: int = 0
) -> Optional[List[bool]]:
    """The Byzantine mask for a spec string (None passes through) — the form
    :class:`~repro.core.trainer.History` records for per-agent eval."""
    if spec is None:
        return None
    return [bool(b) for b in parse_adversary_spec(spec, n_agents, seed).mask()]


# ---------------------------------------------------------------------------
# Per-round operand plumbing: the round index as a scan operand
# ---------------------------------------------------------------------------


class _AdvSlot:
    """Trace-time slot holding the current round index (a live tracer inside
    scan bodies) for round-dependent corruption."""

    __slots__ = ("k",)

    def __init__(self):
        self.k = None


class _CompositeSlot:
    """Slot facade the drivers stage into: splits the augmented gossip
    operand ``{"w": <base>, "adv_k": k}`` between the adversary slot and the
    wrapped network's own slot (if any)."""

    __slots__ = ("base", "adv")

    def __init__(self, base, adv: _AdvSlot):
        self.base = base
        self.adv = adv

    def set(self, w_gossip, w_server) -> None:
        self.adv.k = w_gossip["adv_k"]
        if self.base is not None:
            self.base.set(w_gossip["w"], w_server)


class AdversarialNetwork:
    """Network handle threading the round index through the drivers.

    Wraps the base mixing's network context (or stands alone over a static
    mixing): ``draw_block`` delegates to the base draw — identical message /
    participant counts, so byte and time pricing cannot tell an adversarial
    run from a clean one — and augments the gossip operand with the block's
    round indices.  Pricing paths unwrap via :func:`unwrap_network`.
    """

    adversarial = True

    def __init__(self, base, n_agents: int, static_messages: int):
        self.base = base
        self.n_agents = n_agents
        self._static_messages = int(static_messages)
        self.adv_slot = _AdvSlot()
        self.slot = _CompositeSlot(
            None if base is None else base.slot, self.adv_slot
        )
        self.sparse = bool(getattr(base, "sparse", False))

    def augment(self, w_gossip, start: int, stop: int):
        """Wrap a base gossip operand with the rounds' indices (the events
        driver calls this on engine-drawn operands)."""
        return {
            "w": w_gossip,
            "adv_k": np.arange(start, stop, dtype=np.int32),
        }

    def draw_block(self, start: int, stop: int):
        block = stop - start
        if self.base is None:
            w_gossip = np.zeros((block, 1), dtype=np.float32)
            w_server = np.zeros((block, 1), dtype=np.float32)
            messages = np.full(block, self._static_messages, dtype=int)
            participants = np.full(block, self.n_agents, dtype=int)
        else:
            w_gossip, w_server, messages, participants = self.base.draw_block(
                start, stop
            )
        return self.augment(w_gossip, start, stop), w_server, messages, participants

    def draw_round(self, k: int):
        wg, ws, msgs, parts = self.draw_block(k, k + 1)
        first = lambda tree: jax.tree.map(lambda a: a[0], tree)
        return first(wg), first(ws), int(msgs[0]), int(parts[0])


def unwrap_network(net):
    """The base network context pricing/engine code should see — the
    adversarial wrapper changes numerics only, never costs."""
    return net.base if isinstance(net, AdversarialNetwork) else net


# ---------------------------------------------------------------------------
# The MixingOps wrapper
# ---------------------------------------------------------------------------


def make_adversarial_mixing(
    base: MixingOps,
    adversary: Optional[str] = None,
    robust_agg: str = "mean",
    *,
    n_agents: int,
    seed: int = 0,
) -> MixingOps:
    """Wrap any mixing with fault injection and/or a robust server rule.

    * ``gossip``      becomes corrupt-then-mix: Byzantine rows are replaced
      on the wire, then the base gossip (dense, sparse, dynamic, collective)
      runs unchanged.  Wrapping happens *before* compression, so under a
      compressed spec the corruption rides the compressed wire stream.
    * ``global_avg``  becomes corrupt-then-aggregate, where the aggregate is
      the base rule for ``robust_agg="mean"`` or a robust rule (trimmed /
      median / krum) otherwise.  Robust rules assume full participation
      (``ExperimentSpec`` validates).

    ``adversary=None`` with ``robust_agg="mean"`` returns ``base`` itself —
    the clean path is bit-identical by construction.  Accounting metadata
    (``gossip_edges`` / ``gossip_messages`` / realized counts) is preserved:
    Byzantine agents send *wrong* bytes, not fewer.
    """
    adv = (
        parse_adversary_spec(adversary, n_agents, seed)
        if adversary is not None
        else None
    )
    robust = make_robust_agg(robust_agg, n_agents)
    if adv is None and robust is None:
        return base

    agg = robust if robust is not None else base.global_avg
    name = base.name
    net = base.network
    if adv is None:
        new_gossip, new_global = base.gossip, agg
    else:
        corrupt = adv.make_corrupt()
        base_gossip = base.gossip
        if adv.needs_round:
            static_messages = (
                base.gossip_messages
                if base.gossip_messages is not None
                else 2 * base.gossip_edges
            )
            adv_net = AdversarialNetwork(base.network, n_agents, static_messages)
            net = adv_net
            get_k = lambda: adv_net.adv_slot.k
        else:
            get_k = lambda: None

        def new_gossip(tree: PyTree) -> PyTree:
            return base_gossip(corrupt(tree, get_k()))

        def new_global(tree: PyTree) -> PyTree:
            return agg(corrupt(tree, get_k()))

        name += f"/adv:{adv.spec()}"
    if robust is not None:
        name += f"/robust:{robust_agg}"
    return dataclasses.replace(
        base, gossip=new_gossip, global_avg=new_global, name=name, network=net
    )
