"""PISCO core: the paper's contribution as a composable JAX module."""
from repro.core.pisco import (
    PiscoConfig,
    PiscoState,
    RoundMetrics,
    init_compression_state,
    init_state,
    make_round_fn,
    make_stacked_value_and_grad,
    replicate_params,
    decentralized_config,
    federated_config,
)
from repro.core.topology import (
    Topology,
    TopologyProcess,
    StaticProcess,
    LinkFailureProcess,
    RandomMatchingProcess,
    RoundRobinProcess,
    ParticipationProcess,
    edge_list,
    make_topology,
    make_topology_process,
    parse_process_spec,
    mixing_rate,
    expected_mixing_rate,
    is_doubly_stochastic,
    is_connected,
    global_matrix,
)
from repro.core.mixing import (
    MixingOps,
    NetworkContext,
    dense_mixing,
    dynamic_dense_mixing,
    make_network_mixing,
    identity_mixing,
    collective_global_mixing,
    collective_shift_mixing,
    collective_dense_mixing,
    hierarchical_mixing,
)
from repro.core.schedule import (
    BernoulliSchedule,
    PeriodicSchedule,
    CommAccountant,
    RoundByteModel,
    make_schedule,
)
from repro.core.compression import (
    Compressor,
    IdentityCompressor,
    StochasticQuantizer,
    TopKCompressor,
    CompressedGossip,
    compress_mixing,
    make_compressor,
    make_byte_model,
    message_bytes,
)
from repro.core.trainer import (
    History,
    record_wall_time,
    run_training,
    make_algorithm_round_fns,
)
from repro.core.algorithms import (
    Algorithm,
    BoundAlgorithm,
    CommProfile,
    get_algorithm,
    register_algorithm,
    registered_algorithms,
    unregister_algorithm,
)
from repro.core.driver import (
    drive_loop,
    drive_scan,
    dynamic_round_fns,
    make_block_fn,
)
from repro.core.experiment import Experiment, ExperimentSpec, run_experiment

__all__ = [
    "Algorithm", "BoundAlgorithm", "CommProfile", "get_algorithm",
    "register_algorithm", "registered_algorithms", "unregister_algorithm",
    "drive_loop", "drive_scan", "dynamic_round_fns", "make_block_fn",
    "Experiment", "ExperimentSpec", "run_experiment",
    "PiscoConfig", "PiscoState", "RoundMetrics", "init_state",
    "init_compression_state", "make_round_fn",
    "make_stacked_value_and_grad", "replicate_params", "decentralized_config",
    "federated_config", "Topology", "TopologyProcess", "StaticProcess",
    "LinkFailureProcess", "RandomMatchingProcess", "RoundRobinProcess",
    "ParticipationProcess", "make_topology", "make_topology_process",
    "parse_process_spec", "mixing_rate", "expected_mixing_rate",
    "is_doubly_stochastic", "is_connected", "global_matrix", "MixingOps",
    "NetworkContext", "dense_mixing", "dynamic_dense_mixing",
    "make_network_mixing", "identity_mixing",
    "collective_global_mixing", "collective_shift_mixing",
    "collective_dense_mixing", "hierarchical_mixing", "BernoulliSchedule",
    "PeriodicSchedule", "CommAccountant", "RoundByteModel", "make_schedule",
    "Compressor", "IdentityCompressor", "StochasticQuantizer",
    "TopKCompressor", "CompressedGossip", "compress_mixing", "make_compressor",
    "make_byte_model", "message_bytes", "History", "record_wall_time",
    "run_training", "make_algorithm_round_fns", "edge_list",
]
