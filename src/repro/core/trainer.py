"""History record + the deprecated ``run_training`` entry point.

The experiment-facing API now lives in three places:

* :mod:`repro.core.algorithms` — the :class:`Algorithm` registry (what to run:
  round functions, default schedule, comm-cost profile — all data),
* :mod:`repro.core.driver`     — the round drivers (how to run it: chunked
  ``lax.scan`` on-device, or the legacy per-round host loop),
* :mod:`repro.core.experiment` — :class:`ExperimentSpec` / :class:`Experiment`
  (declarative bundles, ``run()`` / ``sweep()``).

``run_training`` and ``make_algorithm_round_fns`` remain as thin shims over
the registry so pre-registry callers keep working unchanged; new code should
construct an :class:`~repro.core.experiment.Experiment`.
"""
from __future__ import annotations

import contextlib
import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional

import numpy as np

from repro.core.algorithms import get_algorithm
from repro.core.compression import make_byte_model
from repro.core.driver import drive_loop, drive_scan
from repro.core.mixing import MixingOps
from repro.core.pisco import LossFn, PiscoConfig
from repro.core.schedule import CommAccountant, RoundByteModel

PyTree = Any
# sampler(round_idx) -> (local_batches [T_o, A, ...], comm_batch [A, ...])
Sampler = Callable[[int], tuple]
# eval_fn(x_bar) -> dict of python floats
EvalFn = Callable[[PyTree], Dict[str, float]]


@dataclasses.dataclass
class History:
    """Per-round records, numpy-backed for the benchmark harness.

    Two distinct clocks, never to be confused (DESIGN.md §11):

    * ``wall_time_s``  — *real* host seconds the run took, set exclusively by
      :func:`record_wall_time` (the one timing authority);
    * ``sim_time_s``   — *simulated* per-round seconds under the experiment's
      systems model (``ExperimentSpec.systems``), recorded by the drivers
      through the attached ``time_model``; empty when no model is attached.
    """

    loss: List[float] = dataclasses.field(default_factory=list)
    grad_sq_norm: List[float] = dataclasses.field(default_factory=list)
    consensus_err: List[float] = dataclasses.field(default_factory=list)
    is_global: List[bool] = dataclasses.field(default_factory=list)
    eval_metrics: List[Dict[str, float]] = dataclasses.field(default_factory=list)
    accountant: CommAccountant = dataclasses.field(default_factory=CommAccountant)
    byte_model: Optional[RoundByteModel] = None
    wall_time_s: float = 0.0
    # Final algorithm state (agent-stacked pytree NamedTuple), set by the
    # drivers when the run completes.  Excluded from to_dict().
    final_state: Any = None
    # RoundTimeModel (repro.sim.costmodel) when the spec carries a systems
    # profile; holds live process objects, so excluded from to_dict().
    time_model: Any = None
    # Events driver only: per-round per-agent staleness counters (list of
    # length-n lists, one per executed round).  Empty for sync drivers.
    staleness: List[List[int]] = dataclasses.field(default_factory=list)
    # Events driver only: the frozen event trace (repro.events.clock) —
    # gating decisions as numpy arrays, consumed by ``price_history`` for
    # post-hoc repricing under other fleets.  Excluded from to_dict().
    event_trace: Any = None
    # Byzantine runs only (spec.adversary): the fleet's fault mask —
    # adversary_mask[i] True where agent i is Byzantine (pure in the spec
    # seed, set by Experiment at history creation).  None for clean runs.
    adversary_mask: Optional[List[bool]] = None
    # Per-group eval series split by the mask: dicts of honest_<key> (and
    # byz_<key> for the faulty group) + 'round', appended at the same eval
    # boundaries as eval_metrics whenever adversary_mask is set.
    eval_per_agent: List[Dict[str, float]] = dataclasses.field(default_factory=list)
    # Optional repro.obs.trace.TraceRecorder: when set, the drivers' recording
    # funnel additionally emits one span per round (purely host-side — the
    # None default is the bit-identical telemetry-off path).  Excluded from
    # to_dict().
    recorder: Any = None

    @property
    def sim_time_s(self) -> List[float]:
        """Simulated seconds per executed round (the accountant's ledger)."""
        return self.accountant.per_round_seconds

    def agent_params(self) -> Any:
        """The agent-stacked per-agent parameters of the finished run — the
        export hook the serving subsystem (:mod:`repro.serve.delta`) consumes.

        Every algorithm state stores the model estimates ``X`` as its first
        field (``.x`` on the live NamedTuples; index 0 on states restored
        from checkpoints, where namedtuples come back as plain tuples)."""
        st = self.final_state
        if st is None:
            raise ValueError(
                "History has no final_state — run the experiment first "
                "(final_state is set by the drivers on completion)"
            )
        x = getattr(st, "x", None)
        if x is None and isinstance(st, (tuple, list)) and len(st) > 0:
            x = st[0]
        if x is None:
            raise ValueError(
                f"cannot locate agent-stacked params in {type(st).__name__}"
            )
        return x

    def running_mean_eval(self, key: str) -> np.ndarray:
        vals = np.array([m[key] for m in self.eval_metrics], dtype=np.float64)
        return np.cumsum(vals) / (np.arange(len(vals)) + 1)

    def rounds_to_threshold(
        self, key: str, threshold: float, mode: str = "running_le"
    ) -> Optional[int]:
        """First round index where the (running-mean) eval metric crosses the
        threshold — the paper's Fig. 4 success criterion.  Returns None if
        never reached."""
        if not self.eval_metrics:
            return None
        if mode == "running_le":
            series = self.running_mean_eval(key)
            hits = np.nonzero(series <= threshold)[0]
        elif mode == "ge":
            series = np.array([m[key] for m in self.eval_metrics])
            hits = np.nonzero(series >= threshold)[0]
        else:
            raise ValueError(mode)
        return int(hits[0]) if hits.size else None

    def to_dict(self) -> dict:
        """JSON-serializable view for the benchmark writers (``final_state``
        is device data and is deliberately left out)."""

        def native(v):
            # numpy scalars -> python; python int/bool/float/str pass through
            # unchanged (the 'round' index stays an int)
            if isinstance(v, np.bool_):
                return bool(v)
            if isinstance(v, np.integer):
                return int(v)
            if isinstance(v, np.floating):
                return float(v)
            return v

        return {
            "loss": [float(v) for v in self.loss],
            "grad_sq_norm": [float(v) for v in self.grad_sq_norm],
            "consensus_err": [float(v) for v in self.consensus_err],
            "is_global": [bool(v) for v in self.is_global],
            "eval_metrics": [
                {k: native(v) for k, v in m.items()} for m in self.eval_metrics
            ],
            "accountant": dataclasses.asdict(self.accountant),
            "byte_model": (
                dataclasses.asdict(self.byte_model)
                if self.byte_model is not None
                else None
            ),
            "wall_time_s": float(self.wall_time_s),
            "sim_time_s": [float(v) for v in self.sim_time_s],
            "sim_time_total_s": float(self.accountant.total_seconds),
            # a2a/a2s split of the simulated-seconds ledger, promoted to
            # top-level keys (the accountant dict above also carries the
            # totals, but consumers of the flat schema shouldn't have to know
            # the accountant's field names); the per-kind series are the
            # per-round ledger masked by round kind
            "sim_time_a2a_total_s": float(self.accountant.agent_to_agent_seconds),
            "sim_time_a2s_total_s": float(self.accountant.agent_to_server_seconds),
            "sim_time_a2a_s": [
                float(s) for s, g in zip(self.sim_time_s, self.is_global) if not g
            ],
            "sim_time_a2s_s": [
                float(s) for s, g in zip(self.sim_time_s, self.is_global) if g
            ],
            "staleness": [[int(v) for v in row] for row in self.staleness],
            "adversary_mask": (
                [bool(v) for v in self.adversary_mask]
                if self.adversary_mask is not None
                else None
            ),
            "eval_per_agent": [
                {k: native(v) for k, v in m.items()} for m in self.eval_per_agent
            ],
        }

    @classmethod
    def from_dict(cls, d: dict) -> "History":
        """Rebuild a History from :meth:`to_dict` output.

        Device-side fields (``final_state``, ``time_model``, ``event_trace``,
        ``recorder``) are not serialized and come back ``None``; everything
        else — including the accountant's a2a/a2s byte *and* seconds split —
        round-trips exactly."""
        acct_d = d.get("accountant", {})
        acct = CommAccountant(
            **{
                f.name: acct_d[f.name]
                for f in dataclasses.fields(CommAccountant)
                if f.name in acct_d
            }
        )
        bm_d = d.get("byte_model")
        byte_model = RoundByteModel(**bm_d) if bm_d is not None else None
        return cls(
            loss=list(d.get("loss", [])),
            grad_sq_norm=list(d.get("grad_sq_norm", [])),
            consensus_err=list(d.get("consensus_err", [])),
            is_global=[bool(v) for v in d.get("is_global", [])],
            eval_metrics=[dict(m) for m in d.get("eval_metrics", [])],
            accountant=acct,
            byte_model=byte_model,
            wall_time_s=float(d.get("wall_time_s", 0.0)),
            staleness=[list(row) for row in d.get("staleness", [])],
            adversary_mask=(
                [bool(v) for v in d["adversary_mask"]]
                if d.get("adversary_mask") is not None
                else None
            ),
            eval_per_agent=[dict(m) for m in d.get("eval_per_agent", [])],
        )

    def telemetry(self, meta: Optional[Dict[str, Any]] = None):
        """Export this run into a :class:`~repro.obs.metrics.MetricsRegistry`
        — the metrics-side counterpart of the span stream (DESIGN.md §16)."""
        from repro.obs.metrics import MetricsRegistry  # lazy: keep core light

        reg = MetricsRegistry(meta=dict(meta or {}))
        acct = self.accountant
        reg.counter("train.rounds_gossip").inc(acct.agent_to_agent)
        reg.counter("train.rounds_server").inc(acct.agent_to_server)
        reg.counter("train.bytes_a2a").inc(acct.agent_to_agent_bytes)
        reg.counter("train.bytes_a2s").inc(acct.agent_to_server_bytes)
        reg.gauge("train.wall_time_s").set(self.wall_time_s)
        reg.gauge("train.sim_time_a2a_s").set(acct.agent_to_agent_seconds)
        reg.gauge("train.sim_time_a2s_s").set(acct.agent_to_server_seconds)
        reg.histogram("train.round_bytes").observe_many(acct.per_round_bytes)
        if acct.per_round_seconds:
            reg.histogram("train.round_sim_s").observe_many(
                acct.per_round_seconds
            )
        if self.loss:
            reg.gauge("train.final_loss").set(self.loss[-1])
        if self.staleness:
            h = reg.histogram("train.staleness")
            for row in self.staleness:
                h.observe_many(row)
        if self.adversary_mask is not None:
            reg.gauge("train.n_byzantine").set(sum(self.adversary_mask))
        return reg


@contextlib.contextmanager
def record_wall_time(*hists: "History"):
    """The single *real* wall-clock authority: times the enclosed block with
    ``time.perf_counter`` and writes the duration to every history's
    ``wall_time_s`` on exit.  All drivers/entry points time through this one
    helper so host wall time can never be confused with the simulated
    ``sim_time_s`` series the systems model produces."""
    t0 = time.perf_counter()
    try:
        yield
    finally:
        dt = time.perf_counter() - t0
        for h in hists:
            h.wall_time_s = dt


def make_algorithm_round_fns(
    algo: str,
    loss_fn: LossFn,
    cfg: PiscoConfig,
    mixing: MixingOps,
    *,
    eta: Optional[float] = None,
    eta_g: float = 1.0,
) -> tuple:
    """Deprecated shim over the registry: returns
    ``(init_fn, gossip_round_fn, global_round_fn, schedule)``.  Prefer
    ``get_algorithm(algo).bind(loss_fn, cfg, mixing)``."""
    bound = get_algorithm(algo).bind(loss_fn, cfg, mixing, eta=eta, eta_g=eta_g)
    return bound.init, bound.gossip_round, bound.global_round, bound.schedule


def run_training(
    algo: str,
    loss_fn: LossFn,
    x0_stacked: PyTree,
    cfg: PiscoConfig,
    mixing: MixingOps,
    sampler: Sampler,
    rounds: int,
    *,
    eval_fn: Optional[EvalFn] = None,
    eval_every: int = 1,
    stop_when: Optional[Callable[[History], bool]] = None,
    jit: bool = True,
    driver: str = "loop",
    block_size: int = 32,
    local_opt=None,
    server_opt=None,
    opt_policy: Optional[str] = None,
) -> History:
    """Deprecated shim: drive ``rounds`` communication rounds of ``algo``.

    Equivalent to building an :class:`~repro.core.experiment.Experiment`;
    defaults to the legacy per-round host loop (``driver="loop"``) for exact
    backward compatibility — pass ``driver="scan"`` for the chunked on-device
    driver.  ``local_opt`` / ``server_opt`` / ``opt_policy`` pass through to
    ``Algorithm.bind`` (rules or their string forms; None = legacy SGD)."""
    opt_kw = {}
    if local_opt is not None:
        opt_kw["local_opt"] = local_opt
    if server_opt is not None:
        opt_kw["server_opt"] = server_opt
    if opt_policy is not None:
        opt_kw["opt_policy"] = opt_policy
    bound = get_algorithm(algo).bind(loss_fn, cfg, mixing, **opt_kw)
    _, comm0 = sampler(-1)
    state = bound.init(loss_fn, x0_stacked, comm0)

    hist = History()
    hist.byte_model = make_byte_model(
        mixing,
        x0_stacked,
        cfg.n_agents,
        mixes_per_round=bound.comm.mixes_per_round,
        server_payloads=bound.comm.server_payloads,
    )
    with record_wall_time(hist):
        if driver == "scan":
            state = drive_scan(
                bound, state, sampler, rounds, hist,
                eval_fn=eval_fn, eval_every=eval_every, stop_when=stop_when,
                block_size=block_size,
            )
        else:
            state = drive_loop(
                bound, state, sampler, rounds, hist,
                eval_fn=eval_fn, eval_every=eval_every, stop_when=stop_when,
                jit=jit,
            )
    hist.final_state = state
    return hist
