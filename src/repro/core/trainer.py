"""Host-side training loop driving PISCO or any baseline.

The loop owns exactly the things the paper leaves to "the system":

* the Bernoulli(p) / periodic schedule (line 8 of Algorithm 1),
* dispatch between the two pre-compiled round functions (gossip vs global),
* data sampling for the T_o + 1 minibatches each round consumes,
* communication-cost accounting (agent-to-agent vs agent-to-server rounds),
* evaluation at the agent-average parameters x̄ (the paper's metrics:
  running mean of ||∇f(x̄^k)||² and test accuracy).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.compression import make_byte_model
from repro.core.mixing import MixingOps
from repro.core.pisco import (
    LossFn,
    PiscoConfig,
    init_compression_state,
    init_state,
    make_round_fn,
)
from repro.core.schedule import CommAccountant, RoundByteModel, make_schedule
from repro.core import baselines as B

PyTree = Any
# sampler(round_idx) -> (local_batches [T_o, A, ...], comm_batch [A, ...])
Sampler = Callable[[int], tuple]
# eval_fn(x_bar) -> dict of python floats
EvalFn = Callable[[PyTree], Dict[str, float]]

# Mixing invocations per communication round, for the byte model: gradient
# tracking mixes both X and Y; plain-SGD families mix X only.  SCAFFOLD's
# server exchange moves the model plus the control variate (2 payloads).
MIXES_PER_ROUND = {
    "pisco": 2,
    "dsgt": 2,
    "periodical_gt": 2,
    "dsgd": 1,
    "gossip_pga": 1,
    "fedavg": 1,
    "scaffold": 2,
}


@dataclasses.dataclass
class History:
    """Per-round records, numpy-backed for the benchmark harness."""

    loss: List[float] = dataclasses.field(default_factory=list)
    grad_sq_norm: List[float] = dataclasses.field(default_factory=list)
    consensus_err: List[float] = dataclasses.field(default_factory=list)
    is_global: List[bool] = dataclasses.field(default_factory=list)
    eval_metrics: List[Dict[str, float]] = dataclasses.field(default_factory=list)
    accountant: CommAccountant = dataclasses.field(default_factory=CommAccountant)
    byte_model: Optional[RoundByteModel] = None
    wall_time_s: float = 0.0

    def running_mean_eval(self, key: str) -> np.ndarray:
        vals = np.array([m[key] for m in self.eval_metrics], dtype=np.float64)
        return np.cumsum(vals) / (np.arange(len(vals)) + 1)

    def rounds_to_threshold(
        self, key: str, threshold: float, mode: str = "running_le"
    ) -> Optional[int]:
        """First round index where the (running-mean) eval metric crosses the
        threshold — the paper's Fig. 4 success criterion.  Returns None if
        never reached."""
        if not self.eval_metrics:
            return None
        if mode == "running_le":
            series = self.running_mean_eval(key)
            hits = np.nonzero(series <= threshold)[0]
        elif mode == "ge":
            series = np.array([m[key] for m in self.eval_metrics])
            hits = np.nonzero(series >= threshold)[0]
        else:
            raise ValueError(mode)
        return int(hits[0]) if hits.size else None


def make_algorithm_round_fns(
    algo: str,
    loss_fn: LossFn,
    cfg: PiscoConfig,
    mixing: MixingOps,
    *,
    eta: Optional[float] = None,
    eta_g: float = 1.0,
) -> tuple:
    """Return (init_fn, gossip_round_fn, global_round_fn, schedule)."""
    eta = eta if eta is not None else cfg.eta_l
    if algo == "pisco":
        return (
            lambda lf, x0, b0: init_compression_state(init_state(lf, x0, b0), mixing),
            make_round_fn(loss_fn, cfg, mixing, global_round=False),
            make_round_fn(loss_fn, cfg, mixing, global_round=True),
            make_schedule(cfg.p, cfg.seed),
        )
    if algo == "periodical_gt":
        fn = B.make_periodical_gt_round_fn(loss_fn, cfg, mixing)
        return (B.dsgt_init, fn, fn, make_schedule(0.0))
    if algo == "dsgt":
        g = B.make_dsgt_round_fn(loss_fn, eta, mixing, global_round=False)
        s = B.make_dsgt_round_fn(loss_fn, eta, mixing, global_round=True)
        return (B.dsgt_init, g, s, make_schedule(cfg.p, cfg.seed))
    if algo == "dsgd":
        g = B.make_dsgd_round_fn(loss_fn, eta, mixing, global_round=False, t_o=cfg.t_o)
        s = B.make_dsgd_round_fn(loss_fn, eta, mixing, global_round=True, t_o=cfg.t_o)
        return (B.dsgd_init, g, s, make_schedule(0.0))
    if algo == "gossip_pga":
        from repro.core.schedule import PeriodicSchedule

        g = B.make_dsgd_round_fn(loss_fn, eta, mixing, global_round=False, t_o=cfg.t_o)
        s = B.make_dsgd_round_fn(loss_fn, eta, mixing, global_round=True, t_o=cfg.t_o)
        period = max(1, int(round(1.0 / cfg.p))) if cfg.p > 0 else 10
        return (B.dsgd_init, g, s, PeriodicSchedule(period))
    if algo == "fedavg":
        s = B.make_dsgd_round_fn(loss_fn, eta, mixing, global_round=True, t_o=cfg.t_o)
        return (B.dsgd_init, s, s, make_schedule(1.0))
    if algo == "scaffold":
        fn = B.make_scaffold_round_fn(loss_fn, cfg.eta_l, eta_g, cfg.t_o, mixing)
        return (B.scaffold_init, fn, fn, make_schedule(1.0))
    raise ValueError(f"unknown algorithm {algo!r}; options: {sorted(B.BASELINES)}")


def run_training(
    algo: str,
    loss_fn: LossFn,
    x0_stacked: PyTree,
    cfg: PiscoConfig,
    mixing: MixingOps,
    sampler: Sampler,
    rounds: int,
    *,
    eval_fn: Optional[EvalFn] = None,
    eval_every: int = 1,
    stop_when: Optional[Callable[[History], bool]] = None,
    jit: bool = True,
) -> History:
    """Drive ``rounds`` communication rounds of ``algo``; returns History."""
    init_fn, gossip_fn, global_fn, schedule = make_algorithm_round_fns(
        algo, loss_fn, cfg, mixing
    )
    if jit:
        gossip_fn = jax.jit(gossip_fn)
        global_fn = jax.jit(global_fn) if global_fn is not gossip_fn else gossip_fn

    local0, comm0 = sampler(-1)
    state = init_fn(loss_fn, x0_stacked, comm0)

    hist = History()
    hist.byte_model = make_byte_model(
        mixing,
        x0_stacked,
        cfg.n_agents,
        mixes_per_round=MIXES_PER_ROUND.get(algo, 1),
    )
    t0 = time.perf_counter()
    for k in range(rounds):
        local_batches, comm_batch = sampler(k)
        is_global = bool(schedule(k))
        fn = global_fn if is_global else gossip_fn
        state, metrics = fn(state, local_batches, comm_batch)
        hist.loss.append(float(metrics.loss))
        hist.grad_sq_norm.append(float(metrics.grad_sq_norm))
        hist.consensus_err.append(float(metrics.consensus_err))
        hist.is_global.append(is_global)
        hist.accountant.record(is_global, hist.byte_model.round_bytes(is_global))
        if eval_fn is not None and (k % eval_every == 0 or k == rounds - 1):
            x_bar = jax.tree.map(lambda v: jnp.mean(v, axis=0), state.x)
            hist.eval_metrics.append(dict(eval_fn(x_bar), round=k))
        if stop_when is not None and stop_when(hist):
            break
    hist.wall_time_s = time.perf_counter() - t0
    hist.final_state = state  # type: ignore[attr-defined]
    return hist
