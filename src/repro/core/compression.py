"""Compressed gossip: quantization / sparsification for the communication path.

The paper saves communication *rounds* (small ``p``, ``T_o`` local steps); this
module adds the orthogonal axis the Conclusions defer to future work — saving
*bytes per round* — following the compressed decentralized methods of
[ZLL+22 / Li et al.] and the peer-to-peer-aided setting of FedDec.

Three pieces:

* :class:`Compressor` — per-agent-message lossy codecs (stochastic
  quantization to int8/int4, top-k sparsification, identity).  Every
  compressor also *prices* itself: :meth:`Compressor.wire_bits` returns the
  exact bits one agent ships per message, which feeds the byte-level
  accounting in :mod:`repro.core.schedule`.

* :class:`CompressedGossip` — wraps any :class:`MixingOps.gossip` in the
  **mean-preserving difference form**

      out_i = x_i + sum_j W_ij q(m_j) - q(m_i),        m_i = x_i (+ e_i)

  Because W is doubly stochastic, ``mean_i out_i == mean_i x_i`` *exactly*,
  for any compressor — so gradient tracking's Lemma-1 invariant
  (``mean_i y_i == mean_i g_i``) survives compression of Y.  With error
  feedback the residual ``e_i`` accumulates what q dropped and is re-offered
  next round, restoring convergence for biased compressors (top-k).

* :func:`compress_mixing` / :func:`make_byte_model` — glue: attach a
  compressor to existing :class:`MixingOps` (dense or collective), and build
  the closed-form :class:`RoundByteModel` the trainer charges per round.

The compressed path is *opt-in*: a ``MixingOps`` without a ``compression``
spec runs the exact same code as before (bit-identical outputs).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.mixing import MixingOps
from repro.core.schedule import RoundByteModel
from repro.utils.pytree import tree_add, tree_sub, tree_zeros_like

PyTree = Any

SCALE_BITS = 32  # one fp32 scale per (leaf, agent) message row
INDEX_BITS = 32  # one int32 coordinate per surviving top-k entry


# ---------------------------------------------------------------------------
# Compressors
# ---------------------------------------------------------------------------


class Compressor:
    """Lossy codec for one agent-stacked leaf (axis 0 = agents).

    ``compress(x, key)`` returns the *dequantized* wire values (what the
    receiving neighbors reconstruct) with the same shape/dtype as ``x``;
    compression is applied independently per agent row, since each agent
    encodes its own outgoing message.  ``key=None`` selects deterministic
    rounding (used by kernels/tests); a PRNGKey enables stochastic modes.
    """

    name: str = "abstract"

    def compress(self, x: jnp.ndarray, key=None) -> jnp.ndarray:
        raise NotImplementedError

    def wire_bits(self, n_elements: int, itemsize_bits: int = 32) -> int:
        """Exact wire bits for one agent's message of ``n_elements`` scalars
        from a single leaf (including scale/index side channels)."""
        raise NotImplementedError

    def compress_tree(self, tree: PyTree, key=None) -> PyTree:
        flat, treedef = jax.tree.flatten(tree)
        if key is None:
            keys = [None] * len(flat)
        else:
            keys = list(jax.random.split(key, len(flat)))
        out = [self.compress(x, k) for x, k in zip(flat, keys)]
        return jax.tree.unflatten(treedef, out)


@dataclasses.dataclass(frozen=True)
class IdentityCompressor(Compressor):
    """Full precision — the pricing baseline (and the 'disabled' codec)."""

    name: str = "fp32"

    def compress(self, x, key=None):
        return x

    def wire_bits(self, n_elements: int, itemsize_bits: int = 32) -> int:
        return n_elements * itemsize_bits


def _agent_rows(x: jnp.ndarray) -> jnp.ndarray:
    return x.reshape(x.shape[0], -1)


@dataclasses.dataclass(frozen=True)
class StochasticQuantizer(Compressor):
    """QSGD-style symmetric quantizer, per-agent-row max-abs scaling.

    ``bits`` ∈ {4, 8}: signed grid {-qmax..qmax}, qmax = 2^(bits-1) - 1.
    Deterministic mode rounds to nearest (error ≤ scale/2 per element);
    stochastic mode rounds up/down with probability proportional to the
    fractional part, making the codec unbiased: E[q(x)] = x.
    """

    bits: int = 8
    stochastic: bool = True

    def __post_init__(self):
        assert self.bits in (4, 8), "int8 / int4 wire formats only"

    @property
    def name(self) -> str:  # type: ignore[override]
        return f"q{self.bits}" + ("s" if self.stochastic else "")

    @property
    def qmax(self) -> float:
        return float(2 ** (self.bits - 1) - 1)

    def compress(self, x, key=None):
        rows = _agent_rows(x).astype(jnp.float32)
        scale = jnp.maximum(jnp.max(jnp.abs(rows), axis=1, keepdims=True), 1e-12)
        scale = scale / self.qmax
        u = rows / scale
        if self.stochastic and key is not None:
            noise = jax.random.uniform(key, rows.shape)
            q = jnp.floor(u + noise)
        else:
            q = jnp.round(u)
        q = jnp.clip(q, -self.qmax, self.qmax)
        return (q * scale).reshape(x.shape).astype(x.dtype)

    def wire_bits(self, n_elements: int, itemsize_bits: int = 32) -> int:
        return n_elements * self.bits + SCALE_BITS


@dataclasses.dataclass(frozen=True)
class TopKCompressor(Compressor):
    """Keep the ``fraction`` largest-magnitude coordinates per agent row.

    Biased (contractive): ||x - q(x)||² ≤ (1 - k/d) ||x||², which is exactly
    the δ-contraction error feedback needs.  Wire format: (value, index)
    pairs, fp32 + int32 each.
    """

    fraction: float = 0.1

    def __post_init__(self):
        assert 0.0 < self.fraction <= 1.0

    @property
    def name(self) -> str:  # type: ignore[override]
        return f"top{self.fraction:g}"

    def k_for(self, n_elements: int) -> int:
        return max(1, int(math.ceil(self.fraction * n_elements)))

    def compress(self, x, key=None):
        rows = _agent_rows(x)
        d = rows.shape[1]
        k = self.k_for(d)
        _, idx = jax.lax.top_k(jnp.abs(rows), k)  # (n, k)
        mask = jnp.zeros_like(rows, dtype=bool)
        mask = mask.at[jnp.arange(rows.shape[0])[:, None], idx].set(True)
        return jnp.where(mask, rows, 0).reshape(x.shape).astype(x.dtype)

    def wire_bits(self, n_elements: int, itemsize_bits: int = 32) -> int:
        return self.k_for(n_elements) * (itemsize_bits + INDEX_BITS)


_REGISTRY: dict = {
    "none": lambda: IdentityCompressor(),
    "fp32": lambda: IdentityCompressor(),
    "q8": lambda: StochasticQuantizer(bits=8),
    "q4": lambda: StochasticQuantizer(bits=4),
    "q8d": lambda: StochasticQuantizer(bits=8, stochastic=False),
    "q4d": lambda: StochasticQuantizer(bits=4, stochastic=False),
}


def make_compressor(spec: str) -> Compressor:
    """Parse 'none' | 'q8' | 'q4' | 'q8d' | 'q4d' | 'topK' (K a fraction)."""
    if spec in _REGISTRY:
        return _REGISTRY[spec]()
    if spec.startswith("top"):
        try:
            fraction = float(spec[3:])
        except ValueError:
            raise ValueError(
                f"unknown compressor spec {spec!r} (top-k needs a fraction, "
                f"e.g. 'top0.1')"
            ) from None
        return TopKCompressor(fraction=fraction)
    raise ValueError(
        f"unknown compressor spec {spec!r}; options: "
        f"{sorted(_REGISTRY)} or 'top<fraction>'"
    )


# ---------------------------------------------------------------------------
# Mean-preserving compressed gossip (+ error feedback)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class CompressedGossip:
    """Difference-form compressed gossip over a base mixing operator.

    Stateful form (:meth:`__call__`) threads an error-feedback residual and a
    PRNG key through the round function; :meth:`stateless` is the keyless,
    residual-free variant used by baseline round functions that cannot carry
    extra state.  Both preserve the agent mean exactly, for any ``gamma``.

    ``gamma`` is the CHOCO-SGD consensus step size applied to the
    compressed correction:  out = x + γ (W q(m) − q(m)).  γ = 1 is the
    undamped form (fine for quantizers, whose error is a fraction of a
    quantization step); aggressive contractive compressors (small-k top-k)
    need γ < 1 or the error-feedback loop can diverge under large local
    steps — see DESIGN.md §7.
    """

    base_gossip: Callable[[PyTree], PyTree]
    compressor: Compressor
    error_feedback: bool = True
    seed: int = 0
    gamma: float = 1.0

    def init_ef(self, template: PyTree) -> dict:
        """Per-stream residuals (X and Y are mixed separately each round)."""
        res = tree_zeros_like(template) if self.error_feedback else ()
        return {
            "x": res,
            "y": jax.tree.map(jnp.copy, res) if self.error_feedback else (),
            "key": jax.random.PRNGKey(self.seed),
        }

    def _combine(self, tree: PyTree, q: PyTree) -> PyTree:
        diff = tree_sub(self.base_gossip(q), q)
        if self.gamma == 1.0:
            return tree_add(tree, diff)
        return jax.tree.map(lambda t, d: t + self.gamma * d, tree, diff)

    def __call__(
        self, tree: PyTree, residual: PyTree, key
    ) -> Tuple[PyTree, PyTree]:
        m = tree_add(tree, residual) if self.error_feedback else tree
        q = self.compressor.compress_tree(m, key)
        mixed = self._combine(tree, q)
        new_residual = tree_sub(m, q) if self.error_feedback else residual
        return mixed, new_residual

    def stateless(self, tree: PyTree) -> PyTree:
        """Keyless, residual-free form (installed as ``MixingOps.gossip``).

        Without a PRNG key, stochastic quantizers fall back to deterministic
        round-to-nearest here — lower per-round error but biased, and no
        error feedback.  Only PISCO's round function (which threads
        ``state.ef``) gets the stochastic/EF semantics a spec like 'q8'
        advertises; baseline algorithms run this form.
        """
        q = self.compressor.compress_tree(tree, key=None)
        return self._combine(tree, q)


def compress_mixing(
    base: MixingOps,
    compressor: Compressor,
    *,
    error_feedback: bool = True,
    seed: int = 0,
    gamma: Optional[float] = None,
) -> MixingOps:
    """Attach a compressor to any mixing operator (dense or collective).

    ``gossip`` becomes the stateless mean-preserving compressed form;
    PISCO's round function additionally picks up the stateful error-feedback
    path via the ``compression`` spec.  ``global_avg`` (the server round)
    stays full precision — the paper's emphasis is that server rounds set the
    consensus floor, so the expensive link gets the exact average.

    ``gamma=None`` auto-selects the consensus step: 1.0 for (near-)unbiased
    quantizers, 0.5 for contractive sparsifiers (top-k), which diverge
    undamped under aggressive local steps.
    """
    if isinstance(compressor, IdentityCompressor):
        return base
    if gamma is None:
        gamma = 0.5 if isinstance(compressor, TopKCompressor) else 1.0
    cg = CompressedGossip(
        base_gossip=base.gossip,
        compressor=compressor,
        error_feedback=error_feedback,
        seed=seed,
        gamma=gamma,
    )
    return dataclasses.replace(
        base,
        gossip=cg.stateless,
        name=f"{base.name}/{compressor.name}" + ("+ef" if error_feedback else ""),
        compression=cg,
    )


# ---------------------------------------------------------------------------
# Byte-level communication pricing
# ---------------------------------------------------------------------------


def _per_agent_leaf_sizes(template: PyTree, n_agents: int):
    for leaf in jax.tree.leaves(template):
        assert leaf.shape[0] == n_agents, (
            f"leaf {leaf.shape} is not agent-stacked over {n_agents} agents"
        )
        yield int(leaf.size) // n_agents, leaf.dtype.itemsize * 8


def message_bytes(
    compressor: Optional[Compressor], template: PyTree, n_agents: int
) -> int:
    """Bytes ONE agent ships per message for the agent-stacked ``template``."""
    comp = compressor or IdentityCompressor()
    bits = sum(
        comp.wire_bits(n, itemsize)
        for n, itemsize in _per_agent_leaf_sizes(template, n_agents)
    )
    return -(-bits // 8)


def _directed_gossip_messages(mixing: MixingOps) -> int:
    """Directed neighbor messages per gossip mix, network-wide: the explicit
    ``gossip_messages`` field when the mixer sets one (collective shift
    mixers, whose ``gossip_edges`` counts per-agent shifts), else one message
    per direction over each undirected edge."""
    if mixing.gossip_messages is not None:
        return mixing.gossip_messages
    return 2 * mixing.gossip_edges


def make_byte_model(
    mixing: MixingOps,
    template: PyTree,
    n_agents: int,
    *,
    mixes_per_round: int = 2,
    server_payloads: Optional[int] = None,
) -> RoundByteModel:
    """Closed-form network-wide bytes per round (Fig.-4 bits-on-x-axis).

    * gossip round: ``mixes_per_round`` mixes, each moving one *compressed*
      message per directed edge;
    * server round: ``server_payloads`` payloads per direction (defaults to
      ``mixes_per_round`` — gradient-tracking methods ship both streams), each
      an upload + a broadcast download per agent, *full precision*.
    """
    comp = mixing.compression.compressor if mixing.compression is not None else None
    if server_payloads is None:
        server_payloads = mixes_per_round
    gossip_msg = message_bytes(comp, template, n_agents)
    server_msg = message_bytes(None, template, n_agents)
    return RoundByteModel(
        gossip_round_bytes=mixes_per_round
        * _directed_gossip_messages(mixing)
        * gossip_msg,
        server_round_bytes=server_payloads * 2 * n_agents * server_msg,
        gossip_message_bytes=gossip_msg,
        server_message_bytes=server_msg,
        mixes_per_round=mixes_per_round,
        server_payloads=server_payloads,
    )
