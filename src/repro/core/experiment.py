"""Declarative experiments: ``ExperimentSpec`` + ``Experiment``.

An :class:`ExperimentSpec` is a frozen, dict/JSON-round-trippable bundle of
*what* to run — algorithm name (resolved through the registry), topology,
dynamic-network process (``network=``) and server-round participation
fraction, compression, :class:`~repro.core.pisco.PiscoConfig`, round budget,
eval policy, and which round driver executes it.  The *problem* (loss function,
initial parameters, data sampler, eval function) stays runtime state on
:class:`Experiment`, because closures and datasets don't belong in JSON.

::

    spec = ExperimentSpec.create(algo="pisco", n_agents=10, t_o=5, p=0.1,
                                 eta_l=0.3, rounds=100, eval_every=10)
    exp = Experiment(spec, loss_fn=loss_fn, params0=params0,
                     sampler_factory=make_sampler, eval_fn=eval_fn)
    hist = exp.run()                    # -> History
    hists = exp.sweep(seeds=[0, 1, 2])  # vmapped multi-seed, one device program
    grid = exp.sweep(grid={"p": [0.0, 0.1, 1.0]})  # list of (spec, History)

Multi-seed sweeps vmap the scanned round block over a leading seed axis —
every seed advances in lockstep through the *same* realized communication
schedule (the spec's seed draws it), while data sampling and anything else the
``sampler_factory`` keys off ``spec.seed`` vary per seed.
"""
from __future__ import annotations

import dataclasses
import itertools
import json
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.adversary import (
    adversary_mask,
    make_adversarial_mixing,
    parse_adversary_spec,
    unwrap_network,
)
from repro.core.algorithms import BoundAlgorithm, get_algorithm
from repro.core.compression import make_byte_model, make_compressor, compress_mixing
from repro.core.driver import (
    DEFAULT_BLOCK_SIZE,
    DRIVERS,
    _eval_agent_groups,
    record_flags,
    block_bounds,
    drive_loop,
    drive_scan,
    make_block_fn,
    predraw_schedule,
    sample_block,
    stack_rounds,
)
from repro.core.mixing import (
    MixingOps,
    make_network_mixing,
    make_robust_agg,
    make_sparse_network_mixing,
)
from repro.core.pisco import LossFn, PiscoConfig, replicate_params
from repro.core.topology import (
    make_sparse_topology,
    make_topology,
    parse_process_spec,
    use_sparse_topology,
)
from repro.core.trainer import History, record_wall_time
from repro.optim.update_rules import (
    OPT_POLICIES,
    make_lr_schedule,
    parse_update_rule,
    resolve_update_rules,
)

PyTree = Any
Sampler = Callable[[int], tuple]
EvalFn = Callable[[PyTree], Dict[str, float]]

_CONFIG_FIELDS = tuple(f.name for f in dataclasses.fields(PiscoConfig))


@dataclasses.dataclass(frozen=True)
class ExperimentSpec:
    """Everything declarative about one training run."""

    algo: str
    config: PiscoConfig
    topology: str = "ring"
    topology_kwargs: Tuple[Tuple[str, Any], ...] = ()
    # Dynamic network: None => the frozen base matrix every round (legacy
    # path, bit-identical to pre-dynamic runs); else a TopologyProcess spec —
    # "static" | "bernoulli[:failure_prob]" | "matching" | "roundrobin[:n]".
    network: Optional[str] = None
    # Fraction of agents sampled into each server round (uniform m-of-n,
    # doubly stochastic sampled-to-sampled averaging); 1.0 => everyone.
    participation: float = 1.0
    # Sparse substrate (DESIGN.md §12): True => edge-list/CSR mixing
    # (segment_sum gossip, O(n + m) state), False => dense n×n, None (the
    # default, and what every legacy payload deserializes to) => auto — dense
    # for small fleets (the bit-exact reference), sparse above
    # SPARSE_AUTO_MIN_AGENTS.
    sparse: Optional[bool] = None
    # Neighbor-sampled cohorts: fraction of agents seeding each gossip round
    # (only the subgraph incident to the cohort is active; sugar for
    # network="cohort:<frac>", mutually exclusive with an explicit network).
    cohort: Optional[float] = None
    # Simulated systems-cost profile (repro.sim, DESIGN.md §11): a named
    # heterogeneity scenario — "uniform" | "lognormal-stragglers" |
    # "edge-vs-datacenter" | "wan-gossip" | "lan-gossip" — with optional
    # k=v overrides ("uniform:latency=0,bw=inf,rtt=0").  When set, every
    # executed round is priced in simulated seconds (History.sim_time_s)
    # alongside bytes; None (the default, and what every legacy payload
    # deserializes to) records no sim time — bit-identical behavior.
    systems: Optional[str] = None
    # Asynchronous execution config (repro.events, DESIGN.md §13), as a spec
    # string "<rule>[:k=v,...]" over rules constant|poly|buffer with keys
    # alpha / bound / buffer — e.g. "poly:alpha=0.5,bound=2,buffer=4".  Only
    # meaningful with driver="events" (which also requires a systems
    # profile); None (the default, and what every legacy payload deserializes
    # to) means constant weights, no staleness bound, no server buffer.
    async_: Optional[str] = None
    # Byzantine fault injection (repro.core.adversary, DESIGN.md §14): an
    # AdversaryProcess spec — "signflip[:f=..,scale=..]" |
    # "random:f=..,scale=.." | "collusion:f=..,target=drift" — corrupting the
    # selected agents' outgoing gossip payloads and server uploads, pure in
    # (seed, round).  None (the default, and what every legacy payload
    # deserializes to) injects nothing — bit-identical behavior.
    adversary: Optional[str] = None
    # Server-averaging rule at global rounds: "mean" (the default plain
    # average — bit-identical legacy path) | "trimmed[:f=..]" | "median" |
    # "krum[:f=..]".  Robust rules need full participation and sync
    # aggregation (participation=1.0, async_=None).
    robust_agg: str = "mean"
    compression: Optional[str] = None  # None | "q8" | "q4" | "top0.1" | ...
    error_feedback: bool = True
    # Pluggable update rules (DESIGN.md §10), as declarative strings:
    # optimizer        — local rule ("sgd" | "momentum[:beta=..]" | "adam" |
    #                    "clip:1.0|momentum" | ...); None => the registry
    #                    entry's default, which for the built-ins is the
    #                    bit-exact legacy hardcoded-SGD path.
    # server_optimizer — FedOpt server rule at global-averaging rounds
    #                    ("fedavgm" | "fedadam" | "sgd:lr=..." | ...).
    # lr_schedule      — per-round local-LR decay over optim.schedules
    #                    ("linear[:final=..]" | "cosine" | "warmup_cosine").
    # opt_policy       — opt-state comm policy override ("mix"|"keep"|"reset").
    optimizer: Optional[str] = None
    server_optimizer: Optional[str] = None
    lr_schedule: Optional[str] = None
    opt_policy: Optional[str] = None
    rounds: int = 100
    eval_every: int = 1
    # "scan" (on-device blocks) | "loop" (legacy) | "events" (async event
    # queue over the systems profile, repro.events)
    driver: str = "scan"
    block_size: int = DEFAULT_BLOCK_SIZE

    def __post_init__(self):
        if self.driver not in DRIVERS:
            raise ValueError(f"driver {self.driver!r} not in {DRIVERS}")
        # fail fast on malformed optimizer specs (cheap parse, discarded)
        if self.optimizer is not None:
            parse_update_rule(self.optimizer)
        if self.server_optimizer is not None:
            parse_update_rule(self.server_optimizer)
        if self.lr_schedule is not None:
            make_lr_schedule(self.lr_schedule, 1.0, 1)
        if self.opt_policy is not None and self.opt_policy not in OPT_POLICIES:
            raise ValueError(
                f"opt_policy {self.opt_policy!r} not in {OPT_POLICIES}"
            )
        if not 0.0 < self.participation <= 1.0:
            raise ValueError(
                f"participation must be in (0, 1], got {self.participation}"
            )
        if self.cohort is not None:
            if not 0.0 < self.cohort <= 1.0:
                raise ValueError(f"cohort must be in (0, 1], got {self.cohort}")
            if self.network is not None:
                raise ValueError(
                    "cohort is sugar for network='cohort:<frac>'; pass one, not both"
                )
        if self.network is not None:
            parse_process_spec(self.network)  # fail fast on bad specs
        if self.systems is not None:
            # local import: repro.sim imports the Experiment API
            from repro.sim.profiles import parse_systems_spec

            parse_systems_spec(self.systems)  # fail fast on bad profiles
        if self.async_ is not None:
            from repro.events.staleness import parse_async_spec

            parse_async_spec(self.async_)  # fail fast on bad async specs
            if self.driver != "events":
                raise ValueError(
                    "async_ only applies to driver='events' "
                    f"(got driver={self.driver!r})"
                )
        if self.adversary is not None:
            # full probe: validates grammar AND that f leaves an honest agent
            parse_adversary_spec(
                self.adversary, self.config.n_agents, self.config.seed
            )
        # probe the robust rule (validates grammar + that trimming leaves
        # agents); robust rules replace the participation-aware server
        # average wholesale, so they need the synchronous full fleet
        if make_robust_agg(self.robust_agg, self.config.n_agents) is not None:
            if self.participation != 1.0:
                raise ValueError(
                    f"robust_agg={self.robust_agg!r} needs participation=1.0 "
                    f"(got {self.participation}) — robust rules aggregate the "
                    "full fleet"
                )
            if self.async_ is not None:
                raise ValueError(
                    f"robust_agg={self.robust_agg!r} needs synchronous server "
                    f"rounds (async_=None, got {self.async_!r})"
                )
        if self.driver == "events" and self.systems is None:
            raise ValueError(
                "driver='events' needs a systems profile (spec.systems) — "
                "the event clock is drawn from the fleet realization"
            )
        # normalize mapping-typed topology kwargs into sorted item tuples so
        # specs stay hashable and JSON round-trips are canonical
        if isinstance(self.topology_kwargs, dict):
            object.__setattr__(
                self, "topology_kwargs", tuple(sorted(self.topology_kwargs.items()))
            )
        get_algorithm(self.algo)  # fail fast on unknown algorithms

    @classmethod
    def create(cls, algo: str = "pisco", **kw) -> "ExperimentSpec":
        """Flat constructor: PiscoConfig fields may be passed directly
        (``ExperimentSpec.create(algo="pisco", n_agents=10, p=0.1, ...)``)."""
        cfg_kw = {k: kw.pop(k) for k in list(kw) if k in _CONFIG_FIELDS}
        return cls(algo=algo, config=PiscoConfig(**cfg_kw), **kw)

    def replace(self, **kw) -> "ExperimentSpec":
        """`dataclasses.replace` that also routes PiscoConfig field names
        (``spec.replace(p=0.3)``) into the nested config."""
        cfg_kw = {k: kw.pop(k) for k in list(kw) if k in _CONFIG_FIELDS}
        spec = self
        if cfg_kw:
            spec = dataclasses.replace(
                spec, config=dataclasses.replace(spec.config, **cfg_kw)
            )
        return dataclasses.replace(spec, **kw) if kw else spec

    # -- serialization ------------------------------------------------------

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["topology_kwargs"] = dict(self.topology_kwargs)
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "ExperimentSpec":
        d = dict(d)
        d["config"] = PiscoConfig(**d["config"])
        d["topology_kwargs"] = tuple(sorted(dict(d.get("topology_kwargs", {})).items()))
        return cls(**d)

    def to_json(self) -> str:
        return json.dumps(self.to_dict(), sort_keys=True)

    @classmethod
    def from_json(cls, s: str) -> "ExperimentSpec":
        return cls.from_dict(json.loads(s))

    # -- derived pieces -----------------------------------------------------

    @property
    def effective_network(self) -> Optional[str]:
        """The network process spec after ``cohort`` sugar is expanded."""
        if self.cohort is not None:
            return f"cohort:{self.cohort:g}"
        return self.network

    @property
    def use_sparse(self) -> bool:
        """Whether this spec routes through the sparse edge-list mixers."""
        return use_sparse_topology(self.sparse, self.config.n_agents)

    def make_mixing(self) -> MixingOps:
        if self.use_sparse:
            stopo = make_sparse_topology(
                self.topology, self.config.n_agents, **dict(self.topology_kwargs)
            )
            mixing = make_sparse_network_mixing(
                stopo, self.effective_network, self.participation,
                seed=self.config.seed,
            )
        else:
            topo = make_topology(
                self.topology, self.config.n_agents, **dict(self.topology_kwargs)
            )
            mixing = make_network_mixing(
                topo, self.effective_network, self.participation,
                seed=self.config.seed,
            )
        # fault injection + robust server rule wrap BEFORE compression, so
        # corruption rides the compressed wire stream (Byzantine agents
        # corrupt what they transmit); the clean spec returns mixing as-is
        mixing = make_adversarial_mixing(
            mixing, self.adversary, self.robust_agg,
            n_agents=self.config.n_agents, seed=self.config.seed,
        )
        if self.compression is not None:
            mixing = compress_mixing(
                mixing,
                make_compressor(self.compression),
                error_feedback=self.error_feedback,
                seed=self.config.seed,
            )
        return mixing


class Experiment:
    """A spec plus the runtime problem pieces; ``run()`` produces a History.

    ``sampler_factory(spec)`` builds a fresh per-round sampler for a spec (the
    hook multi-seed sweeps use); a plain ``sampler`` works for single runs.
    ``mixing`` overrides the spec-derived dense mixer — the hook the launcher
    uses to swap in collective (shard_map) mixers.
    """

    def __init__(
        self,
        spec: ExperimentSpec,
        *,
        loss_fn: LossFn,
        params0: Optional[PyTree] = None,
        x0: Optional[PyTree] = None,
        sampler: Optional[Sampler] = None,
        sampler_factory: Optional[Callable[[ExperimentSpec], Sampler]] = None,
        eval_fn: Optional[EvalFn] = None,
        mixing: Optional[MixingOps] = None,
        stop_when: Optional[Callable[[History], bool]] = None,
        recorder: Any = None,
    ):
        if (params0 is None) == (x0 is None):
            raise ValueError("pass exactly one of params0 (unstacked) or x0 (stacked)")
        if (sampler is None) == (sampler_factory is None):
            raise ValueError("pass exactly one of sampler or sampler_factory")
        self.spec = spec
        self.loss_fn = loss_fn
        self._params0 = params0
        self._x0 = x0
        self._sampler = sampler
        self._sampler_factory = sampler_factory
        self.eval_fn = eval_fn
        self._mixing = mixing
        self.stop_when = stop_when
        # Optional repro.obs TraceRecorder: threaded onto each History so the
        # drivers' recording funnel emits spans.  Deliberately NOT part of
        # _pieces() — grid sweeps build fresh Experiments and must not share
        # (and interleave onto) one recorder timeline.
        self.recorder = recorder

    # -- plumbing -----------------------------------------------------------

    def _pieces(self) -> dict:
        return dict(
            loss_fn=self.loss_fn,
            params0=self._params0,
            x0=self._x0,
            sampler=self._sampler,
            sampler_factory=self._sampler_factory,
            eval_fn=self.eval_fn,
            mixing=self._mixing,
            stop_when=self.stop_when,
        )

    def _make_sampler(self, spec: ExperimentSpec) -> Sampler:
        if self._sampler_factory is not None:
            return self._sampler_factory(spec)
        return self._sampler

    def _x0_stacked(self) -> PyTree:
        if self._x0 is not None:
            return self._x0
        return replicate_params(self._params0, self.spec.config.n_agents)

    def _bind(self, mixing: MixingOps) -> BoundAlgorithm:
        spec = self.spec
        opt_kw = resolve_update_rules(
            spec.optimizer, spec.server_optimizer, spec.lr_schedule,
            spec.opt_policy,
            eta_l=spec.config.eta_l, rounds=spec.rounds, t_o=spec.config.t_o,
        )
        return get_algorithm(spec.algo).bind(
            self.loss_fn, spec.config, mixing, **opt_kw
        )

    def _fresh_history(self, mixing: MixingOps, bound: BoundAlgorithm) -> History:
        hist = History(
            byte_model=make_byte_model(
                mixing,
                self._x0_stacked(),
                self.spec.config.n_agents,
                mixes_per_round=bound.comm.mixes_per_round,
                server_payloads=bound.comm.server_payloads,
            )
        )
        if self.spec.systems is not None and self.spec.driver != "events":
            # local import: repro.sim imports the Experiment API
            from repro.sim.costmodel import make_time_model

            # pricing sees the base network (unwrap_network): Byzantine
            # agents send wrong bytes, not different byte/time counts
            hist.time_model = make_time_model(
                self.spec, hist.byte_model, network=unwrap_network(mixing.network)
            )
        hist.adversary_mask = adversary_mask(
            self.spec.adversary, self.spec.config.n_agents, self.spec.config.seed
        )
        return hist

    # -- execution ----------------------------------------------------------

    def run(self) -> History:
        spec = self.spec
        if spec.driver == "events":
            return self._run_events()
        mixing = self._mixing if self._mixing is not None else spec.make_mixing()
        bound = self._bind(mixing)
        sampler = self._make_sampler(spec)
        _, comm0 = sampler(-1)
        state = bound.init(self.loss_fn, self._x0_stacked(), comm0)
        hist = self._fresh_history(mixing, bound)
        # single runs only: seed sweeps share device programs but must not
        # interleave many seeds onto one recorder timeline
        hist.recorder = self.recorder
        drive = drive_scan if spec.driver == "scan" else drive_loop
        kw = {"block_size": spec.block_size} if spec.driver == "scan" else {}
        with record_wall_time(hist):
            state = drive(
                bound, state, sampler, spec.rounds, hist,
                eval_fn=self.eval_fn, eval_every=spec.eval_every,
                stop_when=self.stop_when, **kw,
            )
        hist.final_state = state
        return hist

    def _run_events(self) -> History:
        """The events-driver execution path (DESIGN.md §13).

        The event clock needs the whole flag sequence up front (staleness is
        a property of the entire schedule), so the stateful Bernoulli(p)
        schedule is pre-drawn exactly once in round order — the same draws
        the sync drivers would have made.  When the realized fleet makes the
        run **trivial** (no staleness drops, exactly uniform aggregation
        weights — any degenerate uniform/free-link profile), the ordinary
        spec mixing is bound and the executed device program is bit-identical
        to ``driver="scan"``; otherwise the staleness-aware async mixing
        carries the engine's per-round decisions into the numerics.
        """
        from repro.events.clock import make_event_engine
        from repro.events.driver import drive_events, make_async_mixing

        spec = self.spec
        mixing = self._mixing if self._mixing is not None else spec.make_mixing()
        bound = self._bind(mixing)
        flags = predraw_schedule(bound.schedule, 0, spec.rounds)
        byte_model = make_byte_model(
            mixing,
            self._x0_stacked(),
            spec.config.n_agents,
            mixes_per_round=bound.comm.mixes_per_round,
            server_payloads=bound.comm.server_payloads,
        )
        engine = make_event_engine(
            spec, byte_model, flags,
            network=unwrap_network(getattr(mixing, "network", None)),
        )
        if not engine.trivial:
            mixing = make_async_mixing(spec)
            bound = self._bind(mixing)
        sampler = self._make_sampler(spec)
        _, comm0 = sampler(-1)
        state = bound.init(self.loss_fn, self._x0_stacked(), comm0)
        hist = History(byte_model=byte_model)
        hist.recorder = self.recorder
        hist.event_trace = engine.trace
        hist.adversary_mask = adversary_mask(
            spec.adversary, spec.config.n_agents, spec.config.seed
        )
        with record_wall_time(hist):
            state = drive_events(
                bound, state, sampler, spec.rounds, hist,
                engine=engine, eval_fn=self.eval_fn,
                eval_every=spec.eval_every, stop_when=self.stop_when,
                block_size=spec.block_size,
            )
        hist.final_state = state
        return hist

    def sweep(
        self,
        seeds: Optional[Sequence[int]] = None,
        grid: Optional[Dict[str, Sequence[Any]]] = None,
    ):
        """Either a vmapped multi-seed run (``seeds=[...]`` -> list of History,
        one per seed, all seeds advanced on-device in one scanned program) or a
        sequential hyper-parameter grid (``grid={"p": [...], ...}`` -> list of
        ``(spec, History)`` over the cartesian product)."""
        if (seeds is None) == (grid is None):
            raise ValueError("pass exactly one of seeds or grid")
        if grid is not None:
            out = []
            for combo in itertools.product(*grid.values()):
                spec = self.spec.replace(**dict(zip(grid.keys(), combo)))
                out.append((spec, Experiment(spec, **self._pieces()).run()))
            return out
        return self._sweep_seeds(list(seeds))

    def _sweep_seeds(self, seeds: List[int]) -> List[History]:
        if self._sampler_factory is None:
            raise ValueError("sweep(seeds=...) needs a sampler_factory")
        if self.spec.driver == "events":
            raise ValueError(
                "sweep(seeds=...) does not support driver='events'; "
                "run per-seed via sweep(grid={'seed': [...]}) instead"
            )
        spec = self.spec
        n_seeds = len(seeds)
        mixing = self._mixing if self._mixing is not None else spec.make_mixing()
        bound = self._bind(mixing)
        samplers = [self._make_sampler(spec.replace(seed=s)) for s in seeds]

        def stacked_sampler(k: int):
            batches = [s(k) for s in samplers]
            return (
                stack_rounds([b[0] for b in batches]),
                stack_rounds([b[1] for b in batches]),
            )

        # Seed axis in front of everything the round functions touch: states
        # and batches are vmapped, the schedule flag broadcasts.
        x0 = self._x0_stacked()
        x0_s = jax.tree.map(
            lambda v: jnp.broadcast_to(v[None], (n_seeds,) + v.shape), x0
        )
        _, comm0 = stacked_sampler(-1)
        state = jax.vmap(lambda x, b: bound.init(self.loss_fn, x, b))(x0_s, comm0)
        same = bound.global_round is bound.gossip_round
        vgossip = jax.vmap(bound.gossip_round)
        vbound = dataclasses.replace(
            bound,
            gossip_round=vgossip,
            global_round=vgossip if same else jax.vmap(bound.global_round),
        )
        block_fn = make_block_fn(vbound)

        hists = [self._fresh_history(mixing, bound) for _ in seeds]
        cuts = block_bounds(
            spec.rounds,
            eval_every=spec.eval_every if self.eval_fn is not None else 0,
            block_size=spec.block_size,
        )
        net = bound.network
        with record_wall_time(*hists):
            for start, stop in cuts:
                flags = predraw_schedule(bound.schedule, start, stop)
                per_seed = [sample_block(s, start, stop) for s in samplers]
                # (block, seeds, ...) — round axis scans, seed axis vmaps
                local = jax.tree.map(
                    lambda *ls: jnp.stack(ls, axis=1), *[b[0] for b in per_seed]
                )
                comm = jax.tree.map(
                    lambda *ls: jnp.stack(ls, axis=1), *[b[1] for b in per_seed]
                )
                if net is None:
                    realized = None
                    state, metrics = block_fn(state, jnp.asarray(flags), local, comm)
                else:
                    # all seeds advance through the same realized network (like
                    # the shared schedule); the matrices broadcast across the
                    # vmapped seed axis as scan-body closure constants
                    wg, ws, messages, participants = net.draw_block(start, stop)
                    realized = (messages, participants)
                    state, metrics = block_fn(
                        state, jnp.asarray(flags), jax.tree.map(jnp.asarray, wg),
                        jax.tree.map(jnp.asarray, ws), local, comm,
                    )
                loss = np.asarray(metrics.loss, dtype=np.float64)  # (block, seeds)
                gsq = np.asarray(metrics.grad_sq_norm, dtype=np.float64)
                cerr = np.asarray(metrics.consensus_err, dtype=np.float64)
                k_end = stop - 1
                do_eval = self.eval_fn is not None and (
                    k_end % spec.eval_every == 0 or k_end == spec.rounds - 1
                )
                for i, hist in enumerate(hists):
                    hist.loss.extend(loss[:, i].tolist())
                    hist.grad_sq_norm.extend(gsq[:, i].tolist())
                    hist.consensus_err.extend(cerr[:, i].tolist())
                    record_flags(hist, flags, realized, start=start)
                    if do_eval:
                        x_bar = jax.tree.map(
                            lambda v: jnp.mean(v[i], axis=0), state.x
                        )
                        hist.eval_metrics.append(
                            dict(self.eval_fn(x_bar), round=k_end)
                        )
                        if hist.adversary_mask is not None:
                            state_i = jax.tree.map(lambda v: v[i], state)
                            hist.eval_per_agent.append(_eval_agent_groups(
                                self.eval_fn, state_i, k_end,
                                hist.adversary_mask,
                            ))
        for i, hist in enumerate(hists):
            hist.final_state = jax.tree.map(lambda v: v[i], state)
        return hists


def run_experiment(spec: ExperimentSpec, **pieces) -> History:
    """One-shot convenience: ``run_experiment(spec, loss_fn=..., ...)``."""
    return Experiment(spec, **pieces).run()
