"""Round drivers: how communication rounds get executed on the device.

Three drivers, one contract — fill a :class:`~repro.core.trainer.History` and
return the final algorithm state:

* **loop** — the legacy per-round Python host loop: one jitted round-function
  call per round, three scalar device→host syncs per round for the metrics.
  Simple, and the reference semantics.

* **scan** — chunked ``lax.scan``: the Bernoulli(p) schedule for a *block* of
  rounds is pre-drawn on the host (identical draws, in round order, to the
  legacy loop — line 8 of Algorithm 1 is a host-side i.i.d. sequence either
  way), the block's minibatches are stacked along a new leading axis, and the
  whole block runs on-device as one ``lax.scan`` whose body dispatches between
  the gossip and global round functions with ``lax.cond``.  The host touches
  the device once per block (stacked metrics) instead of three times per
  round, and blocks are cut exactly at eval boundaries so the eval-at-x̄
  semantics match the loop round-for-round.

* **events** — the asynchronous event-queue driver (:mod:`repro.events`,
  DESIGN.md §13): round boundaries come from a simulated-clock priority
  queue over the spec's systems profile instead of a global barrier.  It
  lives in its own package and consumes this module's shared helpers
  (:func:`record_block`, :func:`maybe_eval`, :func:`make_block_fn`) — the
  third consumer, not a third copy.

All drivers duck-type the history object (``loss`` / ``grad_sq_norm`` /
``consensus_err`` / ``is_global`` lists, ``accountant``, ``byte_model``,
``eval_metrics``) so this module has no import cycle with the trainer.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.algorithms import BoundAlgorithm

PyTree = Any
Sampler = Callable[[int], tuple]
EvalFn = Callable[[PyTree], Dict[str, float]]

DEFAULT_BLOCK_SIZE = 32

DRIVERS = ("loop", "scan", "events")


def predraw_schedule(schedule, start: int, stop: int) -> np.ndarray:
    """Materialize ``schedule(k)`` for ``k in [start, stop)`` as a bool array.

    Draws happen in round order, so a stateful :class:`BernoulliSchedule`
    yields the exact flag sequence the legacy loop would have seen."""
    return np.array([bool(schedule(k)) for k in range(start, stop)], dtype=bool)


def stack_rounds(per_round: Sequence[PyTree]) -> PyTree:
    """Stack a list of per-round batch pytrees along a new leading round axis."""
    return jax.tree.map(lambda *leaves: jnp.stack(leaves), *per_round)


def sample_block(sampler: Sampler, start: int, stop: int) -> Tuple[PyTree, PyTree]:
    """``(local, comm)`` for rounds ``[start, stop)`` with a leading round
    axis.  Samplers exposing ``sample_block(start, stop)`` (one gather + one
    device put, e.g. :class:`repro.data.RoundSampler`) take the fast path;
    anything else falls back to per-round calls + on-device stacking."""
    fast = getattr(sampler, "sample_block", None)
    if fast is not None:
        return fast(start, stop)
    batches = [sampler(k) for k in range(start, stop)]
    return (
        stack_rounds([b[0] for b in batches]),
        stack_rounds([b[1] for b in batches]),
    )


def block_bounds(
    rounds: int, *, eval_every: int = 0, block_size: int = DEFAULT_BLOCK_SIZE,
    start: int = 0,
) -> List[Tuple[int, int]]:
    """Split ``[start, rounds)`` into scan blocks.

    Blocks end immediately after every eval round (``k % eval_every == 0`` or
    ``k == rounds - 1``; ``eval_every <= 0`` disables eval cuts) and never
    exceed ``block_size`` rounds — the only points where the driver must sync
    state to the host."""
    assert block_size >= 1
    bounds = []
    k = start
    while k < rounds:
        stop = min(k + block_size, rounds)
        if eval_every > 0:
            nxt = k if k % eval_every == 0 else (k // eval_every + 1) * eval_every
            nxt = min(nxt, rounds - 1)
            stop = min(stop, nxt + 1)
        bounds.append((k, stop))
        k = stop
    return bounds


def make_block_fn(bound: BoundAlgorithm, *, jit: bool = True) -> Callable:
    """One jitted block function scanning a block of rounds on-device:
    ``(state, flags, local, comm)`` for a static network, or
    ``(state, flags, w_gossip, w_server, local, comm)`` when ``bound.network``
    is set — the per-round mixing matrices ride the scan exactly like the
    pre-drawn Bernoulli(p) flags.

    ``flags`` is the pre-drawn bool vector (block,), ``local``/``comm`` carry
    the block's batches with a leading round axis.  When the algorithm uses a
    single round function for both kinds (FedAvg, SCAFFOLD) the ``lax.cond``
    is elided."""
    gossip, glob = bound.gossip_round, bound.global_round
    same = glob is gossip
    net = bound.network

    if net is None:
        def body(state, per_round):
            flag, local, comm = per_round
            if same:
                return gossip(state, local, comm)
            return jax.lax.cond(flag, glob, gossip, state, local, comm)

        def block_fn(state, flags, local, comm):
            return jax.lax.scan(body, state, (flags, local, comm))
    else:
        def body(state, per_round):
            flag, w_gossip, w_server, local, comm = per_round
            # Stage this round's matrices; the mixing closures inside the
            # round functions read them as live scan-operand tracers.
            net.slot.set(w_gossip, w_server)
            if same:
                return gossip(state, local, comm)
            return jax.lax.cond(flag, glob, gossip, state, local, comm)

        def block_fn(state, flags, w_gossip, w_server, local, comm):
            return jax.lax.scan(
                body, state, (flags, w_gossip, w_server, local, comm)
            )

    return jax.jit(block_fn) if jit else block_fn


def dynamic_round_fns(
    bound: BoundAlgorithm, *, jit: bool = True
) -> Tuple[Callable, Callable]:
    """Per-round ``(gossip_fn, global_fn)`` for a dynamic network, each with
    signature ``(state, local, comm, w_gossip, w_server)``: the matrices are
    explicit jit arguments (fresh per round, one trace), staged into the
    network slot before the wrapped round function is traced."""
    net = bound.network
    assert net is not None, "dynamic_round_fns requires bound.network"
    gossip, glob = bound.gossip_round, bound.global_round
    same = glob is gossip

    def wrap(fn):
        def fn_w(state, local, comm, w_gossip, w_server):
            net.slot.set(w_gossip, w_server)
            return fn(state, local, comm)

        return fn_w

    gossip_w = wrap(gossip)
    global_w = gossip_w if same else wrap(glob)
    if jit:
        gossip_w = jax.jit(gossip_w)
        global_w = gossip_w if same else jax.jit(global_w)
    return gossip_w, global_w


def _eval_at_xbar(eval_fn: EvalFn, state, k: int) -> Dict[str, float]:
    x_bar = jax.tree.map(lambda v: jnp.mean(v, axis=0), state.x)
    return dict(eval_fn(x_bar), round=k)


def _eval_agent_groups(eval_fn: EvalFn, state, k: int, mask) -> Dict[str, float]:
    """Split eval-at-x̄ by the Byzantine mask: the honest agents' consensus
    point (``honest_<key>``) vs. the faulty group's (``byz_<key>``) — the
    per-agent series a robustness run reads to see who actually converged."""
    m = np.asarray(mask, dtype=bool)
    out: Dict[str, float] = {}
    honest = jax.tree.map(lambda v: jnp.mean(v[~m], axis=0), state.x)
    for key, val in eval_fn(honest).items():
        out[f"honest_{key}"] = val
    if m.any():
        byz = jax.tree.map(lambda v: jnp.mean(v[m], axis=0), state.x)
        for key, val in eval_fn(byz).items():
            out[f"byz_{key}"] = val
    out["round"] = k
    return out


def record_flags(
    hist, flags: np.ndarray, realized=None, start: int = 0, seconds=None
) -> None:
    """Record schedule flags + per-round bytes (and simulated seconds when a
    time model is attached).  ``realized`` is an optional
    ``(messages, participants)`` pair of per-round arrays for dynamic
    networks — bytes are then priced per realized edge/participant instead of
    the static round constants.  ``start`` is the absolute index of the
    block's first round — the time model's draws are pure in ``(seed, k)``.
    ``seconds`` overrides the time model with an explicit per-round array
    (the events driver prices rounds from its own event trace).

    When the history carries a :class:`~repro.obs.trace.TraceRecorder`
    (``hist.recorder``), each round additionally becomes a span with the
    same byte/second attribution the accountant gets — recording is purely
    host-side bookkeeping over values this function already synced, so a
    ``recorder=None`` run is bit-identical by construction."""
    time_model = getattr(hist, "time_model", None)
    rec = getattr(hist, "recorder", None)
    for i, f in enumerate(flags):
        f = bool(f)
        hist.is_global.append(f)
        if realized is None:
            nbytes = hist.byte_model.round_bytes(f)
        else:
            messages, participants = realized
            nbytes = hist.byte_model.realized_round_bytes(
                f, int(messages[i]), int(participants[i])
            )
        if seconds is not None:
            sec = float(seconds[i])
        elif time_model is not None:
            sec = time_model.round_time(start + i, f)
        else:
            sec = None
        hist.accountant.record(f, nbytes, seconds=sec)
        if rec is not None:
            parts = None
            if seconds is None and time_model is not None:
                parts = time_model.round_parts(start + i, f)
            rec.record_round(start + i, f, nbytes, seconds=sec, parts=parts)


def record_block(
    hist, metrics, flags: np.ndarray, realized=None, *, start: int = 0,
    seconds=None,
) -> None:
    """One history append for a block of executed rounds — the single
    recording path every driver (loop, scan, events) funnels through:
    extends the metric series and prices flags/bytes/seconds via
    :func:`record_flags`.  ``metrics`` is a RoundMetrics pytree whose leaves
    carry a leading round axis (a loop round passes block size 1)."""
    hist.loss.extend(
        np.asarray(metrics.loss, dtype=np.float64).reshape(-1).tolist()
    )
    hist.grad_sq_norm.extend(
        np.asarray(metrics.grad_sq_norm, dtype=np.float64).reshape(-1).tolist()
    )
    hist.consensus_err.extend(
        np.asarray(metrics.consensus_err, dtype=np.float64).reshape(-1).tolist()
    )
    record_flags(hist, flags, realized, start=start, seconds=seconds)


def eval_boundary(k: int, rounds: int, eval_every: int) -> bool:
    """Whether round ``k`` is an eval round: every ``eval_every`` rounds and
    always at the final round — the one boundary rule all drivers share (the
    scan driver also cuts its blocks here so eval-at-x̄ matches the loop)."""
    return k % eval_every == 0 or k == rounds - 1


def maybe_eval(hist, eval_fn: Optional[EvalFn], eval_every: int, rounds: int,
               state, k: int) -> None:
    """Append the eval-at-x̄ readout when round ``k`` is an eval boundary;
    histories carrying an ``adversary_mask`` additionally get the
    honest-vs-Byzantine group split appended to ``eval_per_agent``."""
    if eval_fn is None or not eval_boundary(k, rounds, eval_every):
        return
    hist.eval_metrics.append(_eval_at_xbar(eval_fn, state, k))
    rec = getattr(hist, "recorder", None)
    if rec is not None:
        m = {k2: v for k2, v in hist.eval_metrics[-1].items() if k2 != "round"}
        rec.add_instant("rounds", "eval", rec.clock_s, round=k, **m)
    mask = getattr(hist, "adversary_mask", None)
    if mask is not None:
        hist.eval_per_agent.append(_eval_agent_groups(eval_fn, state, k, mask))


def drive_scan(
    bound: BoundAlgorithm,
    state,
    sampler: Sampler,
    rounds: int,
    hist,
    *,
    eval_fn: Optional[EvalFn] = None,
    eval_every: int = 1,
    stop_when: Optional[Callable] = None,
    block_size: int = DEFAULT_BLOCK_SIZE,
    block_fn: Optional[Callable] = None,
):
    """Chunked-scan driver.  ``stop_when`` is consulted at block boundaries
    (the only host-visible points), so a stop may overshoot by at most one
    block relative to the legacy loop.  Pass a prebuilt ``block_fn`` (from
    :func:`make_block_fn`) to reuse its jit cache across drives."""
    if block_fn is None:
        block_fn = make_block_fn(bound)
    cuts = block_bounds(
        rounds,
        eval_every=eval_every if eval_fn is not None else 0,
        block_size=block_size,
    )
    net = bound.network
    for start, stop in cuts:
        flags = predraw_schedule(bound.schedule, start, stop)
        local, comm = sample_block(sampler, start, stop)
        if net is None:
            realized = None
            state, metrics = block_fn(state, jnp.asarray(flags), local, comm)
        else:
            w_gossip, w_server, messages, participants = net.draw_block(start, stop)
            realized = (messages, participants)
            # tree-mapped: sparse networks draw pytree operands, dense draw
            # bare matrices — both convert leafwise
            state, metrics = block_fn(
                state, jnp.asarray(flags), jax.tree.map(jnp.asarray, w_gossip),
                jax.tree.map(jnp.asarray, w_server), local, comm,
            )
        # one device->host sync for the whole block
        record_block(hist, metrics, flags, realized, start=start)
        maybe_eval(hist, eval_fn, eval_every, rounds, state, stop - 1)
        if stop_when is not None and stop_when(hist):
            break
    return state


def drive_loop(
    bound: BoundAlgorithm,
    state,
    sampler: Sampler,
    rounds: int,
    hist,
    *,
    eval_fn: Optional[EvalFn] = None,
    eval_every: int = 1,
    stop_when: Optional[Callable] = None,
    jit: bool = True,
    round_fns: Optional[Tuple[Callable, Callable]] = None,
):
    """The legacy per-round host loop (reference semantics).  ``round_fns``
    supplies prejitted ``(gossip_fn, global_fn)`` to reuse across drives —
    when ``bound.network`` is set they must be the matrix-threaded form from
    :func:`dynamic_round_fns`."""
    net = bound.network
    if round_fns is not None:
        gossip_fn, global_fn = round_fns
    elif net is not None:
        gossip_fn, global_fn = dynamic_round_fns(bound, jit=jit)
    else:
        gossip_fn, global_fn = bound.gossip_round, bound.global_round
        if jit:
            gossip_fn = jax.jit(gossip_fn)
            global_fn = (
                jax.jit(global_fn)
                if global_fn is not bound.gossip_round else gossip_fn
            )
    for k in range(rounds):
        local_batches, comm_batch = sampler(k)
        is_global = bool(bound.schedule(k))
        fn = global_fn if is_global else gossip_fn
        if net is None:
            realized = None
            state, metrics = fn(state, local_batches, comm_batch)
        else:
            w_gossip, w_server, messages, participants = net.draw_round(k)
            state, metrics = fn(
                state, local_batches, comm_batch,
                jax.tree.map(jnp.asarray, w_gossip),
                jax.tree.map(jnp.asarray, w_server),
            )
            realized = ([messages], [participants])
        record_block(
            hist, metrics, np.array([is_global]), realized, start=k
        )
        maybe_eval(hist, eval_fn, eval_every, rounds, state, k)
        if stop_when is not None and stop_when(hist):
            break
    return state


def get_driver(name: str) -> Callable:
    if name == "scan":
        return drive_scan
    if name == "loop":
        return drive_loop
    if name == "events":
        # local import: the event-queue subsystem builds on this module
        from repro.events.driver import drive_events

        return drive_events
    raise ValueError(f"unknown driver {name!r}; options: {DRIVERS}")
