"""PISCO — Algorithm 1 of the paper, verbatim, over agent-stacked pytrees.

One communication round k (two stages):

  Stage 1 — T_o *local* tracked-SGD steps, zero communication (eq. 3a-3c):
      X^{k+1,t} = X^{k+1,t-1} - eta_l * Y^{k+1,t-1}
      G^{k+1,t} = stochastic grads at X^{k+1,t}
      Y^{k+1,t} = Y^{k+1,t-1} + G^{k+1,t} - G^{k+1,t-1}

  Stage 2 — one mixing round with W^k = J w.p. p else W (eq. 4a-4c):
      X^{k+1} = ((1-eta_c) X^k + eta_c (X^{k+1,T_o} - eta_l Y^{k+1,T_o})) W^k
      G^{k+1} = stochastic grads at X^{k+1} on a fresh batch
      Y^{k+1} = (Y^{k+1,T_o} + G^{k+1} - G^{k+1,T_o}) W^k

The probabilistic draw of W^k is made by the *host* trainer (uniform across
agents, i.i.d. per round — identical semantics to line 8 of Algorithm 1), which
dispatches one of two jitted round functions.  See DESIGN.md §2.

State invariant (Lemma 1, tested):  mean_i y_i == mean_i g_i  exactly, at every
round and every local step.

Update rules (DESIGN.md §10): the hardcoded ``x - eta_l * y`` descent of
eq. 3a generalizes to any :class:`repro.optim.UpdateRule` — the tracker Y is
the descent *direction*, the rule (momentum, Adam, clipped/scheduled chains)
decides the step.  ``local_opt=None`` keeps the historical inline arithmetic
bit-for-bit; ``server_opt`` adds a FedOpt-style server update (FedAvgM /
FedAdam) at global-averaging rounds, descending from the averaged previous
iterate along the round pseudo-gradient.  Lemma 1 is untouched either way:
the Y/G recursion never reads the optimizer state.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.mixing import MixingOps
from repro.optim.update_rules import (
    UpdateRule,
    apply_updates,
    comm_opt_state,
    init_opt_state,
    server_step,
    sgd as _sgd_rule,
)
from repro.utils.pytree import (
    tree_add,
    tree_axpy,
    tree_scale,
    tree_sq_norm,
    tree_sub,
)

PyTree = Any
# loss_fn(params, batch) -> scalar loss for ONE agent.
LossFn = Callable[[PyTree, Any], jnp.ndarray]


@dataclasses.dataclass(frozen=True)
class PiscoConfig:
    """Hyper-parameters of Algorithm 1."""

    n_agents: int
    t_o: int = 1  # number of local updates per round (T_o)
    eta_l: float = 0.05  # local-update step size
    eta_c: float = 1.0  # communication step size
    p: float = 0.1  # agent-to-server probability
    seed: int = 0

    def __post_init__(self):
        assert self.t_o >= 1, "T_o >= 1 (at least one local update)"
        assert 0.0 <= self.p <= 1.0


class PiscoState(NamedTuple):
    """Agent-stacked algorithm state (leading axis = n_agents on every leaf)."""

    x: PyTree  # model estimates X^k
    y: PyTree  # gradient-tracking variables Y^k
    g: PyTree  # last stochastic gradients G^k
    step: jnp.ndarray  # round counter k
    # Compressed-gossip side state: () when compression is off (zero leaves,
    # zero bytes), else {"x": residual, "y": residual, "key": PRNGKey} from
    # CompressedGossip.init_ef (see repro.core.compression).
    ef: PyTree = ()
    # Optimizer state: () when no update rules are bound (the legacy
    # hardcoded-SGD path), else {"local": agent-stacked rule state,
    # "server": FedOpt server state or ()} from optim.init_opt_state.
    opt: PyTree = ()


class RoundMetrics(NamedTuple):
    loss: jnp.ndarray  # mean over agents & local steps
    grad_sq_norm: jnp.ndarray  # ||mean_i g_i||^2 (tracked-gradient proxy)
    consensus_err: jnp.ndarray  # ||X - X_bar||_F^2 / n


def make_stacked_value_and_grad(loss_fn: LossFn) -> Callable:
    """vmap value_and_grad over the agent axis: each agent gets its own params
    slice and its own batch slice."""
    vg = jax.value_and_grad(loss_fn)
    return jax.vmap(vg, in_axes=(0, 0))


def init_state(
    loss_fn: LossFn,
    x0: PyTree,
    batch0: Any,
    local_opt: Optional[UpdateRule] = None,
    server_opt: Optional[UpdateRule] = None,
) -> PiscoState:
    """Line 2: draw Z^0 and set Y^0 = G^0 = grads(X^0; Z^0).

    ``x0`` must already be agent-stacked (typically every agent starts from the
    same point: X^0 = x^0 1^T).  When update rules are bound, their state is
    attached up front so the scan driver's carry structure is round-invariant."""
    _, g0 = make_stacked_value_and_grad(loss_fn)(x0, batch0)
    return PiscoState(
        x=x0, y=g0, g=g0, step=jnp.zeros((), jnp.int32),
        opt=init_opt_state(x0, local_opt, server_opt),
    )


def init_compression_state(state: PiscoState, mixing: MixingOps) -> PiscoState:
    """Attach error-feedback residuals when ``mixing`` carries a compressor
    (no-op otherwise); the trainer calls this right after :func:`init_state`."""
    if mixing.compression is None:
        return state
    return state._replace(ef=mixing.compression.init_ef(state.x))


def replicate_params(params: PyTree, n_agents: int) -> PyTree:
    """X^0 = x^0 1_n^T — identical start for all agents."""
    return jax.tree.map(
        lambda p: jnp.broadcast_to(p[None], (n_agents,) + p.shape), params
    )


def _local_phase(
    stacked_vg: Callable,
    state: PiscoState,
    local_batches: Any,  # leaves shaped (T_o, n_agents, ...)
    eta_l: float,
) -> Tuple[PyTree, PyTree, PyTree, jnp.ndarray]:
    """Stage 1: lax.scan over the T_o local updates."""

    def step(carry, batch_t):
        x, y, g = carry
        x = jax.tree.map(lambda xi, yi: xi - eta_l * yi, x, y)  # (3a)
        loss, g_new = stacked_vg(x, batch_t)  # (3b)
        y = tree_add(y, tree_sub(g_new, g))  # (3c)
        return (x, y, g_new), jnp.mean(loss)

    (x_to, y_to, g_to), losses = jax.lax.scan(
        step, (state.x, state.y, state.g), local_batches
    )
    return x_to, y_to, g_to, jnp.mean(losses)


def _local_phase_rule(
    stacked_vg: Callable,
    state: PiscoState,
    local_batches: Any,
    rule: UpdateRule,
    opt0: PyTree,
) -> Tuple[PyTree, PyTree, PyTree, PyTree, jnp.ndarray]:
    """Stage 1 with a pluggable update rule: the tracker Y is the descent
    direction (3a generalized), the rule turns it into a step."""

    def step(carry, batch_t):
        x, y, g, opt = carry
        upd, opt = rule.update(y, opt, x)  # (3a): direction = tracker
        x = apply_updates(x, upd)
        loss, g_new = stacked_vg(x, batch_t)  # (3b)
        y = tree_add(y, tree_sub(g_new, g))  # (3c)
        return (x, y, g_new, opt), jnp.mean(loss)

    (x_to, y_to, g_to, opt), losses = jax.lax.scan(
        step, (state.x, state.y, state.g, opt0), local_batches
    )
    return x_to, y_to, g_to, opt, jnp.mean(losses)


def _consensus_error(x: PyTree) -> jnp.ndarray:
    def leaf(v):
        mean = jnp.mean(v, axis=0, keepdims=True)
        return jnp.sum((v - mean) ** 2)

    errs = jax.tree.map(leaf, x)
    return jax.tree.reduce(jnp.add, errs)


def _round_metrics(cfg, mean_loss, loss_c, g_new, x_new, compute_metrics):
    if not compute_metrics:
        z = jnp.zeros(())
        return RoundMetrics(z, z, z)
    gbar = jax.tree.map(lambda v: jnp.mean(v, axis=0), g_new)
    return RoundMetrics(
        loss=(mean_loss * cfg.t_o + jnp.mean(loss_c)) / (cfg.t_o + 1),
        grad_sq_norm=tree_sq_norm(gbar),
        consensus_err=_consensus_error(x_new) / cfg.n_agents,
    )


def make_round_fn(
    loss_fn: LossFn,
    cfg: PiscoConfig,
    mixing: MixingOps,
    *,
    global_round: bool,
    compute_metrics: bool = True,
    use_ef: bool = True,
    local_opt: Optional[UpdateRule] = None,
    server_opt: Optional[UpdateRule] = None,
    opt_policy: str = "mix",
) -> Callable[[PiscoState, Any, Any], Tuple[PiscoState, RoundMetrics]]:
    """Build one jittable PISCO round for a fixed W^k kind.

    The trainer compiles this twice (gossip / global) and dispatches per the
    host-side Bernoulli(p) draw.

    When ``mixing`` carries a compression spec and this is a gossip round,
    the two mixes go through the stateful error-feedback path: residuals for
    the X and Y streams ride along in ``state.ef`` (initialized by
    :func:`init_compression_state`).  ``use_ef=False`` forces the stateless
    compressed gossip instead — for callers whose state cannot carry
    residuals (the baselines in :mod:`repro.core.baselines`).

    ``local_opt`` / ``server_opt`` plug in composable update rules
    (DESIGN.md §10): the local rule replaces the hardcoded eta_l descent on
    the tracker, ``opt_policy`` ∈ {"mix", "keep", "reset"} decides what
    happens to its agent-stacked buffers at this communication round, and
    the server rule (global rounds only) applies a FedOpt-style update to
    the averaged iterate.  Both ``None`` (the default) runs the historical
    inline arithmetic — bit-identical outputs, empty opt slot.  ``state``
    must then come from :func:`init_state` with the same rules, so the opt
    slot exists up front.

    Args to the returned fn:
      state:         PiscoState
      local_batches: pytree with leaves (T_o, n_agents, ...)
      comm_batch:    pytree with leaves (n_agents, ...) — the fresh Z^{k+1}
    """
    stacked_vg = make_stacked_value_and_grad(loss_fn)
    mix = mixing.global_avg if global_round else mixing.gossip
    compressed = mixing.compression is not None and not global_round and use_ef
    has_rules = local_opt is not None or server_opt is not None
    if has_rules and local_opt is None:
        local_opt = _default_local_rule(cfg)

    def legacy_round_fn(state: PiscoState, local_batches, comm_batch):
        x_to, y_to, g_to, mean_loss = _local_phase(
            stacked_vg, state, local_batches, cfg.eta_l
        )
        # (4a): X^{k+1} = ((1-eta_c) X^k + eta_c (X^{T_o} - eta_l Y^{T_o})) W^k
        cand = jax.tree.map(
            lambda xk, xt, yt: (1.0 - cfg.eta_c) * xk + cfg.eta_c * (xt - cfg.eta_l * yt),
            state.x,
            x_to,
            y_to,
        )
        ef = getattr(state, "ef", ())
        if compressed:
            cg = mixing.compression
            key, kx, ky = jax.random.split(ef["key"], 3)
            x_new, res_x = cg(cand, ef["x"], kx)
            # (4b): fresh-batch gradients at the mixed point
            loss_c, g_new = stacked_vg(x_new, comm_batch)
            # (4c) compressed: the difference form preserves mean_i over the
            # agent axis, so Lemma 1 (mean Y == mean G) survives exactly.
            y_new, res_y = cg(tree_add(y_to, tree_sub(g_new, g_to)), ef["y"], ky)
            ef = {"x": res_x, "y": res_y, "key": key}
        else:
            x_new = mix(cand)
            # (4b): fresh-batch gradients at the mixed point
            loss_c, g_new = stacked_vg(x_new, comm_batch)
            # (4c): Y^{k+1} = (Y^{T_o} + G^{k+1} - G^{T_o}) W^k
            y_new = mix(tree_add(y_to, tree_sub(g_new, g_to)))

        new_state = PiscoState(
            x=x_new, y=y_new, g=g_new, step=state.step + 1, ef=ef,
            opt=getattr(state, "opt", ()),
        )
        return new_state, _round_metrics(
            cfg, mean_loss, loss_c, g_new, x_new, compute_metrics
        )

    def rule_round_fn(state: PiscoState, local_batches, comm_batch):
        lopt, sopt = state.opt["local"], state.opt["server"]
        x_to, y_to, g_to, lopt, mean_loss = _local_phase_rule(
            stacked_vg, state, local_batches, local_opt, lopt
        )
        # (4a) generalized: one more rule step along the tracker gives the
        # communicated point; eta_c interpolates against X^k as before.
        upd, lopt = local_opt.update(y_to, lopt, x_to)
        half = apply_updates(x_to, upd)
        cand = jax.tree.map(
            lambda xk, h: (1.0 - cfg.eta_c) * xk + cfg.eta_c * h,
            state.x, half,
        )
        ef = getattr(state, "ef", ())
        if compressed:
            cg = mixing.compression
            key, kx, ky = jax.random.split(ef["key"], 3)
            x_new, res_x = cg(cand, ef["x"], kx)
            loss_c, g_new = stacked_vg(x_new, comm_batch)
            y_new, res_y = cg(tree_add(y_to, tree_sub(g_new, g_to)), ef["y"], ky)
            ef = {"x": res_x, "y": res_y, "key": key}
        else:
            if global_round and server_opt is not None:
                # FedOpt server round: descend from the averaged previous
                # iterate along the round pseudo-gradient (DESIGN.md §10).
                x_new, sopt = server_step(
                    server_opt, sopt, mix(state.x), mix(cand)
                )
            else:
                x_new = mix(cand)
            loss_c, g_new = stacked_vg(x_new, comm_batch)
            # (4c) is untouched by the rules: Lemma 1 survives any of them.
            y_new = mix(tree_add(y_to, tree_sub(g_new, g_to)))

        lopt = comm_opt_state(
            lopt, mix, cfg.n_agents, opt_policy, is_global=global_round
        )
        new_state = PiscoState(
            x=x_new, y=y_new, g=g_new, step=state.step + 1, ef=ef,
            opt={"local": lopt, "server": sopt},
        )
        return new_state, _round_metrics(
            cfg, mean_loss, loss_c, g_new, x_new, compute_metrics
        )

    return rule_round_fn if has_rules else legacy_round_fn


def _default_local_rule(cfg: PiscoConfig) -> UpdateRule:
    """The rule-path default when only ``server_opt`` is given: plain SGD at
    ``eta_l`` (bit-identical arithmetic to the hardcoded step)."""
    return _sgd_rule(cfg.eta_l)


# ---------------------------------------------------------------------------
# Special cases (paper Remarks 1 & 2)
# ---------------------------------------------------------------------------


def decentralized_config(cfg: PiscoConfig) -> PiscoConfig:
    """Remark 1: p = 0 — fully decentralized PISCO (gossip only)."""
    return dataclasses.replace(cfg, p=0.0)


def federated_config(cfg: PiscoConfig) -> PiscoConfig:
    """Remark 2: p = 1 — federated PISCO (server every round; SCAFFOLD-like)."""
    return dataclasses.replace(cfg, p=1.0)
