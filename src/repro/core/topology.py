"""Communication graphs and mixing matrices (paper §2.1).

Everything here is *host-side* (numpy): topologies are static metadata that the
launcher turns into either a dense mixing matrix (general ``W``) or a neighbor
schedule for ``ppermute``-based collective mixing.

Definition 1 of the paper: ``W`` is nonnegative, doubly stochastic, with
``w_ij = 0`` iff ``{i,j}`` is not an edge (i != j), and the mixing rate is

    lambda_w = 1 - || W - (1/n) 11^T ||_2^2 = 1 - lambda^2,

where ``lambda`` is the second-largest singular value of ``W``.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

# ---------------------------------------------------------------------------
# Graph constructors (adjacency, no self loops)
# ---------------------------------------------------------------------------


def ring_graph(n: int) -> np.ndarray:
    """Ring: agent i connects to (i-1) % n and (i+1) % n."""
    adj = np.zeros((n, n), dtype=bool)
    for i in range(n):
        adj[i, (i + 1) % n] = True
        adj[i, (i - 1) % n] = True
    if n <= 2:  # ring over <=2 nodes degenerates to a single edge / nothing
        adj = adj | adj.T
    np.fill_diagonal(adj, False)
    return adj


def path_graph(n: int) -> np.ndarray:
    adj = np.zeros((n, n), dtype=bool)
    for i in range(n - 1):
        adj[i, i + 1] = adj[i + 1, i] = True
    return adj


def star_graph(n: int) -> np.ndarray:
    """Agent 0 is the hub (useful as an explicit server-like gossip graph)."""
    adj = np.zeros((n, n), dtype=bool)
    adj[0, 1:] = adj[1:, 0] = True
    return adj


def fully_connected_graph(n: int) -> np.ndarray:
    adj = np.ones((n, n), dtype=bool)
    np.fill_diagonal(adj, False)
    return adj


def torus_graph(rows: int, cols: int) -> np.ndarray:
    """2-D torus over ``rows*cols`` agents (the natural ICI topology)."""
    n = rows * cols
    adj = np.zeros((n, n), dtype=bool)
    for r in range(rows):
        for c in range(cols):
            i = r * cols + c
            for dr, dc in ((1, 0), (-1, 0), (0, 1), (0, -1)):
                j = ((r + dr) % rows) * cols + (c + dc) % cols
                if i != j:
                    adj[i, j] = True
    return adj


def erdos_renyi_graph(n: int, prob: float, seed: int = 0) -> np.ndarray:
    """Undirected ER graph; may be disconnected (lambda_w = 0), which the
    paper explicitly exercises (Fig. 6(b)) and Assumption 1 permits when p>0."""
    rng = np.random.default_rng(seed)
    upper = rng.random((n, n)) < prob
    adj = np.triu(upper, k=1)
    adj = adj | adj.T
    return adj.astype(bool)


def disconnected_graph(n: int, n_components: int = 2) -> np.ndarray:
    """Deterministically disconnected: ``n_components`` disjoint rings."""
    adj = np.zeros((n, n), dtype=bool)
    bounds = np.linspace(0, n, n_components + 1).astype(int)
    for a, b in zip(bounds[:-1], bounds[1:]):
        size = b - a
        if size <= 1:
            continue
        sub = ring_graph(size)
        adj[a:b, a:b] = sub
    return adj


GRAPHS = {
    "ring": ring_graph,
    "path": path_graph,
    "star": star_graph,
    "full": fully_connected_graph,
    "erdos_renyi": erdos_renyi_graph,
    "disconnected": disconnected_graph,
}

# ---------------------------------------------------------------------------
# Mixing-matrix weightings
# ---------------------------------------------------------------------------


def metropolis_weights(adj: np.ndarray) -> np.ndarray:
    """Metropolis–Hastings weights: symmetric, doubly stochastic for any graph."""
    n = adj.shape[0]
    deg = adj.sum(axis=1)
    w = np.zeros((n, n), dtype=np.float64)
    for i in range(n):
        for j in range(n):
            if i != j and adj[i, j]:
                w[i, j] = 1.0 / (1.0 + max(deg[i], deg[j]))
    np.fill_diagonal(w, 1.0 - w.sum(axis=1))
    return w


def best_constant_weights(adj: np.ndarray) -> np.ndarray:
    """Xiao–Boyd best-constant edge weight ``W = I - a L`` with
    ``a = 2 / (lam_1(L) + lam_{n-1}(L))`` — the single-parameter optimum from
    [XB04], a cheap stand-in for the full-SDP symmetric FDLA matrix the paper
    uses; it matches FDLA's asymptotics on the ring/path graphs we reproduce."""
    n = adj.shape[0]
    deg = adj.sum(axis=1)
    lap = np.diag(deg.astype(np.float64)) - adj.astype(np.float64)
    eig = np.linalg.eigvalsh(lap)
    # eig[0] ~ 0; smallest nonzero is eig[1] (may also be 0 when disconnected)
    lam_max = eig[-1]
    lam_2 = eig[1]
    if lam_max + lam_2 <= 1e-12:  # empty graph
        return np.eye(n)
    alpha = 2.0 / (lam_max + lam_2) if lam_2 > 1e-12 else 1.0 / lam_max
    # Definition 1 requires a NONNEGATIVE W; the unconstrained best-constant
    # weight can push hub diagonals negative (e.g. star graphs) — clamp so
    # diag(W) = 1 - alpha*deg >= 0.
    deg_max = float(deg.max()) if n > 1 else 1.0
    if deg_max > 0:
        alpha = min(alpha, 1.0 / deg_max)
    return np.eye(n) - alpha * lap


WEIGHTINGS = {
    "metropolis": metropolis_weights,
    "best_constant": best_constant_weights,
}

# ---------------------------------------------------------------------------
# Spectral quantities (Definition 1)
# ---------------------------------------------------------------------------


def global_matrix(n: int) -> np.ndarray:
    """J = (1/n) 1 1^T — the server / global-averaging mixing matrix."""
    return np.full((n, n), 1.0 / n)


def second_singular_value(w: np.ndarray) -> float:
    n = w.shape[0]
    dev = w - global_matrix(n)
    return float(np.linalg.norm(dev, ord=2))


def mixing_rate(w: np.ndarray) -> float:
    """lambda_w = 1 - ||W - J||_2^2  (0 for disconnected, 1 for J itself)."""
    lam = second_singular_value(w)
    return max(0.0, 1.0 - lam * lam)


def expected_mixing_rate(lambda_w: float, p: float) -> float:
    """Assumption 1: lambda_p = lambda_w + p (1 - lambda_w)."""
    return lambda_w + p * (1.0 - lambda_w)


def is_doubly_stochastic(w: np.ndarray, tol: float = 1e-8) -> bool:
    n = w.shape[0]
    ones = np.ones(n)
    return (
        bool(np.all(w >= -tol))
        and np.allclose(w @ ones, ones, atol=tol)
        and np.allclose(ones @ w, ones, atol=tol)
    )


def is_connected(adj: np.ndarray) -> bool:
    n = adj.shape[0]
    seen = np.zeros(n, dtype=bool)
    stack = [0]
    seen[0] = True
    while stack:
        i = stack.pop()
        for j in np.nonzero(adj[i])[0]:
            if not seen[j]:
                seen[j] = True
                stack.append(int(j))
    return bool(seen.all())


# ---------------------------------------------------------------------------
# Topology: the launcher-facing bundle
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Topology:
    """A gossip graph + weighting, with everything the mixers need."""

    name: str
    n_agents: int
    w: np.ndarray  # (n, n) doubly stochastic
    adj: np.ndarray  # (n, n) bool
    lambda_w: float
    connected: bool
    # For collective (ppermute) mixing: neighbor shifts valid for
    # shift-invariant graphs (ring/torus); None => dense mixing only.
    shifts: Optional[tuple] = None  # tuple of (shift, weight) incl. (0, w_self)

    def expected_rate(self, p: float) -> float:
        return expected_mixing_rate(self.lambda_w, p)


def _ring_shifts(w: np.ndarray) -> Optional[tuple]:
    """Detect a circulant structure and extract (shift, weight) pairs."""
    n = w.shape[0]
    first = w[0]
    for i in range(1, n):
        if not np.allclose(np.roll(first, i), w[i], atol=1e-10):
            return None
    shifts = tuple(
        (int(j), float(first[j])) for j in range(n) if abs(first[j]) > 1e-12
    )
    return shifts


def make_topology(
    name: str,
    n_agents: int,
    weighting: str = "metropolis",
    *,
    prob: float = 0.3,
    seed: int = 0,
    rows: Optional[int] = None,
    n_components: int = 2,
) -> Topology:
    """Build a named topology. ``name`` in GRAPHS or 'torus'."""
    if name == "erdos_renyi":
        adj = erdos_renyi_graph(n_agents, prob, seed)
    elif name == "disconnected":
        adj = disconnected_graph(n_agents, n_components)
    elif name == "torus":
        r = rows or int(np.sqrt(n_agents))
        assert n_agents % r == 0, "torus requires rows | n_agents"
        adj = torus_graph(r, n_agents // r)
    elif name in GRAPHS:
        adj = GRAPHS[name](n_agents)
    else:
        raise ValueError(f"unknown topology {name!r}; options: {sorted(GRAPHS)} + torus")
    w = WEIGHTINGS[weighting](adj)
    return Topology(
        name=name,
        n_agents=n_agents,
        w=w,
        adj=adj,
        lambda_w=mixing_rate(w),
        connected=is_connected(adj) if n_agents > 1 else True,
        shifts=_ring_shifts(w),
    )


# ---------------------------------------------------------------------------
# Dynamic networks: per-round topology processes (time-varying W_k)
# ---------------------------------------------------------------------------

# Domain-separation tags so link draws and participation draws at the same
# (seed, round) never correlate.
_LINK_TAG = 0x11AA
_PART_TAG = 0x77EE


def _round_rng(seed: int, tag: int, k: int) -> np.random.Generator:
    """Per-round RNG that is a *pure function* of ``(seed, tag, k)``: every
    driver (legacy per-round loop, chunked scan, vmapped sweep) sees the
    identical realization for round ``k`` regardless of block boundaries."""
    return np.random.default_rng((int(seed), int(tag), int(k)))


def edge_list(adj: np.ndarray) -> np.ndarray:
    """Undirected edges (i < j) of ``adj`` in deterministic row-major order,
    as an (m, 2) int array.  Public: the sim cost model gates gossip rounds
    by the slowest realized edge and needs edge *identities*, not counts."""
    i, j = np.nonzero(np.triu(adj, k=1))
    return np.stack([i, j], axis=1) if i.size else np.zeros((0, 2), dtype=int)


_edge_list = edge_list  # pre-sim internal name, kept for downstream callers


def _adj_from_edges(n: int, edges: np.ndarray) -> np.ndarray:
    adj = np.zeros((n, n), dtype=bool)
    if len(edges):
        adj[edges[:, 0], edges[:, 1]] = True
        adj[edges[:, 1], edges[:, 0]] = True
    return adj


class TopologyProcess:
    """A sequence of per-round gossip graphs over a fixed base :class:`Topology`.

    Each round ``k`` realizes an edge subset of the base graph and re-weights
    it with Metropolis–Hastings weights (:func:`metropolis_weights`), whose
    diagonal fill is exactly the *self-weight absorption* a dropped link
    requires: the mass a failed edge would have carried moves onto ``w_ii``,
    keeping every realization symmetric and doubly stochastic.

    Realizations are drawn **host-side** and are pure functions of
    ``(seed, k)`` — the same contract as the Bernoulli(p) schedule in
    :mod:`repro.core.driver` — so the scan driver can pre-draw a whole block
    (:meth:`draw_block`) and still agree round-for-round with the legacy loop.
    """

    kind = "abstract"

    def __init__(self, base: Topology, seed: int = 0):
        self.base = base
        self.seed = int(seed)
        self._edges = _edge_list(base.adj)

    # -- interface ----------------------------------------------------------

    @property
    def n_agents(self) -> int:
        return self.base.n_agents

    @property
    def static(self) -> bool:
        return False

    def spec(self) -> str:
        """Round-trippable string form (parsed by :func:`make_topology_process`)."""
        return self.kind

    def edges_at(self, k: int) -> np.ndarray:
        """(m_k, 2) realized undirected edges for round ``k``."""
        raise NotImplementedError

    # -- derived ------------------------------------------------------------

    def realize(self, k: int):
        """``(W_k, directed_messages)`` from one edge realization."""
        edges = self.edges_at(k)
        w = metropolis_weights(_adj_from_edges(self.n_agents, edges))
        return w, 2 * len(edges)

    def adjacency_at(self, k: int) -> np.ndarray:
        return _adj_from_edges(self.n_agents, self.edges_at(k))

    def weights_at(self, k: int) -> np.ndarray:
        """The round-``k`` mixing matrix W_k (symmetric, doubly stochastic)."""
        return self.realize(k)[0]

    def messages_at(self, k: int) -> int:
        """Directed neighbor messages one gossip mix moves in round ``k``."""
        return self.realize(k)[1]

    def draw_block(self, start: int, stop: int):
        """Stacked ``(W, messages)`` for rounds ``[start, stop)``: W is
        (block, n, n) float32 (a ``lax.scan`` operand), messages (block,) int
        (what the byte accountant prices)."""
        realized = [self.realize(k) for k in range(start, stop)]
        ws = np.stack([w for w, _ in realized]).astype(np.float32)
        msgs = np.array([m for _, m in realized])
        return ws, msgs


class StaticProcess(TopologyProcess):
    """The degenerate process: the base topology's W every round (this is the
    frozen-matrix behavior every pre-dynamic experiment had)."""

    kind = "static"

    @property
    def static(self) -> bool:
        return True

    def edges_at(self, k: int) -> np.ndarray:
        return self._edges

    def realize(self, k: int):
        # keep the base weighting (may be best_constant), skip re-realization
        return self.base.w, 2 * len(self._edges)


class LinkFailureProcess(TopologyProcess):
    """I.i.d. Bernoulli link failures: each base edge drops independently with
    probability ``failure_prob`` each round (FedDec / sampled-link regime)."""

    kind = "bernoulli"

    def __init__(self, base: Topology, failure_prob: float = 0.2, seed: int = 0):
        super().__init__(base, seed)
        assert 0.0 <= failure_prob <= 1.0
        self.failure_prob = float(failure_prob)

    def spec(self) -> str:
        return f"bernoulli:{self.failure_prob:g}"

    def edges_at(self, k: int) -> np.ndarray:
        if self.failure_prob <= 0.0:
            return self._edges
        rng = _round_rng(self.seed, _LINK_TAG, k)
        keep = rng.random(len(self._edges)) >= self.failure_prob
        return self._edges[keep]


class RandomMatchingProcess(TopologyProcess):
    """One random maximal matching of the base graph per round: every agent
    talks to at most one neighbor (the classic gossip-pairing model), so each
    realized W_k is a disjoint union of 1/2–1/2 edge blocks."""

    kind = "matching"

    def edges_at(self, k: int) -> np.ndarray:
        rng = _round_rng(self.seed, _LINK_TAG, k)
        order = rng.permutation(len(self._edges))
        matched = np.zeros(self.n_agents, dtype=bool)
        picked = []
        for e in self._edges[order]:
            i, j = int(e[0]), int(e[1])
            if not matched[i] and not matched[j]:
                matched[i] = matched[j] = True
                picked.append((i, j))
        return np.array(picked, dtype=int) if picked else np.zeros((0, 2), int)


class RoundRobinProcess(TopologyProcess):
    """Deterministic cycle over ``n_parts`` edge subsets of the base graph:
    round ``k`` gossips over part ``k % n_parts``.  One full cycle touches
    every base edge exactly once (B-connectivity with period ``n_parts``)."""

    kind = "roundrobin"

    def __init__(self, base: Topology, n_parts: int = 2, seed: int = 0):
        super().__init__(base, seed)
        assert n_parts >= 1
        self.n_parts = int(n_parts)
        self._parts = [self._edges[i :: self.n_parts] for i in range(self.n_parts)]

    def spec(self) -> str:
        return f"roundrobin:{self.n_parts}"

    def edges_at(self, k: int) -> np.ndarray:
        return self._parts[k % self.n_parts]


TOPOLOGY_PROCESSES = ("static", "bernoulli", "matching", "roundrobin")


def parse_process_spec(spec: Optional[str]):
    """Validate a declarative network spec and return ``(kind, arg)``.

    ``spec`` is ``'static'`` | ``'bernoulli[:failure_prob]'`` | ``'matching'``
    | ``'roundrobin[:n_parts]'`` (``None`` means static).  ExperimentSpec
    calls this at construction so a typo fails fast, not mid-run."""
    kind, _, arg = (spec or "static").partition(":")
    if kind not in TOPOLOGY_PROCESSES:
        raise ValueError(
            f"unknown topology process {spec!r}; options: {TOPOLOGY_PROCESSES}"
            f" (e.g. 'bernoulli:0.3', 'roundrobin:2')"
        )
    if arg:
        if kind == "bernoulli":
            q = float(arg)
            if not 0.0 <= q <= 1.0:
                raise ValueError(f"failure prob must be in [0, 1], got {arg}")
            return kind, q
        if kind == "roundrobin":
            n = int(arg)
            if n < 1:
                raise ValueError(f"roundrobin needs n_parts >= 1, got {arg}")
            return kind, n
        raise ValueError(f"topology process {kind!r} takes no argument: {spec!r}")
    return kind, None


def make_topology_process(
    spec: Optional[str], base: Topology, *, seed: int = 0
) -> TopologyProcess:
    """Parse a declarative network spec into a :class:`TopologyProcess`
    (see :func:`parse_process_spec` for the grammar)."""
    kind, arg = parse_process_spec(spec)
    if kind == "static":
        return StaticProcess(base, seed=seed)
    if kind == "bernoulli":
        return LinkFailureProcess(
            base, failure_prob=0.2 if arg is None else arg, seed=seed
        )
    if kind == "matching":
        return RandomMatchingProcess(base, seed=seed)
    return RoundRobinProcess(base, n_parts=2 if arg is None else arg, seed=seed)


class ParticipationProcess:
    """Uniform m-of-n partial participation for server rounds.

    Round ``k`` samples ``m = max(1, round(fraction * n))`` participants
    without replacement; the server exchange is expressed as the doubly
    stochastic *sampled-to-sampled* matrix

        S_k[i, j] = 1/m  if i, j both participate;   S_k[i, i] = 1 otherwise.

    Participants average among themselves, absentees keep their iterate.
    Because S_k is doubly stochastic the network mean is invariant — no
    re-scaling needed for unbiasedness: for a uniform sample,
    ``E[(1/m) sum_{i in S} x_i] = x_bar`` exactly.  Draws are pure functions
    of ``(seed, k)``, like :class:`TopologyProcess` realizations.
    """

    def __init__(self, n_agents: int, fraction: float, seed: int = 0):
        assert 0.0 < fraction <= 1.0
        self.n_agents = int(n_agents)
        self.fraction = float(fraction)
        self.seed = int(seed)
        self.m = max(1, min(self.n_agents, int(round(fraction * n_agents))))

    def participants_at(self, k: int) -> np.ndarray:
        """Sorted participant indices for round ``k``."""
        if self.m >= self.n_agents:
            return np.arange(self.n_agents)
        rng = _round_rng(self.seed, _PART_TAG, k)
        return np.sort(rng.choice(self.n_agents, size=self.m, replace=False))

    def server_matrix_at(self, k: int) -> np.ndarray:
        part = self.participants_at(k)
        s = np.eye(self.n_agents, dtype=np.float64)
        s[np.ix_(part, part)] = 1.0 / len(part)
        return s

    def draw_block(self, start: int, stop: int):
        """Stacked ``(S, participants)`` for rounds ``[start, stop)``."""
        ss = np.stack(
            [self.server_matrix_at(k) for k in range(start, stop)]
        ).astype(np.float32)
        counts = np.full(stop - start, self.m, dtype=int)
        return ss, counts
