"""Communication graphs and mixing matrices (paper §2.1).

Everything here is *host-side* (numpy): topologies are static metadata that the
launcher turns into either a dense mixing matrix (general ``W``) or a neighbor
schedule for ``ppermute``-based collective mixing.

Definition 1 of the paper: ``W`` is nonnegative, doubly stochastic, with
``w_ij = 0`` iff ``{i,j}`` is not an edge (i != j), and the mixing rate is

    lambda_w = 1 - || W - (1/n) 11^T ||_2^2 = 1 - lambda^2,

where ``lambda`` is the second-largest singular value of ``W``.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

# ---------------------------------------------------------------------------
# Graph constructors (adjacency, no self loops)
# ---------------------------------------------------------------------------


def ring_graph(n: int) -> np.ndarray:
    """Ring: agent i connects to (i-1) % n and (i+1) % n."""
    adj = np.zeros((n, n), dtype=bool)
    for i in range(n):
        adj[i, (i + 1) % n] = True
        adj[i, (i - 1) % n] = True
    if n <= 2:  # ring over <=2 nodes degenerates to a single edge / nothing
        adj = adj | adj.T
    np.fill_diagonal(adj, False)
    return adj


def path_graph(n: int) -> np.ndarray:
    adj = np.zeros((n, n), dtype=bool)
    for i in range(n - 1):
        adj[i, i + 1] = adj[i + 1, i] = True
    return adj


def star_graph(n: int) -> np.ndarray:
    """Agent 0 is the hub (useful as an explicit server-like gossip graph)."""
    adj = np.zeros((n, n), dtype=bool)
    adj[0, 1:] = adj[1:, 0] = True
    return adj


def fully_connected_graph(n: int) -> np.ndarray:
    adj = np.ones((n, n), dtype=bool)
    np.fill_diagonal(adj, False)
    return adj


def torus_graph(rows: int, cols: int) -> np.ndarray:
    """2-D torus over ``rows*cols`` agents (the natural ICI topology)."""
    n = rows * cols
    adj = np.zeros((n, n), dtype=bool)
    for r in range(rows):
        for c in range(cols):
            i = r * cols + c
            for dr, dc in ((1, 0), (-1, 0), (0, 1), (0, -1)):
                j = ((r + dr) % rows) * cols + (c + dc) % cols
                if i != j:
                    adj[i, j] = True
    return adj


def erdos_renyi_graph(n: int, prob: float, seed: int = 0) -> np.ndarray:
    """Undirected ER graph; may be disconnected (lambda_w = 0), which the
    paper explicitly exercises (Fig. 6(b)) and Assumption 1 permits when p>0."""
    rng = np.random.default_rng(seed)
    upper = rng.random((n, n)) < prob
    adj = np.triu(upper, k=1)
    adj = adj | adj.T
    return adj.astype(bool)


def disconnected_graph(n: int, n_components: int = 2) -> np.ndarray:
    """Deterministically disconnected: ``n_components`` disjoint rings."""
    adj = np.zeros((n, n), dtype=bool)
    bounds = np.linspace(0, n, n_components + 1).astype(int)
    for a, b in zip(bounds[:-1], bounds[1:]):
        size = b - a
        if size <= 1:
            continue
        sub = ring_graph(size)
        adj[a:b, a:b] = sub
    return adj


GRAPHS = {
    "ring": ring_graph,
    "path": path_graph,
    "star": star_graph,
    "full": fully_connected_graph,
    "erdos_renyi": erdos_renyi_graph,
    "disconnected": disconnected_graph,
}

# ---------------------------------------------------------------------------
# Mixing-matrix weightings
# ---------------------------------------------------------------------------


def metropolis_weights(adj: np.ndarray) -> np.ndarray:
    """Metropolis–Hastings weights: symmetric, doubly stochastic for any graph.

    Vectorized over the adjacency matrix — O(n^2) memory like its input, but
    no Python double loop, so dense realizations stay usable into the
    thousands of agents.  Each off-diagonal entry is the same elementwise
    ``1 / (1 + max(deg_i, deg_j))`` the loop form computed, so the result is
    bit-identical to the historical implementation.
    """
    n = adj.shape[0]
    deg = adj.sum(axis=1).astype(np.float64)
    pair_deg = np.maximum(deg[:, None], deg[None, :])
    w = np.where(adj, 1.0 / (1.0 + pair_deg), 0.0)
    np.fill_diagonal(w, 0.0)
    np.fill_diagonal(w, 1.0 - w.sum(axis=1))
    return w


def metropolis_edge_weights(edges: np.ndarray, n: int):
    """Metropolis–Hastings weights from an edge list, never touching n×n.

    Returns ``(edge_w, self_w)``: one weight per undirected edge
    ``1 / (1 + max(deg_i, deg_j))`` and the per-agent diagonal
    ``1 - sum of incident edge weights``.  Agents with no realized edges get
    ``self_w = 1`` (they hold their iterate) — exactly the self-weight
    absorption :func:`metropolis_weights` performs via its diagonal fill.
    O(n + m) time and memory.
    """
    edges = np.asarray(edges, dtype=np.int64).reshape(-1, 2)
    deg = np.bincount(edges.ravel(), minlength=n).astype(np.float64)
    if len(edges) == 0:
        return np.zeros(0, dtype=np.float64), np.ones(n, dtype=np.float64)
    edge_w = 1.0 / (1.0 + np.maximum(deg[edges[:, 0]], deg[edges[:, 1]]))
    incident = np.bincount(edges[:, 0], weights=edge_w, minlength=n)
    incident += np.bincount(edges[:, 1], weights=edge_w, minlength=n)
    return edge_w, 1.0 - incident


def best_constant_weights(adj: np.ndarray) -> np.ndarray:
    """Xiao–Boyd best-constant edge weight ``W = I - a L`` with
    ``a = 2 / (lam_1(L) + lam_{n-1}(L))`` — the single-parameter optimum from
    [XB04], a cheap stand-in for the full-SDP symmetric FDLA matrix the paper
    uses; it matches FDLA's asymptotics on the ring/path graphs we reproduce."""
    n = adj.shape[0]
    deg = adj.sum(axis=1)
    lap = np.diag(deg.astype(np.float64)) - adj.astype(np.float64)
    eig = np.linalg.eigvalsh(lap)
    # eig[0] ~ 0; smallest nonzero is eig[1] (may also be 0 when disconnected)
    lam_max = eig[-1]
    lam_2 = eig[1]
    if lam_max + lam_2 <= 1e-12:  # empty graph
        return np.eye(n)
    alpha = 2.0 / (lam_max + lam_2) if lam_2 > 1e-12 else 1.0 / lam_max
    # Definition 1 requires a NONNEGATIVE W; the unconstrained best-constant
    # weight can push hub diagonals negative (e.g. star graphs) — clamp so
    # diag(W) = 1 - alpha*deg >= 0.
    deg_max = float(deg.max()) if n > 1 else 1.0
    if deg_max > 0:
        alpha = min(alpha, 1.0 / deg_max)
    return np.eye(n) - alpha * lap


WEIGHTINGS = {
    "metropolis": metropolis_weights,
    "best_constant": best_constant_weights,
}

# ---------------------------------------------------------------------------
# Spectral quantities (Definition 1)
# ---------------------------------------------------------------------------


def global_matrix(n: int) -> np.ndarray:
    """J = (1/n) 1 1^T — the server / global-averaging mixing matrix."""
    return np.full((n, n), 1.0 / n)


def second_singular_value(w: np.ndarray) -> float:
    n = w.shape[0]
    dev = w - global_matrix(n)
    return float(np.linalg.norm(dev, ord=2))


def mixing_rate(w: np.ndarray) -> float:
    """lambda_w = 1 - ||W - J||_2^2  (0 for disconnected, 1 for J itself)."""
    lam = second_singular_value(w)
    return max(0.0, 1.0 - lam * lam)


def expected_mixing_rate(lambda_w: float, p: float) -> float:
    """Assumption 1: lambda_p = lambda_w + p (1 - lambda_w)."""
    return lambda_w + p * (1.0 - lambda_w)


def is_doubly_stochastic(w: np.ndarray, tol: Optional[float] = None) -> bool:
    """Row/column-sum check with an n- and dtype-aware tolerance.

    The comparison is an *absolute* one (``rtol=0`` — the historical
    ``np.allclose`` call silently added a relative 1e-5 slack that made the
    advertised ``tol=1e-8`` meaningless for the sum checks).  A row sum
    accumulates O(sqrt(n)) rounding errors of size ``eps``, so a fixed
    absolute tolerance falsely rejects perfectly valid float32 Metropolis
    weights once ``n`` reaches the thousands.  The default scales as
    ``max(1e-8, 16 * sqrt(n) * eps(dtype))``; pass ``tol`` to override.
    """
    n = w.shape[0]
    if tol is None:
        eps = (
            float(np.finfo(w.dtype).eps)
            if np.issubdtype(w.dtype, np.floating)
            else float(np.finfo(np.float64).eps)
        )
        tol = max(1e-8, 16.0 * np.sqrt(n) * eps)
    ones = np.ones(n)
    return (
        bool(np.all(w >= -tol))
        and np.allclose(w @ ones, ones, rtol=0.0, atol=tol)
        and np.allclose(ones @ w, ones, rtol=0.0, atol=tol)
    )


def is_connected(adj: np.ndarray) -> bool:
    n = adj.shape[0]
    seen = np.zeros(n, dtype=bool)
    stack = [0]
    seen[0] = True
    while stack:
        i = stack.pop()
        for j in np.nonzero(adj[i])[0]:
            if not seen[j]:
                seen[j] = True
                stack.append(int(j))
    return bool(seen.all())


# ---------------------------------------------------------------------------
# Topology: the launcher-facing bundle
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Topology:
    """A gossip graph + weighting, with everything the mixers need."""

    name: str
    n_agents: int
    w: np.ndarray  # (n, n) doubly stochastic
    adj: np.ndarray  # (n, n) bool
    lambda_w: float
    connected: bool
    # For collective (ppermute) mixing: neighbor shifts valid for
    # shift-invariant graphs (ring/torus); None => dense mixing only.
    shifts: Optional[tuple] = None  # tuple of (shift, weight) incl. (0, w_self)

    def expected_rate(self, p: float) -> float:
        return expected_mixing_rate(self.lambda_w, p)


def _ring_shifts(w: np.ndarray) -> Optional[tuple]:
    """Detect a circulant structure and extract (shift, weight) pairs."""
    n = w.shape[0]
    first = w[0]
    for i in range(1, n):
        if not np.allclose(np.roll(first, i), w[i], atol=1e-10):
            return None
    shifts = tuple(
        (int(j), float(first[j])) for j in range(n) if abs(first[j]) > 1e-12
    )
    return shifts


def make_topology(
    name: str,
    n_agents: int,
    weighting: str = "metropolis",
    *,
    prob: float = 0.3,
    seed: int = 0,
    rows: Optional[int] = None,
    n_components: int = 2,
    degree: int = 4,
) -> Topology:
    """Build a named topology. ``name`` in GRAPHS, 'torus', or
    'random_regular' (the expander family shared with the sparse path)."""
    if name == "erdos_renyi":
        adj = erdos_renyi_graph(n_agents, prob, seed)
    elif name == "disconnected":
        adj = disconnected_graph(n_agents, n_components)
    elif name == "torus":
        r = rows or int(np.sqrt(n_agents))
        assert n_agents % r == 0, "torus requires rows | n_agents"
        adj = torus_graph(r, n_agents // r)
    elif name == "random_regular":
        adj = _adj_from_edges(
            n_agents, random_regular_edges(n_agents, degree=degree, seed=seed)
        )
    elif name in GRAPHS:
        adj = GRAPHS[name](n_agents)
    else:
        raise ValueError(
            f"unknown topology {name!r}; options: {sorted(GRAPHS)} + torus"
            f" + random_regular"
        )
    w = WEIGHTINGS[weighting](adj)
    return Topology(
        name=name,
        n_agents=n_agents,
        w=w,
        adj=adj,
        lambda_w=mixing_rate(w),
        connected=is_connected(adj) if n_agents > 1 else True,
        shifts=_ring_shifts(w),
    )


# ---------------------------------------------------------------------------
# Sparse topologies: edge-list / CSR representation, never materializing n×n
# ---------------------------------------------------------------------------

# Below this many agents the dense path is auto-selected (ExperimentSpec
# ``sparse=None``): dense einsum gossip is faster for small fleets and stays
# the bit-exact reference the parity tests pin against.
SPARSE_AUTO_MIN_AGENTS = 512


def use_sparse_topology(flag: Optional[bool], n_agents: int) -> bool:
    """Resolve the three-state ``sparse`` spec field: explicit True/False
    wins; ``None`` auto-selects sparse only for large fleets."""
    if flag is not None:
        return bool(flag)
    return n_agents > SPARSE_AUTO_MIN_AGENTS


def _canonical_edges(edges) -> np.ndarray:
    """(m, 2) int array, each row (i, j) with i < j, sorted lexicographically
    and deduplicated — the same order :func:`edge_list` produces from a dense
    adjacency, so sparse and dense constructions agree edge-for-edge."""
    e = np.asarray(edges, dtype=np.int64).reshape(-1, 2)
    if len(e) == 0:
        return np.zeros((0, 2), dtype=int)
    e = np.stack([e.min(axis=1), e.max(axis=1)], axis=1)
    e = e[e[:, 0] != e[:, 1]]  # drop self loops
    return np.unique(e, axis=0).astype(int)


def ring_edges(n: int) -> np.ndarray:
    if n <= 1:
        return np.zeros((0, 2), dtype=int)
    i = np.arange(n)
    return _canonical_edges(np.stack([i, (i + 1) % n], axis=1))


def path_edges(n: int) -> np.ndarray:
    i = np.arange(max(0, n - 1))
    return _canonical_edges(np.stack([i, i + 1], axis=1))


def star_edges(n: int) -> np.ndarray:
    j = np.arange(1, n)
    return _canonical_edges(np.stack([np.zeros_like(j), j], axis=1))


def torus_edges(rows: int, cols: int) -> np.ndarray:
    """Edges of the 2-D torus over ``rows*cols`` agents, O(n) construction."""
    r, c = np.meshgrid(np.arange(rows), np.arange(cols), indexing="ij")
    i = (r * cols + c).ravel()
    right = (r * cols + (c + 1) % cols).ravel()
    down = (((r + 1) % rows) * cols + c).ravel()
    return _canonical_edges(
        np.concatenate(
            [np.stack([i, right], axis=1), np.stack([i, down], axis=1)]
        )
    )


def random_regular_edges(n: int, degree: int = 4, seed: int = 0) -> np.ndarray:
    """Approximately ``degree``-regular connected graph as a union of
    ``ceil(degree / 2)`` random Hamiltonian cycles (deduplicated), O(n)
    memory.  Each cycle alone is connected, so the union always is — the
    standard cheap expander construction for large-fleet experiments."""
    if n <= 1:
        return np.zeros((0, 2), dtype=int)
    rng = np.random.default_rng(seed)
    parts = []
    for _ in range(max(1, -(-degree // 2))):
        perm = rng.permutation(n)
        parts.append(np.stack([perm, np.roll(perm, -1)], axis=1))
    return _canonical_edges(np.concatenate(parts))


SPARSE_GRAPHS = {
    "ring": ring_edges,
    "path": path_edges,
    "star": star_edges,
}

# Above this size, topologies with no O(n)-edge constructor (erdos_renyi,
# full, disconnected) refuse to fall back to dense adjacency extraction.
_SPARSE_DENSE_FALLBACK_MAX = 4096


def _connected_from_edges(n: int, edges: np.ndarray) -> bool:
    """BFS connectivity over adjacency lists — O(n + m)."""
    if n <= 1:
        return True
    if len(edges) == 0:
        return False
    nbr_idx, indptr = _csr_neighbors(n, edges)
    seen = np.zeros(n, dtype=bool)
    seen[0] = True
    frontier = [0]
    while frontier:
        i = frontier.pop()
        for j in nbr_idx[indptr[i] : indptr[i + 1]]:
            if not seen[j]:
                seen[j] = True
                frontier.append(int(j))
    return bool(seen.all())


def _csr_neighbors(n: int, edges: np.ndarray):
    """Neighbor indices + indptr over the directed expansion of ``edges``."""
    senders = np.concatenate([edges[:, 0], edges[:, 1]])
    receivers = np.concatenate([edges[:, 1], edges[:, 0]])
    order = np.argsort(receivers, kind="stable")
    nbr = senders[order]
    indptr = np.searchsorted(receivers[order], np.arange(n + 1))
    return nbr, indptr


@dataclasses.dataclass(frozen=True)
class SparseTopology:
    """A gossip graph in edge-list / CSR form — the large-fleet counterpart
    of :class:`Topology`, built without ever materializing an n×n array.

    ``edges`` is the canonical (i < j, lexicographic) undirected edge list;
    ``edge_weight``/``self_weight`` are its Metropolis–Hastings weights
    (:func:`metropolis_edge_weights`).  The CSR triple (``indptr``,
    ``indices``, ``data``) covers the *directed* expansion sorted by
    receiver: row ``i`` of the implicit W is ``data[indptr[i]:indptr[i+1]]``
    over senders ``indices[indptr[i]:indptr[i+1]]`` plus ``self_weight[i]``
    on the diagonal.  ``lambda_w`` is only computed for small n (dense
    spectral norm) and is ``None`` otherwise.
    """

    name: str
    n_agents: int
    edges: np.ndarray  # (m, 2) int, i < j, canonical order
    edge_weight: np.ndarray  # (m,) float64 Metropolis weights
    self_weight: np.ndarray  # (n,) float64 diagonal
    indptr: np.ndarray  # (n + 1,) CSR row pointers (directed, by receiver)
    indices: np.ndarray  # (2m,) sender index per directed edge
    data: np.ndarray  # (2m,) weight per directed edge
    connected: bool
    lambda_w: Optional[float] = None

    @property
    def n_edges(self) -> int:
        return int(len(self.edges))

    def dense_w(self) -> np.ndarray:
        """Materialize the implicit W (small-n reference / tests only)."""
        w = np.zeros((self.n_agents, self.n_agents), dtype=np.float64)
        if self.n_edges:
            i, j = self.edges[:, 0], self.edges[:, 1]
            w[i, j] = self.edge_weight
            w[j, i] = self.edge_weight
        np.fill_diagonal(w, self.self_weight)
        return w

    def expected_rate(self, p: float) -> float:
        if self.lambda_w is None:
            raise ValueError("lambda_w not computed for this fleet size")
        return expected_mixing_rate(self.lambda_w, p)


def sparse_topology_from_edges(
    name: str, n_agents: int, edges: np.ndarray
) -> SparseTopology:
    edges = _canonical_edges(edges)
    edge_w, self_w = metropolis_edge_weights(edges, n_agents)
    senders = np.concatenate([edges[:, 0], edges[:, 1]])
    receivers = np.concatenate([edges[:, 1], edges[:, 0]])
    order = np.argsort(receivers, kind="stable")
    indices = senders[order].astype(int)
    data = np.concatenate([edge_w, edge_w])[order]
    indptr = np.searchsorted(receivers[order], np.arange(n_agents + 1)).astype(int)
    lam = None
    if n_agents <= SPARSE_AUTO_MIN_AGENTS:
        w = np.zeros((n_agents, n_agents), dtype=np.float64)
        if len(edges):
            w[edges[:, 0], edges[:, 1]] = edge_w
            w[edges[:, 1], edges[:, 0]] = edge_w
        np.fill_diagonal(w, self_w)
        lam = mixing_rate(w)
    return SparseTopology(
        name=name,
        n_agents=n_agents,
        edges=edges,
        edge_weight=edge_w,
        self_weight=self_w,
        indptr=indptr,
        indices=indices,
        data=data,
        connected=_connected_from_edges(n_agents, edges),
        lambda_w=lam,
    )


def make_sparse_topology(
    name: str,
    n_agents: int,
    weighting: str = "metropolis",
    *,
    prob: float = 0.3,
    seed: int = 0,
    rows: Optional[int] = None,
    n_components: int = 2,
    degree: int = 4,
) -> SparseTopology:
    """Sparse counterpart of :func:`make_topology`.

    Topologies with an O(n)-edge constructor (ring/path/star/torus/
    random_regular) scale to millions of agents; the remaining named graphs
    fall back to dense adjacency extraction up to n = 4096 and raise beyond.
    Only Metropolis weighting has a sparse form.
    """
    if weighting != "metropolis":
        raise ValueError(
            f"sparse topologies support only metropolis weighting, got {weighting!r}"
        )
    if name == "torus":
        r = rows or int(np.sqrt(n_agents))
        assert n_agents % r == 0, "torus requires rows | n_agents"
        edges = torus_edges(r, n_agents // r)
    elif name == "random_regular":
        edges = random_regular_edges(n_agents, degree=degree, seed=seed)
    elif name in SPARSE_GRAPHS:
        edges = SPARSE_GRAPHS[name](n_agents)
    elif name in GRAPHS:
        if n_agents > _SPARSE_DENSE_FALLBACK_MAX:
            raise ValueError(
                f"topology {name!r} has no sparse constructor and "
                f"n={n_agents} exceeds the dense-fallback cap "
                f"({_SPARSE_DENSE_FALLBACK_MAX})"
            )
        kw = {}
        if name == "erdos_renyi":
            kw = {"prob": prob, "seed": seed}
        elif name == "disconnected":
            kw = {"n_components": n_components}
        edges = edge_list(GRAPHS[name](n_agents, **kw) if kw else GRAPHS[name](n_agents))
    else:
        raise ValueError(
            f"unknown topology {name!r}; options: {sorted(GRAPHS)} + torus"
            f" + random_regular"
        )
    return sparse_topology_from_edges(name, n_agents, edges)


def topology_edges(topo) -> np.ndarray:
    """Canonical undirected edge list of a :class:`Topology` or
    :class:`SparseTopology` — O(m) for sparse, O(n^2) extraction for dense."""
    edges = getattr(topo, "edges", None)
    if edges is not None:
        return edges
    return edge_list(topo.adj)


# ---------------------------------------------------------------------------
# Dynamic networks: per-round topology processes (time-varying W_k)
# ---------------------------------------------------------------------------

# Domain-separation tags so link draws and participation draws at the same
# (seed, round) never correlate.
_LINK_TAG = 0x11AA
_PART_TAG = 0x77EE


def _round_rng(seed: int, tag: int, k: int) -> np.random.Generator:
    """Per-round RNG that is a *pure function* of ``(seed, tag, k)``: every
    driver (legacy per-round loop, chunked scan, vmapped sweep) sees the
    identical realization for round ``k`` regardless of block boundaries."""
    return np.random.default_rng((int(seed), int(tag), int(k)))


def edge_list(adj: np.ndarray) -> np.ndarray:
    """Undirected edges (i < j) of ``adj`` in deterministic row-major order,
    as an (m, 2) int array.  Public: the sim cost model gates gossip rounds
    by the slowest realized edge and needs edge *identities*, not counts."""
    i, j = np.nonzero(np.triu(adj, k=1))
    return np.stack([i, j], axis=1) if i.size else np.zeros((0, 2), dtype=int)


_edge_list = edge_list  # pre-sim internal name, kept for downstream callers


def _adj_from_edges(n: int, edges: np.ndarray) -> np.ndarray:
    adj = np.zeros((n, n), dtype=bool)
    if len(edges):
        adj[edges[:, 0], edges[:, 1]] = True
        adj[edges[:, 1], edges[:, 0]] = True
    return adj


class TopologyProcess:
    """A sequence of per-round gossip graphs over a fixed base :class:`Topology`.

    Each round ``k`` realizes an edge subset of the base graph and re-weights
    it with Metropolis–Hastings weights (:func:`metropolis_weights`), whose
    diagonal fill is exactly the *self-weight absorption* a dropped link
    requires: the mass a failed edge would have carried moves onto ``w_ii``,
    keeping every realization symmetric and doubly stochastic.

    Realizations are drawn **host-side** and are pure functions of
    ``(seed, k)`` — the same contract as the Bernoulli(p) schedule in
    :mod:`repro.core.driver` — so the scan driver can pre-draw a whole block
    (:meth:`draw_block`) and still agree round-for-round with the legacy loop.
    """

    kind = "abstract"

    def __init__(self, base, seed: int = 0):
        self.base = base  # Topology or SparseTopology
        self.seed = int(seed)
        self._edges = topology_edges(base)
        self._edge_index = None  # lazy (i, j) -> base row map (mask fallback)

    # -- interface ----------------------------------------------------------

    @property
    def n_agents(self) -> int:
        return self.base.n_agents

    @property
    def static(self) -> bool:
        return False

    def spec(self) -> str:
        """Round-trippable string form (parsed by :func:`make_topology_process`)."""
        return self.kind

    def edges_at(self, k: int) -> np.ndarray:
        """(m_k, 2) realized undirected edges for round ``k``."""
        raise NotImplementedError

    def edge_mask_at(self, k: int) -> np.ndarray:
        """Round-``k`` realization as a bool mask over the *base* edge list.

        The sparse drivers thread fixed-shape per-edge operands through
        ``lax.scan``, so realizations must be expressed in base-edge order
        with dropped edges zeroed, not as variable-length subsets.  Subclasses
        override with an O(m) draw; this generic fallback matches
        :meth:`edges_at` rows back to base indices.
        """
        if self._edge_index is None:
            self._edge_index = {
                (int(i), int(j)): t for t, (i, j) in enumerate(self._edges)
            }
        mask = np.zeros(len(self._edges), dtype=bool)
        for i, j in self.edges_at(k):
            mask[self._edge_index[(min(int(i), int(j)), max(int(i), int(j)))]] = True
        return mask

    # -- derived ------------------------------------------------------------

    def realize(self, k: int):
        """``(W_k, directed_messages)`` from one edge realization."""
        edges = self.edges_at(k)
        w = metropolis_weights(_adj_from_edges(self.n_agents, edges))
        return w, 2 * len(edges)

    def adjacency_at(self, k: int) -> np.ndarray:
        return _adj_from_edges(self.n_agents, self.edges_at(k))

    def weights_at(self, k: int) -> np.ndarray:
        """The round-``k`` mixing matrix W_k (symmetric, doubly stochastic)."""
        return self.realize(k)[0]

    def messages_at(self, k: int) -> int:
        """Directed neighbor messages one gossip mix moves in round ``k``."""
        return self.realize(k)[1]

    def draw_block(self, start: int, stop: int):
        """Stacked ``(W, messages)`` for rounds ``[start, stop)``: W is
        (block, n, n) float32 (a ``lax.scan`` operand), messages (block,) int
        (what the byte accountant prices)."""
        realized = [self.realize(k) for k in range(start, stop)]
        ws = np.stack([w for w, _ in realized]).astype(np.float32)
        msgs = np.array([m for _, m in realized])
        return ws, msgs

    # -- sparse realizations (edge sets instead of matrices) ----------------

    def realize_sparse(self, k: int):
        """``(edge_w, self_w, directed_messages)`` for round ``k`` in *base*
        edge order: ``edge_w`` is (m,) with zeros on dropped edges, ``self_w``
        is the (n,) Metropolis diagonal of the realized subgraph.  Same
        re-weighting as :meth:`realize` — :func:`metropolis_edge_weights` over
        the kept edges — without touching n×n."""
        mask = self.edge_mask_at(k)
        m = len(self._edges)
        edge_w = np.zeros(m, dtype=np.float64)
        kept = int(mask.sum())
        if kept:
            sub_w, self_w = metropolis_edge_weights(
                self._edges[mask], self.n_agents
            )
            edge_w[mask] = sub_w
        else:
            self_w = np.ones(self.n_agents, dtype=np.float64)
        return edge_w, self_w, 2 * kept

    def draw_sparse_block(self, start: int, stop: int):
        """Stacked ``(edge_w, self_w, messages)`` for rounds ``[start, stop)``:
        edge_w (block, m) and self_w (block, n) float32 scan operands over the
        base edge order, messages (block,) host ints for the byte accountant
        — the sparse analogue of :meth:`draw_block`."""
        realized = [self.realize_sparse(k) for k in range(start, stop)]
        edge_w = np.stack([r[0] for r in realized]).astype(np.float32)
        self_w = np.stack([r[1] for r in realized]).astype(np.float32)
        msgs = np.array([r[2] for r in realized])
        return edge_w, self_w, msgs


class StaticProcess(TopologyProcess):
    """The degenerate process: the base topology's W every round (this is the
    frozen-matrix behavior every pre-dynamic experiment had)."""

    kind = "static"

    @property
    def static(self) -> bool:
        return True

    def edges_at(self, k: int) -> np.ndarray:
        return self._edges

    def edge_mask_at(self, k: int) -> np.ndarray:
        return np.ones(len(self._edges), dtype=bool)

    def realize(self, k: int):
        # keep the base weighting (may be best_constant), skip re-realization
        w = getattr(self.base, "w", None)
        if w is None:  # SparseTopology base: materialize the implicit W
            return self.base.dense_w(), 2 * len(self._edges)
        return w, 2 * len(self._edges)

    def realize_sparse(self, k: int):
        ew = getattr(self.base, "edge_weight", None)
        if ew is not None:  # SparseTopology base: weights are precomputed
            return (
                np.asarray(ew, dtype=np.float64),
                np.asarray(self.base.self_weight, dtype=np.float64),
                2 * len(self._edges),
            )
        return super().realize_sparse(k)


class LinkFailureProcess(TopologyProcess):
    """I.i.d. Bernoulli link failures: each base edge drops independently with
    probability ``failure_prob`` each round (FedDec / sampled-link regime)."""

    kind = "bernoulli"

    def __init__(self, base: Topology, failure_prob: float = 0.2, seed: int = 0):
        super().__init__(base, seed)
        assert 0.0 <= failure_prob <= 1.0
        self.failure_prob = float(failure_prob)

    def spec(self) -> str:
        return f"bernoulli:{self.failure_prob:g}"

    def edge_mask_at(self, k: int) -> np.ndarray:
        if self.failure_prob <= 0.0:
            return np.ones(len(self._edges), dtype=bool)
        rng = _round_rng(self.seed, _LINK_TAG, k)
        return rng.random(len(self._edges)) >= self.failure_prob

    def edges_at(self, k: int) -> np.ndarray:
        return self._edges[self.edge_mask_at(k)]


class RandomMatchingProcess(TopologyProcess):
    """One random maximal matching of the base graph per round: every agent
    talks to at most one neighbor (the classic gossip-pairing model), so each
    realized W_k is a disjoint union of 1/2–1/2 edge blocks."""

    kind = "matching"

    def _picked_at(self, k: int) -> np.ndarray:
        """Base-edge indices of the round-``k`` matching, in greedy pick
        order (the order :meth:`edges_at` has always returned)."""
        rng = _round_rng(self.seed, _LINK_TAG, k)
        order = rng.permutation(len(self._edges))
        matched = np.zeros(self.n_agents, dtype=bool)
        picked = []
        for t in order:
            i, j = int(self._edges[t, 0]), int(self._edges[t, 1])
            if not matched[i] and not matched[j]:
                matched[i] = matched[j] = True
                picked.append(int(t))
        return np.array(picked, dtype=int)

    def edges_at(self, k: int) -> np.ndarray:
        picked = self._picked_at(k)
        return self._edges[picked] if len(picked) else np.zeros((0, 2), int)

    def edge_mask_at(self, k: int) -> np.ndarray:
        mask = np.zeros(len(self._edges), dtype=bool)
        mask[self._picked_at(k)] = True
        return mask


class RoundRobinProcess(TopologyProcess):
    """Deterministic cycle over ``n_parts`` edge subsets of the base graph:
    round ``k`` gossips over part ``k % n_parts``.  One full cycle touches
    every base edge exactly once (B-connectivity with period ``n_parts``)."""

    kind = "roundrobin"

    def __init__(self, base: Topology, n_parts: int = 2, seed: int = 0):
        super().__init__(base, seed)
        assert n_parts >= 1
        self.n_parts = int(n_parts)
        self._parts = [self._edges[i :: self.n_parts] for i in range(self.n_parts)]

    def spec(self) -> str:
        return f"roundrobin:{self.n_parts}"

    def edges_at(self, k: int) -> np.ndarray:
        return self._parts[k % self.n_parts]

    def edge_mask_at(self, k: int) -> np.ndarray:
        mask = np.zeros(len(self._edges), dtype=bool)
        mask[k % self.n_parts :: self.n_parts] = True
        return mask


class NeighborSampleProcess(TopologyProcess):
    """Neighbor-sampled cohorts: round ``k`` activates only the subgraph
    incident to a uniform sample of ``ceil(fraction * n)`` seed agents.

    Sampled agents gossip with *all* their base-graph neighbors (so the seed
    set's whole one-hop neighborhood participates); everyone else holds.
    This is the client-sampling analogue for decentralized rounds — the
    sampled-to-sampled analysis (PAPERS.md) shows doubly stochastic
    re-weighting over the active subgraph preserves the network mean, which
    the Metropolis re-realization here provides.  Only the active subgraph's
    edges carry nonzero weight per round, so with the sparse mixers the
    materialized per-round state is O(edges incident to the cohort).
    """

    kind = "cohort"

    def __init__(self, base, fraction: float = 0.25, seed: int = 0):
        super().__init__(base, seed)
        assert 0.0 < fraction <= 1.0
        self.fraction = float(fraction)
        self.m_seeds = max(1, min(self.n_agents, int(round(fraction * self.n_agents))))

    def spec(self) -> str:
        return f"cohort:{self.fraction:g}"

    def seeds_at(self, k: int) -> np.ndarray:
        """Sorted seed-agent indices for round ``k``."""
        if self.m_seeds >= self.n_agents:
            return np.arange(self.n_agents)
        rng = _round_rng(self.seed, _LINK_TAG, k)
        return np.sort(rng.choice(self.n_agents, size=self.m_seeds, replace=False))

    def edge_mask_at(self, k: int) -> np.ndarray:
        active = np.zeros(self.n_agents, dtype=bool)
        active[self.seeds_at(k)] = True
        e = self._edges
        if len(e) == 0:
            return np.zeros(0, dtype=bool)
        return active[e[:, 0]] | active[e[:, 1]]

    def edges_at(self, k: int) -> np.ndarray:
        return self._edges[self.edge_mask_at(k)]


TOPOLOGY_PROCESSES = ("static", "bernoulli", "matching", "roundrobin", "cohort")


def parse_process_spec(spec: Optional[str]):
    """Validate a declarative network spec and return ``(kind, arg)``.

    ``spec`` is ``'static'`` | ``'bernoulli[:failure_prob]'`` | ``'matching'``
    | ``'roundrobin[:n_parts]'`` | ``'cohort[:fraction]'`` (``None`` means
    static).  ExperimentSpec calls this at construction so a typo fails
    fast, not mid-run."""
    kind, _, arg = (spec or "static").partition(":")
    if kind not in TOPOLOGY_PROCESSES:
        raise ValueError(
            f"unknown topology process {spec!r}; options: {TOPOLOGY_PROCESSES}"
            f" (e.g. 'bernoulli:0.3', 'roundrobin:2', 'cohort:0.25')"
        )
    if arg:
        if kind == "bernoulli":
            q = float(arg)
            if not 0.0 <= q <= 1.0:
                raise ValueError(f"failure prob must be in [0, 1], got {arg}")
            return kind, q
        if kind == "roundrobin":
            n = int(arg)
            if n < 1:
                raise ValueError(f"roundrobin needs n_parts >= 1, got {arg}")
            return kind, n
        if kind == "cohort":
            f = float(arg)
            if not 0.0 < f <= 1.0:
                raise ValueError(f"cohort fraction must be in (0, 1], got {arg}")
            return kind, f
        raise ValueError(f"topology process {kind!r} takes no argument: {spec!r}")
    return kind, None


def make_topology_process(
    spec: Optional[str], base, *, seed: int = 0
) -> TopologyProcess:
    """Parse a declarative network spec into a :class:`TopologyProcess`
    (see :func:`parse_process_spec` for the grammar).  ``base`` may be a
    :class:`Topology` or a :class:`SparseTopology`."""
    kind, arg = parse_process_spec(spec)
    if kind == "static":
        return StaticProcess(base, seed=seed)
    if kind == "bernoulli":
        return LinkFailureProcess(
            base, failure_prob=0.2 if arg is None else arg, seed=seed
        )
    if kind == "matching":
        return RandomMatchingProcess(base, seed=seed)
    if kind == "cohort":
        return NeighborSampleProcess(
            base, fraction=0.25 if arg is None else arg, seed=seed
        )
    return RoundRobinProcess(base, n_parts=2 if arg is None else arg, seed=seed)


class ParticipationProcess:
    """Uniform m-of-n partial participation for server rounds.

    Round ``k`` samples ``m = max(1, round(fraction * n))`` participants
    without replacement; the server exchange is expressed as the doubly
    stochastic *sampled-to-sampled* matrix

        S_k[i, j] = 1/m  if i, j both participate;   S_k[i, i] = 1 otherwise.

    Participants average among themselves, absentees keep their iterate.
    Because S_k is doubly stochastic the network mean is invariant — no
    re-scaling needed for unbiasedness: for a uniform sample,
    ``E[(1/m) sum_{i in S} x_i] = x_bar`` exactly.  Draws are pure functions
    of ``(seed, k)``, like :class:`TopologyProcess` realizations.
    """

    def __init__(self, n_agents: int, fraction: float, seed: int = 0):
        assert 0.0 < fraction <= 1.0
        self.n_agents = int(n_agents)
        self.fraction = float(fraction)
        self.seed = int(seed)
        self.m = max(1, min(self.n_agents, int(round(fraction * n_agents))))

    def participants_at(self, k: int) -> np.ndarray:
        """Sorted participant indices for round ``k``."""
        if self.m >= self.n_agents:
            return np.arange(self.n_agents)
        rng = _round_rng(self.seed, _PART_TAG, k)
        return np.sort(rng.choice(self.n_agents, size=self.m, replace=False))

    def server_matrix_at(self, k: int) -> np.ndarray:
        part = self.participants_at(k)
        s = np.eye(self.n_agents, dtype=np.float64)
        s[np.ix_(part, part)] = 1.0 / len(part)
        return s

    def draw_block(self, start: int, stop: int):
        """Stacked ``(S, participants)`` for rounds ``[start, stop)``."""
        ss = np.stack(
            [self.server_matrix_at(k) for k in range(start, stop)]
        ).astype(np.float32)
        counts = np.full(stop - start, self.m, dtype=int)
        return ss, counts

    def participant_mask_at(self, k: int) -> np.ndarray:
        """Round-``k`` participation as a (n,) float32 0/1 mask — the O(n)
        operand form the sparse mixers consume instead of the n×n S_k."""
        mask = np.zeros(self.n_agents, dtype=np.float32)
        mask[self.participants_at(k)] = 1.0
        return mask

    def draw_mask_block(self, start: int, stop: int):
        """Stacked ``(mask, participants)`` for rounds ``[start, stop)`` —
        the sparse analogue of :meth:`draw_block`."""
        masks = np.stack(
            [self.participant_mask_at(k) for k in range(start, stop)]
        )
        counts = np.full(stop - start, self.m, dtype=int)
        return masks, counts
