"""Communication graphs and mixing matrices (paper §2.1).

Everything here is *host-side* (numpy): topologies are static metadata that the
launcher turns into either a dense mixing matrix (general ``W``) or a neighbor
schedule for ``ppermute``-based collective mixing.

Definition 1 of the paper: ``W`` is nonnegative, doubly stochastic, with
``w_ij = 0`` iff ``{i,j}`` is not an edge (i != j), and the mixing rate is

    lambda_w = 1 - || W - (1/n) 11^T ||_2^2 = 1 - lambda^2,

where ``lambda`` is the second-largest singular value of ``W``.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

# ---------------------------------------------------------------------------
# Graph constructors (adjacency, no self loops)
# ---------------------------------------------------------------------------


def ring_graph(n: int) -> np.ndarray:
    """Ring: agent i connects to (i-1) % n and (i+1) % n."""
    adj = np.zeros((n, n), dtype=bool)
    for i in range(n):
        adj[i, (i + 1) % n] = True
        adj[i, (i - 1) % n] = True
    if n <= 2:  # ring over <=2 nodes degenerates to a single edge / nothing
        adj = adj | adj.T
    np.fill_diagonal(adj, False)
    return adj


def path_graph(n: int) -> np.ndarray:
    adj = np.zeros((n, n), dtype=bool)
    for i in range(n - 1):
        adj[i, i + 1] = adj[i + 1, i] = True
    return adj


def star_graph(n: int) -> np.ndarray:
    """Agent 0 is the hub (useful as an explicit server-like gossip graph)."""
    adj = np.zeros((n, n), dtype=bool)
    adj[0, 1:] = adj[1:, 0] = True
    return adj


def fully_connected_graph(n: int) -> np.ndarray:
    adj = np.ones((n, n), dtype=bool)
    np.fill_diagonal(adj, False)
    return adj


def torus_graph(rows: int, cols: int) -> np.ndarray:
    """2-D torus over ``rows*cols`` agents (the natural ICI topology)."""
    n = rows * cols
    adj = np.zeros((n, n), dtype=bool)
    for r in range(rows):
        for c in range(cols):
            i = r * cols + c
            for dr, dc in ((1, 0), (-1, 0), (0, 1), (0, -1)):
                j = ((r + dr) % rows) * cols + (c + dc) % cols
                if i != j:
                    adj[i, j] = True
    return adj


def erdos_renyi_graph(n: int, prob: float, seed: int = 0) -> np.ndarray:
    """Undirected ER graph; may be disconnected (lambda_w = 0), which the
    paper explicitly exercises (Fig. 6(b)) and Assumption 1 permits when p>0."""
    rng = np.random.default_rng(seed)
    upper = rng.random((n, n)) < prob
    adj = np.triu(upper, k=1)
    adj = adj | adj.T
    return adj.astype(bool)


def disconnected_graph(n: int, n_components: int = 2) -> np.ndarray:
    """Deterministically disconnected: ``n_components`` disjoint rings."""
    adj = np.zeros((n, n), dtype=bool)
    bounds = np.linspace(0, n, n_components + 1).astype(int)
    for a, b in zip(bounds[:-1], bounds[1:]):
        size = b - a
        if size <= 1:
            continue
        sub = ring_graph(size)
        adj[a:b, a:b] = sub
    return adj


GRAPHS = {
    "ring": ring_graph,
    "path": path_graph,
    "star": star_graph,
    "full": fully_connected_graph,
    "erdos_renyi": erdos_renyi_graph,
    "disconnected": disconnected_graph,
}

# ---------------------------------------------------------------------------
# Mixing-matrix weightings
# ---------------------------------------------------------------------------


def metropolis_weights(adj: np.ndarray) -> np.ndarray:
    """Metropolis–Hastings weights: symmetric, doubly stochastic for any graph."""
    n = adj.shape[0]
    deg = adj.sum(axis=1)
    w = np.zeros((n, n), dtype=np.float64)
    for i in range(n):
        for j in range(n):
            if i != j and adj[i, j]:
                w[i, j] = 1.0 / (1.0 + max(deg[i], deg[j]))
    np.fill_diagonal(w, 1.0 - w.sum(axis=1))
    return w


def best_constant_weights(adj: np.ndarray) -> np.ndarray:
    """Xiao–Boyd best-constant edge weight ``W = I - a L`` with
    ``a = 2 / (lam_1(L) + lam_{n-1}(L))`` — the single-parameter optimum from
    [XB04], a cheap stand-in for the full-SDP symmetric FDLA matrix the paper
    uses; it matches FDLA's asymptotics on the ring/path graphs we reproduce."""
    n = adj.shape[0]
    deg = adj.sum(axis=1)
    lap = np.diag(deg.astype(np.float64)) - adj.astype(np.float64)
    eig = np.linalg.eigvalsh(lap)
    # eig[0] ~ 0; smallest nonzero is eig[1] (may also be 0 when disconnected)
    lam_max = eig[-1]
    lam_2 = eig[1]
    if lam_max + lam_2 <= 1e-12:  # empty graph
        return np.eye(n)
    alpha = 2.0 / (lam_max + lam_2) if lam_2 > 1e-12 else 1.0 / lam_max
    # Definition 1 requires a NONNEGATIVE W; the unconstrained best-constant
    # weight can push hub diagonals negative (e.g. star graphs) — clamp so
    # diag(W) = 1 - alpha*deg >= 0.
    deg_max = float(deg.max()) if n > 1 else 1.0
    if deg_max > 0:
        alpha = min(alpha, 1.0 / deg_max)
    return np.eye(n) - alpha * lap


WEIGHTINGS = {
    "metropolis": metropolis_weights,
    "best_constant": best_constant_weights,
}

# ---------------------------------------------------------------------------
# Spectral quantities (Definition 1)
# ---------------------------------------------------------------------------


def global_matrix(n: int) -> np.ndarray:
    """J = (1/n) 1 1^T — the server / global-averaging mixing matrix."""
    return np.full((n, n), 1.0 / n)


def second_singular_value(w: np.ndarray) -> float:
    n = w.shape[0]
    dev = w - global_matrix(n)
    return float(np.linalg.norm(dev, ord=2))


def mixing_rate(w: np.ndarray) -> float:
    """lambda_w = 1 - ||W - J||_2^2  (0 for disconnected, 1 for J itself)."""
    lam = second_singular_value(w)
    return max(0.0, 1.0 - lam * lam)


def expected_mixing_rate(lambda_w: float, p: float) -> float:
    """Assumption 1: lambda_p = lambda_w + p (1 - lambda_w)."""
    return lambda_w + p * (1.0 - lambda_w)


def is_doubly_stochastic(w: np.ndarray, tol: float = 1e-8) -> bool:
    n = w.shape[0]
    ones = np.ones(n)
    return (
        bool(np.all(w >= -tol))
        and np.allclose(w @ ones, ones, atol=tol)
        and np.allclose(ones @ w, ones, atol=tol)
    )


def is_connected(adj: np.ndarray) -> bool:
    n = adj.shape[0]
    seen = np.zeros(n, dtype=bool)
    stack = [0]
    seen[0] = True
    while stack:
        i = stack.pop()
        for j in np.nonzero(adj[i])[0]:
            if not seen[j]:
                seen[j] = True
                stack.append(int(j))
    return bool(seen.all())


# ---------------------------------------------------------------------------
# Topology: the launcher-facing bundle
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Topology:
    """A gossip graph + weighting, with everything the mixers need."""

    name: str
    n_agents: int
    w: np.ndarray  # (n, n) doubly stochastic
    adj: np.ndarray  # (n, n) bool
    lambda_w: float
    connected: bool
    # For collective (ppermute) mixing: neighbor shifts valid for
    # shift-invariant graphs (ring/torus); None => dense mixing only.
    shifts: Optional[tuple] = None  # tuple of (shift, weight) incl. (0, w_self)

    def expected_rate(self, p: float) -> float:
        return expected_mixing_rate(self.lambda_w, p)


def _ring_shifts(w: np.ndarray) -> Optional[tuple]:
    """Detect a circulant structure and extract (shift, weight) pairs."""
    n = w.shape[0]
    first = w[0]
    for i in range(1, n):
        if not np.allclose(np.roll(first, i), w[i], atol=1e-10):
            return None
    shifts = tuple(
        (int(j), float(first[j])) for j in range(n) if abs(first[j]) > 1e-12
    )
    return shifts


def make_topology(
    name: str,
    n_agents: int,
    weighting: str = "metropolis",
    *,
    prob: float = 0.3,
    seed: int = 0,
    rows: Optional[int] = None,
    n_components: int = 2,
) -> Topology:
    """Build a named topology. ``name`` in GRAPHS or 'torus'."""
    if name == "erdos_renyi":
        adj = erdos_renyi_graph(n_agents, prob, seed)
    elif name == "disconnected":
        adj = disconnected_graph(n_agents, n_components)
    elif name == "torus":
        r = rows or int(np.sqrt(n_agents))
        assert n_agents % r == 0, "torus requires rows | n_agents"
        adj = torus_graph(r, n_agents // r)
    elif name in GRAPHS:
        adj = GRAPHS[name](n_agents)
    else:
        raise ValueError(f"unknown topology {name!r}; options: {sorted(GRAPHS)} + torus")
    w = WEIGHTINGS[weighting](adj)
    return Topology(
        name=name,
        n_agents=n_agents,
        w=w,
        adj=adj,
        lambda_w=mixing_rate(w),
        connected=is_connected(adj) if n_agents > 1 else True,
        shifts=_ring_shifts(w),
    )
