"""Mixing operators: the communication layer of PISCO (paper eq. 4a/4c).

Two families, one interface (:class:`MixingOps`):

* **Dense / simulation mixers** — agent-stacked pytrees live on one device (or
  are auto-sharded by pjit); gossip is an einsum with the dense mixing matrix
  ``W`` and global averaging is a mean over the agent axis.  Under ``jit`` with
  the agent axis sharded, XLA lowers these to ``all-gather`` + local matmul and
  ``all-reduce`` respectively — correct for *any* topology (ER, path,
  disconnected), at the cost of an all-gather.

* **Collective mixers** — TPU-native path used by the launcher: gossip over a
  circulant topology (ring on the agent axis, torus over (pod, data)) becomes a
  weighted sum of ``lax.ppermute`` block rotations — pure neighbor ICI traffic,
  the whole point of the paper's agent-to-agent rounds.  Global averaging is a
  ``psum`` over the agent mesh axes — the "server" round.  Both are expressed
  with ``shard_map`` so the collectives appear explicitly in the lowered HLO
  (which the roofline analysis parses).

The probabilistic `W^k = J w.p. p else W` draw is hoisted to the host launcher
(see DESIGN.md §2): the trainer compiles one step function per mixing kind and
dispatches per round.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core.topology import (
    ParticipationProcess,
    SparseTopology,
    Topology,
    TopologyProcess,
    make_topology_process,
)
from repro.utils.compat import shard_map
from repro.utils.pytree import (
    tree_agent_krum,
    tree_agent_masked_mean,
    tree_agent_mean,
    tree_agent_median,
    tree_agent_mix,
    tree_agent_mix_sparse,
    tree_agent_trimmed_mean,
)

PyTree = Any

# ---------------------------------------------------------------------------
# Robust server-averaging rules (Byzantine-tolerant global_avg variants)
# ---------------------------------------------------------------------------

ROBUST_RULES = ("mean", "trimmed", "median", "krum")


def parse_robust_spec(spec: str):
    """``(rule, f)`` from a robust-aggregation spec string.

    Grammar mirrors the adversary/process specs: ``"mean"`` | ``"median"`` |
    ``"trimmed[:f=0.2]"`` | ``"krum[:f=0.2]"`` — ``f`` is the assumed
    Byzantine *fraction*, turned into an agent count via ``ceil(f * n)`` when
    the rule is instantiated.  Fails fast on unknown rules/keys.
    """
    head, _, tail = str(spec).partition(":")
    rule = head.strip()
    if rule not in ROBUST_RULES:
        raise ValueError(
            f"unknown robust_agg rule {rule!r}; options: {ROBUST_RULES}"
        )
    f = 0.2
    if tail:
        for item in tail.split(","):
            k, _, v = item.partition("=")
            if k.strip() != "f":
                raise ValueError(
                    f"robust_agg {rule!r} takes only 'f=<fraction>' "
                    f"(got {item!r})"
                )
            f = float(v)
    if rule in ("mean", "median") and tail:
        raise ValueError(f"robust_agg {rule!r} takes no arguments")
    if not 0.0 <= f < 0.5:
        raise ValueError(f"robust_agg fraction must be in [0, 0.5), got {f}")
    return rule, f


def make_robust_agg(spec: str, n_agents: int) -> Optional[Callable]:
    """A pluggable server-averaging rule (tree -> tree, agent-broadcast), or
    ``None`` for ``"mean"`` — the caller keeps its exact base ``global_avg``
    so the clean path stays bit-identical.  Validates that the fleet is big
    enough for the requested trim/selection margin."""
    rule, f = parse_robust_spec(spec)
    if rule == "mean":
        return None
    n_byz = int(np.ceil(f * n_agents))
    if rule == "median":
        return tree_agent_median
    if rule == "trimmed":
        if n_agents - 2 * n_byz < 1:
            raise ValueError(
                f"trimmed mean needs n - 2*ceil(f*n) >= 1 agents "
                f"(n={n_agents}, f={f} trims {n_byz} per side)"
            )
        return partial(tree_agent_trimmed_mean, trim=n_byz)
    # krum: neighbor count n - n_byz - 2 is floored at 1 inside the primitive
    return partial(tree_agent_krum, n_byz=n_byz)


@dataclasses.dataclass(frozen=True)
class MixingOps:
    """The two communication primitives Algorithm 1 needs."""

    gossip: Callable[[PyTree], PyTree]  # X -> X W
    global_avg: Callable[[PyTree], PyTree]  # X -> X J
    name: str = "dense"
    # Bytes moved per invocation per agent, filled in by the launcher for
    # communication-cost accounting (benchmarks fig4).
    gossip_edges: int = 0  # number of neighbor messages per gossip round
    # Directed neighbor messages per gossip invocation, network-wide — the
    # quantity the byte model prices.  None => derive as 2 * gossip_edges
    # (one message per direction over each undirected edge); collective
    # mixers, whose gossip_edges counts per-agent shifts, set it explicitly.
    gossip_messages: Optional[int] = None
    # Optional CompressedGossip spec (repro.core.compression).  When set,
    # ``gossip`` is already the stateless compressed form and PISCO's round
    # function threads the stateful error-feedback variant through its state;
    # the byte model prices gossip at the compressor's wire format.
    compression: Optional[Any] = None
    # Optional NetworkContext for time-varying topologies / partial
    # participation: the drivers pre-draw per-round matrices host-side and
    # thread them through the round functions (see dynamic_dense_mixing).
    network: Optional["NetworkContext"] = None


# ---------------------------------------------------------------------------
# Dense / simulation mixers
# ---------------------------------------------------------------------------


def dense_mixing(topology: Topology) -> MixingOps:
    """Reference mixers over agent-stacked pytrees (leading axis = agents)."""
    w = jnp.asarray(topology.w, dtype=jnp.float32)

    def gossip(tree: PyTree) -> PyTree:
        return tree_agent_mix(tree, w)

    return MixingOps(
        gossip=gossip,
        global_avg=tree_agent_mean,
        name=f"dense/{topology.name}",
        gossip_edges=int(topology.adj.sum()) // 2,
    )


def identity_mixing(n_agents: int) -> MixingOps:
    """No communication at all (an isolated baseline / ablation)."""
    return MixingOps(
        gossip=lambda t: t, global_avg=tree_agent_mean, name="identity", gossip_edges=0
    )


# ---------------------------------------------------------------------------
# Dynamic mixers: the mixing matrix is a per-round operand
# ---------------------------------------------------------------------------


class DynamicWSlot:
    """Trace-time injection point for the per-round mixing matrices.

    The algorithm builders close their round functions over
    ``MixingOps.gossip`` / ``global_avg``; for a dynamic network those
    closures read the *current* W_k from this slot.  The driver stores the
    round's matrix operand here immediately before invoking the round
    function **inside the same trace** (the scan body, or a wrapped loop
    round function taking W as an explicit argument), so the read picks up
    the live tracer and the compiled program threads the matrix as a real
    input — nothing is baked in as a constant, and no algorithm needs a
    signature change.
    """

    __slots__ = ("gossip_w", "server_w")

    def __init__(self):
        self.gossip_w = None
        self.server_w = None

    def set(self, gossip_w, server_w) -> None:
        self.gossip_w = gossip_w
        self.server_w = server_w


@dataclasses.dataclass(frozen=True, eq=False)
class NetworkContext:
    """Host-side bundle the drivers use to realize a dynamic network.

    Pairs the gossip-graph process with optional partial participation and
    the :class:`DynamicWSlot` the round functions read from.  ``draw_block``
    pre-draws everything a scan block needs, exactly like the Bernoulli(p)
    schedule pre-draw in :mod:`repro.core.driver`.
    """

    process: TopologyProcess
    slot: DynamicWSlot
    participation: Optional[ParticipationProcess] = None
    # Sparse operand mode: draw per-round *edge weights* (pytree operands)
    # instead of dense matrices — the drivers thread either shape untouched.
    sparse: bool = False

    @property
    def n_agents(self) -> int:
        return self.process.n_agents

    def draw_block(self, start: int, stop: int):
        """``(w_gossip, w_server, messages, participants)`` for rounds
        ``[start, stop)``; operands carry a leading round axis (scan
        operands), counts are host ints for the byte accountant.

        Dense mode: ``w_gossip`` is (block, n, n); without participation the
        server matrix is a (block, 1, 1) placeholder — ``global_avg`` is the
        exact mean and never reads it.  Sparse mode: ``w_gossip`` is the
        pytree ``{'edge_w': (block, 2m), 'self_w': (block, n)}`` over the
        directed base-edge order and ``w_server`` a (block, n) participant
        mask (or a (block, 1) placeholder).  Message/participant counts are
        identical in both modes — byte pricing can't tell them apart."""
        block = stop - start
        if self.sparse:
            edge_w, self_w, messages = self.process.draw_sparse_block(start, stop)
            # duplicate per-undirected-edge weights across both orientations
            w_gossip = {
                "edge_w": np.concatenate([edge_w, edge_w], axis=1),
                "self_w": self_w,
            }
            if self.participation is None:
                w_server = np.zeros((block, 1), dtype=np.float32)
                participants = np.full(block, self.n_agents, dtype=int)
            else:
                w_server, participants = self.participation.draw_mask_block(
                    start, stop
                )
            return w_gossip, w_server, messages, participants
        w_gossip, messages = self.process.draw_block(start, stop)
        if self.participation is None:
            w_server = np.zeros((block, 1, 1), dtype=np.float32)
            participants = np.full(block, self.n_agents, dtype=int)
        else:
            w_server, participants = self.participation.draw_block(start, stop)
        return w_gossip, w_server, messages, participants

    def draw_round(self, k: int):
        """Single-round form for the legacy loop driver."""
        wg, ws, msgs, parts = self.draw_block(k, k + 1)
        first = lambda tree: jax.tree.map(lambda a: a[0], tree)
        return first(wg), first(ws), int(msgs[0]), int(parts[0])


def dynamic_dense_mixing(
    process: TopologyProcess,
    *,
    participation: float = 1.0,
    participation_seed: Optional[int] = None,
) -> MixingOps:
    """Dense mixers over a time-varying network.

    ``gossip`` applies whatever W_k the driver staged in the slot for the
    current round; ``global_avg`` is the exact mean when every agent
    participates, else the doubly stochastic sampled-to-sampled matrix S_k
    (participants average among themselves, absentees hold — the network
    mean is preserved, so gradient tracking's Lemma-1 invariant survives).
    """
    slot = DynamicWSlot()
    part = None
    if participation < 1.0:
        part = ParticipationProcess(
            process.n_agents,
            participation,
            seed=process.seed if participation_seed is None else participation_seed,
        )

    def gossip(tree: PyTree) -> PyTree:
        return tree_agent_mix(tree, slot.gossip_w)

    if part is None:
        global_avg = tree_agent_mean
    else:
        def global_avg(tree: PyTree) -> PyTree:
            return tree_agent_mix(tree, slot.server_w)

    base = process.base
    name = f"dynamic/{process.spec()}/{base.name}"
    if part is not None:
        name += f"/m{part.m}of{part.n_agents}"
    return MixingOps(
        gossip=gossip,
        global_avg=global_avg,
        name=name,
        gossip_edges=int(base.adj.sum()) // 2,
        network=NetworkContext(process=process, slot=slot, participation=part),
    )


def make_network_mixing(
    topology: Topology,
    network: Optional[str] = None,
    participation: float = 1.0,
    *,
    seed: int = 0,
) -> MixingOps:
    """Dense mixers for an optionally dynamic network — the one selection
    point shared by ``ExperimentSpec.make_mixing`` and the launch CLI.

    ``network=None`` with full participation is the legacy frozen-matrix
    path (bit-identical to pre-dynamic runs); anything else routes through
    :func:`dynamic_dense_mixing` over the parsed :class:`TopologyProcess`.
    """
    if network is None and participation >= 1.0:
        return dense_mixing(topology)
    process = make_topology_process(network, topology, seed=seed)
    return dynamic_dense_mixing(process, participation=participation)


# ---------------------------------------------------------------------------
# Sparse mixers: gossip as a segment_sum over edges, never materializing n×n
# ---------------------------------------------------------------------------


def _directed_arrays(topo: SparseTopology):
    """Device arrays for the directed expansion of the base edge list: both
    orientations of each undirected edge, weights duplicated."""
    e = topo.edges
    senders = jnp.asarray(
        np.concatenate([e[:, 0], e[:, 1]]) if len(e) else np.zeros(0, int),
        dtype=jnp.int32,
    )
    receivers = jnp.asarray(
        np.concatenate([e[:, 1], e[:, 0]]) if len(e) else np.zeros(0, int),
        dtype=jnp.int32,
    )
    return senders, receivers


def sparse_mixing(topology: SparseTopology) -> MixingOps:
    """Static sparse mixers: gossip is ``segment_sum`` over the fixed edge
    list with precomputed Metropolis weights — O(n + m) state instead of
    O(n^2), numerically equal to ``dense_mixing`` over the materialized W
    up to float reassociation."""
    senders, receivers = _directed_arrays(topology)
    edge_w = jnp.asarray(
        np.concatenate([topology.edge_weight, topology.edge_weight]),
        dtype=jnp.float32,
    )
    self_w = jnp.asarray(topology.self_weight, dtype=jnp.float32)
    n = topology.n_agents

    def gossip(tree: PyTree) -> PyTree:
        return tree_agent_mix_sparse(tree, senders, receivers, edge_w, self_w, n)

    return MixingOps(
        gossip=gossip,
        global_avg=tree_agent_mean,
        name=f"sparse/{topology.name}",
        gossip_edges=topology.n_edges,
    )


def dynamic_sparse_mixing(
    process: TopologyProcess,
    *,
    participation: float = 1.0,
    participation_seed: Optional[int] = None,
) -> MixingOps:
    """Sparse mixers over a time-varying network.

    The per-round operand is the edge-weight pytree the driver stages in the
    slot (``{'edge_w': (2m,), 'self_w': (n,)}`` in base directed-edge order,
    dropped edges zeroed) — fixed shapes, so ``lax.scan`` threads it like
    the dense W_k, at O(n + m) instead of O(n^2) per round.  Partial
    participation uses the O(n) masked-mean form of the sampled-to-sampled
    matrix (mean-preserving, so gradient tracking's Lemma-1 invariant
    survives, same as the dense path).
    """
    slot = DynamicWSlot()
    part = None
    if participation < 1.0:
        part = ParticipationProcess(
            process.n_agents,
            participation,
            seed=process.seed if participation_seed is None else participation_seed,
        )
    base = process.base
    senders, receivers = _directed_arrays(base)
    n = process.n_agents

    def gossip(tree: PyTree) -> PyTree:
        ops = slot.gossip_w
        return tree_agent_mix_sparse(
            tree, senders, receivers, ops["edge_w"], ops["self_w"], n
        )

    if part is None:
        global_avg = tree_agent_mean
    else:
        def global_avg(tree: PyTree) -> PyTree:
            return tree_agent_masked_mean(tree, slot.server_w)

    name = f"sparse-dynamic/{process.spec()}/{base.name}"
    if part is not None:
        name += f"/m{part.m}of{part.n_agents}"
    return MixingOps(
        gossip=gossip,
        global_avg=global_avg,
        name=name,
        gossip_edges=base.n_edges,
        network=NetworkContext(
            process=process, slot=slot, participation=part, sparse=True
        ),
    )


def make_sparse_network_mixing(
    topology: SparseTopology,
    network: Optional[str] = None,
    participation: float = 1.0,
    *,
    seed: int = 0,
) -> MixingOps:
    """Sparse counterpart of :func:`make_network_mixing` — same selection
    logic, edge-list operands throughout."""
    if network is None and participation >= 1.0:
        return sparse_mixing(topology)
    process = make_topology_process(network, topology, seed=seed)
    return dynamic_sparse_mixing(process, participation=participation)


# ---------------------------------------------------------------------------
# Collective mixers (shard_map + lax collectives)
# ---------------------------------------------------------------------------


def _as_tuple(x) -> tuple:
    return tuple(x) if isinstance(x, (tuple, list)) else (x,)


def _leaf_local_spec(spec: P) -> P:
    """Inside shard_map every mentioned axis is already local; mixing acts on
    axis 0 (the agent axis), other axes stay sharded => specs pass through."""
    return spec


def collective_global_mixing(
    mesh: jax.sharding.Mesh,
    agent_axes: Sequence[str],
    spec_tree: PyTree,
) -> MixingOps:
    """Global averaging (J) as an explicit psum over the agent mesh axes.

    ``spec_tree`` is the PartitionSpec tree of the agent-stacked state: each
    leaf spec must shard axis 0 over ``agent_axes``.
    """
    agent_axes = _as_tuple(agent_axes)
    n_agents = int(np.prod([mesh.shape[a] for a in agent_axes]))

    def avg(tree: PyTree) -> PyTree:
        def per_shard(local_tree):
            def leaf(x):
                acc = jax.lax.psum(x.astype(jnp.float32), agent_axes)
                return (acc / n_agents).astype(x.dtype)

            return jax.tree.map(leaf, local_tree)

        return shard_map(
            per_shard,
            mesh=mesh,
            in_specs=(spec_tree,),
            out_specs=spec_tree,
        )(tree)

    return MixingOps(
        gossip=avg,  # placeholder; callers pair this with a gossip mixer
        global_avg=avg,
        name="collective/global",
    )


def collective_shift_mixing(
    mesh: jax.sharding.Mesh,
    agent_axes: Sequence[str],
    spec_tree: PyTree,
    shifts_per_axis: dict,
    *,
    wire_dtype: Optional[str] = None,
) -> MixingOps:
    """Circulant gossip as weighted ppermute block rotations.

    ``shifts_per_axis`` maps mesh axis name -> sequence of (shift, weight)
    pairs (shift 0 = self weight; recorded on any one axis).  A ring over the
    agent axis is ``{axis: [(0, w0), (1, w1), (-1, w1)]}``; the multi-pod
    torus uses entries for both "pod" and "data".

    ``wire_dtype`` controls what goes over the wire (§Perf iteration):
    * None (default)    — permute in the state's native dtype (bf16 states
                          move bf16 bytes), accumulate the weighted combine
                          in fp32.
    * "float32"         — upcast before the permute (2x traffic for bf16
                          states; the numerically-conservative baseline).
    """
    agent_axes = _as_tuple(agent_axes)
    wire = jnp.dtype(wire_dtype) if wire_dtype is not None else None

    def gossip(tree: PyTree) -> PyTree:
        def per_shard(local_tree):
            def leaf(x):
                xw = x if wire is None else x.astype(wire)
                acc = jnp.zeros_like(x, dtype=jnp.float32)
                for axis_name, pairs in shifts_per_axis.items():
                    size = mesh.shape[axis_name]
                    for shift, weight in pairs:
                        if shift == 0:
                            continue
                        perm = [(s, (s + shift) % size) for s in range(size)]
                        moved = jax.lax.ppermute(xw, axis_name, perm)
                        if wire is None and moved.dtype != jnp.float32:
                            # keep the wire payload in the narrow dtype: the
                            # barrier stops XLA's simplifier from hoisting the
                            # f32 convert above the collective-permute
                            moved = jax.lax.optimization_barrier(moved)
                        acc = acc + weight * moved.astype(jnp.float32)
                self_w = 0.0
                for pairs in shifts_per_axis.values():
                    for shift, weight in pairs:
                        if shift == 0:
                            self_w += weight
                acc = acc + self_w * x.astype(jnp.float32)
                return acc.astype(x.dtype)

            return jax.tree.map(leaf, local_tree)

        return shard_map(
            per_shard,
            mesh=mesh,
            in_specs=(spec_tree,),
            out_specs=spec_tree,
        )(tree)

    g = collective_global_mixing(mesh, agent_axes, spec_tree)
    n_edges = sum(
        len([s for s, _ in pairs if s != 0]) for pairs in shifts_per_axis.values()
    )
    n_agents = int(np.prod([mesh.shape[a] for a in shifts_per_axis]))
    return MixingOps(
        gossip=gossip,
        global_avg=g.global_avg,
        name="collective/shift",
        gossip_edges=n_edges,
        # every agent ships one message per nonzero shift
        gossip_messages=n_agents * n_edges,
    )


def collective_dense_mixing(
    mesh: jax.sharding.Mesh,
    agent_axes: Sequence[str],
    spec_tree: PyTree,
    topology: Topology,
) -> MixingOps:
    """Arbitrary-W gossip on a mesh: all_gather over the agent axes + local
    weighted reduction.  Used for the paper-faithful non-circulant topologies
    (ER / path / disconnected) when running distributed."""
    agent_axes = _as_tuple(agent_axes)
    w = topology.w.astype(np.float32)
    n = topology.n_agents

    def gossip(tree: PyTree) -> PyTree:
        def per_shard(local_tree):
            # Linear agent index of this shard.
            idx = jax.lax.axis_index(agent_axes)

            def leaf(x):
                # x: (1, ...) local block.  Gather all agents' blocks, combine.
                full = jax.lax.all_gather(
                    x.astype(jnp.float32), agent_axes, axis=0, tiled=True
                )  # (n, ...)
                row = jnp.asarray(w)[idx]  # (n,)
                mixed = jnp.tensordot(row, full, axes=((0,), (0,)))
                return mixed[None].astype(x.dtype)

            return jax.tree.map(leaf, local_tree)

        return shard_map(
            per_shard,
            mesh=mesh,
            in_specs=(spec_tree,),
            out_specs=spec_tree,
        )(tree)

    g = collective_global_mixing(mesh, agent_axes, spec_tree)
    return MixingOps(
        gossip=gossip,
        global_avg=g.global_avg,
        name=f"collective/dense/{topology.name}",
        gossip_edges=int(topology.adj.sum()) // 2,
    )


def compressed_mixing(
    base: MixingOps,
    bits: int = 8,
) -> MixingOps:
    """Backward-compatible int-quantized gossip (the original beyond-paper
    extension).  Now a thin front for :mod:`repro.core.compression`:
    deterministic-rounding quantizer, error feedback on, mean-preserving
    difference form, byte-priced wire format.  The server round (J) stays
    exact — the expensive link gets the exact average, matching the paper's
    emphasis that server rounds drive the consensus floor.
    """
    from repro.core.compression import StochasticQuantizer, compress_mixing

    return compress_mixing(
        base, StochasticQuantizer(bits=bits, stochastic=False), error_feedback=True
    )


def hierarchical_mixing(
    mesh: jax.sharding.Mesh,
    spec_tree: PyTree,
    intra_axis: str = "data",
    inter_axes: Sequence[str] = ("pod", "data"),
    ring_weights: Sequence[float] = (0.5, 0.25, 0.25),
) -> MixingOps:
    """Beyond-paper hierarchical mode (DESIGN.md §6): gossip = ring over the
    *intra-pod* data axis only (pure ICI), server round = psum over all agent
    axes (crosses DCI).  This is HL-SGD-shaped communication with PISCO's
    gradient tracking on top."""
    w0, w1, w2 = ring_weights
    shift = {intra_axis: [(0, w0), (1, w1), (-1, w2)]}
    ops = collective_shift_mixing(mesh, inter_axes, spec_tree, shift)
    return dataclasses.replace(ops, name="collective/hierarchical")
