"""First-class algorithm registry — the experiment-facing protocol layer.

Every semi-decentralized protocol the repo can train (PISCO and the Table-1/2
baselines, plus any third-party addition) is one :class:`Algorithm` entry:

* a **builder** closing the round functions over ``(loss_fn, cfg, mixing)``,
* a declarative **default schedule** (``"bernoulli"`` / ``"never"`` /
  ``"always"`` / ``"periodic"`` — line 8 of Algorithm 1 and its degenerate
  cases), and
* a :class:`CommProfile` pricing the protocol's traffic *as data*: how many
  mixing invocations a gossip round performs (gradient tracking mixes both the
  X and Y streams; plain-SGD families mix X only) and how many payloads one
  server exchange moves per direction (SCAFFOLD ships the model *and* the
  control variate).

Registering a new protocol is one file anywhere downstream::

    from repro.core.algorithms import BoundAlgorithm, register_algorithm

    @register_algorithm("my_algo", mixes_per_round=1)
    def _build(spec, loss_fn, cfg, mixing, **_):
        return my_init, my_gossip_round, my_global_round

— no trainer edits, no byte-model edits, no benchmark edits.  The trainer,
the :class:`~repro.core.experiment.Experiment` API, and the benchmark harness
all resolve algorithms exclusively through :func:`get_algorithm`.

Round-function contract (shared with PISCO, see :mod:`repro.core.pisco`)::

    init(loss_fn, x0_stacked, comm_batch0) -> state
    round_fn(state, local_batches, comm_batch) -> (state, RoundMetrics)

``gossip_round`` and ``global_round`` must return identical pytree
structures/dtypes — the scan driver dispatches between them with ``lax.cond``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

from repro.core import baselines as B
from repro.core.mixing import MixingOps
from repro.core.pisco import (
    LossFn,
    PiscoConfig,
    init_compression_state,
    init_state,
    make_round_fn,
)
from repro.core.schedule import PeriodicSchedule, make_schedule
from repro.optim.update_rules import OPT_POLICIES, UpdateRule, parse_update_rule

PyTree = Any
# builder(spec, loss_fn, cfg, mixing, *, eta=None, eta_g=1.0
#         [, local_opt=None, server_opt=None, opt_policy="..."])
#   -> (init, gossip_round, global_round)
# The optimizer kwargs are only passed when update rules are actually bound,
# so legacy builders (and third-party registrations) keep working unchanged.
Builder = Callable[..., Tuple[Callable, Callable, Callable]]

SCHEDULE_KINDS = ("bernoulli", "never", "always", "periodic")


@dataclasses.dataclass(frozen=True)
class CommProfile:
    """Per-protocol communication cost, priced as data (no byte-model edits).

    ``mixes_per_round``   — mixing invocations per communication round; each
                            gossip mix moves one message per directed edge.
    ``server_payloads``   — payloads one agent moves per direction of a server
                            exchange (model only = 1; model + control variate
                            or tracking stream = 2).
    ``server_based``      — every communication round is agent-to-server.
    ``uses_local_updates``— the protocol consumes the T_o local batches.
    """

    mixes_per_round: int = 1
    server_payloads: int = 1
    server_based: bool = False
    uses_local_updates: bool = True


@dataclasses.dataclass(frozen=True)
class BoundAlgorithm:
    """An :class:`Algorithm` closed over ``(loss_fn, cfg, mixing)`` — what the
    round drivers actually run."""

    name: str
    init: Callable[[LossFn, PyTree, Any], Any]
    gossip_round: Callable
    global_round: Callable
    schedule: Callable[[int], bool]
    comm: CommProfile
    # NetworkContext when the mixing is dynamic (time-varying topology and/or
    # partial participation): the drivers pre-draw per-round matrices through
    # it and thread them into the round functions.  None => static network,
    # the exact pre-dynamic code path.
    network: Optional[Any] = None
    # The resolved update rules this binding runs (None/None => the legacy
    # hardcoded-SGD arithmetic) and the opt-state communication policy.
    local_opt: Optional[UpdateRule] = None
    server_opt: Optional[UpdateRule] = None
    opt_policy: str = "mix"


@dataclasses.dataclass(frozen=True)
class Algorithm:
    """One registry entry: builder + declarative schedule + comm profile.

    ``avg_period`` (periodic schedules only) is the explicit server-averaging
    period H used when ``cfg.p == 0`` gives no implied period; Gossip-PGA's
    documented default is H = 10 [CYZ+21].  When ``cfg.p > 0`` the period is
    derived as ``round(1/p)`` so a Bernoulli(p) PISCO run and a periodic
    baseline spend the same expected server budget.
    """

    name: str
    build: Builder
    comm: CommProfile = CommProfile()
    schedule: str = "bernoulli"
    avg_period: int = 10
    description: str = ""
    # Default update rules, as declarative strings parsed at bind time
    # (None => the legacy hardcoded-SGD path); ``opt_policy`` is what happens
    # to agent-stacked optimizer buffers at communication rounds (DESIGN.md
    # §10): "mix" with the round's W/J, "keep" local, or "reset" at server
    # synchronizations.
    local_opt: Optional[str] = None
    server_opt: Optional[str] = None
    opt_policy: str = "mix"

    def __post_init__(self):
        if self.schedule not in SCHEDULE_KINDS:
            raise ValueError(
                f"schedule {self.schedule!r} not in {SCHEDULE_KINDS}"
            )
        if self.opt_policy not in OPT_POLICIES:
            raise ValueError(
                f"opt_policy {self.opt_policy!r} not in {OPT_POLICIES}"
            )

    def make_default_schedule(self, cfg: PiscoConfig):
        if self.schedule == "never":
            return make_schedule(0.0)
        if self.schedule == "always":
            return make_schedule(1.0)
        if self.schedule == "periodic":
            period = (
                max(1, int(round(1.0 / cfg.p))) if cfg.p > 0 else self.avg_period
            )
            return PeriodicSchedule(period)
        return make_schedule(cfg.p, cfg.seed)

    def bind(
        self,
        loss_fn: LossFn,
        cfg: PiscoConfig,
        mixing: MixingOps,
        *,
        eta: Optional[float] = None,
        eta_g: float = 1.0,
        schedule: Optional[Callable[[int], bool]] = None,
        local_opt: Optional[Any] = None,
        server_opt: Optional[Any] = None,
        opt_policy: Optional[str] = None,
    ) -> BoundAlgorithm:
        """Close the algorithm over a concrete problem; ``schedule`` overrides
        the declarative default (e.g. a replayed flag sequence).

        ``local_opt`` / ``server_opt`` accept an :class:`UpdateRule` or its
        declarative string form, overriding the registry entry's defaults;
        both unresolved (the default) runs the legacy hardcoded-SGD
        arithmetic bit-for-bit.  When rules are bound, the comm profile is
        re-priced as data: a server rule ships one extra payload per
        direction (the previous averaged iterate feeding the pseudo-
        gradient), and the "mix" policy moves each params-shaped optimizer
        buffer through the network alongside the model.
        """
        lo = local_opt if local_opt is not None else self.local_opt
        so = server_opt if server_opt is not None else self.server_opt
        policy = opt_policy if opt_policy is not None else self.opt_policy
        if policy not in OPT_POLICIES:
            raise ValueError(f"opt_policy {policy!r} not in {OPT_POLICIES}")
        if isinstance(lo, str):
            lo = parse_update_rule(lo, lr=cfg.eta_l if eta is None else eta)
        if isinstance(so, str):
            so = parse_update_rule(so, lr=eta_g)
        if so is not None and lo is None:
            # a server rule alone still runs the rule path; materialize the
            # default local rule so init and round functions agree on state
            lo = parse_update_rule("sgd", lr=cfg.eta_l if eta is None else eta)

        opt_kw = {}
        comm = self.comm
        if lo is not None or so is not None:
            opt_kw = dict(local_opt=lo, server_opt=so, opt_policy=policy)
            if so is not None:
                comm = dataclasses.replace(
                    comm, server_payloads=comm.server_payloads + 1
                )
            n_buffers = lo.n_buffers if lo is not None else 0
            if n_buffers and policy == "mix":
                comm = dataclasses.replace(
                    comm,
                    mixes_per_round=comm.mixes_per_round + n_buffers,
                    server_payloads=comm.server_payloads + n_buffers,
                )
        init, gossip, glob = self.build(
            self, loss_fn, cfg, mixing, eta=eta, eta_g=eta_g, **opt_kw
        )
        return BoundAlgorithm(
            name=self.name,
            init=init,
            gossip_round=gossip,
            global_round=glob,
            schedule=schedule if schedule is not None else
            self.make_default_schedule(cfg),
            comm=comm,
            network=getattr(mixing, "network", None),
            local_opt=lo,
            server_opt=so,
            opt_policy=policy,
        )


_REGISTRY: Dict[str, Algorithm] = {}


def register_algorithm(
    name: str,
    *,
    mixes_per_round: int = 1,
    server_payloads: Optional[int] = None,
    server_based: bool = False,
    uses_local_updates: bool = True,
    schedule: str = "bernoulli",
    avg_period: int = 10,
    local_opt: Optional[str] = None,
    server_opt: Optional[str] = None,
    opt_policy: str = "mix",
    description: str = "",
) -> Callable[[Builder], Builder]:
    """Decorator registering a builder under ``name``.

    ``server_payloads`` defaults to ``mixes_per_round`` — a protocol that
    mixes two streams over gossip links generally ships both streams through
    the server too (PISCO/DSGT move X and Y; SCAFFOLD the model and variate).

    ``local_opt`` / ``server_opt`` are default update-rule strings (e.g. a
    PISCO-M entry would register ``local_opt="momentum"``); ``opt_policy``
    is the entry's opt-state communication policy when rules are bound.
    """

    def deco(build: Builder) -> Builder:
        if name in _REGISTRY:
            raise ValueError(f"algorithm {name!r} already registered")
        _REGISTRY[name] = Algorithm(
            name=name,
            build=build,
            comm=CommProfile(
                mixes_per_round=mixes_per_round,
                server_payloads=(
                    mixes_per_round if server_payloads is None else server_payloads
                ),
                server_based=server_based,
                uses_local_updates=uses_local_updates,
            ),
            schedule=schedule,
            avg_period=avg_period,
            local_opt=local_opt,
            server_opt=server_opt,
            opt_policy=opt_policy,
            description=description or (build.__doc__ or "").strip(),
        )
        return build

    return deco


def unregister_algorithm(name: str) -> None:
    """Remove a registry entry (tests / plugin reload)."""
    _REGISTRY.pop(name, None)


def get_algorithm(name: str) -> Algorithm:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown algorithm {name!r}; registered: {sorted(_REGISTRY)}"
        ) from None


def registered_algorithms() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


# ---------------------------------------------------------------------------
# The paper's seven protocols, ported onto the registry
# ---------------------------------------------------------------------------


@register_algorithm(
    "pisco",
    mixes_per_round=2,
    description="PISCO (Algorithm 1): tracked local updates + Bernoulli(p) server",
)
def _build_pisco(
    spec, loss_fn, cfg, mixing, *, eta=None, eta_g=1.0,
    local_opt=None, server_opt=None, opt_policy="mix",
):
    del spec, eta, eta_g
    opt_kw = dict(local_opt=local_opt, server_opt=server_opt, opt_policy=opt_policy)
    return (
        lambda lf, x0, b0: init_compression_state(
            init_state(lf, x0, b0, local_opt, server_opt), mixing
        ),
        make_round_fn(loss_fn, cfg, mixing, global_round=False, **opt_kw),
        make_round_fn(loss_fn, cfg, mixing, global_round=True, **opt_kw),
    )


@register_algorithm(
    "periodical_gt",
    mixes_per_round=2,
    schedule="never",
    description="Periodical-GT [LLKS24]: PISCO with p = 0 (gossip every round)",
)
def _build_periodical_gt(
    spec, loss_fn, cfg, mixing, *, eta=None, eta_g=1.0,
    local_opt=None, server_opt=None, opt_policy="mix",
):
    del spec, eta, eta_g
    fn = B.make_periodical_gt_round_fn(
        loss_fn, cfg, mixing,
        local_opt=local_opt, server_opt=server_opt, opt_policy=opt_policy,
    )
    # init_state (not dsgt_init): the round fn carries a PiscoState, and the
    # scan driver needs the carry pytree type to match it exactly.
    def init(lf, x0, b0):
        return init_state(lf, x0, b0, local_opt, server_opt)

    return init, fn, fn


@register_algorithm(
    "dsgt",
    mixes_per_round=2,
    uses_local_updates=False,
    description="DSGT [PN21]: gradient tracking, one step per round",
)
def _build_dsgt(
    spec, loss_fn, cfg, mixing, *, eta=None, eta_g=1.0,
    local_opt=None, server_opt=None, opt_policy="mix",
):
    del spec, eta_g
    eta = cfg.eta_l if eta is None else eta
    opt_kw = dict(local_opt=local_opt, server_opt=server_opt, opt_policy=opt_policy)

    def init(lf, x0, b0):
        return B.dsgt_init(lf, x0, b0, local_opt, server_opt)

    return (
        init,
        B.make_dsgt_round_fn(loss_fn, eta, mixing, global_round=False, **opt_kw),
        B.make_dsgt_round_fn(loss_fn, eta, mixing, global_round=True, **opt_kw),
    )


def _build_dsgd_family(loss_fn, cfg, mixing, eta, local_opt, server_opt, opt_policy):
    opt_kw = dict(local_opt=local_opt, server_opt=server_opt, opt_policy=opt_policy)

    def init(lf, x0, b0):
        return B.dsgd_init(lf, x0, b0, local_opt, server_opt)

    return (
        init,
        B.make_dsgd_round_fn(
            loss_fn, eta, mixing, global_round=False, t_o=cfg.t_o, **opt_kw
        ),
        B.make_dsgd_round_fn(
            loss_fn, eta, mixing, global_round=True, t_o=cfg.t_o, **opt_kw
        ),
    )


@register_algorithm(
    "dsgd",
    mixes_per_round=1,
    uses_local_updates=False,
    schedule="never",
    description="DSGD [NO09]: gossip SGD",
)
def _build_dsgd(
    spec, loss_fn, cfg, mixing, *, eta=None, eta_g=1.0,
    local_opt=None, server_opt=None, opt_policy="mix",
):
    del spec, eta_g
    eta = cfg.eta_l if eta is None else eta
    return _build_dsgd_family(
        loss_fn, cfg, mixing, eta, local_opt, server_opt, opt_policy
    )


@register_algorithm(
    "gossip_pga",
    mixes_per_round=1,
    uses_local_updates=False,
    schedule="periodic",
    avg_period=10,
    description="Gossip-PGA [CYZ+21]: gossip SGD + periodic global averaging",
)
def _build_gossip_pga(
    spec, loss_fn, cfg, mixing, *, eta=None, eta_g=1.0,
    local_opt=None, server_opt=None, opt_policy="mix",
):
    del spec, eta_g
    eta = cfg.eta_l if eta is None else eta
    return _build_dsgd_family(
        loss_fn, cfg, mixing, eta, local_opt, server_opt, opt_policy
    )


@register_algorithm(
    "fedavg",
    mixes_per_round=1,
    server_based=True,
    schedule="always",
    opt_policy="reset",
    description="FedAvg [MMR+17]: local SGD + server averaging every round",
)
def _build_fedavg(
    spec, loss_fn, cfg, mixing, *, eta=None, eta_g=1.0,
    local_opt=None, server_opt=None, opt_policy="reset",
):
    del spec, eta_g
    eta = cfg.eta_l if eta is None else eta

    def init(lf, x0, b0):
        return B.dsgd_init(lf, x0, b0, local_opt, server_opt)

    s = B.make_dsgd_round_fn(
        loss_fn, eta, mixing, global_round=True, t_o=cfg.t_o,
        local_opt=local_opt, server_opt=server_opt, opt_policy=opt_policy,
    )
    return init, s, s


@register_algorithm(
    "scaffold",
    mixes_per_round=2,
    server_based=True,
    schedule="always",
    opt_policy="reset",
    description="SCAFFOLD [KKM+20]: model + control variate per server exchange",
)
def _build_scaffold(
    spec, loss_fn, cfg, mixing, *, eta=None, eta_g=1.0,
    local_opt=None, server_opt=None, opt_policy="reset",
):
    del spec, eta

    def init(lf, x0, b0):
        return B.scaffold_init(lf, x0, b0, local_opt, server_opt)

    fn = B.make_scaffold_round_fn(
        loss_fn, cfg.eta_l, eta_g, cfg.t_o, mixing,
        local_opt=local_opt, server_opt=server_opt, opt_policy=opt_policy,
    )
    return init, fn, fn
