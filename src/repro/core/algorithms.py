"""First-class algorithm registry — the experiment-facing protocol layer.

Every semi-decentralized protocol the repo can train (PISCO and the Table-1/2
baselines, plus any third-party addition) is one :class:`Algorithm` entry:

* a **builder** closing the round functions over ``(loss_fn, cfg, mixing)``,
* a declarative **default schedule** (``"bernoulli"`` / ``"never"`` /
  ``"always"`` / ``"periodic"`` — line 8 of Algorithm 1 and its degenerate
  cases), and
* a :class:`CommProfile` pricing the protocol's traffic *as data*: how many
  mixing invocations a gossip round performs (gradient tracking mixes both the
  X and Y streams; plain-SGD families mix X only) and how many payloads one
  server exchange moves per direction (SCAFFOLD ships the model *and* the
  control variate).

Registering a new protocol is one file anywhere downstream::

    from repro.core.algorithms import BoundAlgorithm, register_algorithm

    @register_algorithm("my_algo", mixes_per_round=1)
    def _build(spec, loss_fn, cfg, mixing, **_):
        return my_init, my_gossip_round, my_global_round

— no trainer edits, no byte-model edits, no benchmark edits.  The trainer,
the :class:`~repro.core.experiment.Experiment` API, and the benchmark harness
all resolve algorithms exclusively through :func:`get_algorithm`.

Round-function contract (shared with PISCO, see :mod:`repro.core.pisco`)::

    init(loss_fn, x0_stacked, comm_batch0) -> state
    round_fn(state, local_batches, comm_batch) -> (state, RoundMetrics)

``gossip_round`` and ``global_round`` must return identical pytree
structures/dtypes — the scan driver dispatches between them with ``lax.cond``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

from repro.core import baselines as B
from repro.core.mixing import MixingOps
from repro.core.pisco import (
    LossFn,
    PiscoConfig,
    init_compression_state,
    init_state,
    make_round_fn,
)
from repro.core.schedule import PeriodicSchedule, make_schedule

PyTree = Any
# builder(spec, loss_fn, cfg, mixing, *, eta=None, eta_g=1.0)
#   -> (init, gossip_round, global_round)
Builder = Callable[..., Tuple[Callable, Callable, Callable]]

SCHEDULE_KINDS = ("bernoulli", "never", "always", "periodic")


@dataclasses.dataclass(frozen=True)
class CommProfile:
    """Per-protocol communication cost, priced as data (no byte-model edits).

    ``mixes_per_round``   — mixing invocations per communication round; each
                            gossip mix moves one message per directed edge.
    ``server_payloads``   — payloads one agent moves per direction of a server
                            exchange (model only = 1; model + control variate
                            or tracking stream = 2).
    ``server_based``      — every communication round is agent-to-server.
    ``uses_local_updates``— the protocol consumes the T_o local batches.
    """

    mixes_per_round: int = 1
    server_payloads: int = 1
    server_based: bool = False
    uses_local_updates: bool = True


@dataclasses.dataclass(frozen=True)
class BoundAlgorithm:
    """An :class:`Algorithm` closed over ``(loss_fn, cfg, mixing)`` — what the
    round drivers actually run."""

    name: str
    init: Callable[[LossFn, PyTree, Any], Any]
    gossip_round: Callable
    global_round: Callable
    schedule: Callable[[int], bool]
    comm: CommProfile
    # NetworkContext when the mixing is dynamic (time-varying topology and/or
    # partial participation): the drivers pre-draw per-round matrices through
    # it and thread them into the round functions.  None => static network,
    # the exact pre-dynamic code path.
    network: Optional[Any] = None


@dataclasses.dataclass(frozen=True)
class Algorithm:
    """One registry entry: builder + declarative schedule + comm profile.

    ``avg_period`` (periodic schedules only) is the explicit server-averaging
    period H used when ``cfg.p == 0`` gives no implied period; Gossip-PGA's
    documented default is H = 10 [CYZ+21].  When ``cfg.p > 0`` the period is
    derived as ``round(1/p)`` so a Bernoulli(p) PISCO run and a periodic
    baseline spend the same expected server budget.
    """

    name: str
    build: Builder
    comm: CommProfile = CommProfile()
    schedule: str = "bernoulli"
    avg_period: int = 10
    description: str = ""

    def __post_init__(self):
        if self.schedule not in SCHEDULE_KINDS:
            raise ValueError(
                f"schedule {self.schedule!r} not in {SCHEDULE_KINDS}"
            )

    def make_default_schedule(self, cfg: PiscoConfig):
        if self.schedule == "never":
            return make_schedule(0.0)
        if self.schedule == "always":
            return make_schedule(1.0)
        if self.schedule == "periodic":
            period = (
                max(1, int(round(1.0 / cfg.p))) if cfg.p > 0 else self.avg_period
            )
            return PeriodicSchedule(period)
        return make_schedule(cfg.p, cfg.seed)

    def bind(
        self,
        loss_fn: LossFn,
        cfg: PiscoConfig,
        mixing: MixingOps,
        *,
        eta: Optional[float] = None,
        eta_g: float = 1.0,
        schedule: Optional[Callable[[int], bool]] = None,
    ) -> BoundAlgorithm:
        """Close the algorithm over a concrete problem; ``schedule`` overrides
        the declarative default (e.g. a replayed flag sequence)."""
        init, gossip, glob = self.build(
            self, loss_fn, cfg, mixing, eta=eta, eta_g=eta_g
        )
        return BoundAlgorithm(
            name=self.name,
            init=init,
            gossip_round=gossip,
            global_round=glob,
            schedule=schedule if schedule is not None else
            self.make_default_schedule(cfg),
            comm=self.comm,
            network=getattr(mixing, "network", None),
        )


_REGISTRY: Dict[str, Algorithm] = {}


def register_algorithm(
    name: str,
    *,
    mixes_per_round: int = 1,
    server_payloads: Optional[int] = None,
    server_based: bool = False,
    uses_local_updates: bool = True,
    schedule: str = "bernoulli",
    avg_period: int = 10,
    description: str = "",
) -> Callable[[Builder], Builder]:
    """Decorator registering a builder under ``name``.

    ``server_payloads`` defaults to ``mixes_per_round`` — a protocol that
    mixes two streams over gossip links generally ships both streams through
    the server too (PISCO/DSGT move X and Y; SCAFFOLD the model and variate).
    """

    def deco(build: Builder) -> Builder:
        if name in _REGISTRY:
            raise ValueError(f"algorithm {name!r} already registered")
        _REGISTRY[name] = Algorithm(
            name=name,
            build=build,
            comm=CommProfile(
                mixes_per_round=mixes_per_round,
                server_payloads=(
                    mixes_per_round if server_payloads is None else server_payloads
                ),
                server_based=server_based,
                uses_local_updates=uses_local_updates,
            ),
            schedule=schedule,
            avg_period=avg_period,
            description=description or (build.__doc__ or "").strip(),
        )
        return build

    return deco


def unregister_algorithm(name: str) -> None:
    """Remove a registry entry (tests / plugin reload)."""
    _REGISTRY.pop(name, None)


def get_algorithm(name: str) -> Algorithm:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown algorithm {name!r}; registered: {sorted(_REGISTRY)}"
        ) from None


def registered_algorithms() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


# ---------------------------------------------------------------------------
# The paper's seven protocols, ported onto the registry
# ---------------------------------------------------------------------------


@register_algorithm(
    "pisco",
    mixes_per_round=2,
    description="PISCO (Algorithm 1): tracked local updates + Bernoulli(p) server",
)
def _build_pisco(spec, loss_fn, cfg, mixing, *, eta=None, eta_g=1.0):
    del spec, eta, eta_g
    return (
        lambda lf, x0, b0: init_compression_state(init_state(lf, x0, b0), mixing),
        make_round_fn(loss_fn, cfg, mixing, global_round=False),
        make_round_fn(loss_fn, cfg, mixing, global_round=True),
    )


@register_algorithm(
    "periodical_gt",
    mixes_per_round=2,
    schedule="never",
    description="Periodical-GT [LLKS24]: PISCO with p = 0 (gossip every round)",
)
def _build_periodical_gt(spec, loss_fn, cfg, mixing, *, eta=None, eta_g=1.0):
    del spec, eta, eta_g
    fn = B.make_periodical_gt_round_fn(loss_fn, cfg, mixing)
    # init_state (not dsgt_init): the round fn carries a PiscoState, and the
    # scan driver needs the carry pytree type to match it exactly.
    return init_state, fn, fn


@register_algorithm(
    "dsgt",
    mixes_per_round=2,
    uses_local_updates=False,
    description="DSGT [PN21]: gradient tracking, one step per round",
)
def _build_dsgt(spec, loss_fn, cfg, mixing, *, eta=None, eta_g=1.0):
    del spec, eta_g
    eta = cfg.eta_l if eta is None else eta
    return (
        B.dsgt_init,
        B.make_dsgt_round_fn(loss_fn, eta, mixing, global_round=False),
        B.make_dsgt_round_fn(loss_fn, eta, mixing, global_round=True),
    )


@register_algorithm(
    "dsgd",
    mixes_per_round=1,
    uses_local_updates=False,
    schedule="never",
    description="DSGD [NO09]: gossip SGD",
)
def _build_dsgd(spec, loss_fn, cfg, mixing, *, eta=None, eta_g=1.0):
    del spec, eta_g
    eta = cfg.eta_l if eta is None else eta
    return (
        B.dsgd_init,
        B.make_dsgd_round_fn(loss_fn, eta, mixing, global_round=False, t_o=cfg.t_o),
        B.make_dsgd_round_fn(loss_fn, eta, mixing, global_round=True, t_o=cfg.t_o),
    )


@register_algorithm(
    "gossip_pga",
    mixes_per_round=1,
    uses_local_updates=False,
    schedule="periodic",
    avg_period=10,
    description="Gossip-PGA [CYZ+21]: gossip SGD + periodic global averaging",
)
def _build_gossip_pga(spec, loss_fn, cfg, mixing, *, eta=None, eta_g=1.0):
    del spec, eta_g
    eta = cfg.eta_l if eta is None else eta
    return (
        B.dsgd_init,
        B.make_dsgd_round_fn(loss_fn, eta, mixing, global_round=False, t_o=cfg.t_o),
        B.make_dsgd_round_fn(loss_fn, eta, mixing, global_round=True, t_o=cfg.t_o),
    )


@register_algorithm(
    "fedavg",
    mixes_per_round=1,
    server_based=True,
    schedule="always",
    description="FedAvg [MMR+17]: local SGD + server averaging every round",
)
def _build_fedavg(spec, loss_fn, cfg, mixing, *, eta=None, eta_g=1.0):
    del spec, eta_g
    eta = cfg.eta_l if eta is None else eta
    s = B.make_dsgd_round_fn(loss_fn, eta, mixing, global_round=True, t_o=cfg.t_o)
    return B.dsgd_init, s, s


@register_algorithm(
    "scaffold",
    mixes_per_round=2,
    server_based=True,
    schedule="always",
    description="SCAFFOLD [KKM+20]: model + control variate per server exchange",
)
def _build_scaffold(spec, loss_fn, cfg, mixing, *, eta=None, eta_g=1.0):
    del spec, eta
    fn = B.make_scaffold_round_fn(loss_fn, cfg.eta_l, eta_g, cfg.t_o, mixing)
    return B.scaffold_init, fn, fn
