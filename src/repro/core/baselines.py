"""Baseline algorithms the paper compares against (Tables 1 & 2).

All baselines share PISCO's substrate: agent-stacked pytrees, the
:class:`~repro.core.mixing.MixingOps` communication layer, per-agent loss
functions, and host-side schedules.  Implemented:

* DSGD           — gossip SGD [NO09]
* Gossip-PGA     — gossip SGD + periodic global averaging every H [CYZ+21]
* DSGT           — distributed stochastic gradient tracking [PN21]
* Periodical-GT  — GT + T_o local updates, gossip every round [LLKS24]
                   (== PISCO with p = 0; provided as a named wrapper)
* FedAvg         — T_o local SGD steps + server averaging [MMR+17, LHY+20]
* SCAFFOLD       — FedAvg + control variates [KKM+20]

Each exposes ``init(loss_fn, x0, batch0)`` and round functions with the same
signature as PISCO's, so the shared trainer drives any of them.

Every baseline takes the same pluggable-optimizer hooks as PISCO
(``local_opt`` / ``server_opt`` / ``opt_policy``, DESIGN.md §10): the local
rule replaces the hardcoded ``x - eta * g`` descent, the server rule turns
global-averaging rounds into FedOpt updates (FedAvg + ``server_opt=fedadam``
*is* FedAdam), and both ``None`` keeps the historical inline arithmetic
bit-for-bit.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.mixing import MixingOps
from repro.core.pisco import (
    LossFn,
    PiscoConfig,
    RoundMetrics,
    _consensus_error,
    make_round_fn,
    make_stacked_value_and_grad,
    init_state as pisco_init_state,
)
from repro.optim.update_rules import (
    UpdateRule,
    apply_updates,
    comm_opt_state,
    init_opt_state,
    server_step,
    sgd as sgd_rule,
)
from repro.utils.pytree import tree_add, tree_sub, tree_sq_norm

PyTree = Any


def _metrics(loss, g_stacked, x) -> RoundMetrics:
    gbar = jax.tree.map(lambda v: jnp.mean(v, axis=0), g_stacked)
    n = jax.tree.leaves(x)[0].shape[0]
    return RoundMetrics(
        loss=jnp.mean(loss),
        grad_sq_norm=tree_sq_norm(gbar),
        consensus_err=_consensus_error(x) / n,
    )


# ---------------------------------------------------------------------------
# DSGD / Gossip-PGA
# ---------------------------------------------------------------------------


class SGDState(NamedTuple):
    x: PyTree
    step: jnp.ndarray
    opt: PyTree = ()  # () legacy | {"local": ..., "server": ...} with rules


def dsgd_init(
    loss_fn: LossFn,
    x0: PyTree,
    batch0: Any,
    local_opt: Optional[UpdateRule] = None,
    server_opt: Optional[UpdateRule] = None,
) -> SGDState:
    del loss_fn, batch0
    return SGDState(
        x=x0, step=jnp.zeros((), jnp.int32),
        opt=init_opt_state(x0, local_opt, server_opt),
    )


def make_dsgd_round_fn(
    loss_fn: LossFn,
    eta: float,
    mixing: MixingOps,
    *,
    global_round: bool,
    t_o: int = 1,
    local_opt: Optional[UpdateRule] = None,
    server_opt: Optional[UpdateRule] = None,
    opt_policy: str = "mix",
) -> Callable:
    """One DSGD round: ``x <- mix(x - eta g)`` (T_o local SGD steps first when
    t_o > 1, which with global mixing == FedAvg / local SGD).  With rules
    bound, the descent step is the local rule and a server rule makes the
    global round a FedOpt update (FedAvg + fedadam == FedAdam)."""
    stacked_vg = make_stacked_value_and_grad(loss_fn)
    mix = mixing.global_avg if global_round else mixing.gossip
    has_rules = local_opt is not None or server_opt is not None
    if has_rules and local_opt is None:
        local_opt = sgd_rule(eta)

    def legacy_round_fn(state: SGDState, local_batches, comm_batch):
        def step(x, batch_t):
            loss, g = stacked_vg(x, batch_t)
            x = jax.tree.map(lambda xi, gi: xi - eta * gi, x, g)
            return x, (loss, g)

        x, (losses, gs) = jax.lax.scan(step, state.x, local_batches)
        # one more SGD step on the comm batch, then mix (keeps the same
        # gradient budget per round as PISCO: T_o + 1 evaluations)
        loss_c, g_c = stacked_vg(x, comm_batch)
        x = jax.tree.map(lambda xi, gi: xi - eta * gi, x, g_c)
        x = mix(x)
        new_state = SGDState(
            x=x, step=state.step + 1, opt=getattr(state, "opt", ())
        )
        return new_state, _metrics(
            (jnp.mean(losses) * t_o + jnp.mean(loss_c)) / (t_o + 1), g_c, x
        )

    def rule_round_fn(state: SGDState, local_batches, comm_batch):
        lopt, sopt = state.opt["local"], state.opt["server"]

        def step(carry, batch_t):
            x, opt = carry
            loss, g = stacked_vg(x, batch_t)
            upd, opt = local_opt.update(g, opt, x)
            x = apply_updates(x, upd)
            return (x, opt), (loss, g)

        (x, lopt), (losses, gs) = jax.lax.scan(
            step, (state.x, lopt), local_batches
        )
        loss_c, g_c = stacked_vg(x, comm_batch)
        upd, lopt = local_opt.update(g_c, lopt, x)
        x = apply_updates(x, upd)
        if global_round and server_opt is not None:
            x, sopt = server_step(server_opt, sopt, mix(state.x), mix(x))
        else:
            x = mix(x)
        lopt = comm_opt_state(
            lopt, mix, _n_agents(state.x), opt_policy, is_global=global_round
        )
        new_state = SGDState(
            x=x, step=state.step + 1, opt={"local": lopt, "server": sopt}
        )
        return new_state, _metrics(
            (jnp.mean(losses) * t_o + jnp.mean(loss_c)) / (t_o + 1), g_c, x
        )

    return rule_round_fn if has_rules else legacy_round_fn


def _n_agents(x: PyTree) -> int:
    return jax.tree.leaves(x)[0].shape[0]


# ---------------------------------------------------------------------------
# DSGT [PN21]
# ---------------------------------------------------------------------------


class GTState(NamedTuple):
    x: PyTree
    y: PyTree
    g: PyTree
    step: jnp.ndarray
    opt: PyTree = ()  # () legacy | {"local": ..., "server": ...} with rules


def dsgt_init(
    loss_fn: LossFn,
    x0: PyTree,
    batch0: Any,
    local_opt: Optional[UpdateRule] = None,
    server_opt: Optional[UpdateRule] = None,
) -> GTState:
    s = pisco_init_state(loss_fn, x0, batch0)
    return GTState(
        x=s.x, y=s.y, g=s.g, step=s.step,
        opt=init_opt_state(x0, local_opt, server_opt),
    )


def make_dsgt_round_fn(
    loss_fn: LossFn,
    eta: float,
    mixing: MixingOps,
    *,
    global_round: bool = False,
    local_opt: Optional[UpdateRule] = None,
    server_opt: Optional[UpdateRule] = None,
    opt_policy: str = "mix",
) -> Callable:
    """DSGT:  x+ = mix(x - eta y);  y+ = mix(y) + g(x+) - g(x).  With rules
    bound, the tracker step goes through the local rule (the y/g recursion —
    and hence Lemma 1 — is untouched)."""
    stacked_vg = make_stacked_value_and_grad(loss_fn)
    mix = mixing.global_avg if global_round else mixing.gossip
    has_rules = local_opt is not None or server_opt is not None
    if has_rules and local_opt is None:
        local_opt = sgd_rule(eta)

    def legacy_round_fn(state: GTState, local_batches, comm_batch):
        del local_batches  # DSGT has no local phase; comm_batch is Z^{k+1}
        x_new = mix(jax.tree.map(lambda xi, yi: xi - eta * yi, state.x, state.y))
        loss, g_new = stacked_vg(x_new, comm_batch)
        y_new = tree_add(mix(state.y), tree_sub(g_new, state.g))
        new_state = GTState(
            x=x_new, y=y_new, g=g_new, step=state.step + 1,
            opt=getattr(state, "opt", ()),
        )
        return new_state, _metrics(loss, g_new, x_new)

    def rule_round_fn(state: GTState, local_batches, comm_batch):
        del local_batches
        lopt, sopt = state.opt["local"], state.opt["server"]
        upd, lopt = local_opt.update(state.y, lopt, state.x)
        cand = apply_updates(state.x, upd)
        if global_round and server_opt is not None:
            x_new, sopt = server_step(server_opt, sopt, mix(state.x), mix(cand))
        else:
            x_new = mix(cand)
        loss, g_new = stacked_vg(x_new, comm_batch)
        y_new = tree_add(mix(state.y), tree_sub(g_new, state.g))
        lopt = comm_opt_state(
            lopt, mix, _n_agents(state.x), opt_policy, is_global=global_round
        )
        new_state = GTState(
            x=x_new, y=y_new, g=g_new, step=state.step + 1,
            opt={"local": lopt, "server": sopt},
        )
        return new_state, _metrics(loss, g_new, x_new)

    return rule_round_fn if has_rules else legacy_round_fn


# ---------------------------------------------------------------------------
# Periodical-GT (PISCO p=0 named wrapper)
# ---------------------------------------------------------------------------


def make_periodical_gt_round_fn(
    loss_fn: LossFn,
    cfg: PiscoConfig,
    mixing: MixingOps,
    *,
    local_opt: Optional[UpdateRule] = None,
    server_opt: Optional[UpdateRule] = None,
    opt_policy: str = "mix",
) -> Callable:
    """[LLKS24]: gradient tracking with T_o local steps, gossip every round —
    exactly PISCO's gossip round (Remark 1).  GTState carries no error-feedback
    residuals, so compressed mixing runs through the stateless path."""
    return make_round_fn(
        loss_fn, cfg, mixing, global_round=False, use_ef=False,
        local_opt=local_opt, server_opt=server_opt, opt_policy=opt_policy,
    )


# ---------------------------------------------------------------------------
# SCAFFOLD [KKM+20] (option II control variates)
# ---------------------------------------------------------------------------


class ScaffoldState(NamedTuple):
    x: PyTree  # agent-stacked copies of the server model (kept in sync)
    c_i: PyTree  # agent control variates (stacked)
    c: PyTree  # server control variate (stacked-broadcast for layout parity)
    step: jnp.ndarray
    opt: PyTree = ()  # () legacy | {"local": ..., "server": ...} with rules


def scaffold_init(
    loss_fn: LossFn,
    x0: PyTree,
    batch0: Any,
    local_opt: Optional[UpdateRule] = None,
    server_opt: Optional[UpdateRule] = None,
) -> ScaffoldState:
    _, g0 = make_stacked_value_and_grad(loss_fn)(x0, batch0)
    c = jax.tree.map(
        lambda v: jnp.broadcast_to(jnp.mean(v, axis=0, keepdims=True), v.shape), g0
    )
    return ScaffoldState(
        x=x0, c_i=g0, c=c, step=jnp.zeros((), jnp.int32),
        opt=init_opt_state(x0, local_opt, server_opt),
    )


def make_scaffold_round_fn(
    loss_fn: LossFn,
    eta_l: float,
    eta_g: float,
    t_o: int,
    mixing: MixingOps,
    *,
    local_opt: Optional[UpdateRule] = None,
    server_opt: Optional[UpdateRule] = None,
    opt_policy: str = "reset",
) -> Callable:
    """SCAFFOLD round (always agent-to-server; the federated anchor of Table 2).

    Local:  x <- x - eta_l (g_i(x) - c_i + c), T_o+1 steps.
    Then:   c_i+ = c_i - c + (x_k - x_To) / ((T_o+1) eta_l)
            x+   = x_k + eta_g * mean(x_To - x_k);  c+ = mean(c_i+)

    With rules bound, the local rule descends along the corrected gradient
    ``g_i + (c - c_i)``; the variate update keeps the option-II difference
    form above (its 1/((T_o+1) eta_l) scale is SCAFFOLD's own estimator and
    stays fixed), and a server rule replaces the eta_g step with a FedOpt
    update on the round pseudo-gradient.
    """
    stacked_vg = make_stacked_value_and_grad(loss_fn)
    g_avg = mixing.global_avg
    has_rules = local_opt is not None or server_opt is not None
    if has_rules and local_opt is None:
        local_opt = sgd_rule(eta_l)

    def _variates_and_server(state, x_to, lopt, sopt):
        steps = (t_o + 1) * eta_l
        c_i_new = jax.tree.map(
            lambda ci, c, xk, xt: ci - c + (xk - xt) / steps,
            state.c_i,
            state.c,
            state.x,
            x_to,
        )
        if sopt is not None and server_opt is not None:
            x_new, sopt = server_step(server_opt, sopt, state.x, g_avg(x_to))
        else:
            delta = g_avg(tree_sub(x_to, state.x))
            x_new = jax.tree.map(lambda xk, d: xk + eta_g * d, state.x, delta)
        c_new = g_avg(c_i_new)
        return c_i_new, c_new, x_new, sopt

    def legacy_round_fn(state: ScaffoldState, local_batches, comm_batch):
        correction = tree_sub(state.c, state.c_i)

        def step(carry, batch_t):
            x = carry
            loss, g = stacked_vg(x, batch_t)
            x = jax.tree.map(
                lambda xi, gi, ci: xi - eta_l * (gi + ci), x, g, correction
            )
            return x, (loss, g)

        x_to, (losses, _) = jax.lax.scan(step, state.x, local_batches)
        loss_c, g_c = stacked_vg(x_to, comm_batch)
        x_to = jax.tree.map(
            lambda xi, gi, ci: xi - eta_l * (gi + ci), x_to, g_c, correction
        )

        c_i_new, c_new, x_new, _ = _variates_and_server(state, x_to, None, None)
        new_state = ScaffoldState(
            x=x_new, c_i=c_i_new, c=c_new, step=state.step + 1,
            opt=getattr(state, "opt", ()),
        )
        return new_state, _metrics(
            (jnp.mean(losses) * t_o + jnp.mean(loss_c)) / (t_o + 1), g_c, x_new
        )

    def rule_round_fn(state: ScaffoldState, local_batches, comm_batch):
        lopt, sopt = state.opt["local"], state.opt["server"]
        correction = tree_sub(state.c, state.c_i)

        def step(carry, batch_t):
            x, opt = carry
            loss, g = stacked_vg(x, batch_t)
            upd, opt = local_opt.update(tree_add(g, correction), opt, x)
            x = apply_updates(x, upd)
            return (x, opt), (loss, g)

        (x_to, lopt), (losses, _) = jax.lax.scan(
            step, (state.x, lopt), local_batches
        )
        loss_c, g_c = stacked_vg(x_to, comm_batch)
        upd, lopt = local_opt.update(tree_add(g_c, correction), lopt, x_to)
        x_to = apply_updates(x_to, upd)

        c_i_new, c_new, x_new, sopt = _variates_and_server(
            state, x_to, lopt, sopt
        )
        lopt = comm_opt_state(
            lopt, g_avg, _n_agents(state.x), opt_policy, is_global=True
        )
        new_state = ScaffoldState(
            x=x_new, c_i=c_i_new, c=c_new, step=state.step + 1,
            opt={"local": lopt, "server": sopt},
        )
        return new_state, _metrics(
            (jnp.mean(losses) * t_o + jnp.mean(loss_c)) / (t_o + 1), g_c, x_new
        )

    return rule_round_fn if has_rules else legacy_round_fn


# ---------------------------------------------------------------------------
# Static baseline descriptors (the runnable registry — builders, schedules,
# comm-cost profiles — lives in repro.core.algorithms; consistency between
# the two is asserted in tests/test_registry.py)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class BaselineSpec:
    name: str
    server_based: bool  # True => every comm round is agent-to-server
    uses_local_updates: bool


BASELINES = {
    "dsgd": BaselineSpec("dsgd", server_based=False, uses_local_updates=False),
    "gossip_pga": BaselineSpec("gossip_pga", server_based=False, uses_local_updates=False),
    "dsgt": BaselineSpec("dsgt", server_based=False, uses_local_updates=False),
    "periodical_gt": BaselineSpec("periodical_gt", server_based=False, uses_local_updates=True),
    "fedavg": BaselineSpec("fedavg", server_based=True, uses_local_updates=True),
    "scaffold": BaselineSpec("scaffold", server_based=True, uses_local_updates=True),
    "pisco": BaselineSpec("pisco", server_based=False, uses_local_updates=True),
}
