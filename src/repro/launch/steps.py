"""Builders for the jitted step functions the launcher / dry-run lowers.

Three step kinds per (architecture × mesh):

* ``build_train_steps``  — one full PISCO round (gossip and global variants),
  agent-stacked params over the agent mesh axes, model-parallel inside.
* ``build_prefill_step`` — inference prefill (forward + cache fill).
* ``build_decode_step``  — one-token decode against the KV/SSM cache.

Every builder returns a :class:`StepSpec`: the jitted function plus the
ShapeDtypeStruct args — ``spec.lower()`` is all the dry-run needs.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.shapes import InputShape
from repro.core.mixing import (
    MixingOps,
    collective_global_mixing,
    collective_shift_mixing,
)
from repro.core.pisco import PiscoConfig, PiscoState, make_round_fn
from repro.launch import input_specs as I
from repro.launch.mesh import agent_axes_for, n_agents_for
from repro.launch.specs import sanitize_specs, stack_spec_tree, to_shardings
from repro.models.registry import ModelBundle

PyTree = Any
SDS = jax.ShapeDtypeStruct


@dataclasses.dataclass
class StepSpec:
    name: str
    fn: Callable  # jitted
    args: Tuple[Any, ...]  # ShapeDtypeStruct pytrees
    notes: Dict[str, Any]

    def lower(self):
        return self.fn.lower(*self.args)


# ---------------------------------------------------------------------------
# Gossip weights on the mesh (circulant; ring over one axis, torus over two)
# ---------------------------------------------------------------------------


def mesh_gossip_shifts(mesh, agent_axes: Sequence[str]) -> Dict[str, list]:
    """Ring (one agent axis) or torus (two axes) neighbor weights.

    Self weight 1/2; the remaining 1/2 split evenly across distinct neighbor
    permutations (an axis of size 2 has a single distinct ±1 neighbor)."""
    axes = list(agent_axes)
    neigh = []
    for a in axes:
        if mesh.shape[a] == 1:
            continue
        if mesh.shape[a] == 2:
            neigh.append((a, [1]))
        else:
            neigh.append((a, [1, -1]))
    total = sum(len(s) for _, s in neigh)
    shifts: Dict[str, list] = {}
    w = 0.5 / max(1, total)
    first = True
    for a, ss in neigh:
        pairs = [(s, w) for s in ss]
        if first:
            pairs = [(0, 0.5)] + pairs
            first = False
        shifts[a] = pairs
    if not neigh:  # single agent: identity
        shifts[axes[0]] = [(0, 1.0)]
    return shifts


def gossip_matrix(mesh, agent_axes: Sequence[str], shifts: Dict[str, list]) -> np.ndarray:
    """Dense equivalent of the circulant mesh gossip (for lambda_w reporting)."""
    sizes = [mesh.shape[a] for a in agent_axes]
    n = int(np.prod(sizes))
    w = np.zeros((n, n))
    idx = np.arange(n).reshape(sizes)
    self_w = sum(
        wt for pairs in shifts.values() for s, wt in pairs if s == 0
    )
    w[np.arange(n), np.arange(n)] += self_w
    for ai, a in enumerate(agent_axes):
        for s, wt in shifts.get(a, []):
            if s == 0:
                continue
            rolled = np.roll(idx, -s, axis=ai)  # dst receives src shifted by s
            w[rolled.reshape(-1), idx.reshape(-1)] += wt
    return w


# ---------------------------------------------------------------------------
# Train steps (one PISCO round)
# ---------------------------------------------------------------------------


def build_train_steps(
    bundle: ModelBundle,
    shape: InputShape,
    mesh,
    *,
    t_o: int = 1,
    eta_l: float = 1e-2,
    eta_c: float = 1.0,
    p: float = 0.1,
    agent_mode: str = "flat",
    compute_metrics: bool = False,
    donate: bool = True,
    wire_dtype: str = "float32",
) -> Dict[str, StepSpec]:
    cfg = bundle.cfg
    agent_axes = agent_axes_for(mesh, agent_mode)
    n_agents = n_agents_for(mesh, agent_mode)
    pcfg = PiscoConfig(n_agents=n_agents, t_o=t_o, eta_l=eta_l, eta_c=eta_c, p=p)

    # --- parameter / state shapes & specs -------------------------------
    params_sds = jax.eval_shape(bundle.init, jax.random.PRNGKey(0))
    stacked_sds = jax.tree.map(
        lambda s: SDS((n_agents,) + s.shape, s.dtype), params_sds
    )
    inner_specs = bundle.param_specs("model")
    stacked_specs = stack_spec_tree(inner_specs, agent_axes)
    if agent_mode == "hierarchical" and "data" in mesh.axis_names:
        # pod-as-agent: each agent's replica also FSDP-shards over the
        # intra-pod data axis (axis 0 is the agent stack — skip it)
        from repro.launch.specs import add_fsdp_axis

        stacked_specs = add_fsdp_axis(
            stacked_specs, stacked_sds, mesh, "data", skip_leading=1
        )
    stacked_specs, dropped = sanitize_specs(stacked_specs, stacked_sds, mesh)

    state_sds = PiscoState(
        x=stacked_sds, y=stacked_sds, g=stacked_sds, step=SDS((), jnp.int32)
    )
    state_specs = PiscoState(
        x=stacked_specs, y=stacked_specs, g=stacked_specs, step=P()
    )

    # --- batch shapes & specs -------------------------------------------
    local_sds, comm_sds = I.train_inputs(cfg, shape, n_agents, t_o)
    agent_entry = agent_axes if len(agent_axes) > 1 else agent_axes[0]
    b_per_agent = shape.global_batch // n_agents
    if agent_mode == "hierarchical" and "data" in mesh.axis_names:
        # pod-as-agent: the per-agent batch dim additionally shards over the
        # intra-pod data axis (synchronous DP inside each agent)
        def _comm_spec(s):
            if len(s.shape) >= 2 and s.shape[1] == b_per_agent:
                return P(agent_entry, "data")
            if len(s.shape) >= 3 and s.shape[2] == b_per_agent:
                return P(agent_entry, None, "data")
            return P(agent_entry)

        comm_specs = jax.tree.map(_comm_spec, comm_sds)
        local_specs = jax.tree.map(
            lambda s: P(None, *_comm_spec_inner(s, b_per_agent, agent_entry)),
            local_sds,
        )
    else:
        comm_specs = jax.tree.map(lambda s: P(agent_entry), comm_sds)
        local_specs = jax.tree.map(lambda s: P(None, agent_entry), local_sds)
    comm_specs, _dropped_b1 = sanitize_specs(comm_specs, comm_sds, mesh)
    local_specs, _dropped_b2 = sanitize_specs(local_specs, local_sds, mesh)

    # --- mixing ops over the agent axes ----------------------------------
    shifts = mesh_gossip_shifts(mesh, agent_axes)
    gossip_ops = collective_shift_mixing(
        mesh, agent_axes, stacked_specs, shifts,
        wire_dtype=None if wire_dtype == "native" else wire_dtype,
    )

    loss_fn = bundle.loss
    in_shardings = (
        to_shardings(state_specs, mesh),
        to_shardings(local_specs, mesh),
        to_shardings(comm_specs, mesh),
    )
    out_shardings = (
        to_shardings(state_specs, mesh),
        None,  # metrics: let XLA place (tiny scalars)
    )
    donate_argnums = (0,) if donate else ()

    steps = {}
    for name, is_global in (("train_gossip", False), ("train_global", True)):
        round_fn = make_round_fn(
            loss_fn, pcfg, gossip_ops, global_round=is_global,
            compute_metrics=compute_metrics,
        )
        fn = jax.jit(
            round_fn,
            in_shardings=in_shardings,
            out_shardings=out_shardings,
            donate_argnums=donate_argnums,
        )
        steps[name] = StepSpec(
            name=name,
            fn=fn,
            args=(state_sds, local_sds, comm_sds),
            notes={
                "n_agents": n_agents,
                "agent_axes": agent_axes,
                "t_o": t_o,
                "gossip_shifts": {k: list(v) for k, v in shifts.items()},
                "wire_dtype": wire_dtype,
                "dropped_shardings": dropped,
                "lambda_w": _lambda_w(mesh, agent_axes, shifts),
            },
        )
    return steps


def _comm_spec_inner(s, b_per_agent, agent_entry):
    """Spec entries for one local-batch leaf BELOW the leading T_o axis."""
    inner_shape = s.shape[1:]
    if len(inner_shape) >= 2 and inner_shape[1] == b_per_agent:
        return (agent_entry, "data")
    if len(inner_shape) >= 3 and inner_shape[2] == b_per_agent:
        return (agent_entry, None, "data")
    return (agent_entry,)


def _lambda_w(mesh, agent_axes, shifts) -> float:
    from repro.core.topology import mixing_rate

    w = gossip_matrix(mesh, agent_axes, shifts)
    return float(mixing_rate(w))


# ---------------------------------------------------------------------------
# Serve steps
# ---------------------------------------------------------------------------


def _batch_axes_entry(mesh, batch: int):
    """Shard the serving batch over all non-model axes when divisible."""
    axes = tuple(n for n in mesh.axis_names if n != "model")
    size = int(np.prod([mesh.shape[a] for a in axes]))
    if batch % size == 0:
        return axes if len(axes) > 1 else axes[0]
    return None


def build_prefill_step(
    bundle: ModelBundle, shape: InputShape, mesh, *, donate: bool = True
) -> StepSpec:
    cfg = bundle.cfg
    batch_sds = I.prefill_inputs(cfg, shape)
    bsz = shape.global_batch
    baxes = _batch_axes_entry(mesh, bsz)

    params_sds = jax.eval_shape(bundle.init, jax.random.PRNGKey(0))
    param_specs, dropped = sanitize_specs(
        bundle.param_specs("model"), params_sds, mesh
    )
    if cfg.is_enc_dec:
        cache_sds = jax.eval_shape(
            lambda: bundle.init_cache(bsz, shape.seq_len, mem_len=shape.seq_len // 4)
        )
    else:
        cache_sds = jax.eval_shape(lambda: bundle.init_cache(bsz, shape.seq_len))
    cache_specs, dropped2 = sanitize_specs(
        bundle.cache_specs(baxes, "model"), cache_sds, mesh
    )
    batch_specs = jax.tree.map(lambda s: P(baxes), batch_sds)
    # positions for VLM are (3, B, S): batch axis second
    if "positions" in batch_sds:
        batch_specs["positions"] = P(None, baxes)
    batch_specs, dropped3 = sanitize_specs(batch_specs, batch_sds, mesh)

    fn = jax.jit(
        bundle.prefill,
        in_shardings=(
            to_shardings(param_specs, mesh),
            to_shardings(batch_specs, mesh),
            to_shardings(cache_specs, mesh),
        ),
        out_shardings=None,
        donate_argnums=(2,) if donate else (),
    )
    return StepSpec(
        name="prefill",
        fn=fn,
        args=(params_sds, batch_sds, cache_sds),
        notes={"batch_axes": baxes, "dropped_shardings": dropped + dropped2 + dropped3},
    )


def _optimize_idle_batch_specs(cache_specs, param_specs, mesh):
    """§Perf lever for batch-1 decode (long_500k): the non-model axes carry no
    batch parallelism, so repurpose "data" as (a) sequence parallelism for KV
    caches, (b) head parallelism for SSM states, (c) expert parallelism for
    MoE weights.  Key-based rewrite; the sanitizer downstream drops anything
    non-divisible."""
    data_axes = tuple(n for n in mesh.axis_names if n != "model")
    entry = data_axes if len(data_axes) > 1 else data_axes[0]

    def rewrite_cache(path, spec):
        keys = [p.key for p in path if isinstance(p, jax.tree_util.DictKey)]
        name = keys[-1] if keys else ""
        n = len(spec)
        if name in ("k", "v", "c_kv", "k_rope"):
            # (..., B, S, [H], D): shard the cache SEQUENCE dim over data
            new = list(spec)
            seq_pos = n - 3 if name in ("k", "v") else n - 2
            if 0 <= seq_pos < n:
                new[seq_pos] = entry
                return P(*new)
        if name == "ssm":
            new = list(spec)
            if n >= 3:
                new[n - 3] = entry  # head dim of (B, H, P, N)
                return P(*new)
        if name == "conv":
            new = list(spec)
            new[n - 1] = ("model",)  # keep channels on model
            if n >= 1:
                return P(*new)
        return spec

    def rewrite_params(path, spec):
        keys = [p.key for p in path if isinstance(p, jax.tree_util.DictKey)]
        if len(spec) >= 3 and keys and keys[-1] in ("w_up", "w_gate", "w_down"):
            if "ffn" in keys:  # expert-stacked (…, E, d, f): experts over data
                new = list(spec)
                new[len(spec) - 3] = entry
                return P(*new)
        return spec

    cache_specs = jax.tree_util.tree_map_with_path(
        rewrite_cache, cache_specs, is_leaf=lambda x: isinstance(x, P)
    )
    param_specs = jax.tree_util.tree_map_with_path(
        rewrite_params, param_specs, is_leaf=lambda x: isinstance(x, P)
    )
    return cache_specs, param_specs


def build_decode_step(
    bundle: ModelBundle, shape: InputShape, mesh, *, donate: bool = True,
    opt_idle_batch: bool = False,
) -> StepSpec:
    cfg = bundle.cfg
    bsz = shape.global_batch
    baxes = _batch_axes_entry(mesh, bsz)
    token_sds = I.decode_token_input(shape)

    params_sds = jax.eval_shape(bundle.init, jax.random.PRNGKey(0))
    raw_param_specs = bundle.param_specs("model")
    raw_cache_specs = bundle.cache_specs(baxes, "model")
    if opt_idle_batch and baxes is None:
        raw_cache_specs, raw_param_specs = _optimize_idle_batch_specs(
            raw_cache_specs, raw_param_specs, mesh
        )
    param_specs, dropped = sanitize_specs(raw_param_specs, params_sds, mesh)
    if cfg.is_enc_dec:
        cache_sds = jax.eval_shape(
            lambda: bundle.init_cache(bsz, shape.seq_len, mem_len=shape.seq_len // 4)
        )
    else:
        cache_sds = jax.eval_shape(lambda: bundle.init_cache(bsz, shape.seq_len))
    cache_specs, dropped2 = sanitize_specs(raw_cache_specs, cache_sds, mesh)

    fn = jax.jit(
        bundle.decode,
        in_shardings=(
            to_shardings(param_specs, mesh),
            NamedSharding(mesh, P(baxes)),
            to_shardings(cache_specs, mesh),
        ),
        out_shardings=None,
        donate_argnums=(2,) if donate else (),
    )
    return StepSpec(
        name="decode",
        fn=fn,
        args=(params_sds, token_sds, cache_sds),
        notes={
            "batch_axes": baxes,
            "opt_idle_batch": opt_idle_batch,
            "dropped_shardings": dropped + dropped2,
        },
    )
