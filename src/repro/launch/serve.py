"""Personalized-fleet serving driver (DESIGN.md §15): delta-multiplexed
continuous-batched decode under simulated traffic.

Serves a *fleet* of per-agent personalized models — a trained federated
checkpoint (``--ckpt`` / ``--ckpt-dir``, e.g. one written by
``examples/train_federated_lm.py`` or :func:`repro.serve.export_fleet`) or a
synthetic stand-in fleet (``--agents``) — as shared base weights plus compact
per-agent deltas, and drives a reproducible Poisson/bursty request trace
through the continuous batcher.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-8b --reduced \
        --agents 64 --requests 32 --arrival poisson:rate=4 --slots 4

    PYTHONPATH=src python -m repro.launch.serve \
        --ckpt-dir artifacts/ckpt --delta topk:f=0.05,q8 --requests 16

``--arch`` is optional with a checkpoint whose manifest carries the model
config (``examples/train_federated_lm.py`` writes it): the bundle is rebuilt
from the checkpoint alone.
"""
from __future__ import annotations

import argparse

import jax

from repro.configs import ARCH_IDS, get_config, get_reduced
from repro.models import config_from_dict, get_bundle
from repro.serve import (
    ArrivalProcess,
    ContinuousBatcher,
    DecodeEngine,
    DeltaSpec,
    FleetDelta,
    StepCosts,
    make_requests,
    materialize_fleet,
    run_load,
)

_INIT_TAG = 0x1217  # parameter-init stream; sampling uses batcher's own tag


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=list(ARCH_IDS), default=None)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--ckpt", default=None, help="fleet/state checkpoint file")
    ap.add_argument(
        "--ckpt-dir", default=None, help="directory; serves latest_checkpoint"
    )
    ap.add_argument(
        "--agents", type=int, default=16,
        help="synthetic fleet size when no checkpoint is given",
    )
    ap.add_argument(
        "--delta", default="topk:f=0.05",
        help="delta format for checkpoint fleets (synthetic fleets are "
        "always lossless top-k): dense | topk[:f=F][,q8] | lowrank[:r=R]",
    )
    ap.add_argument(
        "--dense-baseline", action="store_true",
        help="serve n dense copies instead of deltas (memory baseline)",
    )
    ap.add_argument(
        "--materialize", choices=("admit", "step"), default="admit",
        help="apply deltas once at admission, or inside every decode step",
    )
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--arrival", default="poisson:rate=2")
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument(
        "--fixed-costs", default=None, metavar="PREFILL_S,DECODE_S",
        help="deterministic per-op costs instead of measured engine time",
    )
    ap.add_argument(
        "--trace-out", default=None,
        help="write a Chrome/Perfetto trace of the session (per-agent "
        "tracks of queue→prefill→decode request spans; open the JSON "
        "at ui.perfetto.dev)",
    )
    ap.add_argument(
        "--metrics-out", default=None,
        help="append the session's metrics-registry snapshot (request/"
        "token counters, latency gauges, per-slot occupancy) as one "
        "line of this JSONL file",
    )
    args = ap.parse_args(argv)

    path = args.ckpt
    if path is None and args.ckpt_dir:
        from repro.checkpoint import latest_checkpoint

        path = latest_checkpoint(args.ckpt_dir)
        if path is None:
            raise SystemExit(f"no checkpoint found in {args.ckpt_dir!r}")

    if args.arch is not None:
        cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    elif path is not None:
        from repro.checkpoint import read_manifest

        meta = read_manifest(path).get("metadata", {})
        if "model" not in meta:
            raise SystemExit(
                f"{path!r} has no model config in its manifest — pass --arch"
            )
        cfg = config_from_dict(meta["model"])
    else:
        raise SystemExit("pass --arch (synthetic fleet) or a checkpoint")
    bundle = get_bundle(cfg)
    # Domain-separated streams: init must never share a key with sampling
    # (the batcher folds its own _SAMPLE_TAG off the same seed).
    init_key = jax.random.fold_in(jax.random.PRNGKey(args.seed), _INIT_TAG)

    spec = DeltaSpec.parse(args.delta)
    if path is not None:
        fleet = FleetDelta.from_checkpoint(path, spec)
        print(f"fleet: {path} ({fleet.n_agents} agents, delta={spec.name})")
    else:
        base = bundle.init(init_key)
        fleet = FleetDelta.synthetic(base, args.agents, seed=args.seed)
        print(
            f"fleet: synthetic ({fleet.n_agents} agents, "
            f"delta={fleet.spec.name})"
        )

    ratio = fleet.naive_nbytes() / max(fleet.nbytes(), 1)
    print(
        f"fleet memory: {fleet.nbytes()/2**20:.2f} MiB delta vs "
        f"{fleet.naive_nbytes()/2**20:.2f} MiB naive dense ({ratio:.1f}x)"
    )
    served = materialize_fleet(fleet) if args.dense_baseline else fleet

    max_seq = args.prompt_len + args.gen + 8
    engine = DecodeEngine(
        bundle, served, n_slots=args.slots, max_seq=max_seq,
        materialize=args.materialize,
    )
    batcher = ContinuousBatcher(
        engine, temperature=args.temperature, seed=args.seed
    )
    requests = make_requests(
        ArrivalProcess.parse(args.arrival), args.requests,
        n_agents=fleet.n_agents, vocab_size=cfg.vocab_size,
        prompt_len=args.prompt_len, max_new_tokens=args.gen, seed=args.seed,
    )
    costs = None
    if args.fixed_costs:
        pre, dec = (float(v) for v in args.fixed_costs.split(","))
        costs = StepCosts(prefill_s=pre, decode_s=dec)

    recorder = None
    if args.trace_out:
        from repro.obs import TraceRecorder

        recorder = TraceRecorder(meta={
            "kind": "serve", "arch": cfg.name, "n_agents": fleet.n_agents,
            "n_slots": args.slots, "arrival": args.arrival,
        })

    report = run_load(batcher, requests, costs=costs, recorder=recorder)
    if args.trace_out:
        from repro.obs import write_trace

        write_trace(args.trace_out, recorder)
        print(f"trace written to {args.trace_out} (open at ui.perfetto.dev)")
    if args.metrics_out:
        report.telemetry(meta={
            "kind": "serve", "arch": cfg.name, "arrival": args.arrival,
        }).write_jsonl(args.metrics_out)
        print(f"metrics appended to {args.metrics_out}")
    print(
        f"arch={cfg.name} slots={args.slots} arrival={args.arrival} "
        f"materialize={args.materialize}"
        + (" dense-baseline" if args.dense_baseline else "")
    )
    print(
        f"served {len(report.requests)} requests, "
        f"{report.total_tokens} tokens in {report.makespan_s:.3f} s "
        f"-> {report.tokens_per_s:.1f} tok/s"
    )
    print(
        f"latency p50={report.p50_s*1e3:.1f} ms p99={report.p99_s*1e3:.1f} ms "
        f"(mean queue={report.mean('queue_wait_s')*1e3:.1f} "
        f"prefill={report.mean('prefill_s')*1e3:.1f} "
        f"decode={report.mean('decode_s')*1e3:.1f})"
    )
    for r in sorted(report.requests, key=lambda r: r.rid)[:4]:
        print(
            f"  req{r.rid} agent={r.agent_id} tokens={r.tokens[:8]}"
            + ("..." if len(r.tokens) > 8 else "")
        )
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
