"""Batched serving driver: prefill a batch of prompts, then decode greedily.

Runs the same prefill/decode step functions the dry-run lowers; on the CPU
container use --reduced.

    PYTHONPATH=src python -m repro.launch.serve --arch mixtral-8x7b --reduced \
        --batch 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_config, get_reduced
from repro.models import get_bundle


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=list(ARCH_IDS), required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args(argv)

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    bundle = get_bundle(cfg)
    key = jax.random.PRNGKey(args.seed)
    params = bundle.init(key)
    max_seq = args.prompt_len + args.gen + 8

    rng = np.random.default_rng(args.seed)
    tokens = jnp.asarray(
        rng.integers(0, cfg.vocab_size, size=(args.batch, args.prompt_len)),
        jnp.int32,
    )
    batch = {"tokens": tokens}
    if cfg.is_enc_dec:
        batch["frames"] = jnp.asarray(
            rng.normal(size=(args.batch, args.prompt_len // 4, cfg.d_model)).astype(
                np.float32
            )
        ).astype(jnp.dtype(cfg.dtype))
        cache = bundle.init_cache(args.batch, max_seq, mem_len=args.prompt_len // 4)
    else:
        cache = bundle.init_cache(args.batch, max_seq)
    if cfg.modality == "vlm":
        n_patch = max(1, args.prompt_len // 8)
        batch["prefix_embeds"] = jnp.asarray(
            rng.normal(size=(args.batch, n_patch, cfg.d_model)).astype(np.float32)
        ).astype(jnp.dtype(cfg.dtype))
        from repro.models.rope import mrope_text_positions

        batch["positions"] = mrope_text_positions(
            args.batch, args.prompt_len + n_patch
        )

    prefill = jax.jit(bundle.prefill)
    decode = jax.jit(bundle.decode)

    t0 = time.perf_counter()
    logits, cache = prefill(params, batch, cache)
    logits.block_until_ready()
    t_prefill = time.perf_counter() - t0

    out_tokens = []
    tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    t1 = time.perf_counter()
    for i in range(args.gen):
        out_tokens.append(np.asarray(tok)[:, 0])
        logits, cache = decode(params, tok, cache)
        if args.temperature > 0:
            key, sub = jax.random.split(key)
            tok = jax.random.categorical(
                sub, logits[:, -1] / args.temperature, axis=-1
            )[:, None].astype(jnp.int32)
        else:
            tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    jax.block_until_ready(tok)
    t_decode = time.perf_counter() - t1

    gen = np.stack(out_tokens, axis=1)
    print(f"arch={cfg.name} batch={args.batch} prompt={args.prompt_len} gen={args.gen}")
    print(f"prefill: {t_prefill*1e3:.1f} ms   decode: {t_decode/args.gen*1e3:.2f} ms/tok")
    for b in range(min(2, args.batch)):
        print(f"  seq{b}: {gen[b][:12].tolist()}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
