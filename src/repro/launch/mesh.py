"""Production mesh definitions (TPU v5e pods).

Defined as functions, not module-level constants, so importing this module
never touches JAX device state (the dry-run must set XLA_FLAGS first).
"""
from __future__ import annotations

from typing import Tuple

import jax

from repro.utils.compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    """Single pod: (data=16, model=16) = 256 chips.
    Multi-pod:  (pod=2, data=16, model=16) = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_debug_mesh(shape: Tuple[int, ...] = (1, 1), axes=("data", "model")):
    """A 1x1 mesh over the single CPU device (used by unit tests)."""
    return make_mesh(shape, axes)


def agent_axes_for(mesh: jax.sharding.Mesh, mode: str = "flat"):
    """Which mesh axes form the PISCO agent axis.

    flat:          all non-model axes (16 agents single pod / 32 multi-pod)
    hierarchical:  the 'pod' axis only (beyond-paper mode, DESIGN.md §6)
    """
    names = list(mesh.axis_names)
    if mode == "hierarchical":
        assert "pod" in names, "hierarchical mode needs a pod axis"
        return ("pod",)
    return tuple(n for n in names if n != "model")


def n_agents_for(mesh: jax.sharding.Mesh, mode: str = "flat") -> int:
    axes = agent_axes_for(mesh, mode)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n
