"""PartitionSpec utilities: stacking the agent axis onto model specs and
sanitizing specs against actual shapes + mesh divisibility.

The model modules declare *intent* (shard heads over "model", d_ff over
"model", ...); not every architecture dimension divides every mesh axis
(e.g. Qwen2-VL's 12 heads on a 16-way model axis, Mamba2's 50280 vocab).
``sanitize_specs`` drops the axis name on any dim the mesh cannot divide —
replicate rather than fail, and report what was dropped.
"""
from __future__ import annotations

from typing import Any, List, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

PyTree = Any


def _is_spec(x) -> bool:
    return isinstance(x, P)


def stack_spec_tree(spec_tree: PyTree, agent_axes) -> PyTree:
    """Prefix every leaf spec with the agent axis (leading stacked dim)."""
    axes = tuple(agent_axes)
    entry = axes if len(axes) > 1 else axes[0]
    return jax.tree.map(
        lambda s: P(entry, *s), spec_tree, is_leaf=_is_spec
    )


def _axis_product(mesh, entry) -> int:
    if entry is None:
        return 1
    if isinstance(entry, (tuple, list)):
        prod = 1
        for a in entry:
            prod *= mesh.shape[a]
        return prod
    return mesh.shape[entry]


def sanitize_specs(
    spec_tree: PyTree, shape_tree: PyTree, mesh
) -> Tuple[PyTree, List[str]]:
    """Drop non-divisible axis entries; returns (fixed_specs, report)."""
    report: List[str] = []

    def fix(path, spec, shaped):
        if spec is None:
            return P()
        shape = shaped.shape
        entries = list(spec) + [None] * (len(shape) - len(spec))
        entries = entries[: len(shape)]
        fixed = []
        for dim, entry in zip(shape, entries):
            size = _axis_product(mesh, entry)
            if entry is not None and dim % size != 0:
                report.append(
                    f"{jax.tree_util.keystr(path)}: dim {dim} % {entry}({size}) != 0 -> replicated"
                )
                fixed.append(None)
            else:
                fixed.append(entry)
        while fixed and fixed[-1] is None:
            fixed.pop()
        return P(*fixed)

    fixed = jax.tree_util.tree_map_with_path(
        fix, spec_tree, shape_tree, is_leaf=lambda x: _is_spec(x) or x is None
    )
    return fixed, report


def to_shardings(spec_tree: PyTree, mesh) -> PyTree:
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree, is_leaf=_is_spec
    )


def add_fsdp_axis(
    spec_tree: PyTree, shape_tree: PyTree, mesh, axis: str = "data",
    *, skip_leading: int = 0, min_dim: int = 1024,
) -> PyTree:
    """Greedy FSDP: shard the first unsharded dim divisible by ``axis`` on
    every leaf (used by the hierarchical pod-as-agent mode so each agent's
    replica spreads over the intra-pod data axis instead of replicating)."""
    size = mesh.shape[axis]

    def fix(spec, shaped):
        shape = shaped.shape
        entries = list(spec) + [None] * (len(shape) - len(spec))
        entries = entries[: len(shape)]
        for i in range(skip_leading, len(shape)):
            if entries[i] is None and shape[i] >= min_dim and shape[i] % size == 0:
                entries[i] = axis
                break
        while entries and entries[-1] is None:
            entries.pop()
        return P(*entries)

    return jax.tree.map(
        fix, spec_tree, shape_tree, is_leaf=lambda x: _is_spec(x) or x is None
    )


def shard_bytes(shape_tree: PyTree, spec_tree: PyTree, mesh) -> int:
    """Per-device bytes of a sharded pytree (logical, no padding)."""
    total = 0
    for shaped, spec in zip(
        jax.tree.leaves(shape_tree),
        jax.tree.leaves(spec_tree, is_leaf=_is_spec),
    ):
        n = int(np.prod(shaped.shape)) if shaped.shape else 1
        denom = 1
        for entry in spec:
            denom *= _axis_product(mesh, entry)
        total += (n // max(1, denom)) * shaped.dtype.itemsize
    return total
