"""End-to-end PISCO training driver for the LM architectures.

Runs on whatever devices exist (the CPU container trains the reduced configs;
on a real pod the same code paths drive the production mesh — the step
functions are the ones the dry-run compiles).

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-8b --reduced \
        --rounds 50 --t-o 4 --p 0.1 --batch 8 --seq 128

The host loop is the paper's line 8: a Bernoulli(p) draw per round picks the
pre-compiled gossip or global round function.
"""
from __future__ import annotations

import argparse
import contextlib
import json
import os
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import latest_checkpoint, restore_checkpoint, save_checkpoint
from repro.configs import ARCH_IDS, get_config, get_reduced
from repro.core.algorithms import get_algorithm, registered_algorithms
from repro.core.compression import make_byte_model
from repro.core.driver import (
    dynamic_round_fns,
    make_block_fn,
    predraw_schedule,
    record_flags,
    sample_block,
)
from repro.core.adversary import (
    make_adversarial_mixing,
    parse_adversary_spec,
    unwrap_network,
)
from repro.core.experiment import Experiment, ExperimentSpec
from repro.core.mixing import make_network_mixing
from repro.core.pisco import PiscoConfig, replicate_params
from repro.core.trainer import History
from repro.core.mixing import make_sparse_network_mixing
from repro.core.topology import make_sparse_topology, make_topology
from repro.optim.update_rules import RULE_NAMES, resolve_update_rules
from repro.data.synthetic import synthetic_lm_tokens
from repro.models import get_bundle
from repro.models.rope import mrope_text_positions
from repro.sim import PROFILE_NAMES, make_time_model, tune


def make_lm_sampler(cfg, n_agents: int, batch: int, seq: int, t_o: int, seed: int = 0):
    """Per-round sampler producing (local_batches, comm_batch) of LM batches.

    Heterogeneity: each agent's token stream uses a different Zipf shuffle —
    the LM analogue of the paper's sorted-label partition."""
    streams = [
        synthetic_lm_tokens(200_000, cfg.vocab_size, seed=seed + 17 * i)
        for i in range(n_agents)
    ]
    rng = np.random.default_rng(seed + 999)

    def batch_for(agent: int, b: int):
        s = streams[agent]
        starts = rng.integers(0, len(s) - seq - 1, size=b)
        toks = np.stack([s[st : st + seq] for st in starts])
        return toks

    def per_round(_k: int):
        def stacked(n_sets):
            toks = np.stack(
                [
                    np.stack([batch_for(a, batch) for a in range(n_agents)])
                    for _ in range(n_sets)
                ]
            )  # (n_sets, A, b, seq)
            return toks

        all_toks = stacked(t_o + 1)
        extra = {}
        local = {"tokens": jnp.asarray(all_toks[:t_o]), **extra}
        comm = {"tokens": jnp.asarray(all_toks[-1]), **extra}
        if cfg.modality == "vlm":
            n_patch = max(1, seq // 8)
            d = cfg.d_model
            local["prefix_embeds"] = jnp.asarray(
                rng.normal(size=(t_o, n_agents, batch, n_patch, d)).astype(np.float32)
            ).astype(jnp.dtype(cfg.dtype))
            comm["prefix_embeds"] = local["prefix_embeds"][0]
            pos = np.asarray(mrope_text_positions(batch, seq + n_patch))
            local["positions"] = jnp.asarray(
                np.broadcast_to(pos[None, None], (t_o, n_agents) + pos.shape).copy()
            )
            comm["positions"] = local["positions"][0]
        if cfg.is_enc_dec:
            t_frames = max(1, seq // 4)
            local["frames"] = jnp.asarray(
                rng.normal(size=(t_o, n_agents, batch, t_frames, cfg.d_model)).astype(
                    np.float32
                )
            ).astype(jnp.dtype(cfg.dtype))
            comm["frames"] = local["frames"][0]
        return local, comm

    return per_round


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=list(ARCH_IDS), required=True)
    ap.add_argument("--reduced", action="store_true", help="use the smoke-size config")
    ap.add_argument("--rounds", type=int, default=50)
    ap.add_argument("--n-agents", type=int, default=4)
    ap.add_argument("--t-o", type=int, default=2)
    ap.add_argument("--p", type=float, default=0.1)
    ap.add_argument("--eta-l", type=float, default=0.05)
    ap.add_argument("--eta-c", type=float, default=1.0)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--topology", default="ring")
    ap.add_argument("--network", default=None,
                    help="dynamic-topology process: static | bernoulli[:q] | "
                         "matching | roundrobin[:n] | cohort[:frac] "
                         "(default: frozen base W)")
    ap.add_argument("--participation", type=float, default=1.0,
                    help="fraction of agents sampled into each server round")
    ap.add_argument("--sparse", action="store_true",
                    help="edge-list/CSR mixing (segment_sum gossip, "
                         "O(n+m) state) — required for large fleets; "
                         "default dense n x n (auto-selected by "
                         "ExperimentSpec above 512 agents)")
    ap.add_argument("--cohort", type=float, default=None,
                    help="neighbor-sampled cohorts: fraction of agents "
                         "seeding each gossip round (sugar for "
                         "--network cohort:FRAC)")
    ap.add_argument("--adversary", default=None,
                    help="Byzantine fault injection (DESIGN.md §14): "
                         "signflip[:f=..,scale=..] | random:f=..,scale=.. | "
                         "collusion:f=..,target=drift — the selected agents "
                         "corrupt their outgoing gossip payloads and server "
                         "uploads (default: none)")
    ap.add_argument("--robust-agg", default="mean",
                    help="server-averaging rule at global rounds: mean "
                         "(default, plain average) | trimmed[:f=..] | "
                         "median | krum[:f=..]")
    ap.add_argument("--systems", default=None,
                    help="simulated systems-cost profile (DESIGN.md §11): "
                         f"{'|'.join(PROFILE_NAMES)} with k=v overrides, e.g. "
                         "'wan-gossip' or 'uniform:latency=0'; prints the "
                         "simulated wall-clock split after training")
    ap.add_argument("--tune", action="store_true",
                    help="instead of training, run the p x tau communication "
                         "autotuner under --systems (default profile: "
                         "uniform) and print the simulated time-to-target "
                         "frontier")
    ap.add_argument("--tune-p", type=float, nargs="+",
                    default=[0.0, 0.05, 0.1, 0.3, 1.0],
                    help="server-probability grid for --tune")
    ap.add_argument("--tune-tau", type=int, nargs="+", default=None,
                    help="local-update (T_o) grid for --tune "
                         "(default: just --t-o)")
    ap.add_argument("--tune-rounds", type=int, default=None,
                    help="round budget per tuner configuration "
                         "(default: --rounds)")
    ap.add_argument("--tune-strategy", default="halving",
                    choices=["grid", "halving"],
                    help="sweep every config fully, or successive-halving")
    ap.add_argument("--algo", default="pisco", choices=list(registered_algorithms()))
    ap.add_argument("--local-opt", default=None,
                    help="pluggable local update rule (DESIGN.md §10): "
                         f"{'|'.join(RULE_NAMES)} with k=v args, e.g. "
                         "'momentum:beta=0.9' or 'clip:1.0|adam' "
                         "(default: the bit-exact hardcoded-SGD path)")
    ap.add_argument("--server-opt", default=None,
                    help="FedOpt server rule at global-averaging rounds: "
                         "fedavgm | fedadam | sgd:lr=... | momentum | adam")
    ap.add_argument("--lr-schedule", default=None,
                    help="per-round local-LR decay: linear[:final=..] | "
                         "cosine[:final=..] | warmup_cosine[:warmup=..]")
    ap.add_argument("--opt-policy", default=None,
                    choices=["mix", "keep", "reset"],
                    help="what happens to agent-stacked optimizer buffers at "
                         "communication rounds (default: registry entry's)")
    ap.add_argument("--driver", default="scan",
                    choices=["scan", "loop", "events"],
                    help="scan: chunked on-device lax.scan; loop: legacy host "
                         "loop; events: async event-queue over --systems "
                         "(repro.events, DESIGN.md §13)")
    ap.add_argument("--async", dest="async_spec", default=None,
                    help="async aggregation rule for --driver events: "
                         "'<rule>[:k=v,...]' over constant|poly|buffer with "
                         "keys alpha/bound/buffer, e.g. "
                         "'poly:alpha=0.5,bound=2,buffer=4'")
    ap.add_argument("--staleness-bound", type=int, default=None,
                    help="gossip staleness bound B (events driver): agents "
                         "more than B rounds behind the front are dropped "
                         "from their neighbors' mixes until the next server "
                         "reset")
    ap.add_argument("--buffer-size", type=int, default=None,
                    help="server buffer size m (events driver): a global "
                         "round fires at the m-th participant push instead "
                         "of waiting for the straggler tail")
    ap.add_argument("--block-size", type=int, default=16,
                    help="rounds per scan block (scan driver)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=5)
    ap.add_argument("--trace-out", default=None,
                    help="write a Chrome/Perfetto trace of the run "
                         "(per-round spans with byte/sim-second attribution; "
                         "open the JSON at ui.perfetto.dev)")
    ap.add_argument("--metrics-out", default=None,
                    help="append the run's metrics-registry snapshot "
                         "(rounds/bytes/sim-seconds counters + histograms) "
                         "as one line of this JSONL file")
    ap.add_argument("--profile", default=None, metavar="DIR",
                    help="capture a jax.profiler trace of training into DIR "
                         "(open in TensorBoard's profile plugin)")
    args = ap.parse_args(argv)

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    bundle = get_bundle(cfg)
    pcfg = PiscoConfig(
        n_agents=args.n_agents, t_o=args.t_o, eta_l=args.eta_l,
        eta_c=args.eta_c, p=args.p, seed=args.seed,
    )
    if args.cohort is not None and args.network is not None:
        ap.error("--cohort is sugar for --network cohort:FRAC; pass one, not both")
    network = (
        f"cohort:{args.cohort:g}" if args.cohort is not None else args.network
    )
    if args.sparse:
        topo = make_sparse_topology(args.topology, args.n_agents)
        mixing = make_sparse_network_mixing(
            topo, network, args.participation, seed=args.seed
        )
    else:
        topo = make_topology(args.topology, args.n_agents)
        mixing = make_network_mixing(
            topo, network, args.participation, seed=args.seed
        )
    # fault injection + robust server rule compose as a mixing wrapper, the
    # same way ExperimentSpec.make_mixing layers them (before compression)
    mixing = make_adversarial_mixing(
        mixing, args.adversary, args.robust_agg,
        n_agents=args.n_agents, seed=args.seed,
    )
    lam = "n/a" if topo.lambda_w is None else f"{topo.lambda_w:.4f}"
    print(f"arch={cfg.name} params~{cfg.param_count():,} agents={args.n_agents} "
          f"topology={'sparse/' if args.sparse else ''}{args.topology} "
          f"network={network or 'frozen'} "
          f"participation={args.participation:g} lambda_w={lam} "
          f"p={args.p}")
    if args.adversary is not None or args.robust_agg != "mean":
        adv = (
            parse_adversary_spec(args.adversary, args.n_agents, args.seed)
            if args.adversary is not None else None
        )
        print(f"adversary={args.adversary or 'none'}"
              + (f" ({adv.n_byz}/{args.n_agents} Byzantine)" if adv else "")
              + f" robust_agg={args.robust_agg}")

    sampler = make_lm_sampler(cfg, args.n_agents, args.batch, args.seq, args.t_o, args.seed)
    key = jax.random.PRNGKey(args.seed)
    params = bundle.init(key)
    x0 = replicate_params(params, args.n_agents)

    async_spec = args.async_spec
    if args.staleness_bound is not None or args.buffer_size is not None:
        from repro.events.staleness import AsyncConfig, parse_async_spec
        import dataclasses as _dc

        acfg = parse_async_spec(async_spec) if async_spec else AsyncConfig()
        if args.staleness_bound is not None:
            acfg = _dc.replace(acfg, bound=args.staleness_bound)
        if args.buffer_size is not None:
            acfg = _dc.replace(acfg, buffer=args.buffer_size)
        async_spec = acfg.spec()
    if async_spec is not None and args.driver != "events":
        ap.error("--async/--staleness-bound/--buffer-size need --driver events")
    if args.driver == "events" and not args.systems:
        ap.error("--driver events needs --systems (the event clock is drawn "
                 "from the fleet profile)")

    # Declarative twin of this CLI invocation — what the sim cost model and
    # the autotuner price (network/participation/systems draws are pure
    # functions of this spec).
    spec = ExperimentSpec.create(
        algo=args.algo, n_agents=args.n_agents, t_o=args.t_o,
        eta_l=args.eta_l, eta_c=args.eta_c, p=args.p, seed=args.seed,
        topology=args.topology, network=args.network,
        sparse=args.sparse or None, cohort=args.cohort,
        participation=args.participation,
        systems=args.systems or ("uniform" if args.tune else None),
        async_=async_spec,
        adversary=args.adversary, robust_agg=args.robust_agg,
        optimizer=args.local_opt, server_optimizer=args.server_opt,
        lr_schedule=args.lr_schedule, opt_policy=args.opt_policy,
        rounds=args.rounds, driver=args.driver, block_size=args.block_size,
    )
    if args.tune:
        result = tune(
            spec,
            dict(
                loss_fn=bundle.loss, params0=params,
                sampler_factory=lambda s: make_lm_sampler(
                    cfg, args.n_agents, args.batch, args.seq,
                    s.config.t_o, args.seed,
                ),
            ),
            p_grid=args.tune_p,
            tau_grid=tuple(args.tune_tau) if args.tune_tau else (None,),
            rounds=args.tune_rounds,
            strategy=args.tune_strategy,
        )
        print(f"tuner ({result.strategy}) under {result.systems!r}: "
              f"target smoothed loss {result.target_loss:.4f}")
        print(f"{'p':>6} {'T_o':>4} {'rounds':>6} {'sim s->target':>13} "
              f"{'total sim s':>11} {'final loss':>10}")
        for pt in result.points:
            tts = (
                f"{pt.time_to_target_s:13.2f}"
                if pt.time_to_target_s is not None else f"{'---':>13}"
            )
            print(f"{pt.p:6.2f} {pt.t_o:4d} {pt.rounds_run:6d} {tts} "
                  f"{pt.total_sim_time_s:11.2f} {pt.final_loss:10.4f}")
        print(f"fastest-to-target: p={result.best.p:g} T_o={result.best.t_o}")
        return 0

    recorder = None
    if args.trace_out:
        from repro.obs import TraceRecorder

        recorder = TraceRecorder(meta={
            "kind": "train", "arch": cfg.name, "algo": args.algo,
            "driver": args.driver, "n_agents": args.n_agents,
            "rounds": args.rounds, "systems": args.systems,
        })

    def write_telemetry(hist) -> None:
        if args.trace_out:
            from repro.obs import write_trace

            write_trace(args.trace_out, recorder)
            print(f"trace written to {args.trace_out} (open at ui.perfetto.dev)")
        if args.metrics_out:
            hist.telemetry(meta=dict(recorder.meta) if recorder else {
                "kind": "train", "arch": cfg.name, "algo": args.algo,
                "driver": args.driver,
            }).write_jsonl(args.metrics_out)
            print(f"metrics appended to {args.metrics_out}")

    if args.driver == "events":
        if args.ckpt_dir:
            ap.error("checkpointing is not supported with --driver events")
        from repro.obs import profile_capture

        with profile_capture(args.profile):
            hist = Experiment(
                spec, loss_fn=bundle.loss, params0=params, sampler=sampler,
                recorder=recorder,
            ).run()
        srv = np.asarray(hist.is_global, dtype=bool)
        secs = np.asarray(hist.sim_time_s, dtype=np.float64)
        stale = np.asarray(hist.staleness, dtype=np.int64)
        for k in range(0, args.rounds, max(1, args.log_every)):
            print(f"round {k:4d} [{'J' if hist.is_global[k] else 'W'}] "
                  f"loss={hist.loss[k]:.4f} sim_t={secs[: k + 1].sum():.2f}s "
                  f"max_staleness={int(stale[k].max())}")
        print(
            f"done (events, async={spec.async_ or 'constant'}): "
            f"{args.rounds} rounds, simulated {secs.sum():.2f}s under "
            f"{args.systems!r} (gossip {secs[~srv].sum():.2f}s / "
            f"{int((~srv).sum())} rounds, server {secs[srv].sum():.2f}s / "
            f"{int(srv.sum())} rounds, peak staleness {int(stale.max())})"
        )
        write_telemetry(hist)
        return 0

    start_round = 0
    ckpt_tree = None
    if args.ckpt_dir:
        latest = latest_checkpoint(args.ckpt_dir)
        if latest:
            start_round, ckpt_tree = restore_checkpoint(latest)
            print(f"restored {latest} at round {start_round}")

    opt_kw = resolve_update_rules(
        args.local_opt, args.server_opt, args.lr_schedule, args.opt_policy,
        eta_l=args.eta_l, rounds=args.rounds, t_o=args.t_o,
    )
    if opt_kw:
        lo, so = opt_kw.get("local_opt"), opt_kw.get("server_opt")
        print(f"update rules: local={lo.name if lo else 'sgd (default)'} "
              f"server={so.name if so else 'none'} "
              f"policy={opt_kw.get('opt_policy', 'registry default')}")
    bound = get_algorithm(args.algo).bind(bundle.loss, pcfg, mixing, **opt_kw)
    # The launcher funnels flag/byte/second recording through the same
    # History + record_flags seam the Experiment drivers use, so telemetry
    # (--trace-out / --metrics-out) threads uniformly.
    hist = History(
        byte_model=make_byte_model(
            mixing, x0, args.n_agents,
            mixes_per_round=bound.comm.mixes_per_round,
            server_payloads=bound.comm.server_payloads,
        )
    )
    if args.systems:
        hist.time_model = make_time_model(
            spec, hist.byte_model, network=unwrap_network(bound.network)
        )
    hist.recorder = recorder
    acct = hist.accountant

    local0, comm0 = sampler(-1)
    state = bound.init(bundle.loss, x0, comm0)
    if ckpt_tree is not None:
        # the checkpoint stores namedtuples as plain tuples; pour its leaves
        # back into the freshly-initialized state's structure (which also
        # validates that the bound algorithm/optimizer matches the snapshot)
        treedef = jax.tree.structure(state)
        leaves = jax.tree.leaves(ckpt_tree)
        if len(leaves) != treedef.num_leaves:
            raise ValueError(
                f"checkpoint has {len(leaves)} leaves but the bound "
                f"algorithm state needs {treedef.num_leaves} — was it saved "
                f"with different --algo/--local-opt/--server-opt settings?"
            )
        state = jax.tree.unflatten(
            treedef, [jnp.asarray(leaf) for leaf in leaves]
        )
    from repro.obs import profile_capture

    t0 = time.perf_counter()
    _prof = contextlib.ExitStack()
    _prof.enter_context(profile_capture(args.profile))
    net = bound.network
    if args.driver == "loop":
        if net is not None:
            gossip_fn, global_fn = dynamic_round_fns(bound)
        else:
            gossip_fn = jax.jit(bound.gossip_round)
            global_fn = (
                jax.jit(bound.global_round)
                if bound.global_round is not bound.gossip_round else gossip_fn
            )
        for k in range(start_round, args.rounds):
            local, comm = sampler(k)
            is_global = bool(bound.schedule(k))
            record_flags(hist, np.array([is_global]), start=k)
            fn = global_fn if is_global else gossip_fn
            if net is not None:
                w_gossip, w_server, _, _ = net.draw_round(k)
                state, metrics = fn(
                    state, local, comm,
                    jax.tree.map(jnp.asarray, w_gossip),
                    jax.tree.map(jnp.asarray, w_server),
                )
            else:
                state, metrics = fn(state, local, comm)
            if k % args.log_every == 0 or k == args.rounds - 1:
                print(
                    f"round {k:4d} [{'J' if is_global else 'W'}] "
                    f"loss={float(metrics.loss):.4f} "
                    f"|grad|^2={float(metrics.grad_sq_norm):.3e} "
                    f"consensus={float(metrics.consensus_err):.3e}"
                )
            if args.ckpt_dir and args.ckpt_every and (k + 1) % args.ckpt_every == 0:
                save_checkpoint(args.ckpt_dir, k + 1, state)
    else:
        # Scan driver: pre-draw the Bernoulli(p) flags for each block on the
        # host, run the block on-device, sync only at log/checkpoint cuts.
        block_fn = make_block_fn(bound)
        k = start_round
        while k < args.rounds:
            stop = min(k + args.block_size, args.rounds)
            nxt_log = k if k % args.log_every == 0 else (
                (k // args.log_every + 1) * args.log_every
            )
            if nxt_log < args.rounds:
                stop = min(stop, nxt_log + 1)
            if args.ckpt_dir and args.ckpt_every:
                stop = min(stop, (k // args.ckpt_every + 1) * args.ckpt_every)
            flags = predraw_schedule(bound.schedule, k, stop)
            local, comm = sample_block(sampler, k, stop)
            if net is not None:
                w_gossip, w_server, _, _ = net.draw_block(k, stop)
                state, metrics = block_fn(
                    state, jnp.asarray(flags), jax.tree.map(jnp.asarray, w_gossip),
                    jax.tree.map(jnp.asarray, w_server), local, comm,
                )
            else:
                state, metrics = block_fn(state, jnp.asarray(flags), local, comm)
            record_flags(hist, flags, start=k)
            k_end = stop - 1
            if k_end % args.log_every == 0 or k_end == args.rounds - 1:
                print(
                    f"round {k_end:4d} [{'J' if flags[-1] else 'W'}] "
                    f"loss={float(metrics.loss[-1]):.4f} "
                    f"|grad|^2={float(metrics.grad_sq_norm[-1]):.3e} "
                    f"consensus={float(metrics.consensus_err[-1]):.3e}"
                )
            if args.ckpt_dir and args.ckpt_every and stop % args.ckpt_every == 0:
                save_checkpoint(args.ckpt_dir, stop, state)
            k = stop
    _prof.close()
    dt = time.perf_counter() - t0
    hist.wall_time_s = dt
    print(
        f"done: {args.rounds} rounds in {dt:.1f}s "
        f"({acct.agent_to_agent} gossip, {acct.agent_to_server} server rounds)"
    )
    if args.systems:
        # recorded online by record_flags through the attached time model —
        # identical to the old post-hoc price_rounds pass
        secs = np.asarray(hist.sim_time_s, dtype=np.float64)
        srv = np.asarray(hist.is_global, dtype=bool)
        print(
            f"simulated time under {args.systems!r}: {secs.sum():.2f}s "
            f"(gossip {secs[~srv].sum():.2f}s / {int((~srv).sum())} rounds, "
            f"server {secs[srv].sum():.2f}s / {int(srv.sum())} rounds)"
        )
    write_telemetry(hist)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
