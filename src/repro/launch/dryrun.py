import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
).strip()

"""Multi-pod dry-run: lower + compile every (architecture × input shape) on
the production meshes and extract memory / cost / collective analyses.

MUST be run as its own process (it forces 512 host devices before any other
jax usage):

    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-8b --shape train_4k --mesh single
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both --out artifacts/dryrun

Each run writes one JSON artifact per (arch, shape, mesh, step) that
benchmarks/roofline.py aggregates into EXPERIMENTS.md §Dry-run / §Roofline.
"""
import argparse  # noqa: E402
import json  # noqa: E402
import sys  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402

from repro.configs import ARCH_IDS, SHAPES, get_config  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch.steps import (  # noqa: E402
    build_decode_step,
    build_prefill_step,
    build_train_steps,
)
from repro.models import get_bundle  # noqa: E402
from repro.utils.hlo import Roofline, collective_bytes  # noqa: E402

SKIP_LONG_DECODE_NOTE = (
    "long_500k skipped: pure full-attention decode (unbounded KV cache is "
    "not sub-quadratic); see DESIGN.md §4"
)


def applicable(arch: str, shape_name: str) -> bool:
    cfg = get_config(arch)
    if shape_name == "long_500k":
        return cfg.supports_long_decode()
    return True


def run_one(arch: str, shape_name: str, mesh_kind: str, *, t_o: int = 1,
            agent_mode: str = "flat", steps_filter=None,
            wire_dtype: str = "float32", loss_chunk: int = 0,
            remat_policy: str = "full", ssm_chunk: int = 0,
            opt_idle_batch: bool = False) -> list:
    import dataclasses as _dc

    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    cfg = get_config(arch)
    if loss_chunk:
        cfg = _dc.replace(cfg, loss_chunk=loss_chunk)
    if remat_policy != "full":
        cfg = _dc.replace(cfg, remat_policy=remat_policy)
    if ssm_chunk and cfg.ssm is not None:
        cfg = _dc.replace(cfg, ssm=_dc.replace(cfg.ssm, chunk=ssm_chunk))
    bundle = get_bundle(cfg)
    n_chips = mesh.size

    if shape.kind == "train":
        steps = build_train_steps(
            bundle, shape, mesh, t_o=t_o, agent_mode=agent_mode,
            wire_dtype=wire_dtype,
        )
    elif shape.kind == "prefill":
        steps = {"prefill": build_prefill_step(bundle, shape, mesh)}
    else:
        steps = {"decode": build_decode_step(
            bundle, shape, mesh, opt_idle_batch=opt_idle_batch)}

    results = []
    for name, spec in steps.items():
        if steps_filter and name not in steps_filter:
            continue
        rec = {
            "arch": arch,
            "shape": shape_name,
            "mesh": mesh_kind,
            "n_chips": n_chips,
            "step": name,
            "agent_mode": agent_mode,
            "t_o": t_o,
            "variant": {
                "wire_dtype": wire_dtype, "loss_chunk": loss_chunk,
                "remat_policy": remat_policy, "ssm_chunk": ssm_chunk,
                "opt_idle_batch": opt_idle_batch,
            },
            "notes": _json_safe(spec.notes),
        }
        t0 = time.perf_counter()
        try:
            lowered = spec.lower()
            rec["lower_s"] = time.perf_counter() - t0
            t1 = time.perf_counter()
            compiled = lowered.compile()
            rec["compile_s"] = time.perf_counter() - t1

            ma = compiled.memory_analysis()
            # older jaxlib has no peak_memory_in_bytes; args+outputs+temp
            # (minus donated/aliased buffers) is the upper-bound proxy there
            peak = getattr(ma, "peak_memory_in_bytes", None)
            if peak is None:
                peak = (
                    ma.argument_size_in_bytes
                    + ma.output_size_in_bytes
                    + ma.temp_size_in_bytes
                    - ma.alias_size_in_bytes
                )
            rec["memory"] = {
                "argument_bytes": int(ma.argument_size_in_bytes),
                "output_bytes": int(ma.output_size_in_bytes),
                "temp_bytes": int(ma.temp_size_in_bytes),
                "peak_bytes": int(peak),
                "alias_bytes": int(ma.alias_size_in_bytes),
            }
            ca = compiled.cost_analysis() or {}
            if isinstance(ca, (list, tuple)):  # older jaxlib: one dict per device
                ca = ca[0] if ca else {}
            rec["cost"] = {
                "flops": float(ca.get("flops", 0.0)),
                "bytes_accessed": float(ca.get("bytes accessed", 0.0)),
                "transcendentals": float(ca.get("transcendentals", 0.0)),
            }
            hlo = compiled.as_text()
            rec["collectives"] = collective_bytes(hlo)
            rec["hlo_lines"] = hlo.count("\n")

            model_flops = _model_flops(cfg, shape, name, t_o)
            roof = Roofline.from_counts(
                rec["cost"]["flops"],
                rec["cost"]["bytes_accessed"],
                float(rec["collectives"]["total"]),
                model_flops=model_flops,
                n_chips=n_chips,
            )
            rec["roofline"] = roof.to_dict()
            rec["status"] = "ok"
        except Exception as e:  # noqa: BLE001 — record the failure, keep going
            rec["status"] = "error"
            rec["error"] = f"{type(e).__name__}: {e}"
            rec["traceback"] = traceback.format_exc()[-4000:]
        results.append(rec)
    return results


def _json_safe(obj):
    if isinstance(obj, dict):
        return {str(k): _json_safe(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_json_safe(v) for v in obj]
    if hasattr(obj, "item"):
        return obj.item()
    return obj


def _model_flops(cfg, shape, step_name: str, t_o: int) -> float:
    """MODEL_FLOPS = 6·N·D (dense) / 6·N_active·D (MoE), whole step.

    Train rounds run t_o + 1 gradient evaluations (forward+backward = 3× fwd);
    prefill is one forward (2·N·D); decode is one token (D = batch)."""
    n_active = cfg.active_param_count()
    if step_name.startswith("train"):
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens * (t_o + 1)
    if step_name == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    # decode: one token per sequence
    return 2.0 * n_active * shape.global_batch


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=list(ARCH_IDS) + ["qwen3-8b-swa"])
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="single")
    ap.add_argument("--all", action="store_true", help="run every applicable pair")
    ap.add_argument("--t-o", type=int, default=1)
    ap.add_argument("--agent-mode", choices=["flat", "hierarchical"], default="flat")
    ap.add_argument("--steps", nargs="*", default=None,
                    help="subset of step names (train_gossip train_global ...)")
    ap.add_argument("--wire-dtype", default="float32",
                    choices=["float32", "native"],
                    help="gossip ppermute payload dtype (Perf lever)")
    ap.add_argument("--loss-chunk", type=int, default=0,
                    help=">0: chunked CE loss (Perf lever)")
    ap.add_argument("--remat-policy", default="full", choices=["full", "dots"])
    ap.add_argument("--ssm-chunk", type=int, default=0,
                    help="override SSD chunk length (Perf lever)")
    ap.add_argument("--opt-idle-batch", action="store_true",
                    help="batch-1 decode: seq/expert-shard over the idle data axis")
    ap.add_argument("--tag", default="", help="artifact filename suffix")
    ap.add_argument("--out", default="artifacts/dryrun")
    args = ap.parse_args(argv)

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    if args.all:
        pairs = [
            (a, s) for a in ARCH_IDS for s in SHAPES if applicable(a, s)
        ]
    else:
        assert args.arch and args.shape, "--arch/--shape or --all required"
        pairs = [(args.arch, args.shape)]

    os.makedirs(args.out, exist_ok=True)
    n_fail = 0
    for arch, shape_name in pairs:
        if not applicable(arch, shape_name):
            print(f"SKIP {arch} x {shape_name}: {SKIP_LONG_DECODE_NOTE}")
            continue
        for mesh_kind in meshes:
            for rec in run_one(
                arch, shape_name, mesh_kind,
                t_o=args.t_o, agent_mode=args.agent_mode,
                steps_filter=args.steps,
                wire_dtype=args.wire_dtype, loss_chunk=args.loss_chunk,
                remat_policy=args.remat_policy, ssm_chunk=args.ssm_chunk,
                opt_idle_batch=args.opt_idle_batch,
            ):
                tag = f"{arch}__{shape_name}__{mesh_kind}__{rec['step']}"
                if args.agent_mode != "flat":
                    tag += f"__{args.agent_mode}"
                if args.tag:
                    tag += f"__{args.tag}"
                path = os.path.join(args.out, tag + ".json")
                with open(path, "w") as f:
                    json.dump(rec, f, indent=1)
                if rec["status"] == "ok":
                    r = rec["roofline"]
                    print(
                        f"OK   {tag}: compile={rec['compile_s']:.1f}s "
                        f"flops/dev={rec['cost']['flops']:.3e} "
                        f"peak={rec['memory']['peak_bytes']/2**30:.2f}GiB "
                        f"coll={rec['collectives']['total']/2**20:.1f}MiB "
                        f"dominant={r['dominant']}"
                    )
                    # the dry-run contract: print the full analyses
                    print(f"     memory_analysis: {rec['memory']}")
                    print(f"     cost_analysis:   {rec['cost']}")
                    print(f"     collectives:     {rec['collectives']}")
                else:
                    n_fail += 1
                    print(f"FAIL {tag}: {rec['error']}")
                sys.stdout.flush()
    return 1 if n_fail else 0


if __name__ == "__main__":
    raise SystemExit(main())
