import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
).strip()

"""Scan-aware cost correction for the dry-run roofline.

XLA's ``cost_analysis()`` counts a while-loop body ONCE, so the scanned layer
stacks undercount FLOPs / bytes / collective traffic by the trip count.  This
tool compiles two *unrolled* variants of each (arch × shape × step) with
k = 1 and k = 2 scan periods (full width, tiny depth) and extrapolates

    F_true(n_periods) = outside + n_periods · body,
    body = F(2) - F(1),   outside = F(1) - body,

then rewrites the matching artifacts' ``cost_corrected`` / ``roofline``
fields.  Exact for anything affine in the period count — which FLOPs, HBM
bytes and per-layer collectives are.

    PYTHONPATH=src python -m repro.launch.cost_correction --dir artifacts/dryrun --mesh single
"""
import argparse  # noqa: E402
import dataclasses  # noqa: E402
import glob  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402

import jax  # noqa: E402

from repro.configs import SHAPES, get_config  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch.steps import (  # noqa: E402
    build_decode_step,
    build_prefill_step,
    build_train_steps,
)
from repro.models import get_bundle  # noqa: E402
from repro.utils.hlo import COLLECTIVE_KINDS, Roofline, collective_bytes  # noqa: E402

_MESHES = {}


def _mesh(kind):
    if kind not in _MESHES:
        _MESHES[kind] = make_production_mesh(multi_pod=(kind == "multi"))
    return _MESHES[kind]


def _variant_cfg(cfg, k: int):
    """Full-width model with k scan periods, scan fully unrolled."""
    period = cfg.scan_period()
    upd = dict(
        n_layers=cfg.first_k_dense + k * period,
        scan_unroll=True,
    )
    if cfg.is_enc_dec:
        upd["n_encoder_layers"] = k
    return dataclasses.replace(cfg, **upd)


def _measure(cfg, shape, step_name, mesh, rec):
    bundle = get_bundle(cfg)
    if shape.kind == "train":
        variant = rec.get("variant", {})
        steps = build_train_steps(
            bundle, shape, mesh,
            t_o=rec.get("t_o", 1),
            agent_mode=rec.get("agent_mode", "flat"),
            wire_dtype=variant.get("wire_dtype", "float32"),
        )
        spec = steps[step_name]
    elif shape.kind == "prefill":
        spec = build_prefill_step(bundle, shape, mesh)
    else:
        spec = build_decode_step(
            bundle, shape, mesh,
            opt_idle_batch=rec.get("variant", {}).get("opt_idle_batch", False),
        )
    compiled = spec.lower().compile()
    ca = compiled.cost_analysis() or {}
    coll = collective_bytes(compiled.as_text())
    return {
        "flops": float(ca.get("flops", 0.0)),
        "bytes_accessed": float(ca.get("bytes accessed", 0.0)),
        "collective_total": float(coll["total"]),
        "collectives": {k: float(coll[k]) for k in COLLECTIVE_KINDS},
    }


def correct_record(path: str, *, force: bool = False) -> bool:
    with open(path) as f:
        rec = json.load(f)
    if rec.get("status") != "ok":
        return False
    if rec.get("cost_corrected") and not force:
        return False
    base_cfg = get_config(rec["arch"])
    variant = rec.get("variant", {})
    if variant.get("loss_chunk"):
        base_cfg = dataclasses.replace(base_cfg, loss_chunk=variant["loss_chunk"])
    if variant.get("remat_policy") and variant["remat_policy"] != "full":
        base_cfg = dataclasses.replace(base_cfg, remat_policy=variant["remat_policy"])
    if variant.get("ssm_chunk") and base_cfg.ssm is not None:
        base_cfg = dataclasses.replace(
            base_cfg, ssm=dataclasses.replace(base_cfg.ssm, chunk=variant["ssm_chunk"])
        )
    shape = SHAPES[rec["shape"]]
    mesh = _mesh(rec["mesh"])
    period = base_cfg.scan_period()
    n_periods = (base_cfg.n_layers - base_cfg.first_k_dense) // period

    t0 = time.perf_counter()
    f1 = _measure(_variant_cfg(base_cfg, 1), shape, rec["step"], mesh, rec)
    f2 = _measure(_variant_cfg(base_cfg, 2), shape, rec["step"], mesh, rec)

    def extrapolate(key):
        body = f2[key] - f1[key]
        outside = f1[key] - body
        return max(0.0, outside + n_periods * body)

    corrected = {
        "flops": extrapolate("flops"),
        "bytes_accessed": extrapolate("bytes_accessed"),
        "collective_total": extrapolate("collective_total"),
        "n_periods": n_periods,
        "variant_1": f1,
        "variant_2": f2,
        "method": "two-point unrolled extrapolation (see module docstring)",
        "seconds": time.perf_counter() - t0,
    }
    rec["cost_corrected"] = corrected
    roof = Roofline.from_counts(
        corrected["flops"],
        corrected["bytes_accessed"],
        corrected["collective_total"],
        model_flops=rec["roofline"].get("model_flops"),
        n_chips=rec["n_chips"],
    )
    rec["roofline_raw"] = rec["roofline"]
    rec["roofline"] = roof.to_dict()
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    return True


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--dir", default="artifacts/dryrun")
    ap.add_argument("--mesh", default=None, help="only correct this mesh kind")
    ap.add_argument("--glob", default="*.json")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args(argv)

    n = 0
    for path in sorted(glob.glob(os.path.join(args.dir, args.glob))):
        with open(path) as f:
            rec = json.load(f)
        if args.mesh and rec.get("mesh") != args.mesh:
            continue
        try:
            if correct_record(path, force=args.force):
                r = json.load(open(path))["roofline"]
                print(
                    f"corrected {os.path.basename(path)}: "
                    f"flops/dev={r['flops_per_device']:.3e} "
                    f"dominant={r['dominant']} useful={r['useful_ratio'] and round(r['useful_ratio'],3)}"
                )
                n += 1
        except Exception as e:  # noqa: BLE001
            print(f"FAILED {os.path.basename(path)}: {type(e).__name__}: {e}")
    print(f"corrected {n} records")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
