"""ShapeDtypeStruct stand-ins for every model input — weak-type-correct,
shardable, zero device allocation (the dry-run pattern).

Batch conventions (matching the PISCO trainer's contract):
* train:   local_batches leaves (T_o, A, b, ...) + comm_batch leaves (A, b, ...)
           where A = n_agents, b = global_batch // A.
* prefill: batch leaves (B, ...) with B = global_batch.
* decode:  token (B, 1) + cache (from the model bundle's init_cache).

Modality stubs (the one allowed carve-out): audio supplies precomputed frame
embeddings (B, seq//4, d_model); VLM supplies patch embeddings (B, seq//8,
d_model) + M-RoPE position ids (3, B, seq).
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.shapes import InputShape
from repro.models.config import ModelConfig

SDS = jax.ShapeDtypeStruct


def _per_agent_batch(cfg: ModelConfig, b: int, seq: int) -> Dict[str, SDS]:
    """Loss-function batch for ONE agent (leaves (b, ...))."""
    if cfg.is_enc_dec:
        return {
            "frames": SDS((b, seq // 4, cfg.d_model), jnp.dtype(cfg.dtype)),
            "tokens": SDS((b, seq), jnp.int32),
        }
    if cfg.modality == "vlm":
        n_patch = seq // 8
        return {
            "tokens": SDS((b, seq - n_patch), jnp.int32),
            "prefix_embeds": SDS((b, n_patch, cfg.d_model), jnp.dtype(cfg.dtype)),
            "positions": SDS((3, b, seq), jnp.int32),
        }
    return {"tokens": SDS((b, seq), jnp.int32)}


def train_inputs(
    cfg: ModelConfig, shape: InputShape, n_agents: int, t_o: int
) -> Tuple[Any, Any]:
    """(local_batches, comm_batch) ShapeDtypeStruct pytrees."""
    assert shape.kind == "train"
    assert shape.global_batch % n_agents == 0, (
        f"global_batch {shape.global_batch} must divide across {n_agents} agents"
    )
    b = shape.global_batch // n_agents
    per = _per_agent_batch(cfg, b, shape.seq_len)
    comm = jax.tree.map(lambda s: SDS((n_agents,) + s.shape, s.dtype), per)
    local = jax.tree.map(lambda s: SDS((t_o,) + s.shape, s.dtype), comm)
    return local, comm


def prefill_inputs(cfg: ModelConfig, shape: InputShape) -> Dict[str, SDS]:
    assert shape.kind == "prefill"
    return _per_agent_batch(cfg, shape.global_batch, shape.seq_len)


def decode_token_input(shape: InputShape) -> SDS:
    assert shape.kind == "decode"
    return SDS((shape.global_batch, 1), jnp.int32)
