"""Simulated wall-clock pricing of communication rounds (DESIGN.md §11).

The byte ledger (:class:`~repro.core.schedule.RoundByteModel`) says how much
data a round moves; this module says how long the round *takes* under a
:class:`~repro.sim.profiles.SystemsParams` fleet.  The synchronous-round time
model:

* **gossip round** — every agent runs its local steps (the round is gated by
  the slowest agent in the fleet), then each mix moves one compressed message
  per directed realized edge, all edges in parallel — the mix is gated by the
  *slowest realized edge* (latency + bytes/bandwidth), and the protocol's
  ``mixes_per_round`` mixes are sequential (X then Y streams);

* **server round** — the sampled participants run their local steps (gated by
  the straggler tail of the *sample*, not the fleet), then the exchange costs
  one server RTT plus the slowest participant upload and the slowest
  broadcast download of ``server_payloads`` full-precision payloads.

Everything is host-side numpy and pure in ``(spec, round)``: topology /
participation realizations are re-drawn through the same seed-deterministic
processes the drivers use, so a finished :class:`~repro.core.trainer.History`
can be (re)priced under any profile after the fact (:func:`price_history`).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Sequence

import numpy as np

from repro.core.schedule import RoundByteModel
from repro.core.topology import (
    ParticipationProcess,
    TopologyProcess,
    edge_list,
    make_sparse_topology,
    make_topology,
    make_topology_process,
    topology_edges,
    use_sparse_topology,
)
from repro.sim.profiles import SystemsParams, make_profile


@dataclasses.dataclass(frozen=True)
class SystemsModel:
    """A realized fleet + the round-time arithmetic over it."""

    params: SystemsParams
    profile: str = "uniform"  # the spec string this fleet was drawn from

    @property
    def n_agents(self) -> int:
        return self.params.n_agents

    # -- phases -------------------------------------------------------------

    def compute_time(
        self, local_steps: int, agents: Optional[np.ndarray] = None
    ) -> float:
        """Synchronous local-update phase: ``local_steps`` gradient steps,
        gated by the slowest of ``agents`` (default: the whole fleet)."""
        c = self.params.compute_s if agents is None else self.params.compute_s[agents]
        if c.size == 0:
            return 0.0
        return float(local_steps) * float(c.max())

    def gossip_comm_time(
        self, edges: np.ndarray, message_bytes: int, *, mixes: int = 1
    ) -> float:
        """``mixes`` sequential mixes, each gated by the slowest realized
        edge: ``latency_ij + message_bytes / bw_ij``.  No realized edges (or
        a zero-byte message) costs nothing."""
        if len(edges) == 0 or message_bytes <= 0:
            return 0.0
        i, j = edges[:, 0], edges[:, 1]
        per_edge = (
            self.params.link_latency_s[i, j]
            + message_bytes / self.params.link_bw_Bps[i, j]
        )
        return float(mixes) * float(per_edge.max())

    def server_comm_time(
        self, participants: np.ndarray, message_bytes: int, *, payloads: int = 1
    ) -> float:
        """One RTT + slowest participant upload + slowest broadcast download
        of ``payloads`` payloads each way."""
        if len(participants) == 0 or message_bytes <= 0:
            return 0.0
        nbytes = float(payloads) * float(message_bytes)
        up = float((nbytes / self.params.up_bw_Bps[participants]).max())
        down = float((nbytes / self.params.down_bw_Bps[participants]).max())
        return self.params.server_rtt_s + up + down

    # -- whole rounds -------------------------------------------------------

    def gossip_round_time(
        self, edges: np.ndarray, message_bytes: int,
        *, mixes: int = 1, local_steps: int = 1,
    ) -> float:
        return self.compute_time(local_steps) + self.gossip_comm_time(
            edges, message_bytes, mixes=mixes
        )

    def server_round_time(
        self, participants: np.ndarray, message_bytes: int,
        *, payloads: int = 1, local_steps: int = 1,
    ) -> float:
        return self.compute_time(local_steps, participants) + self.server_comm_time(
            participants, message_bytes, payloads=payloads
        )


def make_systems_model(
    systems: str, n_agents: int, *, seed: int = 0
) -> SystemsModel:
    """Realize a profile spec string into a :class:`SystemsModel`."""
    profile = make_profile(systems)
    return SystemsModel(
        params=profile.realize(n_agents, seed=seed), profile=profile.spec()
    )


@dataclasses.dataclass(frozen=True, eq=False)
class RoundTimeModel:
    """Per-round simulated seconds for one experiment — the time analogue of
    :class:`~repro.core.schedule.RoundByteModel`.

    Bundles the fleet with the experiment's wire sizes (from the byte model,
    so compression shortens transfers), the protocol's mix/payload counts,
    and the realized-network processes (pure in ``(seed, k)``) that decide
    *which* edges and participants gate each round.  The drivers call
    :meth:`round_time` as rounds execute; :meth:`price_rounds` re-prices a
    finished flag sequence post-hoc.
    """

    model: SystemsModel
    gossip_message_bytes: int
    server_message_bytes: int
    mixes_per_round: int
    server_payloads: int
    local_steps: int
    base_edges: np.ndarray  # (m, 2) static-topology fallback
    process: Optional[TopologyProcess] = None
    participation: Optional[ParticipationProcess] = None

    @property
    def n_agents(self) -> int:
        return self.model.n_agents

    def edges_at(self, k: int) -> np.ndarray:
        if self.process is not None:
            return self.process.edges_at(k)
        return self.base_edges

    def participants_at(self, k: int) -> np.ndarray:
        if self.participation is not None:
            return self.participation.participants_at(k)
        return np.arange(self.n_agents)

    def round_time(self, k: int, is_global: bool) -> float:
        if is_global:
            return self.model.server_round_time(
                self.participants_at(k),
                self.server_message_bytes,
                payloads=self.server_payloads,
                local_steps=self.local_steps,
            )
        return self.model.gossip_round_time(
            self.edges_at(k),
            self.gossip_message_bytes,
            mixes=self.mixes_per_round,
            local_steps=self.local_steps,
        )

    def round_parts(self, k: int, is_global: bool) -> dict:
        """Phase decomposition of :meth:`round_time` — ``local_steps`` plus
        ``server_sync`` (global) or ``gossip_mix`` (gossip), in execution
        order.  The parts sum to ``round_time(k, is_global)`` exactly (both
        sides are the same two float adds), which the obs layer relies on to
        nest phase spans inside each round span."""
        if is_global:
            parts = self.participants_at(k)
            return {
                "local_steps": self.model.compute_time(self.local_steps, parts),
                "server_sync": self.model.server_comm_time(
                    parts, self.server_message_bytes,
                    payloads=self.server_payloads,
                ),
            }
        return {
            "local_steps": self.model.compute_time(self.local_steps),
            "gossip_mix": self.model.gossip_comm_time(
                self.edges_at(k), self.gossip_message_bytes,
                mixes=self.mixes_per_round,
            ),
        }

    def price_rounds(
        self, is_global: Sequence[bool], *, start: int = 0
    ) -> np.ndarray:
        """Simulated seconds for an executed flag sequence (round ``start``
        onward) — identical to what the drivers would have recorded online."""
        return np.array(
            [self.round_time(start + i, bool(g)) for i, g in enumerate(is_global)],
            dtype=np.float64,
        )


def make_time_model(
    spec: Any,
    byte_model: RoundByteModel,
    *,
    network: Optional[Any] = None,
    systems: Optional[str] = None,
) -> RoundTimeModel:
    """Build the :class:`RoundTimeModel` for an ``ExperimentSpec``.

    ``network`` is the live :class:`~repro.core.mixing.NetworkContext` when
    the caller has one (the Experiment wiring passes ``mixing.network`` so
    online pricing shares the exact process objects the driver draws from);
    without it the processes are re-derived from the spec — bit-identical,
    because every draw is a pure function of ``(seed, k)``.  ``systems``
    overrides ``spec.systems`` (post-hoc repricing under another profile).
    """
    systems = systems if systems is not None else spec.systems
    if systems is None:
        raise ValueError("spec has no systems profile (pass systems=...)")
    n = spec.config.n_agents
    seed = spec.config.seed
    model = make_systems_model(systems, n, seed=seed)

    from repro.core.algorithms import get_algorithm  # local: avoid cycle

    comm = get_algorithm(spec.algo).comm
    local_steps = spec.config.t_o if comm.uses_local_updates else 1

    if network is not None:
        process = network.process
        part = network.participation
        base_edges = topology_edges(process.base)
    else:
        # mirror the spec's dense/sparse selection so large sparse fleets are
        # priced without an O(n^2) adjacency materialization
        if use_sparse_topology(getattr(spec, "sparse", None), n):
            topo = make_sparse_topology(
                spec.topology, n, **dict(spec.topology_kwargs)
            )
        else:
            topo = make_topology(spec.topology, n, **dict(spec.topology_kwargs))
        base_edges = topology_edges(topo)
        net_spec = getattr(spec, "effective_network", spec.network)
        if net_spec is None and spec.participation >= 1.0:
            process, part = None, None  # legacy frozen-W path
        else:
            process = make_topology_process(net_spec, topo, seed=seed)
            part = (
                ParticipationProcess(n, spec.participation, seed=seed)
                if spec.participation < 1.0
                else None
            )
    return RoundTimeModel(
        model=model,
        gossip_message_bytes=byte_model.gossip_message_bytes,
        server_message_bytes=byte_model.server_message_bytes,
        mixes_per_round=byte_model.mixes_per_round,
        server_payloads=byte_model.server_payloads,
        local_steps=local_steps,
        base_edges=base_edges,
        process=process,
        participation=part,
    )


def price_history(
    hist: Any, spec: Any, *, systems: Optional[str] = None
) -> np.ndarray:
    """Per-round simulated seconds for a finished History under ``spec``
    (optionally repriced under another ``systems`` profile).

    Uses the History's own byte model (so compression wire sizes carry over)
    and its executed ``is_global`` flags; network realizations are re-drawn
    pure-in-``(seed, k)``, so this matches the online series exactly.

    Histories produced by the events driver carry a frozen event trace: its
    gating decisions (active edges, buffer cohorts) are part of the executed
    numerics, so repricing replays only the per-agent clock recursion under
    the new fleet (:func:`repro.events.clock.reprice_trace`) — under the
    original profile this reproduces the online ``sim_time_s`` bit-exactly.
    """
    trace = getattr(hist, "event_trace", None)
    if trace is not None:
        # local import: the events subsystem builds on this module
        from repro.events.clock import reprice_trace

        systems = systems if systems is not None else spec.systems
        if systems is None:
            raise ValueError("spec has no systems profile (pass systems=...)")
        model = make_systems_model(
            systems, int(trace["n_agents"]), seed=spec.config.seed
        )
        # the clock recursion is causal, so a full-trace replay sliced to the
        # executed prefix equals replaying the prefix (early stop_when exits)
        return reprice_trace(trace, model)[: len(hist.is_global)]
    if hist.byte_model is None:
        raise ValueError("history has no byte model; was it driven normally?")
    tm = make_time_model(spec, hist.byte_model, systems=systems)
    return tm.price_rounds(hist.is_global)
