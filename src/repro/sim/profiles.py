"""Named systems-heterogeneity profiles (devices + links), seed-deterministic.

A profile answers "what does the hardware under the federation look like?" —
per-agent compute throughput, peer-to-peer link latency/bandwidth, and the
server uplink/downlink path — as *declarative data*: a name plus ``k=v``
overrides, the same string grammar the rest of the repo uses for networks and
update rules.  ``ExperimentSpec.systems`` stores exactly this string.

    "uniform"                                  # homogeneous LAN-ish fleet
    "uniform:latency=0,bw=inf,rtt=0"           # free network: compute-only time
    "lognormal-stragglers"                     # per-agent lognormal compute tail
    "edge-vs-datacenter"                       # two device classes, thin uplinks
    "wan-gossip"                               # p2p links are WAN, server is DC
    "lan-gossip"                               # p2p links are LAN, server is far

Realizations are **pure functions of (profile, n_agents, seed)** — the same
contract as :class:`~repro.core.topology.TopologyProcess` draws — so the loop
driver, the scan driver, and any post-hoc repricing of a finished History see
bit-identical straggler/latency draws.  Everything here is host-side numpy.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Dict, Tuple

import numpy as np

# Domain-separation tag for profile draws, disjoint from the link (0x11AA) and
# participation (0x77EE) tags in repro.core.topology.
_SIM_TAG = 0x51D3

# Parameter vocabulary (all floats; bandwidths in bytes/s, times in seconds):
#   compute        — seconds one agent spends per local gradient step
#   compute_sigma  — lognormal sigma of per-agent compute multipliers
#   latency        — one-way peer link latency
#   latency_sigma  — lognormal sigma of per-link latency multipliers
#   bw             — peer link bandwidth
#   up_bw/down_bw  — per-agent server uplink / downlink bandwidth
#   rtt            — fixed server round-trip overhead per server exchange
PARAM_KEYS = (
    "compute", "compute_sigma", "latency", "latency_sigma",
    "bw", "up_bw", "down_bw", "rtt",
)

_BASE = dict(
    compute=0.01, compute_sigma=0.0, latency=2e-3, latency_sigma=0.0,
    bw=1.25e8, up_bw=1.25e7, down_bw=2.5e7, rtt=0.04,
)

# Named scenarios.  Each is _BASE plus what makes it interesting.
PROFILES: Dict[str, Dict[str, float]] = {
    # homogeneous fleet on a fast local network
    "uniform": dict(_BASE),
    # same fleet, but per-agent compute is lognormal — the classic straggler
    # tail; gossip and server rounds are gated by the slowest realized agent
    "lognormal-stragglers": dict(_BASE, compute_sigma=0.8),
    # two device classes: the first half of the agents are datacenter nodes
    # (8x faster compute, 10x fatter server links, fast DC-DC peering), the
    # second half are edge devices (2x slower compute, thin uplinks)
    "edge-vs-datacenter": dict(_BASE, latency_sigma=0.1),
    # peer links cross the WAN (high latency, thin), the server is a nearby
    # datacenter — gossip rounds are the expensive kind here
    "wan-gossip": dict(
        _BASE, latency=0.08, latency_sigma=0.3, bw=2.5e6,
        up_bw=1.25e8, down_bw=2.5e8, rtt=0.05,
    ),
    # peer links are cheap LAN, the server is far away behind a thin pipe —
    # server rounds are the expensive kind (the paper's motivating regime)
    "lan-gossip": dict(
        _BASE, latency=5e-4, bw=1.25e9, up_bw=2.5e6, down_bw=5e6, rtt=0.3,
    ),
}

PROFILE_NAMES = tuple(sorted(PROFILES))

# The degenerate "network costs nothing" profile: zero latency, infinite
# bandwidth everywhere, no server RTT.  Under it, simulated round time
# reduces *exactly* to the compute phase (local_steps x slowest agent) —
# the reduction the sim acceptance tests pin.
FREE_NETWORK = "uniform:latency=0,bw=inf,up_bw=inf,down_bw=inf,rtt=0"


def parse_systems_spec(spec: str) -> Tuple[str, Dict[str, float]]:
    """Validate ``'name[:k=v,k=v]'`` and return ``(name, overrides)``.

    ``ExperimentSpec`` calls this at construction so typos fail fast."""
    name, _, arg = spec.partition(":")
    if name not in PROFILES:
        raise ValueError(
            f"unknown systems profile {name!r}; options: {PROFILE_NAMES}"
            f" (e.g. 'wan-gossip', 'uniform:latency=0,bw=inf,rtt=0')"
        )
    overrides: Dict[str, float] = {}
    if arg:
        for item in arg.split(","):
            key, eq, val = item.partition("=")
            if not eq or key not in PARAM_KEYS:
                raise ValueError(
                    f"bad systems override {item!r} in {spec!r}; "
                    f"expected k=v with k in {PARAM_KEYS}"
                )
            v = float(val)  # 'inf' parses to float('inf')
            # bandwidths divide the message size: zero/negative would turn
            # the seconds ledger into inf/negative garbage with no error
            if key in ("bw", "up_bw", "down_bw") and not v > 0:
                raise ValueError(
                    f"systems override {item!r} in {spec!r}: "
                    f"bandwidths must be positive (inf allowed)"
                )
            if v < 0 or (key not in ("bw", "up_bw", "down_bw") and np.isinf(v)):
                raise ValueError(
                    f"systems override {item!r} in {spec!r}: "
                    f"{key} must be finite and >= 0"
                )
            overrides[key] = v
    return name, overrides


@dataclasses.dataclass(frozen=True)
class SystemsParams:
    """One realized fleet: per-agent / per-link quantities (host numpy).

    ``link_latency_s`` / ``link_bw_Bps`` are symmetric (n, n) matrices over
    *all* pairs — which edges actually carry traffic in a round is the
    topology process's business, not the profile's.
    """

    compute_s: np.ndarray  # (n,) seconds per local gradient step
    link_latency_s: np.ndarray  # (n, n) one-way peer latency
    link_bw_Bps: np.ndarray  # (n, n) peer bandwidth
    up_bw_Bps: np.ndarray  # (n,) server uplink
    down_bw_Bps: np.ndarray  # (n,) server downlink
    server_rtt_s: float

    @property
    def n_agents(self) -> int:
        return int(self.compute_s.shape[0])

    def to_dict(self) -> dict:
        def enc(a):
            # inf survives JSON as the string "inf" (json.dumps would emit
            # the non-portable bare Infinity token)
            return np.where(np.isinf(a), None, a).tolist()

        return {
            "compute_s": self.compute_s.tolist(),
            "link_latency_s": self.link_latency_s.tolist(),
            "link_bw_Bps": enc(self.link_bw_Bps),
            "up_bw_Bps": enc(self.up_bw_Bps),
            "down_bw_Bps": enc(self.down_bw_Bps),
            "server_rtt_s": float(self.server_rtt_s),
        }

    @classmethod
    def from_dict(cls, d: dict) -> "SystemsParams":
        def dec(v):
            a = np.array(
                [[np.inf if x is None else x for x in row] for row in v]
                if v and isinstance(v[0], list)
                else [np.inf if x is None else x for x in v],
                dtype=np.float64,
            )
            return a

        return cls(
            compute_s=np.asarray(d["compute_s"], dtype=np.float64),
            link_latency_s=np.asarray(d["link_latency_s"], dtype=np.float64),
            link_bw_Bps=dec(d["link_bw_Bps"]),
            up_bw_Bps=dec(d["up_bw_Bps"]),
            down_bw_Bps=dec(d["down_bw_Bps"]),
            server_rtt_s=float(d["server_rtt_s"]),
        )


@dataclasses.dataclass(frozen=True)
class Profile:
    """A named scenario + overrides; :meth:`realize` draws one fleet."""

    name: str
    overrides: Tuple[Tuple[str, float], ...] = ()

    def __post_init__(self):
        if self.name not in PROFILES:
            raise ValueError(
                f"unknown systems profile {self.name!r}; options: {PROFILE_NAMES}"
            )
        if isinstance(self.overrides, dict):
            object.__setattr__(
                self, "overrides", tuple(sorted(self.overrides.items()))
            )

    # -- serialization ------------------------------------------------------

    def spec(self) -> str:
        """Round-trippable string form (``parse_systems_spec`` inverse)."""
        if not self.overrides:
            return self.name
        kv = ",".join(f"{k}={v:g}" for k, v in self.overrides)
        return f"{self.name}:{kv}"

    def to_dict(self) -> dict:
        return {"name": self.name, "overrides": dict(self.overrides)}

    @classmethod
    def from_dict(cls, d: dict) -> "Profile":
        return cls(name=d["name"], overrides=tuple(sorted(d.get("overrides", {}).items())))

    def to_json(self) -> str:
        return json.dumps(self.to_dict(), sort_keys=True)

    @classmethod
    def from_json(cls, s: str) -> "Profile":
        return cls.from_dict(json.loads(s))

    # -- realization --------------------------------------------------------

    def params(self) -> Dict[str, float]:
        base = dict(PROFILES[self.name])
        base.update(dict(self.overrides))
        return base

    def realize(self, n_agents: int, *, seed: int = 0) -> SystemsParams:
        """Draw one fleet — a pure function of ``(self, n_agents, seed)``.

        Draw order is fixed (compute multipliers, then link-latency
        multipliers) so realizations are reproducible across drivers and
        across partial consumers.
        """
        p = self.params()
        n = int(n_agents)
        rng = np.random.default_rng((_SIM_TAG, int(seed)))

        compute = np.full(n, p["compute"], dtype=np.float64)
        if p["compute_sigma"] > 0:
            compute = compute * rng.lognormal(
                mean=-0.5 * p["compute_sigma"] ** 2,  # E[mult] = 1
                sigma=p["compute_sigma"], size=n,
            )

        latency = np.full((n, n), p["latency"], dtype=np.float64)
        if p["latency_sigma"] > 0:
            mult = rng.lognormal(
                mean=-0.5 * p["latency_sigma"] ** 2,
                sigma=p["latency_sigma"], size=(n, n),
            )
            mult = np.triu(mult, k=1)
            latency = latency * (mult + mult.T + np.eye(n))

        bw = np.full((n, n), p["bw"], dtype=np.float64)
        up = np.full(n, p["up_bw"], dtype=np.float64)
        down = np.full(n, p["down_bw"], dtype=np.float64)

        if self.name == "edge-vs-datacenter":
            # first half datacenter, second half edge (deterministic split)
            dc = np.arange(n) < (n + 1) // 2
            compute = np.where(dc, compute / 8.0, compute * 2.0)
            up = np.where(dc, up * 10.0, up / 10.0)
            down = np.where(dc, down * 10.0, down / 10.0)
            dc_pair = np.outer(dc, dc)
            bw = np.where(dc_pair, bw * 10.0, bw)
            latency = np.where(dc_pair, latency / 4.0, latency)

        np.fill_diagonal(latency, 0.0)
        return SystemsParams(
            compute_s=compute,
            link_latency_s=latency,
            link_bw_Bps=bw,
            up_bw_Bps=up,
            down_bw_Bps=down,
            server_rtt_s=float(p["rtt"]),
        )


def make_profile(spec: str) -> Profile:
    """Parse ``'name[:k=v,...]'`` into a :class:`Profile`."""
    name, overrides = parse_systems_spec(spec)
    return Profile(name=name, overrides=tuple(sorted(overrides.items())))
