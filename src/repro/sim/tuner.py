"""The p/τ communication autotuner (paper §5's trade-off, operationalized).

The paper's central dial is *how often to pay for the server*: gossip rounds
are cheap but numerous, server rounds expensive but few, and which mixture is
fastest depends on the systems costs — not just on bytes.  ``tune`` sweeps a
``p × τ`` grid of :class:`~repro.core.experiment.ExperimentSpec` variants
under a :mod:`~repro.sim.profiles` systems profile and reports the simulated
**time-to-target-loss frontier**: for every configuration, the simulated
seconds (and rounds, and bytes) until the trailing-window-smoothed training
loss first crosses the target.

Two strategies:

* ``"grid"``    — run every configuration for the full round budget;
* ``"halving"`` — successive halving: run everything for a small budget,
  keep the better half by current loss, double the budget, repeat.  Each
  rung re-runs survivors from round 0 (cheap at these scales and keeps every
  run a pure function of its spec).

Because simulated time is priced post-hoc from pure ``(seed, k)`` draws,
:func:`retime` re-prices a finished tuning run under a *different* profile
without re-training — the cheap way to ask "and if the gossip links were WAN?"
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.events.staleness import parse_async_spec, with_staleness_bound
from repro.sim.costmodel import price_history


def _smoothed(values: Sequence[float], window: int) -> np.ndarray:
    """Trailing moving average over ``window`` rounds — tracks the current
    loss level (unlike the all-history running mean, which is dominated by
    the early rounds and would declare every configuration 'at target'
    almost immediately)."""
    v = np.asarray(values, dtype=np.float64)
    if window <= 1 or v.size == 0:
        return v
    c = np.concatenate([[0.0], np.cumsum(v)])
    idx = np.arange(v.size) + 1
    lo = np.maximum(idx - window, 0)
    return (c[idx] - c[lo]) / (idx - lo)


def _auto_window(budget: int) -> int:
    return max(1, min(20, budget // 10))


@dataclasses.dataclass
class TunePoint:
    """One ``(p, τ[, staleness bound])`` configuration's frontier readout."""

    p: float
    t_o: int
    rounds_run: int
    final_loss: float
    total_sim_time_s: float
    time_to_target_s: Optional[float] = None
    rounds_to_target: Optional[int] = None
    bytes_to_target: Optional[int] = None
    # gossip staleness bound B (events driver only; None elsewhere)
    staleness_bound: Optional[int] = None
    # runtime attachments (excluded from to_dict)
    spec: Any = None
    history: Any = None

    def to_dict(self) -> dict:
        return {
            "p": self.p,
            "t_o": self.t_o,
            "staleness_bound": self.staleness_bound,
            "rounds_run": self.rounds_run,
            "final_loss": self.final_loss,
            "total_sim_time_s": self.total_sim_time_s,
            "time_to_target_s": self.time_to_target_s,
            "rounds_to_target": self.rounds_to_target,
            "bytes_to_target": self.bytes_to_target,
        }


@dataclasses.dataclass
class TunerResult:
    """All points, sorted fastest-to-target first."""

    points: List[TunePoint]
    target_loss: float
    systems: str
    strategy: str
    window: int = 1  # trailing-mean smoothing the target was judged on

    def __post_init__(self):
        self.points.sort(key=_point_order)

    @property
    def best(self) -> TunePoint:
        return self.points[0]

    def ranking(self) -> List[Tuple[float, int]]:
        """``(p, t_o)`` pairs, fastest simulated time-to-target first
        (configurations that never reached the target rank last, by loss)."""
        return [(pt.p, pt.t_o) for pt in self.points]

    def to_dict(self) -> dict:
        return {
            "systems": self.systems,
            "strategy": self.strategy,
            "target_loss": self.target_loss,
            "window": self.window,
            "best": self.best.to_dict() if self.points else None,
            "ranking": [[p, t] for p, t in self.ranking()],
            "points": [pt.to_dict() for pt in self.points],
        }


def _point_order(pt: TunePoint):
    reached = pt.time_to_target_s is not None
    return (
        0 if reached else 1,
        pt.time_to_target_s if reached else math.inf,
        pt.final_loss,
    )


def _readout(
    hist, spec, target_loss: float, seconds: np.ndarray, window: int
) -> TunePoint:
    series = _smoothed(hist.loss, window)
    cum_s = np.cumsum(seconds)
    cum_b = np.cumsum(hist.accountant.per_round_bytes)
    hits = np.nonzero(series <= target_loss)[0]
    async_spec = getattr(spec, "async_", None)
    pt = TunePoint(
        p=float(spec.config.p),
        t_o=int(spec.config.t_o),
        staleness_bound=(
            parse_async_spec(async_spec).bound if async_spec else None
        ),
        rounds_run=len(hist.loss),
        final_loss=float(series[-1]),
        total_sim_time_s=float(cum_s[-1]) if cum_s.size else 0.0,
        spec=spec,
        history=hist,
    )
    if hits.size:
        r = int(hits[0])
        pt.time_to_target_s = float(cum_s[r])
        pt.rounds_to_target = r + 1
        pt.bytes_to_target = int(cum_b[r])
    return pt


def tune(
    spec: Any,
    pieces: Dict[str, Any],
    *,
    p_grid: Sequence[float],
    tau_grid: Sequence[Optional[int]] = (None,),
    staleness_grid: Sequence[Optional[int]] = (None,),
    systems: Optional[str] = None,
    target_loss: Optional[float] = None,
    rounds: Optional[int] = None,
    strategy: str = "grid",
    min_rounds: int = 8,
    window: Optional[int] = None,
) -> TunerResult:
    """Sweep ``p_grid × tau_grid × staleness_grid`` variants of ``spec`` and
    rank them by simulated time-to-target-loss.

    ``pieces`` are the :class:`~repro.core.experiment.Experiment` runtime
    kwargs (``loss_fn``, ``params0``/``x0``, and a ``sampler_factory`` —
    required when ``tau_grid`` varies ``t_o``, since samplers are built per
    spec).  The loss trajectory is smoothed with a trailing ``window``-round
    mean (auto: ``min(20, budget // 10)``); ``target_loss=None`` auto-selects
    1.05× the best final smoothed loss across the sweep, so the frontier is
    populated for at least the winning configuration.

    ``staleness_grid`` is the third tuned axis (events driver only): each
    entry is a gossip staleness bound B substituted into the spec's
    ``async_`` config via :func:`~repro.events.staleness.with_staleness_bound`
    — the async analogue of tuning p.  The default ``(None,)`` leaves the
    spec's async config untouched, so sync sweeps are unchanged.
    """
    from repro.core.experiment import Experiment  # local: avoid import cycle

    if strategy not in ("grid", "halving"):
        raise ValueError(f"strategy {strategy!r} not in ('grid', 'halving')")
    systems = systems if systems is not None else spec.systems
    if systems is None:
        raise ValueError("tune() needs a systems profile (systems=... or spec.systems)")
    tunes_staleness = tuple(staleness_grid) != (None,)
    if tunes_staleness and spec.driver != "events":
        raise ValueError(
            "staleness_grid tunes the events driver's gossip bound; "
            f"spec.driver is {spec.driver!r} (want 'events')"
        )
    budget = int(rounds if rounds is not None else spec.rounds)
    window = _auto_window(budget) if window is None else max(1, int(window))

    configs = [
        (float(p), tau, b)
        for p in p_grid for tau in tau_grid for b in staleness_grid
    ]
    if not configs:
        raise ValueError("empty p_grid x tau_grid x staleness_grid")

    def spec_for(p: float, tau: Optional[int], b: Optional[int], r: int):
        kw: Dict[str, Any] = {"systems": systems, "p": p, "rounds": r}
        if tau is not None:
            kw["t_o"] = int(tau)
        if tunes_staleness:
            kw["async_"] = with_staleness_bound(
                getattr(spec, "async_", None), b
            )
        return spec.replace(**kw)

    def run(p: float, tau: Optional[int], b: Optional[int], r: int):
        s = spec_for(p, tau, b, r)
        return s, Experiment(s, **pieces).run()

    results: Dict[Tuple[float, Optional[int], Optional[int]], Tuple[Any, Any]] = {}
    if strategy == "grid":
        for cfg in configs:
            results[cfg] = run(*cfg, budget)
    else:
        survivors = list(configs)
        r = min(max(1, int(min_rounds)), budget)
        while True:
            for cfg in survivors:
                results[cfg] = run(*cfg, r)
            if r >= budget:
                break
            survivors.sort(
                key=lambda cfg: float(
                    _smoothed(results[cfg][1].loss, window)[-1]
                )
            )
            survivors = survivors[: max(1, math.ceil(len(survivors) / 2))]
            r = min(2 * r, budget)

    if target_loss is None:
        target_loss = 1.05 * min(
            float(_smoothed(h.loss, window)[-1]) for _, h in results.values()
        )

    points = [
        _readout(
            h, s, target_loss,
            np.asarray(h.sim_time_s, dtype=np.float64), window,
        )
        for s, h in results.values()
    ]
    return TunerResult(
        points=points, target_loss=float(target_loss),
        systems=systems, strategy=strategy, window=window,
    )


def retime(
    result: TunerResult, systems: str, *, target_loss: Optional[float] = None
) -> TunerResult:
    """Re-price a finished tuning run under another profile — no re-training.

    Keeps the original target loss by default (it is a statement about the
    optimization trajectory, which repricing does not change); pass
    ``target_loss`` to move the target too, e.g. to compare profiles at a
    threshold every configuration reaches.
    """
    target = result.target_loss if target_loss is None else float(target_loss)
    points = []
    for pt in result.points:
        seconds = price_history(pt.history, pt.spec, systems=systems)
        points.append(_readout(pt.history, pt.spec, target, seconds, result.window))
    return TunerResult(
        points=points, target_loss=target,
        systems=systems, strategy=result.strategy, window=result.window,
    )
