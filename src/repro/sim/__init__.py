"""Simulated systems costs: wall-clock network model, heterogeneity profiles,
and the p/τ communication autotuner (DESIGN.md §11).

The byte accountant answers "how much moved?"; this package answers "how long
did it take?" under a declarative fleet — per-agent compute, peer link
latency/bandwidth, server uplink/downlink — so experiments can be ranked by
simulated time-to-target instead of rounds or bytes.
"""
from repro.sim.costmodel import (
    RoundTimeModel,
    SystemsModel,
    make_systems_model,
    make_time_model,
    price_history,
)
from repro.sim.profiles import (
    FREE_NETWORK,
    PROFILE_NAMES,
    PROFILES,
    Profile,
    SystemsParams,
    make_profile,
    parse_systems_spec,
)
from repro.sim.tuner import TunePoint, TunerResult, retime, tune

__all__ = [
    "FREE_NETWORK", "PROFILE_NAMES", "PROFILES", "Profile", "SystemsParams",
    "make_profile", "parse_systems_spec", "RoundTimeModel", "SystemsModel",
    "make_systems_model", "make_time_model", "price_history",
    "TunePoint", "TunerResult", "retime", "tune",
]
