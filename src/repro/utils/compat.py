"""JAX version compatibility shims.

The code targets the current jax API (``jax.shard_map`` with ``check_vma``,
``jax.make_mesh`` with ``axis_types``); the pinned container ships an older
jax where shard_map lives in ``jax.experimental`` (``check_rep``) and meshes
are built from a device array.  These two helpers pick whichever spelling the
installed jax supports, so both the library and the subprocess-based
distributed tests run on either version.
"""
from __future__ import annotations

import math
from typing import Sequence, Tuple

import jax
import numpy as np


def shard_map(f, *, mesh, in_specs, out_specs):
    """``jax.shard_map`` with replication checking off, on any jax version."""
    if hasattr(jax, "shard_map"):
        try:
            return jax.shard_map(
                f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                check_vma=False,
            )
        except TypeError:  # pre-check_vma spelling
            return jax.shard_map(
                f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                check_rep=False,
            )
    from jax.experimental.shard_map import shard_map as _sm

    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_rep=False)


def make_mesh(shape: Tuple[int, ...], axes: Sequence[str]) -> jax.sharding.Mesh:
    """Auto-axis mesh over the first prod(shape) devices, on any jax version."""
    axes = tuple(axes)
    if hasattr(jax, "make_mesh") and hasattr(jax.sharding, "AxisType"):
        return jax.make_mesh(
            shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
        )
    n = math.prod(shape)
    devices = np.asarray(jax.devices()[:n]).reshape(shape)
    return jax.sharding.Mesh(devices, axes)
