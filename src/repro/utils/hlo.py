"""Compiled-HLO analysis: collective traffic + roofline terms.

``compiled.as_text()`` is the SPMD-partitioned module of one device, so every
byte count extracted here is *per device per step* — matching
``cost_analysis()``'s per-device FLOPs.  Collective bytes use each collective
op's RESULT shape (the received payload), summed per category; ``*-start``
ops are counted, their ``*-done`` halves are not (same buffer).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e3m4": 1, "f8e4m3b11fnuz": 1,
}

COLLECTIVE_KINDS = (
    "all-reduce",
    "all-gather",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
# `%name = <result-shape> op-name(' — result shape may be a (tuple, of, shapes)
_OP_RE = re.compile(
    r"=\s+(\([^)]*\)|[\w\[\],{}:#* ]+?)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?(?:\.\d+)?\("
)


def shape_bytes(shape_text: str) -> int:
    """Bytes of an HLO shape string (handles tuples by summing)."""
    total = 0
    for m in _SHAPE_RE.finditer(shape_text):
        dtype, dims = m.group(1), m.group(2)
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(\([^)]*\)|[\w\[\],{}]+)\s+([\w\-]+)\(([^)]*)\)"
)
_OPERAND_RE = re.compile(r"%([\w.\-]+)")


def _instr_table(hlo_text: str) -> Dict[str, tuple]:
    """name -> (shape_text, op_name, [operand names])."""
    table: Dict[str, tuple] = {}
    for line in hlo_text.splitlines():
        m = _INSTR_RE.match(line)
        if m:
            name, shape, op, operands = m.groups()
            table[name] = (shape, op, _OPERAND_RE.findall(operands))
    return table


def _wire_corrected_bytes(shape_text: str, operands, table) -> int:
    """CPU-backend float normalization upcasts bf16 collectives to f32 (a
    host-only artifact — TPUs move bf16 natively).  When every operand of a
    collective is a convert/convert-fusion from a narrower source, count the
    payload at the SOURCE dtype (what the TPU wire would carry)."""
    raw = shape_bytes(shape_text)
    src_bytes = 0
    for op_name in operands:
        entry = table.get(op_name)
        if entry is None:
            return raw
        shape, op, inner = entry
        if "convert" in op_name or op == "convert":
            # source dtype = the convert's own operand dtype
            if inner and inner[0] in table:
                src_shape = table[inner[0]][0]
                src_bytes += shape_bytes(src_shape)
                continue
        return raw
    return src_bytes if 0 < src_bytes < raw else raw


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-category result bytes of all collectives in a partitioned module.

    Two figures per category: raw result bytes, and ``wire_*`` corrected for
    the CPU float-normalization artifact (see :func:`_wire_corrected_bytes`).
    ``total`` uses the corrected figures (what a TPU would move)."""
    out: Dict[str, int] = {k: 0 for k in COLLECTIVE_KINDS}
    wire: Dict[str, int] = {k: 0 for k in COLLECTIVE_KINDS}
    counts: Dict[str, int] = {k: 0 for k in COLLECTIVE_KINDS}
    table = _instr_table(hlo_text)
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        shape_text, kind = m.group(1), m.group(2)
        im = _INSTR_RE.match(line)
        operands = im.group(4) if im else ""
        out[kind] += shape_bytes(shape_text)
        wire[kind] += _wire_corrected_bytes(
            shape_text, _OPERAND_RE.findall(operands), table
        )
        counts[kind] += 1
    out_counts = {f"n_{k}": v for k, v in counts.items()}
    out_wire = {f"wire_{k}": v for k, v in wire.items()}
    return {
        **out,
        **out_wire,
        **out_counts,
        "raw_total": sum(out[k] for k in COLLECTIVE_KINDS),
        "total": sum(wire[k] for k in COLLECTIVE_KINDS),
    }


# ---------------------------------------------------------------------------
# Roofline (TPU v5e constants per the assignment)
# ---------------------------------------------------------------------------

PEAK_FLOPS_BF16 = 197e12  # per chip
HBM_BW = 819e9  # bytes/s per chip
ICI_BW = 50e9  # bytes/s per link


@dataclasses.dataclass
class Roofline:
    """All quantities per device per step; terms in seconds."""

    flops_per_device: float
    hbm_bytes_per_device: float
    collective_bytes_per_device: float
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops: Optional[float] = None  # 6·N·D (active N for MoE), whole step
    useful_ratio: Optional[float] = None  # model_flops / (flops_per_device · chips)

    @classmethod
    def from_counts(
        cls,
        flops_per_device: float,
        hbm_bytes: float,
        coll_bytes: float,
        *,
        model_flops: Optional[float] = None,
        n_chips: int = 1,
    ) -> "Roofline":
        compute_s = flops_per_device / PEAK_FLOPS_BF16
        memory_s = hbm_bytes / HBM_BW
        collective_s = coll_bytes / ICI_BW
        terms = {
            "compute": compute_s,
            "memory": memory_s,
            "collective": collective_s,
        }
        dominant = max(terms, key=terms.get)
        ratio = None
        if model_flops is not None and flops_per_device > 0:
            ratio = model_flops / (flops_per_device * n_chips)
        return cls(
            flops_per_device=flops_per_device,
            hbm_bytes_per_device=hbm_bytes,
            collective_bytes_per_device=coll_bytes,
            compute_s=compute_s,
            memory_s=memory_s,
            collective_s=collective_s,
            dominant=dominant,
            model_flops=model_flops,
            useful_ratio=ratio,
        )

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)
