"""Pytree arithmetic helpers used throughout the PISCO core.

All PISCO state (model estimates ``X``, tracking variables ``Y``, last local
gradients ``G``) lives in *agent-stacked pytrees*: every leaf carries a leading
axis of size ``n_agents``.  These helpers implement the (small) linear algebra
Algorithm 1 needs on such trees without materializing flattened vectors.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def tree_stack(trees):
    """Stack a list of identically-structured pytrees along a new axis 0."""
    return jax.tree.map(lambda *xs: jnp.stack(xs, axis=0), *trees)


def tree_unstack(tree, n):
    """Inverse of :func:`tree_stack`: split leading axis into ``n`` trees."""
    return [jax.tree.map(lambda x, i=i: x[i], tree) for i in range(n)]


def tree_zeros_like(tree):
    return jax.tree.map(jnp.zeros_like, tree)


def tree_add(a, b):
    return jax.tree.map(jnp.add, a, b)


def tree_sub(a, b):
    return jax.tree.map(jnp.subtract, a, b)


def tree_scale(a, s):
    return jax.tree.map(lambda x: x * s, a)


def tree_axpy(alpha, x, y):
    """alpha * x + y, leafwise."""
    return jax.tree.map(lambda xi, yi: alpha * xi + yi, x, y)


def tree_dot(a, b):
    """Sum of elementwise products across all leaves (a scalar)."""
    leaves = jax.tree.map(lambda x, y: jnp.sum(x * y), a, b)
    return jax.tree.reduce(jnp.add, leaves)


def tree_sq_norm(a):
    return tree_dot(a, a)


def tree_agent_mean(tree):
    """Mean over the leading (agent) axis, broadcast back to the same shape.

    This is exactly the ``X J`` operation of the paper (J = (1/n) 11^T).
    """
    return jax.tree.map(
        lambda x: jnp.broadcast_to(jnp.mean(x, axis=0, keepdims=True), x.shape), tree
    )


def tree_agent_mix(tree, w):
    """Apply a mixing matrix ``w`` (n, n) along the leading agent axis.

    Computes, per leaf ``x`` of shape (n, ...):   out_i = sum_j w_ij x_j,
    i.e. the compact-form ``X W^T``... note the paper writes states as columns
    (``X in R^{d x n}``, update ``X W``); with our leading-agent-axis layout the
    equivalent contraction is ``einsum('ij,j...->i...', W^T, x)``.  Since all
    mixing matrices here are symmetric and doubly stochastic, ``W^T = W``;
    we still transpose to stay correct for any future asymmetric matrix.
    """
    wt = jnp.asarray(w).T

    def mix(x):
        return jnp.tensordot(wt, x, axes=((1,), (0,))).astype(x.dtype)

    return jax.tree.map(mix, tree)


def tree_agent_mix_sparse(tree, senders, receivers, edge_w, self_w, n_agents):
    """Sparse gossip over directed edges — the edge-list form of
    :func:`tree_agent_mix` without ever materializing W.

    Per leaf ``x`` of shape (n, ...):

        out_i = self_w[i] * x_i + sum_{e : senders[e] -> i} edge_w[e] * x_{senders[e]}

    via a gather + ``jax.ops.segment_sum`` scatter-accumulate.  For a
    symmetric realization the directed arrays are the two orientations of
    each undirected edge with the weight duplicated; per-round edge dropout
    is expressed as zeros in ``edge_w`` (fixed shapes, so scan can thread
    the weights as operands).  Accumulates in float32, like the dense path.
    """

    def mix(x):
        xf = x.astype(jnp.float32)
        extra = (1,) * (x.ndim - 1)
        contrib = edge_w.reshape(edge_w.shape + extra) * xf[senders]
        acc = jax.ops.segment_sum(contrib, receivers, num_segments=n_agents)
        return (self_w.reshape(self_w.shape + extra) * xf + acc).astype(x.dtype)

    return jax.tree.map(mix, tree)


def tree_agent_masked_mean(tree, mask):
    """Sampled-to-sampled server round in O(n): participants (``mask`` 1.0)
    average among themselves, absentees hold.  Equals applying the dense
    doubly stochastic S_k of ``ParticipationProcess.server_matrix_at``."""

    def leaf(x):
        xf = x.astype(jnp.float32)
        m = mask.reshape(mask.shape + (1,) * (x.ndim - 1))
        total = jnp.sum(m * xf, axis=0, keepdims=True)
        count = jnp.maximum(jnp.sum(mask), 1.0)
        avg = total / count
        return (m * avg + (1.0 - m) * xf).astype(x.dtype)

    return jax.tree.map(leaf, tree)


def tree_agent_weighted_mean(tree, w, keep):
    """Staleness-weighted server round in O(n): ``out_i = keep_i * x_i +
    (1 - keep_i) * sum_j w_j x_j``.

    ``w`` is an (n,) weight vector summing to one over the participating
    agents (zeros elsewhere) — the buffered-async aggregator's staleness
    weights; ``keep`` is 1.0 for agents holding their iterate (absentees).
    With uniform weights over the participants this equals
    :func:`tree_agent_masked_mean`; with ``keep = 0`` and ``w = 1/n`` it is
    the plain global average up to float reassociation."""

    def leaf(x):
        xf = x.astype(jnp.float32)
        wv = w.reshape(w.shape + (1,) * (x.ndim - 1))
        kv = keep.reshape(keep.shape + (1,) * (x.ndim - 1))
        avg = jnp.sum(wv * xf, axis=0, keepdims=True)
        return (kv * xf + (1.0 - kv) * avg).astype(x.dtype)

    return jax.tree.map(leaf, tree)


def tree_agent_trimmed_mean(tree, trim: int):
    """Coordinate-wise trimmed mean over the agent axis, broadcast back.

    Per leaf and per coordinate the ``trim`` smallest and ``trim`` largest
    agent values are discarded and the rest averaged — the classic
    Byzantine-robust server rule: up to ``trim`` arbitrary outliers per side
    cannot move the aggregate outside the honest value range.  ``trim = 0``
    equals :func:`tree_agent_mean` exactly.  Callers must guarantee
    ``n_agents - 2 * trim >= 1``.
    """
    trim = int(trim)

    def leaf(x):
        n = x.shape[0]
        s = jnp.sort(x.astype(jnp.float32), axis=0)
        kept = s[trim : n - trim] if trim > 0 else s
        m = jnp.mean(kept, axis=0, keepdims=True)
        return jnp.broadcast_to(m, x.shape).astype(x.dtype)

    return jax.tree.map(leaf, tree)


def tree_agent_median(tree):
    """Coordinate-wise median over the agent axis, broadcast back — robust to
    strictly fewer than half the agents being corrupted per coordinate."""

    def leaf(x):
        m = jnp.median(x.astype(jnp.float32), axis=0, keepdims=True)
        return jnp.broadcast_to(m, x.shape).astype(x.dtype)

    return jax.tree.map(leaf, tree)


def tree_agent_krum(tree, n_byz: int):
    """Krum-style selection over the agent axis, broadcast back.

    Scores each agent by the summed squared distance (across *all* leaves) to
    its ``n - n_byz - 2`` closest peers and broadcasts the minimizer's whole
    pytree — the aggregate is always one agent's actual submission, never a
    blend containing corrupted coordinates.  For tiny fleets the neighbor
    count is floored at one.
    """
    leaves = jax.tree.leaves(tree)
    n = leaves[0].shape[0]
    d2 = jnp.zeros((n, n), dtype=jnp.float32)
    for x in leaves:
        xf = x.reshape(n, -1).astype(jnp.float32)
        sq = jnp.sum(xf * xf, axis=1)
        d2 = d2 + jnp.maximum(sq[:, None] + sq[None, :] - 2.0 * xf @ xf.T, 0.0)
    m = max(1, n - int(n_byz) - 2)
    # exclude self-distance (zero) from every agent's closest-neighbor set
    d2 = d2 + jnp.where(jnp.eye(n, dtype=bool), jnp.inf, 0.0)
    scores = jnp.sum(jnp.sort(d2, axis=1)[:, :m], axis=1)
    sel = jnp.argmin(scores)
    return jax.tree.map(
        lambda x: jnp.broadcast_to(x[sel][None], x.shape).astype(x.dtype), tree
    )


def tree_size(tree) -> int:
    """Total number of scalar elements."""
    return sum(int(x.size) for x in jax.tree.leaves(tree))


def tree_bytes(tree) -> int:
    return sum(int(x.size) * x.dtype.itemsize for x in jax.tree.leaves(tree))
