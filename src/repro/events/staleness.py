"""Staleness-weighted aggregation rules for the async event-queue driver.

An :class:`AsyncConfig` is the declarative knob set of the events driver
(DESIGN.md §13), carried on ``ExperimentSpec.async_`` as a spec string::

    "<rule>[:k=v,...]"      e.g.  "poly:alpha=0.5,bound=2,buffer=4"

* ``rule`` — how the buffered-async server aggregator weights each agent's
  contribution by its staleness ``s`` (rounds since the agent last kept pace):

  - ``constant`` — uniform weights regardless of staleness (plain averaging;
    with everything else default this is "async timing, sync numerics");
  - ``poly``     — polynomial decay ``w ∝ (1 + s)^{-alpha}`` (the classic
    staleness discount of async SGD);
  - ``buffer``   — FedBuff-style: only the buffer cohort (the ``buffer``
    earliest pushes) is averaged, late pushes get weight zero this round.

* ``bound``  — the gossip staleness bound B: an agent that has fallen more
  than B rounds behind the front is dropped from its neighbors' mixes (its
  mass moves onto their self-weights — link-failure semantics) and stops
  gating round availability.  ``None``/``inf`` disables dropping.

* ``buffer`` — server buffer size m: a global round fires when the first m
  participant pushes arrive instead of waiting for the slowest (``None`` =
  everyone, the synchronous barrier).

Weights are always normalized to sum to one over the participants, so with
zero staleness everywhere every rule degenerates to the exact uniform
average — the hinge of the events driver's bit-exact degenerate mode.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

import numpy as np

RULES = ("constant", "poly", "buffer")


@dataclasses.dataclass(frozen=True)
class AsyncConfig:
    """Parsed form of an ``ExperimentSpec.async_`` spec string."""

    rule: str = "constant"
    alpha: float = 0.5  # poly decay exponent
    bound: Optional[int] = None  # gossip staleness bound B (None = never drop)
    buffer: Optional[int] = None  # server buffer size m (None = all participants)

    def __post_init__(self):
        if self.rule not in RULES:
            raise ValueError(f"async rule {self.rule!r} not in {RULES}")
        if self.alpha < 0:
            raise ValueError(f"alpha must be >= 0, got {self.alpha}")
        if self.bound is not None and self.bound < 0:
            raise ValueError(f"bound must be >= 0, got {self.bound}")
        if self.buffer is not None and self.buffer < 1:
            raise ValueError(f"buffer must be >= 1, got {self.buffer}")

    def spec(self) -> str:
        parts = []
        if self.alpha != 0.5:
            parts.append(f"alpha={self.alpha:g}")
        if self.bound is not None:
            parts.append(f"bound={self.bound}")
        if self.buffer is not None:
            parts.append(f"buffer={self.buffer}")
        return self.rule + (":" + ",".join(parts) if parts else "")


def parse_async_spec(spec: str) -> AsyncConfig:
    """``"poly:alpha=0.5,bound=2,buffer=4"`` -> :class:`AsyncConfig`.

    Raises ``ValueError`` on unknown rules/keys or malformed values — the
    same fail-fast contract as ``parse_systems_spec``."""
    spec = str(spec).strip()
    if not spec:
        raise ValueError("empty async spec")
    rule, _, rest = spec.partition(":")
    kw = {}
    if rest:
        for item in rest.split(","):
            key, eq, val = item.partition("=")
            key = key.strip()
            if not eq or not val.strip():
                raise ValueError(f"malformed async override {item!r} (want k=v)")
            if key == "alpha":
                kw["alpha"] = float(val)
            elif key in ("bound", "buffer"):
                v = val.strip().lower()
                if v in ("inf", "none"):
                    kw[key] = None
                else:
                    f = float(v)
                    if not f.is_integer():
                        raise ValueError(f"{key} must be an integer, got {val!r}")
                    kw[key] = int(f)
            else:
                raise ValueError(
                    f"unknown async key {key!r}; options: alpha, bound, buffer"
                )
    return AsyncConfig(rule=rule.strip(), **kw)


def with_staleness_bound(spec: Optional[str], bound: Optional[int]) -> str:
    """Return ``spec`` with its staleness bound replaced — the tuner's third
    axis edits async specs through this, like ``spec.replace(p=...)`` for p."""
    cfg = parse_async_spec(spec) if spec else AsyncConfig()
    return dataclasses.replace(cfg, bound=bound).spec()


def staleness_weights(
    staleness: np.ndarray,
    cfg: AsyncConfig,
    *,
    ontime: Optional[np.ndarray] = None,
    participants: Optional[np.ndarray] = None,
) -> np.ndarray:
    """Normalized aggregation weights for one buffered server round.

    ``staleness`` is the per-agent effective staleness (rounds) at push time;
    ``ontime`` marks the buffer cohort (pushes that arrived before the buffer
    fired — required by the ``buffer`` rule); ``participants`` masks the
    agents in this server round (default: everyone).  Returns an (n,) vector
    summing to one over the participants, zero elsewhere."""
    s = np.asarray(staleness, dtype=np.float64)
    part = (
        np.ones_like(s, dtype=bool)
        if participants is None
        else np.asarray(participants, dtype=bool)
    )
    if cfg.rule == "constant":
        w = np.ones_like(s)
    elif cfg.rule == "poly":
        w = (1.0 + np.maximum(s, 0.0)) ** (-cfg.alpha)
    else:  # buffer
        if ontime is None:
            raise ValueError("buffer rule needs the ontime cohort mask")
        w = np.asarray(ontime, dtype=np.float64)
    w = np.where(part, w, 0.0)
    total = w.sum()
    if not math.isfinite(total) or total <= 0.0:
        # no weighable contribution (can't happen with a non-empty buffer);
        # fall back to uniform over the participants
        w = part.astype(np.float64)
        total = w.sum()
    return w / total
