"""The ``events`` round driver: asynchronous execution of the round sequence.

:func:`drive_events` is the third consumer of the shared driver helpers in
:mod:`repro.core.driver` (``record_block`` / ``maybe_eval`` /
``make_block_fn``): the numerics still run as chunked on-device scans over
the registry's round functions **unchanged** — what changes is where the
per-round operands come from.  A synchronous driver draws mixing matrices
from the topology process and prices rounds with the barrier time model; the
events driver draws both from the :class:`~repro.events.clock.EventEngine`:

* gossip matrices are built from the *active* edge set — realized edges minus
  those incident to agents beyond the staleness bound;
* server rounds average with the buffered aggregator's staleness weights via
  :func:`~repro.utils.pytree.tree_agent_weighted_mean`, staged through the
  same :class:`~repro.core.mixing.DynamicWSlot` mechanism as any dynamic
  network (so FedOpt server rules and compression compose untouched);
* per-round seconds come from the engine's availability clock instead of the
  barrier model.

When the engine reports ``trivial=True`` (degenerate fleet: nothing dropped,
uniform weights), :class:`Experiment` binds the ordinary spec mixing instead
of :func:`make_async_mixing` and this driver becomes ``drive_scan`` with an
engine-priced clock — the executed device program is identical, which is the
bit-exactness acceptance pin.
"""
from __future__ import annotations

from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.core.algorithms import BoundAlgorithm
from repro.core.driver import (
    DEFAULT_BLOCK_SIZE,
    block_bounds,
    make_block_fn,
    maybe_eval,
    record_block,
    sample_block,
)
from repro.core.mixing import DynamicWSlot, MixingOps, _directed_arrays
from repro.core.topology import make_sparse_topology, make_topology
from repro.events.clock import EventEngine
from repro.utils.pytree import (
    tree_agent_mix,
    tree_agent_mix_sparse,
    tree_agent_weighted_mean,
)

PyTree = Any


class EventNetwork:
    """Minimal network handle binding round functions to the event engine.

    ``make_block_fn`` only needs ``.slot`` to stage per-round operands inside
    the scan body; the operands themselves are drawn by the
    :class:`~repro.events.clock.EventEngine` (``drive_events`` dispatches on
    the ``events`` marker), not by a ``TopologyProcess``.
    """

    events = True
    __slots__ = ("slot", "sparse")

    def __init__(self, slot: DynamicWSlot, sparse: bool):
        self.slot = slot
        self.sparse = sparse


def make_async_mixing(spec: Any) -> MixingOps:
    """Mixing ops whose per-round operands are event-engine decisions.

    Gossip reads whatever W_k (dense) or edge-weight pytree (sparse) the
    driver staged for the current round — exactly the dynamic-network slot
    mechanism — built by the engine from the staleness-masked active edge
    set.  The global average reads the engine's ``{'w', 'keep'}`` staleness
    weights: participants are averaged with the buffered aggregator's
    normalized weights, absentees hold.  Compression wraps on top like any
    other mixing, so the error-feedback wire path is identical.
    """
    slot = DynamicWSlot()
    n = spec.config.n_agents
    if spec.use_sparse:
        stopo = make_sparse_topology(
            spec.topology, n, **dict(spec.topology_kwargs)
        )
        senders, receivers = _directed_arrays(stopo)

        def gossip(tree: PyTree) -> PyTree:
            ops = slot.gossip_w
            return tree_agent_mix_sparse(
                tree, senders, receivers, ops["edge_w"], ops["self_w"], n
            )

        gossip_edges = stopo.n_edges
        base_name = stopo.name
    else:
        topo = make_topology(spec.topology, n, **dict(spec.topology_kwargs))

        def gossip(tree: PyTree) -> PyTree:
            return tree_agent_mix(tree, slot.gossip_w)

        gossip_edges = int(topo.adj.sum()) // 2
        base_name = topo.name

    def global_avg(tree: PyTree) -> PyTree:
        ops = slot.server_w
        return tree_agent_weighted_mean(tree, ops["w"], ops["keep"])

    mixing = MixingOps(
        gossip=gossip,
        global_avg=global_avg,
        name=f"events/{base_name}",
        gossip_edges=gossip_edges,
        network=EventNetwork(slot, spec.use_sparse),
    )
    if getattr(spec, "adversary", None) is not None:
        # same wrap order as ExperimentSpec.make_mixing: corruption before
        # compression, on whatever operands the engine stages (robust rules
        # are validated out for async specs, so robust_agg is a no-op here)
        from repro.core.adversary import make_adversarial_mixing

        mixing = make_adversarial_mixing(
            mixing, spec.adversary, getattr(spec, "robust_agg", "mean"),
            n_agents=n, seed=spec.config.seed,
        )
    if spec.compression is not None:
        from repro.core.compression import compress_mixing, make_compressor

        mixing = compress_mixing(
            mixing,
            make_compressor(spec.compression),
            error_feedback=spec.error_feedback,
            seed=spec.config.seed,
        )
    return mixing


def drive_events(
    bound: BoundAlgorithm,
    state,
    sampler: Callable[[int], tuple],
    rounds: int,
    hist,
    *,
    engine: EventEngine,
    eval_fn: Optional[Callable] = None,
    eval_every: int = 1,
    stop_when: Optional[Callable] = None,
    block_size: int = DEFAULT_BLOCK_SIZE,
    block_fn: Optional[Callable] = None,
):
    """Event-queue driver: scan-blocked numerics, engine-supplied operands.

    The schedule was consumed once when the engine was built — ``engine.flags``
    is the authoritative flag sequence (identical draws, in round order, to
    what the sync drivers would see), so ``bound.schedule`` is never called
    here.  Per-round simulated seconds come from the engine's availability
    clock (``record_block(..., seconds=...)`` overrides any attached barrier
    time model), and the per-agent staleness series is appended to
    ``hist.staleness`` as rounds execute.
    """
    if block_fn is None:
        block_fn = make_block_fn(bound)
    cuts = block_bounds(
        rounds,
        eval_every=eval_every if eval_fn is not None else 0,
        block_size=block_size,
    )
    net = bound.network
    staleness = getattr(hist, "staleness", None)
    for start, stop in cuts:
        flags = engine.flags[start:stop]
        local, comm = sample_block(sampler, start, stop)
        if net is None:
            realized = None
            state, metrics = block_fn(state, jnp.asarray(flags), local, comm)
        else:
            # trivial mode binds the ordinary dynamic mixing (its own
            # NetworkContext draws operands); the async mixing's EventNetwork
            # routes the draw to the engine instead.  An AdversarialNetwork
            # wrapping the EventNetwork still draws from the engine, then
            # augments the gossip operand with the block's round indices.
            inner = getattr(net, "base", net)
            if getattr(inner, "events", False):
                w_gossip, w_server, messages, participants = engine.draw_block(
                    start, stop
                )
                if inner is not net:
                    w_gossip = net.augment(w_gossip, start, stop)
            else:
                w_gossip, w_server, messages, participants = net.draw_block(
                    start, stop
                )
            realized = (messages, participants)
            state, metrics = block_fn(
                state, jnp.asarray(flags), jax.tree.map(jnp.asarray, w_gossip),
                jax.tree.map(jnp.asarray, w_server), local, comm,
            )
        rec = getattr(hist, "recorder", None)
        t_block = rec.clock_s if rec is not None else 0.0
        record_block(
            hist, metrics, flags, realized, start=start,
            seconds=engine.seconds[start:stop],
        )
        if rec is not None:
            # per-agent tracks: each agent's view of every round in the block,
            # annotated with the engine's frozen gating/participation/staleness
            # decisions — the async story the aggregate round span can't tell
            gate = engine.trace["gate"]
            parts = engine.trace["participants"]
            t0 = t_block
            for k in range(start, stop):
                dur = float(engine.seconds[k])
                f = bool(flags[k - start])
                for a in range(engine.n_agents):
                    rec.record_agent_round(
                        k, a, t0, dur, f,
                        staleness=int(engine.staleness[k, a]),
                        participant=bool(parts[k, a]),
                        gated=bool(not gate[k, a]),
                    )
                t0 += dur
        if staleness is not None:
            staleness.extend(engine.staleness[start:stop].tolist())
        maybe_eval(hist, eval_fn, eval_every, rounds, state, stop - 1)
        if stop_when is not None and stop_when(hist):
            break
    return state
