"""The simulated-clock event queue behind the ``events`` driver (DESIGN.md §13).

Where the synchronous cost model (:mod:`repro.sim.costmodel`) prices every
round by the slowest realized agent/edge — a global barrier — this module
simulates **per-agent clocks**: each agent draws its compute and link times
from the same :class:`~repro.sim.profiles.SystemsParams` realization and
advances through the logical round sequence at its own speed.

Three asynchronous mechanisms replace the barrier:

* **bounded-staleness gossip** — an agent that falls behind the round front
  accrues a staleness counter ``s``; once ``s`` exceeds the configured bound
  B its edges are dropped from its neighbors' mixes (self-weight absorption,
  exactly the link-failure re-weighting of DESIGN.md §9) and it stops gating
  round availability — neighbors no longer wait for it;

* **buffered server rounds** — a global round fires when the first ``m``
  participant pushes arrive (FedBuff-style buffer-of-m) instead of waiting
  for the straggler tail; the broadcast then *re-baselines* every
  participant's clock (server pushes preempt in-flight work) and resets
  staleness to zero — server rounds double as staleness resets, which is the
  semi-decentralized p/τ story on the time axis;

* **staleness-weighted aggregation** — each push is weighted by
  :func:`~repro.events.staleness.staleness_weights` of its effective
  staleness at push time, applied through the mixing layer so the registry
  round functions (and any bound FedOpt server rule) run unchanged.

Everything is host-side numpy, **pure** in ``(profile realization, flag
sequence, async config)``.  The engine separates *what happened* (the gating
decisions: active edges, buffer cohorts — the event trace) from *how long it
took* (the clock replay over a fleet realization): :func:`reprice_trace`
replays the frozen trace under a different fleet, so a finished async run can
be re-priced under another profile without re-training — and repricing under
the original profile reproduces the online seconds bit-exactly, because the
online seconds are themselves produced by the same replay.

Degenerate fleets (uniform compute, free links) keep every clock in lockstep:
no edge is ever dropped, every buffer cohort is the full fleet, every weight
vector is exactly uniform — the engine reports ``trivial=True`` and the
driver falls back to the synchronous scan path bit-for-bit.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

import numpy as np

from repro.core.topology import metropolis_edge_weights, metropolis_weights
from repro.events.staleness import AsyncConfig, parse_async_spec, staleness_weights
from repro.sim.costmodel import SystemsModel, make_time_model


def _edge_costs(params, edges: np.ndarray, nbytes: int, mixes: int) -> np.ndarray:
    """Per-undirected-edge message time ``mixes * (latency + bytes/bw)`` —
    zero when nothing is shipped, matching the synchronous model."""
    if len(edges) == 0 or nbytes <= 0:
        return np.zeros(len(edges), dtype=np.float64)
    i, j = edges[:, 0], edges[:, 1]
    return float(mixes) * (
        params.link_latency_s[i, j] + float(nbytes) / params.link_bw_Bps[i, j]
    )


def _server_costs(params, nbytes: int, payloads: int):
    """``(up_time (n,), down_time (n,), rtt)`` — all zero for a free server
    exchange, matching the synchronous model."""
    n = len(params.up_bw_Bps)
    if nbytes <= 0:
        z = np.zeros(n, dtype=np.float64)
        return z, z, 0.0
    b = float(payloads) * float(nbytes)
    return b / params.up_bw_Bps, b / params.down_bw_Bps, float(params.server_rtt_s)


def reprice_trace(trace: Dict[str, Any], model: SystemsModel) -> np.ndarray:
    """Replay a frozen event trace's clock recursion under ``model``.

    The trace's gating decisions (active edges, buffer cohorts, participant
    sets) are *numerics* — they determined what the executed run computed —
    so repricing keeps them fixed and only re-draws the clock arithmetic:
    "how long would this exact executed schedule have taken on that fleet?"
    """
    p = model.params
    flags = np.asarray(trace["flags"], dtype=bool)
    edges = np.asarray(trace["base_edges"], dtype=np.int64).reshape(-1, 2)
    active = np.asarray(trace["active"], dtype=bool)
    gate = np.asarray(trace["gate"], dtype=bool)
    parts = np.asarray(trace["participants"], dtype=bool)
    steps = int(trace["local_steps"])
    n = int(trace["n_agents"])
    rounds = len(flags)

    ecost = _edge_costs(p, edges, int(trace["gossip_bytes"]), int(trace["mixes"]))
    up_t, down_t, rtt = _server_costs(
        p, int(trace["server_bytes"]), int(trace["payloads"])
    )
    compute = steps * p.compute_s

    T = np.zeros(n, dtype=np.float64)
    avail = 0.0
    seconds = np.zeros(rounds, dtype=np.float64)
    for k in range(rounds):
        cd = T + compute
        if flags[k]:
            part, cohort = parts[k], gate[k]
            push = cd + up_t
            if not cohort.any():
                cohort = part if part.any() else np.ones(n, dtype=bool)
            fire = float(push[cohort].max())
            event = fire + rtt + float(down_t[cohort].max())
            T = np.where(part, fire + rtt + down_t, cd)
        else:
            t_new = cd.copy()
            act = active[k]
            if act.any():
                ii, jj = edges[act, 0], edges[act, 1]
                c = ecost[act]
                # both endpoints wait for each other's message
                np.maximum.at(t_new, ii, cd[jj] + c)
                np.maximum.at(t_new, jj, cd[ii] + c)
            cohort = gate[k]
            event = float(t_new[cohort].max() if cohort.any() else t_new.max())
            T = t_new
        nxt = max(avail, event)
        seconds[k] = nxt - avail
        avail = nxt
    return seconds


@dataclasses.dataclass(eq=False)
class EventEngine:
    """One experiment's simulated event queue, fully realized at build time.

    Holds the per-round gating decisions, staleness counters, aggregation
    weights and availability seconds for the whole flag sequence; the driver
    consumes them block-by-block (:meth:`draw_block`, :attr:`seconds`) and
    exports :attr:`trace` onto the History for post-hoc repricing.
    """

    model: SystemsModel
    cfg: AsyncConfig
    flags: np.ndarray  # (R,) bool — predrawn schedule, the driver's source of truth
    base_edges: np.ndarray  # (m, 2) base undirected edge list
    process: Optional[Any] = None  # TopologyProcess (realized edges per round)
    participation: Optional[Any] = None  # ParticipationProcess
    local_steps: int = 1
    gossip_bytes: int = 0
    server_bytes: int = 0
    mixes: int = 1
    payloads: int = 1
    sparse: bool = False

    def __post_init__(self):
        self.flags = np.asarray(self.flags, dtype=bool)
        self.base_edges = np.asarray(self.base_edges, dtype=np.int64).reshape(-1, 2)
        self._simulate()
        self.seconds = reprice_trace(self.trace, self.model)

    @property
    def n_agents(self) -> int:
        return self.model.n_agents

    # -- event simulation ---------------------------------------------------

    def _realized_mask(self, k: int) -> np.ndarray:
        if self.process is not None:
            return np.asarray(self.process.edge_mask_at(k), dtype=bool)
        return np.ones(len(self.base_edges), dtype=bool)

    def _participants_mask(self, k: int) -> np.ndarray:
        part = np.zeros(self.n_agents, dtype=bool)
        if self.participation is not None:
            part[np.asarray(self.participation.participants_at(k), dtype=int)] = True
        else:
            part[:] = True
        return part

    def _simulate(self) -> None:
        p = self.model.params
        n, rounds = self.n_agents, len(self.flags)
        edges = self.base_edges
        ecost = _edge_costs(p, edges, self.gossip_bytes, self.mixes)
        up_t, down_t, rtt = _server_costs(p, self.server_bytes, self.payloads)
        compute = self.local_steps * p.compute_s
        # the round quantum: one median-agent round — "on time" means
        # finishing within one such round of the front
        q = float(np.median(compute)) + (
            float(np.median(ecost)) if len(ecost) else 0.0
        )

        T = np.zeros(n, dtype=np.float64)
        s = np.zeros(n, dtype=np.int64)
        active = np.zeros((rounds, len(edges)), dtype=bool)
        gate = np.zeros((rounds, n), dtype=bool)
        parts = np.zeros((rounds, n), dtype=bool)
        stale = np.zeros((rounds, n), dtype=np.int64)
        weights = np.zeros((rounds, n), dtype=np.float64)
        messages = np.zeros(rounds, dtype=np.int64)
        n_parts = np.zeros(rounds, dtype=np.int64)
        trivial = True

        for k in range(rounds):
            cd = T + compute
            if self.flags[k]:
                part = self._participants_mask(k)
                npart = int(part.sum())
                push = cd + up_t
                m_eff = npart if self.cfg.buffer is None else min(
                    self.cfg.buffer, npart
                )
                fire0 = float(np.sort(push[part])[m_eff - 1])
                ontime = part & (push <= fire0)
                # effective staleness at push time: the counter, plus one for
                # pushes that missed the buffer this round
                sigma = np.where(part, s + np.where(ontime, 0, 1), 0)
                w = staleness_weights(
                    sigma, self.cfg, ontime=ontime, participants=part
                )
                if not np.all(w[part] == 1.0 / npart):
                    trivial = False
                # the broadcast resets every participant's staleness
                s = np.where(part, 0, s)
                T = np.where(part, fire0 + rtt + down_t, cd)
                gate[k], parts[k] = ontime, part
                stale[k], weights[k] = sigma, w
                n_parts[k] = npart
            else:
                front = float(cd.min())
                ontime = cd <= front + q
                s = np.where(ontime, 0, s + 1)
                cohort = (
                    np.ones(n, dtype=bool)
                    if self.cfg.bound is None
                    else s <= self.cfg.bound
                )
                realized = self._realized_mask(k)
                if len(edges):
                    act = realized & cohort[edges[:, 0]] & cohort[edges[:, 1]]
                else:
                    act = realized
                if act.sum() != realized.sum():
                    trivial = False
                t_new = cd.copy()
                if act.any():
                    ii, jj = edges[act, 0], edges[act, 1]
                    c = ecost[act]
                    np.maximum.at(t_new, ii, cd[jj] + c)
                    np.maximum.at(t_new, jj, cd[ii] + c)
                T = t_new
                active[k], gate[k] = act, cohort
                parts[k] = True
                stale[k] = s
                weights[k] = 1.0 / n
                messages[k] = 2 * int(act.sum())
                n_parts[k] = n

        self.trivial = trivial
        self.staleness = stale
        self.weights = weights
        self.messages = messages
        self.n_participants = n_parts
        self.trace: Dict[str, Any] = {
            "flags": self.flags,
            "base_edges": edges,
            "active": active,
            "gate": gate,
            "participants": parts,
            "local_steps": int(self.local_steps),
            "mixes": int(self.mixes),
            "payloads": int(self.payloads),
            "gossip_bytes": int(self.gossip_bytes),
            "server_bytes": int(self.server_bytes),
            "n_agents": n,
            "systems": self.model.profile,
        }

    # -- per-block operands for the numerics --------------------------------

    def realized(self, start: int, stop: int):
        """``(messages, participants)`` counts for the byte accountant."""
        return self.messages[start:stop], self.n_participants[start:stop]

    def _server_ops(self, start: int, stop: int):
        w = self.weights[start:stop].astype(np.float32)
        keep = 1.0 - self.trace["participants"][start:stop].astype(np.float32)
        return {"w": w, "keep": keep}

    def draw_block(self, start: int, stop: int):
        """Event-derived mixing operands for rounds ``[start, stop)`` in the
        shapes :func:`~repro.core.driver.make_block_fn` threads: dense W_k
        stacks (or sparse edge-weight pytrees) for gossip, and the
        ``{'w', 'keep'}`` staleness-weight pytree for the buffered server
        average — same contract as ``NetworkContext.draw_block``."""
        n, edges = self.n_agents, self.base_edges
        active = self.trace["active"]
        if self.sparse:
            m = len(edges)
            ew = np.zeros((stop - start, m), dtype=np.float32)
            sw = np.ones((stop - start, n), dtype=np.float32)
            for t, k in enumerate(range(start, stop)):
                if self.flags[k]:
                    continue  # unused branch operand at server rounds
                mask = active[k]
                if mask.any():
                    sub_w, self_w = metropolis_edge_weights(edges[mask], n)
                    ew[t, mask] = sub_w
                    sw[t] = self_w
            w_gossip = {
                "edge_w": np.concatenate([ew, ew], axis=1), "self_w": sw
            }
        else:
            ws = np.empty((stop - start, n, n), dtype=np.float32)
            eye = np.eye(n, dtype=np.float32)
            for t, k in enumerate(range(start, stop)):
                if self.flags[k]:
                    ws[t] = eye  # unused branch operand at server rounds
                else:
                    adj = np.zeros((n, n), dtype=bool)
                    e = edges[active[k]]
                    if len(e):
                        adj[e[:, 0], e[:, 1]] = True
                        adj[e[:, 1], e[:, 0]] = True
                    ws[t] = metropolis_weights(adj)
            w_gossip = ws
        return (
            w_gossip,
            self._server_ops(start, stop),
            self.messages[start:stop],
            self.n_participants[start:stop],
        )


def make_event_engine(
    spec: Any,
    byte_model: Any,
    flags: np.ndarray,
    *,
    network: Optional[Any] = None,
    systems: Optional[str] = None,
) -> EventEngine:
    """Build the :class:`EventEngine` for an ``ExperimentSpec`` — fleet,
    wire sizes, and network processes all come from the same
    :func:`~repro.sim.costmodel.make_time_model` derivation the synchronous
    pricing uses, so both clocks see identical realizations."""
    tm = make_time_model(spec, byte_model, network=network, systems=systems)
    cfg = (
        parse_async_spec(spec.async_)
        if getattr(spec, "async_", None) is not None
        else AsyncConfig()
    )
    return EventEngine(
        model=tm.model,
        cfg=cfg,
        flags=flags,
        base_edges=tm.base_edges,
        process=tm.process,
        participation=tm.participation,
        local_steps=tm.local_steps,
        gossip_bytes=tm.gossip_message_bytes,
        server_bytes=tm.server_message_bytes,
        mixes=tm.mixes_per_round,
        payloads=tm.server_payloads,
        sparse=bool(getattr(spec, "use_sparse", False)),
    )
