"""Asynchronous event-queue execution (DESIGN.md §13).

The synchronous drivers advance a global barrier: every agent finishes round
k before anyone starts round k+1, so simulated time is priced by the slowest
realized agent/edge.  This package replaces the barrier with a simulated
event clock over the spec's :mod:`repro.sim.profiles` fleet realization —
bounded-staleness gossip, buffered staleness-weighted server aggregation —
while reusing the registry round functions and the scan execution machinery
unchanged.

* :mod:`repro.events.staleness` — ``AsyncConfig`` (the ``ExperimentSpec.async_``
  spec string) and the constant / poly / buffer aggregation weight rules;
* :mod:`repro.events.clock` — the :class:`EventEngine` per-agent clock
  simulation, its frozen event trace, and :func:`reprice_trace`;
* :mod:`repro.events.driver` — :func:`drive_events` (the third registered
  driver) and :func:`make_async_mixing`.
"""
from repro.events.clock import EventEngine, make_event_engine, reprice_trace
from repro.events.driver import drive_events, make_async_mixing
from repro.events.staleness import (
    RULES,
    AsyncConfig,
    parse_async_spec,
    staleness_weights,
    with_staleness_bound,
)

__all__ = [
    "AsyncConfig",
    "EventEngine",
    "RULES",
    "drive_events",
    "make_async_mixing",
    "make_event_engine",
    "parse_async_spec",
    "reprice_trace",
    "staleness_weights",
    "with_staleness_bound",
]
