"""Arrival-process load model + the simulated-clock request loop.

Same discipline as :mod:`repro.events.clock`: the clock is **simulated** and
advanced explicitly by the cost of each engine operation, so a load sweep is
reproducible and never conflates host noise with the serving model.  Two cost
sources:

* **measured** (``costs=None``, the default) — each prefill / decode step is
  actually executed and timed (``perf_counter`` around a device barrier); the
  simulated clock advances by real engine seconds, so tokens/s and latency
  reflect the hardware while arrivals stay perfectly reproducible;
* **fixed** (:class:`StepCosts`) — deterministic per-op costs, the mode tests
  hand-check latency arithmetic with.

Arrival processes are declarative specs in the :mod:`repro.sim.profiles`
grammar — ``"poisson:rate=2"`` (exponential gaps) or
``"bursty:rate=2,burst=8"`` (groups of ``burst`` simultaneous arrivals whose
group gaps keep the long-run rate) — pure in ``(spec, n, seed)`` with
domain-separated RNG streams for arrivals vs. request contents.

The loop itself is the serving semantics: pull due arrivals into the wait
queue, admit into free slots (each admission charges one prefill), then one
decode step for the whole batch (charged once, attributed to every active
request — the slots advance in parallel).  When the engine is idle the clock
jumps to the next arrival.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, List, Optional

import numpy as np

from repro.serve.batcher import ContinuousBatcher, Request

_ARRIVAL_TAG = 0xA331  # arrival-time stream
_WORK_TAG = 0x3031  # request-content stream (agents, prompts, lengths)


# ---------------------------------------------------------------------------
# Arrival processes
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ArrivalProcess:
    """``"poisson:rate=R"`` | ``"bursty:rate=R,burst=B"`` (requests/second)."""

    kind: str = "poisson"
    rate: float = 1.0
    burst: int = 8

    def __post_init__(self):
        if self.kind not in ("poisson", "bursty"):
            raise ValueError(f"unknown arrival kind {self.kind!r}")
        if self.rate <= 0:
            raise ValueError(f"rate must be > 0, got {self.rate}")
        if self.burst < 1:
            raise ValueError(f"burst must be >= 1, got {self.burst}")

    @classmethod
    def parse(cls, spec: str) -> "ArrivalProcess":
        name, _, tail = spec.partition(":")
        kw: dict = {"kind": name}
        if tail:
            for item in tail.split(","):
                k, sep, v = item.partition("=")
                if not sep:
                    raise ValueError(f"bad arrival spec item {item!r} in {spec!r}")
                if k == "rate":
                    kw["rate"] = float(v)
                elif k == "burst":
                    kw["burst"] = int(v)
                else:
                    raise ValueError(f"unknown arrival key {k!r} in {spec!r}")
        return cls(**kw)

    @property
    def name(self) -> str:
        if self.kind == "bursty":
            return f"bursty:rate={self.rate:g},burst={self.burst}"
        return f"poisson:rate={self.rate:g}"

    def draw(self, n: int, seed: int = 0) -> np.ndarray:
        """(n,) sorted arrival times in seconds, pure in (self, n, seed)."""
        rng = np.random.default_rng([seed, _ARRIVAL_TAG])
        if self.kind == "poisson":
            return np.cumsum(rng.exponential(1.0 / self.rate, size=n))
        # bursty: groups of ``burst`` simultaneous arrivals; group gaps are
        # exponential with mean burst/rate so the long-run rate matches
        n_groups = int(np.ceil(n / self.burst))
        gaps = rng.exponential(self.burst / self.rate, size=n_groups)
        return np.repeat(np.cumsum(gaps), self.burst)[:n]


def make_requests(
    process: ArrivalProcess,
    n_requests: int,
    *,
    n_agents: int,
    vocab_size: int,
    prompt_len: int = 32,
    max_new_tokens: int = 16,
    eos_id: Optional[int] = None,
    seed: int = 0,
) -> List[Request]:
    """Draw a reproducible request trace: arrival times from the process
    stream, contents (agent ids, prompt tokens) from a separate stream."""
    arrivals = process.draw(n_requests, seed=seed)
    rng = np.random.default_rng([seed, _WORK_TAG])
    agents = rng.integers(0, n_agents, size=n_requests)
    prompts = rng.integers(0, vocab_size, size=(n_requests, prompt_len))
    return [
        Request(
            rid=i,
            agent_id=int(agents[i]),
            prompt=prompts[i].astype(np.int32),
            max_new_tokens=max_new_tokens,
            eos_id=eos_id,
            arrival_s=float(arrivals[i]),
        )
        for i in range(n_requests)
    ]


# ---------------------------------------------------------------------------
# The request loop
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class StepCosts:
    """Fixed per-operation costs (seconds) for the deterministic mode."""

    prefill_s: float = 0.05
    decode_s: float = 0.01


@dataclasses.dataclass
class ServeReport:
    """Completed request records + the aggregates the benchmarks consume."""

    requests: List[Request]
    clock_s: float  # simulated time at which the last request finished

    @property
    def total_tokens(self) -> int:
        return sum(len(r.tokens) for r in self.requests)

    @property
    def makespan_s(self) -> float:
        if not self.requests:
            return 0.0
        start = min(r.arrival_s for r in self.requests)
        return max(self.clock_s - start, 1e-12)

    @property
    def tokens_per_s(self) -> float:
        return self.total_tokens / self.makespan_s

    def latency_percentile(self, q: float) -> float:
        lats = [r.latency_s for r in self.requests]
        return float(np.percentile(lats, q)) if lats else 0.0

    @property
    def p50_s(self) -> float:
        return self.latency_percentile(50.0)

    @property
    def p99_s(self) -> float:
        return self.latency_percentile(99.0)

    def mean(self, field: str) -> float:
        vals = [getattr(r, field) for r in self.requests]
        return float(np.mean(vals)) if vals else 0.0

    def to_dict(self) -> dict:
        return {
            "n_requests": len(self.requests),
            "total_tokens": self.total_tokens,
            "makespan_s": self.makespan_s,
            "tokens_per_s": self.tokens_per_s,
            "p50_s": self.p50_s,
            "p99_s": self.p99_s,
            "mean_queue_wait_s": self.mean("queue_wait_s"),
            "mean_prefill_s": self.mean("prefill_s"),
            "mean_decode_s": self.mean("decode_s"),
            "requests": [r.breakdown() for r in self.requests],
        }

    def telemetry(self, meta: Optional[dict] = None):
        """Export this session into a
        :class:`~repro.obs.metrics.MetricsRegistry` — request/token counters,
        latency gauges, lifecycle histograms, and per-slot decode occupancy
        (seconds of decode attributed to each slot, DESIGN.md §16)."""
        from repro.obs.metrics import MetricsRegistry  # lazy: keep serve light

        reg = MetricsRegistry(meta=dict(meta or {}))
        reg.counter("serve.requests").inc(len(self.requests))
        reg.counter("serve.tokens").inc(self.total_tokens)
        reg.gauge("serve.tokens_per_s").set(self.tokens_per_s)
        reg.gauge("serve.p50_s").set(self.p50_s)
        reg.gauge("serve.p99_s").set(self.p99_s)
        reg.gauge("serve.makespan_s").set(self.makespan_s)
        reg.histogram("serve.queue_wait_s").observe_many(
            r.queue_wait_s for r in self.requests
        )
        reg.histogram("serve.prefill_s").observe_many(
            r.prefill_s for r in self.requests
        )
        reg.histogram("serve.decode_s").observe_many(
            r.decode_s for r in self.requests
        )
        for r in self.requests:
            if r.slot is not None:
                reg.counter(f"serve.slot.{r.slot}.requests").inc()
                reg.counter(f"serve.slot.{r.slot}.decode_s").inc(r.decode_s)
        return reg


def run_load(
    batcher: ContinuousBatcher,
    requests: List[Request],
    *,
    costs: Optional[StepCosts] = None,
    recorder=None,
) -> ServeReport:
    """Drive ``requests`` through ``batcher`` on a simulated clock.

    ``recorder`` (a :class:`~repro.obs.trace.TraceRecorder`) gets each
    finished request's queue→prefill→decode lifecycle as spans on the owning
    agent's track — recorded after the loop from the timestamps the loop
    already stamps, so recording cannot perturb the clock."""
    pending = sorted(requests, key=lambda r: (r.arrival_s, r.rid))
    waiting: List[Request] = []
    done: List[Request] = []
    t = 0.0

    def charge(op: Callable[[], object], fixed: float) -> float:
        if costs is not None:
            op()
            return fixed
        t0 = time.perf_counter()
        op()
        batcher.engine.block_until_ready()
        return time.perf_counter() - t0

    while pending or waiting or batcher.active:
        # idle engine, empty queue: jump to the next arrival
        if not waiting and not batcher.active and pending:
            t = max(t, pending[0].arrival_s)
        # pull due arrivals
        while pending and pending[0].arrival_s <= t:
            waiting.append(pending.pop(0))
        # admit into free slots (one prefill each)
        while waiting and batcher.free_slots():
            req = waiting.pop(0)
            req.admit_s = t
            out: List = []
            dt = charge(
                lambda: out.append(batcher.admit(req)),
                costs.prefill_s if costs is not None else 0.0,
            )
            req.prefill_s = dt
            t += dt
            req.first_token_s = t
            if out[0]:  # finished at admission (max_new_tokens == 1 / EOS)
                req.done_s = t
                done.append(req)
        # one decode step for the whole batch
        if batcher.active:
            active = list(batcher.active)
            out = []
            dt = charge(
                lambda: out.extend(batcher.step()),
                costs.decode_s if costs is not None else 0.0,
            )
            t += dt
            for r in active:
                r.decode_s += dt
            for r in out:
                r.done_s = t
                done.append(r)
    if recorder is not None:
        for r in sorted(done, key=lambda r: (r.agent_id, r.arrival_s, r.rid)):
            recorder.record_request(r)
    return ServeReport(requests=done, clock_s=t)
