"""Personalized-fleet serving: delta-compressed weights, continuous-batched
multiplexed decode, and a simulated-traffic load model (DESIGN.md §15)."""
from repro.serve.batcher import ContinuousBatcher, Request
from repro.serve.delta import (
    DeltaSpec,
    DenseFleet,
    FleetDelta,
    export_fleet,
    materialize,
    materialize_fleet,
)
from repro.serve.engine import DecodeEngine
from repro.serve.load import (
    ArrivalProcess,
    ServeReport,
    StepCosts,
    make_requests,
    run_load,
)

__all__ = [
    "ArrivalProcess",
    "ContinuousBatcher",
    "DecodeEngine",
    "DeltaSpec",
    "DenseFleet",
    "FleetDelta",
    "Request",
    "ServeReport",
    "StepCosts",
    "export_fleet",
    "make_requests",
    "materialize",
    "materialize_fleet",
    "run_load",
]
