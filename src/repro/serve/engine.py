"""Jitted decode engine: one decode step multiplexed across per-agent deltas.

The engine owns the device state of a fixed number of decode **slots**: a
slot-stacked KV/SSM cache (the existing ``bundle.init_cache`` layout with a
leading slot axis on every leaf, including the per-slot position counter) and,
in ``materialize="admit"`` mode, a slot-stacked parameter buffer.  One
:meth:`step` advances *all* slots by one token with a single jitted program —
``jax.vmap`` of the bundle's ``decode`` over the slot axis, which lowers every
projection to a batched base matmul — even though each slot belongs to a
*different* agent of the personalized fleet:

* ``materialize="admit"`` (default) — an agent's delta is gathered and applied
  once, when its request is admitted to a slot; decode steps then run off the
  cached slot-stacked buffer.  Cheapest steady state.
* ``materialize="step"`` — every decode step re-gathers the active agents'
  deltas and rebuilds the slot parameters inside the jitted step (broadcast
  base + batched residual scatter/correction, then the batched matmuls).  No
  persistent per-slot dense copies; what the ISSUE calls delta-multiplexing
  in its purest form.

Both modes are bit-identical to each other and — for lossless deltas — to the
dense-materialized baseline fleet, because both funnel through the same
``Fleet.gather`` reconstruction and the same decode program.

Prefill runs per admitted request at batch 1 (one compile per distinct prompt
length — callers should bucket prompt lengths) and its filled cache is
scattered into the slot axis.  The decode/prefill programs are the same ones
the dry-run lowers, so the flash-attention / ssd_scan kernel paths of the
model zoo are exercised unchanged.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.registry import ModelBundle
from repro.serve.delta import DenseFleet, FleetDelta

PyTree = Any

MATERIALIZE_MODES = ("admit", "step")


class DecodeEngine:
    """Fixed-slot continuous-decode engine over a personalized fleet.

    Device state: ``self.cache`` (slot-stacked), ``self.slot_params`` (admit
    mode only), ``self.agent_ids`` (host-side (S,) int array; slot -> agent).
    The batcher is the policy layer on top — it decides which request occupies
    which slot and when; the engine only moves tensors.
    """

    def __init__(
        self,
        bundle: ModelBundle,
        fleet,
        *,
        n_slots: int = 4,
        max_seq: int = 128,
        materialize: str = "admit",
    ):
        cfg = bundle.cfg
        if cfg.is_enc_dec or cfg.modality != "text":
            raise ValueError(
                "DecodeEngine serves decoder-only text models "
                f"(got {cfg.name!r}: enc_dec={cfg.is_enc_dec}, "
                f"modality={cfg.modality!r})"
            )
        if materialize not in MATERIALIZE_MODES:
            raise ValueError(
                f"materialize {materialize!r} not in {MATERIALIZE_MODES}"
            )
        if not isinstance(fleet, (FleetDelta, DenseFleet)):
            raise TypeError(f"not a fleet: {type(fleet)}")
        self.bundle = bundle
        self.fleet = fleet
        self.n_slots = int(n_slots)
        self.max_seq = int(max_seq)
        self.materialize = materialize
        self._fleet_arrays = fleet.arrays
        gather = type(fleet).gather

        # -- jitted programs (fleet arrays passed as arguments, not baked in)
        self._gather = jax.jit(gather)

        def _decode(slot_params, tokens, cache):
            # tokens (S, 1, 1): inner decode sees a (1, 1) batch per slot
            return jax.vmap(bundle.decode)(slot_params, tokens, cache)

        def _decode_gathered(arrays, ids, tokens, cache):
            return _decode(gather(arrays, ids), tokens, cache)

        self._decode = jax.jit(_decode)
        self._decode_gathered = jax.jit(_decode_gathered)
        self._prefill = jax.jit(bundle.prefill)
        self._write_slot = jax.jit(
            lambda stacked, one, slot: jax.tree.map(
                lambda s, c: s.at[slot].set(c), stacked, one
            )
        )

        # -- device state
        self.agent_ids = np.zeros(self.n_slots, dtype=np.int32)
        cache1 = bundle.init_cache(1, self.max_seq)
        self.cache = jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (self.n_slots,) + x.shape) + 0,
            cache1,
        )
        self.slot_params: Optional[PyTree] = None
        if self.materialize == "admit":
            self.slot_params = self._gather(
                self._fleet_arrays, jnp.asarray(self.agent_ids)
            )

    # -- lifecycle ----------------------------------------------------------

    def admit(self, slot: int, agent_id: int, prompt: np.ndarray) -> np.ndarray:
        """Prefill ``prompt`` (1-D int32) for ``agent_id`` into ``slot``.

        Returns the last-position logits (V,) — the distribution the first
        generated token is sampled from."""
        prompt = jnp.asarray(prompt, jnp.int32)[None]  # (1, L)
        ids = jnp.asarray([agent_id], jnp.int32)
        params1 = jax.tree.map(
            lambda l: l[0], self._gather(self._fleet_arrays, ids)
        )
        cache1 = self.bundle.init_cache(1, self.max_seq)
        logits, cache1 = self._prefill(params1, {"tokens": prompt}, cache1)
        slot_ix = jnp.asarray(slot, jnp.int32)
        self.cache = self._write_slot(self.cache, cache1, slot_ix)
        if self.materialize == "admit":
            self.slot_params = self._write_slot(self.slot_params, params1, slot_ix)
        self.agent_ids[slot] = agent_id
        return np.asarray(logits[0, -1])

    def step(self, tokens: np.ndarray) -> np.ndarray:
        """One decode step for all slots; ``tokens`` (S,) int32 are each
        slot's previous token.  Returns logits (S, V)."""
        toks = jnp.asarray(tokens, jnp.int32).reshape(self.n_slots, 1, 1)
        if self.materialize == "admit":
            logits, self.cache = self._decode(self.slot_params, toks, self.cache)
        else:
            logits, self.cache = self._decode_gathered(
                self._fleet_arrays, jnp.asarray(self.agent_ids), toks, self.cache
            )
        return np.asarray(logits[:, 0, -1])

    def block_until_ready(self) -> None:
        """Barrier for wall-clock measurement (load.py's measured mode)."""
        jax.block_until_ready(self.cache)

    # -- accounting ---------------------------------------------------------

    def fleet_nbytes(self) -> int:
        return self.fleet.nbytes()

    def naive_fleet_nbytes(self) -> int:
        return self.fleet.naive_nbytes()
