"""Continuous batching: the request/slot state machine over a DecodeEngine.

Classic static batching pads a batch of requests to the longest generation
and leaves slots idle as short requests finish.  Continuous batching instead
treats the decode batch as **S slots** with independent lifecycles:

    FREE --admit(prefill + first token)--> ACTIVE --EOS / max-gen--> FREE

A slot is (re)filled the moment it frees up, so the decode program — one
jitted step for all S slots, multiplexed across each slot's *own* agent delta
— keeps running at full width under load.  The batcher is pure policy: it
owns no device state beyond what the engine exposes, and no clock — the load
generator (:mod:`repro.serve.load`) owns time and stamps the request records.

Sampling: greedy argmax by default; with ``temperature > 0`` tokens are drawn
from per-request PRNG streams domain-separated as ``fold_in(fold_in(key,
_SAMPLE_TAG), rid)`` then per-step — no key is ever reused across requests,
steps, or with the parameter-init stream (the PR 8 determinism conventions).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import jax
import numpy as np

from repro.serve.engine import DecodeEngine

_SAMPLE_TAG = 0x5A3B1E  # domain tag for the sampling stream


@dataclasses.dataclass
class Request:
    """One generation request plus its recorded lifecycle.

    Timestamps are on the load generator's (simulated) clock, in seconds;
    ``prefill_s`` / ``decode_s`` accumulate the engine time attributed to this
    request, so ``latency ≈ queue_wait + prefill + decode`` by construction.
    """

    rid: int
    agent_id: int
    prompt: np.ndarray  # (L,) int32
    max_new_tokens: int
    eos_id: Optional[int] = None
    arrival_s: float = 0.0
    admit_s: Optional[float] = None
    first_token_s: Optional[float] = None
    done_s: Optional[float] = None
    prefill_s: float = 0.0
    decode_s: float = 0.0
    tokens: List[int] = dataclasses.field(default_factory=list)
    # Which decode slot served this request (set at admission) — the key the
    # obs layer groups per-slot occupancy metrics by.
    slot: Optional[int] = None

    @property
    def queue_wait_s(self) -> float:
        return (self.admit_s or 0.0) - self.arrival_s

    @property
    def latency_s(self) -> float:
        return (self.done_s or 0.0) - self.arrival_s

    def breakdown(self) -> dict:
        return {
            "rid": self.rid,
            "agent": self.agent_id,
            "slot": self.slot,
            "tokens": len(self.tokens),
            "queue_wait_s": self.queue_wait_s,
            "prefill_s": self.prefill_s,
            "decode_s": self.decode_s,
            "latency_s": self.latency_s,
        }


class ContinuousBatcher:
    """Admit-on-free-slot / evict-on-EOS-or-max-gen over a fixed-slot engine."""

    def __init__(
        self,
        engine: DecodeEngine,
        *,
        temperature: float = 0.0,
        seed: int = 0,
    ):
        self.engine = engine
        self.temperature = float(temperature)
        self._key = jax.random.fold_in(jax.random.PRNGKey(seed), _SAMPLE_TAG)
        self.slots: List[Optional[Request]] = [None] * engine.n_slots
        self._next_tok = np.zeros(engine.n_slots, dtype=np.int32)
        self.completed: List[Request] = []

    # -- state --------------------------------------------------------------

    def free_slots(self) -> List[int]:
        return [i for i, r in enumerate(self.slots) if r is None]

    @property
    def active(self) -> List[Request]:
        return [r for r in self.slots if r is not None]

    # -- sampling -----------------------------------------------------------

    def _sample(self, req: Request, logits: np.ndarray) -> int:
        if self.temperature <= 0.0:
            return int(np.argmax(logits))
        key = jax.random.fold_in(self._key, req.rid)
        key = jax.random.fold_in(key, len(req.tokens))
        return int(
            jax.random.categorical(
                key, jax.numpy.asarray(logits, jax.numpy.float32) / self.temperature
            )
        )

    def _emit(self, slot: int, req: Request, token: int) -> bool:
        """Record ``token`` for ``req``; evict if done.  Returns finished."""
        req.tokens.append(token)
        self._next_tok[slot] = token
        done = len(req.tokens) >= req.max_new_tokens or (
            req.eos_id is not None and token == req.eos_id
        )
        if done:
            self.slots[slot] = None
            self.completed.append(req)
        return done

    # -- lifecycle ----------------------------------------------------------

    def admit(self, req: Request) -> bool:
        """Prefill ``req`` into a free slot and emit its first token.

        Returns True when the request already finished at admission
        (``max_new_tokens == 1`` or an immediate EOS)."""
        free = self.free_slots()
        if not free:
            raise RuntimeError("admit() with no free slot — check free_slots()")
        slot = free[0]
        logits = self.engine.admit(slot, req.agent_id, req.prompt)
        self.slots[slot] = req
        req.slot = slot
        return self._emit(slot, req, self._sample(req, logits))

    def step(self) -> List[Request]:
        """One decode step for every occupied slot; returns newly finished
        requests (their slots are already freed)."""
        if not self.active:
            return []
        logits = self.engine.step(self._next_tok)
        finished = []
        for slot, req in enumerate(self.slots):
            if req is None:
                continue
            if self._emit(slot, req, self._sample(req, logits[slot])):
                finished.append(req)
        return finished
