"""Personalized fleets as shared base weights + compact per-agent deltas.

A trained semi-decentralized run produces *n* personalized parameter sets
(the agent-stacked ``X`` of the final algorithm state).  Storing them as *n*
dense copies is O(n · P) — hopeless for millions of agents.  This module
stores the fleet as one shared **base** pytree plus one compact **delta** per
agent, in one of three leaf representations selected by :class:`DeltaSpec`:

* ``dense``   — raw per-agent values (lossless, the trivial reference; same
  footprint as naive copies, used to pin the others);
* ``topk``    — the ``k = ceil(f·d)`` coordinates per leaf where the agent
  deviates most from the base, stored in **set-form**: ``(idx, val)`` where
  ``val`` holds the *raw* parameter values at those coordinates and
  materialization overwrites ``base[idx] = val``.  Set-form (rather than the
  additive ``base + (p - base)``) makes reconstruction **bit-exact by
  construction** whenever the index set covers every differing coordinate —
  no float cancellation caveats — which is the lossless case the serving
  bit-identity pin relies on.  With ``q8`` the stored payload switches to the
  int8-quantized *difference* plus one fp32 scale per (leaf, agent) row
  (additive reconstruction, error ≤ scale/2 per coordinate, deterministic
  rounding — the same wire format family as :mod:`repro.core.compression`);
* ``lowrank`` — a rank-``r`` SVD of the per-agent residual for ndim ≥ 2
  leaves (1-D leaves — norms, biases — fall back to ``dense``; they are a
  rounding error of the footprint).  Approximate; for serving studies of the
  quality/footprint frontier, not the bit-identity path.

``gather(arrays, ids)`` is the jit-facing entry the decode engine calls: it
reconstructs a *slot-stacked* parameter pytree for the (few) agents currently
scheduled in the decode batch, so only ``n_slots`` dense copies ever exist on
device no matter how large the fleet is.

Exporters close the train→checkpoint→serve loop: :meth:`FleetDelta.from_history`
consumes a finished :class:`~repro.core.trainer.History` (via its
``agent_params()`` hook) and :meth:`FleetDelta.from_checkpoint` consumes a
``repro.checkpoint`` file written during training (the algorithm-state tuple,
a ``{"x": stacked}`` dict, or a bare stacked pytree).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any

_QMAX = 127.0  # int8 symmetric grid


# ---------------------------------------------------------------------------
# Spec
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class DeltaSpec:
    """Declarative delta format: ``"dense" | "topk[:f=..][,q8] | lowrank[:r=..]"``."""

    kind: str = "topk"
    fraction: float = 0.05  # topk: kept fraction of each leaf
    rank: int = 4  # lowrank: SVD rank per ndim>=2 leaf
    quantize: bool = False  # topk: int8-quantize the residual payload

    def __post_init__(self):
        if self.kind not in ("dense", "topk", "lowrank"):
            raise ValueError(f"unknown delta kind {self.kind!r}")
        if not 0.0 < self.fraction <= 1.0:
            raise ValueError(f"fraction must be in (0, 1], got {self.fraction}")
        if self.rank < 1:
            raise ValueError(f"rank must be >= 1, got {self.rank}")
        if self.quantize and self.kind != "topk":
            raise ValueError("q8 only applies to kind='topk'")

    @classmethod
    def parse(cls, spec: str) -> "DeltaSpec":
        """``"topk:f=0.05,q8"`` / ``"lowrank:r=8"`` / ``"dense"``."""
        name, _, tail = spec.partition(":")
        kw: dict = {"kind": name}
        if tail:
            for item in tail.split(","):
                item = item.strip()
                if item == "q8":
                    kw["quantize"] = True
                    continue
                k, sep, v = item.partition("=")
                if not sep:
                    raise ValueError(f"bad delta spec item {item!r} in {spec!r}")
                if k == "f":
                    kw["fraction"] = float(v)
                elif k == "r":
                    kw["rank"] = int(v)
                else:
                    raise ValueError(f"unknown delta spec key {k!r} in {spec!r}")
        return cls(**kw)

    @property
    def name(self) -> str:
        if self.kind == "topk":
            return f"topk:f={self.fraction:g}" + (",q8" if self.quantize else "")
        if self.kind == "lowrank":
            return f"lowrank:r={self.rank}"
        return "dense"


# ---------------------------------------------------------------------------
# Per-leaf delta payloads (NamedTuples => pytree nodes, jit-traversable)
# ---------------------------------------------------------------------------


class DenseDelta(NamedTuple):
    val: jnp.ndarray  # (n,) + leaf.shape raw values


class TopKDelta(NamedTuple):
    idx: jnp.ndarray  # (n, k) int32 flat coordinates
    val: jnp.ndarray  # (n, k) raw parameter values (set-form)


class QTopKDelta(NamedTuple):
    idx: jnp.ndarray  # (n, k) int32 flat coordinates
    q: jnp.ndarray  # (n, k) int8 quantized residual
    scale: jnp.ndarray  # (n, 1) fp32 per-row dequant scale


class LowRankDelta(NamedTuple):
    u: jnp.ndarray  # (n, d1, r) fp32
    v: jnp.ndarray  # (n, r, d2) fp32


_DELTA_TYPES = (DenseDelta, TopKDelta, QTopKDelta, LowRankDelta)


def _is_delta(x) -> bool:
    return isinstance(x, _DELTA_TYPES)


# ---------------------------------------------------------------------------
# Per-leaf encode / gather
# ---------------------------------------------------------------------------


def _encode_leaf(stacked: np.ndarray, base: np.ndarray, spec: DeltaSpec):
    """Host-side: one agent-stacked leaf (n, *shape) -> a delta payload."""
    n = stacked.shape[0]
    rows = stacked.reshape(n, -1)
    d = rows.shape[1]
    if spec.kind == "dense" or (spec.kind == "lowrank" and base.ndim < 2):
        return DenseDelta(val=jnp.asarray(stacked))
    if spec.kind == "lowrank":
        d1 = base.shape[0]
        d2 = d // d1
        diff = (rows.astype(np.float32) - base.reshape(1, -1).astype(np.float32))
        diff = diff.reshape(n, d1, d2)
        r = min(spec.rank, d1, d2)
        u_out = np.zeros((n, d1, r), np.float32)
        v_out = np.zeros((n, r, d2), np.float32)
        for i in range(n):
            u, s, vt = np.linalg.svd(diff[i], full_matrices=False)
            u_out[i] = u[:, :r] * s[:r][None, :]
            v_out[i] = vt[:r]
        return LowRankDelta(u=jnp.asarray(u_out), v=jnp.asarray(v_out))
    # topk
    k = min(d, max(1, int(math.ceil(spec.fraction * d))))
    diff = rows.astype(np.float32) - base.reshape(1, -1).astype(np.float32)
    # largest-|residual| coordinates per agent row; sorted indices keep the
    # payload deterministic in the input (argpartition order is not)
    part = np.argpartition(np.abs(diff), d - k, axis=1)[:, d - k:]
    idx = np.sort(part, axis=1).astype(np.int32)
    take = np.take_along_axis
    if spec.quantize:
        dsel = take(diff, idx, axis=1)
        scale = np.maximum(np.max(np.abs(dsel), axis=1, keepdims=True), 1e-12)
        scale = (scale / _QMAX).astype(np.float32)
        q = np.clip(np.round(dsel / scale), -_QMAX, _QMAX).astype(np.int8)
        return QTopKDelta(idx=jnp.asarray(idx), q=jnp.asarray(q),
                          scale=jnp.asarray(scale))
    val = take(rows, idx, axis=1)  # raw values: set-form, bit-exact coverage
    return TopKDelta(idx=jnp.asarray(idx), val=jnp.asarray(val))


def _gather_leaf(base: jnp.ndarray, delta, ids: jnp.ndarray) -> jnp.ndarray:
    """Jit-friendly: slot-stacked leaf (S, *shape) for the selected agents."""
    s = ids.shape[0]
    shape = base.shape
    if isinstance(delta, DenseDelta):
        return delta.val[ids]
    flat = base.reshape(-1)
    d = flat.shape[0]
    rows = jnp.broadcast_to(flat[None], (s, d))
    slot = jnp.arange(s)[:, None]
    if isinstance(delta, TopKDelta):
        rows = rows.at[slot, delta.idx[ids]].set(delta.val[ids].astype(base.dtype))
    elif isinstance(delta, QTopKDelta):
        corr = delta.q[ids].astype(jnp.float32) * delta.scale[ids]
        rows = rows.at[slot, delta.idx[ids]].add(corr.astype(base.dtype))
    elif isinstance(delta, LowRankDelta):
        corr = jnp.einsum("sir,srj->sij", delta.u[ids], delta.v[ids])
        rows = rows + corr.reshape(s, d).astype(base.dtype)
    else:
        raise TypeError(f"not a delta payload: {type(delta)}")
    return rows.reshape((s,) + shape)


# ---------------------------------------------------------------------------
# Fleet containers
# ---------------------------------------------------------------------------


def _tree_nbytes(tree: PyTree) -> int:
    return sum(
        int(np.asarray(leaf).size) * np.dtype(np.asarray(leaf).dtype).itemsize
        for leaf in jax.tree.leaves(tree)
    )


@dataclasses.dataclass(frozen=True)
class FleetDelta:
    """A servable fleet: shared ``base`` + per-agent compact ``deltas``.

    ``deltas`` mirrors the structure of ``base`` with a delta payload
    (NamedTuple of agent-stacked arrays) at every leaf position.
    """

    base: PyTree
    deltas: PyTree
    spec: DeltaSpec
    n_agents: int

    # -- construction -------------------------------------------------------

    @classmethod
    def from_stacked(
        cls, stacked: PyTree, spec: DeltaSpec, base: Optional[PyTree] = None
    ) -> "FleetDelta":
        """Encode an agent-stacked params pytree (leading axis = agents).

        ``base`` defaults to the agent mean (the consensus point a converged
        semi-decentralized run hovers around, so residuals are small).
        """
        stacked = jax.tree.map(np.asarray, stacked)
        n = jax.tree.leaves(stacked)[0].shape[0]
        if base is None:
            base = jax.tree.map(
                lambda l: l.mean(axis=0, dtype=np.float64).astype(l.dtype), stacked
            )
        else:
            base = jax.tree.map(np.asarray, base)
        deltas = jax.tree.map(
            lambda l, b: _encode_leaf(l, b, spec), stacked, base
        )
        return cls(
            base=jax.tree.map(jnp.asarray, base), deltas=deltas, spec=spec,
            n_agents=int(n),
        )

    @classmethod
    def from_history(
        cls, hist, spec: DeltaSpec, base: Optional[PyTree] = None
    ) -> "FleetDelta":
        """Export the servable fleet from a finished ``Experiment.run``."""
        return cls.from_stacked(hist.agent_params(), spec, base=base)

    @classmethod
    def from_checkpoint(
        cls, path: str, spec: DeltaSpec, base: Optional[PyTree] = None
    ) -> "FleetDelta":
        """Export from a ``repro.checkpoint`` file.  Accepts the algorithm
        state tuple the training launchers save (X first), a ``{"x": ...}``
        dict, or a bare agent-stacked params pytree."""
        from repro.checkpoint import restore_checkpoint

        _, tree = restore_checkpoint(path)
        return cls.from_stacked(_stacked_of(tree), spec, base=base)

    @classmethod
    def synthetic(
        cls,
        base: PyTree,
        n_agents: int,
        *,
        fraction: float = 0.02,
        scale: float = 0.05,
        seed: int = 0,
    ) -> "FleetDelta":
        """A stand-in personalized fleet (no training): each agent perturbs a
        random ``fraction`` of each leaf's coordinates.  Built directly in
        delta form — the n-times-dense stack is never materialized — so
        launchers and benchmarks can exercise large fleets cheaply.  The
        resulting top-k deltas are lossless by construction (the index set is
        exactly the perturbed set)."""
        rng = np.random.default_rng([seed, 0x5EED])
        base_np = jax.tree.map(np.asarray, base)

        def one(leaf: np.ndarray):
            d = int(leaf.size)
            k = min(d, max(1, int(math.ceil(fraction * d))))
            idx = np.stack(
                [np.sort(rng.choice(d, size=k, replace=False)) for _ in range(n_agents)]
            ).astype(np.int32)
            noise = rng.normal(scale=scale, size=(n_agents, k)).astype(np.float32)
            val = leaf.reshape(-1)[idx].astype(np.float32) + noise
            return TopKDelta(idx=jnp.asarray(idx), val=jnp.asarray(val))

        deltas = jax.tree.map(one, base_np)
        spec = DeltaSpec(kind="topk", fraction=fraction)
        return cls(
            base=jax.tree.map(jnp.asarray, base_np), deltas=deltas, spec=spec,
            n_agents=n_agents,
        )

    # -- jit-facing ---------------------------------------------------------

    @property
    def arrays(self) -> tuple:
        """The device-array pytree jitted engines take as an argument."""
        return (self.base, self.deltas)

    @staticmethod
    def gather(arrays: tuple, ids: jnp.ndarray) -> PyTree:
        """Slot-stacked params (S, ...) for agent ids (S,) — pure, jit-safe."""
        base, deltas = arrays
        # tree.map flattens ``deltas`` only down to ``base``'s leaf positions,
        # so each delta payload (a NamedTuple) arrives at ``_gather_leaf`` whole
        return jax.tree.map(lambda b, dl: _gather_leaf(b, dl, ids), base, deltas)

    # -- accounting ---------------------------------------------------------

    def nbytes(self) -> int:
        """Fleet-weights footprint: base + all per-agent delta payloads."""
        return _tree_nbytes(self.base) + _tree_nbytes(self.deltas)

    def naive_nbytes(self) -> int:
        """What n dense per-agent copies would cost."""
        return self.n_agents * _tree_nbytes(self.base)


@dataclasses.dataclass(frozen=True)
class DenseFleet:
    """The naive baseline: n dense parameter copies, gathered by row."""

    stacked: PyTree
    n_agents: int

    @classmethod
    def from_stacked(cls, stacked: PyTree) -> "DenseFleet":
        n = jax.tree.leaves(stacked)[0].shape[0]
        return cls(stacked=jax.tree.map(jnp.asarray, stacked), n_agents=int(n))

    @property
    def arrays(self) -> PyTree:
        return self.stacked

    @staticmethod
    def gather(arrays: PyTree, ids: jnp.ndarray) -> PyTree:
        return jax.tree.map(lambda l: l[ids], arrays)

    def nbytes(self) -> int:
        return _tree_nbytes(self.stacked)

    def naive_nbytes(self) -> int:
        return self.nbytes()


# ---------------------------------------------------------------------------
# Materialization + export glue
# ---------------------------------------------------------------------------


def materialize(
    base: PyTree, deltas: PyTree, agents: Optional[Sequence[int]] = None
) -> PyTree:
    """Reconstruct dense parameters from ``(base, deltas)``.

    ``agents=None`` materializes every agent (leading axis = fleet);
    otherwise the given agent ids.  Bit-exact for lossless deltas (dense
    payloads always; top-k set-form whenever the index set covers every
    coordinate where the agent deviates from the base)."""
    n = jax.tree.leaves(deltas)[0].shape[0]
    ids = jnp.arange(n) if agents is None else jnp.asarray(agents, jnp.int32)
    return FleetDelta.gather((base, deltas), ids)


def materialize_fleet(fleet: FleetDelta) -> DenseFleet:
    """The dense-materialized baseline of the same personalized fleet."""
    return DenseFleet.from_stacked(materialize(fleet.base, fleet.deltas))


def _stacked_of(tree: PyTree) -> PyTree:
    """Find the agent-stacked params inside a restored checkpoint tree."""
    if isinstance(tree, dict):
        if "x" in tree:
            return tree["x"]
        return tree  # bare stacked params dict
    if isinstance(tree, (tuple, list)) and len(tree) > 0:
        return tree[0]  # algorithm state: X is the first field by convention
    return tree


def export_fleet(directory: str, hist, step: int = 0) -> str:
    """Write the agent-stacked final params of a finished run as a fleet
    checkpoint (``{"x": stacked}`` + a ``kind: fleet`` manifest tag) that
    :meth:`FleetDelta.from_checkpoint` consumes directly."""
    from repro.checkpoint import save_checkpoint

    return save_checkpoint(
        directory, step, {"x": jax.tree.map(np.asarray, hist.agent_params())},
        metadata={"kind": "fleet"},
    )
