"""Round-driver microbenchmark: host loop vs chunked lax.scan vs events.

Runs the quick Fig.-4 setting (§5.1 logreg workload, 10-agent ring, p = 0.1)
under all three drivers with identical specs and batches, three reps each
with reused compiled functions, and writes ``BENCH_driver.json``.

Batches for all rounds are drawn and cached *outside* the timed region (the
data pipeline is identical for every driver and is not what a round driver
changes), so the readout isolates the driver's own cost — and separates the
one-time tracing cost from the steady state (a cold scan drive is
compile-dominated, which made raw cold-vs-cold comparisons dishonest):

* ``compile_s``   — one-time trace/compile cost, estimated as the first
  drive's wall time minus the best warm drive's (both run the identical
  round sequence, so the difference is jit tracing + XLA compilation);
* ``per_round_s`` — best warm drive per round, compile amortized: dispatch +
  sync overhead — one device sync per *block* for the scan/events drivers vs
  three scalar device→host syncs per *round* for the legacy loop.

The events driver runs under the degenerate ``FREE_NETWORK`` fleet, so its
device program is bit-identical to scan's and the comparison is pure driver
overhead (event-clock simulation + operand plumbing).

    PYTHONPATH=src python -m benchmarks.bench_driver
"""
from __future__ import annotations

import dataclasses

import jax

from benchmarks.common import make_logreg_workload, save_result
from repro.core import ExperimentSpec, get_algorithm, replicate_params
from repro.core.driver import (
    drive_loop,
    drive_scan,
    make_block_fn,
    predraw_schedule,
    stack_rounds,
)
from repro.core.compression import make_byte_model
from repro.core.schedule import make_schedule
from repro.core.trainer import History, record_wall_time
from repro.data import RoundSampler
from repro.obs.profile import profile_capture, track_compile_time
from repro.sim import FREE_NETWORK


class _CachedSampler:
    """Replays pre-drawn batches; memoizes the stacked blocks the scan driver
    asks for, so warm reps measure pure driver overhead."""

    def __init__(self, sampler, rounds: int):
        self._batches = {k: sampler(k) for k in range(-1, rounds)}
        self._blocks = {}

    def __call__(self, k: int):
        return self._batches[k]

    def sample_block(self, start: int, stop: int):
        key = (start, stop)
        if key not in self._blocks:
            batches = [self._batches[k] for k in range(start, stop)]
            self._blocks[key] = (
                stack_rounds([b[0] for b in batches]),
                stack_rounds([b[1] for b in batches]),
            )
        return self._blocks[key]


def _drive_reps(driver: str, *, rounds: int, eval_every: int, quick: bool):
    """Three identical drives over cached batches (fresh schedule each),
    reusing the jitted round program between them: one cold, two warm."""
    data, loss_fn, eval_fn, params0 = make_logreg_workload(quick=quick, seed=0)
    spec = ExperimentSpec.create(
        algo="pisco", n_agents=data.n_agents, t_o=1, eta_l=0.5, p=0.1, seed=0,
        rounds=rounds, eval_every=eval_every, driver=driver,
        systems=FREE_NETWORK if driver == "events" else None,
    )
    mixing = spec.make_mixing()
    bound = get_algorithm(spec.algo).bind(loss_fn, spec.config, mixing)
    x0 = replicate_params(params0, spec.config.n_agents)
    if driver == "scan":
        compiled = {"block_fn": make_block_fn(bound)}
        drive = drive_scan
        extra = {"block_size": spec.block_size}
    elif driver == "events":
        from repro.events.clock import make_event_engine
        from repro.events.driver import drive_events

        byte_model = make_byte_model(
            mixing, x0, spec.config.n_agents,
            mixes_per_round=bound.comm.mixes_per_round,
            server_payloads=bound.comm.server_payloads,
        )
        engine = make_event_engine(
            spec, byte_model,
            predraw_schedule(bound.schedule, 0, rounds),
            network=mixing.network,
        )
        assert engine.trivial  # FREE_NETWORK: same device program as scan
        compiled = {"block_fn": make_block_fn(bound)}
        drive = drive_events
        extra = {"block_size": spec.block_size, "engine": engine}
    else:
        gj = jax.jit(bound.gossip_round)
        sj = jax.jit(bound.global_round)
        compiled = {"round_fns": (gj, sj)}
        drive = drive_loop
        extra = {}

    sampler = _CachedSampler(
        RoundSampler(data, batch_size=256, t_o=1, seed=0), rounds
    )
    out = []
    for _rep in range(3):
        # fresh identically-seeded schedule per rep; replace() keeps the
        # round-fn objects (and their jit cache) intact
        b = dataclasses.replace(
            bound, schedule=make_schedule(spec.config.p, spec.config.seed)
        )
        _, comm0 = sampler(-1)
        state = b.init(loss_fn, x0, comm0)
        hist = History(
            byte_model=make_byte_model(
                mixing, x0, spec.config.n_agents,
                mixes_per_round=b.comm.mixes_per_round,
                server_payloads=b.comm.server_payloads,
            )
        )
        with record_wall_time(hist):
            state = drive(
                b, state, sampler, rounds, hist,
                eval_fn=eval_fn, eval_every=eval_every, **extra, **compiled,
            )
        hist.final_state = state
        out.append(hist)
    return out


def run(quick: bool = True, profile_dir: str | None = None) -> dict:
    rounds = 150 if quick else 600
    eval_every = 25 if quick else 50
    results = {}
    with profile_capture(profile_dir):
        for driver in ("loop", "scan", "events"):
            # all three reps share the jit cache, so compilation only happens
            # inside the cold drive — the listener-measured XLA seconds
            # cross-check the wall-clock compile_s estimate below
            with track_compile_time() as cstats:
                cold, *warms = _drive_reps(
                    driver, rounds=rounds, eval_every=eval_every, quick=quick
                )
            warm = min(warms, key=lambda h: h.wall_time_s)
            results[driver] = {
                "driver": driver,
                "rounds": rounds,
                "eval_every": eval_every,
                # one-time trace/compile cost vs steady-state per-round cost —
                # reported separately so cold-vs-cold (compile-dominated) never
                # masquerades as a per-round comparison
                "compile_s": max(cold.wall_time_s - warm.wall_time_s, 0.0),
                "cold_wall_s": cold.wall_time_s,
                "per_round_s": warm.wall_time_s / rounds,
                "final_loss": warm.loss[-1],
                "a2a_rounds": warm.accountant.agent_to_agent,
                "a2s_rounds": warm.accountant.agent_to_server,
            }
            if cstats.supported:
                results[driver]["compile_events_s"] = cstats.seconds
                results[driver]["compile_events"] = dict(cstats.events)
    speedup = results["loop"]["per_round_s"] / max(
        results["scan"]["per_round_s"], 1e-12
    )
    payload = {
        "bench": "driver",
        "quick": quick,
        "results": results,
        "speedup": speedup,
        "events_speedup": results["loop"]["per_round_s"]
        / max(results["events"]["per_round_s"], 1e-12),
    }
    save_result("BENCH_driver", payload)
    return payload


def main() -> None:
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument(
        "--profile", default=None, metavar="DIR",
        help="capture a jax.profiler device trace of the sweep into DIR",
    )
    args = ap.parse_args()
    payload = run(quick=True, profile_dir=args.profile)
    for d in ("loop", "scan", "events"):
        r = payload["results"][d]
        print(
            f"{d:>6}:  compile {r['compile_s']:6.2f} s | "
            f"steady {r['per_round_s']*1e3:7.2f} ms/round  "
            f"(loss {r['final_loss']:.4f})"
        )
    print(
        f"warm speedup vs loop: scan {payload['speedup']:.2f}x, "
        f"events {payload['events_speedup']:.2f}x"
    )


if __name__ == "__main__":
    main()
