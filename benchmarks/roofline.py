"""Roofline aggregation: read artifacts/dryrun/*.json and emit the
per-(arch x shape x mesh x step) table for EXPERIMENTS.md §Roofline."""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List, Optional


def load_records(art_dir: str = "artifacts/dryrun") -> List[dict]:
    """Load dry-run records, degrading gracefully: a missing directory
    yields an empty list (CI smoke runs before any dry-run has happened),
    and malformed/unreadable files become ``status="load-error"`` records
    instead of crashing the whole aggregation."""
    recs = []
    for path in sorted(glob.glob(os.path.join(art_dir, "*.json"))):
        try:
            with open(path) as f:
                rec = json.load(f)
            if not isinstance(rec, dict):
                raise ValueError(f"expected a JSON object, got {type(rec).__name__}")
        except (OSError, ValueError) as exc:
            rec = {
                "status": "load-error",
                "arch": os.path.basename(path),
                "shape": "-",
                "mesh": "-",
                "error": str(exc),
            }
        recs.append(rec)
    return recs


def fmt_table(recs: List[dict], mesh: Optional[str] = "single") -> str:
    rows = []
    header = (
        "| arch | shape | step | FLOPs/dev | HBM B/dev | coll B/dev | "
        "compute s | memory s | coll s | dominant | useful |"
    )
    sep = "|" + "---|" * 11
    rows.append(header)
    rows.append(sep)
    for r in recs:
        if r.get("status") != "ok" or not r.get("roofline"):
            continue
        if mesh and r.get("mesh") != mesh:
            continue
        if r.get("step") == "train_global":
            continue  # table shows the gossip (technique) round; global in §Dry-run
        ro = r["roofline"]
        useful = f"{ro['useful_ratio']:.2f}" if ro.get("useful_ratio") else "-"
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['step']} "
            f"| {ro['flops_per_device']:.2e} | {ro['hbm_bytes_per_device']:.2e} "
            f"| {ro['collective_bytes_per_device']:.2e} "
            f"| {ro['compute_s']:.2e} | {ro['memory_s']:.2e} | {ro['collective_s']:.2e} "
            f"| **{ro['dominant']}** | {useful} |"
        )
    return "\n".join(rows)


def summarize(recs: List[dict]) -> Dict:
    """Aggregate counts; total on no/partial records (an ``ok`` record
    missing its roofline payload counts as a failure, not a crash)."""
    ok = [r for r in recs if r.get("status") == "ok" and r.get("roofline")]
    fails = [r for r in recs if r not in ok]
    doms: Dict[str, int] = {}
    for r in ok:
        dom = r["roofline"].get("dominant", "?")
        doms[dom] = doms.get(dom, 0) + 1
    worst = sorted(
        (
            r
            for r in ok
            if r.get("mesh") == "single" and r["roofline"].get("useful_ratio")
        ),
        key=lambda r: r["roofline"]["useful_ratio"],
    )
    most_coll = sorted(
        (r for r in ok if r.get("mesh") == "single"),
        key=lambda r: -r["roofline"].get("collective_s", 0.0),
    )
    return {
        "n_ok": len(ok),
        "n_fail": len(fails),
        "dominant_counts": doms,
        "worst_useful": [
            (r.get("arch"), r.get("shape"), r.get("step"),
             r["roofline"]["useful_ratio"])
            for r in worst[:5]
        ],
        "most_collective_bound": [
            (r.get("arch"), r.get("shape"), r.get("step"),
             r["roofline"].get("collective_s", 0.0))
            for r in most_coll[:5]
        ],
        "failures": [
            (r.get("arch", "?"), r.get("shape", "?"), r.get("mesh", "?"),
             r.get("error", "?"))
            for r in fails
        ],
    }


def main():
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="artifacts/dryrun")
    ap.add_argument("--mesh", default="single")
    args = ap.parse_args()
    recs = load_records(args.dir)
    print(fmt_table(recs, args.mesh))
    print()
    s = summarize(recs)
    print(f"ok={s['n_ok']} fail={s['n_fail']} dominant={s['dominant_counts']}")
    print("worst useful_ratio:", s["worst_useful"])
    print("most collective-bound:", s["most_collective_bound"])
    for f in s["failures"]:
        print("FAIL:", f)


if __name__ == "__main__":
    main()
