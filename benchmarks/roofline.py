"""Roofline aggregation: read artifacts/dryrun/*.json and emit the
per-(arch x shape x mesh x step) table for EXPERIMENTS.md §Roofline."""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List, Optional


def load_records(art_dir: str = "artifacts/dryrun") -> List[dict]:
    recs = []
    for path in sorted(glob.glob(os.path.join(art_dir, "*.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def fmt_table(recs: List[dict], mesh: Optional[str] = "single") -> str:
    rows = []
    header = (
        "| arch | shape | step | FLOPs/dev | HBM B/dev | coll B/dev | "
        "compute s | memory s | coll s | dominant | useful |"
    )
    sep = "|" + "---|" * 11
    rows.append(header)
    rows.append(sep)
    for r in recs:
        if r.get("status") != "ok":
            continue
        if mesh and r["mesh"] != mesh:
            continue
        if r["step"] == "train_global":
            continue  # table shows the gossip (technique) round; global in §Dry-run
        ro = r["roofline"]
        useful = f"{ro['useful_ratio']:.2f}" if ro.get("useful_ratio") else "-"
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['step']} "
            f"| {ro['flops_per_device']:.2e} | {ro['hbm_bytes_per_device']:.2e} "
            f"| {ro['collective_bytes_per_device']:.2e} "
            f"| {ro['compute_s']:.2e} | {ro['memory_s']:.2e} | {ro['collective_s']:.2e} "
            f"| **{ro['dominant']}** | {useful} |"
        )
    return "\n".join(rows)


def summarize(recs: List[dict]) -> Dict:
    ok = [r for r in recs if r.get("status") == "ok"]
    fails = [r for r in recs if r.get("status") != "ok"]
    doms: Dict[str, int] = {}
    for r in ok:
        doms[r["roofline"]["dominant"]] = doms.get(r["roofline"]["dominant"], 0) + 1
    worst = sorted(
        (r for r in ok if r["mesh"] == "single" and r["roofline"].get("useful_ratio")),
        key=lambda r: r["roofline"]["useful_ratio"],
    )
    most_coll = sorted(
        (r for r in ok if r["mesh"] == "single"),
        key=lambda r: -r["roofline"]["collective_s"],
    )
    return {
        "n_ok": len(ok),
        "n_fail": len(fails),
        "dominant_counts": doms,
        "worst_useful": [
            (r["arch"], r["shape"], r["step"], r["roofline"]["useful_ratio"])
            for r in worst[:5]
        ],
        "most_collective_bound": [
            (r["arch"], r["shape"], r["step"], r["roofline"]["collective_s"])
            for r in most_coll[:5]
        ],
        "failures": [
            (r["arch"], r["shape"], r["mesh"], r.get("error", "?")) for r in fails
        ],
    }


def main():
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="artifacts/dryrun")
    ap.add_argument("--mesh", default="single")
    args = ap.parse_args()
    recs = load_records(args.dir)
    print(fmt_table(recs, args.mesh))
    print()
    s = summarize(recs)
    print(f"ok={s['n_ok']} fail={s['n_fail']} dominant={s['dominant_counts']}")
    print("worst useful_ratio:", s["worst_useful"])
    print("most collective-bound:", s["most_collective_bound"])
    for f in s["failures"]:
        print("FAIL:", f)


if __name__ == "__main__":
    main()
