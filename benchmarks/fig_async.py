"""When does async win?  Sync barrier vs event-queue execution (DESIGN.md §13).

The synchronous drivers price every round at the slowest realized agent/edge
— the barrier.  The events driver replaces it with per-agent clocks, bounded-
staleness gossip, and a buffered staleness-weighted server aggregator.  This
benchmark runs the same §5.1 logreg workload both ways under three fleets and
writes ``BENCH_async.json``.

Claims exercised:

* **degenerate fleet** (``FREE_NETWORK``: uniform compute, free links) — the
  events driver detects the trivial regime and its loss trajectory is
  **bit-identical** to the scan driver's; async costs nothing and buys
  nothing, exactly as it should;
* **straggler/wan fleets** (``lognormal-stragglers``: slowest agent gates
  every barrier round; ``wan-gossip``: slow heterogeneous peer links) — the
  barrier pays the tail every round while the async run drops stale agents
  from gossip gating and fires server rounds at the m-th push, so simulated
  **time-to-target flips from sync-best to async-best**;
* **repricing** — the async run's frozen event trace re-prices under another
  profile without re-training, and under its own profile reproduces the
  online ``sim_time_s`` ledger exactly.

    PYTHONPATH=src python -m benchmarks.fig_async [--quick]
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import make_logreg_workload, save_result
from repro.core import ExperimentSpec
from repro.core.experiment import Experiment
from repro.data import RoundSampler
from repro.sim import FREE_NETWORK, price_history
from repro.sim.tuner import _smoothed

PROFILES_SWEPT = (
    ("free", FREE_NETWORK),
    ("lognormal-stragglers", "lognormal-stragglers"),
    ("wan-gossip", "wan-gossip"),
)


def _readout(hist, target: float, window: int) -> dict:
    series = _smoothed(hist.loss, window)
    secs = np.cumsum(np.asarray(hist.sim_time_s, dtype=np.float64))
    # the heterogeneous logreg trajectory dips below its consensus value in
    # the first few local-overfit rounds, then climbs to a peak and descends;
    # "time to target" means the descent crossing, so search from the peak
    start = int(np.argmax(series))
    hits = start + np.nonzero(series[start:] <= target)[0]
    out = {
        "rounds": len(hist.loss),
        "final_loss": float(series[-1]),
        "total_sim_time_s": float(secs[-1]) if secs.size else 0.0,
        "time_to_target_s": float(secs[hits[0]]) if hits.size else None,
    }
    if hist.staleness:
        out["peak_staleness"] = int(np.max(hist.staleness))
    return out


def run(quick: bool = True, seed: int = 0) -> dict:
    rounds = 200 if quick else 600
    window = max(1, min(20, rounds // 10))
    data, loss_fn, _eval_fn, params0 = make_logreg_workload(quick=quick, seed=seed)
    n = data.n_agents
    b = min(256, data.samples_per_agent)
    pieces = dict(
        loss_fn=loss_fn,
        params0=params0,
        sampler_factory=lambda s: RoundSampler(
            data, batch_size=b, t_o=s.config.t_o, seed=s.config.seed
        ),
    )
    async_cfg = f"poly:alpha=0.5,bound=2,buffer={max(2, n // 2)}"

    profiles = {}
    reprice = None
    for label, prof in PROFILES_SWEPT:
        sync_spec = ExperimentSpec.create(
            algo="pisco", n_agents=n, t_o=2, eta_l=0.1, p=0.1, seed=seed,
            rounds=rounds, eval_every=rounds, driver="scan", systems=prof,
        )
        async_spec = sync_spec.replace(driver="events", async_=async_cfg)
        h_sync = Experiment(sync_spec, **pieces).run()
        h_async = Experiment(async_spec, **pieces).run()
        target = 1.05 * max(
            float(_smoothed(h_sync.loss, window)[-1]),
            float(_smoothed(h_async.loss, window)[-1]),
        )
        cell = {
            "systems": prof,
            "target_loss": target,
            "sync": _readout(h_sync, target, window),
            "async": _readout(h_async, target, window),
            # the degenerate-fleet acceptance pin: identical device programs
            "bit_identical_loss": list(h_sync.loss) == list(h_async.loss),
        }
        profiles[label] = cell
        if label == "wan-gossip":
            # satellite: event-trace repricing — same profile must reproduce
            # the online ledger exactly; other profiles come for free
            same = price_history(h_async, async_spec)
            reprice = {
                "self_exact": bool(
                    np.array_equal(same, np.asarray(h_async.sim_time_s))
                ),
                "under_stragglers_total_s": float(
                    price_history(
                        h_async, async_spec, systems="lognormal-stragglers"
                    ).sum()
                ),
            }

    payload = {
        "bench": "fig_async",
        "quick": quick,
        "async_config": async_cfg,
        "profiles": profiles,
        "reprice": reprice,
    }
    save_result("BENCH_async", payload)
    return payload


def async_flip(profiles: dict):
    """Per-profile sync/async simulated-time speedup — the flip readout.

    Uses time-to-target when both runs reach it, else total simulated time
    (same executed round count either way).  > 1 means async is faster."""
    out = {}
    for label, cell in profiles.items():
        s, a = cell["sync"], cell["async"]
        if s["time_to_target_s"] is not None and a["time_to_target_s"] is not None:
            out[label] = s["time_to_target_s"] / max(a["time_to_target_s"], 1e-12)
        else:
            out[label] = s["total_sim_time_s"] / max(a["total_sim_time_s"], 1e-12)
    return out


def main():
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    payload = run(quick=args.quick)
    speed = async_flip(payload["profiles"])
    print(f"async config: {payload['async_config']}")
    print(f"{'profile':>22} | {'sync s->tgt':>11} | {'async s->tgt':>12} | "
          f"{'speedup':>7} | bit-identical")
    for label, cell in payload["profiles"].items():
        fmt = lambda v: f"{v:.2f}" if v is not None else "---"
        print(f"{label:>22} | {fmt(cell['sync']['time_to_target_s']):>11} | "
              f"{fmt(cell['async']['time_to_target_s']):>12} | "
              f"{speed[label]:7.2f} | {cell['bit_identical_loss']}")
    if payload["reprice"]:
        print(f"event-trace reprice self-exact: {payload['reprice']['self_exact']}")


if __name__ == "__main__":
    main()
