"""How many Byzantine agents can PISCO survive?  (DESIGN.md §14)

The §5.1 logreg workload (iid split) on n=16 agents with f = ⌈n/5⌉ = 4
sign-flippers (``adversary="signflip:f=0.2"``): the corrupted agents
transmit ``-x`` in every payload while the honest twelve run PISCO
unchanged.  The attack targets a *warm* fleet — a clean pretraining phase
first converges the model, then the Byzantine agents switch on — because a
sign-flip attack from a zero init is degenerate in an instructive way: while
``‖x‖`` is below the per-coordinate batch-noise floor, flipped payloads are
statistically indistinguishable from honest ones, every symmetric
aggregation rule halves the mean each round, and the model self-locks at
the origin (the benchmark's ``origin_trap`` row records this regime).

From the warm point, in the federated regime (p=1.0, every round a server
round — Remark 2) all communication passes through the server rule, so the
rule *is* the defense:

* **plain mean** — four flipped uploads contract the aggregate by
  (n−2f)/n per round; the trained model collapses to the origin trap and
  final loss lands far from the clean run's;
* **trimmed mean** (``robust_agg="trimmed"``, trims ⌈f·n⌉ per side) — the
  flipped coordinates are outliers relative to the warm iterate and get
  discarded; final loss stays within 10% of the clean continuation — the
  robustness flip ``BENCH_robust.json`` pins.  **median** matches it;
* **krum** — selects one agent's whole vector, which feeds single-agent
  batch noise into the gradient tracker every round (Lemma 1 only survives
  averaging); it degrades badly and is reported as a negative result.

A gossip-regime row (p=0.1, trimmed) documents the boundary: robust rules
guard *server* rounds only — corruption injected through gossip mixing
reaches honest agents between server rounds (the FedDec observation that
the p2p/server mix changes what a bad peer can corrupt).

    PYTHONPATH=src python -m benchmarks.fig_robust [--quick]
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import run_pisco_variant, save_result
from repro.data import FederatedDataset
from repro.data.synthetic import synthetic_a9a
from repro.models import simple as S
from repro.sim.tuner import _smoothed

N_AGENTS = 16
ADVERSARY = "signflip:f=0.2"  # ceil(0.2 * 16) = 4 Byzantine agents

ROWS = (
    # (label, adversary, robust_agg, p)
    ("clean", None, "mean", 1.0),
    ("signflip+mean", ADVERSARY, "mean", 1.0),
    ("signflip+trimmed", ADVERSARY, "trimmed", 1.0),
    ("signflip+median", ADVERSARY, "median", 1.0),
    ("signflip+krum", ADVERSARY, "krum", 1.0),
    # robust server rule with mostly-gossip rounds: corruption leaks through
    # the p2p path the server rule never sees
    ("signflip+trimmed@p0.1", ADVERSARY, "trimmed", 0.1),
)


def make_iid_workload(quick: bool, seed: int):
    """Logreg on the iid partition: honest uploads cluster tightly, so the
    Byzantine/robustness effect is isolated from heterogeneity bias (the
    sorted split's honest extremes would themselves be trimmed)."""
    n_samples = 4000 if quick else 32560
    x, y = synthetic_a9a(n_samples, seed=seed)
    data = FederatedDataset.from_arrays(
        x, y, N_AGENTS, heterogeneous=False, seed=seed
    )
    loss_fn = functools.partial(S.logreg_loss, rho=0.01)
    xe, ye = jnp.asarray(data.x_test), jnp.asarray(data.y_test)

    def eval_fn(params):
        return {"test_acc": float(S.logreg_accuracy(params, xe, ye))}

    return data, loss_fn, eval_fn, {"w": jnp.zeros((x.shape[1],), jnp.float32)}


def _readout(hist, window: int) -> dict:
    series = _smoothed(hist.loss, window)
    out = {
        "rounds": len(hist.loss),
        "final_loss": float(series[-1]),
        "final_test_acc": float(hist.eval_metrics[-1]["test_acc"]),
        "adversary_mask": hist.adversary_mask,
        "total_bytes": int(hist.accountant.total_bytes),
    }
    if hist.eval_per_agent:
        last = hist.eval_per_agent[-1]
        out["final_honest_test_acc"] = float(last["honest_test_acc"])
        out["final_byz_test_acc"] = float(last["byz_test_acc"])
    return out


def run(quick: bool = True, seed: int = 0) -> dict:
    rounds = 100 if quick else 300
    window = max(1, min(20, rounds // 10))
    data, loss_fn, eval_fn, params0 = make_iid_workload(quick, seed)

    # phase 1 — clean pretraining to a warm iterate (the model under attack)
    h_warm, _ = run_pisco_variant(
        data=data, loss_fn=loss_fn, eval_fn=eval_fn, params0=params0,
        p=1.0, t_o=2, eta_l=0.1, rounds=rounds, seed=seed, eval_every=rounds,
    )
    warm = jax.tree.map(lambda v: jnp.mean(v, axis=0), h_warm.final_state.x)

    # phase 2 — the Byzantine agents switch on; small steps keep the honest
    # noise floor below the flip separation (see module docstring)
    rows = {}
    for label, adversary, robust_agg, p in ROWS:
        hist, _ = run_pisco_variant(
            data=data, loss_fn=loss_fn, eval_fn=eval_fn, params0=warm,
            p=p, t_o=2, eta_l=0.02, rounds=rounds, seed=seed + 1,
            eval_every=max(1, rounds // 4),
            adversary=adversary, robust_agg=robust_agg,
        )
        rows[label] = _readout(hist, window)

    # the degenerate regime for the record: attacking a zero init self-locks
    # at the origin for every rule (loss pinned at ln 2)
    h_trap, _ = run_pisco_variant(
        data=data, loss_fn=loss_fn, eval_fn=eval_fn, params0=params0,
        p=1.0, t_o=2, eta_l=0.1, rounds=rounds, seed=seed,
        eval_every=rounds, adversary=ADVERSARY, robust_agg="trimmed",
    )

    clean = rows["clean"]["final_loss"]
    # the robustness flip: within 10% of the clean final loss or not
    within = lambda row: rows[row]["final_loss"] <= 1.10 * clean
    payload = {
        "bench": "fig_robust",
        "quick": quick,
        "n_agents": N_AGENTS,
        "adversary": ADVERSARY,
        "n_byzantine": int(np.sum(rows["signflip+mean"]["adversary_mask"])),
        "warm_final_loss": float(_smoothed(h_warm.loss, window)[-1]),
        "rows": rows,
        "origin_trap": _readout(h_trap, window),
        "clean_final_loss": clean,
        "trimmed_within_10pct": bool(within("signflip+trimmed")),
        "mean_within_10pct": bool(within("signflip+mean")),
        "robustness_flip": bool(
            within("signflip+trimmed") and not within("signflip+mean")
        ),
    }
    save_result("BENCH_robust", payload)
    return payload


def main():
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    payload = run(quick=args.quick)
    clean = payload["clean_final_loss"]
    print(f"n={payload['n_agents']}, adversary={payload['adversary']} "
          f"({payload['n_byzantine']} Byzantine), warm loss "
          f"{payload['warm_final_loss']:.4f}, clean final loss {clean:.4f}")
    print(f"{'variant':>24} | {'final loss':>10} | {'vs clean':>8} | "
          f"{'test acc':>8}")
    for label, row in payload["rows"].items():
        ratio = row["final_loss"] / max(clean, 1e-12)
        print(f"{label:>24} | {row['final_loss']:10.4f} | {ratio:8.2f}x | "
              f"{row['final_test_acc']:8.3f}")
    trap = payload["origin_trap"]
    print(f"{'origin trap (cold init)':>24} | {trap['final_loss']:10.4f} | "
          f"{'---':>8} | {trap['final_test_acc']:8.3f}")
    print(f"robustness flip (trimmed within 10%, mean not): "
          f"{payload['robustness_flip']}")


if __name__ == "__main__":
    main()
