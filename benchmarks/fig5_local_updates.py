"""Figure 5 reproduction: speedup from multiple local updates.

PISCO with T_o in {1, 10} and p in {1, 10^-0.5, 10^-1, 0} on the ring —
the paper reports ~50% fewer communication rounds at T_o=10 vs T_o=1 for
p=0.1, and p=0.1 performing on par with p=1.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import (
    comm_rounds_to_targets,
    make_logreg_workload,
    run_pisco_variant,
    save_result,
)

P_GRID = [1.0, 10**-0.5, 10**-1, 0.0]
T_GRID = [1, 10]


def run(quick: bool = False, seeds=(0, 1)) -> dict:
    rounds = 120 if quick else 500
    seeds = seeds[:1] if quick else seeds
    results = {}
    for t_o in T_GRID:
        for p in P_GRID:
            per_seed = []
            for seed in seeds:
                data, loss_fn, eval_fn, params0 = make_logreg_workload(
                    quick=quick, seed=seed
                )
                # same per-step budget: eta_l tuned down for larger T_o
                eta_l = 0.5 if t_o == 1 else 0.25
                hist, _ = run_pisco_variant(
                    data=data, loss_fn=loss_fn, eval_fn=eval_fn, params0=params0,
                    p=p, t_o=t_o, eta_l=eta_l, rounds=rounds, seed=seed,
                )
                out = comm_rounds_to_targets(hist, 0.002, 0.75)
                out["final_loss"] = hist.loss[-1]
                per_seed.append(out)
            key = f"T_o={t_o},p={p:.4f}"
            reached = [s["train"] for s in per_seed if s["train"]]
            results[key] = {
                "train_rounds": float(np.mean([r["rounds"] for r in reached]))
                if reached else None,
                "final_loss": float(np.mean([s["final_loss"] for s in per_seed])),
            }
    payload = {"bench": "fig5_local_updates", "quick": quick, "results": results}
    save_result("fig5_local_updates", payload)
    return payload


def main():
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    payload = run(quick=args.quick)
    print(f"{'config':>22} | {'rounds to 0.05':>14} | {'final loss':>10}")
    for key, r in payload["results"].items():
        rr = f"{r['train_rounds']:14.1f}" if r["train_rounds"] else f"{'n/a':>14}"
        print(f"{key:>22} | {rr} | {r['final_loss']:10.4f}")


if __name__ == "__main__":
    main()
