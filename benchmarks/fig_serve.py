"""Serving-path benchmark: delta-multiplexed continuous-batched decode.

Three readouts, all asserted in ``run()`` (DESIGN.md §15):

* **memory** — fleet-weights footprint of the delta representation vs naive
  ``n`` dense copies at fleet sizes up to 64+ agents (pin: >= 10x at n=64);
* **bit_identity** — token streams from the delta engine (both materialize
  modes) vs the dense-materialized baseline fleet under the same request
  trace (pin: identical for lossless top-k deltas);
* **rates** — measured tokens/s and p50/p99 request latency for the delta
  engine under Poisson traffic at two or more request rates.

    PYTHONPATH=src python -m benchmarks.fig_serve
"""
from __future__ import annotations

import jax

from benchmarks.common import save_result
from repro.models import ModelConfig, get_bundle
from repro.serve import (
    ArrivalProcess,
    ContinuousBatcher,
    DecodeEngine,
    FleetDelta,
    StepCosts,
    make_requests,
    materialize_fleet,
    run_load,
)

_INIT_TAG = 0x1217

TINY = ModelConfig(
    name="serve-tiny",
    arch_type="dense",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab_size=256,
    mlp_type="swiglu",
    dtype="float32",
    attn_chunk=64,
    remat=False,
)


def _tokens_of(report) -> dict:
    return {r.rid: list(r.tokens) for r in report.requests}


def _trace(fleet, n_requests, rate, seed=0, prompt_len=16, gen=8):
    return make_requests(
        ArrivalProcess(kind="poisson", rate=rate), n_requests,
        n_agents=fleet.n_agents, vocab_size=TINY.vocab_size,
        prompt_len=prompt_len, max_new_tokens=gen, seed=seed,
    )


def run(quick: bool = True) -> dict:
    bundle = get_bundle(TINY)
    base = bundle.init(jax.random.fold_in(jax.random.PRNGKey(0), _INIT_TAG))
    slots = 4
    n_requests = 10 if quick else 32
    gen = 8 if quick else 16
    max_seq = 16 + gen + 8

    # -- memory: delta vs naive dense copies over fleet sizes ---------------
    memory = {}
    for n in (8, 64) if quick else (8, 64, 256):
        f = FleetDelta.synthetic(base, n, seed=1)
        memory[str(n)] = {
            "n_agents": n,
            "delta_bytes": f.nbytes(),
            "naive_bytes": f.naive_nbytes(),
            "ratio": f.naive_nbytes() / f.nbytes(),
        }
    assert memory["64"]["ratio"] >= 10.0, (
        f"delta fleet must be >=10x smaller than dense copies at n=64, "
        f"got {memory['64']['ratio']:.1f}x"
    )

    # -- bit identity: delta engine (both modes) vs dense baseline ----------
    fleet = FleetDelta.synthetic(base, 16, seed=1)
    dense = materialize_fleet(fleet)
    costs = StepCosts(prefill_s=0.05, decode_s=0.01)
    streams = {}
    engines = {}
    for name, (fl, mode) in {
        "dense": (dense, "admit"),
        "delta_admit": (fleet, "admit"),
        "delta_step": (fleet, "step"),
    }.items():
        eng = DecodeEngine(
            bundle, fl, n_slots=slots, max_seq=max_seq, materialize=mode
        )
        rep = run_load(
            ContinuousBatcher(eng), _trace(fleet, n_requests, 4.0, gen=gen),
            costs=costs,
        )
        streams[name] = _tokens_of(rep)
        engines[name] = eng
    bit_identical = (
        streams["delta_admit"] == streams["dense"]
        and streams["delta_step"] == streams["dense"]
    )
    assert bit_identical, (
        "delta engine must be bit-identical to the dense-materialized "
        "baseline for lossless top-k deltas"
    )
    bit_identity = {
        "n_requests": n_requests,
        "admit_vs_dense": streams["delta_admit"] == streams["dense"],
        "step_vs_dense": streams["delta_step"] == streams["dense"],
    }

    # -- measured throughput/latency vs request rate ------------------------
    eng = engines["delta_admit"]
    # warm-up trace: absorb prefill/decode compiles before timing
    run_load(ContinuousBatcher(eng), _trace(fleet, 2, 100.0, gen=2))
    rates = {}
    for rate in (2.0, 8.0) if quick else (1.0, 4.0, 16.0):
        rep = run_load(
            ContinuousBatcher(eng), _trace(fleet, n_requests, rate, gen=gen)
        )
        row = {
            "rate": rate,
            "n_requests": len(rep.requests),
            "total_tokens": rep.total_tokens,
            "tokens_per_s": rep.tokens_per_s,
            "p50_s": rep.p50_s,
            "p99_s": rep.p99_s,
            "mean_queue_wait_s": rep.mean("queue_wait_s"),
        }
        assert row["tokens_per_s"] > 0, f"no throughput at rate={rate}"
        assert row["p99_s"] >= row["p50_s"] > 0
        rates[f"rate={rate:g}"] = row

    payload = {
        "quick": quick,
        "arch": TINY.name,
        "n_slots": slots,
        "memory": memory,
        "bit_identity": bit_identity,
        "rates": rates,
    }
    save_result("BENCH_serve", payload)
    return payload


def main() -> None:
    payload = run(quick=True)
    print(f"memory ratio @64 agents: {payload['memory']['64']['ratio']:.1f}x")
    print(f"bit identity: {payload['bit_identity']}")
    for k, v in payload["rates"].items():
        print(
            f"{k}: {v['tokens_per_s']:.1f} tok/s "
            f"p50={v['p50_s']*1e3:.1f}ms p99={v['p99_s']*1e3:.1f}ms"
        )


if __name__ == "__main__":
    main()
