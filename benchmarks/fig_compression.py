"""Compression sweep: bits-to-target-accuracy over compression ratio × p.

The new axis PISCO's round-saving (`p`, `T_o`) composes with: compressed
gossip (int8/int4 quantization, top-k + error feedback) shrinks every
agent-to-agent message, so the natural readout is *network bytes* — not
rounds — when the running-mean gradient norm first crosses the target (the
Fig.-4 protocol with bits on the x-axis).

Paper-claims extended:
* int8/int4 gossip reaches the uncompressed target at a fraction of the
  gossip bytes, with round counts within ~2x;
* compression composes with semi-decentralization: the best (compressor, p)
  cell beats both axes used alone.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import (
    comm_rounds_to_targets,
    make_logreg_workload,
    run_pisco_variant,
    save_result,
)

COMPRESSORS = [None, "q8", "q4", "top0.1"]
P_GRID = [0.0, 0.05, 0.1, 0.3]


def _bytes_to_target(hist, grad_target: float):
    """(rounds, gossip_bytes, server_bytes, total_bytes) at first crossing."""
    r = hist.rounds_to_threshold("grad_sq", grad_target, mode="running_le")
    if r is None:
        return None
    n_gossip = sum(1 for g in hist.is_global[: r + 1] if not g)
    n_server = (r + 1) - n_gossip
    bm = hist.byte_model
    return {
        "rounds": r + 1,
        "gossip_bytes": n_gossip * bm.gossip_round_bytes,
        "server_bytes": n_server * bm.server_round_bytes,
        "total_bytes": bm.total_bytes(n_gossip, n_server),
    }


def run(quick: bool = False, seeds=(0, 1, 2)) -> dict:
    rounds = 150 if quick else 600
    p_grid = [0.05, 0.1] if quick else P_GRID
    seeds = seeds[:1] if quick else seeds
    grad_target = 0.002

    workloads = {
        seed: make_logreg_workload(quick=quick, seed=seed) for seed in seeds
    }
    results = {}
    for comp in COMPRESSORS:
        for p in p_grid:
            per_seed = []
            for seed in seeds:
                data, loss_fn, eval_fn, params0 = workloads[seed]
                hist, _ = run_pisco_variant(
                    data=data, loss_fn=loss_fn, eval_fn=eval_fn, params0=params0,
                    p=p, t_o=1, eta_l=0.5, rounds=rounds, seed=seed,
                    compression=comp,
                )
                per_seed.append(_bytes_to_target(hist, grad_target))
            key = f"comp={comp or 'none'},p={p:.4f}"
            vals = [s for s in per_seed if s is not None]
            if not vals:
                results[key] = None
                continue
            agg = {
                k: float(np.mean([v[k] for v in vals]))
                for k in ("rounds", "gossip_bytes", "server_bytes", "total_bytes")
            }
            agg["n_reached"] = len(vals)
            results[key] = agg
    payload = {"bench": "fig_compression", "quick": quick, "results": results}
    save_result("fig_compression", payload)
    return payload


def best_same_p_savings(results: dict):
    """Max gossip-byte savings of any compressed cell vs fp32 *at the same p*
    (isolates codec savings from schedule savings).  Lives here, next to the
    result-key format it parses.  Returns None if no pair is comparable."""
    savings = []
    for key, agg in results.items():
        if key.startswith("comp=none") or not agg:
            continue
        p_key = key.split(",", 1)[1]
        base = results.get(f"comp=none,{p_key}")
        if base:
            savings.append(base["gossip_bytes"] / max(1.0, agg["gossip_bytes"]))
    return max(savings) if savings else None


def main():
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    payload = run(quick=args.quick)
    print(f"{'compressor,p':>20} | {'rounds':>7} {'gossip MB':>10} {'total MB':>9}")
    for key, agg in payload["results"].items():
        if agg is None:
            print(f"{key:>20} | {'target never reached':>28}")
            continue
        print(
            f"{key:>20} | {agg['rounds']:7.1f} "
            f"{agg['gossip_bytes'] / 1e6:10.3f} {agg['total_bytes'] / 1e6:9.3f}"
        )


if __name__ == "__main__":
    main()
