"""Figure 6 reproduction: robustness to topology + extreme heterogeneity.

1-hidden-layer MLP (32 sigmoid units) on the sorted synthetic-MNIST split
(each agent holds ONE class), T_o=10, over
(a) a well-connected ER(0.3) graph and (b) a disconnected ER(0.1) graph;
p in {1, 10^-0.5, 10^-1, 0}.

Claims validated: semi-decentralized (0<p<1) tracks p=1 closely on both
graphs; p=0 degrades sharply when the graph is disconnected.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import run_pisco_variant, save_result
from repro.data import FederatedDataset
from repro.data.synthetic import synthetic_mnist
from repro.models import simple as S

P_GRID = [1.0, 10**-0.5, 10**-1, 0.0]


def make_mnist_workload(quick: bool = False, seed: int = 0):
    n_samples = 3000 if quick else 20000
    x, y = synthetic_mnist(n_samples, seed=seed)
    data = FederatedDataset.from_arrays(x, y, 10, heterogeneous=True, seed=seed)
    loss_fn = S.mlp_loss

    xe, ye = jnp.asarray(data.x_test), jnp.asarray(data.y_test)
    xt = jnp.asarray(np.concatenate(data.x_train, axis=0))
    yt = jnp.asarray(np.concatenate(data.y_train, axis=0))

    @jax.jit
    def _metrics(params):
        loss = S.mlp_loss(params, (xt, yt))
        return loss, S.mlp_accuracy(params, xe, ye)

    def eval_fn(params):
        loss, acc = _metrics(params)
        return {"train_loss": float(loss), "test_acc": float(acc)}

    params0 = S.mlp_init(jax.random.PRNGKey(seed))
    return data, loss_fn, eval_fn, params0


def run(quick: bool = False, seed: int = 0) -> dict:
    rounds = 60 if quick else 300
    graphs = {
        "er_connected": {"name": "erdos_renyi", "kw": {"prob": 0.3, "seed": 7}},
        "er_disconnected": {"name": "erdos_renyi", "kw": {"prob": 0.08, "seed": 23}},
    }
    results = {}
    for gname, g in graphs.items():
        for p in P_GRID:
            data, loss_fn, eval_fn, params0 = make_mnist_workload(quick=quick, seed=seed)
            hist, topo = run_pisco_variant(
                data=data, loss_fn=loss_fn, eval_fn=eval_fn, params0=params0,
                topology_name=g["name"], topo_kwargs=g["kw"],
                p=p, t_o=10, eta_l=0.2, rounds=rounds, batch=100, seed=seed,
                eval_every=max(1, rounds // 30),
            )
            key = f"{gname},p={p:.4f}"
            results[key] = {
                "lambda_w": topo.lambda_w,
                "connected": bool(topo.connected),
                "final_train_loss": hist.eval_metrics[-1]["train_loss"],
                "final_test_acc": hist.eval_metrics[-1]["test_acc"],
            }
    payload = {"bench": "fig6_topology", "quick": quick, "results": results}
    save_result("fig6_topology", payload)
    return payload


def main():
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    payload = run(quick=args.quick)
    print(f"{'config':>28} | {'lam_w':>6} | {'loss':>8} | {'test acc':>8}")
    for key, r in payload["results"].items():
        print(
            f"{key:>28} | {r['lambda_w']:6.3f} | {r['final_train_loss']:8.4f} | "
            f"{r['final_test_acc']:8.3f}"
        )


if __name__ == "__main__":
    main()
