"""Dynamic-network scenario sweep: link failures × participation × topology.

The paper's experiments freeze the gossip graph; the realistic regime
(Rodio et al.; FedDec) samples links and clients every round.  This sweep
runs PISCO over the dynamic :class:`~repro.core.topology.TopologyProcess`
stack — i.i.d. Bernoulli link failures at several failure probabilities,
partial m-of-n server participation — on multiple base topologies, and reads
out *realized* communication (the accountant prices the edges and
participants that actually fired, not the static round constants).

Emits both ``BENCH_dynamic.json`` and a flat ``fig_dynamic.csv`` under
``artifacts/bench/``.

    PYTHONPATH=src python -m benchmarks.fig_dynamic [--quick]
"""
from __future__ import annotations

import csv
import os

import numpy as np

from benchmarks.common import ARTIFACTS, make_logreg_workload, run_pisco_variant, save_result

FAILURE_GRID = [0.0, 0.3, 0.6]
PARTICIPATION_GRID = [1.0, 0.5]
TOPOLOGIES = ["ring", "full"]

CSV_FIELDS = (
    "topology", "failure_prob", "participation", "rounds_to_target",
    "bytes_to_target", "gossip_bytes", "server_bytes", "total_bytes",
    "final_grad_sq",
)


def _cell_readout(hist, grad_target: float) -> dict:
    """Rounds + realized bytes when the running-mean grad norm first crosses
    the target (None when never reached), plus realized totals."""
    acct = hist.accountant
    cum_bytes = np.cumsum(acct.per_round_bytes)
    r = hist.rounds_to_threshold("grad_sq", grad_target, mode="running_le")
    return {
        "rounds_to_target": None if r is None else r + 1,
        "bytes_to_target": None if r is None else int(cum_bytes[r]),
        "gossip_bytes": int(acct.agent_to_agent_bytes),
        "server_bytes": int(acct.agent_to_server_bytes),
        "total_bytes": int(acct.total_bytes),
        "final_grad_sq": float(hist.grad_sq_norm[-1]),
    }


def run(quick: bool = False, seed: int = 0) -> dict:
    rounds = 150 if quick else 600
    failures = [0.0, 0.4] if quick else FAILURE_GRID
    parts = PARTICIPATION_GRID
    topologies = ["ring"] if quick else TOPOLOGIES
    grad_target = 0.002

    data, loss_fn, eval_fn, params0 = make_logreg_workload(quick=quick, seed=seed)
    results = {}
    rows = []
    for topo in topologies:
        for q in failures:
            for frac in parts:
                hist, _ = run_pisco_variant(
                    data=data, loss_fn=loss_fn, eval_fn=eval_fn,
                    params0=params0, topology_name=topo,
                    p=0.1, t_o=1, eta_l=0.5, rounds=rounds, seed=seed,
                    network=f"bernoulli:{q}" if q > 0 else "static",
                    participation=frac,
                )
                cell = _cell_readout(hist, grad_target)
                key = f"topo={topo},q={q:.2f},part={frac:.2f}"
                results[key] = cell
                rows.append(
                    dict(topology=topo, failure_prob=q, participation=frac, **cell)
                )
    payload = {"bench": "fig_dynamic", "quick": quick, "results": results}
    save_result("BENCH_dynamic", payload)
    os.makedirs(ARTIFACTS, exist_ok=True)
    csv_path = os.path.join(ARTIFACTS, "fig_dynamic.csv")
    with open(csv_path, "w", newline="") as f:
        writer = csv.DictWriter(f, fieldnames=CSV_FIELDS)
        writer.writeheader()
        writer.writerows(rows)
    payload["csv"] = csv_path
    return payload


def participation_byte_savings(results: dict):
    """Server-byte savings of half participation vs full, same topology and
    failure prob (the honest realized-edge readout).  None if incomparable."""
    savings = []
    for key, cell in results.items():
        if ",part=0.50" not in key or not cell:
            continue
        base = results.get(key.replace(",part=0.50", ",part=1.00"))
        if base and base["server_bytes"] and cell["server_bytes"]:
            savings.append(base["server_bytes"] / cell["server_bytes"])
    return max(savings) if savings else None


def main():
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    payload = run(quick=args.quick)
    print(f"{'scenario':>32} | {'rounds':>7} {'MB@target':>10} {'final |g|^2':>12}")
    for key, cell in payload["results"].items():
        rt = cell["rounds_to_target"]
        bt = cell["bytes_to_target"]
        print(
            f"{key:>32} | "
            f"{rt if rt is not None else '---':>7} "
            f"{bt / 1e6 if bt is not None else float('nan'):10.3f} "
            f"{cell['final_grad_sq']:12.3e}"
        )
    print(f"csv: {payload['csv']}")


if __name__ == "__main__":
    main()
