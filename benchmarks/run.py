"""Benchmark harness — one entry per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--full]

Default (quick) mode runs reduced sizes suitable for the CPU container; each
row prints `name,seconds,derived` CSV.  --full reproduces the paper-scale
settings (slower).  Individual figures: `python -m benchmarks.fig4_p_sweep`.
"""
from __future__ import annotations

import argparse
import time


def _row(name: str, seconds: float, derived: str) -> None:
    print(f"{name},{seconds:.2f},{derived}")


# Every figure/table this harness knows how to run.  "ablation" and "driver"
# are opt-in (not part of the default sweep).
KNOWN = (
    "fig4", "fig5", "fig6", "fig7", "table2", "roofline", "compression",
    "dynamic", "optimizers", "timecost", "sparse", "async", "robust",
    "serve", "ablation", "driver",
)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true", help="paper-scale settings")
    ap.add_argument(
        "--only", nargs="*", default=None,
        help=f"subset of: {' '.join(KNOWN)}",
    )
    args = ap.parse_args()
    quick = not args.full
    only = set(args.only) if args.only else None
    if only is not None:
        unknown = only - set(KNOWN)
        if unknown:
            ap.error(
                f"unknown figure name(s): {' '.join(sorted(unknown))}; "
                f"choose from: {' '.join(KNOWN)}"
            )

    print("name,seconds,derived")

    if only is None or "fig4" in only:
        from benchmarks import fig4_p_sweep

        t0 = time.perf_counter()
        payload = fig4_p_sweep.run(quick=quick)
        res = payload["results"]
        p0 = next((v for k, v in res.items() if k.startswith("p=0.0000")), None)
        pm = min(
            (v for k, v in res.items() if not k.startswith("p=0.0000") and v["train"]),
            key=lambda v: v["train"]["rounds"],
            default=None,
        )
        p1 = res.get("p=1.0000")
        derived = "n/a"
        if p0 and p0["train"] and pm:
            saving = 1.0 - pm["train"]["a2a"] / max(1.0, p0["train"]["a2a"])
            derived = f"a2a_savings_vs_p0={saving:.0%}"
        elif pm and p1 and p1["train"]:
            # p=0 never reached the target (the strongest form of the claim)
            derived = (
                f"p0_never_reached;best_semi_a2s={pm['train']['a2s']:.0f}"
                f";p1_a2s={p1['train']['a2s']:.0f}"
            )
        _row("fig4_p_sweep", time.perf_counter() - t0, derived)

    if only is None or "fig5" in only:
        from benchmarks import fig5_local_updates

        t0 = time.perf_counter()
        payload = fig5_local_updates.run(quick=quick)
        res = payload["results"]
        r1 = res.get("T_o=1,p=0.1000", {}).get("train_rounds")
        r10 = res.get("T_o=10,p=0.1000", {}).get("train_rounds")
        derived = (
            f"rounds_T1={r1:.0f};rounds_T10={r10:.0f}" if r1 and r10 else "n/a"
        )
        _row("fig5_local_updates", time.perf_counter() - t0, derived)

    if only is None or "fig6" in only:
        from benchmarks import fig6_topology

        t0 = time.perf_counter()
        payload = fig6_topology.run(quick=quick)
        res = payload["results"]
        dis0 = res.get("er_disconnected,p=0.0000", {}).get("final_train_loss")
        dis1 = res.get("er_disconnected,p=0.1000", {}).get("final_train_loss")
        derived = (
            f"disc_loss_p0={dis0:.3f};p0.1={dis1:.3f}" if dis0 and dis1 else "n/a"
        )
        _row("fig6_topology", time.perf_counter() - t0, derived)

    if only is None or "fig7" in only:
        from benchmarks import fig7_cnn

        t0 = time.perf_counter()
        payload = fig7_cnn.run(quick=quick)
        res = payload["results"]
        accs = {k: v["final_test_acc"] for k, v in res.items()}
        derived = ";".join(f"{k}={v:.2f}" for k, v in accs.items())
        _row("fig7_cnn", time.perf_counter() - t0, derived)

    if only is None or "compression" in only:
        from benchmarks import fig_compression

        t0 = time.perf_counter()
        payload = fig_compression.run(quick=quick)
        res = payload["results"]
        saving = fig_compression.best_same_p_savings(res)
        derived = (
            f"gossip_byte_savings_vs_fp32={saving:.1f}x" if saving else "n/a"
        )
        _row("fig_compression", time.perf_counter() - t0, derived)

    if only is None or "dynamic" in only:
        from benchmarks import fig_dynamic

        t0 = time.perf_counter()
        payload = fig_dynamic.run(quick=quick)
        saving = fig_dynamic.participation_byte_savings(payload["results"])
        _row(
            "fig_dynamic",
            time.perf_counter() - t0,
            f"server_byte_savings_half_part={saving:.2f}x" if saving else "n/a",
        )

    if only is None or "optimizers" in only:
        from benchmarks import fig_optimizers

        t0 = time.perf_counter()
        payload = fig_optimizers.run(quick=quick)
        s = fig_optimizers.best_adaptive_speedup(payload["results"])
        _row(
            "fig_optimizers",
            time.perf_counter() - t0,
            f"best_adaptive_speedup={s:.2f}x" if s else "n/a",
        )

    if only is None or "timecost" in only:
        from benchmarks import fig_timecost

        t0 = time.perf_counter()
        payload = fig_timecost.run(quick=quick)
        flip = fig_timecost.tuner_flip(payload["profiles"])
        derived = (
            f"best_p_lan={flip[0]:g};best_p_wan={flip[1]:g}" if flip else "n/a"
        )
        _row("fig_timecost", time.perf_counter() - t0, derived)

    if only is None or "async" in only:
        from benchmarks import fig_async

        t0 = time.perf_counter()
        payload = fig_async.run(quick=quick)
        speed = fig_async.async_flip(payload["profiles"])
        trivial_ok = payload["profiles"]["free"]["bit_identical_loss"]
        derived = (
            f"free_bit_identical={trivial_ok}"
            + "".join(f";{k}_speedup={v:.2f}x" for k, v in speed.items()
                      if k != "free")
        )
        _row("fig_async", time.perf_counter() - t0, derived)

    if only is None or "robust" in only:
        from benchmarks import fig_robust

        t0 = time.perf_counter()
        payload = fig_robust.run(quick=quick)
        rows = payload["rows"]
        clean = payload["clean_final_loss"]
        trim_ratio = rows["signflip+trimmed"]["final_loss"] / max(clean, 1e-12)
        mean_ratio = rows["signflip+mean"]["final_loss"] / max(clean, 1e-12)
        derived = (
            f"flip={payload['robustness_flip']}"
            f";trimmed_vs_clean={trim_ratio:.2f}x"
            f";mean_vs_clean={mean_ratio:.2f}x"
        )
        _row("fig_robust", time.perf_counter() - t0, derived)

    if only is None or "table2" in only:
        from benchmarks import table2_complexity

        t0 = time.perf_counter()
        payload = table2_complexity.run(quick=quick)
        nd = payload["network_dependency"]
        r = next(x for x in nd if x["lambda_w"] == 1e-4 and 0 < x["p"] < 1 and x["p"] > x["lambda_w"])
        derived = f"lam1e-4_sqrtp_dependency={r['network_term']:.1e}"
        _row("table2_complexity", time.perf_counter() - t0, derived)

    if only is not None and "ablation" in only:
        from benchmarks import ablation_eta_c

        t0 = time.perf_counter()
        payload = ablation_eta_c.run(quick=quick)
        best = min(
            (v["final_grad_sq"] for v in payload["results"].values()),
        )
        _row("ablation_eta_c", time.perf_counter() - t0, f"best_grad_sq={best:.2e}")

    if only is not None and "driver" in only:
        from benchmarks import bench_driver

        t0 = time.perf_counter()
        payload = bench_driver.run(quick=quick)
        _row(
            "bench_driver",
            time.perf_counter() - t0,
            f"scan_speedup={payload['speedup']:.2f}x",
        )

    if only is None or "sparse" in only:
        from benchmarks import fig_sparse

        t0 = time.perf_counter()
        payload = fig_sparse.run(quick=quick)
        ratio = fig_sparse.memory_ratio(payload["results"])
        biggest = max(payload["results"].values(), key=lambda r: r["n_agents"])
        derived = (
            f"mem_savings_n{biggest['n_agents']}={ratio:.0f}x"
            f";per_round_ms={biggest['per_round_s'] * 1e3:.1f}"
            f";parity_n{payload['parity']['n']}={payload['parity']['ok']}"
        )
        _row("fig_sparse", time.perf_counter() - t0, derived)

    if only is None or "serve" in only:
        from benchmarks import fig_serve

        t0 = time.perf_counter()
        payload = fig_serve.run(quick=quick)
        mem = payload["memory"]["64"]["ratio"]
        bit = all(payload["bit_identity"][k] for k in
                  ("admit_vs_dense", "step_vs_dense"))
        best = max(v["tokens_per_s"] for v in payload["rates"].values())
        derived = (
            f"mem_savings_n64={mem:.0f}x;bit_identical={bit}"
            f";best_tok_s={best:.0f}"
        )
        _row("fig_serve", time.perf_counter() - t0, derived)

    if only is None or "roofline" in only:
        from benchmarks import roofline
        from benchmarks.common import save_result

        t0 = time.perf_counter()
        recs = roofline.load_records()
        s = roofline.summarize(recs)
        # persist the aggregation so the regression gate can pin n_fail == 0
        save_result(
            "BENCH_roofline",
            {"bench": "roofline", "quick": quick, "summary": s},
        )
        derived = f"ok={s['n_ok']};fail={s['n_fail']};dominant={s['dominant_counts']}"
        _row("roofline", time.perf_counter() - t0, derived.replace(",", ";"))

    # re-index whatever BENCH_* artifacts now exist (this run's plus any
    # earlier ones in the same artifacts dir) for benchmarks/check_regress.py
    from benchmarks.common import write_manifest

    write_manifest()


if __name__ == "__main__":
    main()
