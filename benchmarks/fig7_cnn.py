"""Figure 7 reproduction: CNN on the sorted synthetic-CIFAR split.

Ring of 5 agents (agent i holds classes {i, i+5}), batch 20, T_o=4,
p in {1, 1/sqrt(5), 0.2, 0}.  Claim: p=0 converges more slowly under the
sparse ring + extreme heterogeneity; p = 1/sqrt(5) ~ p=1.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import run_pisco_variant, save_result
from repro.data import FederatedDataset
from repro.data.synthetic import synthetic_cifar
from repro.models import simple as S

P_GRID = [1.0, 1.0 / np.sqrt(5), 0.2, 0.0]


def make_cifar_workload(quick: bool = False, seed: int = 0):
    n_samples = 1500 if quick else 8000
    x, y = synthetic_cifar(n_samples, seed=seed)
    # paper split: agent i gets labels i and i+5 => sorted split across 5
    data = FederatedDataset.from_arrays(x, y, 5, heterogeneous=True, seed=seed)
    loss_fn = S.cnn_loss
    xe, ye = jnp.asarray(data.x_test), jnp.asarray(data.y_test)

    @jax.jit
    def _metrics(params):
        loss = S.cnn_loss(params, (xe, ye))
        return loss, S.cnn_accuracy(params, xe, ye)

    def eval_fn(params):
        loss, acc = _metrics(params)
        return {"test_loss": float(loss), "test_acc": float(acc)}

    params0 = S.cnn_init(jax.random.PRNGKey(seed))
    return data, loss_fn, eval_fn, params0


def run(quick: bool = False, seed: int = 0) -> dict:
    rounds = 20 if quick else 120
    results = {}
    for p in P_GRID:
        data, loss_fn, eval_fn, params0 = make_cifar_workload(quick=quick, seed=seed)
        hist, topo = run_pisco_variant(
            data=data, loss_fn=loss_fn, eval_fn=eval_fn, params0=params0,
            topology_name="ring", p=p, t_o=4, eta_l=0.05, rounds=rounds,
            batch=20, seed=seed, eval_every=max(1, rounds // 15),
        )
        results[f"p={p:.4f}"] = {
            "final_test_loss": hist.eval_metrics[-1]["test_loss"],
            "final_test_acc": hist.eval_metrics[-1]["test_acc"],
            "loss_curve": [m["test_loss"] for m in hist.eval_metrics],
        }
    payload = {"bench": "fig7_cnn", "quick": quick, "results": results}
    save_result("fig7_cnn", payload)
    return payload


def main():
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    payload = run(quick=args.quick)
    print(f"{'p':>8} | {'test loss':>9} | {'test acc':>8}")
    for key, r in payload["results"].items():
        print(f"{key[2:]:>8} | {r['final_test_loss']:9.4f} | {r['final_test_acc']:8.3f}")


if __name__ == "__main__":
    main()
