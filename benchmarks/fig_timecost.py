"""Simulated time-to-target across systems profiles: which p/τ is fastest?

The paper ranks configurations by communication *rounds* (Fig. 4) and PR 1
added *bytes*; this benchmark adds the axis that actually decides deployments
— simulated **wall-clock** under a systems-cost profile (DESIGN.md §11).  It
runs the p × τ autotuner once on the §5.1 logreg workload, then re-prices the
same trajectories under every profile (pure ``(seed, k)`` draws make repricing
free), and compares PISCO's frontier against FedAvg and DSGT.

Claims exercised:

* under the free-network profile (zero latency, infinite bandwidth) the
  ranking over ``p`` collapses to the rounds/bytes ranking of
  ``fig4_p_sweep`` — time adds nothing when the network is free;
* under ``wan-gossip`` (expensive peer links) the fastest configuration
  moves to a *higher* ``p`` than under ``lan-gossip`` (cheap peers, far
  server) — the paper's trade-off, now with a time axis.

Emits ``BENCH_timecost.json`` under ``artifacts/bench/``.

    PYTHONPATH=src python -m benchmarks.fig_timecost [--quick]
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import make_logreg_workload, save_result
from repro.core import ExperimentSpec
from repro.data import RoundSampler
from repro.sim import FREE_NETWORK, retime, tune

P_GRID = [0.0, 0.03, 0.1, 0.3, 1.0]
TAU_GRID = (1, 4)
PROFILES_SWEPT = (
    ("free", FREE_NETWORK),
    ("lan-gossip", "lan-gossip"),
    ("wan-gossip", "wan-gossip"),
    ("lognormal-stragglers", "lognormal-stragglers"),
    ("edge-vs-datacenter", "edge-vs-datacenter"),
)


def _curve(point, window: int, systems: str, max_points: int = 60) -> dict:
    """Downsampled (cumulative sim seconds, smoothed loss) trajectory, priced
    under ``systems`` — NOT the history's online ledger, which belongs to the
    profile the sweep originally ran under."""
    from repro.sim import price_history
    from repro.sim.tuner import _smoothed

    secs = np.cumsum(price_history(point.history, point.spec, systems=systems))
    loss = _smoothed(point.history.loss, window)
    idx = np.unique(
        np.linspace(0, len(secs) - 1, min(max_points, len(secs))).astype(int)
    )
    return {
        "sim_time_s": secs[idx].round(4).tolist(),
        "loss": loss[idx].round(6).tolist(),
    }


def _bytes_ranking(result) -> list:
    """(p, t_o) ranked by bytes-to-target — the fig4-style readout on the
    identical trajectories (unreached configs last, by loss)."""
    pts = sorted(
        result.points,
        key=lambda pt: (
            0 if pt.bytes_to_target is not None else 1,
            pt.bytes_to_target if pt.bytes_to_target is not None else 0,
            pt.final_loss,
        ),
    )
    return [[pt.p, pt.t_o] for pt in pts]


def run(quick: bool = False, seed: int = 0) -> dict:
    rounds = 150 if quick else 600
    p_grid = [0.0, 0.1, 1.0] if quick else P_GRID
    tau_grid = (1,) if quick else TAU_GRID
    profiles = PROFILES_SWEPT[:3] if quick else PROFILES_SWEPT

    data, loss_fn, _eval_fn, params0 = make_logreg_workload(quick=quick, seed=seed)
    b = min(256, data.samples_per_agent)
    pieces = dict(
        loss_fn=loss_fn,
        params0=params0,
        sampler_factory=lambda s: RoundSampler(
            data, batch_size=b, t_o=s.config.t_o, seed=s.config.seed
        ),
    )

    def base_spec(algo: str, p: float = 0.1, t_o: int = 1) -> ExperimentSpec:
        return ExperimentSpec.create(
            algo=algo, n_agents=data.n_agents, t_o=t_o, eta_l=0.5, p=p,
            seed=seed, rounds=rounds, eval_every=rounds, driver="scan",
        )

    # one training pass per (p, tau); every profile is a repricing
    first = profiles[0][1]
    pisco = tune(
        base_spec("pisco"), pieces, p_grid=p_grid, tau_grid=tau_grid,
        systems=first, strategy="grid",
    )
    target = pisco.target_loss
    baselines = {
        "fedavg": tune(
            base_spec("fedavg"), pieces, p_grid=[1.0], systems=first,
            target_loss=target,
        ),
        "dsgt": tune(
            base_spec("dsgt", p=0.1), pieces, p_grid=[0.1], systems=first,
            target_loss=target,
        ),
    }

    per_profile = {}
    for label, prof in profiles:
        tuned = pisco if prof == first else retime(pisco, prof)
        curves = {
            f"pisco:p={tuned.best.p:g},tau={tuned.best.t_o}": _curve(
                tuned.best, tuned.window, prof
            )
        }
        bl = {}
        for name, res in baselines.items():
            r = res if prof == first else retime(res, prof)
            bl[name] = r.points[0].to_dict()
            curves[name] = _curve(r.points[0], tuned.window, prof)
        per_profile[label] = {
            "tuner": tuned.to_dict(),
            "best_p": tuned.best.p,
            "best_tau": tuned.best.t_o,
            "baselines": bl,
            "curves": curves,
        }

    consistency = {
        "free_time_ranking": [[p, t] for p, t in pisco.ranking()],
        "free_bytes_ranking": _bytes_ranking(pisco),
    }
    payload = {
        "bench": "fig_timecost",
        "quick": quick,
        "target_loss": target,
        "profiles": per_profile,
        "consistency": consistency,
    }
    save_result("BENCH_timecost", payload)
    return payload


def tuner_flip(results: dict):
    """Best-p under wan-gossip vs lan-gossip — the trade-off readout."""
    lan = results.get("lan-gossip")
    wan = results.get("wan-gossip")
    if not lan or not wan:
        return None
    return lan["best_p"], wan["best_p"]


def main():
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    payload = run(quick=args.quick)
    print(f"target smoothed loss: {payload['target_loss']:.4f}")
    print(f"{'profile':>22} | {'best p':>7} {'tau':>4} | "
          f"{'sim s->target':>13} | baselines (fedavg / dsgt)")
    for label, cell in payload["profiles"].items():
        best = cell["tuner"]["best"]
        tts = best["time_to_target_s"]
        fa = cell["baselines"]["fedavg"]["time_to_target_s"]
        dg = cell["baselines"]["dsgt"]["time_to_target_s"]
        fmt = lambda v: f"{v:.2f}" if v is not None else "---"
        print(f"{label:>22} | {best['p']:7.2f} {best['t_o']:4d} | "
              f"{fmt(tts):>13} | {fmt(fa)} / {fmt(dg)}")
    flip = tuner_flip(payload["profiles"])
    if flip:
        print(f"best p: lan-gossip={flip[0]:g} -> wan-gossip={flip[1]:g}")


if __name__ == "__main__":
    main()
