"""Ablation: the communication step size eta_c (Theorem 1 sets
eta_c = alpha*sqrt(1+p)*lambda_p).  Sweeps eta_c x p on the ring-logreg
workload; validates that (a) eta_c=1 (full mixing) is stable and fastest on
well-connected graphs, (b) smaller eta_c trades per-round progress for
robustness — the damped-mixing knob the paper's analysis exposes.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import (
    comm_rounds_to_targets,
    make_logreg_workload,
    run_pisco_variant,
    save_result,
)


def run(quick: bool = False, seed: int = 0) -> dict:
    rounds = 150 if quick else 400
    results = {}
    for p in (0.0, 0.1):
        for eta_c in (0.25, 0.5, 1.0):
            data, loss_fn, eval_fn, params0 = make_logreg_workload(quick=quick, seed=seed)
            hist, topo = run_pisco_variant(
                data=data, loss_fn=loss_fn, eval_fn=eval_fn, params0=params0,
                p=p, t_o=2, eta_l=0.4, eta_c=eta_c, rounds=rounds, seed=seed,
            )
            r = comm_rounds_to_targets(hist, 0.002, 0.75)
            results[f"p={p},eta_c={eta_c}"] = {
                "rounds_to_grad": r["train"]["rounds"] if r["train"] else None,
                "final_grad_sq": hist.eval_metrics[-1]["grad_sq"],
                "lambda_p": topo.expected_rate(p),
            }
    payload = {"bench": "ablation_eta_c", "quick": quick, "results": results}
    save_result("ablation_eta_c", payload)
    return payload


def main():
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    payload = run(quick=args.quick)
    print(f"{'config':>20} | {'rounds':>7} | {'final grad^2':>12} | {'lam_p':>6}")
    for key, r in payload["results"].items():
        rr = f"{r['rounds_to_grad']:7.0f}" if r["rounds_to_grad"] else f"{'n/a':>7}"
        print(f"{key:>20} | {rr} | {r['final_grad_sq']:12.6f} | {r['lambda_p']:6.3f}")


if __name__ == "__main__":
    main()
