"""Figure 4 reproduction: communication rounds (agent-to-agent vs
agent-to-server) required to reach 0.05 training gradient-norm and the test
accuracy target, sweeping the server probability p on a ring of 10 agents
(logistic regression + nonconvex regularizer, sorted a9a-like split, T_o=1).

Paper claims validated:
* a small p (~0.06-0.1) cuts agent-to-agent rounds by a large factor vs p=0
  at the cost of only a handful of server rounds;
* increasing p beyond ~0.1 yields no further total-round savings.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import (
    comm_rounds_to_targets,
    make_logreg_workload,
    run_pisco_variant,
    save_result,
)

P_GRID = [0.0, 10**-2, 10**-1.75, 10**-1.5, 10**-1.25, 10**-1, 10**-0.75, 10**-0.5, 1.0]


def run(quick: bool = False, seeds=(0, 1, 2)) -> dict:
    rounds = 150 if quick else 600
    p_grid = [0.0, 0.03, 0.1, 0.3, 1.0] if quick else P_GRID
    seeds = seeds[:1] if quick else seeds
    # thresholds re-calibrated for the synthetic a9a stand-in (same protocol
    # as the paper: grad-norm target + ~95%-of-peak test accuracy)
    grad_target = 0.002
    acc_target = 0.75

    results = {}
    for p in p_grid:
        per_seed = []
        for seed in seeds:
            data, loss_fn, eval_fn, params0 = make_logreg_workload(quick=quick, seed=seed)
            hist, topo = run_pisco_variant(
                data=data, loss_fn=loss_fn, eval_fn=eval_fn, params0=params0,
                p=p, t_o=1, eta_l=0.5, rounds=rounds, seed=seed,
            )
            per_seed.append(comm_rounds_to_targets(hist, grad_target, acc_target))
        key = f"p={p:.4f}"
        results[key] = _aggregate(per_seed)
    payload = {"bench": "fig4_p_sweep", "quick": quick, "results": results}
    save_result("fig4_p_sweep", payload)
    return payload


def _aggregate(per_seed):
    agg = {}
    for phase in ("train", "test"):
        vals = [s[phase] for s in per_seed if s[phase] is not None]
        if not vals:
            agg[phase] = None
            continue
        agg[phase] = {
            k: float(np.mean([v[k] for v in vals])) for k in ("rounds", "a2a", "a2s")
        }
        agg[phase]["n_reached"] = len(vals)
    return agg


def main():
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    payload = run(quick=args.quick)
    print(f"{'p':>8} | {'train rounds':>12} {'a2a':>7} {'a2s':>6} | {'test rounds':>11}")
    for key, agg in payload["results"].items():
        tr = agg["train"]
        te = agg["test"]
        tr_s = (
            f"{tr['rounds']:12.1f} {tr['a2a']:7.1f} {tr['a2s']:6.1f}"
            if tr else f"{'n/a':>27}"
        )
        te_s = f"{te['rounds']:11.1f}" if te else f"{'n/a':>11}"
        print(f"{key[2:]:>8} | {tr_s} | {te_s}")


if __name__ == "__main__":
    main()
