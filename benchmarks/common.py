"""Shared harness for the paper-figure benchmarks."""
from __future__ import annotations

import functools
import json
import os
from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import Experiment, ExperimentSpec, make_topology
from repro.data import FederatedDataset, RoundSampler
from repro.models import simple as S

ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "artifacts", "bench")


def save_result(name: str, payload: dict) -> str:
    os.makedirs(ARTIFACTS, exist_ok=True)
    path = os.path.join(ARTIFACTS, f"{name}.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)
    return path


def write_manifest(art_dir: str = ARTIFACTS) -> str:
    """Index the ``BENCH_*.json`` artifacts for the regression gate.

    The manifest maps each bench key (``BENCH_driver.json`` -> ``driver``) to
    its artifact filename, stamped with the git rev the baselines were built
    at and the gate schema version, so ``benchmarks/check_regress.py`` can
    pair baseline/fresh runs without guessing at globs."""
    import glob
    import subprocess

    from repro.obs.regress import BENCH_SCHEMA_VERSION, bench_key

    try:
        rev = subprocess.run(
            ["git", "rev-parse", "HEAD"],
            capture_output=True, text=True, timeout=10,
            cwd=os.path.dirname(os.path.abspath(__file__)),
        ).stdout.strip() or None
    except (OSError, subprocess.SubprocessError):
        rev = None
    benches = {}
    for path in sorted(glob.glob(os.path.join(art_dir, "BENCH_*.json"))):
        fname = os.path.basename(path)
        benches[bench_key(fname)] = {"path": fname}
    manifest = {
        "schema_version": BENCH_SCHEMA_VERSION,
        "git_rev": rev,
        "benches": benches,
    }
    os.makedirs(art_dir, exist_ok=True)
    out = os.path.join(art_dir, "MANIFEST.json")
    with open(out, "w") as f:
        json.dump(manifest, f, indent=1)
    return out


def make_logreg_workload(n_agents: int = 10, quick: bool = False, seed: int = 0):
    """§5.1 workload: synthetic-a9a, sorted split, logreg + nonconvex reg."""
    from repro.data.synthetic import synthetic_a9a

    n_samples = 4000 if quick else 32560
    x, y = synthetic_a9a(n_samples, seed=seed)
    data = FederatedDataset.from_arrays(x, y, n_agents, heterogeneous=True, seed=seed)
    loss_fn = functools.partial(S.logreg_loss, rho=0.01)

    xt = jnp.asarray(np.concatenate(data.x_train, axis=0))
    yt = jnp.asarray(np.concatenate(data.y_train, axis=0))
    xe = jnp.asarray(data.x_test)
    ye = jnp.asarray(data.y_test)

    @jax.jit
    def eval_metrics(params):
        g = jax.grad(lambda p: S.logreg_loss(p, (xt, yt), 0.01))(params)
        gsq = sum(jnp.sum(v**2) for v in jax.tree.leaves(g))
        return gsq, S.logreg_accuracy(params, xe, ye)

    def eval_fn(params):
        gsq, acc = eval_metrics(params)
        return {"grad_sq": float(gsq), "test_acc": float(acc)}

    d = x.shape[1]
    return data, loss_fn, eval_fn, {"w": jnp.zeros((d,), jnp.float32)}


def run_pisco_variant(
    *,
    data: FederatedDataset,
    loss_fn,
    eval_fn,
    params0,
    topology_name: str = "ring",
    p: float = 0.1,
    t_o: int = 1,
    eta_l: float = 0.5,
    eta_c: float = 1.0,
    rounds: int = 400,
    batch: int = 256,
    seed: int = 0,
    algo: str = "pisco",
    eval_every: int = 1,
    topo_kwargs: Optional[dict] = None,
    compression: Optional[str] = None,
    error_feedback: bool = True,
    driver: str = "scan",
    network: Optional[str] = None,
    participation: float = 1.0,
    optimizer: Optional[str] = None,
    server_optimizer: Optional[str] = None,
    lr_schedule: Optional[str] = None,
    opt_policy: Optional[str] = None,
    adversary: Optional[str] = None,
    robust_agg: str = "mean",
):
    spec = ExperimentSpec.create(
        algo=algo,
        n_agents=data.n_agents,
        t_o=t_o,
        eta_l=eta_l,
        eta_c=eta_c,
        p=p,
        seed=seed,
        topology=topology_name,
        topology_kwargs=topo_kwargs or {},
        network=network,
        participation=participation,
        compression=compression,
        error_feedback=error_feedback,
        optimizer=optimizer,
        server_optimizer=server_optimizer,
        lr_schedule=lr_schedule,
        opt_policy=opt_policy,
        adversary=adversary,
        robust_agg=robust_agg,
        rounds=rounds,
        eval_every=eval_every,
        driver=driver,
    )
    # build the topology once: the returned topo is the one trained on
    topo = make_topology(topology_name, data.n_agents, **(topo_kwargs or {}))
    mixing = spec.make_mixing()
    b = min(batch, data.samples_per_agent)
    exp = Experiment(
        spec,
        loss_fn=loss_fn,
        params0=params0,
        sampler_factory=lambda s: RoundSampler(
            data, batch_size=b, t_o=s.config.t_o, seed=s.config.seed
        ),
        eval_fn=eval_fn,
        mixing=mixing,
    )
    hist = exp.run()
    return hist, topo


def comm_rounds_to_targets(hist, grad_target=0.05, acc_target=0.80):
    """Paper Fig. 4 readout: (a2a, a2s) rounds when each target is first met."""
    out = {}
    for name, key, target, mode in (
        ("train", "grad_sq", grad_target, "running_le"),
        ("test", "test_acc", acc_target, "ge"),
    ):
        r = hist.rounds_to_threshold(key, target, mode=mode)
        if r is None:
            out[name] = None
        else:
            # eval_every=1 => round index == eval index
            a2a = sum(1 for g in hist.is_global[: r + 1] if not g)
            a2s = sum(1 for g in hist.is_global[: r + 1] if g)
            out[name] = {"rounds": r + 1, "a2a": a2a, "a2s": a2s}
    return out
